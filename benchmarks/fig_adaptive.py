"""Adaptive hybrid command/value logging: the recovery-time vs log-size
frontier (arXiv:1503.03653's trade-off, plumbed through this repo's engine).

Three framing arms run the identical deterministic workload:

* ``value``    — ``AdaptivePolicy(force_value=True)``: every record ships
  full tuple images (the baseline wire format);
* ``command``  — ``force_command=True``: every *eligible* record ships
  ``(op id, param, dep SSNs)`` instead (ineligible ones — unregistered op,
  uncovered dep — still fall back to value framing: the escape hatch is
  part of the format);
* ``adaptive`` — the policy decides per record.

Two workloads: ``ycsb_rmw`` (YCSB-style field update over 1 KB tuples —
``OP_PATCH_PREFIX``, 100 B param vs 1000 B image) and ``payment``
(TPC-C-payment-style f64 balance deltas over narrow tuples —
``OP_ADD_F64``, where the byte win is thin and replay pays command
re-execution: the frontier's other end).

Per round each arm reports on-disk log bytes, ``recover()`` wall time,
replica ship bytes (a full promote from scratch), and — after a
checkpoint+truncation pass — the retained log footprint.  All three arms'
recovered images are asserted identical (the crash-equivalence invariant
tests/test_adaptive_recovery.py property-checks), and the RMW workload
must show the headline trade: ≥30 % fewer log bytes than pure-value at
≤2× its recovery time.

Emits ``BENCH_adaptive.json`` rows:
``workload,config,round,txns_total,log_bytes,cmd_records,value_records,
recover_s,ship_bytes,post_truncate_bytes``.
"""

import os
import shutil
import struct
import sys
import tempfile
import time

sys.path.insert(0, os.path.dirname(__file__))

from _util import FAST, bench_runtime_setup, emit  # noqa: E402

from repro.core import (  # noqa: E402
    CheckpointDaemon,
    EngineConfig,
    LogTruncator,
    PoplarEngine,
    recover,
)
from repro.core.engine import AdaptivePolicy  # noqa: E402
from repro.core.txn import decode_columnar  # noqa: E402
from repro.db import ArrayTable, BatchOCC  # noqa: E402
from repro.db import ycsb  # noqa: E402
from repro.replica import Replica  # noqa: E402

N_ROUNDS = 2 if FAST else 4
BATCHES_PER_ROUND = 2 if FAST else 4
BATCH = 256 if FAST else 512
N_RECORDS = 1024 if FAST else 2048
N_DEVICES = 2
ARMS = ("value", "command", "adaptive")


def _csn_fn(engine):
    def fn():
        for i in range(len(engine.buffers)):
            engine.logger_tick(i, force=True)
        return engine.commit.advance_csn()
    return fn


def _load(table: ArrayTable, workload: str) -> None:
    if workload == "ycsb_rmw":
        ycsb.load(table, N_RECORDS)
    else:  # payment: f64 balance + 24 B opaque tail
        import random
        rng = random.Random(7)
        for i in range(N_RECORDS):
            table.insert(
                ycsb.key_of(i),
                struct.pack("<d", 1000.0 + i) + rng.randbytes(24),
            )


def _full_image(table: ArrayTable):
    # full image *including ssn-0 rows*: the cover the adaptive policy's
    # dep-0 clause relies on (a filtered image would strand initial loads)
    return sorted((k.encode(), v, s) for k, v, s in table.items())


def _run_arm(workload: str, arm: str, workdir: str):
    dev_dir = os.path.join(workdir, "devs")
    ckpt_dir = os.path.join(workdir, "ckpt")
    engine = PoplarEngine(EngineConfig(
        n_buffers=N_DEVICES, device_kind="ssd", device_dir=dev_dir,
        device_clock="virtual", segment_bytes=64 * 1024,
    ))
    table = ArrayTable(capacity=N_RECORDS)
    _load(table, workload)
    daemon = CheckpointDaemon(ckpt_dir, n_threads=2, m_files=2,
                              csn_fn=_csn_fn(engine))
    policy = AdaptivePolicy(
        checkpoint_dir=ckpt_dir,
        force_value=(arm == "value"),
        force_command=(arm == "command"),
    )
    occ = BatchOCC(table, engine, n_workers=2, policy=policy)
    wl = ycsb.AdaptiveRMW(
        table, N_RECORDS, seed=11,
        op="patch" if workload == "ycsb_rmw" else "add_f64",
    )
    # checkpoint the loaded image up front so dep-0 records are coverable
    e = _full_image(table)
    daemon.run_once([e[0::2], e[1::2]], epoch=0)
    policy.refresh()

    rows = []
    txns_total = 0
    final_state = None
    for rnd in range(1, N_ROUNDS + 1):
        for _ in range(BATCHES_PER_ROUND):
            occ.execute_batch(wl.next_batch(BATCH))
            for i in range(len(engine.buffers)):
                engine.logger_tick(i, force=True)
            occ.drain()
            txns_total += BATCH
        t0 = time.perf_counter()
        state = recover(engine.devices, checkpoint_dir=ckpt_dir,
                        parallel=False)
        recover_s = time.perf_counter() - t0
        final_state = state
        n_cmd = n_rec = 0
        for d in engine.devices:
            log = decode_columnar(d.read_from(d.base_offset()))
            n_cmd += log.n_command
            n_rec += log.n_records
        rep = Replica(engine.devices, checkpoint_dir=ckpt_dir,
                      parallel=False)
        rep.drain()
        ship_bytes = sum(
            s.consumed - d.base_offset()
            for s, d in zip(rep.shippers, engine.devices)
        )
        rows.append({
            "workload": workload, "config": arm, "round": rnd,
            "txns_total": txns_total,
            "log_bytes": sum(d.disk_bytes() for d in engine.devices),
            "cmd_records": n_cmd, "value_records": n_rec - n_cmd,
            "recover_s": round(recover_s, 4),
            "ship_bytes": ship_bytes,
            "post_truncate_bytes": None,
        })
    # lifecycle tail: checkpoint the final image, truncate, report what the
    # safe-point rule (plus the command-dep pin) must retain
    e = _full_image(table)
    daemon.run_once([e[0::2], e[1::2]], epoch=N_ROUNDS)
    LogTruncator(engine, ckpt_dir).run_once()
    rows[-1]["post_truncate_bytes"] = sum(
        d.disk_bytes() for d in engine.devices
    )
    for d in engine.devices:
        d.close()
    return rows, final_state


def run() -> None:
    rows = []
    for workload in ("ycsb_rmw", "payment"):
        states = {}
        for arm in ARMS:
            workdir = tempfile.mkdtemp(prefix=f"fig_adaptive_{arm}_")
            try:
                r, state = _run_arm(workload, arm, workdir)
            finally:
                shutil.rmtree(workdir, ignore_errors=True)
            rows.extend(r)
            states[arm] = state
        for arm in ("command", "adaptive"):
            assert states[arm].data == states["value"].data, (
                f"{workload}/{arm} recovery diverged from the value oracle"
            )
        last = {r["config"]: r for r in rows
                if r["workload"] == workload and r["round"] == N_ROUNDS}
        assert last["adaptive"]["cmd_records"] > 0, "policy framed nothing"
        if workload == "ycsb_rmw":
            # the headline frontier point: ≥30 % log-byte reduction at ≤2×
            # recovery time (small absolute slack absorbs timer noise on
            # these CI-sized logs)
            v, a = last["value"], last["adaptive"]
            assert a["log_bytes"] <= 0.7 * v["log_bytes"], (
                a["log_bytes"], v["log_bytes"])
            assert a["recover_s"] <= 2.0 * v["recover_s"] + 0.05, (
                a["recover_s"], v["recover_s"])
    header = ["workload", "config", "round", "txns_total", "log_bytes",
              "cmd_records", "value_records", "recover_s", "ship_bytes",
              "post_truncate_bytes"]
    emit(rows, header, name="adaptive")


if __name__ == "__main__":
    bench_runtime_setup()
    run()
