"""Sharded engine scalability — throughput vs shard count × cross-shard ratio.

Each shard owns one log buffer + one emulated SSD, so shard count scales the
aggregate IO bandwidth exactly like fig9 scales devices — but with fully
private engines (no shared CSN, no shared buffer latch) and a router in
front.  The sweep crosses shard count (1, 2, 4) with the fraction of
transactions spanning two shards (0%, 10%, 50%); cross-shard transactions
pay the coordinator path (global base SSN, one record per participant,
commit gated on both shards' watermarks).

Emulated-SSD bandwidth is pinned low (``REPRO_SHARD_BW``, default 10 MB/s
per device) so the 1-shard configuration is firmly IO-bound on this 1-core
container — the scaling axis under test is devices-with-private-engines,
not GIL arithmetic.  Each cell reports the median of 3 runs, with the
repeats interleaved across the whole grid so a noisy host window (steal
time on this container runs ~5x) lands on every cell rather than
concentrating on one.
"""

import os
import time
from typing import List

from _util import DURATION, FAST, bench_runtime_setup, emit, robust_stats

from repro.core.engine import EngineConfig
from repro.db import TxnSpec
from repro.db.ycsb import key_of
from repro.shard import ShardedConfig, ShardedEngine

import numpy as np

SHARDS = (1, 2, 4)
RATIOS = (0.0, 0.1, 0.5)
REPEATS = 3
N_RECORDS = 4_000 if FAST else 20_000
BATCH = 1024 if FAST else 4096
VALUE_BYTES = 1000          # single-shard: 1 write; cross-shard: 2 x half
SHARD_BW = os.environ.get("REPRO_SHARD_BW", "10e6")


class ShardedYCSB:
    """Write-only YCSB with a controlled cross-shard ratio.

    Keys are pre-bucketed per shard (the router hash is stable), so a
    transaction is made single- or cross-shard by construction: one
    full-size write in one bucket, or two half-size writes in two distinct
    buckets (same total payload either way)."""

    def __init__(self, buckets: List[List[str]], ratio: float, seed: int = 1):
        self.buckets = buckets
        self.ratio = ratio if len(buckets) > 1 else 0.0
        self.rng = np.random.default_rng(seed)

    def next_batch(self, n: int) -> List[TxnSpec]:
        rng = self.rng
        nb = len(self.buckets)
        blob = rng.bytes(n * VALUE_BYTES)
        half = VALUE_BYTES // 2
        cross = rng.random(n) < self.ratio
        s1 = rng.integers(0, nb, n)
        s2 = (s1 + rng.integers(1, max(nb, 2), n)) % nb  # distinct shard
        sizes = np.asarray([len(b) for b in self.buckets])
        k1 = rng.integers(0, sizes[s1])
        k2 = rng.integers(0, sizes[s2])
        specs: List[TxnSpec] = []
        for i in range(n):
            off = i * VALUE_BYTES
            a = self.buckets[s1[i]][k1[i]]
            if cross[i]:
                b = self.buckets[s2[i]][k2[i]]
                specs.append(TxnSpec(writes=[
                    (a, blob[off : off + half]),
                    (b, blob[off + half : off + VALUE_BYTES]),
                ]))
            else:
                specs.append(TxnSpec(writes=[(a, blob[off : off + VALUE_BYTES])]))
        return specs


def _run_one(n_shards: int, ratio: float, duration: float, seed: int) -> dict:
    eng = ShardedEngine(ShardedConfig(
        n_shards=n_shards, n_buffers=1, n_workers=2,
        device_kind="ssd", device_clock="real",
        table_capacity=N_RECORDS // max(n_shards, 1) + 1,
        # coarser idle poll than the 0.2ms default: at 4 shards the logger
        # threads' wakeups otherwise GIL-churn the 1-core container (~1.6x
        # at the 4-shard cell); 1ms still samples the 5ms group-commit
        # timer comfortably
        engine=EngineConfig(n_buffers=1, device_kind="ssd",
                            logger_poll=1e-3),
    ))
    buckets: List[List[str]] = [[] for _ in range(n_shards)]
    for i in range(N_RECORDS):
        k = key_of(i)
        buckets[eng.shard_of(k)].append(k)
        eng.insert(k, b"\x00")
    wl = ShardedYCSB(buckets, ratio, seed=seed)

    eng.start()
    n_committed = 0
    pending: List = []

    def sweep() -> None:
        nonlocal n_committed
        keep = []
        for t in pending:
            if t.committed:
                n_committed += 1
            else:
                keep.append(t)
        pending[:] = keep

    eng.execute_batch(wl.next_batch(64))  # warm-up outside the window
    eng.drain()
    t0 = time.perf_counter()
    deadline = t0 + duration
    submitted = 0
    while time.perf_counter() < deadline:
        specs = wl.next_batch(BATCH)
        submitted += len(specs)
        res = eng.execute_batch(specs, max_rounds=2)
        pending.extend(res.committed)
        pending.extend(res.cross)
        eng.drain()
        sweep()
    quiesce_timeout = False
    try:
        eng.quiesce(timeout=30)
    except TimeoutError:
        # the 30s wait is inside the measured window (the drain is part of
        # the IO-bound cost) — flag it so a deflated cell is explainable
        quiesce_timeout = True
    elapsed = time.perf_counter() - t0
    eng.stop()
    sweep()
    stats = eng.stats()
    return {
        "txn_per_s": n_committed / elapsed,
        "submitted": submitted,
        "cross_committed": stats["cross_committed"],
        "cross_aborts": stats["cross_aborts"],
        "quiesce_timeout": quiesce_timeout,
    }


def run(duration=None):
    duration = duration or DURATION
    cells = [(s, r) for s in SHARDS for r in RATIOS
             if not (s == 1 and r > 0)]  # cross-shard needs >= 2 shards
    results = {c: [] for c in cells}
    # pin the per-device bandwidth for this sweep (restored afterwards):
    # the 1-shard baseline must be IO-bound for shard count to be the axis
    saved = os.environ.get("REPRO_SSD_BW")
    os.environ["REPRO_SSD_BW"] = SHARD_BW
    rows = []
    try:
        # one discarded warm-up run per cell: first-touch numpy / thread /
        # page-cache costs land here instead of skewing the first repeat
        for c in cells:
            _run_one(*c, min(duration, 0.3), seed=11)
        for rep in range(REPEATS):       # repeats interleaved over the grid
            for c in cells:
                results[c].append(_run_one(*c, duration, seed=17 + rep))
        for n_shards, ratio in cells:
            runs = results[(n_shards, ratio)]
            stats_r = robust_stats([r["txn_per_s"] for r in runs])
            rows.append({
                "bench": "shard", "workload": "ycsb_write",
                "shards": n_shards, "cross_ratio": ratio,
                "ssd_bw": SHARD_BW,
                "txn_per_s": round(stats_r["median"], 1),
                "iqr_rel": round(stats_r["iqr_rel"], 3),
                "runs": [round(r["txn_per_s"], 1) for r in runs],
                "quiesce_timeouts": sum(r["quiesce_timeout"] for r in runs),
                "cross_committed": runs[-1]["cross_committed"],
                "cross_aborts": runs[-1]["cross_aborts"],
            })
        # emit inside the pinned-env window so the JSON's meta fingerprint
        # records the bandwidth the sweep actually ran with
        emit(rows, ["bench", "workload", "shards", "cross_ratio", "ssd_bw",
                    "txn_per_s", "iqr_rel", "cross_committed",
                    "cross_aborts"],
             name="shard")
    finally:
        if saved is None:
            os.environ.pop("REPRO_SSD_BW", None)
        else:
            os.environ["REPRO_SSD_BW"] = saved
    base = {r["shards"]: r["txn_per_s"] for r in rows if r["cross_ratio"] == 0}
    if 1 in base and 4 in base and base[1] > 0:
        print(f"# 0%-cross scaling 1->4 shards: {base[4] / base[1]:.2f}x")
    return rows


if __name__ == "__main__":
    bench_runtime_setup()
    run()
