"""Kernel microbenchmarks — compiled vs interpret vs numpy, across the
bucket ladder (BENCH_kernels.json).

Two OLTP device ops, timed at each power-of-two bucket size the hot paths
pad to:

* ``replay_scan`` — hash-slot last-writer-wins scan (the device half of the
  compiled replay path, ``kernels/ops.fused_replay_scan``).
* ``validate_seq`` — the fused validate→sequence pass of ``BatchOCC``
  (``kernels/ops.fused_validate_sequence``).

Per (op, n) row, four engines where available:

* ``numpy_sort_s`` — the *engine's prior idiom*: lexsort + first-per-group
  segment reduction (what ``_group_winners`` / ``_first_writer`` do on the
  vectorized path).  This is the baseline the compiled path replaced and
  the one ``compiled_speedup`` is computed against.
* ``numpy_scatter_s`` — best-case pure-int ``ufunc.at`` scatter on the same
  columns.  An upper bound numpy cannot reach on the real path (keys are
  byte strings; the hash-slot layout that makes an int scatter possible is
  itself part of the compiled design) but reported for honesty: at small n
  it beats everything, including the compiled op.
* ``interpret_s`` — the Pallas kernel in interpret mode (what
  ``mode="pallas"`` executed on CPU before the compiled XLA twins;
  Python-evaluated, so size-capped).  Replay only — validate has no Pallas
  twin.
* ``compiled_s`` — the jit-compiled fused entry point.

After the sweep, the jit-cache specialization counts
(``kernels/ops.fused_cache_sizes``) are emitted — with bucket padding these
stay at one entry per ladder rung no matter how many raw shapes stream
through (the bound ``tests/test_bucketing.py`` asserts).
"""

from __future__ import annotations

import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from _util import FAST, bench_runtime_setup, emit  # noqa: E402

REPS = 3 if FAST else 5
SIZES = (1024, 4096, 16384) if FAST else (1024, 4096, 16384, 65536)
INTERPRET_MAX = 4096  # interpret mode is Python-evaluated; cap its sizes
NO_POS = 2**31 - 1
NO_WRITER = 2**31 - 1


def _best_of(f, reps: int = REPS) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        f()
        best = min(best, time.perf_counter() - t0)
    return best


# --- numpy engines -------------------------------------------------------------

def _replay_np_sort(slot, ssn, pos, n_slots):
    """The vectorized engine's group-winner idiom on the slot columns:
    lexsort under the (max ssn, then min pos) lattice, first row per slot
    group wins."""
    order = np.lexsort((pos, -ssn, slot))
    s = slot[order]
    first = np.ones(len(s), bool)
    first[1:] = s[1:] != s[:-1]
    win = order[first]
    out_ssn = np.full(n_slots, -1, np.int64)
    out_pos = np.full(n_slots, NO_POS, np.int64)
    out_ssn[s[first]] = ssn[win]
    out_pos[s[first]] = pos[win]
    return out_ssn, out_pos


def _replay_np_scatter(slot, ssn, pos, n_slots):
    out_ssn = np.full(n_slots + 1, -1, np.int64)  # +1: overflow/padding slot
    np.maximum.at(out_ssn, slot, ssn)
    out_pos = np.full(n_slots + 1, NO_POS, np.int64)
    cand = ssn == out_ssn[slot]
    np.minimum.at(out_pos, slot[cand], pos[cand])
    return out_ssn[:n_slots], out_pos[:n_slots]


def _validate_common(acc, a_len, n_txn, k, fw_row):
    row, pos, _, obs, ssn_now, locked = (acc[i].astype(np.int64) for i in range(6))
    valid = (np.arange(n_txn * k) % k) < np.repeat(a_len, k)
    ok = (fw_row >= pos) & ((obs < 0) | (ssn_now == obs)) & (locked == 0)
    survive = (ok | ~valid).reshape(n_txn, k).all(axis=1)
    bases = np.where(valid, ssn_now, 0).reshape(n_txn, k).max(axis=1)
    return survive, bases


def _validate_np_sort(acc, a_len, n_txn, k, cap):
    """First-writer via lexsort + first-per-group (the ``_first_writer``
    numpy idiom), then the mask/reduce validate math."""
    row, pos, iw, _, _, _ = (acc[i].astype(np.int64) for i in range(6))
    valid = (np.arange(n_txn * k) % k) < np.repeat(a_len, k)
    wmask = (iw != 0) & valid
    w_row, w_pos = row[wmask], pos[wmask]
    fw = np.full(cap, NO_WRITER, np.int64)
    if len(w_row):
        order = np.lexsort((w_pos, w_row))
        r = w_row[order]
        first = np.ones(len(r), bool)
        first[1:] = r[1:] != r[:-1]
        fw[r[first]] = w_pos[order][first]
    return _validate_common(acc, a_len, n_txn, k, fw[row])


def _validate_np_scatter(acc, a_len, n_txn, k, cap):
    row, pos, iw, _, _, _ = (acc[i].astype(np.int64) for i in range(6))
    valid = (np.arange(n_txn * k) % k) < np.repeat(a_len, k)
    w_pos = np.where((iw != 0) & valid, pos, NO_WRITER)
    fw = np.full(cap, NO_WRITER, np.int64)
    np.minimum.at(fw, row, w_pos)
    return _validate_common(acc, a_len, n_txn, k, fw[row])


# --- workload synthesis --------------------------------------------------------

def _replay_inputs(n, rng):
    n_slots = 2 * n
    scan = np.empty((3, n), np.int32)
    scan[0] = rng.integers(0, n_slots, n)            # slot
    scan[1] = rng.permutation(n) + 1                 # distinct SSNs
    scan[2] = rng.integers(0, 1 << 20, n)            # replay positions
    return scan, n_slots


def _validate_inputs(n_txn, k, cap, rng):
    lanes = n_txn * k
    acc = np.empty((6, lanes), np.int32)
    acc[0] = rng.integers(0, cap, lanes)             # row
    acc[1] = rng.permutation(lanes)                  # pos (txn-major order)
    acc[2] = rng.integers(0, 2, lanes)               # is_write
    ssn = rng.integers(1, 1 << 20, lanes).astype(np.int32)
    acc[3] = np.where(rng.random(lanes) < 0.5, ssn, -1)  # obs (reads)
    acc[4] = ssn                                     # ssn_now
    acc[5] = 0                                       # locked
    a_len = rng.integers(1, k + 1, n_txn)
    return acc, a_len


def run(duration=None):
    from repro.kernels.ops import (fused_cache_sizes, fused_replay_scan,
                                   fused_validate_sequence, ssn_scatter_max)

    rng = np.random.default_rng(7)
    rows = []

    for n in SIZES:
        scan, n_slots = _replay_inputs(n, rng)
        slot64, ssn64, pos64 = (scan[i].astype(np.int64) for i in range(3))
        t_sort = _best_of(lambda: _replay_np_sort(slot64, ssn64, pos64, n_slots))
        t_scat = _best_of(lambda: _replay_np_scatter(slot64, ssn64, pos64, n_slots))
        compiled = lambda: [a.block_until_ready() for a in  # noqa: E731
                            fused_replay_scan(scan, n_slots=n_slots)]
        compiled()  # compile outside the timed region
        t_c = _best_of(compiled)
        t_i = None
        if n <= INTERPRET_MAX:
            img_s = np.full(n_slots, -1, np.int32)
            img_p = np.full(n_slots, NO_POS, np.int32)
            interp = lambda: [a.block_until_ready() for a in  # noqa: E731
                              ssn_scatter_max(img_s, img_p, scan[0],
                                              scan[1], scan[2])]
            interp()
            t_i = _best_of(interp, reps=1 if n > 1024 else REPS)
        # cross-check the engines agree before reporting their times
        ref_s, ref_p = _replay_np_sort(slot64, ssn64, pos64, n_slots)
        assert np.array_equal(*map(np.asarray,
                                   (_replay_np_scatter(slot64, ssn64, pos64,
                                                       n_slots)[0], ref_s)))
        out_s, out_p = fused_replay_scan(scan, n_slots=n_slots)
        assert np.array_equal(np.asarray(out_s, np.int64), ref_s)
        assert np.array_equal(np.asarray(out_p, np.int64), ref_p)
        rows.append({
            "bench": "kernels", "op": "replay_scan", "n": n,
            "numpy_sort_s": round(t_sort, 6),
            "numpy_scatter_s": round(t_scat, 6),
            "interpret_s": round(t_i, 6) if t_i else None,
            "compiled_s": round(t_c, 6),
            "compiled_speedup": round(t_sort / t_c, 2),
        })

    for lanes in SIZES:
        k, cap = 4, 4096
        n_txn = lanes // k
        acc, a_len = _validate_inputs(n_txn, k, cap, rng)
        t_sort = _best_of(lambda: _validate_np_sort(acc, a_len, n_txn, k, cap))
        t_scat = _best_of(lambda: _validate_np_scatter(acc, a_len, n_txn, k, cap))
        a_len32 = a_len.astype(np.int32)
        compiled = lambda: [a.block_until_ready() for a in  # noqa: E731
                            fused_validate_sequence(acc, a_len32, n_txn=n_txn,
                                                    k=k, cap=cap)]
        compiled()
        t_c = _best_of(compiled)
        ref_sv, ref_b = _validate_np_sort(acc, a_len, n_txn, k, cap)
        out_sv, out_b = fused_validate_sequence(acc, a_len32, n_txn=n_txn,
                                                k=k, cap=cap)
        assert np.array_equal(np.asarray(out_sv), ref_sv)
        assert np.array_equal(np.asarray(out_b, np.int64), ref_b)
        rows.append({
            "bench": "kernels", "op": "validate_seq", "n": lanes,
            "numpy_sort_s": round(t_sort, 6),
            "numpy_scatter_s": round(t_scat, 6),
            "interpret_s": None,
            "compiled_s": round(t_c, 6),
            "compiled_speedup": round(t_sort / t_c, 2),
        })

    emit(rows, ["bench", "op", "n", "numpy_sort_s", "numpy_scatter_s",
                "interpret_s", "compiled_s", "compiled_speedup"],
         name="kernels")

    cache = fused_cache_sizes()
    cache_rows = [{"bench": "kernels_jit_cache", "op": op, "n": cnt}
                  for op, cnt in sorted(cache.items())]
    emit(cache_rows, ["bench", "op", "n"], name="kernels", append=True)
    return rows + cache_rows


if __name__ == "__main__":
    bench_runtime_setup()
    run()
