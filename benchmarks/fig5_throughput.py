"""Fig. 5 — throughput vs worker threads (YCSB write-only + TPC-C, 2 SSDs).

Expectation (paper): POPLAR ≈ SILO > CENTR (IO-bound on one device);
NVM-D far below on SSDs (synchronous unbatched per-txn writes).

The ``poplar_batch`` rows drive the same Poplar engine through the batched
array-native forward path (`repro.db.batch.BatchOCC`: vectorized OCC +
bulk ``reserve_batch`` SSN allocation + batch record encode) at matched
worker counts — the acceptance target is ≥3x the scalar OCC path on YCSB
write-only.  The ``fig5_batch_compiled`` row then pits the compiled fused
validate→sequence pass (``mode="pallas"``) against the vectorized numpy
rounds on the same batched engine.  The end-to-end gap is small by
construction — the fused stage is ~5% of batch wall (encode/publish
dominate; `fig_kernels.py` carries the isolated 1.4–5x stage win) — so the
speedup is the median of *paired* back-to-back ratios, the only estimator
that survives this container's CPU-speed episodes.
"""
import statistics

from _util import (DURATION, THREADS, bench_runtime_setup, emit,
                   run_batch_bench, run_bench,
                   tpcc_factory, ycsb_write_factory)

ENGINES = ("centr", "silo", "nvmd", "poplar")


def run(duration=None):
    dur = {"duration": duration} if duration else {}
    rows = []
    for wl_name, (load, make) in (
        ("ycsb_write", ycsb_write_factory()),
        ("tpcc", tpcc_factory()),
    ):
        for engine in ENGINES:
            for n in THREADS:
                r = run_bench(engine, make, load, n_workers=n, n_devices=2,
                              workload_name=wl_name, **dur)
                rows.append({
                    "bench": "fig5", "workload": wl_name, "engine": engine,
                    "threads": n, "txn_per_s": round(r.txn_per_s, 1),
                    "committed": r.committed, "aborts": r.aborts,
                })
    # batched forward path vs the scalar OCC path: matched pairs per worker
    # count.  The shared 1-core container has multi-second host-steal
    # episodes (a fixed CPU workload varies >5x between runs), so a single
    # draw per config is meaningless — each side is the median of several
    # short interleaved trials spread across the episode timescale.
    load, make = ycsb_write_factory()
    trials = 3
    pair_duration = duration or max(DURATION, 1.5)
    for n in THREADS:
        s_rates, b_results = [], []
        for _ in range(trials):
            s = run_bench("poplar", make, load, n_workers=n, n_devices=2,
                          workload_name="ycsb_write", duration=pair_duration)
            b = run_batch_bench(n_workers=n, n_devices=2, workload="ycsb_write",
                                duration=pair_duration)
            s_rates.append(s.txn_per_s)
            b_results.append(b)
        s_med = statistics.median(s_rates)
        b = sorted(b_results, key=lambda r: r.txn_per_s)[trials // 2]
        rows.append({
            "bench": "fig5_batch", "workload": "ycsb_write",
            "engine": "poplar_batch", "threads": n,
            "txn_per_s": round(b.txn_per_s, 1), "committed": b.committed,
            "aborts": b.aborts,
            "scalar_txn_per_s": round(s_med, 1),
            "speedup_vs_scalar_occ": round(b.txn_per_s / max(s_med, 1e-9), 2),
        })
    # compiled fused validate→sequence (mode="pallas") vs the vectorized
    # numpy rounds at the widest worker count — same interleaved-median
    # protocol.  At the default batch size (2048 lanes) the fused pass is
    # above its engagement threshold, so this measures the compiled device
    # path, not a silent numpy fallback (benchmarks/fig_kernels.py carries
    # the isolated kernel crossover).
    n = THREADS[-1]
    # the true gap here is small (the fused stage is ~5% of batch wall by
    # Amdahl; encode/publish dominate) — 5 interleaved trials, not 3, so the
    # medians can resolve it through the container's CPU-speed swings
    pair_trials = 5
    v_results, p_results = [], []
    for _ in range(pair_trials):
        v_results.append(run_batch_bench(n_workers=n, n_devices=2,
                                         workload="ycsb_write",
                                         duration=pair_duration,
                                         mode="vectorized"))
        p_results.append(run_batch_bench(n_workers=n, n_devices=2,
                                         workload="ycsb_write",
                                         duration=pair_duration,
                                         mode="pallas"))
    v = sorted(v_results, key=lambda r: r.txn_per_s)[pair_trials // 2]
    p = sorted(p_results, key=lambda r: r.txn_per_s)[pair_trials // 2]
    # speedup from the median of *paired* ratios, not the ratio of medians:
    # each (vectorized, pallas) pair runs back-to-back, so the container's
    # multi-second CPU-speed episodes hit both sides of a pair alike and
    # cancel in the ratio — the only estimator fine enough for a few-percent
    # end-to-end effect on this box
    ratios = sorted(pi.txn_per_s / max(vi.txn_per_s, 1e-9)
                    for vi, pi in zip(v_results, p_results))
    rows.append({
        "bench": "fig5_batch_compiled", "workload": "ycsb_write",
        "engine": "poplar_batch[pallas]", "threads": n,
        "txn_per_s": round(p.txn_per_s, 1), "committed": p.committed,
        "aborts": p.aborts,
        "vectorized_txn_per_s": round(v.txn_per_s, 1),
        "speedup_vs_vectorized": round(ratios[pair_trials // 2], 3),
    })
    emit(rows, ["bench", "workload", "engine", "threads", "txn_per_s",
                "committed", "aborts", "scalar_txn_per_s",
                "speedup_vs_scalar_occ", "vectorized_txn_per_s",
                "speedup_vs_vectorized"], name="fig5")
    return rows


if __name__ == "__main__":
    bench_runtime_setup()
    run()
