"""Fig. 5 — throughput vs worker threads (YCSB write-only + TPC-C, 2 SSDs).

Expectation (paper): POPLAR ≈ SILO > CENTR (IO-bound on one device);
NVM-D far below on SSDs (synchronous unbatched per-txn writes).
"""
from _util import THREADS, emit, run_bench, tpcc_factory, ycsb_write_factory

ENGINES = ("centr", "silo", "nvmd", "poplar")


def run(duration=None):
    rows = []
    for wl_name, (load, make) in (
        ("ycsb_write", ycsb_write_factory()),
        ("tpcc", tpcc_factory()),
    ):
        for engine in ENGINES:
            for n in THREADS:
                r = run_bench(engine, make, load, n_workers=n, n_devices=2,
                              workload_name=wl_name,
                              **({"duration": duration} if duration else {}))
                rows.append({
                    "bench": "fig5", "workload": wl_name, "engine": engine,
                    "threads": n, "txn_per_s": round(r.txn_per_s, 1),
                    "committed": r.committed, "aborts": r.aborts,
                })
    emit(rows, ["bench", "workload", "engine", "threads", "txn_per_s", "committed", "aborts"])
    return rows


if __name__ == "__main__":
    run()
