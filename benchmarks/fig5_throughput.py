"""Fig. 5 — throughput vs worker threads (YCSB write-only + TPC-C, 2 SSDs).

Expectation (paper): POPLAR ≈ SILO > CENTR (IO-bound on one device);
NVM-D far below on SSDs (synchronous unbatched per-txn writes).

The ``poplar_batch`` rows drive the same Poplar engine through the batched
array-native forward path (`repro.db.batch.BatchOCC`: vectorized OCC +
bulk ``reserve_batch`` SSN allocation + batch record encode) at matched
worker counts — the acceptance target is ≥3x the scalar OCC path on YCSB
write-only.
"""
import statistics

from _util import (DURATION, THREADS, emit, run_batch_bench, run_bench,
                   tpcc_factory, ycsb_write_factory)

ENGINES = ("centr", "silo", "nvmd", "poplar")


def run(duration=None):
    dur = {"duration": duration} if duration else {}
    rows = []
    for wl_name, (load, make) in (
        ("ycsb_write", ycsb_write_factory()),
        ("tpcc", tpcc_factory()),
    ):
        for engine in ENGINES:
            for n in THREADS:
                r = run_bench(engine, make, load, n_workers=n, n_devices=2,
                              workload_name=wl_name, **dur)
                rows.append({
                    "bench": "fig5", "workload": wl_name, "engine": engine,
                    "threads": n, "txn_per_s": round(r.txn_per_s, 1),
                    "committed": r.committed, "aborts": r.aborts,
                })
    # batched forward path vs the scalar OCC path: matched pairs per worker
    # count.  The shared 1-core container has multi-second host-steal
    # episodes (a fixed CPU workload varies >5x between runs), so a single
    # draw per config is meaningless — each side is the median of several
    # short interleaved trials spread across the episode timescale.
    load, make = ycsb_write_factory()
    trials = 3
    pair_duration = duration or max(DURATION, 1.5)
    for n in THREADS:
        s_rates, b_results = [], []
        for _ in range(trials):
            s = run_bench("poplar", make, load, n_workers=n, n_devices=2,
                          workload_name="ycsb_write", duration=pair_duration)
            b = run_batch_bench(n_workers=n, n_devices=2, workload="ycsb_write",
                                duration=pair_duration)
            s_rates.append(s.txn_per_s)
            b_results.append(b)
        s_med = statistics.median(s_rates)
        b = sorted(b_results, key=lambda r: r.txn_per_s)[trials // 2]
        rows.append({
            "bench": "fig5_batch", "workload": "ycsb_write",
            "engine": "poplar_batch", "threads": n,
            "txn_per_s": round(b.txn_per_s, 1), "committed": b.committed,
            "aborts": b.aborts,
            "scalar_txn_per_s": round(s_med, 1),
            "speedup_vs_scalar_occ": round(b.txn_per_s / max(s_med, 1e-9), 2),
        })
    emit(rows, ["bench", "workload", "engine", "threads", "txn_per_s",
                "committed", "aborts", "scalar_txn_per_s",
                "speedup_vs_scalar_occ"], name="fig5")
    return rows


if __name__ == "__main__":
    run()
