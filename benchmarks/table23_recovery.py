"""Tables 2–3 — recovery performance (checkpoint + log recovery time).

A scaled workload journals through each variant onto n emulated SSDs with a
mid-run fuzzy checkpoint; we then crash and recover, reporting

  * checkpoint recovery time = max over devices of (ckpt bytes / read bw)
    + parallel in-memory replay (CENTR: single device serializes reads);
  * log recovery time analogously over log bytes;
  * measured wall replay time (CPU component, parallel threads).

Per the paper, recovery time is proportional to bytes-read / device
parallelism: POPLAR/SILO with n devices ≈ CENTR / n.
"""

from __future__ import annotations

import os
import shutil
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from _util import emit, run_bench, ycsb_write_factory  # noqa: E402

from repro.core import CheckpointDaemon, EngineConfig, PoplarEngine, recover  # noqa: E402
from repro.core.variants import CentrEngine, SiloEngine  # noqa: E402
from repro.db import OCCWorker, Table  # noqa: E402
from repro.db import ycsb  # noqa: E402

SSD_READ_BW = 1.2e9  # symmetric with write (§6.1)


def _run_one(engine_name: str, n_devices: int, tmp: str, n_txns: int = 4000):
    table = Table()
    ycsb.load(table, 10_000)
    cfg = EngineConfig(n_buffers=n_devices, device_kind="null", device_dir=tmp)
    if engine_name == "centr":
        eng = CentrEngine(cfg)
        n_devices = 1
    elif engine_name == "silo":
        eng = SiloEngine(cfg, epoch_interval=10e-3)
    else:
        eng = PoplarEngine(cfg)
    eng.start()
    workers = [OCCWorker(table, eng, i) for i in range(4)]
    wl = [ycsb.YCSBWriteOnly(10_000, seed=i) for i in range(4)]

    # first half of the workload
    for i in range(n_txns // 2):
        w = workers[i % 4]
        wl[i % 4].next_txn(w)
        w.drain()

    # fuzzy checkpoint (Poplar engines expose a CSN; others use buffer DSN)
    csn_fn = (lambda: eng.commit.csn) if hasattr(eng, "commit") else (lambda: 10**12)
    ck = CheckpointDaemon(os.path.join(tmp, "ckpt"), n_threads=2, m_files=2, csn_fn=csn_fn)
    parts = table.partitions(2)
    try:
        ck.run_once([table.snapshot_partition(p) for p in parts], validate_timeout=5.0)
        ckpt_dir = os.path.join(tmp, "ckpt")
    except TimeoutError:
        ckpt_dir = None

    # second half
    for i in range(n_txns // 2):
        w = workers[i % 4]
        wl[i % 4].next_txn(w)
        w.drain()
    eng.quiesce(range(4), timeout=30)
    eng.stop()

    # crash + recover
    t0 = time.perf_counter()
    state = recover(eng.devices, checkpoint_dir=ckpt_dir, parallel=True)
    wall_replay_s = time.perf_counter() - t0

    log_bytes = [d.bytes_written for d in eng.devices]
    ckpt_bytes = 0
    if ckpt_dir:
        ckpt_bytes = sum(
            os.path.getsize(os.path.join(ckpt_dir, f))
            for f in os.listdir(ckpt_dir) if f.endswith(".bin")
        )
    # emulated IO makespans (devices read in parallel)
    log_io_s = max(b / SSD_READ_BW for b in log_bytes) if log_bytes else 0.0
    ckpt_io_s = (ckpt_bytes / n_devices) / SSD_READ_BW
    return {
        "engine": engine_name,
        "devices": n_devices,
        "log_MB": round(sum(log_bytes) / 1e6, 2),
        "ckpt_MB": round(ckpt_bytes / 1e6, 2),
        "ckpt_recovery_s": round(ckpt_io_s, 6),
        "log_recovery_s": round(log_io_s, 6),
        "wall_replay_s": round(wall_replay_s, 4),
        "recovered_keys": len(state.data),
        "rsne": state.rsne,
    }


def run(duration=None):
    rows = []
    for engine_name, nd in (("centr", 1), ("silo", 2), ("poplar", 2), ("poplar", 4)):
        tmp = tempfile.mkdtemp(prefix=f"rec_{engine_name}_{nd}_")
        try:
            r = _run_one(engine_name, nd, tmp)
            r["bench"] = "table23"
            rows.append(r)
        finally:
            shutil.rmtree(tmp, ignore_errors=True)
    emit(rows, ["bench", "engine", "devices", "log_MB", "ckpt_MB",
                "ckpt_recovery_s", "log_recovery_s", "wall_replay_s",
                "recovered_keys", "rsne"])
    return rows


if __name__ == "__main__":
    run()
