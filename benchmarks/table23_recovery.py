"""Tables 2–3 — recovery performance (checkpoint + log recovery time), plus
the replay-throughput comparison for the vectorized recovery engine.

Part 1 (paper tables): a scaled workload journals through each variant onto
n emulated SSDs with a mid-run fuzzy checkpoint; we then crash and recover,
reporting

  * checkpoint recovery time = max over devices of (ckpt bytes / read bw)
    + parallel in-memory replay (CENTR: single device serializes reads);
  * log recovery time analogously over log bytes;
  * measured wall replay time (CPU component).

Per the paper, recovery time is proportional to bytes-read / device
parallelism: POPLAR/SILO with n devices ≈ CENTR / n.

Part 2 (``bench=replay``): synthesized multi-device logs (write-only and
RAW-carrying records, one device's flush frontier lagging so RSNe actually
skips durable-but-uncommitted records) replayed through the scalar oracle
and the batched vectorized engine across 1–8 devices, reporting the replay
stage's wall time and records/s for each — the vectorized path must come out
>= 5x at 100k+ records.  A ``bench=replay_kernel`` row exercises the
compiled bucket-padded scatter-max apply (``replay_columnar`` with
``use_kernel=True``; XLA-compiled on CPU, the Pallas kernel on TPU).

Part 3 (``bench=recover_fused``): end-to-end segmented recovery — the same
synthesized logs written and sealed onto segment-chained devices, recovered
via ``recover(mode="pallas")`` (crc-trusted fast tile decode + compiled
hash-slot replay) vs ``recover(mode="vectorized")``, asserted state-equal.
The compiled path must beat the vectorized one end-to-end.
"""

from __future__ import annotations

import os
import random
import shutil
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from _util import (FAST, bench_runtime_setup, emit, run_bench,  # noqa: E402
                   ycsb_write_factory)

from repro.core import CheckpointDaemon, EngineConfig, PoplarEngine, Txn, recover  # noqa: E402
from repro.core.recovery import (  # noqa: E402
    RecoveredState,
    _replay_scalar,
    compute_rsne,
    replay_columnar,
)
from repro.core.txn import decode_columnar, decode_records  # noqa: E402
from repro.core.variants import CentrEngine, SiloEngine  # noqa: E402
from repro.db import OCCWorker, Table  # noqa: E402
from repro.db import ycsb  # noqa: E402

SSD_READ_BW = 1.2e9  # symmetric with write (§6.1)

REPLAY_RECORDS = 20_000 if FAST else 200_000
REPLAY_KEYS = REPLAY_RECORDS // 10


def _run_one(engine_name: str, n_devices: int, tmp: str, n_txns: int = 4000):
    table = Table()
    ycsb.load(table, 10_000)
    cfg = EngineConfig(n_buffers=n_devices, device_kind="null", device_dir=tmp)
    if engine_name == "centr":
        eng = CentrEngine(cfg)
        n_devices = 1
    elif engine_name == "silo":
        eng = SiloEngine(cfg, epoch_interval=10e-3)
    else:
        eng = PoplarEngine(cfg)
    eng.start()
    workers = [OCCWorker(table, eng, i) for i in range(4)]
    wl = [ycsb.YCSBWriteOnly(10_000, seed=i) for i in range(4)]

    # first half of the workload
    for i in range(n_txns // 2):
        w = workers[i % 4]
        wl[i % 4].next_txn(w)
        w.drain()

    # fuzzy checkpoint (Poplar engines expose a CSN; others use buffer DSN)
    csn_fn = (lambda: eng.commit.csn) if hasattr(eng, "commit") else (lambda: 10**12)
    ck = CheckpointDaemon(os.path.join(tmp, "ckpt"), n_threads=2, m_files=2, csn_fn=csn_fn)
    parts = table.partitions(2)
    try:
        ck.run_once([table.snapshot_partition(p) for p in parts], validate_timeout=5.0)
        ckpt_dir = os.path.join(tmp, "ckpt")
    except TimeoutError:
        ckpt_dir = None

    # second half
    for i in range(n_txns // 2):
        w = workers[i % 4]
        wl[i % 4].next_txn(w)
        w.drain()
    eng.quiesce(range(4), timeout=30)
    eng.stop()

    # crash + recover
    t0 = time.perf_counter()
    state = recover(eng.devices, checkpoint_dir=ckpt_dir, parallel=True)
    wall_replay_s = time.perf_counter() - t0

    log_bytes = [d.bytes_written for d in eng.devices]
    ckpt_bytes = 0
    if ckpt_dir:
        ckpt_bytes = sum(
            os.path.getsize(os.path.join(ckpt_dir, f))
            for f in os.listdir(ckpt_dir) if f.endswith(".bin")
        )
    # emulated IO makespans (devices read in parallel)
    log_io_s = max(b / SSD_READ_BW for b in log_bytes) if log_bytes else 0.0
    ckpt_io_s = (ckpt_bytes / n_devices) / SSD_READ_BW
    rep = state.report
    return {
        "engine": engine_name,
        "devices": n_devices,
        "log_MB": round(sum(log_bytes) / 1e6, 2),
        "ckpt_MB": round(ckpt_bytes / 1e6, 2),
        "ckpt_recovery_s": round(ckpt_io_s, 6),
        "log_recovery_s": round(log_io_s, 6),
        "wall_replay_s": round(wall_replay_s, 4),
        "recovered_keys": len(state.data),
        "rsne": state.rsne,
        # structured RecoveryReport breakdown (what replayed, what each §5
        # rule dropped, decode vs replay wall split)
        "n_decoded": rep.n_decoded,
        "n_replayed": rep.n_replayed,
        "n_dropped_above_rsne": rep.n_dropped_above_rsne,
        "ckpt_keys": rep.checkpoint_keys,
        "decode_s": round(rep.decode_s, 4),
        "replay_s": round(rep.replay_s, 4),
        "n_segments": len(rep.segments),
    }


def _synth_logs(n_devices: int, n_records: int, n_keys: int,
                val_bytes: int = 64, wr_frac: float = 0.2, seed: int = 1234):
    """Synthesize per-device framed logs: globally increasing SSNs dealt
    round-robin (per-device monotone, like flush order), a mix of write-only
    and RAW-carrying records, and device 0's frontier stopped at ~90% so
    RSNe genuinely skips tail Qwr records on the other devices."""
    rng = random.Random(seed)
    bufs = [bytearray() for _ in range(n_devices)]
    stall_at = int(n_records * 0.9)
    ssn = 0
    for i in range(n_records):
        ssn += 1
        d = i % n_devices
        if n_devices > 1 and d == 0 and i >= stall_at:
            continue  # device 0 "crashed" with this record still in memory
        key = f"k{rng.randrange(n_keys):010d}"
        t = Txn(
            tid=i,
            write_set=[(key, ssn.to_bytes(8, "little") * (val_bytes // 8))],
            read_set=[("dep", 0)] if rng.random() < wr_frac else [],
        )
        t.ssn = ssn
        bufs[d].extend(t.encode())
    return [bytes(b) for b in bufs]


def _best_of(f, reps: int = 3) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        f()
        best = min(best, time.perf_counter() - t0)
    return best


def _bench_replay(n_devices: int, n_records: int):
    logs = _synth_logs(n_devices, n_records, REPLAY_KEYS)

    t0 = time.perf_counter()
    device_records = [decode_records(b) for b in logs]
    t_dec_scalar = time.perf_counter() - t0
    rsne = compute_rsne(device_records)

    # scalar oracle, lock-free sequential loop (best case for scalar)
    t_scalar = _best_of(
        lambda: _replay_scalar(RecoveredState(), device_records, rsne, parallel=False)
    )
    st = RecoveredState()
    st.rsne = rsne
    _replay_scalar(st, device_records, rsne, parallel=False)

    # the seed's deployed replay path: one thread per device, per-write lock
    t_scalar_thr = _best_of(
        lambda: _replay_scalar(RecoveredState(), device_records, rsne, parallel=True),
        reps=1,
    )

    t0 = time.perf_counter()
    cols = [decode_columnar(b) for b in logs]
    t_dec_vec = time.perf_counter() - t0
    assert compute_rsne(cols) == rsne

    t_vec = _best_of(lambda: replay_columnar(cols, rsne))
    data, n_replayed, n_skipped = replay_columnar(cols, rsne)

    assert data == st.data, "vectorized replay diverged from the scalar oracle"
    assert (n_replayed, n_skipped) == (st.n_replayed, st.n_skipped_uncommitted)
    return {
        "bench": "replay",
        "devices": n_devices,
        "n_records": n_records,
        "n_skipped": n_skipped,
        "scalar_decode_s": round(t_dec_scalar, 4),
        "vec_decode_s": round(t_dec_vec, 4),
        "scalar_replay_s": round(t_scalar, 4),
        "scalar_threaded_s": round(t_scalar_thr, 4),
        "vec_replay_s": round(t_vec, 4),
        "scalar_rec_per_s": int(n_records / t_scalar),
        "vec_rec_per_s": int(n_records / t_vec),
        "speedup": round(t_scalar / t_vec, 2),
        "speedup_vs_threaded": round(t_scalar_thr / t_vec, 2),
    }


def _seg_devices(logs, n_segments: int = 4):
    """Write each synthesized blob onto a segment-chained in-memory device:
    ``n_segments - 1`` sealed segments (sealed at record boundaries with the
    correct last-SSN stamp, so seal-time crcs and RSNe floors are exactly
    what the engine's flush path would have produced) plus a live tail."""
    from repro.core.storage import DeviceSpec, StorageDevice
    from repro.core.txn import _HDR, frame_scan, gather_u64
    import numpy as np

    devs = []
    for blob in logs:
        rec_off, _, _ = frame_scan(blob)
        ssn = gather_u64(np.frombuffer(blob, np.uint8), rec_off + _HDR.size)
        d = StorageDevice(DeviceSpec.null(), clock="virtual")
        n = len(rec_off)
        cuts = [max(1, n * i // n_segments) for i in range(1, n_segments)] + [n]
        lo = 0
        for ci, c in enumerate(cuts):
            hi = int(rec_off[c]) if c < n else len(blob)
            if hi > lo:
                d.write(blob[lo:hi])
                if ci < len(cuts) - 1:
                    d.seal(int(ssn[c - 1]))
            lo = hi
        devs.append(d)
    return devs


def _bench_recover_fused(n_devices: int, n_records: int):
    """End-to-end ``recover()`` on segmented devices: compiled fused path
    (mode="pallas") vs the vectorized numpy engine, state-equality asserted."""
    logs = _synth_logs(n_devices, n_records, REPLAY_KEYS)
    devs = _seg_devices(logs)

    # warm the jit cache outside the timed region (one-time process cost;
    # bucket padding keeps it warm for every later shape)
    recover(devs, mode="pallas")

    t_vec = _best_of(lambda: recover(devs, mode="vectorized"))
    t_fused = _best_of(lambda: recover(devs, mode="pallas"))
    a = recover(devs, mode="vectorized")
    b = recover(devs, mode="pallas")
    assert a.data == b.data and a.rsne == b.rsne, "fused recovery diverged"
    assert (a.n_replayed, a.n_skipped_uncommitted) == (
        b.n_replayed, b.n_skipped_uncommitted)
    return {
        "bench": "recover_fused",
        "devices": n_devices,
        "n_records": n_records,
        "segments_per_device": 4,
        "vec_recover_s": round(t_vec, 4),
        "fused_recover_s": round(t_fused, 4),
        "vec_rec_per_s": int(n_records / t_vec),
        "fused_rec_per_s": int(n_records / t_fused),
        "speedup": round(t_vec / t_fused, 2),
        "recovered_keys": len(b.data),
        "agrees": True,
    }


def _bench_replay_kernel(n_devices: int = 2, n_records: int = 4096):
    """Compiled bucket-padded scatter-max apply through ``replay_columnar``
    (XLA on CPU, the Pallas kernel on TPU — kernels/ops.fused_replay_apply)."""
    logs = _synth_logs(n_devices, n_records, n_keys=512)
    cols = [decode_columnar(b) for b in logs]
    rsne = compute_rsne(cols)
    data_np, _, _ = replay_columnar(cols, rsne)
    t0 = time.perf_counter()
    data_k, _, _ = replay_columnar(cols, rsne, use_kernel=True)
    t_kernel = time.perf_counter() - t0
    assert data_k == data_np, "pallas replay diverged from the numpy engine"
    return {
        "bench": "replay_kernel",
        "devices": n_devices,
        "n_records": n_records,
        "kernel_replay_s": round(t_kernel, 4),
        "agrees": True,
    }


def run(duration=None):
    rows = []
    for engine_name, nd in (("centr", 1), ("silo", 2), ("poplar", 2), ("poplar", 4)):
        tmp = tempfile.mkdtemp(prefix=f"rec_{engine_name}_{nd}_")
        try:
            r = _run_one(engine_name, nd, tmp)
            r["bench"] = "table23"
            rows.append(r)
        finally:
            shutil.rmtree(tmp, ignore_errors=True)
    emit(rows, ["bench", "engine", "devices", "log_MB", "ckpt_MB",
                "ckpt_recovery_s", "log_recovery_s", "wall_replay_s",
                "recovered_keys", "rsne", "n_decoded", "n_replayed",
                "n_dropped_above_rsne", "ckpt_keys", "decode_s", "replay_s",
                "n_segments"], name="table23")

    replay_rows = [_bench_replay(nd, REPLAY_RECORDS) for nd in (1, 2, 4, 8)]
    emit(replay_rows, ["bench", "devices", "n_records", "n_skipped",
                       "scalar_decode_s", "vec_decode_s", "scalar_replay_s",
                       "scalar_threaded_s", "vec_replay_s", "scalar_rec_per_s",
                       "vec_rec_per_s", "speedup", "speedup_vs_threaded"],
         name="table23", append=True)
    kernel_row = _bench_replay_kernel()
    emit([kernel_row], ["bench", "devices", "n_records", "kernel_replay_s", "agrees"], name="table23", append=True)
    fused_rows = [_bench_recover_fused(nd, REPLAY_RECORDS) for nd in (2, 4)]
    emit(fused_rows, ["bench", "devices", "n_records", "segments_per_device",
                      "vec_recover_s", "fused_recover_s", "vec_rec_per_s",
                      "fused_rec_per_s", "speedup", "recovered_keys", "agrees"],
         name="table23", append=True)
    return rows + replay_rows + [kernel_row] + fused_rows


if __name__ == "__main__":
    bench_runtime_setup()
    run()
