"""Fig. 8 — runtime breakdown at max worker threads: Log contention
(sequence-number allocation) / Log work (insert + buffer waits) / Other."""
from _util import (THREADS, bench_runtime_setup, emit, run_bench,
                   tpcc_factory, ycsb_write_factory)

ENGINES = ("centr", "silo", "nvmd", "poplar")


def run(duration=None):
    rows = []
    for wl_name, (load, make) in (
        ("ycsb_write", ycsb_write_factory()),
        ("tpcc", tpcc_factory()),
    ):
        for engine in ENGINES:
            n = max(THREADS)
            r = run_bench(engine, make, load, n_workers=n, n_devices=2,
                          workload_name=wl_name,
                          **({"duration": duration} if duration else {}))
            total = sum(r.breakdown.values()) or 1.0
            rows.append({
                "bench": "fig8", "workload": wl_name, "engine": engine,
                "threads": n,
                "log_contention_pct": round(100 * r.breakdown["contention"] / total, 2),
                "log_work_pct": round(100 * r.breakdown["log_work"] / total, 2),
                "other_pct": round(100 * r.breakdown["other"] / total, 2),
            })
    emit(rows, ["bench", "workload", "engine", "threads",
                "log_contention_pct", "log_work_pct", "other_pct"], name="fig8")
    return rows


if __name__ == "__main__":
    bench_runtime_setup()
    run()
