"""Serving-tier offered-load sweep — open-loop group commit.

For each offered load (Poisson arrivals, open loop: arrivals never wait for
the system), clients submit single write transactions into the
GroupCommitScheduler; commit latency is measured from the *scheduled*
arrival time to the durable ack (coordinated-omission-safe), so queueing
delay past saturation shows up as the textbook latency hockey stick rather
than vanishing into a stalled load generator.

Reported per load point: p50/p99/p999 commit latency, goodput (acked/s —
diverges from offered load past saturation), explicit admission rejects,
and scheduler + per-shard commit-queue depths.  Two serving stacks:

* ``1shard`` — SingleBackend over one Poplar engine (2 log devices);
* ``4shard`` — ShardedBackend over a 4-shard engine (per-shard devices).

The sweep deliberately extends well past saturation (the top loads exceed
what one GIL-bound core can serve) so the saturation knee, the goodput
plateau and the admission-control behaviour are all visible in the data.
"""

import tempfile
import threading
import time

from _util import FAST, bench_runtime_setup, emit

from repro.core import EngineConfig
from repro.db.ycsb import YCSBWriteOnly
from repro.serve import (
    GroupCommitScheduler,
    OpenLoopDriver,
    ServeConfig,
    ShardedBackend,
    SingleBackend,
)

RATES = (1000, 3000, 6000, 12000) if FAST else (
    1000, 3000, 6000, 12000, 24000, 48000, 96000)
DURATION = 0.25 if FAST else 1.0
MAX_TXNS = 1500 if FAST else 12000
N_RECORDS = 10_000
SETTLE_S = 10.0 if FAST else 30.0


def _mk_backend(config: str, device_dir: str):
    if config == "1shard":
        return SingleBackend.make(
            "vectorized", n_workers=2,
            cfg=EngineConfig(n_buffers=2, device_kind="ssd",
                             device_dir=device_dir, device_clock="real",
                             flush_interval=1e-3, logger_poll=1e-4),
        )
    return ShardedBackend.make(
        n_shards=4, n_buffers=1, n_workers=2, device_kind="ssd",
        device_dir=device_dir,
    )


def _run_point(config: str, rate: float) -> dict:
    n = min(MAX_TXNS, max(200, int(rate * DURATION)))
    wl = YCSBWriteOnly(N_RECORDS, seed=int(rate))
    specs = wl.next_specs(n)
    with tempfile.TemporaryDirectory() as d:
        be = _mk_backend(config, d)
        sched = GroupCommitScheduler(
            be, ServeConfig(latency_budget_s=1e-3, max_batch=256,
                            queue_capacity=4096),
        )
        depth_samples: list = []
        stop = threading.Event()

        def _sampler():
            while not stop.is_set():
                depth_samples.append(be.queue_depths())
                time.sleep(5e-3)

        sampler = threading.Thread(target=_sampler, daemon=True)
        sched.start()
        sampler.start()
        try:
            rep = OpenLoopDriver(sched, specs, rate_per_s=rate,
                                 seed=int(rate) + 1).run(settle_timeout_s=SETTLE_S)
        finally:
            stop.set()
            sampler.join(timeout=2)
            sched.stop(quiesce=True)
        st = sched.stats()
    per_shard_max = [max(s[i] for s in depth_samples)
                     for i in range(len(depth_samples[0]))] if depth_samples else []
    goodput = rep.goodput_per_s
    return {
        "bench": "fig_serve",
        "config": config,
        "offered_per_s": int(rate),
        "submitted": rep.submitted,
        "acked": rep.acked,
        "rejected": rep.rejected,
        "aborted": rep.aborted,
        "goodput_per_s": round(goodput, 1),
        "p50_ms": round(rep.pct_ms(50), 3),
        "p99_ms": round(rep.pct_ms(99), 3),
        "p999_ms": round(rep.pct_ms(99.9), 3),
        "saturated": int(goodput < 0.92 * rate),
        "mean_cut": round(st["mean_cut"], 2),
        "sched_queue_max": st["max_queue_depth"],
        "qdepth_per_shard_max": "|".join(str(v) for v in per_shard_max),
    }


HEADER = [
    "bench", "config", "offered_per_s", "submitted", "acked", "rejected",
    "aborted", "goodput_per_s", "p50_ms", "p99_ms", "p999_ms", "saturated",
    "mean_cut", "sched_queue_max", "qdepth_per_shard_max",
]


def run(duration=None):
    global DURATION
    if duration:
        DURATION = duration
    rows = []
    for config in ("1shard", "4shard"):
        for rate in RATES:
            rows.append(_run_point(config, rate))
    n_sat = sum(r["saturated"] for r in rows if r["config"] == "1shard")
    assert n_sat >= 2 or FAST, (
        f"sweep only reached {n_sat} past-saturation points; extend RATES"
    )
    emit(rows, HEADER, name="serve")
    return rows


if __name__ == "__main__":
    bench_runtime_setup()
    run()
