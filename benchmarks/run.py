"""Benchmark driver — one function per paper table/figure.

Prints CSV blocks per benchmark (name,metrics...) plus the roofline table
derived from the dry-run artifacts, and persists each benchmark's rows to
``BENCH_<name>.json`` at the repo root (machine-readable perf trajectory
across PRs).  BENCH_FAST=1 shrinks durations for CI.

Usage: ``python benchmarks/run.py [--list] [--seed N] [bench_name ...]``

* positional names run only those benchmarks (e.g. ``fig5_throughput
  table23_recovery`` for the CI smoke subset);
* ``--list`` prints the available benchmark names and exits (the subset CLI
  is discoverable without reading this file);
* ``--seed N`` seeds ``random`` and ``numpy`` and exports
  ``REPRO_BENCH_SEED`` before any benchmark imports, so stochastic
  workload draws are reproducible across runs/machines.
"""

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.dirname(__file__))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

BENCH_NAMES = [
    "fig5_throughput",
    "fig6_io_bandwidth",
    "fig7_commit_latency",
    "fig8_breakdown",
    "fig9_scalability",
    "fig10_commit_protocol",
    "fig_shard_scalability",
    "fig_replication",
    "fig_truncation",
    "fig_adaptive",
    "fig_serve",
    "fig_kernels",
    "fig_trace",
    "table23_recovery",
    "roofline",
]


def main(only=None, seed=None) -> None:
    if seed is not None:
        import random

        import numpy as np

        os.environ["REPRO_BENCH_SEED"] = str(seed)
        random.seed(seed)
        np.random.seed(seed)

    from _util import bench_runtime_setup

    bench_runtime_setup()

    import importlib

    benches = [(n, importlib.import_module(n).run) for n in BENCH_NAMES]
    if only:
        unknown = set(only) - {n for n, _ in benches}
        if unknown:
            raise SystemExit(f"unknown benchmarks: {sorted(unknown)}; "
                             f"available: {[n for n, _ in benches]}")
        benches = [(n, fn) for n, fn in benches if n in set(only)]
    for name, fn in benches:
        t0 = time.perf_counter()
        print(f"\n### {name}")
        fn()
        print(f"# {name} done in {time.perf_counter() - t0:.1f}s", flush=True)


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("benchmarks", nargs="*",
                    help="benchmark names to run (default: all)")
    ap.add_argument("--list", action="store_true",
                    help="print available benchmark names and exit")
    ap.add_argument("--seed", type=int, default=None,
                    help="seed random+numpy (and REPRO_BENCH_SEED) first")
    args = ap.parse_args()
    if args.list:
        # stable-sorted so CI diffs of the listing are deterministic and
        # independent of the run-order grouping above
        print("\n".join(sorted(BENCH_NAMES)))
        raise SystemExit(0)
    main(args.benchmarks, seed=args.seed)
