"""Benchmark driver — one function per paper table/figure.

Prints CSV blocks per benchmark (name,metrics...) plus the roofline table
derived from the dry-run artifacts, and persists each benchmark's rows to
``BENCH_<name>.json`` at the repo root (machine-readable perf trajectory
across PRs).  BENCH_FAST=1 shrinks durations for CI.

Usage: ``python benchmarks/run.py [bench_name ...]`` — with arguments, only
the named benchmarks run (e.g. ``fig5_throughput table23_recovery`` for the
CI smoke subset).
"""

import os
import sys
import time

sys.path.insert(0, os.path.dirname(__file__))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main(only=None) -> None:
    import fig5_throughput
    import fig6_io_bandwidth
    import fig7_commit_latency
    import fig8_breakdown
    import fig9_scalability
    import fig10_commit_protocol
    import fig_shard_scalability
    import table23_recovery
    import roofline

    benches = [
        ("fig5_throughput", fig5_throughput.run),
        ("fig6_io_bandwidth", fig6_io_bandwidth.run),
        ("fig7_commit_latency", fig7_commit_latency.run),
        ("fig8_breakdown", fig8_breakdown.run),
        ("fig9_scalability", fig9_scalability.run),
        ("fig10_commit_protocol", fig10_commit_protocol.run),
        ("fig_shard_scalability", fig_shard_scalability.run),
        ("table23_recovery", table23_recovery.run),
        ("roofline", roofline.run),
    ]
    if only:
        unknown = set(only) - {n for n, _ in benches}
        if unknown:
            raise SystemExit(f"unknown benchmarks: {sorted(unknown)}; "
                             f"available: {[n for n, _ in benches]}")
        benches = [(n, fn) for n, fn in benches if n in set(only)]
    for name, fn in benches:
        t0 = time.perf_counter()
        print(f"\n### {name}")
        fn()
        print(f"# {name} done in {time.perf_counter() - t0:.1f}s", flush=True)


if __name__ == "__main__":
    main(sys.argv[1:])
