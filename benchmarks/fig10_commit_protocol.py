"""Fig. 10 — commit-protocol impact on emulated NVM, hybrid workload.

The scan length controls the read-set size: NVM-D's GSN updates every read
tuple (WAR tracking) so its cost grows with scan length; Poplar's SSN does
not touch read-only tuples.  SILO pays the epoch wait in latency."""
from _util import bench_runtime_setup, emit, run_bench, ycsb_hybrid_factory

SCANS = (0, 10, 50, 100)


def run(duration=None):
    rows = []
    for engine in ("centr", "silo", "nvmd", "poplar"):
        for scan in SCANS:
            load, make = ycsb_hybrid_factory(scan_length=scan)
            r = run_bench(engine, make, load, n_workers=4, n_devices=2,
                          device_kind="nvm", workload_name=f"hybrid_scan{scan}",
                          epoch_interval=50e-3,
                          **({"duration": duration} if duration else {}))
            rows.append({
                "bench": "fig10", "engine": engine, "scan_length": scan,
                "txn_per_s": round(r.txn_per_s, 1),
                "avg_latency_ms": round(r.avg_latency_ms, 3),
            })
    emit(rows, ["bench", "engine", "scan_length", "txn_per_s", "avg_latency_ms"], name="fig10")
    return rows


if __name__ == "__main__":
    bench_runtime_setup()
    run()
