"""Fig. 9 — peak throughput vs number of devices (CENTR pinned to 1)."""
from _util import (FAST, THREADS, bench_runtime_setup, emit, run_bench,
                   tpcc_factory, ycsb_write_factory)

DEVICES = (1, 2, 4)


def run(duration=None):
    rows = []
    for wl_name, (load, make) in (
        ("ycsb_write", ycsb_write_factory()),
        ("tpcc", tpcc_factory()),
    ):
        for engine in ("centr", "silo", "nvmd", "poplar"):
            for nd in DEVICES:
                if engine == "centr" and nd > 1:
                    continue
                n = max(THREADS)
                r = run_bench(engine, make, load, n_workers=max(n, nd), n_devices=nd,
                              workload_name=wl_name,
                              **({"duration": duration} if duration else {}))
                rows.append({
                    "bench": "fig9", "workload": wl_name, "engine": engine,
                    "devices": nd, "txn_per_s": round(r.txn_per_s, 1),
                })
    emit(rows, ["bench", "workload", "engine", "devices", "txn_per_s"], name="fig9")
    return rows


if __name__ == "__main__":
    bench_runtime_setup()
    run()
