"""Replication — apply throughput and end-to-end lag vs device count and
batch size (``BENCH_replication.json``).

A synthetic primary appends framed records round-robin across n in-memory
devices (globally increasing SSNs, a write/RAW mix like the table23 replay
bench); after every ``batch`` records the replica polls once — ship the new
frames from every device, advance the watermark, fold the batch through the
applier.  Reported per (devices × batch × mode):

* ``rec_per_s``  — replica apply throughput (records / total poll wall);
* ``lag_ms_p50`` / ``lag_ms_max`` — per-poll wall time: the freshness delay
  a replica read pays right after a batch lands on the primary;
* ``speedup`` (on vectorized/pallas rows) — vs the scalar per-record tailer
  at the same (devices, batch): the replica apply is expected to track the
  vectorized-replay advantage (>30x over scalar tailing at large batches
  on the full-size run).

The scalar and vectorized replicas must agree exactly on the final promoted
state — asserted every run, so the bench doubles as an equivalence check.
"""

from __future__ import annotations

import os
import random
import statistics
import sys
import threading
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from _util import FAST, bench_runtime_setup, emit  # noqa: E402

from repro.core import Txn, make_devices  # noqa: E402
from repro.replica import LogShipper, Replica  # noqa: E402

N_RECORDS = 20_000 if FAST else 100_000
DEVICES = (1, 2, 4)
BATCHES = (256, 2048) if FAST else (512, 4096)
VAL_BYTES = 64
WR_FRAC = 0.2


class _Primary:
    """Round-robin record generator appending straight to the devices."""

    def __init__(self, devices, n_keys: int, seed: int = 1234):
        self.devices = devices
        self.n_keys = n_keys
        self.rng = random.Random(seed)
        self.ssn = 0
        self.i = 0

    def append(self, n: int) -> None:
        for _ in range(n):
            self.ssn += 1
            key = f"k{self.rng.randrange(self.n_keys):010d}"
            t = Txn(
                tid=self.i,
                write_set=[(key, self.ssn.to_bytes(8, "little") * (VAL_BYTES // 8))],
                read_set=[("dep", 0)] if self.rng.random() < WR_FRAC else [],
            )
            t.ssn = self.ssn
            self.devices[self.i % len(self.devices)].write(t.encode())
            self.i += 1


def _run_one(n_devices: int, batch: int, mode: str, n_records: int):
    devices = make_devices(n_devices, "null", clock="virtual")
    primary = _Primary(devices, n_keys=max(64, n_records // 10))
    rep = Replica(devices, mode=mode, parallel=False)

    poll_s = []     # end-to-end per-poll wall (ship + apply): the lag a
    ship_s = 0.0    # read pays right after a batch lands
    apply_s = 0.0   # apply stage alone: what the mode changes
    fed = 0
    while fed < n_records:
        n = min(batch, n_records - fed)
        primary.append(n)
        fed += n
        t0 = time.perf_counter()
        new = rep.ship()
        t1 = time.perf_counter()
        rep.apply(new)
        t2 = time.perf_counter()
        ship_s += t1 - t0
        apply_s += t2 - t1
        poll_s.append(t2 - t0)
    t0 = time.perf_counter()
    st = rep.promote()
    promote_s = time.perf_counter() - t0
    return {
        "bench": "replication",
        "devices": n_devices,
        "batch": batch,
        "mode": mode,
        "n_records": n_records,
        "applied": st.n_replayed,
        "held_final": st.n_skipped_uncommitted,
        "ship_s": round(ship_s, 4),
        "apply_s": round(apply_s, 4),
        "rec_per_s": int(n_records / apply_s) if apply_s else 0,
        "e2e_rec_per_s": int(n_records / (ship_s + apply_s)),
        "lag_ms_p50": round(statistics.median(poll_s) * 1e3, 3),
        "lag_ms_max": round(max(poll_s) * 1e3, 3),
        "promote_s": round(promote_s, 4),
        "visible_ssn": st.rsne,
    }, st


def _run_scalar_tail(n_devices: int, batch: int, n_records: int):
    """The seed-style replica a naive port of the threaded scalar replay
    would build: per-record row objects, one tailer thread per device, a
    shared lock around every dict write, held Qwr rows rechecked per poll.
    This is the 'scalar tailing' baseline the vectorized applier is
    measured against."""
    devices = make_devices(n_devices, "null", clock="virtual")
    primary = _Primary(devices, n_keys=max(64, n_records // 10))
    shippers = [LogShipper(d, i) for i, d in enumerate(devices)]
    state = {}
    lock = threading.Lock()
    held = [[] for _ in range(n_devices)]

    def _apply_rows(recs, w, out_held):
        for rec in recs:
            if rec.write_only or rec.ssn <= w:
                for k, v in rec.writes:
                    with lock:
                        cur = state.get(k)
                        if cur is None or rec.ssn > cur[1]:
                            state[k] = (v, rec.ssn)
            else:
                out_held.append(rec)

    poll_s = []
    ship_s = 0.0
    apply_s = 0.0
    fed = 0
    while fed < n_records:
        n = min(batch, n_records - fed)
        primary.append(n)
        fed += n
        t0 = time.perf_counter()
        chunks = [sh.poll() for sh in shippers]
        t1 = time.perf_counter()
        w = min(sh.frontier for sh in shippers)
        threads = []
        for p, log in enumerate(chunks):
            recs, held[p] = held[p], []
            if log is not None:
                recs = recs + log.to_records()
            if recs:
                threads.append(threading.Thread(
                    target=_apply_rows, args=(recs, w, held[p])))
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        t2 = time.perf_counter()
        ship_s += t1 - t0
        apply_s += t2 - t1
        poll_s.append(t2 - t0)
    return {
        "bench": "replication",
        "devices": n_devices,
        "batch": batch,
        "mode": "scalar_tail",
        "n_records": n_records,
        "applied": n_records - sum(len(h) for h in held),
        "held_final": sum(len(h) for h in held),
        "ship_s": round(ship_s, 4),
        "apply_s": round(apply_s, 4),
        "rec_per_s": int(n_records / apply_s) if apply_s else 0,
        "e2e_rec_per_s": int(n_records / (ship_s + apply_s)),
        "lag_ms_p50": round(statistics.median(poll_s) * 1e3, 3),
        "lag_ms_max": round(max(poll_s) * 1e3, 3),
        "promote_s": 0.0,
        "visible_ssn": min(sh.frontier for sh in shippers),
    }, state


def _catchup_rows(n_records: int):
    """Cold-start catch-up: a fresh replica attaches to a fully-written log
    and drains the whole backlog in one poll — the table23 replay regime,
    where the vectorized applier's advantage over seed-style scalar tailing
    (threaded per-record dict walk) is largest."""
    out = []
    for nd in DEVICES:
        r_tail, tail_state = _run_scalar_tail(nd, n_records, n_records)
        r_tail.update(bench="catchup")
        out.append(r_tail)
        r, st = _run_one(nd, n_records, "vectorized", n_records)
        assert tail_state == st.data, f"catchup diverged at devices={nd}"
        r.update(bench="catchup",
                 speedup_vs_tail=round(r_tail["apply_s"] / r["apply_s"], 2))
        out.append(r)
    return out


def run(duration=None):
    rows = []
    for nd in DEVICES:
        for batch in BATCHES:
            r_tail, tail_state = _run_scalar_tail(nd, batch, N_RECORDS)
            rows.append(r_tail)
            ref = None
            for mode in ("scalar", "vectorized"):
                r, st = _run_one(nd, batch, mode, N_RECORDS)
                if ref is None:
                    ref = st
                    scalar_apply = r["apply_s"]
                else:
                    assert st.data == ref.data and st.rsne == ref.rsne, (
                        f"replica modes diverged at devices={nd} batch={batch}"
                    )
                    # both the library oracle and the seed-style tailer must
                    # land on the identical replicated state
                    assert tail_state == st.data, (
                        f"scalar tailer diverged at devices={nd} batch={batch}"
                    )
                    r["speedup"] = round(scalar_apply / r["apply_s"], 2)
                    r["speedup_vs_tail"] = round(
                        r_tail["apply_s"] / r["apply_s"], 2)
                rows.append(r)

    rows.extend(_catchup_rows(N_RECORDS))

    # pallas apply (interpret mode on CPU → sized down; compiled on TPU)
    r, _ = _run_one(2, 512, "pallas", 4096)
    rows.append(r)

    emit(rows, ["bench", "devices", "batch", "mode", "n_records", "applied",
                "held_final", "ship_s", "apply_s", "rec_per_s",
                "e2e_rec_per_s", "lag_ms_p50", "lag_ms_max", "promote_s",
                "visible_ssn", "speedup", "speedup_vs_tail"],
         name="replication")
    return rows


if __name__ == "__main__":
    bench_runtime_setup()
    run()
