"""Trace-driven cost model vs reality — predicted vs measured throughput.

One traced calibration run fits per-stage cost models
(`repro.trace.sim.CostModel`); the replay simulator then *predicts* txn/s
and commit latency for every other cell of a (batch size × devices ×
shards × cross-ratio) grid spanning the fig5 (batch), fig9 (devices) and
shard-scalability axes — each prediction is checked against a real
measured run of the same cell.  Also reported:

* ``fidelity`` — discrete-event replay of the calibration DAG itself vs
  its measured makespan (the simulator's floor: same config, recorded
  durations, re-derived schedule);
* ``critical_path`` — per-stage attribution of a noisy cross-shard cell's
  critical path (what the raw BENCH_shard swings never showed);
* ``overhead`` — traced vs untraced throughput, interleaved windows on
  one live engine (must stay < 3%: a few ring writes per *batch*);
* ``autotune`` — the simulator-chosen (batch, devices) vs the
  measured-best cell.

The calibration trace dump is persisted to ``BENCH_trace_dump.json`` next
to ``BENCH_trace.json``.  With ``REPRO_TRACE_GATE=1`` (CI bench smoke)
the script exits non-zero when the calibration cell's predicted-vs-
measured drift exceeds 25% — the regression gate ROADMAP item 4 asks for.
"""

import json
import os
import time
from typing import Dict, List

import numpy as np

from _util import FAST, bench_runtime_setup, emit, robust_stats, run_metadata

from repro.core.engine import EngineConfig
from repro.db import TxnSpec
from repro.db.ycsb import key_of
from repro.shard import ShardedConfig, ShardedEngine
from repro.trace import (
    ST_DRIVER,
    ST_XPREPARE,
    TRACER,
    CostModel,
    SimConfig,
    WorkloadProfile,
    autotune,
    build_dag,
    critical_path,
    disable,
    enable,
    simulate,
    simulate_dag,
)

N_TXN = 8192 if FAST else 24576
N_RECORDS = 8192 if FAST else 40_000
VALUE_BYTES = 600
MAX_DRIFT = 0.25
IO_UNIT = EngineConfig().io_unit

# the grid: batch axis (fig5-style), device axis (fig9-style), shard axis
CAL = (512, 2)                                   # calibration cell
SINGLE = [(b, d) for b in (512, 2048) for d in (1, 2, 4)]
SHARD_CELLS = [(2, 0.0), (2, 0.5)] if FAST else [(2, 0.0), (2, 0.5), (4, 0.5)]
NOISY_CELL = (2, 0.5)                            # traced for the breakdown
OVERHEAD_REPS = 8 if FAST else 10  # max-of-windows only needs one clean
#                                    window per side; 5 was too few to dodge
#                                    a burst of host steal-time
CELL_REPS = 3        # measured cells keep the best of 3 (steal-time noise
#                      on this container only ever deflates a window)


class _Workload:
    """Write-only workload with a controlled cross-shard ratio (the
    fig_shard construction: one full write, or two half writes on two
    distinct shards — same payload either way)."""

    def __init__(self, buckets: List[List[str]], ratio: float, seed: int = 7):
        self.buckets = buckets
        self.ratio = ratio if len(buckets) > 1 else 0.0
        self.rng = np.random.default_rng(seed)

    def next_batch(self, n: int) -> List[TxnSpec]:
        rng = self.rng
        nb = len(self.buckets)
        blob = rng.bytes(n * VALUE_BYTES)
        half = VALUE_BYTES // 2
        cross = rng.random(n) < self.ratio
        s1 = rng.integers(0, nb, n)
        s2 = (s1 + rng.integers(1, max(nb, 2), n)) % nb
        sizes = np.asarray([len(b) for b in self.buckets])
        k1 = rng.integers(0, sizes[s1])
        k2 = rng.integers(0, sizes[s2])
        specs: List[TxnSpec] = []
        for i in range(n):
            off = i * VALUE_BYTES
            a = self.buckets[s1[i]][k1[i]]
            if cross[i]:
                b = self.buckets[s2[i]][k2[i]]
                specs.append(TxnSpec(writes=[
                    (a, blob[off:off + half]),
                    (b, blob[off + half:off + VALUE_BYTES]),
                ]))
            else:
                specs.append(
                    TxnSpec(writes=[(a, blob[off:off + VALUE_BYTES])])
                )
        return specs


def _run_cell(shards: int, devices: int, batch: int,
              ratio: float = 0.0) -> Dict:
    """Measure one cell: fixed N_TXN work through the threaded sharded
    engine (logger threads flush concurrently — the regime the simulator's
    cpu/device resource split models).  When the tracer is armed, the
    driver halves of the loop (workload gen; drain + ack sweep) are traced
    too, so the calibration trace covers the whole wall window."""
    eng = ShardedEngine(ShardedConfig(
        n_shards=shards, n_buffers=devices, n_workers=devices,
        device_kind="ssd", device_clock="real",
        table_capacity=N_RECORDS // shards + 1,
        engine=EngineConfig(n_buffers=devices, device_kind="ssd",
                            logger_poll=1e-3),
    ))
    buckets: List[List[str]] = [[] for _ in range(shards)]
    for i in range(N_RECORDS):
        k = key_of(i)
        buckets[eng.shard_of(k)].append(k)
        eng.insert(k, b"\x00")
    wl = _Workload(buckets, ratio)
    eng.start()

    n_committed = 0
    lat: List[float] = []
    pending: List = []

    def sweep() -> None:
        nonlocal n_committed
        keep = []
        for t in pending:
            if t.committed:
                n_committed += 1
                tc = getattr(t, "t_commit", 0.0)
                tp = getattr(t, "t_precommit", 0.0)
                if tc and tp:
                    lat.append(tc - tp)
            else:
                keep.append(t)
        pending[:] = keep

    eng.execute_batch(wl.next_batch(min(batch, 256)))  # warm-up
    eng.drain()
    _trace = TRACER.enabled
    t0 = time.perf_counter()
    done = 0
    while done < N_TXN:
        if _trace:
            _td0 = time.perf_counter()
        specs = wl.next_batch(batch)
        if _trace:
            TRACER.record(ST_DRIVER, t0=_td0, t1=time.perf_counter(),
                          n_txn=batch)
        res = eng.execute_batch(specs, max_rounds=2)
        done += batch
        if _trace:
            _td0 = time.perf_counter()
        pending.extend(res.committed)
        pending.extend(res.cross)
        eng.drain()
        sweep()
        if _trace:
            TRACER.record(ST_DRIVER, t0=_td0, t1=time.perf_counter())
    try:
        eng.quiesce(timeout=30)
    except TimeoutError:
        pass
    elapsed = time.perf_counter() - t0
    eng.stop()
    sweep()
    out = {
        "txn_s": n_committed / elapsed,
        "elapsed_s": elapsed,
        "committed": n_committed,
    }
    if lat:
        out["p50_ms"] = float(np.percentile(lat, 50)) * 1e3
        out["p99_ms"] = float(np.percentile(lat, 99)) * 1e3
    return out


def _overhead_windows(reps: int):
    """Traced vs untraced throughput on ONE live engine: alternate
    measurement windows of fixed work with the tracer off/on, engine and
    page cache shared, so the comparison isn't swamped by per-run setup
    variance (table build, thread starts) the way separate runs are."""
    eng = ShardedEngine(ShardedConfig(
        n_shards=1, n_buffers=CAL[1], n_workers=CAL[1],
        device_kind="ssd", device_clock="real",
        table_capacity=N_RECORDS + 1,
        engine=EngineConfig(n_buffers=CAL[1], device_kind="ssd",
                            logger_poll=1e-3),
    ))
    keys = []
    for i in range(N_RECORDS):
        k = key_of(i)
        keys.append(k)
        eng.insert(k, b"\x00")
    wl = _Workload([keys], 0.0)
    eng.start()
    pending: List = []

    def window() -> float:
        done = 0
        n_committed = 0
        _trace = TRACER.enabled
        t0 = time.perf_counter()
        while done < N_TXN:
            if _trace:
                _td0 = time.perf_counter()
            specs = wl.next_batch(CAL[0])
            if _trace:
                TRACER.record(ST_DRIVER, t0=_td0, t1=time.perf_counter(),
                              n_txn=CAL[0])
            res = eng.execute_batch(specs, max_rounds=2)
            done += CAL[0]
            if _trace:
                _td0 = time.perf_counter()
            pending.extend(res.committed)
            eng.drain()
            keep = []
            for t in pending:
                if t.committed:
                    n_committed += 1
                else:
                    keep.append(t)
            pending[:] = keep
            if _trace:
                TRACER.record(ST_DRIVER, t0=_td0, t1=time.perf_counter())
        return done / (time.perf_counter() - t0)

    window()                                   # warm-up, discarded
    off_runs, on_runs = [], []
    for _ in range(reps):
        off_runs.append(window())
        enable()
        on_runs.append(window())
        disable()
    eng.stop()
    # this container's steal-time spikes inflate single windows by up to
    # 2x; the MIN over alternating windows is the classic robust estimator
    # for added-cost noise (a spike only ever slows a window down), so the
    # overhead ratio compares the cleanest traced vs untraced windows
    return off_runs, on_runs, 1.0 - max(on_runs) / max(off_runs)


def _measure_cell(shards: int, devices: int, batch: int,
                  ratio: float = 0.0) -> Dict:
    """Best of CELL_REPS runs — host noise only deflates a window."""
    runs = [_run_cell(shards, devices, batch, ratio)
            for _ in range(CELL_REPS)]
    return max(runs, key=lambda r: r["txn_s"])


def _predict(model: CostModel, profile: WorkloadProfile, shards: int,
             devices: int, batch: int, ratio: float = 0.0):
    return simulate(model, SimConfig(
        shards=shards, devices=devices, batch_size=batch, n_txn=N_TXN,
        cross_ratio=ratio, io_unit=IO_UNIT,
    ), profile)


def _drift(pred: float, meas: float) -> float:
    return abs(pred - meas) / meas if meas else float("inf")


def run():
    repo_root = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    rows: List[Dict] = []

    # --- calibration: one traced run fits the cost model -----------------
    # (best of CELL_REPS: a steal-time spike inside the calibration run
    # would bias every coefficient, not just one cell)
    cal = dump = None
    for _ in range(CELL_REPS):
        enable()
        c = _run_cell(1, CAL[1], CAL[0])
        d = disable()
        if cal is None or c["txn_s"] > cal["txn_s"]:
            cal, dump = c, d
    if dump.dropped:
        raise SystemExit(
            f"fig_trace: calibration trace dropped {dump.dropped} spans — "
            f"a wrapped ring under-samples early stages and would skew "
            f"every CostModel coefficient; raise enable(capacity=...)"
        )
    dump.save(
        os.path.join(repo_root, "BENCH_trace_dump.json"),
        extra={"bench": "trace_dump", "fast": FAST, "meta": run_metadata()},
    )
    model = CostModel.fit(dump)
    profile = WorkloadProfile.from_dump(dump)
    dag = build_dag(dump)
    _, cal_attr = critical_path(dag)

    # --- second traced run: the noisy cross-shard cell -------------------
    # serves double duty: (a) only a sharded trace observes the per-txn
    # coordinator prepare cost, grafted onto the calibration fit; (b) its
    # critical path is the breakdown BENCH_shard's raw swings never showed
    enable()
    _run_cell(NOISY_CELL[0], 1, CAL[0], NOISY_CELL[1])
    xdump = disable()
    if xdump.dropped:
        raise SystemExit(
            f"fig_trace: cross-shard trace dropped {xdump.dropped} spans; "
            f"refusing to graft a biased ST_XPREPARE fit"
        )
    model.merge_stage(CostModel.fit(xdump), ST_XPREPARE)
    # fold the untraced per-txn residual (routing, GIL churn) into the
    # driver lane so predictions extrapolate from an unbiased baseline
    model.calibrate_pad(cal["txn_s"], SimConfig(
        shards=1, devices=CAL[1], batch_size=CAL[0], n_txn=N_TXN,
        io_unit=IO_UNIT,
    ), profile)

    # simulator floor: replay the recorded DAG vs its measured makespan
    replay = simulate_dag(dag)
    rows.append({
        "bench": "trace", "kind": "fidelity",
        "batch": CAL[0], "devices": CAL[1], "shards": 1, "cross_ratio": 0.0,
        "measured_txn_s": round(cal["txn_s"], 1),
        "predicted_txn_s": round(replay.txn_s, 1),
        "drift_pct": round(100 * _drift(replay.makespan, dump.makespan()), 1),
        "detail": json.dumps({
            "replay_makespan_s": round(replay.makespan, 4),
            "measured_makespan_s": round(dump.makespan(), 4),
        }),
    })

    # --- predicted vs measured over the grid -----------------------------
    measured_single: Dict = {}
    cal_drift = None
    for batch, devices in SINGLE:
        meas = _measure_cell(1, devices, batch)
        measured_single[(batch, devices)] = meas
        pred = _predict(model, profile, 1, devices, batch)
        drift = _drift(pred.txn_s, meas["txn_s"])
        if (batch, devices) == CAL:
            cal_drift = drift
        rows.append({
            "bench": "trace", "kind": "config",
            "batch": batch, "devices": devices, "shards": 1,
            "cross_ratio": 0.0,
            "measured_txn_s": round(meas["txn_s"], 1),
            "predicted_txn_s": round(pred.txn_s, 1),
            "drift_pct": round(100 * drift, 1),
            "measured_p50_ms": round(meas.get("p50_ms", float("nan")), 2),
            "predicted_p50_ms": round(pred.p50_commit * 1e3, 2),
            "predicted_p99_ms": round(pred.p99_commit * 1e3, 2),
        })
    for shards, ratio in SHARD_CELLS:
        meas = _measure_cell(shards, 1, CAL[0], ratio)
        pred = _predict(model, profile, shards, 1, CAL[0], ratio)
        rows.append({
            "bench": "trace", "kind": "config",
            "batch": CAL[0], "devices": 1, "shards": shards,
            "cross_ratio": ratio,
            "measured_txn_s": round(meas["txn_s"], 1),
            "predicted_txn_s": round(pred.txn_s, 1),
            "drift_pct": round(100 * _drift(pred.txn_s, meas["txn_s"]), 1),
            "predicted_p50_ms": round(pred.p50_commit * 1e3, 2),
            "predicted_p99_ms": round(pred.p99_commit * 1e3, 2),
        })

    # --- critical path of the noisy cross-shard cell ---------------------
    xdag = build_dag(xdump)
    _, xattr = critical_path(xdag)
    total = sum(xattr.values()) or 1.0
    rows.append({
        "bench": "trace", "kind": "critical_path",
        "batch": CAL[0], "devices": 1, "shards": NOISY_CELL[0],
        "cross_ratio": NOISY_CELL[1],
        "detail": json.dumps({
            k: round(v / total, 3)
            for k, v in sorted(xattr.items(), key=lambda kv: -kv[1])
        }),
    })
    rows.append({
        "bench": "trace", "kind": "critical_path",
        "batch": CAL[0], "devices": CAL[1], "shards": 1, "cross_ratio": 0.0,
        "detail": json.dumps({
            k: round(v / (sum(cal_attr.values()) or 1.0), 3)
            for k, v in sorted(cal_attr.items(), key=lambda kv: -kv[1])
        }),
    })

    # --- tracer overhead: interleaved traced/untraced windows ------------
    off_runs, on_runs, overhead = _overhead_windows(OVERHEAD_REPS)
    rows.append({
        "bench": "trace", "kind": "overhead",
        "batch": CAL[0], "devices": CAL[1], "shards": 1, "cross_ratio": 0.0,
        "measured_txn_s": round(max(off_runs), 1),
        "predicted_txn_s": round(max(on_runs), 1),  # traced throughput
        "drift_pct": round(100 * overhead, 2),
        "detail": json.dumps({
            "untraced": robust_stats(off_runs),
            "traced": robust_stats(on_runs),
            "untraced_runs": [round(x, 1) for x in off_runs],
            "traced_runs": [round(x, 1) for x in on_runs],
        }),
    })

    # --- autotune vs the measured-best single-shard cell -----------------
    tn = autotune(model, profile, n_txn=N_TXN, batch_grid=(512, 2048),
                  device_grid=(1, 2, 4), io_unit=IO_UNIT)
    best_cell = max(measured_single, key=lambda c: measured_single[c]["txn_s"])
    best_meas = measured_single[best_cell]["txn_s"]
    chosen = measured_single.get((tn.batch_size, tn.devices))
    chosen_meas = chosen["txn_s"] if chosen else float("nan")
    rows.append({
        "bench": "trace", "kind": "autotune",
        "batch": tn.batch_size, "devices": tn.devices, "shards": 1,
        "cross_ratio": 0.0,
        "measured_txn_s": round(chosen_meas, 1),
        "predicted_txn_s": round(tn.predicted.txn_s, 1),
        "drift_pct": round(
            100 * _drift(chosen_meas, best_meas), 1
        ),  # vs measured-best
        "detail": json.dumps({
            "measured_best_cell": list(best_cell),
            "measured_best_txn_s": round(best_meas, 1),
        }),
    })

    emit(rows, ["bench", "kind", "batch", "devices", "shards", "cross_ratio",
                "measured_txn_s", "predicted_txn_s", "drift_pct"],
         name="trace")

    assert cal_drift is not None
    print(f"# calibration drift: {100 * cal_drift:.1f}% "
          f"(gate {100 * MAX_DRIFT:.0f}%), tracer overhead: "
          f"{100 * overhead:.2f}%")
    if os.environ.get("REPRO_TRACE_GATE") == "1" and cal_drift > MAX_DRIFT:
        raise SystemExit(
            f"trace drift gate: |predicted-measured| = {100 * cal_drift:.1f}%"
            f" > {100 * MAX_DRIFT:.0f}% on the calibration config"
        )
    return rows


if __name__ == "__main__":
    bench_runtime_setup()
    run()
