"""Roofline table renderer — reads the dry-run JSONs from
``benchmarks/results/`` and prints the per-(arch x shape x mesh) terms.

    compute   = dot-FLOPs/device   / 197 TFLOP/s  (bf16, TPU v5e)
    memory    = HBM bytes/device   / 819 GB/s
    collective= ICI bytes/device   / 50 GB/s (single-link, conservative)

``fraction`` = compute_s / step_lower_bound — how close the cell is to being
compute-bound (1.0 == at the compute roofline given perfect overlap).
"""

from __future__ import annotations

import json
import os
import sys
from typing import Dict, List, Optional

RESULTS = os.path.join(os.path.dirname(__file__), "results")


def load_results(tag: Optional[str] = None) -> List[Dict]:
    out = []
    if not os.path.isdir(RESULTS):
        return out
    for f in sorted(os.listdir(RESULTS)):
        if not f.endswith(".json"):
            continue
        if tag and not f.endswith(f"__{tag}.json"):
            continue
        with open(os.path.join(RESULTS, f)) as fh:
            out.append(json.load(fh))
    return out


def render(rows: List[Dict], title: str = "roofline") -> None:
    hdr = (f"{'arch':24s} {'shape':12s} {'mesh':6s} {'tag':10s} "
           f"{'compute_s':>10s} {'memory_s':>10s} {'collect_s':>10s} "
           f"{'bound':>10s} {'step>=s':>9s} {'frac':>6s} {'peakGB':>7s} {'MF/HLO':>7s}")
    print(f"== {title} ==")
    print(hdr)
    for r in rows:
        if r.get("status") == "skipped":
            print(f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:6s} {r.get('tag','-'):10s} "
                  f"{'SKIP':>10s}  ({r['reason'][:70]})")
            continue
        if r.get("status") != "ok":
            print(f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:6s} {r.get('tag','-'):10s} "
                  f"{'FAIL':>10s}  ({r.get('error','?')[:70]})")
            continue
        rf = r["roofline"]
        frac = rf["compute_s"] / rf["step_s_lower_bound"] if rf["step_s_lower_bound"] else 0
        mem = rf.get("memory_tpu_s", rf["memory_s"])
        print(
            f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:6s} {r.get('tag','-'):10s} "
            f"{rf['compute_s']:10.4f} {mem:10.4f} {rf['collective_s']:10.4f} "
            f"{rf['bottleneck']:>10s} {rf['step_s_lower_bound']:9.4f} {frac:6.3f} "
            f"{r['memory']['peak_gb']:7.2f} {r.get('useful_flop_ratio') or 0:7.3f}"
        )


def run(duration=None):
    rows = load_results()
    render(rows)
    # CSV summary for run.py
    out = []
    for r in rows:
        if r.get("status") != "ok":
            continue
        rf = r["roofline"]
        out.append({
            "bench": "roofline", "arch": r["arch"], "shape": r["shape"],
            "mesh": r["mesh"], "tag": r.get("tag", "baseline"),
            "bottleneck": rf["bottleneck"],
            "step_lower_bound_s": round(rf["step_s_lower_bound"], 5),
            "compute_fraction": round(rf["compute_s"] / rf["step_s_lower_bound"], 4)
            if rf["step_s_lower_bound"] else 0,
            "peak_gb": r["memory"]["peak_gb"],
        })
    if out:
        from _util import emit

        emit(out, ["bench", "arch", "shape", "mesh", "tag", "bottleneck",
                   "step_lower_bound_s", "compute_fraction", "peak_gb"],
             name="roofline")
    return out


if __name__ == "__main__":
    run()
