"""Roofline tables: the model dry-run renderer plus the OLTP log-pipeline
roofline (BENCH_roofline_oltp.json).

Part 1 renders the dry-run JSONs from ``benchmarks/results/`` into
per-(arch x shape x mesh) terms:

    compute   = dot-FLOPs/device   / 197 TFLOP/s  (bf16, TPU v5e)
    memory    = HBM bytes/device   / 819 GB/s
    collective= ICI bytes/device   / 50 GB/s (single-link, conservative)

``fraction`` = compute_s / step_lower_bound — how close the cell is to being
compute-bound (1.0 == at the compute roofline given perfect overlap).

Part 2 measures the logging/recovery pipeline the same way: each OLTP stage
is a byte stream (log bytes in, table state out), so its roof is the
machine's *measured* stream-copy memory bandwidth (probed at startup — the
shared container's attainable rate, not a spec sheet), with the emulated
SSD read bandwidth (``REPRO_SSD_BW`` x device parallelism) shown alongside
as the IO roof the paper's recovery model divides by.  Per (stage, mode)
row: achieved bytes/s over the stage's wall time vs those roofs, for

* ``replay`` — end-to-end ``recover()`` on segmented devices;
* ``replica_apply`` — ship + continuous apply into a live ``ArrayTable``;
* ``batch_occ`` — the batched forward path (validate→sequence→encode→
  publish) on null devices, bytes = log bytes produced;

in all three equivalence modes (scalar oracle / vectorized numpy /
compiled ``pallas``).  The fraction column is achieved/mem-roof: how much
of the machine's copy bandwidth the mode sustains.
"""

from __future__ import annotations

import json
import os
import sys
import time
from typing import Dict, List, Optional

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

RESULTS = os.path.join(os.path.dirname(__file__), "results")
OLTP_MODES = ("scalar", "vectorized", "pallas")


def load_results(tag: Optional[str] = None) -> List[Dict]:
    out = []
    if not os.path.isdir(RESULTS):
        return out
    for f in sorted(os.listdir(RESULTS)):
        if not f.endswith(".json"):
            continue
        if tag and not f.endswith(f"__{tag}.json"):
            continue
        with open(os.path.join(RESULTS, f)) as fh:
            out.append(json.load(fh))
    return out


def render(rows: List[Dict], title: str = "roofline") -> None:
    hdr = (f"{'arch':24s} {'shape':12s} {'mesh':6s} {'tag':10s} "
           f"{'compute_s':>10s} {'memory_s':>10s} {'collect_s':>10s} "
           f"{'bound':>10s} {'step>=s':>9s} {'frac':>6s} {'peakGB':>7s} {'MF/HLO':>7s}")
    print(f"== {title} ==")
    print(hdr)
    for r in rows:
        if r.get("status") == "skipped":
            print(f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:6s} {r.get('tag','-'):10s} "
                  f"{'SKIP':>10s}  ({r['reason'][:70]})")
            continue
        if r.get("status") != "ok":
            print(f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:6s} {r.get('tag','-'):10s} "
                  f"{'FAIL':>10s}  ({r.get('error','?')[:70]})")
            continue
        rf = r["roofline"]
        frac = rf["compute_s"] / rf["step_s_lower_bound"] if rf["step_s_lower_bound"] else 0
        mem = rf.get("memory_tpu_s", rf["memory_s"])
        print(
            f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:6s} {r.get('tag','-'):10s} "
            f"{rf['compute_s']:10.4f} {mem:10.4f} {rf['collective_s']:10.4f} "
            f"{rf['bottleneck']:>10s} {rf['step_s_lower_bound']:9.4f} {frac:6.3f} "
            f"{r['memory']['peak_gb']:7.2f} {r.get('useful_flop_ratio') or 0:7.3f}"
        )


# --- Part 2: OLTP log-pipeline roofline ---------------------------------------

def _mem_bw_probe(nbytes: int = 32 << 20, reps: int = 5) -> float:
    """Measured stream-copy bandwidth (read + write streams counted), the
    attainable roof for the byte-stream OLTP stages on this machine."""
    src = np.ones(nbytes, np.uint8)
    dst = np.empty_like(src)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        np.copyto(dst, src)
        best = min(best, time.perf_counter() - t0)
    return 2 * nbytes / best


def _oltp_row(section, mode, nbytes, wall_s, mem_bw, ssd_bw, extra=None):
    r = {
        "bench": "roofline_oltp", "section": section, "mode": mode,
        "MB": round(nbytes / 1e6, 2), "wall_s": round(wall_s, 4),
        "achieved_MBps": round(nbytes / wall_s / 1e6, 2),
        "mem_roof_MBps": round(mem_bw / 1e6, 1),
        "ssd_roof_MBps": round(ssd_bw / 1e6, 1),
        "frac_of_mem_roof": round(nbytes / wall_s / mem_bw, 4),
    }
    if extra:
        r.update(extra)
    return r


def _oltp_replay(t23, mem_bw, ssd_bw_dev, n_devices=2):
    from repro.core import recover

    logs = t23._synth_logs(n_devices, t23.REPLAY_RECORDS, t23.REPLAY_KEYS)
    nbytes = sum(len(b) for b in logs)
    devs = t23._seg_devices(logs)
    rows = []
    ref = None
    for mode in OLTP_MODES:
        recover(devs, mode=mode)  # warm (jit compiles / allocator first-touch)
        t0 = time.perf_counter()
        st = recover(devs, mode=mode)
        wall = time.perf_counter() - t0
        if ref is None:
            ref = st.data
        else:
            assert st.data == ref, f"replay mode {mode} diverged"
        rows.append(_oltp_row("replay", mode, nbytes, wall, mem_bw,
                              ssd_bw_dev * n_devices,
                              {"records": t23.REPLAY_RECORDS}))
    return rows


def _oltp_replica_apply(t23, mem_bw, ssd_bw_dev, n_devices=2):
    from repro.replica import Replica

    logs = t23._synth_logs(n_devices, t23.REPLAY_RECORDS, t23.REPLAY_KEYS)
    nbytes = sum(len(b) for b in logs)
    rows = []
    applied = {}
    for mode in OLTP_MODES:
        devs = t23._seg_devices(logs)
        # warm pass on its own replica (jit compiles for the pallas mode,
        # allocator first-touch for the others), then the timed catch-up
        warm = Replica(t23._seg_devices(logs), mode=mode, parallel=False)
        while warm.poll(parallel=False):
            pass
        rep = Replica(devs, mode=mode, parallel=False)
        t0 = time.perf_counter()
        while rep.poll(parallel=False):
            pass
        wall = time.perf_counter() - t0
        applied[mode] = rep.applier.n_applied
        rows.append(_oltp_row("replica_apply", mode, nbytes, wall, mem_bw,
                              ssd_bw_dev * n_devices,
                              {"records": rep.applier.n_applied}))
    assert len(set(applied.values())) == 1, f"apply counts diverged: {applied}"
    return rows


def _oltp_batch_occ(mem_bw, ssd_bw_dev, n_devices=2, batch_size=2048):
    from _util import FAST, make_engine

    from repro.db import ArrayTable, BatchOCC, ScalarBatchOCC, Table
    from repro.db import ycsb

    n_records = 20_000
    n_batches = 2 if FAST else 8
    scalar_batches = 1 if FAST else 2  # per-txn python loop; keep it bounded
    rows = []
    for mode in OLTP_MODES:
        engine = make_engine("poplar", n_devices, "null", 4)
        engine.start()
        if mode == "scalar":
            table = Table()
            ycsb.load(table, n_records)
            occ = ScalarBatchOCC(table, engine, n_workers=4)
            n_b = scalar_batches
        else:
            table = ArrayTable(capacity=n_records)
            ycsb.load(table, n_records)
            occ = BatchOCC(table, engine, n_workers=4, mode=mode)
            n_b = n_batches
        wl = ycsb.YCSBWriteOnly(n_records, seed=1)
        # full-size warm-up batch: above the fused engagement threshold, so
        # the pallas mode's jit compiles land outside the timed window
        occ.execute_batch(wl.next_batch(batch_size), max_rounds=2)
        base_bytes = sum(d.bytes_written for d in engine.devices)
        t0 = time.perf_counter()
        for _ in range(n_b):
            occ.execute_batch(wl.next_batch(batch_size), max_rounds=2)
        wall = time.perf_counter() - t0
        nbytes = sum(d.bytes_written for d in engine.devices) - base_bytes
        engine.stop()
        rows.append(_oltp_row("batch_occ", mode, nbytes, wall, mem_bw,
                              ssd_bw_dev * n_devices,
                              {"records": n_b * batch_size}))
    return rows


def run_oltp():
    import table23_recovery as t23

    mem_bw = _mem_bw_probe()
    ssd_bw_dev = float(os.environ.get("REPRO_SSD_BW", 1.2e9))
    rows = (_oltp_replay(t23, mem_bw, ssd_bw_dev)
            + _oltp_replica_apply(t23, mem_bw, ssd_bw_dev)
            + _oltp_batch_occ(mem_bw, ssd_bw_dev))
    from _util import emit

    emit(rows, ["bench", "section", "mode", "MB", "records", "wall_s",
                "achieved_MBps", "mem_roof_MBps", "ssd_roof_MBps",
                "frac_of_mem_roof"], name="roofline_oltp")
    return rows


def run(duration=None):
    rows = load_results()
    render(rows)
    # CSV summary for run.py
    out = []
    for r in rows:
        if r.get("status") != "ok":
            continue
        rf = r["roofline"]
        out.append({
            "bench": "roofline", "arch": r["arch"], "shape": r["shape"],
            "mesh": r["mesh"], "tag": r.get("tag", "baseline"),
            "bottleneck": rf["bottleneck"],
            "step_lower_bound_s": round(rf["step_s_lower_bound"], 5),
            "compute_fraction": round(rf["compute_s"] / rf["step_s_lower_bound"], 4)
            if rf["step_s_lower_bound"] else 0,
            "peak_gb": r["memory"]["peak_gb"],
        })
    if out:
        from _util import emit

        emit(out, ["bench", "arch", "shape", "mesh", "tag", "bottleneck",
                   "step_lower_bound_s", "compute_fraction", "peak_gb"],
             name="roofline")
    out.extend(run_oltp())
    return out


if __name__ == "__main__":
    from _util import bench_runtime_setup

    bench_runtime_setup()
    run()
