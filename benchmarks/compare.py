"""Diff fresh ``BENCH_<name>.json`` results against the committed baselines.

The repo commits every benchmark's row dump (``emit(..., name=...)`` in
``_util.py``), so after a bench run the working tree holds fresh JSON while
``git show HEAD:BENCH_<name>.json`` still serves the committed baseline —
this tool joins the two and prints per-row relative drift on every numeric
column, largest movers first.

Rows are matched by their *identity fields* (the non-numeric values: engine
name, workload, config string, ...) plus a duplicate counter, falling back
to row order when a file carries no identity at all.  Only files whose
``fast`` flag matches are compared — a FAST=1 run against a full-duration
baseline would be all noise.

Warn-only by default (exit 0, for the CI smoke lane); ``--fail-over PCT``
turns any drift beyond PCT percent into exit 1 for use as a local gate:

    python benchmarks/run.py fig5_throughput          # refresh the JSON
    python benchmarks/compare.py --fail-over 30 fig5  # gate at 30%
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import subprocess
import sys
from typing import Dict, List, Optional, Tuple

_REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

# columns that identify a row rather than measure it, even though numeric
_ID_HINTS = {"threads", "devices", "n_workers", "n_devices", "n_records",
             "n_shards", "shards", "segment", "device", "warehouses", "seed"}


def _baseline(name: str) -> Optional[Dict]:
    """The committed ``BENCH_<name>.json`` at HEAD (None if never committed)."""
    try:
        out = subprocess.run(
            ["git", "show", f"HEAD:BENCH_{name}.json"],
            cwd=_REPO_ROOT, capture_output=True, check=True,
        ).stdout
        return json.loads(out)
    except (subprocess.CalledProcessError, json.JSONDecodeError, OSError):
        return None


def _fresh(name: str) -> Optional[Dict]:
    path = os.path.join(_REPO_ROOT, f"BENCH_{name}.json")
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError):
        return None


def _row_key(row: Dict) -> Tuple:
    """Identity of a row: its non-measurement fields, in sorted field order."""
    parts = []
    for k in sorted(row):
        v = row[k]
        if isinstance(v, str) or isinstance(v, bool) or k in _ID_HINTS:
            parts.append((k, v))
    return tuple(parts)


def _index(rows: List[Dict]) -> Dict[Tuple, Dict]:
    """Key -> row, with a duplicate counter so repeated identities (e.g.
    append-mode sub-tables) still pair positionally."""
    out: Dict[Tuple, Dict] = {}
    seen: Dict[Tuple, int] = {}
    for i, row in enumerate(rows):
        k = _row_key(row)
        n = seen.get(k, 0)
        seen[k] = n + 1
        out[k + (("#", n),) if k else (("row", i),)] = row
    return out


def _drift_rows(name: str, base: Dict, new: Dict) -> List[Dict]:
    out: List[Dict] = []
    base_idx = _index(base.get("rows", []))
    new_idx = _index(new.get("rows", []))
    for key, brow in base_idx.items():
        nrow = new_idx.get(key)
        if nrow is None:
            continue
        ident = " ".join(
            f"{k}={v}" for k, v in key if k not in ("#", "row")) or f"{key}"
        for col in sorted(set(brow) & set(nrow)):
            b, n = brow[col], nrow[col]
            if (
                isinstance(b, bool) or isinstance(n, bool)
                or not isinstance(b, (int, float))
                or not isinstance(n, (int, float))
                or col in _ID_HINTS
            ):
                continue
            if b == n:
                continue
            drift = (n - b) / abs(b) if b else float("inf")
            out.append({
                "bench": name, "row": ident, "col": col,
                "base": b, "new": n, "drift_pct": 100.0 * drift,
            })
    return out


def compare(names: Optional[List[str]] = None, top: int = 20) -> List[Dict]:
    """All drift rows across the requested benches (default: every
    ``BENCH_*.json`` in the working tree), sorted by |drift| descending."""
    if not names:
        names = sorted(
            os.path.basename(p)[len("BENCH_"):-len(".json")]
            for p in glob.glob(os.path.join(_REPO_ROOT, "BENCH_*.json"))
        )
    drifts: List[Dict] = []
    for name in names:
        base, new = _baseline(name), _fresh(name)
        if base is None or new is None:
            print(f"# {name}: no {'baseline' if base is None else 'fresh run'}"
                  " — skipped")
            continue
        if base.get("fast") != new.get("fast"):
            print(f"# {name}: fast flag differs (baseline={base.get('fast')} "
                  f"fresh={new.get('fast')}) — skipped")
            continue
        drifts.extend(_drift_rows(name, base, new))
    drifts.sort(key=lambda d: abs(d["drift_pct"]), reverse=True)

    print("bench,row,col,base,new,drift_pct")
    for d in drifts[:top]:
        print(f"{d['bench']},{d['row']},{d['col']},{d['base']},{d['new']},"
              f"{d['drift_pct']:+.1f}")
    if len(drifts) > top:
        print(f"# ... {len(drifts) - top} more columns moved (use --top)")
    if not drifts:
        print("# no drift: fresh results match the committed baselines")
    return drifts


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    ap.add_argument("benchmarks", nargs="*",
                    help="bench names (fig5, table23, ...); default: all "
                         "BENCH_*.json present in the working tree")
    ap.add_argument("--top", type=int, default=20,
                    help="print at most N drift rows (default 20)")
    ap.add_argument("--fail-over", type=float, default=None, metavar="PCT",
                    help="exit 1 if any |drift| exceeds PCT percent "
                         "(default: warn-only, always exit 0)")
    args = ap.parse_args(argv)
    drifts = compare(args.benchmarks, top=args.top)
    if args.fail_over is not None:
        over = [d for d in drifts if abs(d["drift_pct"]) > args.fail_over]
        if over:
            print(f"# FAIL: {len(over)} column(s) drifted beyond "
                  f"{args.fail_over}% of baseline", file=sys.stderr)
            return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
