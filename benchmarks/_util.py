"""Benchmark harness shared by the per-figure scripts.

Each benchmark drives one logging-engine variant with N worker threads for a
fixed duration against an emulated-device set, then reports throughput,
commit latency and device/breakdown stats.

Container note (DESIGN §9): 1 CPU core — compute is GIL-serialized but the
emulated device waits release the GIL, preserving the IO-bound regime the
paper measures; thread counts are scaled down vs the paper's 20-core Xeon
(ratios between variants are the reproduction target).  Set
``BENCH_FAST=1`` for CI-speed runs.
"""

from __future__ import annotations

import json
import os
import statistics
import sys
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import EngineConfig, LoggingEngine, PoplarEngine  # noqa: E402
from repro.core.variants import CentrEngine, NvmDEngine, SiloEngine  # noqa: E402
from repro.db import OCCWorker, Table  # noqa: E402

FAST = os.environ.get("BENCH_FAST", "0") == "1"
DURATION = 0.6 if FAST else 2.0
THREADS = (1, 2, 4) if FAST else (1, 2, 4, 8)

_runtime_ready = False


def bench_runtime_setup() -> None:
    """Apply the bench-box runtime knobs (idempotent).

    Importing this module used to apply them as side effects, which leaked
    into anything importing it for a helper (tests grabbing
    ``robust_stats``, tools reading ``emit``'s accumulator).  Now they only
    apply when a benchmark actually runs: ``run.py`` and the per-figure
    ``__main__`` blocks call this, and the engine-creating entry points
    (:func:`run_bench` / :func:`run_batch_bench`) call it defensively —
    DeviceSpec reads ``REPRO_SSD_BW`` at device-creation time, so the env
    default must precede any ``make_engine``.
    """
    global _runtime_ready
    if _runtime_ready:
        return
    _runtime_ready = True
    # finer GIL timeslices: commit-latency measurements on 1 core are
    # otherwise dominated by 5ms thread-scheduling quanta rather than
    # protocol behaviour
    sys.setswitchinterval(5e-4)
    # benchmark-scaled SSD bandwidth (see repro.core.storage.DeviceSpec.ssd)
    os.environ.setdefault("REPRO_SSD_BW", "30e6")


def robust_stats(runs: Sequence[float]) -> Dict[str, float]:
    """Noise-robust summary for repeated bench cells: the median and the
    relative interquartile range (IQR ÷ median — 0 means perfectly stable,
    1 means the middle half of the runs spans the median's own magnitude).
    Stamped next to every ``runs`` list so run-to-run swings (the ~3x
    cross-shard wobble) are visible in the JSON rather than averaged away.
    """
    xs = sorted(float(x) for x in runs)
    if not xs:
        return {"median": float("nan"), "iqr_rel": float("nan")}
    med = statistics.median(xs)
    if len(xs) < 2:
        return {"median": med, "iqr_rel": 0.0}
    q1, q3 = statistics.quantiles(xs, n=4)[0], statistics.quantiles(xs, n=4)[2]
    return {
        "median": med,
        "iqr_rel": (q3 - q1) / med if med else float("inf"),
    }


def make_engine(
    name: str,
    n_devices: int = 2,
    device_kind: str = "ssd",
    n_workers: int = 4,
    epoch_interval: float = 50e-3,
) -> LoggingEngine:
    cfg = EngineConfig(n_buffers=n_devices, device_kind=device_kind)
    if device_kind == "nvm":
        cfg = EngineConfig.nvm(n_buffers=n_devices)
    if name == "poplar":
        return PoplarEngine(cfg)
    if name == "centr":
        return CentrEngine(EngineConfig(**{**cfg.__dict__, "n_buffers": 1}))
    if name == "silo":
        return SiloEngine(cfg, epoch_interval=epoch_interval)
    if name == "nvmd":
        return NvmDEngine(n_workers=n_workers, n_devices=n_devices, device_kind=device_kind)
    raise KeyError(name)


@dataclass
class BenchResult:
    engine: str
    workload: str
    n_workers: int
    n_devices: int
    duration_s: float
    committed: int
    submitted: int
    aborts: int
    latencies_ms: List[float] = field(default_factory=list)
    breakdown: Dict[str, float] = field(default_factory=dict)
    device_stats: List[Dict] = field(default_factory=list)

    @property
    def txn_per_s(self) -> float:
        return self.committed / self.duration_s

    @property
    def avg_latency_ms(self) -> float:
        return statistics.fmean(self.latencies_ms) if self.latencies_ms else float("nan")

    @property
    def p50_latency_ms(self) -> float:
        return statistics.median(self.latencies_ms) if self.latencies_ms else float("nan")


def run_bench(
    engine_name: str,
    workload_factory: Callable[[Table, int], object],
    load_fn: Callable[[Table], None],
    n_workers: int = 4,
    n_devices: int = 2,
    device_kind: str = "ssd",
    duration: float = DURATION,
    workload_name: str = "?",
    epoch_interval: float = 50e-3,
) -> BenchResult:
    bench_runtime_setup()
    table = Table()
    load_fn(table)
    engine = make_engine(engine_name, n_devices, device_kind, n_workers, epoch_interval)
    engine.start()
    occ = [OCCWorker(table, engine, i) for i in range(n_workers)]
    workloads = [workload_factory(table, i) for i in range(n_workers)]

    stop = threading.Event()
    txns_done: List[List] = [[] for _ in range(n_workers)]
    breakdown = [
        {"contention": 0.0, "log_work": 0.0, "other": 0.0} for _ in range(n_workers)
    ]

    # instrument allocate (Log contention: sequence-number allocation) and
    # publish (Log work: record insert + buffer-space waits)
    orig_alloc, orig_pub = engine.allocate, engine.publish

    local = threading.local()

    def timed_alloc(txn, r, w):
        t0 = time.perf_counter()
        out = orig_alloc(txn, r, w)
        local.alloc_t = time.perf_counter() - t0
        return out

    def timed_pub(txn):
        t0 = time.perf_counter()
        orig_pub(txn)
        local.pub_t = time.perf_counter() - t0

    engine.allocate = timed_alloc  # type: ignore[method-assign]
    engine.publish = timed_pub  # type: ignore[method-assign]

    def worker_loop(i: int) -> None:
        wl, oc = workloads[i], occ[i]
        bd = breakdown[i]
        while not stop.is_set():
            t0 = time.perf_counter()
            local.alloc_t = local.pub_t = 0.0
            txn = wl.next_txn(oc)
            dt = time.perf_counter() - t0
            bd["contention"] += getattr(local, "alloc_t", 0.0)
            bd["log_work"] += getattr(local, "pub_t", 0.0)
            bd["other"] += dt - getattr(local, "alloc_t", 0.0) - getattr(local, "pub_t", 0.0)
            if txn is not None:
                txns_done[i].append(txn)
            oc.drain()

    threads = [threading.Thread(target=worker_loop, args=(i,), daemon=True) for i in range(n_workers)]
    t_start = time.perf_counter()
    for t in threads:
        t.start()
    time.sleep(duration)
    stop.set()
    for t in threads:
        t.join(timeout=10)
    try:
        engine.quiesce(range(n_workers), timeout=30)
    except TimeoutError:
        pass
    elapsed = time.perf_counter() - t_start
    engine.stop()

    all_txns = [t for lst in txns_done for t in lst]
    committed = [t for t in all_txns if t.committed]
    # commit latency = wait from pre-commit (record buffered, SSN assigned)
    # to durable commit — the paper's Fig. 7/10 quantity
    lat = [(t.t_commit - t.t_precommit) * 1e3 for t in committed[: 200000]]
    agg = {k: sum(b[k] for b in breakdown) for k in ("contention", "log_work", "other")}
    devices = getattr(engine, "devices", [])
    return BenchResult(
        engine=engine_name,
        workload=workload_name,
        n_workers=n_workers,
        n_devices=n_devices,
        duration_s=elapsed,
        committed=len(committed),
        submitted=len(all_txns),
        aborts=sum(o.aborts for o in occ),
        latencies_ms=lat,
        breakdown=agg,
        device_stats=[d.stats() for d in devices],
    )


def run_batch_bench(
    n_workers: int = 4,
    n_devices: int = 2,
    device_kind: str = "ssd",
    duration: float = DURATION,
    batch_size: int = 2048,
    mode: str = "vectorized",
    workload: str = "ycsb_write",
    n_records: int = 20_000,
    max_rounds: int = 2,
) -> BenchResult:
    """Drive the batched array-native forward path (`repro.db.batch.BatchOCC`)
    for ``duration`` seconds: one Python thread generating ``batch_size``-txn
    batches, executed with vectorized OCC + bulk SSN reservation + batch
    encode against ``n_workers`` tid/buffer stripes — the apples-to-apples
    comparator for ``run_bench('poplar', ...)`` at the same worker count."""
    bench_runtime_setup()
    from repro.db import ArrayTable, BatchOCC
    from repro.db import ycsb

    table = ArrayTable(capacity=n_records)
    ycsb.load(table, n_records)
    indexed = False
    if workload == "ycsb_write":
        wl = ycsb.YCSBWriteOnly(n_records, seed=1)
        # rows equal key indices after load(): take the array-native entry
        indexed = table.row_of(ycsb.key_of(0)) == 0
    elif workload == "ycsb_hybrid":
        wl = ycsb.YCSBHybrid(n_records, seed=1)
    else:
        raise KeyError(workload)
    engine = make_engine("poplar", n_devices, device_kind, n_workers)
    engine.start()
    occ = BatchOCC(table, engine, n_workers=n_workers, mode=mode)

    n_committed = 0
    lat: List[float] = []
    pending: List = []  # pre-committed txns whose durable commit is in flight

    def sweep() -> None:
        nonlocal n_committed
        keep = []
        for t in pending:
            if t.committed:
                n_committed += 1
                if len(lat) < 200000:
                    lat.append((t.t_commit - t.t_precommit) * 1e3)
            else:
                keep.append(t)
        pending[:] = keep

    def one_batch() -> "object":
        if indexed:
            rd, rs, wr, ws, vals, vlen = wl.next_batch_indexed(batch_size)
            return occ.execute_indexed(rd, rs, wr, ws, vals, wr_vlen=vlen,
                                       max_rounds=max_rounds)
        return occ.execute_batch(wl.next_batch(batch_size),
                                 max_rounds=max_rounds)

    submitted = 0
    # one full-size warm-up batch outside the timed window: first-touch
    # numpy/alloc costs, and — crucially for mode="pallas" — a batch *above*
    # the fused engagement threshold so the jit compiles happen here, not on
    # the first timed batch (the scalar comparator's thread-start is
    # likewise pre-timing)
    one_batch()
    occ.drain()
    import gc

    gc.collect()
    t_start = time.perf_counter()
    deadline = t_start + duration
    while time.perf_counter() < deadline:
        submitted += batch_size
        res = one_batch()
        pending.extend(res.committed)
        occ.drain()
        # release committed txns (and their payload bytes) promptly: keeps
        # the GC working set flat instead of growing with throughput
        sweep()
    try:
        engine.quiesce(range(n_workers), timeout=30)
    except TimeoutError:
        pass
    elapsed = time.perf_counter() - t_start
    engine.stop()
    sweep()

    return BenchResult(
        engine=f"poplar_batch[{mode}]",
        workload=workload,
        n_workers=n_workers,
        n_devices=n_devices,
        duration_s=elapsed,
        committed=n_committed,
        submitted=submitted,
        aborts=occ.aborts,
        latencies_ms=lat,
        device_stats=[d.stats() for d in engine.devices],
    )


# --- workload factories -----------------------------------------------------------

def ycsb_write_factory(n_records: int = 20_000):
    from repro.db import ycsb

    def load(table: Table) -> None:
        ycsb.load(table, n_records)

    def make(table: Table, worker_id: int):
        return ycsb.YCSBWriteOnly(n_records, seed=worker_id)

    return load, make


def ycsb_hybrid_factory(n_records: int = 20_000, scan_length: int = 10):
    from repro.db import ycsb

    def load(table: Table) -> None:
        ycsb.load(table, n_records)

    def make(table: Table, worker_id: int):
        return ycsb.YCSBHybrid(n_records, scan_length=scan_length, seed=worker_id)

    return load, make


def tpcc_factory(warehouses: int = 8):
    from repro.db import tpcc

    def load(table: Table) -> None:
        tpcc.load(table, warehouses)

    def make(table: Table, worker_id: int):
        return tpcc.TPCC(table, warehouses, seed=worker_id)

    return load, make


_REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
_JSON_ACC: Dict[str, List[Dict]] = {}


def run_metadata() -> Dict[str, str]:
    """Environment fingerprint stamped into every ``BENCH_<name>.json`` so
    the committed bench trajectory stays interpretable across machines:
    UTC timestamp, hostname, the emulated-SSD bandwidth scaling, and the
    python/jax/numpy versions (package metadata — jax itself stays
    unimported; most benches never need it)."""
    import datetime
    import platform
    import socket

    def _ver(pkg: str) -> str:
        try:
            from importlib.metadata import version

            return version(pkg)
        except Exception:
            return "unknown"

    return {
        "utc": datetime.datetime.now(datetime.timezone.utc).isoformat(
            timespec="seconds"
        ),
        "hostname": socket.gethostname(),
        "repro_ssd_bw": os.environ.get("REPRO_SSD_BW", ""),
        "python": platform.python_version(),
        "jax": _ver("jax"),
        "numpy": _ver("numpy"),
    }


def emit(rows: Sequence[Dict], header: Sequence[str], name: Optional[str] = None,
         append: bool = False) -> None:
    """Print a CSV block; with ``name``, also persist the rows (plus the
    :func:`run_metadata` fingerprint) to ``BENCH_<name>.json`` at the repo
    root so the perf trajectory is machine-readable across PRs.  A plain
    emit resets the file's rows (so a re-invoked ``run()`` never
    duplicates); a benchmark emitting several sub-tables passes
    ``append=True`` on the later calls (table23)."""
    print(",".join(header))
    for r in rows:
        print(",".join(str(r.get(h, "")) for h in header))
    if name is None:
        return
    acc = _JSON_ACC.setdefault(name, [])
    if not append:
        acc.clear()
    acc.extend(dict(r) for r in rows)
    path = os.path.join(_REPO_ROOT, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(
            {"bench": name, "fast": FAST, "meta": run_metadata(), "rows": acc},
            f, indent=1,
        )
        f.write("\n")
