"""Fig. 7 — commit latency vs worker threads.

Expectation (paper): SILO worst (~epoch interval, 50 ms); POPLAR/CENTR near
the 5 ms group-commit interval at low thread counts."""
from _util import (THREADS, bench_runtime_setup, emit, run_bench,
                   tpcc_factory, ycsb_write_factory)

ENGINES = ("centr", "silo", "nvmd", "poplar")


def run(duration=None):
    rows = []
    for wl_name, (load, make) in (
        ("ycsb_write", ycsb_write_factory()),
        ("tpcc", tpcc_factory()),
    ):
        for engine in ENGINES:
            for n in THREADS:
                r = run_bench(engine, make, load, n_workers=n, n_devices=2,
                              workload_name=wl_name,
                              **({"duration": duration} if duration else {}))
                rows.append({
                    "bench": "fig7", "workload": wl_name, "engine": engine,
                    "threads": n,
                    "avg_latency_ms": round(r.avg_latency_ms, 3),
                    "p50_latency_ms": round(r.p50_latency_ms, 3),
                })
    emit(rows, ["bench", "workload", "engine", "threads", "avg_latency_ms", "p50_latency_ms"], name="fig7")
    return rows


if __name__ == "__main__":
    bench_runtime_setup()
    run()
