"""Fig. 6 — per-device IO bandwidth at saturation (derived from emulated
device busy-time accounting)."""
from _util import (THREADS, bench_runtime_setup, emit, run_bench,
                   tpcc_factory, ycsb_write_factory)

ENGINES = ("centr", "silo", "nvmd", "poplar")


def run(duration=None):
    rows = []
    for wl_name, (load, make) in (
        ("ycsb_write", ycsb_write_factory()),
        ("tpcc", tpcc_factory()),
    ):
        for engine in ENGINES:
            n = max(THREADS)
            r = run_bench(engine, make, load, n_workers=n, n_devices=2,
                          workload_name=wl_name,
                          **({"duration": duration} if duration else {}))
            for i, d in enumerate(r.device_stats):
                mbps = d["bytes_written"] / max(r.duration_s, 1e-9) / 1e6
                util = d["busy_time_s"] / max(r.duration_s, 1e-9)
                rows.append({
                    "bench": "fig6", "workload": wl_name, "engine": engine,
                    "device": i, "MB_per_s": round(mbps, 2),
                    "utilization": round(util, 3),
                    "avg_write_KB": round(d["avg_write_bytes"] / 1e3, 2),
                })
    emit(rows, ["bench", "workload", "engine", "device", "MB_per_s", "utilization", "avg_write_KB"], name="fig6")
    return rows


if __name__ == "__main__":
    bench_runtime_setup()
    run()
