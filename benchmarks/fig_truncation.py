"""Log lifecycle benchmark: disk footprint + recovery time, bounded vs not.

A sustained YCSB write stream runs for N checkpoint rounds on file-backed
devices.  Two configs:

* ``unbounded``  — checkpoints are taken but the log is append-only-forever
  (the pre-lifecycle behaviour): on-disk log bytes and ``recover()`` wall
  time grow linearly with the rounds;
* ``truncated``  — a :class:`~repro.core.truncate.LogTruncator` pass runs
  after each checkpoint, dropping the sealed segments the checkpoint
  covers: both metrics stay flat in N.

Both configs recover from ``(checkpoint, log suffix)`` with the vectorized
replay, and the recovered images are asserted identical — the boundedness
comes for free, not at the cost of recovery fidelity.

Emits ``BENCH_truncation.json`` rows:
``config,round,txns_total,log_bytes,sealed_segments,bytes_dropped_total,
recover_s,recovered_keys``.
"""

import os
import shutil
import sys
import tempfile
import time

sys.path.insert(0, os.path.dirname(__file__))

from _util import FAST, bench_runtime_setup, emit  # noqa: E402

from repro.core import (  # noqa: E402
    CheckpointDaemon,
    EngineConfig,
    LogTruncator,
    PoplarEngine,
    recover,
)
from repro.db import ArrayTable, BatchOCC  # noqa: E402
from repro.db import ycsb  # noqa: E402

N_ROUNDS = 4 if FAST else 8
BATCHES_PER_ROUND = 3 if FAST else 8
BATCH = 1024
N_RECORDS = 4096
N_DEVICES = 2


def _csn_fn(engine):
    def fn():
        for i in range(len(engine.buffers)):
            engine.logger_tick(i, force=True)
        return engine.commit.advance_csn()

    return fn


def _run_config(truncate: bool, workdir: str):
    dev_dir = os.path.join(workdir, "devs")
    ckpt_dir = os.path.join(workdir, "ckpt")
    engine = PoplarEngine(EngineConfig(
        n_buffers=N_DEVICES, device_kind="ssd", device_dir=dev_dir,
        device_clock="virtual", segment_bytes=64 * 1024,
    ))
    table = ArrayTable(capacity=N_RECORDS)
    ycsb.load(table, N_RECORDS)
    occ = BatchOCC(table, engine, n_workers=2)
    wl = ycsb.YCSBWriteOnly(N_RECORDS, seed=7)
    daemon = CheckpointDaemon(ckpt_dir, n_threads=2, m_files=2,
                              csn_fn=_csn_fn(engine))
    truncator = LogTruncator(engine, ckpt_dir) if truncate else None

    rows = []
    txns_total = 0
    final_state = None
    for rnd in range(1, N_ROUNDS + 1):
        for _ in range(BATCHES_PER_ROUND):
            occ.execute_batch(wl.next_batch(BATCH), max_rounds=2)
            for i in range(len(engine.buffers)):
                engine.logger_tick(i, force=True)
            occ.drain()
            txns_total += BATCH
        # measure at the end of the round, *before* this round's checkpoint:
        # the truncated config's steady state is then ~one round of retained
        # log (whatever the previous round's pass could not yet cover), not
        # the degenerate just-truncated zero
        t0 = time.perf_counter()
        state = recover(engine.devices, checkpoint_dir=ckpt_dir)
        dt = time.perf_counter() - t0
        final_state = state
        rows.append({
            "config": "truncated" if truncate else "unbounded",
            "round": rnd,
            "txns_total": txns_total,
            "log_bytes": sum(d.disk_bytes() for d in engine.devices),
            "sealed_segments": sum(len(d.segments()) for d in engine.devices),
            "bytes_dropped_total": (
                truncator.total_bytes_dropped if truncator else 0
            ),
            "recover_s": round(dt, 4),
            "recovered_keys": len(state.data),
        })
        entries = sorted((k.encode(), v, s) for k, v, s in table.items()
                         if s > 0)
        daemon.run_once([entries[0::2], entries[1::2]], epoch=rnd)
        if truncator is not None:
            truncator.run_once()
    for d in engine.devices:
        d.close()
    return rows, final_state


def run() -> None:
    rows = []
    states = {}
    for truncate in (False, True):
        workdir = tempfile.mkdtemp(prefix="fig_truncation_")
        try:
            r, state = _run_config(truncate, workdir)
        finally:
            shutil.rmtree(workdir, ignore_errors=True)
        rows.extend(r)
        states[truncate] = state
    # boundedness must not cost fidelity: identical final recovered images
    # is the same invariant tests/test_truncation.py property-checks
    assert states[True].data == states[False].data, (
        "truncated recovery diverged from the unbounded oracle"
    )
    header = ["config", "round", "txns_total", "log_bytes",
              "sealed_segments", "bytes_dropped_total", "recover_s",
              "recovered_keys"]
    emit(rows, header, name="truncation")


if __name__ == "__main__":
    bench_runtime_setup()
    run()
