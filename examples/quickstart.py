"""Quickstart: train a small llama-family model with Poplar-journaled
fault tolerance, then generate from it.

    PYTHONPATH=src python examples/quickstart.py

This drives the same code paths as the production launcher
(`repro.launch.train`) at CPU-friendly scale; swap ``--reduced`` off and add
the production mesh for pod-scale runs (see launch/dryrun.py for the
sharding configs that compile for 256/512 chips).
"""

import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch import train as train_mod


def main() -> None:
    journal = tempfile.mkdtemp(prefix="quickstart_journal_")
    # QUICKSTART_STEPS shrinks the run further (CI smoke uses 12)
    steps = int(os.environ.get("QUICKSTART_STEPS", "60"))
    train_mod.main([
        "--arch", "tinyllama-1.1b",
        "--reduced",
        "--n-layers", "4",
        "--d-model", "128",
        "--steps", str(steps),
        "--batch", "8",
        "--seq", "128",
        "--journal-dir", journal,
        "--save-every", str(min(20, max(steps // 2, 1))),
        "--log-every", "10",
    ])
    print(f"\njournal lanes written to {journal}:")
    for f in sorted(os.listdir(journal)):
        print("  ", f, os.path.getsize(os.path.join(journal, f)), "bytes")


if __name__ == "__main__":
    main()
