"""Crash + parallel recovery demo: the Poplar journal guarantees that a
training run resumes from the newest *committed* step marker — shard
records from a half-flushed step are provably uncommitted and ignored
(recoverability, paper §3.1/§5, applied to train state).
"""

import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import reduced
from repro.configs.registry import get_config
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.journal import PoplarCheckpointManager, restore_latest, to_pytree
from repro.models.api import build_model
from repro.optim import adamw
from repro.train.step import make_train_step


def main() -> None:
    cfg = reduced(get_config("qwen2-1.5b"), n_layers=2)
    model = build_model(cfg)
    opt_cfg = adamw.AdamWConfig(lr=1e-3)
    step_fn = jax.jit(make_train_step(model, opt_cfg))
    pipe = TokenPipeline(DataConfig(vocab=cfg.vocab, batch=4, seq_len=64))

    params = model.init(jax.random.PRNGKey(0))
    opt = adamw.init(params, opt_cfg)

    journal = tempfile.mkdtemp(prefix="crash_demo_")
    mgr = PoplarCheckpointManager(journal, n_lanes=3, flush_interval=1e-3)

    print("== phase 1: train 12 steps, journaling every step ==")
    for step in range(12):
        batch = {k: jnp.asarray(v) for k, v in pipe.next_batch().items()}
        params, opt, m = step_fn(params, opt, batch)
        h = mgr.save(step, {"params": params, "opt": opt, "data": pipe.state()},
                     {"loss": float(m["loss"])})
        h.wait()
        if step == 9:
            mgr.wait_for_commit(9, timeout=30)  # make sure step 9 is durable
    committed_before = mgr.last_committed_step()
    print(f"   last committed step before crash: {committed_before}")
    print("== CRASH (loggers killed, volatile buffers lost) ==")
    mgr.crash()

    print("== phase 2: parallel recovery from journal lanes ==")
    out = restore_latest(journal)
    assert out is not None
    rstep, flat, meta = out
    print(f"   restored step {rstep} (meta {meta}) — "
          f"{'all' if rstep == 11 else 'volatile tail dropped;'} consistent by construction")
    assert rstep >= 9
    tree = to_pytree(flat, {"params": params, "opt": opt, "data": pipe.state()})
    pipe2 = TokenPipeline.restore(DataConfig(vocab=cfg.vocab, batch=4, seq_len=64), tree["data"])
    params2 = jax.tree.map(jnp.asarray, tree["params"])
    opt2 = jax.tree.map(jnp.asarray, tree["opt"])

    print("== phase 3: resume training ==")
    for step in range(rstep + 1, rstep + 4):
        batch = {k: jnp.asarray(v) for k, v in pipe2.next_batch().items()}
        params2, opt2, m = step_fn(params2, opt2, batch)
        print(f"   step {step} loss {float(m['loss']):.4f}")
    print("OK — resumed exactly at the recovered cursor", pipe2.cursor)


if __name__ == "__main__":
    main()
