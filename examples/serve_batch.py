"""Batched serving demo: prefill a batch of prompts, decode greedily.

Exercises the same prefill/decode_step code paths the decode_32k/long_500k
dry-run cells lower at pod scale (ring caches for SWA, constant-size state
for rwkv).
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import reduced
from repro.configs.registry import get_config
from repro.models.api import build_model
from repro.models.serve_llm import ServeEngine


def main() -> None:
    for arch in ("tinyllama-1.1b", "mixtral-8x22b", "rwkv6-7b"):
        cfg = reduced(get_config(arch), n_layers=2)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        engine = ServeEngine(model, params, cache_len=96)
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (4, 32)), jnp.int32)}
        res = engine.generate(batch, max_new=12)
        print(f"{arch:16s} prefill {res.prefill_s*1e3:7.1f}ms "
              f"decode {res.decode_s*1e3:7.1f}ms  {res.tokens_per_s:7.1f} tok/s "
              f"first tokens {res.tokens[0][:6].tolist()}")


if __name__ == "__main__":
    main()
