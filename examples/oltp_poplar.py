"""The paper's own setting: OLTP transactions through Poplar vs CENTR on
emulated SSDs, plus crash recovery of the database image (paper §4–§5)."""

import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
os.environ.setdefault("REPRO_SSD_BW", "30e6")  # benchmark-scaled SSD

import threading
import time

from repro.core import CheckpointDaemon, EngineConfig, PoplarEngine, recover
from repro.core.variants import CentrEngine
from repro.db import OCCWorker, Table, ycsb


def run_engine(name, engine, n_workers=4, duration=1.5):
    table = Table()
    ycsb.load(table, 10_000)
    engine.start()
    occ = [OCCWorker(table, engine, i) for i in range(n_workers)]
    wls = [ycsb.YCSBWriteOnly(10_000, seed=i) for i in range(n_workers)]
    stop = threading.Event()
    counts = [0] * n_workers

    def loop(i):
        while not stop.is_set():
            if wls[i].next_txn(occ[i]) is not None:
                counts[i] += 1
            occ[i].drain()

    ts = [threading.Thread(target=loop, args=(i,), daemon=True) for i in range(n_workers)]
    t0 = time.perf_counter()
    for t in ts:
        t.start()
    time.sleep(duration)
    stop.set()
    for t in ts:
        t.join()
    engine.quiesce(range(n_workers), timeout=30)
    elapsed = time.perf_counter() - t0
    engine.stop()
    print(f"{name:8s} {sum(counts)/elapsed:10,.0f} txn/s "
          f"({len(engine.devices) if hasattr(engine,'devices') else 1} devices)")
    return engine, table


def main() -> None:
    print("== YCSB write-only, 4 workers ==")
    run_engine("centr", CentrEngine(EngineConfig(n_buffers=1, device_kind="ssd")))
    d = tempfile.mkdtemp(prefix="poplar_oltp_")
    eng, table = run_engine(
        "poplar", PoplarEngine(EngineConfig(n_buffers=2, device_kind="ssd", device_dir=d))
    )

    print("== crash + parallel recovery (Poplar) ==")
    t0 = time.perf_counter()
    state = recover(eng.devices)
    dt = time.perf_counter() - t0
    mismatch = sum(
        1 for k, (v, s) in state.data.items()
        if (table.get(k.decode()) or type("x", (), {"value": None})).value != v
    )
    print(f"recovered {len(state.data)} keys in {dt*1e3:.0f}ms wall "
          f"(RSNe={state.rsne}); mismatches vs live table: {mismatch}")
    assert mismatch == 0


if __name__ == "__main__":
    main()
