"""Sharded engine forward path (`repro.shard`).

* the router partitions deterministically and splits batches correctly;
* a 1-shard ShardedEngine is byte-identical to a bare BatchOCC (the fast
  path really is unchanged);
* cross-shard transactions commit only once durable on *every* participant
  (the generalized Qww/Qwr rule), and their writes are invisible before;
* property: random mixed workloads satisfy Level-1 recoverability
  (`core/levels.check_recoverability`) on every shard projection — RAW ⇒
  global commit order, WAW ⇒ per-shard SSN order.
"""

import random
from typing import Dict, List, Tuple

import numpy as np

from repro.core import EngineConfig, PoplarEngine
from repro.core.levels import Dep, TxnInfo, check_recoverability
from repro.db import ArrayTable, BatchOCC, TxnSpec
from repro.shard import Router, ShardedConfig, ShardedEngine


def _mk(tmp_path=None, **kw) -> ShardedEngine:
    # ssd spec + virtual clock: no sleeping, but no inline flush-on-drain
    # either (null's sub-5us latency triggers it), so commit gating is real
    cfg = dict(n_shards=2, n_buffers=1, n_workers=2, device_kind="ssd",
               device_clock="virtual")
    cfg.update(kw)
    if tmp_path is not None:
        cfg["device_dir"] = str(tmp_path)
    return ShardedEngine(ShardedConfig(**cfg))


def _keys_by_shard(eng: ShardedEngine, n: int) -> List[List[str]]:
    out: List[List[str]] = [[] for _ in range(eng.cfg.n_shards)]
    for i in range(n):
        k = f"user{i:010d}"
        out[eng.shard_of(k)].append(k)
    return out


# --- router -------------------------------------------------------------------

def test_router_deterministic_and_covering():
    r1, r2 = Router(4), Router(4)
    keys = [f"k{i}" for i in range(400)]
    assert [r1.shard_of(k) for k in keys] == [r2.shard_of(k) for k in keys]
    assert {r1.shard_of(k) for k in keys} == {0, 1, 2, 3}


def test_router_split():
    r = Router(2)
    k0 = next(k for k in (f"a{i}" for i in range(50)) if r.shard_of(k) == 0)
    k1 = next(k for k in (f"a{i}" for i in range(50)) if r.shard_of(k) == 1)
    specs = [
        TxnSpec(writes=[(k0, b"x")]),
        TxnSpec(reads=[k1], writes=[(k1, b"y")]),
        TxnSpec(reads=[k0], writes=[(k1, b"z")]),   # spans both
    ]
    per_shard, cross = r.split(specs)
    assert [i for i, _ in per_shard[0]] == [0]
    assert [i for i, _ in per_shard[1]] == [1]
    assert [(i, shards) for i, _, shards in cross] == [(2, [0, 1])]


def test_engine_template_device_dir_is_split_per_shard(tmp_path):
    """A device_dir supplied through the EngineConfig override must still
    be re-pointed per shard — shards sharing one directory would
    interleave frames into the same log files."""
    from repro.core.engine import EngineConfig

    eng = ShardedEngine(ShardedConfig(
        n_shards=2,
        engine=EngineConfig(n_buffers=1, device_kind="null",
                            device_dir=str(tmp_path)),
    ))
    paths = [d.path for sh in eng.shards for d in sh.engine.devices]
    assert len(set(paths)) == len(paths)
    assert all(f"shard{p}" in path for p, path in enumerate(paths))


# --- 1-shard == bare BatchOCC -------------------------------------------------

def test_single_shard_is_the_unchanged_fast_path(tmp_path):
    rng = random.Random(3)
    keys = [f"user{i:010d}" for i in range(30)]
    sharded = _mk(tmp_path / "sharded", n_shards=1, n_buffers=2)
    tab = ArrayTable()
    eng = PoplarEngine(EngineConfig(n_buffers=2, device_kind="ssd",
                                    device_clock="virtual",
                                    device_dir=str(tmp_path / "bare")))
    bare = BatchOCC(tab, eng, n_workers=2)
    for k in keys[:15]:
        v = rng.randbytes(8)
        sharded.insert(k, v)
        tab.insert(k, v)
    for _ in range(3):
        specs = [
            TxnSpec(
                reads=rng.sample(keys, rng.randrange(0, 2)),
                writes=[(k, rng.randbytes(10))
                        for k in rng.sample(keys, rng.randrange(1, 3))],
            )
            for _ in range(20)
        ]
        rs = sharded.execute_batch(specs, max_rounds=2)
        rb = bare.execute_batch(specs, max_rounds=2)
        assert not rs.cross
        assert rs.committed_idx == rb.committed_idx
        assert [(t.tid, t.ssn) for t in rs.committed] == [
            (t.tid, t.ssn) for t in rb.committed
        ]
        sharded.drain()
        bare.drain()
    sharded.quiesce()
    eng.quiesce(range(2))
    assert sharded.to_dict() == tab.to_dict()
    for d in sharded.shards[0].engine.devices + eng.devices:
        d.close()
    assert [d.read_all() for d in sharded.shards[0].engine.devices] == [
        d.read_all() for d in eng.devices
    ]


# --- cross-shard commit gating ------------------------------------------------

def test_cross_shard_commits_only_when_durable_everywhere():
    eng = _mk()
    by_shard = _keys_by_shard(eng, 40)
    k0, k1 = by_shard[0][0], by_shard[1][0]
    eng.insert(k0, b"old0")
    eng.insert(k1, b"old1")

    res = eng.execute_batch(
        [TxnSpec(reads=[k0], writes=[(k0, b"new0"), (k1, b"new1")])]
    )
    assert len(res.cross) == 1 and not res.aborted
    xt = res.cross[0]
    assert sorted(p.shard for p in xt.parts) == [0, 1]

    # nothing durable: invisible, locked, uncommitted
    assert eng.drain() == 0 and not xt.committed
    assert eng.get(k0) == (b"old0", 0)
    r0 = eng.shards[0].table.row_of(k0)
    assert eng.shards[0].table.lock_owner[r0] == xt.gtid

    # shard 0 durable only: still gated on shard 1
    for i in range(len(eng.shards[0].engine.buffers)):
        eng.shards[0].engine.logger_tick(i, force=True)
    assert eng.coordinator.sweep() == 0 and not xt.committed

    # both durable: commits, applies, unlocks
    eng.tick(force=True)
    assert eng.drain() == 1 and xt.committed
    assert eng.get(k0) == (b"new0", xt.parts[0].ssn)
    assert eng.get(k1) == (b"new1", xt.parts[1].ssn)
    assert eng.shards[0].table.lock_owner[r0] == 0


def test_cross_shard_conflicts_abort():
    eng = _mk()
    by_shard = _keys_by_shard(eng, 40)
    k0, k1 = by_shard[0][0], by_shard[1][0]
    spec = TxnSpec(writes=[(k0, b"a"), (k1, b"a")])
    res1 = eng.execute_batch([spec])
    assert len(res1.cross) == 1
    # same rows, first txn still pending => foreign locks => abort
    res2 = eng.execute_batch([TxnSpec(writes=[(k0, b"b"), (k1, b"b")])])
    assert res2.aborted == [0] and not res2.cross
    # single-shard txns on the locked rows abort too (and win after commit)
    res3 = eng.execute_batch([TxnSpec(writes=[(k0, b"c")])])
    assert res3.aborted == [0]
    eng.quiesce()
    assert res1.cross[0].committed
    res4 = eng.execute_batch([TxnSpec(writes=[(k0, b"c")])])
    assert res4.committed_idx == [0]
    eng.quiesce()
    assert eng.get(k0)[0] == b"c" and eng.get(k1)[0] == b"a"


def test_stale_observed_ssn_aborts_cross_shard():
    eng = _mk()
    by_shard = _keys_by_shard(eng, 40)
    k0, k1 = by_shard[0][0], by_shard[1][0]
    eng.insert(k0, b"v")
    res = eng.execute_batch(
        [TxnSpec(reads=[k0], writes=[(k1, b"w")], observed=[99])]
    )
    assert res.aborted == [0]
    res = eng.execute_batch(
        [TxnSpec(reads=[k0], writes=[(k1, b"w")], observed=[0])]
    )
    assert len(res.cross) == 1
    eng.quiesce()


# --- recoverability property --------------------------------------------------

def _run_random_schedule(seed: int):
    """Random mixed single/cross-shard schedule through a stepped sharded
    engine; returns (engine, txn records, ack-ordered tids)."""
    rng = random.Random(seed)
    n_shards = rng.choice([2, 3])
    eng = _mk(n_shards=n_shards, n_buffers=rng.choice([1, 2]))
    keys = [f"user{i:010d}" for i in range(14)]
    for k in keys[:7]:
        eng.insert(k, rng.randbytes(6))

    # per committed txn: tid, per-shard ssn, writes [(key, shard, ssn)],
    # reads [(key, shard, observed ssn)]
    records: List[Dict] = []
    live: List[Tuple] = []  # (kind, obj, spec) awaiting commit

    # commit order is tracked at drain-pass granularity: within one pass
    # every txn whose watermark already passed is acked, and ack order
    # across independent worker queues inside a pass is arbitrary (the
    # same relaxation test_levels_property documents) — so txns acked in
    # the same pass get equal commit_seq, which RAW permits
    commit_pass: Dict[int, int] = {}
    pass_no = 0

    def _drain_pass():
        nonlocal pass_no
        eng.drain()
        pass_no += 1
        for kind, obj, _ in live:
            tid = obj.tid if kind == "s" else obj.gtid
            if obj.committed and tid not in commit_pass:
                commit_pass[tid] = pass_no

    for _ in range(5):
        specs = []
        for _ in range(rng.randrange(2, 10)):
            reads = rng.sample(keys, rng.randrange(0, 3))
            writes = [(k, rng.randbytes(6))
                      for k in rng.sample(keys, rng.randrange(0, 3))]
            if not reads and not writes:
                writes = [(keys[0], b"f")]
            specs.append(TxnSpec(reads=reads, writes=writes))
        res = eng.execute_batch(specs, max_rounds=2)
        for t, i in zip(res.committed, res.committed_idx):
            live.append(("s", t, specs[i]))
        for xt, i in zip(res.cross, res.cross_idx):
            live.append(("x", xt, specs[i]))
        if rng.random() < 0.7:
            eng.tick(force=True)
        _drain_pass()
    for _ in range(8):
        eng.tick(force=True)
        _drain_pass()

    for kind, obj, spec in live:
        assert obj.committed  # fully flushed + drained above
        rec = {"tid": obj.tid if kind == "s" else obj.gtid,
               "commit_pass": commit_pass[obj.tid if kind == "s" else obj.gtid],
               "writes": [], "reads": []}
        if kind == "s":
            p = eng.shard_of(spec.writes[0][0]) if spec.writes else (
                eng.shard_of(spec.reads[0]))
            rec["writes"] = [(k, p, obj.ssn) for k, _ in spec.writes]
            rec["reads"] = [(k, eng.shard_of(k), int(s))
                            for k, s in obj.read_set]
        else:
            for part in obj.parts:
                tab = eng.shards[part.shard].table
                rec["writes"] += [(tab.key_of(int(r)), part.shard, part.ssn)
                                  for r in part.wr_rows]
                rec["reads"] += [
                    (tab.key_of(int(r)), part.shard, int(s))
                    for r, s in zip(part.rd_rows, part.rd_ssn)
                ]
        records.append(rec)
    return eng, records


def test_sharded_recoverability_property():
    for seed in range(4):
        eng, records = _run_random_schedule(seed)
        n_shards = eng.cfg.n_shards
        commit_seq = {r["tid"]: r["commit_pass"] for r in records}
        # (shard, ssn) -> writer tid; per-key writer chain in SSN order
        writer_of: Dict[Tuple[int, int], int] = {}
        chains: Dict[str, List[Tuple[int, int]]] = {}  # key -> [(ssn, tid)]
        for r in records:
            for k, p, s in r["writes"]:
                writer_of[(p, s)] = r["tid"]
                chains.setdefault(k, []).append((s, r["tid"]))

        # per-shard projections: shard-local SSNs are comparable, commit
        # order is global — every RAW/WAW edge lives inside one shard
        for p in range(n_shards):
            infos: Dict[int, TxnInfo] = {}
            for r in records:
                ssns = {q for _, q, s in r["writes"]} | {
                    q for _, q, s in r["reads"]}
                if p not in ssns:
                    continue
                ssn_p = next(
                    (s for _, q, s in r["writes"] if q == p),
                    max((s for _, q, s in r["reads"] if q == p), default=0),
                )
                deps = []
                for k, q, obs in r["reads"]:
                    if q == p and obs > 0:
                        pred = writer_of.get((p, obs))
                        if pred is not None and pred != r["tid"]:
                            deps.append((pred, Dep.RAW))
                for k, q, s in r["writes"]:
                    if q != p:
                        continue
                    prev = [(cs, ct) for cs, ct in chains[k]
                            if cs < s and ct != r["tid"]]
                    if prev:
                        deps.append((max(prev)[1], Dep.WAW))
                infos[r["tid"]] = TxnInfo(
                    tid=r["tid"], ssn=ssn_p,
                    commit_seq=commit_seq[r["tid"]], deps=deps,
                )
            errs = check_recoverability(infos)
            assert errs == [], (seed, p, errs)
