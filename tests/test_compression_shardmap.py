"""int8 error-feedback compressed psum under shard_map (subprocess, 4 devs)."""

import json
import os
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import sys, json
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp
    from repro.launch.mesh import mesh_context
    import numpy as np
    from functools import partial
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    from repro.parallel.compression import compressed_psum

    kw = ({"axis_types": (jax.sharding.AxisType.Auto,)}
          if hasattr(jax.sharding, "AxisType") else {})
    mesh = jax.make_mesh((4,), ("data",), **kw)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 1, (4, 512)), jnp.float32)

    @partial(shard_map, mesh=mesh, in_specs=P("data"), out_specs=P("data"),
             check_rep=False)
    def f(xs):
        return compressed_psum(xs[0], "data")[None]

    with mesh_context(mesh):
        out = jax.jit(f)(x)
    exact = jnp.sum(x, axis=0)
    # every shard holds the same (compressed) sum
    for i in range(4):
        err = float(jnp.max(jnp.abs(out[i] - exact)))
        rel = err / float(jnp.max(jnp.abs(exact)))
        assert rel < 0.05, (i, rel)
    print(json.dumps({"rel_err": rel}))
""")


def test_compressed_psum(tmp_path):
    script = tmp_path / "cpsum_test.py"
    script.write_text(SCRIPT)
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, str(script)], capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))), env=env,
        timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["rel_err"] < 0.05
