"""Replica correctness: live shipping + continuous apply must end exactly
where crash recovery ends, and the read watermark must be RAW-safe.

* **promote ≡ recover**: after shipping whatever a (possibly torn, possibly
  partially flushed) primary left behind, ``Replica.promote()`` must be
  byte-identical to ``recover()`` on the same devices — data incl. SSNs,
  RSNe, replayed/skipped counts — for all three apply modes, single-shard
  and 2-shard (vs ``recover_sharded``, including the cross-shard cut
  statistics).
* **watermark monotonicity / RAW safety**: ``visible_ssn()`` never
  decreases, and no HAS_READS record is ever applied above the watermark it
  was applied under (`ReplicaApplier.max_qwr_applied`).
* **catch-up**: a replica seeded from a fuzzy checkpoint and shipped the
  full log promotes to the same state as checkpoint+log crash recovery.
"""

import os
import random

import pytest

from repro.core import EngineConfig, PoplarEngine, Txn, Worker, recover
from repro.core.checkpoint import CheckpointDaemon
from repro.core.recovery import RecoveredState
from repro.db import TxnSpec
from repro.replica import Replica, ShardedReplica
from repro.shard import ShardedConfig, ShardedEngine, recover_sharded

KEYS = [f"k{i}" for i in range(10)]


class _Cell:
    __slots__ = ("ssn",)

    def __init__(self):
        self.ssn = 0


def _states_equal(a: RecoveredState, b: RecoveredState) -> bool:
    return (
        a.data == b.data
        and a.rsns == b.rsns
        and a.rsne == b.rsne
        and a.n_replayed == b.n_replayed
        and a.n_skipped_uncommitted == b.n_skipped_uncommitted
    )


def _drive_primary(engine, rng, n_txns, workers, cells, replica=None):
    """Random mixed workload with random partial flushes; polls the replica
    mid-stream (checking watermark monotonicity + RAW safety) if given."""
    wm_prev = 0
    for i in range(n_txns):
        reads = rng.sample(KEYS, rng.randrange(0, 3))
        writes = rng.sample(KEYS, rng.randrange(0, 3))
        t = Txn(
            tid=1000 + i,
            read_set=[(k, cells[k].ssn) for k in reads],
            write_set=[(k, f"{i}/{k}".encode()) for k in writes],
        )
        workers[rng.randrange(len(workers))].run(
            t, [cells[k] for k in reads], [cells[k] for k in writes]
        )
        if rng.random() < 0.4:
            for b in range(len(engine.buffers)):
                if rng.random() < 0.6:
                    engine.logger_tick(b, force=True)
        if replica is not None and rng.random() < 0.4:
            replica.poll()
            wm = replica.visible_ssn()
            assert wm >= wm_prev, "visible_ssn must be monotone"
            assert replica.applier.max_qwr_applied <= wm, (
                "a HAS_READS record was applied above the read watermark"
            )
            wm_prev = wm


@pytest.mark.parametrize("mode", ["vectorized", "pallas", "scalar"])
@pytest.mark.parametrize("seed", [0, 1])
def test_promote_equals_recover_single(mode, seed, tmp_path):
    rng = random.Random(seed)
    n_buffers = rng.choice([1, 2, 3])
    engine = PoplarEngine(
        EngineConfig(n_buffers=n_buffers, device_kind="null", device_dir=str(tmp_path))
    )
    workers = [Worker(engine, i) for i in range(n_buffers * 2)]
    cells = {k: _Cell() for k in KEYS}
    rep = Replica(engine.devices, mode=mode, parallel=False)
    _drive_primary(engine, rng, 80, workers, cells, replica=rep)
    # crash: whatever was never flushed is lost
    for d in engine.devices:
        d.close()

    st = rep.promote()
    ref = recover(engine.devices, parallel=False)
    assert _states_equal(st, ref)
    # the replica used the incremental read path, not repeated full reads
    assert all(s.n_polls > 1 for s in rep.shippers)


def test_raw_safety_deterministic(tmp_path):
    """Qwr visibility is pinned by the *lagging* device: a RAW-carrying
    record on a flushed buffer must stay invisible until every other device
    frontier passes it — then it appears."""
    engine = PoplarEngine(
        EngineConfig(n_buffers=2, device_kind="null", device_dir=str(tmp_path))
    )
    w = Worker(engine, 0)  # -> buffer 0; buffer 1 idle
    cells = {"a": _Cell(), "b": _Cell()}
    t1 = Txn(tid=1, write_set=[("a", b"v1")])
    w.run(t1, [], [cells["a"]])
    t2 = Txn(tid=2, read_set=[("a", cells["a"].ssn)], write_set=[("b", b"v2")])
    w.run(t2, [cells["a"]], [cells["b"]])

    engine.logger_tick(0, force=True)  # flush buffer 0 only
    rep = Replica(engine.devices, parallel=False)
    rep.poll()
    # write-only t1 visible (durable on its own device = committed)...
    assert rep.read("a") == (b"v1", t1.ssn)
    # ...but t2 (RAW on a) is held: device 1's frontier pins the watermark
    assert rep.visible_ssn() == 0
    assert rep.read("b") is None and rep.held() >= 1

    engine.logger_tick(1, force=True)  # heartbeat unpins the frontier
    rep.poll()
    assert rep.visible_ssn() == t2.ssn
    assert rep.read("b") == (b"v2", t2.ssn)
    assert rep.held() == 0


def test_non_ascii_keys_readable(tmp_path):
    """Replica point reads must find keys the primary wrote through the
    string API regardless of encoding: the applier's bytes->row mapping has
    to invert the workload's utf-8 framing exactly (regression for the
    latin-1 index mismatch)."""
    engine = PoplarEngine(
        EngineConfig(n_buffers=1, device_kind="null", device_dir=str(tmp_path))
    )
    w = Worker(engine, 0)
    keys = ["café", "naïve", "ascii", "日本"]
    cells = {k: _Cell() for k in keys}
    for i, k in enumerate(keys):
        t = Txn(tid=10 + i, write_set=[(k, f"v-{k}".encode())])
        w.run(t, [], [cells[k]])
    engine.logger_tick(0, force=True)

    rep = Replica(engine.devices, parallel=False)
    rep.poll()
    for k in keys:
        got = rep.read(k)
        assert got is not None and got[0] == f"v-{k}".encode(), k
    assert rep.table.to_dict().keys() == {k.encode() for k in keys}


def test_replica_checkpoint_catchup(tmp_path):
    """Seed from a fuzzy checkpoint, ship the log on top: promote must equal
    checkpoint+log crash recovery (checkpoint wins its SSN ties)."""
    rng = random.Random(3)
    engine = PoplarEngine(
        EngineConfig(n_buffers=2, device_kind="null", device_dir=str(tmp_path / "dev"))
    )
    workers = [Worker(engine, i) for i in range(2)]
    cells = {k: _Cell() for k in KEYS}
    _drive_primary(engine, rng, 40, workers, cells)
    engine.quiesce(range(2))
    for b in range(2):  # heartbeat any lagging buffer so the CSN reaches
        engine.logger_tick(b, force=True)  # the max observed SSN (ELR rule)

    ck_dir = str(tmp_path / "ckpt")
    ck = CheckpointDaemon(ck_dir, n_threads=1, m_files=2,
                          csn_fn=engine.commit.advance_csn)
    snap = [(k.encode(), f"ck/{k}".encode(), cells[k].ssn) for k in KEYS]
    ck.run_once([iter(snap)], validate_timeout=5.0)

    _drive_primary(engine, rng, 40, workers, cells)  # post-checkpoint traffic
    for d in engine.devices:
        d.close()

    for mode in ("vectorized", "pallas", "scalar"):
        rep = Replica(engine.devices, checkpoint_dir=ck_dir, mode=mode,
                      parallel=False)
        st = rep.promote()
        ref = recover(engine.devices, checkpoint_dir=ck_dir, parallel=False)
        assert _states_equal(st, ref), mode


def test_replica_torn_tail(tmp_path):
    """A physically torn trailing frame (crash mid-flush) is retried by the
    shipper, never decoded — promote still equals recovery, which truncates
    at the same byte."""
    engine = PoplarEngine(
        EngineConfig(n_buffers=2, device_kind="ssd", device_dir=str(tmp_path),
                     device_clock="virtual")
    )
    workers = [Worker(engine, i) for i in range(2)]
    cells = {k: _Cell() for k in KEYS}
    _drive_primary(engine, random.Random(5), 30, workers, cells)
    engine.quiesce(range(2))
    for d in engine.devices:
        d.close()
    torn = Txn(tid=777, write_set=[("k0", b"TORN-NEVER-COMMITTED")])
    torn.ssn = 1 << 40
    with open(os.path.join(str(tmp_path), "log_0.bin"), "ab") as f:
        f.write(torn.encode()[:-7])

    rep = Replica(engine.devices, parallel=False)
    rep.poll()
    consumed = rep.shippers[0].consumed
    rep.poll()  # torn tail retried: consumed must not advance past it
    assert rep.shippers[0].consumed == consumed
    st = rep.promote()
    ref = recover(engine.devices, parallel=False)
    assert _states_equal(st, ref)
    assert all(v != b"TORN-NEVER-COMMITTED" for v, _ in st.data.values())


def _drive_sharded(eng, rep, rng, rounds, keys, by_shard):
    for r in range(rounds):
        specs = [TxnSpec(writes=[(k, f"{k}r{r}".encode())]) for k in keys]
        specs.append(TxnSpec(
            writes=[(by_shard[0][0], f"x0r{r}".encode()),
                    (by_shard[1][0], f"x1r{r}".encode())],
        ))
        specs.append(TxnSpec(
            reads=[by_shard[0][1]],
            writes=[(by_shard[1][1], f"xr{r}".encode())],
        ))
        eng.execute_batch(specs)
        for sh in eng.shards:
            for i in range(len(sh.engine.buffers)):
                if rng.random() < 0.7:
                    sh.engine.logger_tick(i, force=True)
        eng.drain()
        if rep is not None and rng.random() < 0.7:
            rep.poll()


@pytest.mark.parametrize("mode", ["vectorized", "pallas", "scalar"])
def test_promote_equals_recover_sharded(mode, tmp_path):
    eng = ShardedEngine(ShardedConfig(
        n_shards=2, n_buffers=2, n_workers=2, device_kind="null",
        device_dir=str(tmp_path),
    ))
    keys = [f"user{i:06d}" for i in range(20)]
    by_shard = [[], []]
    for k in keys:
        by_shard[eng.shard_of(k)].append(k)
    rep = ShardedReplica(eng.devices, mode=mode, parallel=False)
    _drive_sharded(eng, rep, random.Random(11), 6, keys, by_shard)
    # crash without quiescing: some records unflushed, some cross-shard
    # transactions may be durable on only one participant
    for devs in eng.devices:
        for d in devs:
            d.close()

    st = rep.promote()
    ref = recover_sharded(eng.devices, parallel=False)
    assert (st.n_cross_seen, st.n_cross_dropped) == (
        ref.n_cross_seen, ref.n_cross_dropped)
    for p, (a, b) in enumerate(zip(st.shards, ref.shards)):
        assert _states_equal(a, b), (mode, p)
    # routed reads serve the merged state
    for k in keys:
        got = rep.read(k)
        want = ref.data.get(k.encode())
        assert (got == want) or (got is None and want is None)


def test_sharded_xshard_held_until_all_participants(tmp_path):
    """A cross-shard record shipped from one participant only stays
    invisible (and holds the shard's watermark down for RAW carriers) until
    the other participant's copy ships."""
    eng = ShardedEngine(ShardedConfig(
        n_shards=2, n_buffers=1, n_workers=1, device_kind="null",
        device_dir=str(tmp_path),
    ))
    keys = [f"user{i:06d}" for i in range(8)]
    by_shard = [[], []]
    for k in keys:
        by_shard[eng.shard_of(k)].append(k)
    k0, k1 = by_shard[0][0], by_shard[1][0]
    res = eng.execute_batch(
        [TxnSpec(writes=[(k0, b"x0"), (k1, b"x1")])]
    )
    assert len(res.cross) == 1
    # flush shard 0 only: the x record is durable on one participant
    eng.shards[0].engine.logger_tick(0, force=True)

    rep = ShardedReplica(eng.devices, parallel=False)
    rep.poll()
    assert rep.read(k0) is None and rep.read(k1) is None
    assert rep.held() >= 1

    eng.shards[1].engine.logger_tick(0, force=True)  # now durable everywhere
    rep.poll()
    assert rep.read(k0) == (b"x0", res.cross[0].parts[0].ssn)
    assert rep.read(k1) == (b"x1", res.cross[0].parts[1].ssn)


def test_live_xshard_with_reads_becomes_visible(tmp_path):
    """A cross-shard HAS_READS transaction, once shipped-durable from every
    participant, must become visible during *live* polling — and must not
    starve later single-shard HAS_READS records on its shards (regression:
    the watermark cap used to block the x-record's own cut decision
    forever, freezing the shard)."""
    eng = ShardedEngine(ShardedConfig(
        n_shards=2, n_buffers=1, n_workers=1, device_kind="null",
        device_dir=str(tmp_path),
    ))
    keys = [f"user{i:06d}" for i in range(8)]
    by_shard = [[], []]
    for k in keys:
        by_shard[eng.shard_of(k)].append(k)
    k0, k1 = by_shard[0][0], by_shard[1][0]
    res = eng.execute_batch([TxnSpec(writes=[(k, b"w0") for k in keys])])
    eng.tick()
    eng.drain()
    # a cross-shard txn WITH reads, then an ordinary Qwr behind it
    xres = eng.execute_batch(
        [TxnSpec(reads=[k0], writes=[(k0, b"xv0"), (k1, b"xv1")])]
    )
    assert len(xres.cross) == 1
    eng.tick()
    eng.drain()
    later = eng.execute_batch(
        [TxnSpec(reads=[by_shard[0][1]], writes=[(by_shard[0][1], b"later")])]
    )
    assert len(later.committed) == 1
    eng.tick()
    eng.drain()

    rep = ShardedReplica(eng.devices, parallel=False)
    for _ in range(4):
        rep.poll()
    assert rep.held() == 0, "live polling left decided records held"
    assert rep.read(k0) == (b"xv0", xres.cross[0].parts[0].ssn)
    assert rep.read(k1) == (b"xv1", xres.cross[0].parts[1].ssn)
    assert rep.read(by_shard[0][1])[0] == b"later"
    # applied gtids are pruned from the live cut registry (O(in-flight),
    # not O(lifetime)), without losing the seen/dropped statistics
    assert not rep._info and not rep._durable and rep._seen_x >= 1
    # and the final state still equals crash recovery
    st = rep.promote()
    ref = recover_sharded(eng.devices, parallel=False)
    for a, b in zip(st.shards, ref.shards):
        assert _states_equal(a, b)
