"""Checkpoint → recovery integration (satellite of the sharding PR).

Previously fuzzy checkpoints (`core/checkpoint.py`) and vectorized recovery
were only tested in isolation.  Here the full §5 pipeline runs end-to-end:
run transactions, take a fuzzy checkpoint mid-stream, keep running, crash
with an unflushed tail, and assert that replay *from the checkpoint* equals
full-log replay — single-engine and 2-shard sharded.
"""

import random

from repro.core import CheckpointDaemon, EngineConfig, PoplarEngine, recover
from repro.db import OCCWorker, Table, TxnSpec
from repro.shard import ShardedConfig, ShardedEngine, recover_sharded


def _partitions(items, n):
    """Split (key_bytes, value, ssn) entries into n key-ordered partitions."""
    items = sorted(items)
    return [items[i::n] for i in range(n)]


def test_checkpoint_then_crash_then_recover(tmp_path):
    dev_dir = tmp_path / "devs"
    ckpt_dir = str(tmp_path / "ckpt")
    engine = PoplarEngine(EngineConfig(n_buffers=2, device_kind="ssd",
                                       device_dir=str(dev_dir),
                                       device_clock="virtual"))
    table = Table()
    workers = [OCCWorker(table, engine, i) for i in range(2)]
    rng = random.Random(11)
    keys = [f"k{i}" for i in range(25)]

    def run_txns(n, tag):
        done = []
        for i in range(n):
            w = workers[i % 2]
            wk = rng.sample(keys, rng.randrange(1, 3))
            rk = rng.sample(keys, rng.randrange(0, 2))
            t = w.execute(reads=rk,
                          writes=[(k, f"{tag}{i}:{k}".encode()) for k in wk])
            if t is not None:
                done.append(t)
        return done

    phase1 = run_txns(40, "a")
    engine.quiesce(range(2))
    assert all(t.committed for t in phase1)

    # fuzzy checkpoint of the live store; the csn_fn stands in for the live
    # logger ticks (stepped mode): heartbeats lift lagging buffers to the
    # frontier so the CSN can pass the checkpoint's max observed SSN
    def csn_fn() -> int:
        for i in range(2):
            engine.logger_tick(i, force=True)
        return engine.commit.advance_csn()

    daemon = CheckpointDaemon(ckpt_dir, n_threads=2, m_files=2, csn_fn=csn_fn)
    entries = [
        (k.encode(), table.get(k).value, table.get(k).ssn)
        for k in table.sorted_keys()
        if table.get(k).ssn > 0  # skip read-created, never-written cells
    ]
    daemon.run_once(_partitions(entries, 2))

    # the checkpoint alone reproduces the phase-1 image
    ck_only = recover([], checkpoint_dir=ckpt_dir, parallel=False)
    assert ck_only.rsns > 0
    for t in phase1:
        for k, v in t.write_set:
            got = ck_only.data[k.encode()]
            assert got[1] >= t.ssn
            if got[1] == t.ssn:
                assert got[0] == v

    # keep running past the checkpoint, then crash with buffer 1 unflushed
    phase2 = run_txns(40, "b")
    assert phase2
    engine.logger_tick(0, force=True)
    for d in engine.devices:
        d.close()

    full = recover(engine.devices, checkpoint_dir=None, parallel=False)
    from_ckpt = recover(engine.devices, checkpoint_dir=ckpt_dir, parallel=False)
    scalar = recover(engine.devices, checkpoint_dir=ckpt_dir, parallel=False,
                     mode="scalar")
    # replay from the checkpoint RSN == full-log replay (the per-tuple SSN
    # guard makes the overlap idempotent); the checkpoint contributes rsns
    assert from_ckpt.rsns > 0 == full.rsns
    assert from_ckpt.rsne == full.rsne
    assert from_ckpt.data == full.data == scalar.data


def test_sharded_checkpoint_then_crash_then_recover(tmp_path):
    eng = ShardedEngine(ShardedConfig(
        n_shards=2, n_buffers=1, n_workers=2, device_kind="ssd",
        device_clock="virtual", device_dir=str(tmp_path / "devs"),
    ))
    rng = random.Random(5)
    keys = [f"user{i:010d}" for i in range(24)]
    by_shard = [[], []]
    for k in keys:
        by_shard[eng.shard_of(k)].append(k)

    def batch(tag, n=None):
        specs = [TxnSpec(writes=[(k, f"{tag}:{k}".encode())]) for k in
                 (keys if n is None else rng.sample(keys, n))]
        specs.append(TxnSpec(writes=[(by_shard[0][0], f"{tag}:x0".encode()),
                                     (by_shard[1][0], f"{tag}:x1".encode())]))
        return specs

    eng.execute_batch(batch("a"))
    eng.quiesce()

    ckpt_dirs = []
    for p, sh in enumerate(eng.shards):
        d = str(tmp_path / f"ckpt{p}")
        daemon = CheckpointDaemon(
            d, n_threads=1, m_files=2,
            csn_fn=sh.engine.commit.advance_csn,
        )
        entries = [(k.encode(), v, s) for k, v, s in sh.table.items() if s > 0]
        daemon.run_once([sorted(entries)])
        ckpt_dirs.append(d)

    # run past the checkpoint; crash with shard 1 completely unflushed
    eng.execute_batch(batch("b", n=12))
    for i in range(len(eng.shards[0].engine.buffers)):
        eng.shards[0].engine.logger_tick(i, force=True)
    for devs in eng.devices:
        for d in devs:
            d.close()

    full = recover_sharded(eng.devices, parallel=False)
    from_ckpt = recover_sharded(eng.devices, checkpoint_dirs=ckpt_dirs,
                                parallel=False)
    scalar = recover_sharded(eng.devices, checkpoint_dirs=ckpt_dirs,
                             parallel=False, mode="scalar")
    assert from_ckpt.data == scalar.data
    assert all(st.rsns > 0 for st in from_ckpt.shards)
    # the phase-b cross-shard txn is torn (shard 1 unflushed) in both runs
    assert full.n_cross_dropped == from_ckpt.n_cross_dropped == 1
    # full-log replay lacks the checkpoint image of keys never re-written
    # in phase b, but must agree wherever the logs speak
    for kb, pair in full.data.items():
        assert from_ckpt.data[kb] == pair
    # and the checkpoint restores every phase-a key even on the dead shard
    for k in keys:
        assert from_ckpt.data[k.encode()][1] > 0
