"""Mixed command/value log fuzzing: every decoder stops at a clean frame
boundary — or raises — under truncation at *every* byte offset and under
single-byte corruption at *every* byte offset.  Never a mis-framed record.

The adaptive-logging wire format interleaves three frame shapes in one
stream (value, FLAG_COMMAND with the dep footer, FLAG_XSHARD with the
participant footer), so framing bugs have three times the surface: a
command footer misparsed as the next frame's header, a dep count read as a
length, a torn param spilling into a value record.  This suite pins the
contract for all four consumers:

* ``decode_records``        — scalar oracle;
* ``decode_columnar``       — batch columnar decode;
* ``decode_columnar_stream``— incremental framing + consumed offset;
* ``decode_fast_tile``      — the fused-replay tile, which must *decline*
  (return ``None``) whenever the clean prefix carries COMMAND/XSHARD
  frames, and otherwise frame byte-identically to the stream decoder.

Exhaustive small cases run unconditionally; a hypothesis wrapper widens the
seed/offset space when the library is installed (same pattern as
``test_serve_property.py``).
"""

from struct import error as struct_error

import numpy as np
import pytest

from repro.core import Txn, decode_columnar, decode_columnar_stream, decode_records
from repro.core.command import OP_ADD_U64, OP_PATCH_PREFIX
from repro.core.fastdecode import decode_fast_tile
from repro.core.txn import FLAG_COMMAND, FLAG_XSHARD

try:  # pragma: no cover - environment dependent
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# blob builder: value + command + xshard records interleaved
# ---------------------------------------------------------------------------

def _mixed_blob(n_records: int = 16, seed: int = 11):
    """Returns ``(blob, ends, txns)`` where ``ends[i]`` is the byte offset
    one past record ``i`` (the clean frame boundaries)."""
    rng = np.random.RandomState(seed)
    out = bytearray()
    ends = []
    txns = []
    for i in range(n_records):
        nw = int(rng.randint(1, 4))
        keys = [f"k{int(rng.randint(8))}" for _ in range(nw)]
        t = Txn(
            tid=1000 + i,
            write_set=[
                (k, bytes(rng.bytes(int(rng.randint(0, 24))))) for k in keys
            ],
            read_set=[("r", 0)] if rng.rand() < 0.5 else [],
        )
        # first three records pin one of each shape (value/command/xshard)
        # so the mix is guaranteed regardless of seed; the rest are random
        shape = (0.7, 0.2, 0.5)[i] if i < 3 else rng.rand()
        if shape < 0.4:
            # command frame: params in the value slots, deps mirror writes
            t.cmd_op = OP_ADD_U64 if rng.rand() < 0.5 else OP_PATCH_PREFIX
            t.cmd_deps = [(k, int(rng.randint(1, 50))) for k in keys]
        elif shape < 0.6:
            t.xdep = [(0, i + 1), (1, i + 2)]
        t.ssn = i + 1
        out.extend(t.encode())
        ends.append(len(out))
        txns.append(t)
    return bytes(out), ends, txns


def _rec_eq(rec, txn) -> bool:
    """Does a decoded LogRecord match the Txn that framed it?"""
    if rec.ssn != txn.ssn or rec.tid != txn.tid:
        return False
    if rec.has_reads != bool(txn.read_set):
        return False
    want_writes = [(k.encode(), v) for k, v in txn.write_set]
    if rec.writes != want_writes:
        return False
    if (rec.cmd_op is not None) != (txn.cmd_op is not None):
        return False
    if txn.cmd_op is not None:
        if rec.cmd_op != txn.cmd_op:
            return False
        want_deps = [(k.encode(), s) for k, s in txn.cmd_deps]
        if rec.cmd_deps != want_deps:
            return False
    if (rec.xdep is not None) != (txn.xdep is not None):
        return False
    if txn.xdep is not None and rec.xdep != txn.xdep:
        return False
    return True


def _columnar_matches_records(log, recs) -> None:
    """Cross-check the columnar decode against the scalar oracle records."""
    assert log.n_records == len(recs)
    assert log.ssn.tolist() == [r.ssn for r in recs]
    assert log.tid.tolist() == [r.tid for r in recs]
    assert log.has_reads.tolist() == [r.has_reads for r in recs]
    assert log.n_writes.tolist() == [len(r.writes) for r in recs]
    flat = [(i, k, v) for i, r in enumerate(recs) for k, v in r.writes]
    assert log.wr_rec.tolist() == [i for i, _, _ in flat]
    assert log.keys == [k for _, k, _ in flat]
    assert log.values == [v for _, _, v in flat]
    cmd_idx = [i for i, r in enumerate(recs) if r.is_command]
    if not cmd_idx:
        assert log.n_command == 0
    else:
        assert log.cmd_rec.tolist() == cmd_idx
        assert log.cmd_op.tolist() == [recs[i].cmd_op for i in cmd_idx]
        deps = [d for i in cmd_idx for d in recs[i].cmd_deps]
        assert log.cmd_dep_key == [k for k, _ in deps]
        assert log.cmd_dep_ssn.tolist() == [s for _, s in deps]
        assert np.diff(log.cmd_dep_start).tolist() == [
            len(recs[i].cmd_deps) for i in cmd_idx
        ]


def _n_clean(ends, cut: int) -> int:
    """How many whole records fit in ``blob[:cut]``."""
    return sum(1 for e in ends if e <= cut)


def _check_prefix(blob: bytes, ends, txns, cut: int) -> None:
    """The decoder contract at one truncation point: every decoder yields
    exactly the records of the longest clean frame prefix <= cut."""
    pref = blob[:cut]
    n = _n_clean(ends, cut)
    boundary = ends[n - 1] if n else 0

    recs = decode_records(pref)
    assert len(recs) == n
    for rec, txn in zip(recs, txns):
        assert _rec_eq(rec, txn)

    log, consumed = decode_columnar_stream(pref)
    assert consumed == boundary
    _columnar_matches_records(log, recs)
    _columnar_matches_records(decode_columnar(pref), recs)

    tile = decode_fast_tile(pref)
    mixed = any(
        txns[i].cmd_op is not None or txns[i].xdep is not None for i in range(n)
    )
    if mixed:
        # the fused tile must decline mixed prefixes, never guess
        assert tile is None
    else:
        assert tile is not None
        assert tile.consumed == boundary
        assert tile.n_records == n
        assert tile.ssn.tolist() == log.ssn.tolist()
        assert tile.wr_rec.tolist() == log.wr_rec.tolist()
        assert [
            tile.buf[o : o + ln]
            for o, ln in zip(tile.val_off.tolist(), tile.val_len.tolist())
        ] == log.values


def _check_corruption(blob: bytes, ends, txns, pos: int) -> None:
    """Flip one byte; every decoder must stop at (or before) the frame
    holding it, yielding only untouched records — or raise.  A crc32
    collision on a single-byte flip is impossible, so 'before' only happens
    if a decoder chooses to raise instead of truncate (also acceptable)."""
    bad = bytearray(blob)
    bad[pos] ^= 0xFF
    bad = bytes(bad)
    j = _n_clean(ends, pos)  # index of the frame containing byte ``pos``

    try:
        recs = decode_records(bad)
    except (ValueError, struct_error):
        recs = None
    if recs is not None:
        assert len(recs) <= j
        for rec, txn in zip(recs, txns):
            assert _rec_eq(rec, txn)

    try:
        log, consumed = decode_columnar_stream(bad)
    except (ValueError, struct_error):
        log = None
    if log is not None:
        assert log.n_records <= j
        assert consumed <= (ends[j - 1] if j else 0)
        if recs is not None:
            _columnar_matches_records(log, recs[: log.n_records])

    try:
        tile = decode_fast_tile(bad)
    except (ValueError, struct_error):
        tile = None
    if tile is not None:
        assert tile.n_records <= j
        for i in range(tile.n_records):
            assert int(tile.ssn[i]) == txns[i].ssn


# ---------------------------------------------------------------------------
# exhaustive small cases
# ---------------------------------------------------------------------------

def test_full_blob_round_trips():
    blob, ends, txns = _mixed_blob()
    assert ends[-1] == len(blob)
    recs = decode_records(blob)
    assert len(recs) == len(txns)
    for rec, txn in zip(recs, txns):
        assert _rec_eq(rec, txn)
    # the blob genuinely mixes all three shapes, or the suite tests nothing
    flags = {(r.is_command, r.xdep is not None) for r in recs}
    assert (True, False) in flags and (False, True) in flags and (False, False) in flags


def test_truncate_at_every_byte_offset():
    blob, ends, txns = _mixed_blob()
    for cut in range(len(blob) + 1):
        _check_prefix(blob, ends, txns, cut)


def test_corrupt_every_byte_offset():
    blob, ends, txns = _mixed_blob(n_records=12, seed=3)
    for pos in range(len(blob)):
        _check_corruption(blob, ends, txns, pos)


def test_fast_tile_declines_exactly_on_mixed_frames():
    """Byte-level pin of the decline rule: the tile is None iff the clean
    prefix contains a COMMAND or XSHARD frame (the flag bits, not heuristics)."""
    blob, ends, txns = _mixed_blob(n_records=20, seed=5)
    for n, e in enumerate(ends, start=1):
        pref = blob[:e]
        recs = decode_records(pref)
        flags_mixed = any(
            r.is_command or r.xdep is not None for r in recs
        )
        tile = decode_fast_tile(pref)
        assert (tile is None) == flags_mixed
        if tile is not None:
            assert tile.n_records == n


def test_command_value_flag_bits_disjoint():
    """COMMAND and XSHARD flag bits must stay distinct and single-bit (the
    decoders branch on them independently)."""
    assert FLAG_COMMAND & FLAG_XSHARD == 0
    assert bin(FLAG_COMMAND).count("1") == 1


# ---------------------------------------------------------------------------
# hypothesis wrapper (same gating pattern as test_serve_property.py)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    @settings(max_examples=40, deadline=None)
    @given(
        seed=st.integers(min_value=0, max_value=2**31 - 1),
        n=st.integers(min_value=1, max_value=24),
        frac=st.floats(min_value=0.0, max_value=1.0),
        corrupt=st.booleans(),
    )
    def test_fuzz_truncate_and_corrupt(seed, n, frac, corrupt):
        blob, ends, txns = _mixed_blob(n_records=n, seed=seed)
        pos = min(int(frac * len(blob)), len(blob) - 1)
        if corrupt:
            _check_corruption(blob, ends, txns, pos)
        else:
            _check_prefix(blob, ends, txns, pos)

else:  # pragma: no cover

    @pytest.mark.skip(
        reason="hypothesis not installed; the exhaustive cases above "
        "exercise the same properties"
    )
    def test_fuzz_truncate_and_corrupt():
        pass
