"""Figure 1 scenarios + hypothesis property tests for the recoverability
invariant under arbitrary txn mixes, flush interleavings and crash points."""

from typing import Dict, List

import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (pip install -r requirements-dev.txt)"
)
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import EngineConfig, PoplarEngine, Txn, Worker, recover
from repro.core.levels import (
    Dep,
    Op,
    TxnInfo,
    check_recoverability,
    check_rigorousness,
    check_sequentiality,
    derive_deps,
)

KEYS = ["a", "b", "c", "d", "e"]


# --- Figure 1: the eight scenarios -------------------------------------------

def _info(tid, ssn, commit_seq, deps=()):
    return TxnInfo(tid=tid, ssn=ssn, commit_seq=commit_seq, deps=list(deps))


def test_fig1_raw_scenarios():
    # W1(x); R2(x); W2(y): T1 -RAW-> T2
    # (a) C1<C2, L1<L2: OK
    txns = {1: _info(1, 1, 0), 2: _info(2, 2, 1, [(1, Dep.RAW)])}
    assert check_recoverability(txns) == []
    # (b) C1<C2, L2<L1: OK (RAW needs commit order only)
    txns = {1: _info(1, 5, 0), 2: _info(2, 3, 1, [(1, Dep.RAW)])}
    assert check_recoverability(txns) == []
    # (c) C2<C1, L2<L1: VIOLATION
    txns = {1: _info(1, 5, 1), 2: _info(2, 3, 0, [(1, Dep.RAW)])}
    assert check_recoverability(txns) != []


def test_fig1_waw_scenarios():
    # R2(x); W2(y); W3(y): T2 -WAW-> T3
    # (d) C2<C3, L2<L3: OK
    txns = {2: _info(2, 1, 0), 3: _info(3, 2, 1, [(2, Dep.WAW)])}
    assert check_recoverability(txns) == []
    # (e) C2<C3, L3<L2: VIOLATION (T3's update would be lost on replay)
    txns = {2: _info(2, 4, 0), 3: _info(3, 2, 1, [(2, Dep.WAW)])}
    assert check_recoverability(txns) != []
    # (f) C3<C2, L2<L3: OK (commit order free for WAW)
    txns = {2: _info(2, 1, 1), 3: _info(3, 2, 0, [(2, Dep.WAW)])}
    assert check_recoverability(txns) == []


def test_fig1_war_scenarios():
    # R2(x); W2(y); W4(x): T2 -WAR-> T4
    # (g) C2<C4, L2<L4: OK
    txns = {2: _info(2, 1, 0), 4: _info(4, 2, 1, [(2, Dep.WAR)])}
    assert check_recoverability(txns) == []
    # (h) C4<C2, L4<L2: ALSO OK — WAR is untracked at level 1
    txns = {2: _info(2, 3, 1), 4: _info(4, 1, 0, [(2, Dep.WAR)])}
    assert check_recoverability(txns) == []
    # ...but rigorousness (level 2) forbids (h)
    assert check_rigorousness(txns) != []


def test_sequentiality_total_order():
    txns = {
        1: _info(1, 1, 0),
        2: _info(2, 3, 1),
        3: _info(3, 2, 2),  # commit order disagrees with SSN order
    }
    assert check_recoverability(txns) == []
    assert check_sequentiality(txns) != []


def test_derive_deps():
    ops = [
        Op(1, "w", "x", 0),
        Op(2, "r", "x", 1),
        Op(2, "w", "y", 2),
        Op(3, "w", "y", 3),
        Op(4, "w", "x", 4),
    ]
    deps = derive_deps(ops)
    assert (1, Dep.RAW) in deps[2]
    assert (2, Dep.WAW) in deps[3]
    assert (2, Dep.WAR) in deps[4]  # T2 read x, T4 overwrote it


# --- property: engine histories satisfy recoverability --------------------------

class _Cell:
    __slots__ = ("ssn",)

    def __init__(self):
        self.ssn = 0


txn_strategy = st.tuples(
    st.integers(0, 3),                                  # worker
    st.lists(st.sampled_from(KEYS), max_size=3, unique=True),   # reads
    st.lists(st.sampled_from(KEYS), min_size=0, max_size=3, unique=True),  # writes
)


@settings(max_examples=60, deadline=None)
@given(
    txns=st.lists(txn_strategy, min_size=1, max_size=25),
    ticks=st.lists(st.integers(0, 2), min_size=25, max_size=25),
    crash_at=st.integers(0, 24),
)
def test_recoverability_invariant(txns, ticks, crash_at):
    """Random serial schedule through Poplar with random flush interleavings
    and a random crash point.  Invariants:

      I1 (durability): every committed txn's write survives recovery with an
         SSN >= its own (present or overwritten by a later writer).
      I2 (no phantom reads): every recovered RAW-carrying txn's predecessors
         are themselves reflected in the recovered state.
      I3 (level 1): the observed history satisfies recoverability.
    """
    engine = PoplarEngine(EngineConfig(n_buffers=2, device_kind="null"))
    workers = [Worker(engine, i) for i in range(4)]
    cells: Dict[str, _Cell] = {k: _Cell() for k in KEYS}

    history: List[Txn] = []
    ops: List[Op] = []
    last_writer: Dict[str, int] = {}
    raw_preds: Dict[int, List[int]] = {}
    commit_seq: List[int] = []
    seq = 0

    def drain_all():
        engine.commit.advance_csn()
        for w in workers:
            w.drain()

    for i, (wid, reads, writes) in enumerate(txns):
        crashed = i >= crash_at
        tid = 1000 + i
        t = Txn(tid=tid)
        t.read_set = [(k, cells[k].ssn) for k in reads]
        t.write_set = [(k, f"{tid}".encode()) for k in writes]
        preds = [last_writer[k] for k in reads if k in last_writer]
        raw_preds[tid] = preds
        workers[wid].run(t, [cells[k] for k in reads], [cells[k] for k in writes])
        history.append(t)
        for k in reads:
            ops.append(Op(tid, "r", k, seq)); seq += 1
        for k in writes:
            ops.append(Op(tid, "w", k, seq)); seq += 1
            last_writer[k] = tid
        if not crashed:
            # flush interleaving driven by hypothesis
            mode = ticks[i % len(ticks)]
            if mode:
                for b in ([0], [1], [0, 1])[mode - 1] if mode <= 3 else []:
                    engine.logger_tick(b, force=True)
            drain_all()

    drain_all()
    committed = [t for t in history if t.committed]

    # --- I3: SSN partial order — Poplar's SSN tracks RAW and WAW (§4.2);
    # the *commit decision* order is enforced by the DSN/CSN watermarks
    # (commit-ack events across independent worker queues may drain late
    # for write-only txns — durability, not ack order, is the contract,
    # and I1/I2 below verify it end-to-end through a crash).
    deps = derive_deps(ops)
    ssn_of = {t.tid: t.ssn for t in history}
    for t in history:
        for pred_tid, kind in deps.get(t.tid, []):
            if kind in (Dep.RAW, Dep.WAW):
                if not t.write_set:
                    # read-only txns take ssn = base without +1 (Alg 1 l.17):
                    # equality is legal — commit via CSN still implies the
                    # predecessor is durable (csn >= ssn >= pred.ssn)
                    assert ssn_of[pred_tid] <= t.ssn
                else:
                    assert ssn_of[pred_tid] < t.ssn, (
                        f"{kind} SSN order violated: T{pred_tid}={ssn_of[pred_tid]} "
                        f"!< T{t.tid}={t.ssn}"
                    )
    # a committed RAW-successor's predecessors must be durable (CSN rule):
    # ssn(pred) < ssn(succ) <= CSN <= every DSN => pred's record flushed
    for t in committed:
        if t.has_reads:
            for pred_tid, kind in deps.get(t.tid, []):
                if kind is Dep.RAW:
                    pred = next(h for h in history if h.tid == pred_tid)
                    if pred.write_set:
                        assert pred.ssn <= engine.buffers[pred.buffer_id].dsn, (
                            f"T{t.tid} committed but RAW pred T{pred_tid} not durable"
                        )

    # --- crash: recover from whatever is durable
    state = recover(engine.devices)

    # I1: durability of committed writes
    for t in committed:
        for k, v in t.write_set:
            kssn = state.ssn_of(k.encode())
            assert kssn >= t.ssn, (t.tid, k, kssn, t.ssn)
            if kssn == t.ssn:
                assert state.get(k.encode()) == v

    # I2: recovered values are RAW-closed
    ssn_of_tid = {t.tid: t.ssn for t in history}
    for k, (v, s) in state.data.items():
        tid = int(v.decode())
        for p in raw_preds.get(tid, []):
            pt = next(t for t in history if t.tid == p)
            for pk, pv in pt.write_set:
                assert state.ssn_of(pk.encode()) >= pt.ssn, (
                    f"recovered T{tid} but RAW pred T{p} write {pk} missing"
                )
