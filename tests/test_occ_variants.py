"""OCC (§4.4) + engine-variant semantics: serializability under contention,
ELR correctness, variant constraint levels (Table 1)."""

import threading

import pytest

from repro.core import EngineConfig, PoplarEngine, Txn, Worker, recover
from repro.core.variants import CentrEngine, NvmDEngine, SiloEngine
from repro.db import OCCWorker, Table


def _poplar(n=2):
    return PoplarEngine(EngineConfig(n_buffers=n, device_kind="null", flush_interval=1e-3))


def test_occ_read_validation_abort():
    """A txn whose read set changed during validation must abort."""
    table = Table()
    table.insert("x", b"0")
    eng = _poplar()
    w0 = OCCWorker(table, eng, 0)
    w1 = OCCWorker(table, eng, 1)
    cell = table.get("x")

    # interleave manually: w0 reads x, then w1 commits a write to x,
    # then w0 validates -> ssn changed -> abort
    seen_ssn = cell.ssn
    assert w1.execute(reads=[], writes=[("x", b"1")]) is not None
    # emulate w0's read-set validation against the stale ssn
    assert cell.ssn != seen_ssn


def test_occ_concurrent_counter_serializable():
    """N threads increment a counter via RMW txns; committed increments must
    equal the final counter value (lost-update freedom under OCC)."""
    table = Table()
    table.insert("ctr", (0).to_bytes(8, "little"))
    eng = _poplar()
    eng.start()
    n_workers, per = 4, 60
    commits = [0] * n_workers

    def loop(i):
        w = OCCWorker(table, eng, i)
        for _ in range(per):
            while True:
                cell = table.get("ctr")
                val = int.from_bytes(cell.value[:8], "little")
                t = w.execute(reads=["ctr"], writes=[("ctr", (val + 1).to_bytes(8, "little"))])
                if t is not None:
                    commits[i] += 1
                    break
            w.drain()

    threads = [threading.Thread(target=loop, args=(i,)) for i in range(n_workers)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
    eng.quiesce(range(n_workers), timeout=30)
    eng.stop()
    final = int.from_bytes(table.get("ctr").value[:8], "little")
    assert final == sum(commits) == n_workers * per

    # crash-recover: the recovered counter must equal the live value
    st = recover(eng.devices)
    assert int.from_bytes(st.get(b"ctr")[:8], "little") == final


def test_elr_reader_commits_after_writer():
    """Early lock release: a reader of pre-committed data must not commit
    before its writer (strictness via SSN ordering + CSN)."""
    table = Table()
    table.insert("a", b"0")
    table.insert("b", b"0")
    # a huge flush interval keeps drain()'s inline null-device logger tick
    # from auto-flushing between steps on a slow CI machine — the "nothing
    # flushed yet" assertions below need flushing pinned to quiesce()
    eng = PoplarEngine(
        EngineConfig(n_buffers=2, device_kind="null", flush_interval=60.0)
    )
    w0 = OCCWorker(table, eng, 0)
    w1 = OCCWorker(table, eng, 1)
    t_writer = w0.execute(reads=[], writes=[("a", b"W")])
    # reader observes the (pre-committed, ELR-released) write immediately
    t_reader = w1.execute(reads=["a"], writes=[("b", b"R")])
    assert t_writer.ssn < t_reader.ssn
    # drain with nothing flushed: neither commits
    assert eng.drain(0) == 0 and eng.drain(1) == 0
    eng.quiesce([0, 1], timeout=10)
    assert t_writer.committed and t_reader.committed
    assert t_writer.t_commit <= t_reader.t_commit


def test_nvmd_tracks_war_in_gsn():
    """NVM-D's GSN updates read tuples (WAR tracked) — Poplar's SSN doesn't."""

    class Cell:
        def __init__(self):
            self.ssn = 0

    nv = NvmDEngine(n_workers=2, n_devices=2, device_kind="null")
    nv.register_worker(0)
    a = Cell()
    t = Txn(tid=1, read_set=[("a", 0)], write_set=[("b", b"x")])
    t.worker_id = 0
    nv.allocate(t, [a], [Cell()])
    assert a.ssn == t.ssn  # read tuple got the GSN

    pop = _poplar()
    pop.register_worker(0)
    a2 = Cell()
    t2 = Txn(tid=2, read_set=[("a", 0)], write_set=[("b", b"x")])
    t2.worker_id = 0
    pop.allocate(t2, [a2], [Cell()])
    assert a2.ssn == 0     # WAR untracked (recoverability)


def test_silo_epoch_commit():
    eng = SiloEngine(EngineConfig(n_buffers=2, device_kind="null"), epoch_interval=3600)
    w0 = Worker(eng, 0)

    class Cell:
        def __init__(self):
            self.ssn = 0

    t = Txn(tid=1, write_set=[("a", b"1")])
    w0.run(t, [], [Cell()])
    # flush everything: txn still cannot commit until the epoch advances
    eng.logger_tick(0, force=True)
    eng.logger_tick(1, force=True)
    assert eng.drain(0) == 0
    eng.advance_epoch()
    eng.logger_tick(0, force=True)
    eng.logger_tick(1, force=True)
    assert eng.drain(0) == 1 and t.committed


def test_centr_total_order():
    eng = CentrEngine(EngineConfig(device_kind="null"))
    w = Worker(eng, 0)

    class Cell:
        def __init__(self):
            self.ssn = 0

    ssns = []
    for i in range(5):
        t = Txn(tid=i + 1, write_set=[(f"k{i}", b"v")])
        w.run(t, [], [Cell()])
        ssns.append(t.ssn)
    assert ssns == sorted(ssns) and len(set(ssns)) == 5  # strict total order
