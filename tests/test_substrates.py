"""Substrate tests: data pipeline determinism/resume, AdamW, gradient
compression, serving engine, HLO cost model sanity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import DataConfig, TokenPipeline
from repro.optim import adamw
from repro.parallel import compression
from repro.parallel.hlo_analysis import analyze_hlo


def test_pipeline_deterministic_and_resumable():
    cfg = DataConfig(vocab=100, batch=2, seq_len=16, seed=7)
    p1 = TokenPipeline(cfg)
    batches = [p1.next_batch() for _ in range(5)]
    # resume from cursor 3 must reproduce batch 3 exactly
    p2 = TokenPipeline.restore(cfg, {"cursor": np.asarray(3)})
    np.testing.assert_array_equal(p2.next_batch()["tokens"], batches[3]["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(batches[0]["tokens"][:, 1:], batches[0]["labels"][:, :-1])


def test_adamw_decreases_quadratic():
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1, total_steps=1000)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = adamw.init(params, cfg)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    for _ in range(50):
        g = jax.grad(loss)(params)
        params, state, m = adamw.update(g, state, params, cfg)
    assert float(loss(params)) < 0.2
    assert int(state["count"]) == 50


def test_adamw_grad_clip():
    cfg = adamw.AdamWConfig(lr=1e-3, grad_clip=1.0)
    params = {"w": jnp.zeros(3)}
    state = adamw.init(params, cfg)
    g = {"w": jnp.asarray([100.0, 0.0, 0.0])}
    _, _, m = adamw.update(g, state, params, cfg)
    assert float(m["grad_norm"]) == pytest.approx(100.0)


def test_quantize_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 1, (1000,)), jnp.float32)
    y = compression.fake_quantize(x)
    err = float(jnp.max(jnp.abs(x - y)))
    assert err <= float(jnp.max(jnp.abs(x))) / 127 + 1e-6


def test_error_feedback_accumulates():
    x = jnp.full((64,), 1e-4, jnp.float32)   # below one quantization step
    err = jnp.zeros_like(x)
    total = jnp.zeros_like(x)
    for _ in range(40):
        q, err = compression.ef_quantize(x, err)
        total = total + q
    # with error feedback the mean emitted value converges to the input
    np.testing.assert_allclose(float(total.mean()) / 40, 1e-4, rtol=0.2)


def test_compressed_psum_shardmap():
    import os
    if jax.device_count() < 2:
        pytest.skip("needs >1 device (covered by test_sharding subprocess)")


def test_train_step_accum_equivalence():
    from repro.configs.base import ShapeConfig, reduced
    from repro.configs.registry import get_config, make_inputs
    from repro.models.api import build_model
    from repro.train.step import make_train_step

    cfg = reduced(get_config("tinyllama-1.1b"), n_layers=2)
    model = build_model(cfg)
    ocfg = adamw.AdamWConfig(lr=1e-3)
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw.init(params, ocfg)
    batch = make_inputs(cfg, ShapeConfig("t", 32, 4, "train"))

    s1 = make_train_step(model, ocfg, accum_steps=1)
    s2 = make_train_step(model, ocfg, accum_steps=2)
    _, _, m1 = s1(params, opt, batch)
    _, _, m2 = s2(params, opt, batch)
    # microbatched loss == mean of microbatch losses ~= full-batch loss
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=5e-3)


def test_hlo_cost_model_counts_loops():
    """scan-over-layers flops must equal the unrolled equivalent."""
    D, L = 64, 4

    def layer(x, w):
        return jnp.tanh(x @ w)

    def scan_model(ws, x):
        def body(x, w):
            return layer(x, w), None
        x, _ = jax.lax.scan(body, x, ws)
        return x.sum()

    def unroll_model(ws, x):
        for i in range(L):
            x = layer(x, ws[i])
        return x.sum()

    ws = jnp.zeros((L, D, D))
    x = jnp.zeros((8, D))
    c_scan = analyze_hlo(jax.jit(scan_model).lower(ws, x).compile().as_text())
    c_unroll = analyze_hlo(jax.jit(unroll_model).lower(ws, x).compile().as_text())
    assert c_scan.dot_flops == pytest.approx(c_unroll.dot_flops, rel=0.01)
    assert c_scan.dot_flops == pytest.approx(2 * 8 * D * D * L, rel=0.01)
    assert c_scan.while_trips == [L]


# (the LLM ServeEngine smoke test lives in test_models_smoke.py, next to
# the model-family tests it belongs with — repro.models.serve_llm)
