"""Sharding rule resolution + small-mesh pjit integration (subprocess with
forced host devices so the main test process keeps 1 device)."""

import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.models.common import ParamSpec
import jax.numpy as jnp


class _FakeMesh:
    """Duck-typed mesh exposing .shape for rule resolution tests."""

    def __init__(self, shape):
        self.shape = shape


def _resolve(shape, logical, mesh_shape, policy="train"):
    from repro.parallel.sharding import POLICIES, resolve_pspec

    return tuple(resolve_pspec(shape, logical, _FakeMesh(mesh_shape), POLICIES[policy]))


def test_fsdp_tp_weight():
    # (d_model, d_ff) -> embed over (pod,data), mlp over model
    spec = _resolve((6144, 16384), ("embed", "mlp"), {"pod": 2, "data": 16, "model": 16})
    assert spec == (("pod", "data"), "model")


def test_single_pod_fallback():
    # no 'pod' axis: embed falls back to (data,)
    spec = _resolve((6144, 16384), ("embed", "mlp"), {"data": 16, "model": 16})
    assert spec == ("data", "model")


def test_divisibility_fallback_heads():
    # qwen2: 12 heads don't divide 16 -> heads unsharded
    spec = _resolve((1536, 12 * 128), ("embed", "heads"), {"data": 16, "model": 16})
    assert spec == ("data", "model") or spec[0] == "data"
    # hymba q proj: 25*64=1600 divides 16 even though heads=25 don't
    spec = _resolve((1600, 1600), ("embed", "heads"), {"data": 16, "model": 16})
    assert spec == ("data", "model")


def test_expert_dim_unsharded():
    # 8 experts vs 16-wide axes: falls through to replicated on E
    spec = _resolve((8, 6144, 32768), ("expert", "embed", "mlp"), {"data": 16, "model": 16})
    assert spec[0] is None and spec[1] == "data" and spec[2] == "model"


def test_no_axis_reuse_per_leaf():
    # batch grabs (pod,data); kv_seq must not reuse them
    spec = _resolve(
        (32, 128, 32768, 8, 128),
        ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
        {"pod": 2, "data": 16, "model": 16},
    )
    assert spec[1] == ("pod", "data")
    assert spec[2] is None           # data already used by batch
    assert spec[4] == "model" or spec[3] == "model"


def test_long500k_seq_sharding():
    # batch=1 unshardable -> kv_seq gets the data axis (SP)
    spec = _resolve(
        (32, 1, 4096, 8, 128),
        ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
        {"data": 16, "model": 16},
    )
    assert spec[1] is None and spec[2] == "data"


SUBPROCESS_TEST = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys, json
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp
    from repro.launch.mesh import mesh_context
    import numpy as np
    from repro.configs.base import reduced, ShapeConfig
    from repro.configs.registry import get_config, make_inputs
    from repro.models.api import build_model
    from repro.models.common import specs_to_sds, init_params
    from repro.optim import adamw
    from repro.parallel import sharding as shd
    from repro.parallel.axes import logical_context
    from repro.train.step import make_train_step
    from repro.launch.mesh import make_smoke_mesh

    mesh = make_smoke_mesh()  # (2, 2) data x model
    cfg = reduced(get_config("tinyllama-1.1b"), n_layers=2, d_model=64, vocab=256)
    model = build_model(cfg)
    pspecs = model.param_specs()
    opt_cfg = adamw.AdamWConfig()
    step = make_train_step(model, opt_cfg)

    params = model.init(jax.random.PRNGKey(0))
    opt = adamw.init(params, opt_cfg)
    batch = make_inputs(cfg, ShapeConfig("t", 32, 4, "train"))

    param_sh = shd.tree_shardings(pspecs, mesh, "train")
    opt_sh = shd.tree_shardings(adamw.opt_state_specs(pspecs, opt_cfg), mesh, "train")
    batch_sh = shd.batch_shardings({k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in batch.items()}, mesh, "train")
    rep = shd.replicated(mesh)

    def wrapped(p, o, b):
        with logical_context(mesh, "train"):
            return step(p, o, b)

    jitted = jax.jit(wrapped, in_shardings=(param_sh, opt_sh, batch_sh),
                     out_shardings=(param_sh, opt_sh, {"grad_norm": rep, "lr": rep, "loss": rep}))
    with mesh_context(mesh):
        p1, o1, m1 = jitted(params, opt, batch)
        p2, o2, m2 = jitted(p1, o1, batch)
    # compare against single-device execution
    sp, so, sm = step(params, opt, batch)
    err = abs(float(m1["loss"]) - float(sm["loss"]))
    print(json.dumps({"loss_mesh": float(m1["loss"]), "loss_single": float(sm["loss"]),
                      "err": err, "loss2": float(m2["loss"])}))
    assert err < 2e-2, err
""")


def test_pjit_matches_single_device(tmp_path):
    """The sharded train step must produce the same loss as single-device."""
    script = tmp_path / "mesh_test.py"
    script.write_text(SUBPROCESS_TEST)
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, str(script)], capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))), env=env,
        timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["err"] < 2e-2
