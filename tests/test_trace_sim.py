"""Cost model + replay simulator fidelity.

The load-bearing contracts from the ROADMAP's cost-model items:

* replaying a recorded single-shard trace through the list scheduler
  reproduces the measured wall time within 10% (the simulator's floor —
  noise inside a span lands in both the measurement and the replay, so
  only *untraced gaps* can diverge, and the driver hooks close those);
* critical-path attribution accounts for >= 95% of the measured wall
  window;
* the fitted per-stage models and the synthetic what-if generator behave
  monotonically (more devices never hurts an IO-bound config, cross-shard
  ratio taxes throughput, pad calibration zeroes the calibration cell).
"""

import time

import numpy as np
import pytest

from repro.core import EngineConfig
from repro.db.batch import TxnSpec
from repro.db.ycsb import key_of
from repro.serve import SingleBackend
from repro.trace import (
    ST_DRIVER,
    ST_ENCODE,
    ST_FLUSH,
    ST_PUBLISH,
    ST_SEQUENCE,
    ST_VALIDATE,
    ST_XPREPARE,
    TRACER,
    CostModel,
    SimConfig,
    TraceDump,
    WorkloadProfile,
    autotune,
    build_dag,
    critical_path,
    disable,
    enable,
    simulate,
    simulate_dag,
)
from repro.trace.sim import _list_schedule

N_KEYS = 512
BATCH = 256
N_BATCH = 8


@pytest.fixture(autouse=True)
def _disarm():
    yield
    TRACER.enabled = False
    TRACER.reset()


def _traced_single_shard_run(tmp_path):
    """Deterministic single-shard loop, traced end to end: driver halves
    (workload gen, drain) wrapped in ST_DRIVER spans exactly the way
    ``benchmarks/fig_trace.py`` wraps its measurement loop."""
    cfg = EngineConfig(n_buffers=2, device_kind="null",
                       device_dir=str(tmp_path))
    backend = SingleBackend.make("vectorized", n_workers=2, cfg=cfg,
                                 table_capacity=N_KEYS + 1)
    for i in range(N_KEYS):
        backend.occ.table.insert(key_of(i), b"\x00")
    # warm-up outside the trace window
    backend.execute([TxnSpec(writes=[(key_of(0), b"w")])])
    backend.drain()

    enable()
    t0 = time.perf_counter()
    for b in range(N_BATCH):
        _td = time.perf_counter()
        specs = [
            TxnSpec(writes=[(key_of((b * BATCH + i) % N_KEYS),
                             bytes([i % 251]) * 64)])
            for i in range(BATCH)
        ]
        TRACER.record(ST_DRIVER, t0=_td, t1=time.perf_counter(),
                      n_txn=BATCH)
        backend.execute(specs)
        _td = time.perf_counter()
        backend.drain()
        TRACER.record(ST_DRIVER, t0=_td, t1=time.perf_counter())
    elapsed = time.perf_counter() - t0
    dump = disable()
    return dump, elapsed


def test_replay_makespan_matches_measured(tmp_path):
    dump, elapsed = _traced_single_shard_run(tmp_path)
    res = simulate_dag(build_dag(dump))
    assert res.makespan == pytest.approx(elapsed, rel=0.10)
    assert res.txn_s > 0


def test_critical_path_covers_wall_time(tmp_path):
    dump, elapsed = _traced_single_shard_run(tmp_path)
    _, attr = critical_path(build_dag(dump))
    assert sum(attr.values()) >= 0.95 * elapsed
    # a single-threaded run should attribute most time to stages, not waits
    assert attr.get("wait", 0.0) <= 0.2 * elapsed


# --- list scheduler -----------------------------------------------------------

def test_list_schedule_serializes_on_one_server():
    # three independent unit tasks on one cpu -> finish at 1, 2, 3
    finish = _list_schedule([[], [], []], [1.0, 1.0, 1.0],
                            ["cpu", "cpu", "cpu"], {"cpu": 1})
    assert sorted(finish.tolist()) == [1.0, 2.0, 3.0]
    # ... and on three cpus they all finish at 1
    finish = _list_schedule([[], [], []], [1.0, 1.0, 1.0],
                            ["cpu", "cpu", "cpu"], {"cpu": 3})
    assert finish.tolist() == [1.0, 1.0, 1.0]


def test_list_schedule_honors_dependencies_and_virtual_nodes():
    # chain 0 -> 1 -> (virtual join 2) -> 3
    finish = _list_schedule(
        [[], [0], [1], [2]], [1.0, 2.0, 0.0, 1.0],
        ["cpu", "cpu", None, "cpu"], {"cpu": 1},
    )
    assert finish.tolist() == [1.0, 3.0, 3.0, 4.0]


def test_list_schedule_rejects_cycles():
    with pytest.raises(ValueError, match="cycle"):
        _list_schedule([[1], [0]], [1.0, 1.0], ["cpu", "cpu"], {"cpu": 1})


# --- cost model fitting -------------------------------------------------------

def _synthetic_dump(a=1e-4, b=2e-6, c=1e-9, n_rows=64, seed=3):
    """Rows whose durations follow a known linear law t = a + b*n + c*bytes."""
    rng = np.random.default_rng(seed)
    n_txn = rng.integers(32, 512, n_rows)
    nbytes = n_txn * 64
    t0 = np.cumsum(rng.random(n_rows)) * 1e-3
    dur = a + b * n_txn + c * nbytes
    return TraceDump(
        stage=np.full(n_rows, ST_VALIDATE, np.int16),
        shard=np.zeros(n_rows, np.int32),
        device=np.full(n_rows, -1, np.int32),
        batch=np.arange(n_rows, dtype=np.int64),
        txn_lo=np.zeros(n_rows, np.int64),
        txn_hi=np.zeros(n_rows, np.int64),
        t0=t0, t1=t0 + dur,
        nbytes=nbytes.astype(np.int64),
        n_txn=n_txn.astype(np.int64),
        aux=np.zeros(n_rows, np.int64),
    )


def test_fit_recovers_linear_stage_cost():
    dump = _synthetic_dump()
    m = CostModel.fit(dump)
    # predicted cost at a fresh operating point within 5% of ground truth
    for n in (64, 300, 1000):
        truth = 1e-4 + 2e-6 * n + 1e-9 * (n * 64)
        assert m.stage_cost(ST_VALIDATE, n, n * 64) == pytest.approx(
            truth, rel=0.05
        )


def test_fit_flush_recovers_device_model():
    lat, bw = 2e-4, 50e6
    rng = np.random.default_rng(5)
    nbytes = rng.integers(4096, 1 << 20, 48)
    t0 = np.cumsum(rng.random(48)) * 1e-3
    dur = lat + nbytes / bw
    dump = TraceDump(
        stage=np.full(48, ST_FLUSH, np.int16),
        shard=np.zeros(48, np.int32), device=np.zeros(48, np.int32),
        batch=np.full(48, -1, np.int64),
        txn_lo=np.zeros(48, np.int64), txn_hi=np.zeros(48, np.int64),
        t0=t0, t1=t0 + dur,
        nbytes=nbytes.astype(np.int64),
        n_txn=np.ones(48, np.int64), aux=np.zeros(48, np.int64),
    )
    m = CostModel.fit(dump)
    assert m.dev_lat == pytest.approx(lat, rel=0.05)
    assert m.dev_bw == pytest.approx(bw, rel=0.05)
    assert m.flush_cost(1 << 20, bw=25e6) > m.flush_cost(1 << 20, bw=50e6)


def _toy_model():
    m = CostModel()
    m.coef[ST_VALIDATE] = (1e-4, 1e-6, 0.0)
    m.coef[ST_SEQUENCE] = (5e-5, 5e-7, 0.0)
    m.coef[ST_ENCODE] = (5e-5, 2e-7, 2e-9)
    m.coef[ST_PUBLISH] = (5e-5, 2e-7, 1e-9)
    m.dev_lat, m.dev_bw = 1e-4, 30e6
    return m


def test_simulate_monotone_in_devices_when_io_bound():
    m = _toy_model()
    prof = WorkloadProfile(bytes_per_txn=1000.0, txn_per_batch=512.0)
    one = simulate(m, SimConfig(devices=1, batch_size=512, n_txn=8192), prof)
    four = simulate(m, SimConfig(devices=4, batch_size=512, n_txn=8192), prof)
    assert four.txn_s > one.txn_s          # striping relieves the device
    assert one.p50_commit >= 0 and one.p99_commit >= one.p50_commit


def test_simulate_taxes_cross_shard_ratio():
    m = _toy_model()
    m.coef[ST_XPREPARE] = (0.0, 2e-4, 0.0)   # expensive per-txn prepare
    prof = WorkloadProfile(bytes_per_txn=600.0, txn_per_batch=512.0)
    base = simulate(m, SimConfig(shards=2, batch_size=512, n_txn=8192,
                                 cross_ratio=0.0), prof)
    taxed = simulate(m, SimConfig(shards=2, batch_size=512, n_txn=8192,
                                  cross_ratio=0.5), prof)
    assert taxed.txn_s < 0.8 * base.txn_s


def test_calibrate_pad_zeroes_calibration_cell():
    m = _toy_model()
    prof = WorkloadProfile(bytes_per_txn=600.0, txn_per_batch=512.0)
    cfg = SimConfig(devices=2, batch_size=512, n_txn=8192)
    raw = simulate(m, cfg, prof)
    measured = raw.txn_s * 0.7             # pretend 30% untraced overhead
    pad = m.calibrate_pad(measured, cfg, prof)
    assert pad > 0
    again = simulate(m, cfg, prof)
    assert again.txn_s == pytest.approx(measured, rel=0.02)
    # a faster-than-predicted measurement clamps to zero, never speeds up
    assert m.calibrate_pad(raw.txn_s * 2.0, cfg, prof) == 0.0


def test_merge_stage_grafts_coefficients():
    m, other = _toy_model(), CostModel()
    other.coef[ST_XPREPARE] = (1.0, 2.0, 3.0)
    m.merge_stage(other, ST_XPREPARE)
    assert m.coef[ST_XPREPARE] == (1.0, 2.0, 3.0)
    m.merge_stage(CostModel(), ST_DRIVER)  # absent stage: no-op
    assert ST_DRIVER not in m.coef


# --- autotune -----------------------------------------------------------------

def test_autotune_picks_grid_member_and_fills_table():
    m = _toy_model()
    prof = WorkloadProfile(bytes_per_txn=1000.0, txn_per_batch=512.0)
    r = autotune(m, prof, n_txn=8192, batch_grid=(128, 512),
                 device_grid=(1, 2))
    assert (r.batch_size, r.devices) in {(128, 1), (128, 2), (512, 1),
                                         (512, 2)}
    assert len(r.table) == 4
    best = max(r.table, key=lambda row: row["txn_s"])
    assert r.predicted.txn_s == pytest.approx(best["txn_s"])
    d = r.to_dict()
    assert d["batch_size"] == r.batch_size and len(d["table"]) == 4


def test_autotune_p99_budget_filters_candidates():
    m = _toy_model()
    prof = WorkloadProfile(bytes_per_txn=1000.0, txn_per_batch=512.0)
    free = autotune(m, prof, n_txn=8192, batch_grid=(128, 2048),
                    device_grid=(1,))
    tight = autotune(m, prof, n_txn=8192, batch_grid=(128, 2048),
                     device_grid=(1,),
                     p99_budget=free.predicted.p99_commit * 0.5)
    # the budget either changed the choice or the choice already fit it
    assert tight.predicted.p99_commit <= max(
        free.predicted.p99_commit * 0.5, tight.predicted.p99_commit
    )
    impossible = autotune(m, prof, n_txn=8192, batch_grid=(128, 2048),
                          device_grid=(1,), p99_budget=1e-12)
    assert (impossible.batch_size, impossible.devices) == (
        free.batch_size, free.devices
    )  # falls back to the unconstrained best
