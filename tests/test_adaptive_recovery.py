"""Adaptive logging crash equivalence: a run whose winners are
command-framed recovers **byte-identically** to the pure-value oracle —
the same workload executed with ``AdaptivePolicy(force_value=True)`` — at
arbitrary kill points, through every replay surface:

* single-shard ``recover()`` in all three modes (vectorized/pallas/scalar),
  with a fuzzy checkpoint underneath (so command deps split into
  image-covered and log-covered classes);
* 2-shard ``recover_sharded()`` with cross-shard riders (which the policy
  must keep value-framed) and a partially-flushed crash;
* ``Replica.promote()`` over shipped prefixes of the same logs.

Kill points use the captured-byte-stream pattern of ``test_truncation``:
both runs execute the identical deterministic schedule, so their devices
hold the *same records in the same order* (only framed differently), and
cutting each device after record ``n`` crashes both runs at the same
logical instant.  Cuts land mid-schedule, between devices asymmetrically,
and on torn garbage tails.
"""

import os
import struct

import numpy as np
import pytest

from repro.core import (
    CheckpointDaemon,
    DeviceSpec,
    EngineConfig,
    PoplarEngine,
    StorageDevice,
    recover,
)
from repro.core.command import OP_ADD_U64, OP_PATCH_PREFIX
from repro.core.engine import AdaptivePolicy
from repro.core.txn import decode_columnar
from repro.db import ArrayTable, BatchOCC, TxnSpec
from repro.replica import Replica
from repro.shard import ShardedConfig, ShardedEngine, recover_sharded

MODES = ("vectorized", "pallas", "scalar")


# ---------------------------------------------------------------------------
# captured-byte-stream kill points
# ---------------------------------------------------------------------------

def _prefix_records(blob: bytes, n: int) -> bytes:
    """The byte prefix holding the first ``n`` whole frames of ``blob``."""
    off = 0
    for _ in range(n):
        if off + 8 > len(blob):
            break
        plen = struct.unpack_from("<I", blob, off)[0]
        if off + 8 + plen > len(blob):
            break
        off += 8 + plen
    return blob[:off]


def _n_records(blob: bytes) -> int:
    off = n = 0
    while off + 8 <= len(blob):
        plen = struct.unpack_from("<I", blob, off)[0]
        if off + 8 + plen > len(blob):
            break
        off += 8 + plen
        n += 1
    return n


def _mem_devices(blobs):
    out = []
    for b in blobs:
        d = StorageDevice(DeviceSpec.null(), clock="virtual")
        d.write(b)
        out.append(d)
    return out


def _cut_devices(streams, counts, torn: bool = False):
    """In-memory devices holding each stream cut after ``counts[i]`` records
    (the crash), optionally with a torn garbage tail on device 0."""
    blobs = [_prefix_records(s, n) for s, n in zip(streams, counts)]
    if torn:
        blobs[0] = blobs[0] + b"\xfe" * 13
    return _mem_devices(blobs)


# ---------------------------------------------------------------------------
# single-shard workload (identical schedule, framing decided by the policy)
# ---------------------------------------------------------------------------

def _csn_fn(engine):
    def fn():
        for i in range(len(engine.buffers)):
            engine.logger_tick(i, force=True)
        return engine.commit.advance_csn()
    return fn


def _run_single(root: str, adaptive: bool):
    """Deterministic mixed workload: preloaded wide tuples (dep SSN 0 —
    command-eligible only once a full-image checkpoint exists), logged
    counters (log-covered deps), blind value writes, an unregistered-op
    spec (forced-value hatch), and a mid-run checkpoint.  Returns the
    engine's devices + checkpoint dir, fully flushed."""
    dev_dir = os.path.join(root, "devs")
    ckpt_dir = os.path.join(root, "ckpt")
    cfg = EngineConfig(n_buffers=2, device_kind="ssd", device_dir=dev_dir,
                       device_clock="virtual", segment_bytes=64 * 1024)
    eng = PoplarEngine(cfg)
    table = ArrayTable()
    wide = [f"w{i}" for i in range(10)]
    for k in wide:
        table.insert(k, b"\x00" * 48)          # ssn 0: in no log
    ctrs = [f"c{i}" for i in range(4)]
    daemon = CheckpointDaemon(ckpt_dir, n_threads=2, m_files=2,
                              csn_fn=_csn_fn(eng))
    pol = AdaptivePolicy(checkpoint_dir=ckpt_dir, force_value=not adaptive)
    occ = BatchOCC(table, eng, policy=pol)
    rng = np.random.default_rng(42)

    # counters get logged base versions first (log-covered command deps)
    occ.execute_batch(
        [TxnSpec(writes=[(k, struct.pack("<Q", 5) + b"\x00" * 8)])
         for k in ctrs]
    )
    for rnd in range(10):
        specs = []
        picks = rng.choice(len(wide), size=3, replace=False)
        for j in picks.tolist():
            k = wide[j]
            cur, cssn = table.get(k)
            pfx = bytes([rnd + 1]) * 6
            specs.append(TxnSpec(
                reads=[k], writes=[(k, pfx + cur[len(pfx):])],
                observed=[cssn], cmd_op=OP_PATCH_PREFIX, cmd_params=[pfx],
            ))
        c = ctrs[int(rng.integers(len(ctrs)))]
        cur, cssn = table.get(c)
        delta = int(rng.integers(1, 9))
        newv = struct.pack(
            "<Q", (struct.unpack_from("<Q", cur)[0] + delta) & (2**64 - 1)
        ) + cur[8:]
        specs.append(TxnSpec(
            reads=[c], writes=[(c, newv)], observed=[cssn],
            cmd_op=OP_ADD_U64, cmd_params=[struct.pack("<Q", delta)],
        ))
        specs.append(TxnSpec(writes=[(f"blind{rnd}", bytes([rnd]) * 24)]))
        if rnd % 3 == 0:
            # unregistered op: the policy's forced-value escape hatch
            k = wide[int(picks[0])]
            cur, cssn = table.get(k)
            specs.append(TxnSpec(
                reads=[k], writes=[(k, b"U" * 8 + cur[8:])],
                observed=[cssn], cmd_op=999, cmd_params=[b"U" * 8],
            ))
        occ.execute_batch(specs)
        if rnd == 4:
            # full image — including ssn-0 rows, the cover the policy's
            # dep-0 clause relies on (fig_truncation's s>0 filter would
            # be unsound here)
            entries = sorted((k.encode(), v, s) for k, v, s in table.items())
            daemon.run_once([entries[0::2], entries[1::2]], epoch=rnd)
            pol.refresh()
    for i in range(cfg.n_buffers):
        eng.logger_tick(i, force=True)
    return eng.devices, ckpt_dir


@pytest.fixture(scope="module")
def single_runs(tmp_path_factory):
    vroot = str(tmp_path_factory.mktemp("value"))
    aroot = str(tmp_path_factory.mktemp("adaptive"))
    vdevs, vck = _run_single(vroot, adaptive=False)
    adevs, ack = _run_single(aroot, adaptive=True)
    vstreams = [d.read_from(0) for d in vdevs]
    astreams = [d.read_from(0) for d in adevs]
    return vstreams, vck, astreams, ack


def test_workload_actually_mixes_framings(single_runs):
    vstreams, _, astreams, _ = single_runs
    ncmd = sum(decode_columnar(s).n_command for s in astreams)
    nval = sum(
        decode_columnar(s).n_records - decode_columnar(s).n_command
        for s in astreams
    )
    assert ncmd > 10, "adaptive run framed no commands — the test is vacuous"
    assert nval > 0, "forced-value hatch never taken"
    assert sum(decode_columnar(s).n_command for s in vstreams) == 0
    # the two runs hold the same records in the same order (only framing
    # differs) — the premise of every record-count kill point below
    for vs, as_ in zip(vstreams, astreams):
        lv, la = decode_columnar(vs), decode_columnar(as_)
        assert lv.ssn.tolist() == la.ssn.tolist()
        assert lv.tid.tolist() == la.tid.tolist()
    # and command framing ships fewer bytes on this RMW-heavy mix
    assert sum(map(len, astreams)) < sum(map(len, vstreams))


def test_quiesced_recovery_equals_value_oracle(single_runs):
    vstreams, vck, astreams, ack = single_runs
    oracle = recover(_mem_devices(vstreams), checkpoint_dir=vck,
                     parallel=False)
    for mode in MODES:
        got = recover(_mem_devices(astreams), checkpoint_dir=ack,
                      parallel=False, mode=mode)
        assert got.data == oracle.data, mode
        assert got.rsne == oracle.rsne and got.rsns == oracle.rsns, mode


def test_kill_point_recovery_equals_value_oracle(single_runs):
    vstreams, vck, astreams, ack = single_runs
    totals = [_n_records(s) for s in vstreams]
    rng = np.random.default_rng(7)
    cuts = [(0, 0), tuple(totals)]
    cuts += [
        tuple(int(rng.integers(0, t + 1)) for t in totals) for _ in range(8)
    ]
    for torn in (False, True):
        for counts in cuts:
            oracle = recover(
                _cut_devices(vstreams, counts, torn=torn),
                checkpoint_dir=vck, parallel=False,
            )
            for mode in MODES:
                got = recover(
                    _cut_devices(astreams, counts, torn=torn),
                    checkpoint_dir=ack, parallel=False, mode=mode,
                )
                assert got.data == oracle.data, (counts, torn, mode)
                assert got.rsne == oracle.rsne, (counts, torn, mode)


def test_promote_equals_value_oracle_at_kill_points(single_runs):
    vstreams, vck, astreams, ack = single_runs
    totals = [_n_records(s) for s in vstreams]
    rng = np.random.default_rng(13)
    cuts = [tuple(totals)] + [
        tuple(int(rng.integers(0, t + 1)) for t in totals) for _ in range(4)
    ]
    for counts in cuts:
        oracle = recover(_cut_devices(vstreams, counts),
                         checkpoint_dir=vck, parallel=False)
        for mode in MODES:
            rep = Replica(_cut_devices(astreams, counts),
                          checkpoint_dir=ack, mode=mode, parallel=False)
            st = rep.promote()
            assert st.data == oracle.data, (counts, mode)
            assert st.rsne == oracle.rsne, (counts, mode)


# ---------------------------------------------------------------------------
# 2-shard: adaptive per-shard framing + value-framed cross-shard riders
# ---------------------------------------------------------------------------

def _run_sharded(tmp_path, adaptive: bool, flush_all: bool):
    eng = ShardedEngine(ShardedConfig(
        n_shards=2, n_buffers=1, n_workers=2, device_kind="ssd",
        device_dir=str(tmp_path), device_clock="virtual",
        policy_factory=lambda sid: AdaptivePolicy(force_value=not adaptive),
    ))
    keys = [f"user{i:010d}" for i in range(24)]
    by = [[k for k in keys if eng.shard_of(k) == p] for p in range(2)]
    assert all(len(b) >= 4 for b in by)
    # logged base versions (no checkpoints here, so only log-covered deps
    # are command-eligible; preloads would be dep-0 and must stay value)
    eng.execute_batch(
        [TxnSpec(writes=[(k, struct.pack("<Q", 10) + b"\x00" * 24)])
         for k in keys]
    )
    eng.tick(force=True)
    rng = np.random.default_rng(99)
    for rnd in range(6):
        specs = []
        for p in range(2):
            k = by[p][int(rng.integers(len(by[p])))]
            cur, cssn = eng.get(k)
            delta = int(rng.integers(1, 7))
            newv = struct.pack(
                "<Q", (struct.unpack_from("<Q", cur)[0] + delta) & (2**64 - 1)
            ) + cur[8:]
            specs.append(TxnSpec(
                reads=[k], writes=[(k, newv)], observed=[cssn],
                cmd_op=OP_ADD_U64, cmd_params=[struct.pack("<Q", delta)],
            ))
        # a cross-shard rider: spans both shards, must stay value-framed
        specs.append(TxnSpec(
            writes=[(by[0][rnd % 4], b"X0" * 8), (by[1][rnd % 4], b"X1" * 8)]
        ))
        eng.execute_batch(specs)
        eng.tick(force=True)
        eng.drain()
    # one final cross-shard transaction left torn on shard 1 when not
    # flushing everything (the partially-durable crash)
    eng.execute_batch(
        [TxnSpec(writes=[(by[0][0], b"T0" * 4), (by[1][0], b"T1" * 4)])]
    )
    if flush_all:
        eng.tick(force=True)
        eng.drain()
    else:
        for i in range(len(eng.shards[0].engine.buffers)):
            eng.shards[0].engine.logger_tick(i, force=True)
    return eng


@pytest.mark.parametrize("flush_all", [True, False])
def test_sharded_recovery_equals_value_oracle(tmp_path, flush_all):
    veng = _run_sharded(tmp_path / "value", adaptive=False,
                        flush_all=flush_all)
    aeng = _run_sharded(tmp_path / "adaptive", adaptive=True,
                        flush_all=flush_all)
    ncmd = sum(
        decode_columnar(d.read_from(0)).n_command
        for devs in aeng.devices for d in devs
    )
    assert ncmd > 0, "sharded adaptive run framed no commands"
    # cross-shard records must all be value-framed in both runs
    for devs in aeng.devices:
        for d in devs:
            log = decode_columnar(d.read_from(0))
            if log.x_rec is not None and log.n_command:
                assert not log.cmd_mask[log.x_rec].any()
    oracle = recover_sharded(veng.devices, parallel=False)
    for mode in MODES:
        st = recover_sharded(aeng.devices, parallel=False, mode=mode)
        assert st.data == oracle.data, mode
        assert st.n_cross_dropped == oracle.n_cross_dropped, mode
