"""Decode-cache correctness oracles: prefill+decode must equal one long
prefill — including sliding-window ring-cache *wraparound* and hybrid/rwkv
state carry."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import reduced
from repro.configs.registry import get_config
from repro.models.api import build_model


def _continuation_check(arch, prompt, total, cache_len, atol=3e-2, **overrides):
    cfg = reduced(get_config(arch), **overrides)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (1, total)), jnp.int32)

    full_logits, _ = model.prefill(params, {"tokens": toks}, cache_len=cache_len)
    logits, cache = model.prefill(params, {"tokens": toks[:, :prompt]}, cache_len=cache_len)
    for i in range(prompt, total):
        logits, cache = model.decode_step(
            params, cache, toks[:, i : i + 1], jnp.asarray(i, jnp.int32)
        )
    np.testing.assert_allclose(
        np.asarray(logits[0, 0], np.float32),
        np.asarray(full_logits[0, -1], np.float32),
        atol=atol, rtol=atol,
    )
    return cfg


def test_full_attention_continuation():
    _continuation_check("deepseek-7b", prompt=8, total=14, cache_len=16)


def test_swa_ring_wraparound():
    """Sliding-window ring cache must stay exact across slot wraparound:
    window 8, decode well past 2x the window."""
    _continuation_check(
        "mixtral-8x22b", prompt=6, total=28, cache_len=8,
        sliding_window=8, full_attn_layers=(),
    )


def test_hybrid_state_continuation():
    """hymba: SWA ring cache + SSM state must both carry across decode."""
    _continuation_check(
        "hymba-1.5b", prompt=6, total=20, cache_len=8,
        sliding_window=8, full_attn_layers=(),
    )


def test_rwkv_state_continuation():
    """rwkv6: wkv + token-shift states replace the KV cache entirely."""
    _continuation_check("rwkv6-7b", prompt=6, total=18, cache_len=8)


def test_whisper_decode_continuation():
    cfg = reduced(get_config("whisper-medium"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    rng = np.random.default_rng(0)
    frames = jnp.asarray(rng.normal(0, 0.5, (1, cfg.enc_dec.enc_seq, cfg.d_model)), jnp.bfloat16)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (1, 12)), jnp.int32)

    full_logits, _ = model.prefill(
        params, {"frame_embeds": frames, "tokens": toks}, cache_len=16)
    logits, cache = model.prefill(
        params, {"frame_embeds": frames, "tokens": toks[:, :8]}, cache_len=16)
    for i in range(8, 12):
        logits, cache = model.decode_step(
            params, cache, toks[:, i : i + 1], jnp.asarray(i, jnp.int32))
    np.testing.assert_allclose(
        np.asarray(logits[0, 0], np.float32),
        np.asarray(full_logits[0, -1], np.float32),
        atol=5e-2, rtol=5e-2,
    )
