"""Batched forward-path equivalence (paper §4.2/§4.4, batched).

Property: on randomized YCSB-style batches — conflict-free and
conflict-heavy, with and without driver-observed SSNs — the batched
array-native pipeline (`BatchOCC`: vectorized OCC + batched Algorithm-1
allocation via ``reserve_batch`` + ``encode_batch``/``publish_batch``)
produces *exactly* what the scalar per-transaction machinery
(`ScalarBatchOCC`: dict Table cells, per-txn ``engine.allocate`` +
``Txn.encode`` + ``engine.publish``) produces under the same batch
semantics:

* the same winners/losers per round, the same tids, the same per-txn SSNs
  and read/write sets;
* the same final per-tuple (value, SSN) state;
* byte-identical device logs — so records written via ``publish_batch``
  recover byte-identically through the existing vectorized ``recover()``.
"""

import random

import numpy as np
import pytest

from repro.core import EngineConfig, PoplarEngine, Txn, encode_batch, recover
from repro.db import ArrayTable, BatchOCC, ScalarBatchOCC, Table, TxnSpec
from repro.db import ycsb
from repro.db.batch import _concat_ranges


def _mk_engine(tmp_path, tag: str, n_buffers: int) -> PoplarEngine:
    d = tmp_path / tag
    d.mkdir()
    # flush_interval is explicit (conftest leaves it alone) and effectively
    # infinite: heartbeats are wall-clock-gated, and the scalar oracle's
    # slower per-txn drains would otherwise cross the interval on a slow
    # machine and heartbeat-bump its buffer SSN chains to the frontier while
    # the faster batched engine's drains don't — breaking SSN equivalence
    # nondeterministically.  quiesce() force-ticks at the end, so both
    # engines still heartbeat/flush identically from identical states.
    return PoplarEngine(
        EngineConfig(n_buffers=n_buffers, device_kind="null",
                     device_dir=str(d), flush_interval=60.0)
    )


def _gen_batch(rng, keys, batch_size, scalar_table, with_observed):
    specs = []
    for i in range(batch_size):
        reads = rng.sample(keys, rng.randrange(0, 3))
        writes = [
            (k, rng.randbytes(rng.randrange(0, 40)))
            for k in rng.sample(keys, rng.randrange(0, 3))
        ]
        if not reads and not writes:
            writes = [(keys[0], b"fallback")]
        observed = None
        if with_observed and reads and rng.random() < 0.4:
            observed = [scalar_table.get_or_insert(k).ssn for k in reads]
            if rng.random() < 0.3:
                # deliberately stale: exercises the vectorized observed-SSN
                # abort path
                observed[rng.randrange(len(observed))] += 1
        specs.append(TxnSpec(reads=reads, writes=writes, observed=observed))
    return specs


def _run_trial(seed: int, tmp_path, mode: str) -> None:
    rng = random.Random(seed)
    n_buffers = rng.choice([1, 2, 3])
    n_workers = n_buffers * 2
    # small keyspace => conflict-heavy batches; large => mostly conflict-free
    n_keys = rng.choice([6, 60])
    keys = [ycsb.key_of(i) for i in range(n_keys)]

    tab_s = Table()
    tab_v = ArrayTable()
    for k in keys[: n_keys // 2]:  # half preloaded, half created by specs
        v = rng.randbytes(8)
        tab_s.insert(k, v)
        tab_v.insert(k, v)
    eng_s = _mk_engine(tmp_path, "scalar", n_buffers)
    eng_v = _mk_engine(tmp_path, "vec", n_buffers)
    oracle = ScalarBatchOCC(tab_s, eng_s, n_workers=n_workers)
    batched = BatchOCC(tab_v, eng_v, n_workers=n_workers, mode=mode)

    for _ in range(rng.randrange(2, 5)):
        specs = _gen_batch(rng, keys, rng.randrange(1, 24), tab_s,
                           with_observed=True)
        max_rounds = rng.choice([1, 2, 3])
        rs = oracle.execute_batch(specs, max_rounds=max_rounds)
        rv = batched.execute_batch(specs, max_rounds=max_rounds)

        assert rs.committed_idx == rv.committed_idx, seed
        assert rs.aborted == rv.aborted, seed
        assert rs.rounds == rv.rounds, seed
        for ts, tv in zip(rs.committed, rv.committed):
            assert (ts.tid, ts.ssn, ts.worker_id) == (tv.tid, tv.ssn, tv.worker_id), seed
            assert ts.read_set == tv.read_set and ts.write_set == tv.write_set, seed
        oracle.drain()
        batched.drain()

    # identical per-tuple (value, ssn) state
    state_s = {
        k: (tab_s.get(k).value, tab_s.get(k).ssn) for k in keys if tab_s.get(k)
    }
    state_v = {k: tab_v.get(k) for k in keys if tab_v.get(k) is not None}
    assert state_s == state_v, seed

    eng_s.quiesce(range(n_workers))
    eng_v.quiesce(range(n_workers))
    for d in eng_s.devices + eng_v.devices:
        d.close()

    # byte-identical logs, and batch-published records recover byte-identically
    assert [d.read_all() for d in eng_s.devices] == [
        d.read_all() for d in eng_v.devices
    ], seed
    st_s = recover(eng_s.devices, mode="vectorized", parallel=False)
    st_v = recover(eng_v.devices, mode="vectorized", parallel=False)
    assert st_s.data == st_v.data and st_s.rsne == st_v.rsne, seed
    # the recovered image agrees with the live columnar table wherever the
    # log wrote (uncontacted preloaded keys aren't in the log)
    live = tab_v.to_dict()
    for kb, (val, ssn) in st_v.data.items():
        assert live[kb] == (val, ssn), seed


@pytest.mark.parametrize("seed", range(8))
def test_batched_equals_scalar_oracle(seed, tmp_path):
    _run_trial(seed, tmp_path, mode="vectorized")


@pytest.mark.parametrize("seed", range(2))
def test_batched_equals_scalar_oracle_pallas(seed, tmp_path):
    _run_trial(seed, tmp_path, mode="pallas")


def test_ycsb_write_only_batch(tmp_path):
    """The fig5 configuration in miniature: write-only YCSB batches through
    the batched pipeline, recovered through vectorized recover()."""
    n_records = 200
    tab = ArrayTable()
    ycsb.load(tab, n_records)
    eng = _mk_engine(tmp_path, "ycsb", 2)
    occ = BatchOCC(tab, eng, n_workers=4)
    wl = ycsb.YCSBWriteOnly(n_records, seed=5)
    total = 0
    for _ in range(4):
        specs = wl.next_batch(64)
        res = occ.execute_batch(specs, max_rounds=2)
        total += len(res.committed)
        assert len(res.committed) + len(res.aborted) == len(specs)
        occ.drain()
    assert total > 0
    eng.quiesce(range(4))
    for d in eng.devices:
        d.close()
    st = recover(eng.devices, mode="vectorized")
    live = tab.to_dict()
    assert st.data  # something durable
    for kb, pair in st.data.items():
        assert live[kb] == pair


def test_tpcc_batch_driver(tmp_path):
    """TPC-C batch generation against the columnar store: read-modify-write
    specs carry observed SSNs and commit through the batched pipeline."""
    from repro.db import tpcc

    tab = ArrayTable()
    tpcc.load(tab, warehouses=2)
    eng = _mk_engine(tmp_path, "tpcc", 2)
    occ = BatchOCC(tab, eng, n_workers=2)
    wl = tpcc.TPCC(Table(), warehouses=2, seed=3)  # dict table unused w/ lookup
    specs = wl.next_batch(16, lookup=tab.get_or_insert)
    res = occ.execute_batch(specs, max_rounds=1)
    assert len(res.committed) >= 1
    # all committed specs validated their observed SSNs against live state
    occ.drain()
    eng.quiesce(range(2))


def test_indexed_equals_spec_path(tmp_path):
    """`execute_indexed` (read/write-index arrays, columnar framing from the
    table's key columns) ≡ `execute_batch` (string-keyed specs) on the same
    batches: same winners, tids, SSNs, final state, byte-identical logs."""
    n_records = 100
    tab_a, tab_b = ArrayTable(), ArrayTable()
    ycsb.load(tab_a, n_records)
    ycsb.load(tab_b, n_records)
    eng_a = _mk_engine(tmp_path, "spec", 2)
    eng_b = _mk_engine(tmp_path, "idx", 2)
    occ_a = BatchOCC(tab_a, eng_a, n_workers=4)
    occ_b = BatchOCC(tab_b, eng_b, n_workers=4)
    rng = random.Random(7)
    for it in range(3):
        bsz = 40
        kidx = [rng.randrange(n_records) for _ in range(bsz)]
        vals = [rng.randbytes(rng.randrange(0, 30)) for _ in range(bsz)]
        # every third txn also reads a random row (Qwr routing + flag)
        rd = [[rng.randrange(n_records)] if i % 3 == 0 else [] for i in range(bsz)]
        specs = [
            TxnSpec(reads=[ycsb.key_of(r) for r in rd[i]],
                    writes=[(ycsb.key_of(kidx[i]), vals[i])])
            for i in range(bsz)
        ]
        r_a = occ_a.execute_batch(specs, max_rounds=2)

        rd_row = np.asarray([r for rs in rd for r in rs], dtype=np.int64)
        rd_start = np.zeros(bsz + 1, dtype=np.int64)
        np.cumsum([len(rs) for rs in rd], out=rd_start[1:])
        r_b = occ_b.execute_indexed(
            rd_row, rd_start,
            np.asarray(kidx, dtype=np.int64),
            np.arange(bsz + 1, dtype=np.int64),
            vals, max_rounds=2,
        )
        assert r_a.committed_idx == r_b.committed_idx, it
        assert r_a.aborted == r_b.aborted, it
        for ta, tb in zip(r_a.committed, r_b.committed):
            assert (ta.tid, ta.ssn, ta.worker_id) == (tb.tid, tb.ssn, tb.worker_id)
            assert ta.write_only == tb.write_only
        occ_a.drain()
        occ_b.drain()

    assert tab_a.to_dict() == tab_b.to_dict()
    eng_a.quiesce(range(4))
    eng_b.quiesce(range(4))
    for d in eng_a.devices + eng_b.devices:
        d.close()
    assert [d.read_all() for d in eng_a.devices] == [
        d.read_all() for d in eng_b.devices
    ]


def test_encode_batch_matches_scalar_encode():
    """encode_batch is byte-identical to per-record Txn.encode."""
    rng = random.Random(9)
    txns = []
    for i in range(20):
        t = Txn(
            tid=i + 1,
            read_set=[("r", 0)] if i % 3 == 0 else [],
            write_set=[
                (f"key{j}", rng.randbytes(rng.randrange(0, 30)))
                for j in range(rng.randrange(0, 4))
            ],
        )
        t.ssn = 100 + i
        txns.append(t)
    blob, lengths = encode_batch(txns)
    scalar = [t.encode() for t in txns]
    assert blob == b"".join(scalar)
    assert lengths.tolist() == [len(r) for r in scalar]


def test_concat_ranges():
    starts = np.array([0, 5, 9], dtype=np.int64)
    lens = np.array([2, 0, 3], dtype=np.int64)
    assert _concat_ranges(starts, lens).tolist() == [0, 1, 9, 10, 11]
    assert _concat_ranges(starts[:0], lens[:0]).tolist() == []


def test_reserve_batch_matches_serial_reserve():
    """One reserve_batch == N serial reserves: same SSN chain, same offsets,
    same final buffer state (Algorithm 1 equivalence at the buffer level)."""
    from repro.core.log_buffer import LogBuffer

    rng = random.Random(11)
    a = LogBuffer(0, capacity=1 << 20)
    b = LogBuffer(0, capacity=1 << 20)
    a.ssn = b.ssn = 7
    bases = np.array([rng.randrange(0, 30) for _ in range(50)], dtype=np.int64)
    lengths = np.array([rng.randrange(29, 200) for _ in range(50)], dtype=np.int64)
    ssns, offsets, _ = a.reserve_batch(bases, lengths)
    serial = [b.reserve(int(bs), int(ln))[:2] for bs, ln in zip(bases, lengths)]
    assert ssns.tolist() == [s for s, _ in serial]
    assert offsets.tolist() == [o for _, o in serial]
    assert (a.ssn, a.offset) == (b.ssn, b.offset)
