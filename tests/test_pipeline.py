"""GPipe-over-pod-axis: pipeline output must equal sequential execution.
Runs in a subprocess with forced host devices (main process keeps 1)."""

import json
import os
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import sys, json
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp
    from repro.launch.mesh import mesh_context
    import numpy as np
    from repro.parallel.pipeline import gpipe_apply, sequential_reference

    kw = ({"axis_types": (jax.sharding.AxisType.Auto,)}
          if hasattr(jax.sharding, "AxisType") else {})
    mesh = jax.make_mesh((4,), ("pod",), **kw)
    S, M, MB, D = 4, 6, 3, 8
    rng = np.random.default_rng(0)
    params = {"w": jnp.asarray(rng.normal(0, 0.5, (S, D, D)), jnp.float32)}
    xs = jnp.asarray(rng.normal(0, 1, (M, MB, D)), jnp.float32)

    def stage_fn(p, x):
        return jnp.tanh(x @ p["w"])

    with mesh_context(mesh):
        out = jax.jit(lambda p, x: gpipe_apply(stage_fn, p, x, mesh))(params, xs)
    ref = sequential_reference(stage_fn, params, xs)
    err = float(jnp.max(jnp.abs(out - ref)))
    # the lowered HLO must contain the expected collective-permutes
    with mesh_context(mesh):
        hlo = jax.jit(lambda p, x: gpipe_apply(stage_fn, p, x, mesh)).lower(params, xs).compile().as_text()
    n_cp = hlo.count("collective-permute(")
    print(json.dumps({"err": err, "n_cp": n_cp}))
    assert err < 1e-5, err
""")


def test_gpipe_matches_sequential(tmp_path):
    script = tmp_path / "pipe_test.py"
    script.write_text(SCRIPT)
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, str(script)], capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))), env=env,
        timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["err"] < 1e-5
    assert res["n_cp"] >= 1
