"""Crash injection under open-loop serving load.

The serving tier's ack contract is: an acknowledged transaction is durable
and committable, so it must survive any crash *after* the ack — including a
mid-flush kill that leaves a torn frame at the device tail (the same
physical injection as test_crash_injection.py).  The acked set at kill time
is therefore an exact recovery oracle:

* every ACKED ticket's write is present in the recovered state;
* no torn-tail or never-flushed ("lost tail") value is ever recovered;
* un-acked work may or may not have reached the device — no constraint,
  which is precisely why the ack gate, not execution, is the contract.

One threaded run (real clocks, Poisson open-loop arrivals, kill mid-stream)
and one deterministic stepped sharded run (partial per-shard flushing, cut
off mid-drain) — single-shard and cross-shard transactions both covered.
"""

import os
import time

from repro.core import EngineConfig, Txn, recover
from repro.db.batch import TxnSpec
from repro.db.ycsb import key_of
from repro.serve import (
    ACKED,
    GroupCommitScheduler,
    ServeConfig,
    ShardedBackend,
    SingleBackend,
)
from repro.shard import recover_sharded


def _torn_record(key: str, cut: int = 7) -> bytes:
    t = Txn(tid=777777, write_set=[(key, b"TORN-VALUE-NEVER-COMMITTED")])
    t.ssn = 1 << 40  # would win every last-writer-wins race if replayed
    rec = t.encode()
    assert cut < len(rec)
    return rec[:-cut]


def test_open_loop_kill_torn_tail(tmp_path):
    cfg = EngineConfig(n_buffers=2, device_kind="ssd",
                       device_dir=str(tmp_path), device_clock="real",
                       flush_interval=1e-3, logger_poll=1e-4)
    be = SingleBackend.make("vectorized", n_workers=2, cfg=cfg)
    sched = GroupCommitScheduler(
        be, ServeConfig(latency_budget_s=5e-4, queue_capacity=10**6)
    )
    sched.start()
    tickets = []
    try:
        # open-loop: submit at a steady offered rate, never awaiting acks,
        # then kill mid-stream — later submissions are still in flight
        for i in range(120):
            tickets.append(sched.submit(
                TxnSpec(writes=[(key_of(2000 + i), b"val-%d" % i)]),
                client_id=i,
            ))
            time.sleep(2e-4)
    finally:
        sched.stop(quiesce=False)   # kill: no final flush, no final drain

    acked = [t for t in tickets if t.status == ACKED]
    unacked = [t for t in tickets if t.status != ACKED]
    assert acked, "no transaction acked before the kill"

    # writes buffered after the kill are never flushed (the crash tail)
    be.occ.execute_batch(
        [TxnSpec(writes=[(key_of(9000 + i), b"lost-%d" % i)]) for i in range(4)]
    )
    for d in be.engine.devices:
        d.close()

    # mid-flush kill: a partial frame lands at the end of device 0
    with open(os.path.join(str(tmp_path), "log_0.bin"), "ab") as f:
        f.write(_torn_record(key_of(2000)))
        f.flush()
        os.fsync(f.fileno())

    state = recover(be.engine.devices, parallel=False)
    for v, _ in state.data.values():
        assert v != b"TORN-VALUE-NEVER-COMMITTED"
        assert not v.startswith(b"lost-")
    # acked-prefix oracle: every acked write survives, exactly (keys are
    # written once, so value and SSN must match the ticket)
    for t in acked:
        k, v = t.spec.writes[0]
        assert state.data[k.encode()] == (v, t.ssn), k
    # recovered un-acked writes are uncorrupted (prefix property: whatever
    # of the tail did reach the device is the real record)
    for t in unacked:
        k, v = t.spec.writes[0]
        got = state.data.get(k.encode())
        assert got is None or got[0] == v


def test_stepped_sharded_kill_torn_tail(tmp_path):
    be = ShardedBackend.make(n_shards=2, n_buffers=1, n_workers=2,
                             device_kind="ssd", device_dir=str(tmp_path))
    sched = GroupCommitScheduler(
        be, ServeConfig(max_batch=4, latency_budget_steps=1)
    )
    keys = [key_of(3000 + i) for i in range(30)]
    by_shard = [[k for k in keys if be.eng.shard_of(k) == s] for s in (0, 1)]
    tickets = [sched.submit(TxnSpec(writes=[(k, b"s-" + k.encode())]))
               for k in keys]
    # cross-shard transaction on fresh keys (one per shard, written nowhere
    # else, so the acked/un-acked oracle stays exact per key)
    xk = [next(k for k in (key_of(4000 + i) for i in range(40))
               if be.eng.shard_of(k) == s) for s in (0, 1)]
    cross = sched.submit(TxnSpec(writes=[(xk[0], b"x0"), (xk[1], b"x1")]))
    tickets.append(cross)
    # a few full steps, then steps that flush only shard 0 — shard 1's tail
    # stays volatile — then stop mid-drain (no quiesce: this is the crash)
    for _ in range(4):
        sched.step()
    for _ in range(3):
        sched.step(tick_parts=[0])
    acked = [t for t in tickets if t.status == ACKED]
    unacked = [t for t in tickets if t.status != ACKED]
    assert acked and unacked, "want a genuine mid-drain kill"

    for devs in be.eng.devices:
        for d in devs:
            d.close()
    with open(os.path.join(str(tmp_path), "shard1", "log_0.bin"), "ab") as f:
        f.write(_torn_record(by_shard[1][0]))
        f.flush()
        os.fsync(f.fileno())

    st = recover_sharded(be.eng.devices, parallel=False)
    for v, _ in st.data.values():
        assert v != b"TORN-VALUE-NEVER-COMMITTED"
    for t in acked:
        for k, v in t.spec.writes:
            assert st.data[k.encode()][0] == v, k
    for t in unacked:
        for k, v in t.spec.writes:
            got = st.data.get(k.encode())
            assert got is None or got[0] == v
