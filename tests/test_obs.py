"""Metrics registry semantics: sketch accuracy, the disarmed zero-cost
contract, armed-overhead bounds, and the health monitors.

The load-bearing guarantees pinned here (mirroring ``test_trace.py``):

* the disarmed registry allocates nothing — a tight serve loop with
  ``REGISTRY.enabled == False`` must not allocate a single block in
  ``obs/metrics.py`` (tracemalloc-filtered);
* armed overhead on the fig5-style batch loop stays under 3%, measured
  with the alternating-window max estimator (host noise only ever
  deflates a window);
* sketch quantiles stay within the log2 bucket bound (a factor of 2 of
  ``numpy.percentile``) while count/sum/min/max are exact.
"""

import math
import time
import tracemalloc

import numpy as np
import pytest

from repro.core import EngineConfig, PoplarEngine
from repro.db import ArrayTable, BatchOCC, TxnSpec
from repro.db.ycsb import key_of
from repro.obs import REGISTRY, QuantileSketch, disable, enable
from repro.obs.health import (
    CRIT,
    WARN,
    HealthMonitor,
    ReplicaLagMonitor,
    SaturationMonitor,
    TruncationStallMonitor,
)
from repro.serve import GroupCommitScheduler, ServeConfig, SingleBackend


@pytest.fixture(autouse=True)
def _disarm():
    """Every test leaves the process registry disarmed and empty."""
    yield
    REGISTRY.enabled = False
    REGISTRY.reset()


# --- quantile sketch ----------------------------------------------------------

def test_sketch_exact_moments():
    sk = QuantileSketch()
    vals = [0.5, 2.0, 2.0, 8.0, 0.125]
    for v in vals:
        sk.record(v)
    assert sk.count == len(vals)
    assert sk.total == pytest.approx(sum(vals))
    assert sk.vmin == min(vals) and sk.vmax == max(vals)
    assert sk.mean() == pytest.approx(sum(vals) / len(vals))


def test_sketch_quantiles_within_bucket_bound():
    """p50/p90/p99 within the factor-of-2 log2-bucket guarantee of the true
    sample percentiles, across magnitudes from microseconds to seconds."""
    rng = np.random.default_rng(42)
    vals = rng.lognormal(mean=-7.0, sigma=2.0, size=20_000)  # ~1us .. ~1s
    sk = QuantileSketch()
    sk.record_many(vals)
    for q in (0.50, 0.90, 0.99):
        truth = float(np.percentile(vals, 100 * q))
        got = sk.quantile(q)
        assert 0.5 * truth <= got <= 2.0 * truth, (q, truth, got)
    # extreme quantiles clamp to the exact observed range
    assert sk.quantile(0.0) >= float(vals.min())
    assert sk.quantile(1.0) == pytest.approx(float(vals.max()))


def test_sketch_record_many_equals_looped_record():
    rng = np.random.default_rng(7)
    vals = np.concatenate([
        rng.lognormal(size=500), [0.0, -1.0, 1e-30, 1e30]])
    a, b = QuantileSketch(), QuantileSketch()
    for v in vals:
        a.record(float(v))
    b.record_many(vals)
    assert a.counts.tolist() == b.counts.tolist()
    assert a.count == b.count
    assert a.total == pytest.approx(b.total)
    assert (a.vmin, a.vmax) == (b.vmin, b.vmax)


def test_sketch_empty_and_reset():
    sk = QuantileSketch()
    assert sk.quantile(0.5) == 0.0 and sk.summary() == {"count": 0}
    sk.record(3.0)
    sk.reset()
    assert sk.count == 0 and sk.summary() == {"count": 0}


# --- registry ----------------------------------------------------------------

def test_registry_counters_gauges_sketches_snapshot():
    enable()
    REGISTRY.count("c.a")
    REGISTRY.count("c.a", 4)
    REGISTRY.gauge_set("g.x", 0.5)
    REGISTRY.gauge_max("g.x", 0.25)       # lower: no change
    REGISTRY.gauge_max("g.y", 2.0)
    REGISTRY.observe("s.lat", 0.010)
    REGISTRY.observe_many("s.lat", [0.020, 0.040])
    snap = disable()
    assert snap["counters"]["c.a"] == 5
    assert snap["gauges"]["g.x"] == 0.5 and snap["gauges"]["g.y"] == 2.0
    assert snap["sketches"]["s.lat"]["count"] == 3
    assert snap["sketches"]["s.lat"]["min"] == pytest.approx(0.010)
    # deterministic ordering
    assert list(snap["counters"]) == sorted(snap["counters"])


def test_registry_callback_gauges_are_snapshot_sampled_and_guarded():
    enable()
    REGISTRY.register_callback("cb.good", lambda: 7.0)
    REGISTRY.register_callback("cb.bad", lambda: 1 / 0)
    snap = REGISTRY.snapshot()
    assert snap["gauges"]["cb.good"] == 7.0
    assert "callback error" in snap["gauges"]["cb.bad"]
    REGISTRY.unregister_callback("cb.good")
    REGISTRY.unregister_callback("cb.bad")
    assert "cb.good" not in REGISTRY.snapshot()["gauges"]


# --- disarmed zero-cost contract ---------------------------------------------

def _stepped_sched(tmp_path, sub="a"):
    cfg = EngineConfig(n_buffers=2, device_kind="null",
                       device_dir=str(tmp_path / sub))
    backend = SingleBackend.make("vectorized", n_workers=2, cfg=cfg)
    return GroupCommitScheduler(
        backend, ServeConfig(max_batch=16, latency_budget_steps=1)
    )


def test_disarmed_registry_allocates_nothing(tmp_path):
    """tracemalloc filtered to obs/metrics.py: a tight submit+step loop with
    the registry disarmed must not allocate a single block in the metrics
    module (every hook reduces to one attribute load + a false branch)."""
    sched = _stepped_sched(tmp_path)
    for i in range(32):
        sched.submit(TxnSpec(writes=[(key_of(i), b"w")]))
    sched.step()  # warm up every code path before measuring

    assert not REGISTRY.enabled
    flt = tracemalloc.Filter(True, "*obs/metrics.py")
    tracemalloc.start()
    try:
        for i in range(32, 160):
            sched.submit(TxnSpec(writes=[(key_of(i), b"w")]))
            sched.step()
        snap = tracemalloc.take_snapshot().filter_traces([flt])
    finally:
        tracemalloc.stop()
    assert sum(s.size for s in snap.statistics("filename")) == 0


def test_disarmed_registry_records_nothing(tmp_path):
    sched = _stepped_sched(tmp_path)
    for i in range(8):
        sched.submit(TxnSpec(writes=[(key_of(i), b"w")]))
    sched.run_until_drained()
    snap = REGISTRY.snapshot()
    assert not snap["counters"] and not snap["gauges"] and not snap["sketches"]


# --- armed coverage across the layers ----------------------------------------

def test_armed_serve_run_populates_every_layer(tmp_path):
    enable()
    try:
        sched = _stepped_sched(tmp_path)
        for i in range(64):
            sched.submit(TxnSpec(writes=[(key_of(i % 40), bytes([i % 251]))]))
            if i % 4 == 3:
                sched.step()
        sched.run_until_drained()
    finally:
        snap = disable()
    c, g, s = snap["counters"], snap["gauges"], snap["sketches"]
    assert c["serve.cut_txns"] >= 64
    assert c["serve.acked"] >= 1
    assert c["occ.validate.wins"] >= 64
    assert c["engine.flush_txns.d0"] + c["engine.flush_txns.d1"] > 0
    assert c["engine.flush_bytes.d0"] > 0
    assert "serve.queue_depth" in g
    assert "engine.buffer_occupancy.d0" in g
    assert s["serve.ack_latency"]["count"] >= 1


# --- armed overhead on the fig5-style batch loop ------------------------------

def _overhead_trial(tmp_path, sub, adaptive=False):
    """One armed-vs-disarmed overhead estimate on a live BatchOCC loop.

    Per-batch wall times with the registry alternately off/on on the same
    engine + prebuilt specs; the MIN batch per arm is the robust estimator
    (host noise — GIL quanta, steal time — only ever *inflates* a batch,
    while the instrumentation cost, if any, is deterministic per batch).

    ``adaptive=True`` runs the command-framing RMW shape instead (an
    ``AdaptivePolicy`` on the executor, specs carrying op + params), so the
    bound also covers the adaptive encode path's instrumentation."""
    from repro.core.command import OP_PATCH_PREFIX
    from repro.core.engine import AdaptivePolicy

    d = tmp_path / sub
    d.mkdir()
    eng = PoplarEngine(EngineConfig(n_buffers=2, device_kind="null",
                                    device_dir=str(d), flush_interval=60.0))
    table = ArrayTable()
    keys = [key_of(i) for i in range(2048)]
    for k in keys:
        table.insert(k, b"seed")
    occ = BatchOCC(table, eng, n_workers=2,
                   policy=AdaptivePolicy() if adaptive else None)

    def _batches():
        if not adaptive:
            return [
                [TxnSpec(writes=[(keys[(b * 256 + i) % len(keys)], b"v")])
                 for i in range(256)]
                for b in range(8)
            ]
        # RMW shape: fresh observed SSNs per rep (keys disjoint per batch,
        # so a whole rep validates conflict-free); first warm-up rep sees
        # dep SSN 0 (no checkpoint) and value-frames — the hatch itself
        out = []
        for b in range(8):
            sp = []
            for i in range(256):
                k = keys[(b * 256 + i) % len(keys)]
                v, s = table.get(k)
                sp.append(TxnSpec(
                    reads=[k], writes=[(k, b"nu" + v[2:])], observed=[s],
                    cmd_op=OP_PATCH_PREFIX, cmd_params=[b"nu"],
                ))
            out.append(sp)
        return out

    eng.start()
    try:
        for sp in _batches():              # warm-up: jit compiles, allocs
            occ.execute_batch(sp, max_rounds=2)
            occ.drain()
        off, on = [], []
        for rep in range(8):
            armed = rep % 2 == 1
            batches = _batches()           # rebuilt outside the timed region
            if armed:
                enable(reset=False)
            else:
                REGISTRY.enabled = False
            for sp in batches:
                t0 = time.perf_counter()
                occ.execute_batch(sp, max_rounds=2)
                occ.drain()
                (on if armed else off).append(time.perf_counter() - t0)
        REGISTRY.enabled = False
    finally:
        eng.stop()
    return min(on) / min(off) - 1.0


@pytest.mark.parametrize("flavor", ["value", "adaptive"])
def test_armed_overhead_under_3pct(tmp_path, flavor):
    """The fig5-style batch loop pays < 3% for an armed registry — on both
    the plain write-only shape and the adaptive command-framing RMW shape.
    The shared bench box swings batch times several-fold, so one estimate
    can read high on pure noise: up to 6 independent trials, passing on the
    first clean one — a *real* >3% regression is deterministic per batch
    and fails every trial."""
    adaptive = flavor == "adaptive"
    best = math.inf
    for trial in range(6):
        best = min(best, _overhead_trial(tmp_path, f"ov{trial}",
                                         adaptive=adaptive))
        if best < 0.03:
            break
    assert best < 0.03, f"armed registry overhead {best:.1%} (all trials)"
    # and the armed windows actually measured something
    assert REGISTRY.counter_value("occ.validate.wins") > 0
    if adaptive:
        assert REGISTRY.counter_value("adaptive.policy.command") > 0


def test_armed_adaptive_run_populates_metrics(tmp_path):
    """An armed adaptive encode + recover round populates every adaptive
    counter family: framing byte split, policy decisions, replay command
    stats."""
    from repro.core import recover
    from repro.core.command import OP_PATCH_PREFIX
    from repro.core.engine import AdaptivePolicy

    eng = PoplarEngine(EngineConfig(
        n_buffers=2, device_kind="ssd", device_dir=str(tmp_path / "devs"),
        device_clock="virtual",
    ))
    table = ArrayTable()
    keys = [key_of(i) for i in range(16)]
    occ = BatchOCC(table, eng, policy=AdaptivePolicy())
    enable()
    try:
        # logged base versions, then an RMW round the policy command-frames
        occ.execute_batch([TxnSpec(writes=[(k, b"0" * 16)]) for k in keys])
        specs = []
        for k in keys:
            v, s = table.get(k)
            specs.append(TxnSpec(
                reads=[k], writes=[(k, b"XY" + v[2:])], observed=[s],
                cmd_op=OP_PATCH_PREFIX, cmd_params=[b"XY"],
            ))
        # one unregistered op rides along: the forced-value hatch counter
        v, s = table.get(keys[0])
        occ.execute_batch(specs[1:] + [TxnSpec(
            reads=[keys[0]], writes=[(keys[0], b"ZZ" + v[2:])],
            observed=[s], cmd_op=999, cmd_params=[b"ZZ"],
        )])
        for i in range(len(eng.buffers)):
            eng.logger_tick(i, force=True)
        st = recover(eng.devices, parallel=False)
    finally:
        snap = disable()
    assert st.data[keys[1].encode()][0] == b"XY" + b"0" * 14
    c, g = snap["counters"], snap["gauges"]
    assert c["adaptive.policy.command"] >= len(keys) - 1
    assert c["adaptive.policy.value"] > 0           # the blind base writes
    assert c["adaptive.policy.forced_value"] >= 1   # the op-999 spec
    assert c["adaptive.log_bytes_command"] > 0
    assert c["adaptive.log_bytes_value"] > 0
    assert c["adaptive.replay.commands"] >= len(keys) - 1
    assert g["adaptive.replay.cmd_depth"] >= 1


# --- health monitors ----------------------------------------------------------

class _FakeReplica:
    def __init__(self, frontier=100, visible=90, backlog=0, stalled_s=0.0):
        self._frontier = frontier
        self._visible = visible
        self._backlog = backlog
        self._w_advance_t = time.monotonic() - stalled_s

    def shipped_frontiers(self):
        return [self._frontier]

    def visible_ssn(self):
        return self._visible

    def lag_bytes(self):
        return self._backlog


class _FakeRegistry:
    def frontiers(self):
        return {"ckpt": 5}


class _FakeTruncator:
    def __init__(self, pin=0):
        self.pin = pin
        self.registry = _FakeRegistry()

    def stall_ssn(self):
        return self.pin


class _FakeBackend:
    def __init__(self, sat=False):
        self._sat = sat

    def saturated(self):
        return self._sat

    def queue_depths(self):
        return [3, 4]


class _FakeScheduler:
    def __init__(self):
        self.n_rejected = 0
        self.backend = _FakeBackend()


def test_replica_lag_monitor_thresholds():
    m = ReplicaLagMonitor(_FakeReplica(frontier=100, visible=90),
                          max_lag_ssn=5, max_lag_s=None)
    evs = m.check()
    assert len(evs) == 1 and evs[0].severity == CRIT
    assert evs[0].kind == "replica_lag" and evs[0].value == 10.0
    # within SLO: silent
    assert not ReplicaLagMonitor(
        _FakeReplica(frontier=100, visible=98), max_lag_ssn=5).check()
    # stalled watermark + backlog are WARNs
    m2 = ReplicaLagMonitor(
        _FakeReplica(frontier=5, visible=5, backlog=1 << 20, stalled_s=10.0),
        max_lag_s=1.0, max_backlog_bytes=1024)
    kinds = [(e.severity, e.kind) for e in m2.check()]
    assert kinds == [(WARN, "replica_lag"), (WARN, "replica_lag")]


def test_truncation_stall_monitor_requires_sustained_pin():
    tr = _FakeTruncator(pin=7)
    m = TruncationStallMonitor(tr, sustain=2)
    assert m.check() == []            # first sighting: not yet a stall
    evs = m.check()                   # second consecutive: CRIT
    assert len(evs) == 1 and evs[0].severity == CRIT
    assert "ckpt" in evs[0].message
    tr.pin = 0
    assert m.check() == []            # pin released: streak resets
    tr.pin = 7
    assert m.check() == []


def test_saturation_monitor_sustained_rejects():
    sched = _FakeScheduler()
    m = SaturationMonitor(sched, sustain=2)
    assert m.check() == []            # no rejects
    sched.n_rejected = 3
    assert m.check() == []            # first rejecting window
    sched.n_rejected = 9
    evs = m.check()                   # second consecutive: CRIT
    assert len(evs) == 1 and evs[0].severity == CRIT
    sched.backend._sat = True
    sched.n_rejected = 9              # delta 0: streak resets, but WARN fires
    evs = m.check()
    assert [e.severity for e in evs] == [WARN]


def test_health_monitor_aggregates_and_mirrors_counters():
    events = []
    hm = HealthMonitor(
        [TruncationStallMonitor(_FakeTruncator(pin=3), sustain=1)],
        on_event=events.append,
    )
    enable()
    try:
        evs = hm.poll()
    finally:
        REGISTRY.enabled = False
    assert len(evs) == 1 and events == evs
    assert list(hm.history) == evs
    assert REGISTRY.counter_value("health.events.truncation_stall") == 1
    assert evs[0].to_dict()["kind"] == "truncation_stall"


def test_health_monitor_threaded_start_stop():
    hm = HealthMonitor(
        [TruncationStallMonitor(_FakeTruncator(pin=1), sustain=1)])
    hm.start(poll_interval=1e-3)
    deadline = time.monotonic() + 5.0
    while hm.n_polls < 3 and time.monotonic() < deadline:
        time.sleep(1e-3)
    hm.stop()
    assert hm.n_polls >= 3
    assert any(e.kind == "truncation_stall" for e in hm.history)
