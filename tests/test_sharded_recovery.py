"""Sharded recovery: per-shard vectorized replay + the cross-shard cut.

* a cross-shard transaction durable on *all* participants is replayed; one
  missing any participant's record is dropped on every shard (all-or-
  nothing — §3.1's recoverability argument applied per dependency edge);
* crash-at-arbitrary-point property: every acknowledged transaction's
  writes survive replay, cross-shard replay is atomic, and the recovered
  state of a quiesced run equals both the live sharded state and a
  single-shard oracle run of the same schedule;
* ``mode="vectorized"``, ``"pallas"`` and ``"scalar"`` agree record-for-
  record on randomized crash logs.
"""

import random
from typing import List

from repro.core import EngineConfig, PoplarEngine
from repro.db import ArrayTable, BatchOCC, TxnSpec
from repro.shard import ShardedConfig, ShardedEngine, recover_sharded


def _mk(tmp_path=None, **kw) -> ShardedEngine:
    cfg = dict(n_shards=2, n_buffers=1, n_workers=2, device_kind="ssd",
               device_clock="virtual")
    cfg.update(kw)
    if tmp_path is not None:
        cfg["device_dir"] = str(tmp_path)
    return ShardedEngine(ShardedConfig(**cfg))


def _keys_by_shard(eng: ShardedEngine, n: int) -> List[List[str]]:
    out: List[List[str]] = [[] for _ in range(eng.cfg.n_shards)]
    for i in range(n):
        k = f"user{i:010d}"
        out[eng.shard_of(k)].append(k)
    return out


def test_cut_keeps_fully_durable_cross_shard():
    eng = _mk()
    ks = _keys_by_shard(eng, 40)
    res = eng.execute_batch(
        [TxnSpec(writes=[(ks[0][0], b"X0"), (ks[1][0], b"X1")])]
    )
    xt = res.cross[0]
    eng.tick(force=True)  # durable on both shards; never swept/acknowledged
    st = recover_sharded(eng.devices, parallel=False)
    # write-only + durable everywhere == committed by the generalized Qww
    # rule, so replay keeps it even though no ack was ever delivered
    assert st.n_cross_seen == 1 and st.n_cross_dropped == 0
    assert st.data[ks[0][0].encode()] == (b"X0", xt.parts[0].ssn)
    assert st.data[ks[1][0].encode()] == (b"X1", xt.parts[1].ssn)


def test_cut_drops_partially_durable_cross_shard():
    eng = _mk()
    ks = _keys_by_shard(eng, 40)
    eng.insert(ks[0][0], b"old0")
    eng.insert(ks[1][0], b"old1")
    # a committed single-shard write on shard 0 rides along
    r0 = eng.execute_batch([TxnSpec(writes=[(ks[0][1], b"solo")])])
    res = eng.execute_batch(
        [TxnSpec(writes=[(ks[0][0], b"X0"), (ks[1][0], b"X1")])]
    )
    assert len(res.cross) == 1
    # crash with only shard 0 flushed: the cross record is torn on shard 1
    for i in range(len(eng.shards[0].engine.buffers)):
        eng.shards[0].engine.logger_tick(i, force=True)
    eng.drain()
    assert r0.committed[0].committed and not res.cross[0].committed
    for mode in ("vectorized", "scalar"):
        st = recover_sharded(eng.devices, parallel=False, mode=mode)
        assert st.n_cross_seen == 1 and st.n_cross_dropped == 1, mode
        # all-or-nothing: neither shard reflects the dropped transaction,
        # the committed rider survives
        assert ks[0][0].encode() not in st.data or (
            st.data[ks[0][0].encode()][0] == b"old0"
        )
        assert st.data.get(ks[1][0].encode(), (b"old1", 0))[0] == b"old1"
        assert st.data[ks[0][1].encode()][0] == b"solo"


def test_raw_carrying_cross_shard_needs_rsne_on_every_shard():
    """A cross-shard txn *with reads* whose record is durable everywhere
    but past one shard's RSNe frontier is dropped (the generalized Qwr
    rule evaluated at recovery)."""
    eng = _mk(n_buffers=2)
    ks = _keys_by_shard(eng, 40)
    eng.insert(ks[0][0], b"old0")
    res = eng.execute_batch(
        [TxnSpec(reads=[ks[1][0]], writes=[(ks[0][0], b"X0"), (ks[1][1], b"X1")])]
    )
    xt = res.cross[0]
    # flush only the buffers holding the records: the sibling buffer on
    # each shard stays behind, pinning that shard's RSNe below the record
    for part in xt.parts:
        sh = eng.shards[part.shard]
        sh.engine.buffers[part.buffer_id].force_establish()
        sh.engine.buffers[part.buffer_id].flush_ready(sh.engine.devices[part.buffer_id])
    st = recover_sharded(eng.devices, parallel=False)
    assert st.n_cross_seen == 1 and st.n_cross_dropped == 1
    assert st.data.get(ks[0][0].encode(), (b"old0", 0))[0] == b"old0"
    # after full flush the same logs keep it
    eng.tick(force=True)
    st2 = recover_sharded(eng.devices, parallel=False)
    assert st2.n_cross_dropped == 0
    assert st2.data[ks[0][0].encode()] == (b"X0", xt.parts[0].ssn)


# --- crash-at-arbitrary-point property ---------------------------------------

def _random_batches(rng, keys, n_batches):
    """Batches with unique keys *within* each batch (no intra-batch
    conflicts); across batches keys repeat, so pending cross-shard locks
    legitimately abort later writers."""
    out = []
    for _ in range(n_batches):
        ks = rng.sample(keys, rng.randrange(4, min(12, len(keys))))
        specs = []
        while ks:
            nw = rng.choice([1, 1, 2])  # 2-key specs may span shards
            grp, ks = ks[:nw], ks[nw:]
            reads = [grp[0]] if rng.random() < 0.3 else []
            specs.append(TxnSpec(
                reads=reads,
                writes=[(k, f"{k}@{rng.randrange(1 << 20)}".encode())
                        for k in grp],
            ))
        out.append(specs)
    return out


def test_sharded_crash_recovery_property(tmp_path):
    for seed in range(4):
        rng = random.Random(100 + seed)
        n_shards = rng.choice([2, 3])
        eng = _mk(tmp_path / f"s{seed}", n_shards=n_shards,
                  n_buffers=rng.choice([1, 2]))
        oracle_tab = ArrayTable()
        oracle_eng = PoplarEngine(EngineConfig(n_buffers=1, device_kind="null"))
        oracle = BatchOCC(oracle_tab, oracle_eng, n_workers=2)

        keys = [f"user{i:010d}" for i in range(16)]
        for k in keys[:8]:
            eng.insert(k, b"init")
            oracle_tab.insert(k, b"init")

        batches = _random_batches(rng, keys, 5)
        crash_after = rng.randrange(0, len(batches) + 1)
        acked: List = []       # (obj, kind) acknowledged before the crash
        for bi, specs in enumerate(batches):
            res = eng.execute_batch(specs)
            # the oracle replays exactly the sharded run's winners (losers
            # aborted against pending cross-shard locks and touched nothing);
            # keys are unique within a batch, so intra-batch order is free
            winners = sorted(res.committed_idx + res.cross_idx)
            ro = oracle.execute_batch([specs[i] for i in winners])
            assert not ro.aborted, (seed, bi)
            if bi < crash_after:
                eng.tick(force=True)
                eng.tick(force=True)   # heartbeat round for lagging buffers
                eng.drain()
                acked += [(t, "s") for t in res.committed if t.committed]
                acked += [(x, "x") for x in res.cross if x.committed]
            # else: volatile tail — never flushed before the crash
        oracle_eng.quiesce(range(2))

        # crash: whatever the devices hold is the durable image
        st = recover_sharded(eng.devices, parallel=False)
        st_scalar = recover_sharded(eng.devices, parallel=False, mode="scalar")
        st_pallas = recover_sharded(eng.devices, parallel=False, mode="pallas")
        data = st.data
        assert data == st_scalar.data, seed
        assert data == st_pallas.data, seed
        for a, b in zip(st.shards, st_scalar.shards):
            assert (a.rsne, a.data) == (b.rsne, b.data), seed

        # I1: every acknowledged txn's writes survive with ssn >= its own
        for obj, kind in acked:
            if kind == "s":
                for k, v in obj.write_set:
                    got = data.get(k.encode())
                    assert got is not None and got[1] >= obj.ssn, (seed, k)
                    if got[1] == obj.ssn:
                        assert got[0] == v, (seed, k)
            else:
                for part in obj.parts:
                    tab = eng.shards[part.shard].table
                    for r, v in zip(part.wr_rows.tolist(), part.wr_vals):
                        got = data.get(tab.key_of(r).encode())
                        assert got is not None and got[1] >= part.ssn, (seed, r)
                        if got[1] == part.ssn:
                            assert got[0] == v, (seed, r)

        # full-quiesce equivalence: flush + drain everything, crash, and
        # the recovered image must equal live state AND the oracle run
        eng.quiesce()
        st_full = recover_sharded(eng.devices, parallel=False)
        live = eng.to_dict()
        recovered = st_full.data
        for kb, (v, s) in recovered.items():
            assert live[kb] == (v, s), (seed, kb)
        ovals = {k: v[0] for k, v in oracle_tab.to_dict().items() if v[1] > 0}
        svals = {k: v[0] for k, v in live.items() if v[1] > 0}
        assert svals == ovals, seed
        for devs in eng.devices:
            for d in devs:
                d.close()
