"""Serving-tier scheduler semantics, deterministic and randomized (stepped
mode only — no wall clocks anywhere in this file).

Covers: batch-cut triggers (size / latency budget / head-of-line FIFO), the
ack = durable ∧ committable gate under partial flush interleavings (the
Qww/Qwr watermark rule observed end-to-end through the scheduler), the RAW
commit-order invariant under randomized flush schedules asserted against
Qwr footers in the decoded device logs, lossless-or-explicit admission
control (including the retry-capacity exemption), max_unacked backpressure,
the Zipfian generator, and retry-with-backoff under hot-key skew.
"""

import random
from collections import Counter

import pytest

from repro.core import EngineConfig
from repro.core.txn import decode_records
from repro.db.batch import TxnSpec
from repro.db.ycsb import COL_BYTES, RMWSpecFactory, Zipfian, key_of, load
from repro.serve import (
    ABORTED,
    ACKED,
    INFLIGHT,
    REJECTED,
    GroupCommitScheduler,
    ServeConfig,
    ShardedBackend,
    SingleBackend,
    run_stepped_schedule,
)


def _backend(tmp_path, n_workers=2, n_buffers=1, mode="vectorized",
             device_kind="null"):
    # watermark-gating tests pass device_kind="ssd": the null device is fast
    # enough that drain() self-ticks the logger (fast-device assist), which
    # would flush buffers the test deliberately holds back
    cfg = EngineConfig(n_buffers=n_buffers, device_kind=device_kind,
                       device_dir=str(tmp_path))
    return SingleBackend.make(mode, n_workers=n_workers, cfg=cfg)


def _wspec(i, val=b"v"):
    return TxnSpec(writes=[(key_of(1000 + i), val)])


# --- batch cutting ------------------------------------------------------------

def test_cut_on_max_batch(tmp_path):
    """A full queue cuts immediately, without waiting out the budget."""
    sched = GroupCommitScheduler(
        _backend(tmp_path),
        ServeConfig(max_batch=4, latency_budget_steps=10**6),
    )
    tickets = [sched.submit(_wspec(i)) for i in range(10)]
    sched.step()
    assert sched.n_cuts == 1 and sched.n_cut_txns == 4
    assert [t.status for t in tickets[:4]] == [ACKED] * 4
    assert all(t.status != ACKED for t in tickets[4:])
    sched.step()  # 6 queued >= max_batch: cuts again without budget expiry
    assert sched.n_cuts == 2 and sched.n_cut_txns == 8
    # the final 2 are below max_batch and the budget is effectively infinite:
    # they stay queued until the budget is restored
    sched.step()
    assert sched.stats()["queue_depth"] == 2 and sched.n_cuts == 2
    sched.cfg.latency_budget_steps = 1
    sched.run_until_drained()
    assert all(t.status == ACKED for t in tickets)


def test_cut_on_latency_budget(tmp_path):
    """Below max_batch, the head's wait time triggers the cut."""
    sched = GroupCommitScheduler(
        _backend(tmp_path),
        ServeConfig(max_batch=64, latency_budget_steps=3),
    )
    t = sched.submit(_wspec(0))  # t_submit = step 0
    sched.step()                 # now=1: waited 1 < 3
    sched.step()                 # now=2: waited 2 < 3
    assert t.status != ACKED and sched.n_cuts == 0
    sched.step()                 # now=3: waited 3 >= 3 -> cut
    assert sched.n_cuts == 1 and t.status == ACKED
    assert t.latency() == 3.0    # steps, by construction


def test_cut_head_of_line_fifo(tmp_path):
    """Conflicting transactions split cuts but never reorder: commit and
    ack order equal admission order, per key and globally."""
    sched = GroupCommitScheduler(
        _backend(tmp_path), ServeConfig(max_batch=64, latency_budget_steps=1)
    )
    k1, k2 = key_of(1), key_of(2)
    a = sched.submit(TxnSpec(writes=[(k1, b"a")]))
    b = sched.submit(TxnSpec(writes=[(k1, b"b")]))  # conflicts with a
    c = sched.submit(TxnSpec(writes=[(k2, b"c")]))  # behind b: FIFO holds it
    sched.step()
    # first cut is [a] alone — b conflicts, and c must not jump the queue
    assert sched.n_cut_txns == 1
    assert a.status == ACKED and b.status != ACKED and c.status != ACKED
    sched.run_until_drained()
    assert [a.ack_seq, b.ack_seq, c.ack_seq] == [0, 1, 2]
    # k1's final value is the later admission's write
    got = sched.backend.table.get(k1)
    val = got[0] if isinstance(got, tuple) else got.value
    assert val == b"b"


# --- ack gate: durable AND committable ---------------------------------------

def test_ack_gated_on_watermarks_partial_ticks(tmp_path):
    """With two log buffers and selective flushing, acks wait for the exact
    Qww (own-buffer DSN) / Qwr (CSN = min DSN) watermark conditions."""
    be = _backend(tmp_path, n_workers=2, n_buffers=2, device_kind="ssd")
    sched = GroupCommitScheduler(
        be, ServeConfig(max_batch=8, latency_budget_steps=1)
    )
    k, k2 = key_of(1), key_of(2)
    w = sched.submit(TxnSpec(writes=[(k, b"w")]))          # worker 0 -> buf 0
    r = sched.submit(TxnSpec(reads=[k], writes=[(k2, b"r")]))  # worker 1 -> buf 1
    sched.step(tick_parts=[1])  # cut [w]; only buffer 1 flushes
    sched.step(tick_parts=[1])  # cut [r]; r's record durable in buf 1
    # w's record sits unflushed in buffer 0: w fails Qww (own DSN), and r
    # fails Qwr (CSN = min DSN is pinned by buffer 0) even though its own
    # record is durable
    assert w.status == INFLIGHT and r.status == INFLIGHT
    sched.step()  # full tick: w durable -> acked; CSN still below r's SSN
    assert w.status == ACKED
    assert r.status == INFLIGHT
    sched.step()  # idle buffer 0 heartbeats to the frontier; CSN catches up
    assert r.status == ACKED
    assert w.ack_seq < r.ack_seq


@pytest.mark.parametrize("seed", range(5))
def test_raw_commit_order_randomized(seed, tmp_path):
    """Randomized stepped interleavings: writers write unique keys, readers
    carry RAW dependencies on earlier writers.  Invariants, checked against
    the ack sequence AND the decoded device logs:

    * every admitted transaction acks (liveness under partial flushing);
    * a RAW-dependent reader acks strictly after each of its predecessor
      writers, and its SSN exceeds theirs;
    * its log record carries the Qwr footer (has_reads) — the recovery-time
      witness of the commit-order constraint — and writers carry none.
    """
    rng = random.Random(seed)
    be = _backend(tmp_path, n_workers=2, n_buffers=2, device_kind="ssd")
    sched = GroupCommitScheduler(
        be,
        ServeConfig(max_batch=rng.choice([2, 4, 8]), latency_budget_steps=1,
                    queue_capacity=10**6),
    )
    n = rng.randrange(8, 30)
    schedule, preds, written = [], [], []
    at = 0
    for i in range(n):
        at += rng.randrange(0, 2)
        if written and rng.random() < 0.5:
            picks = rng.sample(written, min(len(written), rng.randrange(1, 3)))
            reads = [k for k, _ in picks]
            preds.append([j for _, j in picks])
        else:
            reads = []
            preds.append([])
        wkey = key_of(1000 + i)
        schedule.append((at, TxnSpec(reads=reads,
                                     writes=[(wkey, b"v%d" % i)])))
        written.append((wkey, i))

    trng = random.Random(seed + 777)
    tickets = run_stepped_schedule(
        sched, schedule,
        tick_parts_fn=lambda step: trng.choice([None, None, [0], [1], []]),
    )
    assert all(t.status == ACKED for t in tickets)

    by_tid = {}
    for dev in be.engine.devices:
        for rec in decode_records(dev.read_all()):
            if rec.tid:  # tid 0 = heartbeat records
                by_tid[rec.tid] = rec
    for i, t in enumerate(tickets):
        rec = by_tid[t.txn.tid]
        assert rec.ssn == t.ssn
        assert rec.has_reads == bool(schedule[i][1].reads)  # Qwr footer
        for p in preds[i]:
            assert tickets[p].ack_seq < t.ack_seq, (i, p)
            assert tickets[p].ssn < t.ssn, (i, p)


# --- admission control: lossless or explicit ---------------------------------

def test_admission_overflow_explicit_reject(tmp_path):
    """Deterministic queue overflow: beyond capacity, submissions are
    refused explicitly at submit time; every *admitted* transaction still
    terminates ACKED.  Statuses exactly partition the submissions — nothing
    is silently dropped."""
    sched = GroupCommitScheduler(
        _backend(tmp_path),
        ServeConfig(max_batch=2, latency_budget_steps=1, queue_capacity=4),
    )
    tickets = [sched.submit(_wspec(i)) for i in range(12)]
    assert [t.status for t in tickets[4:]] == [REJECTED] * 8
    assert sched.n_admitted == 4 and sched.n_rejected == 8
    sched.run_until_drained()
    counts = Counter(t.status for t in tickets)
    assert counts == {ACKED: 4, REJECTED: 8}
    assert sched.n_admitted + sched.n_rejected == sched.n_submitted
    # capacity freed: new submissions are admitted again and complete
    t = sched.submit(_wspec(99))
    assert t.status != REJECTED
    sched.run_until_drained()
    assert t.status == ACKED


def test_retry_is_capacity_exempt(tmp_path):
    """A validation loser must re-enter the queue even when new arrivals
    have filled it to capacity: retries are already-admitted work, so the
    admission bound does not apply to them (re-admitting them through the
    bounded queue would silently drop them exactly under overload).  The
    loser re-enters at the *front* and completes."""
    be = _backend(tmp_path)
    load(be.table, 4, seed=7)
    sched = GroupCommitScheduler(
        be,
        ServeConfig(max_batch=8, latency_budget_steps=1, queue_capacity=2,
                    backoff_steps=1, max_retries=3),
    )
    k = key_of(0)

    def rmw():
        got = be.table.get_or_insert(k)
        val, ssn = got if isinstance(got, tuple) else (got.value, got.ssn)
        return TxnSpec(reads=[k], writes=[(k, val[:8] + b"!")], observed=[ssn])

    t1 = sched.submit(make_spec=rmw)
    t2 = sched.submit(make_spec=rmw)  # same key: observed SSN goes stale
    assert t1.status != REJECTED and t2.status != REJECTED
    sched.step()   # cut [t1] (head-of-line), ack t1
    sched.step()   # cut [t2]: t2's observed SSN is stale -> retry backoff
    assert t1.status == ACKED and sched.n_retries == 1 and t2.attempts == 2
    # flood the queue to capacity while t2 is in backoff
    f1, f2 = sched.submit(_wspec(1)), sched.submit(_wspec(2))
    f3 = sched.submit(_wspec(3))
    assert f1.status != REJECTED and f2.status != REJECTED
    assert f3.status == REJECTED  # capacity enforced for *new* admissions
    sched.run_until_drained()
    # ...but the retry re-entered (front of queue) and acked before the flood
    assert t2.status == ACKED and t2.attempts == 2
    assert t2.ack_seq < f1.ack_seq < f2.ack_seq
    got = be.table.get_or_insert(k)
    val = got[0] if isinstance(got, tuple) else got.value
    assert val[:9].endswith(b"!")


def test_backpressure_max_unacked(tmp_path):
    """Durability-lag backpressure: with flushing stalled, at most
    max_unacked transactions are executed-but-unacked; cutting resumes as
    acks release."""
    sched = GroupCommitScheduler(
        _backend(tmp_path, device_kind="ssd"),
        ServeConfig(max_batch=2, latency_budget_steps=1, max_unacked=2),
    )
    tickets = [sched.submit(_wspec(i)) for i in range(6)]
    for _ in range(5):
        sched.step(tick_parts=[])  # execute but never flush
    st = sched.stats()
    assert st["max_unacked"] == 2        # cutter stalled at the cap
    assert st["queue_depth"] == 4        # the rest stayed queued
    assert all(t.status != ACKED for t in tickets)
    sched.run_until_drained()            # full ticks: drains in waves of <= 2
    assert all(t.status == ACKED for t in tickets)
    assert sched.stats()["max_unacked"] == 2


# --- zipfian ------------------------------------------------------------------

def test_zipfian_distribution():
    z = Zipfian(1000, theta=0.99, seed=3)
    s = z.sample(50_000)
    assert s.min() >= 0 and s.max() < 1000
    freq = Counter(s.tolist())
    # rank 0 is the hottest, by a wide margin over the tail
    assert freq[0] > freq.most_common(20)[-1][1]
    assert freq[0] / len(s) > 0.05                    # heavy head
    assert freq[0] >= freq[1] >= freq[5] > freq[500]  # monotone-ish decay
    # deterministic under the seed
    assert Zipfian(1000, 0.99, seed=3).sample(100).tolist() == \
        Zipfian(1000, 0.99, seed=3).sample(100).tolist()
    # theta=0 degenerates to (near-)uniform
    u = Zipfian(1000, theta=0.0, seed=3).sample(50_000)
    assert Counter(u.tolist()).most_common(1)[0][1] / len(u) < 0.01


def test_retry_with_backoff_under_skew(tmp_path):
    """Zipf-hot read-modify-write clients: losers retry with regenerated
    specs and eventually win; exhausted tickets abort explicitly after
    exactly 1 + max_retries attempts; the final table state equals the net
    effect of exactly the acked transactions (each RMW flips the first
    column's bits, so per-key XOR parity is the oracle)."""
    be = _backend(tmp_path, n_workers=2)
    n_keys = 8
    load(be.table, n_keys, seed=7)
    before = {key_of(i): be.table.get(key_of(i))[0] for i in range(n_keys)}
    fac = RMWSpecFactory(be.table, n_keys, seed=11, theta=0.9)
    sched = GroupCommitScheduler(
        be,
        ServeConfig(max_batch=8, latency_budget_steps=1, max_retries=4,
                    backoff_steps=1, queue_capacity=10**6),
    )
    tickets = [sched.submit(make_spec=fac.spec_fn(), client_id=i)
               for i in range(40)]
    sched.run_until_drained(max_steps=5000)
    assert all(t.status in (ACKED, ABORTED) for t in tickets)
    assert sched.n_retries > 0  # skew actually produced conflicts
    for t in tickets:
        if t.status == ABORTED:
            assert t.attempts == 1 + sched.cfg.max_retries
    acked_per_key = Counter(t.spec.writes[0][0] for t in tickets
                            if t.status == ACKED)
    for i in range(n_keys):
        k = key_of(i)
        head = before[k][:COL_BYTES]
        if acked_per_key[k] % 2:
            head = bytes(b ^ 0xFF for b in head)
        assert be.table.get(k)[0][:COL_BYTES] == head, k


# --- sharded serving ----------------------------------------------------------

def test_sharded_serving_with_cross_shard(tmp_path):
    """The scheduler over a ShardedBackend: single-shard and cross-shard
    transactions interleave; cross-shard acks release only after the
    coordinator's durable-on-all sweep marks them committed."""
    be = ShardedBackend.make(n_shards=2, n_buffers=1, n_workers=2,
                             device_kind="null", device_dir=str(tmp_path))
    sched = GroupCommitScheduler(
        be, ServeConfig(max_batch=8, latency_budget_steps=1)
    )
    shard0 = [k for k in (key_of(i) for i in range(40))
              if be.eng.shard_of(k) == 0]
    shard1 = [k for k in (key_of(i) for i in range(40))
              if be.eng.shard_of(k) == 1]
    singles = [sched.submit(TxnSpec(writes=[(k, b"s-" + k.encode())]))
               for k in (shard0[:3] + shard1[:3])]
    cross = sched.submit(TxnSpec(writes=[(shard0[5], b"x0"),
                                         (shard1[5], b"x1")]))
    sched.run_until_drained()
    assert all(t.status == ACKED for t in singles + [cross])
    assert cross.txn.committed and len(cross.txn.parts) == 2
    data = be.eng.to_dict()
    for k in shard0[:3] + shard1[:3]:
        assert data[k.encode()][0] == b"s-" + k.encode()
    assert data[shard0[5].encode()][0] == b"x0"
    assert data[shard1[5].encode()][0] == b"x1"
