"""Log lifecycle: segmented devices, checkpoint-anchored truncation, and
bounded-log recovery.

The load-bearing property throughout: **crash + recover at any point across
a truncation event equals the never-truncated oracle**.  The oracle is the
full byte stream each device *would* still hold had nothing been dropped —
captured before the truncator runs and spliced with the post-truncation
suffix — replayed by the same recovery code.  Byte-level equality of the
recovered images (all three replay modes) is exactly the truncator's safety
contract: everything it dropped was superseded by the checkpoint image.

Also here: the checkpoint-correctness bugfix regressions (numeric epoch
ordering, no metadata publish over a dead worker, ``size()`` under the
device lock).
"""

import json
import os
import random
import threading

import pytest

from repro.core import (
    CheckpointDaemon,
    EngineConfig,
    FrontierRegistry,
    LogTruncator,
    PoplarEngine,
    ShardedLogTruncator,
    StorageDevice,
    TruncatedLogError,
    DeviceSpec,
    load_latest_checkpoint,
    load_latest_checkpoint_meta,
    recover,
)
from repro.db import OCCWorker, Table, TxnSpec
from repro.replica import Replica, ShardedReplica
from repro.shard import ShardedConfig, ShardedEngine, recover_sharded


# --- segmented StorageDevice --------------------------------------------------

def _dev(tmp_path=None, name="seg.bin"):
    path = None if tmp_path is None else str(tmp_path / name)
    return StorageDevice(DeviceSpec.null(), path=path, clock="virtual")


@pytest.mark.parametrize("backed", ["memory", "path"])
def test_seal_preserves_logical_offsets(tmp_path, backed):
    d = _dev(tmp_path if backed == "path" else None)
    d.write(b"aaaa")
    d.write(b"bbbb")
    assert d.seal(last_ssn=2) is not None
    d.write(b"cccc")
    assert d.seal(last_ssn=3) is not None
    assert d.seal(last_ssn=3) is None          # empty tail: no-op
    d.write(b"dddd")
    assert d.size() == 16
    assert d.read_from(0) == b"aaaabbbbccccdddd"
    assert d.read_from(6) == b"bbccccdddd"      # mid-sealed-segment
    assert d.read_from(10) == b"ccdddd"         # crosses seal boundary
    assert d.read_from(12) == b"dddd"           # tail only
    assert d.read_all() == b"aaaabbbbccccdddd"
    assert d.segments() == [(0, 8, 2), (8, 12, 3)]
    assert d.read_segment_blobs() == [b"aaaabbbb", b"cccc", b"dddd"]
    assert d.disk_bytes() == 16


@pytest.mark.parametrize("backed", ["memory", "path"])
def test_truncate_drops_whole_sealed_prefix_only(tmp_path, backed):
    d = _dev(tmp_path if backed == "path" else None)
    d.write(b"aaaa")
    d.seal(last_ssn=10)
    d.write(b"bbbb")
    d.seal(last_ssn=20)
    d.write(b"cccc")                             # tail, never droppable
    assert d.truncate_to_ssn(9) == (0, 0)        # nothing fully covered
    assert d.truncate_to_ssn(10) == (1, 4)
    assert d.base_offset() == 4
    assert d.truncated_ssn == 10
    assert d.read_all() == b"bbbbcccc"
    with pytest.raises(TruncatedLogError):
        d.read_from(3)
    assert d.read_from(4) == b"bbbbcccc"
    # keep_from pins a still-needed segment regardless of its SSN
    assert d.truncate_to_ssn(99, keep_from=0) == (0, 0)
    assert d.truncate_to_ssn(99) == (1, 4)       # tail survives
    assert d.size() == 12 and d.read_all() == b"cccc"
    assert d.truncated_ssn == 20 and d.truncated_bytes == 8


def test_manifest_survives_reopen(tmp_path):
    path = str(tmp_path / "log_0.bin")
    d = StorageDevice(DeviceSpec.null(), path=path, clock="virtual")
    d.write(b"aaaa")
    d.seal(last_ssn=5)
    d.write(b"bbbb")
    d.seal(last_ssn=7)
    d.write(b"cc")
    d.truncate_to_ssn(5)
    d.close()
    # a fresh process reopening the same path sees the same chain
    d2 = StorageDevice(DeviceSpec.null(), path=path, clock="virtual")
    assert d2.base_offset() == 4
    assert d2.truncated_ssn == 5
    assert d2.segments() == [(4, 8, 7)]
    assert d2.size() == 10
    assert d2.read_all() == b"bbbbcc"
    d2.write(b"dd")
    assert d2.read_all() == b"bbbbccdd" and d2.size() == 12


def test_size_is_frontier_not_torn_append(tmp_path):
    """size() must never report a frontier inside an in-flight append (it
    used to stat the file after releasing the device lock)."""
    d = StorageDevice(DeviceSpec.null(), path=str(tmp_path / "r.bin"),
                      clock="virtual")
    rec = b"x" * 64
    stop = threading.Event()

    def writer():
        while not stop.is_set():
            d.write(rec)

    t = threading.Thread(target=writer)
    t.start()
    try:
        for _ in range(3000):
            assert d.size() % 64 == 0
    finally:
        stop.set()
        t.join()


def test_concurrent_reader_vs_seal_and_truncate(tmp_path):
    """Readers must never observe spliced/mispositioned bytes or vanished
    sealed files while seal() renames the tail and truncate_to_ssn()
    unlinks segments concurrently: every logical offset o always reads the
    byte pattern written at o (all chain IO happens under the device lock).
    """
    d = StorageDevice(DeviceSpec.null(), path=str(tmp_path / "c.bin"),
                      clock="virtual")
    stop = threading.Event()
    errors = []

    def pattern(start, n):
        return bytes((start + j) % 251 for j in range(n))

    def writer():
        off = 0
        chunk = 0
        while not stop.is_set():
            d.write(pattern(off, 37))
            off += 37
            chunk += 1
            if chunk % 5 == 0:
                d.seal(last_ssn=chunk)
                d.truncate_to_ssn(chunk - 10)

    def reader():
        while not stop.is_set():
            try:
                base = d.base_offset()
                blob = d.read_from(base)
            except TruncatedLogError:
                continue           # lost the race to a truncation: retry
            except Exception as e:  # noqa: BLE001 - collected for the assert
                errors.append(e)
                return
            if blob != pattern(base, len(blob)):
                errors.append(AssertionError(f"bytes at {base} mispositioned"))
                return

    w = threading.Thread(target=writer)
    rs = [threading.Thread(target=reader) for _ in range(2)]
    w.start()
    for r in rs:
        r.start()
    import time as _time
    _time.sleep(0.5)
    stop.set()
    w.join()
    for r in rs:
        r.join()
    assert not errors, errors
    assert d.truncated_bytes > 0      # the race was actually exercised


# --- checkpoint bugfix regressions --------------------------------------------

def _mk_ckpt(directory, epoch, rsn):
    daemon = CheckpointDaemon(directory, n_threads=1, m_files=1,
                              csn_fn=lambda: 1 << 50)
    entries = [(f"e{epoch}".encode(), str(rsn).encode(), rsn)]
    daemon.csn_fn = lambda: 1 << 50
    # write via the daemon so the on-disk shape is the real one
    daemon.run_once([entries], epoch=epoch)
    # patch the rsn (csn_fn stands in for a live engine)
    meta_path = os.path.join(directory, f"ckpt_{epoch}.meta.json")
    with open(meta_path) as f:
        meta = json.load(f)
    meta["rsn"] = rsn
    with open(meta_path, "w") as f:
        json.dump(meta, f)


def test_latest_checkpoint_numeric_epoch_order(tmp_path):
    """Epoch 1000 must beat 999 — lexicographically '999' sorts *after*
    'ckpt_1000', so the old sort recovered from the stale checkpoint."""
    d = str(tmp_path)
    _mk_ckpt(d, epoch=999, rsn=111)
    _mk_ckpt(d, epoch=1000, rsn=222)
    meta = load_latest_checkpoint_meta(d)
    assert meta["epoch"] == 1000 and meta["rsn"] == 222
    ck = load_latest_checkpoint(d, parallel=False)
    assert ck.rsn == 222
    assert ck.data[b"e1000"] == (b"222", 222)


def test_checkpoint_worker_failure_never_publishes(tmp_path):
    class Boom(Exception):
        pass

    def bad_partition():
        yield (b"k", b"v", 1)
        raise Boom("snapshot iterator died")

    daemon = CheckpointDaemon(str(tmp_path), n_threads=2, m_files=2,
                              csn_fn=lambda: 100)
    good = [(b"a", b"1", 1), (b"b", b"2", 2)]
    with pytest.raises(Boom):
        daemon.run_once([good, bad_partition()], epoch=7)
    # nothing published: no metadata, and recovery sees no checkpoint at all
    assert load_latest_checkpoint_meta(str(tmp_path)) is None
    assert load_latest_checkpoint(str(tmp_path), parallel=False) is None
    # a later, healthy checkpoint on the same directory is unaffected
    daemon.run_once([good, [(b"c", b"3", 3)]], epoch=8)
    assert load_latest_checkpoint_meta(str(tmp_path))["epoch"] == 8


# --- truncation end-to-end: crash/recover vs the never-truncated oracle -------

def _capture_full(devices):
    """Every device's full byte stream (before any truncation drops it)."""
    return [d.read_from(0) for d in devices]


def _oracle_devices(pre_bytes, devices):
    """In-memory devices holding what each device *would* contain had
    nothing been truncated: captured prefix + retained suffix past it."""
    out = []
    for pre, d in zip(pre_bytes, devices):
        base = d.base_offset()
        suffix = d.read_from(base)
        full = pre + suffix[len(pre) - base:]
        od = StorageDevice(DeviceSpec.null(), clock="virtual")
        od.write(full)
        out.append(od)
    return out


def _engine_csn_fn(engine):
    def csn_fn():
        for i in range(len(engine.buffers)):
            engine.logger_tick(i, force=True)
        return engine.commit.advance_csn()

    return csn_fn


def _run_phase(workers, table, keys, rng, n, tag):
    done = []
    for i in range(n):
        w = workers[i % len(workers)]
        wk = rng.sample(keys, rng.randrange(1, 3))
        rk = rng.sample(keys, rng.randrange(0, 2))   # some Qwr records
        t = w.execute(reads=rk,
                      writes=[(k, f"{tag}{i}:{k}".encode()) for k in wk])
        if t is not None:
            done.append(t)
    return done


@pytest.mark.parametrize("crash", ["at_truncation", "mid_stream", "flushed"])
def test_truncated_recovery_equals_oracle(tmp_path, crash):
    dev_dir = tmp_path / "devs"
    ckpt_dir = str(tmp_path / "ckpt")
    engine = PoplarEngine(EngineConfig(
        n_buffers=2, device_kind="ssd", device_dir=str(dev_dir),
        device_clock="virtual", segment_bytes=256,
    ))
    table = Table()
    workers = [OCCWorker(table, engine, i) for i in range(2)]
    rng = random.Random(23)
    keys = [f"k{i}" for i in range(25)]

    _run_phase(workers, table, keys, rng, 40, "a")
    engine.quiesce(range(2))

    daemon = CheckpointDaemon(ckpt_dir, n_threads=2, m_files=2,
                              csn_fn=_engine_csn_fn(engine))
    entries = sorted(
        (k.encode(), table.get(k).value, table.get(k).ssn)
        for k in table.sorted_keys() if table.get(k).ssn > 0
    )
    daemon.run_once([entries[0::2], entries[1::2]])

    _run_phase(workers, table, keys, rng, 30, "b")
    engine.quiesce(range(2))

    # oracle capture, then the truncation event
    pre = _capture_full(engine.devices)
    tr = LogTruncator(engine, ckpt_dir)
    stats = tr.run_once()
    assert stats.bytes_dropped > 0, "truncation must actually drop segments"
    assert all(d.base_offset() > 0 for d in engine.devices)

    if crash != "at_truncation":
        _run_phase(workers, table, keys, rng, 30, "c")
        if crash == "flushed":
            engine.quiesce(range(2))
        else:
            engine.logger_tick(0, force=True)   # buffer 1 dies unflushed
    for d in engine.devices:
        d.close()
    if crash == "mid_stream":                   # torn frame lands on device 0
        with open(os.path.join(str(dev_dir), "log_0.bin"), "ab") as f:
            f.write(b"\xff" * 11)

    oracle_devs = _oracle_devices(pre, engine.devices)
    if crash == "mid_stream":
        oracle_devs[0].write(b"\xff" * 11)

    oracle = recover(oracle_devs, checkpoint_dir=ckpt_dir, parallel=False)
    for mode in ("vectorized", "pallas", "scalar"):
        got = recover(engine.devices, checkpoint_dir=ckpt_dir,
                      parallel=False, mode=mode)
        assert got.data == oracle.data, mode
        assert got.rsne == oracle.rsne and got.rsns == oracle.rsns, mode


def test_truncator_respects_consumer_frontier(tmp_path):
    engine = PoplarEngine(EngineConfig(
        n_buffers=2, device_kind="ssd", device_dir=str(tmp_path / "devs"),
        device_clock="virtual",
    ))
    table = Table()
    workers = [OCCWorker(table, engine, i) for i in range(2)]
    rng = random.Random(3)
    keys = [f"k{i}" for i in range(10)]
    _run_phase(workers, table, keys, rng, 30, "a")
    engine.quiesce(range(2))

    ckpt_dir = str(tmp_path / "ckpt")
    daemon = CheckpointDaemon(ckpt_dir, n_threads=1, m_files=1,
                              csn_fn=_engine_csn_fn(engine))
    entries = sorted((k.encode(), table.get(k).value, table.get(k).ssn)
                     for k in table.sorted_keys() if table.get(k).ssn > 0)
    daemon.run_once([entries])

    registry = FrontierRegistry()
    registry.register("lagging-consumer", lambda: 0)
    tr = LogTruncator(engine, ckpt_dir, registry=registry)
    stats = tr.run_once()
    assert stats.bytes_dropped == 0 and stats.safe_ssn == 0
    assert all(d.base_offset() == 0 for d in engine.devices)

    registry.unregister("lagging-consumer")
    stats = tr.run_once()
    assert stats.bytes_dropped > 0


def test_threaded_truncator_follows_checkpoint_epochs(tmp_path):
    import time as _time

    engine = PoplarEngine(EngineConfig(
        n_buffers=2, device_kind="ssd", device_dir=str(tmp_path / "devs"),
        device_clock="virtual",
    ))
    table = Table()
    workers = [OCCWorker(table, engine, i) for i in range(2)]
    rng = random.Random(13)
    keys = [f"k{i}" for i in range(10)]
    _run_phase(workers, table, keys, rng, 30, "a")
    engine.quiesce(range(2))

    ckpt_dir = str(tmp_path / "ckpt")
    registry = FrontierRegistry()
    rep = Replica(engine.devices, checkpoint_dir=ckpt_dir, parallel=False)
    rep.poll()                               # fully caught up: no cap
    registry.register_replica("replica", rep)

    tr = LogTruncator(engine, ckpt_dir, registry=registry)
    tr.start(poll_interval=1e-3)
    try:
        daemon = CheckpointDaemon(ckpt_dir, n_threads=1, m_files=1,
                                  csn_fn=_engine_csn_fn(engine))
        entries = sorted((k.encode(), table.get(k).value, table.get(k).ssn)
                         for k in table.sorted_keys() if table.get(k).ssn > 0)
        daemon.run_once([entries], epoch=1)
        deadline = _time.monotonic() + 10
        while tr.total_bytes_dropped == 0 and _time.monotonic() < deadline:
            rep.poll()       # a live consumer keeps its frontier advancing —
            _time.sleep(2e-3)  # the safe point is capped at it until then
    finally:
        tr.stop()
    assert tr.total_bytes_dropped > 0 and tr.last_epoch == 1
    # the registered, caught-up replica never saw a hole: polling just works
    rep.poll()
    assert rep.n_rebases == 0


def _sharded_ckpt(eng, tmp_path):
    dirs = []
    for p, sh in enumerate(eng.shards):
        d = str(tmp_path / f"ckpt{p}")
        daemon = CheckpointDaemon(d, n_threads=1, m_files=2,
                                  csn_fn=sh.engine.commit.advance_csn)
        entries = [(k.encode(), v, s) for k, v, s in sh.table.items() if s > 0]
        daemon.run_once([sorted(entries)])
        dirs.append(d)
    return dirs


def _sharded_phase(eng, keys, by_shard, tag, rng):
    specs = [TxnSpec(writes=[(k, f"{tag}:{k}".encode())])
             for k in rng.sample(keys, 12)]
    specs.append(TxnSpec(writes=[(by_shard[0][0], f"{tag}:x0".encode()),
                                 (by_shard[1][0], f"{tag}:x1".encode())]))
    res = eng.execute_batch(specs)
    assert not res.aborted
    eng.quiesce()


@pytest.mark.parametrize("crash", ["at_truncation", "mid_stream"])
def test_sharded_truncated_recovery_equals_oracle(tmp_path, crash):
    eng = ShardedEngine(ShardedConfig(
        n_shards=2, n_buffers=1, n_workers=2, device_kind="ssd",
        device_clock="virtual", device_dir=str(tmp_path / "devs"),
    ))
    rng = random.Random(17)
    keys = [f"user{i:010d}" for i in range(24)]
    by_shard = [[], []]
    for k in keys:
        by_shard[eng.shard_of(k)].append(k)

    for r in range(2):
        _sharded_phase(eng, keys, by_shard, f"a{r}", rng)
    ckpt_dirs = _sharded_ckpt(eng, tmp_path)

    # oracle capture, then truncate right after the checkpoint (the daemon
    # pattern): the sealed phase-a segments are exactly what it covers
    pre = [_capture_full(devs) for devs in eng.devices]
    tr = ShardedLogTruncator(eng, ckpt_dirs)
    stats = tr.run_once()
    assert sum(s.bytes_dropped for s in stats) > 0
    _sharded_phase(eng, keys, by_shard, "b", rng)

    if crash == "mid_stream":
        _sharded_phase(eng, keys, by_shard, "c", rng)
        # shard 0 flushes; shard 1's buffer dies unflushed... then a torn
        # frame lands on shard 1's device
        eng.execute_batch([TxnSpec(writes=[(by_shard[1][0], b"lost")])])
        for i in range(len(eng.shards[0].engine.buffers)):
            eng.shards[0].engine.logger_tick(i, force=True)
    for devs in eng.devices:
        for d in devs:
            d.close()
    if crash == "mid_stream":
        with open(os.path.join(str(tmp_path / "devs"), "shard1",
                               "log_0.bin"), "ab") as f:
            f.write(b"\x07" * 9)

    oracle_devs = [_oracle_devices(pre[p], eng.devices[p]) for p in range(2)]
    oracle = recover_sharded(oracle_devs, checkpoint_dirs=ckpt_dirs,
                             parallel=False)
    for mode in ("vectorized", "pallas", "scalar"):
        got = recover_sharded(eng.devices, checkpoint_dirs=ckpt_dirs,
                              parallel=False, mode=mode)
        assert got.data == oracle.data, mode
        for a, b in zip(got.shards, oracle.shards):
            assert a.data == b.data and a.rsne == b.rsne, mode


def test_sharded_truncator_pins_uncovered_cross_records(tmp_path):
    """A segment holding a cross-shard record whose peer shard has no
    checkpoint must never be dropped (dropping it would break the
    durable-on-all-participants cut for a committed transaction)."""
    eng = ShardedEngine(ShardedConfig(
        n_shards=2, n_buffers=1, n_workers=2, device_kind="ssd",
        device_clock="virtual", device_dir=str(tmp_path / "devs"),
    ))
    rng = random.Random(5)
    keys = [f"user{i:010d}" for i in range(24)]
    by_shard = [[], []]
    for k in keys:
        by_shard[eng.shard_of(k)].append(k)
    _sharded_phase(eng, keys, by_shard, "a", rng)

    # checkpoint only shard 0: its x-records name shard 1, which stays
    # uncovered, so shard 0 must keep every segment holding one
    d0 = str(tmp_path / "ckpt0")
    daemon = CheckpointDaemon(d0, n_threads=1, m_files=1,
                              csn_fn=eng.shards[0].engine.commit.advance_csn)
    entries = [(k.encode(), v, s)
               for k, v, s in eng.shards[0].table.items() if s > 0]
    daemon.run_once([sorted(entries)])

    tr = ShardedLogTruncator(eng, [d0, None])
    stats = tr.run_once()
    assert stats[0].bytes_dropped == 0      # x-record pins the only segment
    assert stats[1].bytes_dropped == 0      # no checkpoint at all
    eng.stop()


# --- replica re-basing across truncation --------------------------------------

def test_replica_rebases_after_truncation(tmp_path):
    dev_dir = tmp_path / "devs"
    ckpt_dir = str(tmp_path / "ckpt")
    engine = PoplarEngine(EngineConfig(
        n_buffers=2, device_kind="ssd", device_dir=str(dev_dir),
        device_clock="virtual", segment_bytes=256,
    ))
    table = Table()
    workers = [OCCWorker(table, engine, i) for i in range(2)]
    rng = random.Random(29)
    keys = [f"k{i}" for i in range(20)]

    # the replica attaches from offset 0 but never polls: it will lag
    rep = Replica(engine.devices, checkpoint_dir=ckpt_dir, parallel=False)

    _run_phase(workers, table, keys, rng, 40, "a")
    engine.quiesce(range(2))
    daemon = CheckpointDaemon(ckpt_dir, n_threads=1, m_files=2,
                              csn_fn=_engine_csn_fn(engine))
    entries = sorted((k.encode(), table.get(k).value, table.get(k).ssn)
                     for k in table.sorted_keys() if table.get(k).ssn > 0)
    daemon.run_once([entries])

    _run_phase(workers, table, keys, rng, 20, "b")
    engine.quiesce(range(2))
    stats = LogTruncator(engine, ckpt_dir).run_once()
    assert stats.bytes_dropped > 0

    # the lagging shipper's offset now predates the truncation point:
    # polling re-bases via checkpoint catch-up instead of reading a hole
    rep.poll()
    assert rep.n_rebases >= 1
    assert rep.rsns > 0

    _run_phase(workers, table, keys, rng, 20, "c")
    engine.quiesce(range(2))
    for d in engine.devices:
        d.close()

    promoted = rep.promote()
    want = recover(engine.devices, checkpoint_dir=ckpt_dir, parallel=False)
    assert promoted.data == want.data
    assert promoted.rsne == want.rsne and promoted.rsns == want.rsns

    # byte-identical to a replica that never lagged: fresh checkpoint
    # catch-up over the truncated devices
    fresh = Replica(engine.devices, checkpoint_dir=ckpt_dir, parallel=False)
    fresh_promoted = fresh.promote()
    assert fresh_promoted.data == promoted.data
    assert fresh.table.to_dict() == rep.table.to_dict()


def test_rebase_round_keeps_other_shippers_chunks(tmp_path):
    """When one shipper hits the truncation hole mid-round, the round's
    successfully shipped chunks from the *other* devices must survive: those
    shippers already advanced their consumed offsets, so a whole-round retry
    would lose their records forever while the watermark still covered them.
    """
    dev_dir = tmp_path / "devs"
    ckpt_dir = str(tmp_path / "ckpt")
    engine = PoplarEngine(EngineConfig(
        n_buffers=2, device_kind="ssd", device_dir=str(dev_dir),
        device_clock="virtual",
    ))
    table = Table()
    workers = [OCCWorker(table, engine, i) for i in range(2)]
    rng = random.Random(41)
    keys = [f"k{i}" for i in range(20)]
    rep = Replica(engine.devices, checkpoint_dir=ckpt_dir, parallel=False)

    _run_phase(workers, table, keys, rng, 30, "a")
    engine.quiesce(range(2))
    # segment boundary after phase a on device 0 only
    buf0, dev0 = engine.buffers[0], engine.devices[0]
    with buf0.flush_lock:
        dev0.seal(buf0.dsn)
    daemon = CheckpointDaemon(ckpt_dir, n_threads=1, m_files=2,
                              csn_fn=_engine_csn_fn(engine))
    entries = sorted((k.encode(), table.get(k).value, table.get(k).ssn)
                     for k in table.sorted_keys() if table.get(k).ssn > 0)
    daemon.run_once([entries])

    _run_phase(workers, table, keys, rng, 30, "b")
    engine.quiesce(range(2))
    # drop device 0's phase-a segment; device 1 keeps its whole log
    n, nbytes = dev0.truncate_to_ssn(
        load_latest_checkpoint_meta(ckpt_dir)["rsn"])
    assert n == 1 and nbytes > 0 and dev0.base_offset() > 0
    assert engine.devices[1].base_offset() == 0

    # one round: shipper 0 re-bases, shipper 1's chunk must still apply
    rep.poll()
    assert rep.n_rebases == 1
    for d in engine.devices:
        d.close()
    promoted = rep.promote()
    want = recover(engine.devices, checkpoint_dir=ckpt_dir, parallel=False)
    assert promoted.data == want.data and promoted.rsne == want.rsne


def test_sharded_replica_promote_across_truncation(tmp_path):
    eng = ShardedEngine(ShardedConfig(
        n_shards=2, n_buffers=1, n_workers=2, device_kind="ssd",
        device_clock="virtual", device_dir=str(tmp_path / "devs"),
    ))
    rng = random.Random(31)
    keys = [f"user{i:010d}" for i in range(24)]
    by_shard = [[], []]
    for k in keys:
        by_shard[eng.shard_of(k)].append(k)

    ckpt_dirs = [str(tmp_path / "ckpt0"), str(tmp_path / "ckpt1")]
    rep = ShardedReplica(eng.devices, checkpoint_dirs=ckpt_dirs,
                         parallel=False)   # attaches at offset 0, lags

    for r in range(2):
        _sharded_phase(eng, keys, by_shard, f"a{r}", rng)
    got_dirs = _sharded_ckpt(eng, tmp_path)
    assert got_dirs == ckpt_dirs
    tr = ShardedLogTruncator(eng, ckpt_dirs)
    assert sum(s.bytes_dropped for s in tr.run_once()) > 0
    _sharded_phase(eng, keys, by_shard, "b", rng)

    rep.poll()                              # re-bases the lagging shippers
    assert any(r.n_rebases for r in rep.replicas)

    _sharded_phase(eng, keys, by_shard, "c", rng)
    for devs in eng.devices:
        for d in devs:
            d.close()

    promoted = rep.promote()
    want = recover_sharded(eng.devices, checkpoint_dirs=ckpt_dirs,
                           parallel=False)
    assert promoted.data == want.data
    for a, b in zip(promoted.shards, want.shards):
        assert a.data == b.data
