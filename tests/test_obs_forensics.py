"""Crash flight recorder + recovery forensics, end-to-end.

The acceptance contract pinned here: a kill mid-stream produces a
``*.flight.json`` dump, and ``explain_recovery()`` over the surviving
device bytes assigns **every** gtid in the log a verdict (kept/dropped +
which §5 rule) that byte-agrees with what ``recover()`` /
``recover_sharded()`` actually kept — checked with
``RecoveryExplanation.verify_bytes``, which replays only the verdict-kept
records and compares images dict-for-dict.

The kill idiom mirrors ``test_crash_injection.py``: real-clock file-backed
devices, ``engine.stop()`` as the crash point (volatile ring contents are
lost), plus physically injected tail bytes — a torn frame (interrupted
flush) and, for rule coverage, records durable on *some* devices only
(a HAS_READS record above RSNe; a cross-shard record missing one
participant).
"""

import json
import os
import signal
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.core import EngineConfig, PoplarEngine, Txn, Worker, recover
from repro.db import TxnSpec
from repro.obs import REGISTRY, enable
from repro.obs.flight import FlightRecorder, load_flight
from repro.obs.forensics import (
    RULE_ABOVE_RSNE,
    RULE_NOT_DURABLE,
    RULE_REPLAYED,
    RULE_TORN_TAIL,
    explain_recovery,
    explain_recovery_sharded,
)
from repro.shard import ShardedConfig, ShardedEngine, recover_sharded

_SRC = str(Path(__file__).resolve().parents[1] / "src")


@pytest.fixture(autouse=True)
def _disarm():
    yield
    REGISTRY.enabled = False
    REGISTRY.reset()


def _record(tid, ssn, key, val, reads=False, xdep=None) -> bytes:
    t = Txn(tid=tid, write_set=[(key, val)],
            read_set=[("dep", 0)] if reads else [], xdep=xdep)
    t.ssn = ssn
    return t.encode()


def _torn_record(key: str, cut: int = 7) -> bytes:
    rec = _record(777777, 1 << 40, key, b"TORN-NEVER-COMMITTED")
    assert cut < len(rec)
    return rec[:-cut]


class _Cell:
    __slots__ = ("ssn",)

    def __init__(self):
        self.ssn = 0


# --- flight recorder ----------------------------------------------------------

def test_flight_dump_roundtrip(tmp_path):
    enable()
    REGISTRY.count("unit.events", 3)
    REGISTRY.observe("unit.lat", 0.25)
    rec = FlightRecorder(str(tmp_path / "run"))
    path = rec.dump("unit-test")
    assert path.endswith(".flight.json") and os.path.exists(path)
    d = load_flight(path)
    assert d["schema"] == 1
    assert d["reason"] == "unit-test"
    assert d["pid"] == os.getpid()
    assert d["metrics"]["counters"]["unit.events"] == 3
    assert d["metrics"]["sketches"]["unit.lat"]["count"] == 1
    assert "trace" in d
    # dumps are atomic full rewrites: a second dump supersedes the first
    rec.dump("second")
    assert load_flight(path)["reason"] == "second"
    assert rec.n_dumps == 2


def test_flight_sigterm_writes_dump(tmp_path):
    """A killed process leaves a loadable flight dump behind (the installed
    SIGTERM handler snapshots, then chains to the default and dies)."""
    target = tmp_path / "crash"
    child = textwrap.dedent(f"""
        import time
        from repro.obs import REGISTRY, enable
        from repro.obs.flight import FlightRecorder
        enable()
        REGISTRY.count("child.alive")
        FlightRecorder({str(target)!r}).install()
        print("READY", flush=True)
        time.sleep(30)
    """)
    env = dict(os.environ, PYTHONPATH=_SRC, JAX_PLATFORMS="cpu")
    proc = subprocess.Popen([sys.executable, "-c", child],
                            stdout=subprocess.PIPE, env=env, text=True)
    try:
        assert proc.stdout.readline().strip() == "READY"
        proc.send_signal(signal.SIGTERM)
        proc.wait(timeout=15)
    finally:
        if proc.poll() is None:
            proc.kill()
    assert proc.returncode != 0  # the handler re-raises: the kill still kills
    d = load_flight(str(target) + ".flight.json")
    assert d["reason"] == "signal:SIGTERM"
    assert d["pid"] == proc.pid
    assert d["metrics"]["counters"]["child.alive"] == 1


# --- single-shard: kill mid-stream, then explain what recover() kept ---------

def test_single_shard_kill_forensics_byte_agree(tmp_path):
    dev_dir = tmp_path / "devs"
    dev_dir.mkdir()
    cfg = EngineConfig(n_buffers=2, device_kind="ssd",
                       device_dir=str(dev_dir), device_clock="real",
                       flush_interval=1e-3, logger_poll=1e-4)
    engine = PoplarEngine(cfg)
    enable()
    rec = FlightRecorder(str(tmp_path / "crash"))
    engine.start()
    try:
        workers = [Worker(engine, i) for i in range(2)]
        cells = {f"k{i}": _Cell() for i in range(30)}
        txns = []
        for i in range(60):
            t = Txn(tid=1000 + i)
            key = f"k{i % 30}"
            t.write_set = [(key, f"v{i}".encode())]
            if i % 4 == 0:        # a quarter of the stream carries reads
                t.read_set = [(key, cells[key].ssn)]
            workers[i % 2].run(t, [], [cells[key]])
            txns.append(t)
        engine.quiesce(range(2))
        assert all(t.committed for t in txns)
    finally:
        engine.stop()             # the kill: volatile ring contents are lost
    # writes buffered after the kill never reach a device
    for i in range(5):
        t = Txn(tid=5000 + i)
        t.write_set = [(f"k{i}", f"lost{i}".encode())]
        workers[i % 2].run(t, [], [cells[f"k{i}"]])
    flight_path = rec.dump("kill:mid-stream")
    for d in engine.devices:
        d.close()

    # physically injected crash tail on device 0: a HAS_READS record durable
    # on one device only (ssn far above RSNe, which the other devices pin
    # down), then a torn frame from an interrupted flush
    with open(os.path.join(str(dev_dir), "log_0.bin"), "ab") as f:
        f.write(_record(888888, 1 << 39, "k0", b"ABOVE-RSNE", reads=True))
        f.write(_torn_record("k0"))
        f.flush()
        os.fsync(f.fileno())

    state = recover(engine.devices, parallel=False)
    ex = explain_recovery(engine.devices, flight=flight_path)

    # every committed gtid has a kept verdict; the injected ones are named
    for t in txns:
        v = ex.verdicts[t.tid]
        assert v.kept and v.rule == RULE_REPLAYED
        assert v.has_reads == bool(t.read_set)
    assert not ex.verdicts[888888].kept
    assert ex.verdicts[888888].rule == RULE_ABOVE_RSNE
    assert not ex.verdicts[777777].kept
    assert ex.verdicts[777777].rule == RULE_TORN_TAIL
    assert ex.torn and ex.torn[0]["gtid"] == 777777
    # no verdict for the never-flushed tail: those bytes do not exist
    assert all(5000 + i not in ex.verdicts for i in range(5))

    # the headline acceptance: replaying exactly the verdict-kept records
    # reproduces recover()'s image byte-for-byte
    agrees, bad = ex.verify_bytes(state)
    assert agrees, bad
    kept = sum(1 for v in ex.verdicts.values() if v.kept)
    assert kept == len(txns) == state.report.n_replayed
    assert state.report.n_dropped_above_rsne == 1
    assert state.report.mode == "vectorized"
    assert state.report.to_dict()["n_devices"] == len(engine.devices)

    # crash context from the flight dump is folded into the rendering
    assert ex.flight["reason"] == "kill:mid-stream"
    out = ex.render()
    assert "kill:mid-stream" in out and RULE_TORN_TAIL in out
    json.dumps(ex.to_dict())  # the whole explanation is JSON-serializable


# --- 2-shard: the consistent cut, explained ----------------------------------

def test_two_shard_kill_forensics_byte_agree(tmp_path):
    eng = ShardedEngine(ShardedConfig(
        n_shards=2, n_buffers=1, n_workers=2, device_kind="ssd",
        device_dir=str(tmp_path), device_clock="real",
    ))
    enable()
    rec = FlightRecorder(str(tmp_path / "crash2"))
    keys = [f"user{i:010d}" for i in range(24)]
    gtids = []
    eng.start()
    try:
        by_shard = [[], []]
        for k in keys:
            by_shard[eng.shard_of(k)].append(k)
        assert by_shard[0] and by_shard[1]
        for r in range(3):
            specs = [TxnSpec(writes=[(k, f"{k}r{r}".encode())]) for k in keys]
            specs.append(TxnSpec(
                writes=[(by_shard[0][0], f"x0r{r}".encode()),
                        (by_shard[1][0], f"x1r{r}".encode())],
            ))
            res = eng.execute_batch(specs)
            assert not res.aborted
            eng.quiesce()
            gtids += [t.tid for t in res.committed]
            gtids += [x.gtid for x in res.cross]
            cross_gtids = [x.gtid for x in res.cross]
    finally:
        eng.stop()                # the kill
    flight_path = rec.dump("kill:2shard")
    for devs in eng.devices:
        for d in devs:
            d.close()

    # crash tail on shard 0: a cross-shard record whose shard-1 twin never
    # made it out of the ring — durable on one participant only
    with open(os.path.join(str(tmp_path), "shard0", "log_0.bin"), "ab") as f:
        f.write(_record(999999, 1 << 39, by_shard[0][0], b"X-NEVER",
                        xdep=[(0, 1 << 39), (1, 1 << 39)]))
        f.flush()
        os.fsync(f.fileno())
    # and a torn frame at the tail of shard 1's device
    with open(os.path.join(str(tmp_path), "shard1", "log_0.bin"), "ab") as f:
        f.write(_torn_record(by_shard[1][0]))
        f.flush()
        os.fsync(f.fileno())

    st = recover_sharded(eng.devices, parallel=False)
    ex = explain_recovery_sharded(eng.devices, flight=flight_path)

    assert ex.n_shards == 2 and len(ex.rsne) == 2
    for g in gtids:
        assert ex.verdicts[g].kept and ex.verdicts[g].rule == RULE_REPLAYED
    # the kept cross records carry their per-participant SSN vector
    for g in cross_gtids:
        assert set(ex.verdicts[g].ssn) == {0, 1}
    v = ex.verdicts[999999]
    assert not v.kept and v.rule == RULE_NOT_DURABLE
    assert "shard(s) [1]" in v.detail
    assert not ex.verdicts[777777].kept
    assert ex.verdicts[777777].rule == RULE_TORN_TAIL

    agrees, bad = ex.verify_bytes(st)
    assert agrees, bad
    assert b"X-NEVER" not in {val for val, _ in st.data.items()}

    rep = st.report_dict()
    assert rep["n_shards"] == 2
    assert rep["n_cross_dropped"] == 1    # the injected half-commit
    # a kept cross gtid replays one record per participant shard
    assert sum(s["n_replayed"] for s in rep["shards"]) == \
        sum(len(x.ssn) for x in ex.verdicts.values() if x.kept)
    assert ex.flight["reason"] == "kill:2shard"
    out = ex.render()
    assert RULE_NOT_DURABLE in out and "2 shard(s)" in out
    json.dumps(ex.to_dict())
