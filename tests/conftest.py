"""Shared test fixtures.

The engines default to production timing: a 5 ms group-commit interval, a
0.2 ms logger idle poll, and *slept* emulated device latencies (storage.py
``device_clock="real"``).  Tests that spin up threaded engines inherit those
wall-clock timers, which pushes the full suite past two minutes for no
coverage gain — the protocol logic is timer-value independent.

The autouse fixture below tightens every ``EngineConfig`` a test constructs
(unless the test passes those fields explicitly, which keeps timing-specific
tests honest): virtual device clocks (no sleeping; durability is unchanged —
the backing file/buffer is still written synchronously) and sub-millisecond
flush/poll intervals.
"""

import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import pytest

from repro.core.engine import EngineConfig

# positional index of each tightened field in EngineConfig's __init__
_FIELD_POS = {f.name: i for i, f in enumerate(dataclasses.fields(EngineConfig))}
_FAST = {"flush_interval": 5e-4, "device_clock": "virtual", "logger_poll": 1e-5}


@pytest.fixture(autouse=True)
def fast_engine_defaults(monkeypatch):
    orig_init = EngineConfig.__init__

    def init(self, *args, **kwargs):
        for name, fast in _FAST.items():
            if len(args) <= _FIELD_POS[name] and name not in kwargs:
                kwargs[name] = fast
        orig_init(self, *args, **kwargs)

    monkeypatch.setattr(EngineConfig, "__init__", init)
    yield
