"""Commit protocol (§4.3): Qww vs Qwr, DSN/CSN watermarks, heartbeats."""

import pytest

from repro.core import EngineConfig, PoplarEngine, Txn, Worker


class Cell:
    def __init__(self, ssn=0):
        self.ssn = ssn


def _engine(n=2):
    # a huge flush interval pins all flushing/heartbeating to the explicit
    # force-ticks these tests issue: with the conftest's sub-ms default, a
    # slow CI machine can let drain()'s inline null-device logger tick
    # auto-heartbeat between steps and commit Qwr txns before the
    # "not yet committed" assertions run
    return PoplarEngine(
        EngineConfig(n_buffers=n, device_kind="null", flush_interval=60.0)
    )


def test_qww_commits_on_own_dsn_only():
    """A write-only txn commits as soon as its own buffer's DSN covers it,
    even if the other buffer never flushed (scenario d/f freedom)."""
    e = _engine()
    w0, w1 = Worker(e, 0), Worker(e, 1)
    a, b = Cell(), Cell()
    t0 = Txn(tid=1, write_set=[("a", b"1")])
    w0.run(t0, [], [a])
    # put something unflushed in buffer 1 so its DSN stays behind
    t1 = Txn(tid=2, write_set=[("b", b"2")])
    w1.run(t1, [], [b])
    # flush ONLY buffer 0
    e.buffers[0].force_establish()
    e.buffers[0].flush_ready(e.devices[0])
    assert w0.drain() == 1 and t0.committed
    assert not t1.committed


def test_qwr_waits_for_csn():
    """A RAW-carrying txn cannot commit until every buffer's DSN passes its
    SSN (scenario c prevention)."""
    e = _engine()
    w0, w1 = Worker(e, 0), Worker(e, 1)
    a, b = Cell(), Cell()
    t0 = Txn(tid=1, write_set=[("a", b"1")])
    w0.run(t0, [], [a])          # ssn 1 in buffer 0 (NOT flushed)
    t1 = Txn(tid=2, read_set=[("a", a.ssn)], write_set=[("b", b"2")])
    w1.run(t1, [a], [b])         # ssn 2 in buffer 1, RAW on t0
    # flush only buffer 1: t1's record durable but its predecessor is not
    e.buffers[1].force_establish()
    e.buffers[1].flush_ready(e.devices[1])
    e.commit.advance_csn()
    assert w1.drain() == 0 and not t1.committed
    # flush buffer 0: its DSN reaches t0.ssn=1 but CSN=min(1, dsn1) < t1.ssn,
    # so t1 still waits (CSN is conservative)...
    e.buffers[0].force_establish()
    e.buffers[0].flush_ready(e.devices[0])
    e.commit.advance_csn()
    assert w0.drain() == 1 and t0.committed  # t0's own-buffer commit is fine
    assert w1.drain() == 0 and not t1.committed
    # ...until the idle buffer 0 heartbeats up to the global frontier
    e.logger_tick(0, force=True)
    e.commit.advance_csn()
    assert w1.drain() == 1 and t1.committed


def test_read_only_commits_via_csn():
    e = _engine()
    w0, w1 = Worker(e, 0), Worker(e, 1)
    a = Cell()
    t0 = Txn(tid=1, write_set=[("a", b"1")])
    w0.run(t0, [], [a])
    ro = Txn(tid=2, read_set=[("a", a.ssn)])
    w1.run(ro, [a], [])
    assert ro.ssn == t0.ssn  # read-only: ssn = base, no +1
    e.quiesce([0, 1], timeout=5)
    assert ro.committed


def test_heartbeat_unblocks_idle_buffer():
    """An idle lane must not pin the CSN forever (liveness — see
    engine._emit_heartbeat)."""
    e = _engine()
    w0, w1 = Worker(e, 0), Worker(e, 1)
    a, b = Cell(), Cell()
    # only worker 0 (buffer 0) does writes; buffer 1 stays idle
    t0 = Txn(tid=1, write_set=[("a", b"1")])
    w0.run(t0, [], [a])
    t1 = Txn(tid=3, read_set=[("a", a.ssn)], write_set=[("b", b"2")])
    t1.worker_id = 0
    e.allocate(t1, [a], [b])
    from repro.core import ssn as ssn_mod

    ssn_mod.writeback(t1.ssn, [b])
    e.publish(t1)
    # logger ticks must heartbeat buffer 1 past t1.ssn
    for i in range(2):
        e.logger_tick(i, force=True)
    for i in range(2):
        e.logger_tick(i, force=True)
    assert e.commit.csn >= t1.ssn
    assert e.drain(0) == 2
    assert t1.committed


def test_csn_is_min_of_dsns():
    e = _engine(3)
    workers = [Worker(e, i) for i in range(3)]
    cells = [Cell() for _ in range(3)]
    for i, w in enumerate(workers):
        w.run(Txn(tid=10 + i, write_set=[(f"k{i}", b"v")]), [], [cells[i]])
    # flush buffers 0 and 2 only
    for i in (0, 2):
        e.buffers[i].force_establish()
        e.buffers[i].flush_ready(e.devices[i])
    csn = e.commit.advance_csn()
    assert csn == e.buffers[1].dsn == 0
