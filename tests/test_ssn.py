"""Algorithm 1 (SSN allocation) unit tests, incl. the Figure 3 walkthrough."""

import pytest

from repro.core import EngineConfig, PoplarEngine, Txn, Worker
from repro.core.log_buffer import LogBuffer
from repro.core import ssn as ssn_mod


class Cell:
    def __init__(self, ssn=0):
        self.ssn = ssn


def test_figure3_walkthrough():
    """Reproduces Figure 3: T1..T4 SSN calculation across two buffers."""
    la = LogBuffer(0, capacity=1 << 20)
    lb = LogBuffer(1, capacity=1 << 20)
    la.ssn = 5
    lb.ssn = 5
    a, b, c = Cell(2), Cell(4), Cell(0)

    # T1 updates tuple a via LA: max(a.ssn=2, LA.ssn=5)+1 = 6
    s1, _, _ = ssn_mod.allocate(la, [], [a], 64)
    assert s1 == 6
    ssn_mod.writeback(s1, [a])
    assert a.ssn == 6

    # T2 reads b, overwrites a via LB: max(a=6, b=4, LB=5)+1 = 7
    s2, _, _ = ssn_mod.allocate(lb, [b], [a], 64)
    assert s2 == 7
    ssn_mod.writeback(s2, [a])

    # T3 reads a (RAW on T2), writes c via LA: max(a=7, c=0, LA=6)+1 = 8
    s3, _, _ = ssn_mod.allocate(la, [a], [c], 64)
    assert s3 == 8
    ssn_mod.writeback(s3, [c])
    # WAR: T3 read a but must NOT update a's SSN
    assert a.ssn == 7

    # T4 overwrites... (WAR predecessor T3 read a): T4 writes a via LB:
    # max(a=7, LB=7)+1 = 8 — equal to T3's SSN (WAR untracked, Fig 3)
    s4, _, _ = ssn_mod.allocate(lb, [], [a], 64)
    assert s4 == 8 == s3


def test_read_only_takes_no_slot():
    buf = LogBuffer(0, capacity=1 << 16)
    a = Cell(9)
    s, off, seg = ssn_mod.allocate(buf, [a], [], 64)
    assert s == 9 and off == -1 and seg == -1
    assert buf.offset == 0  # nothing reserved


def test_per_buffer_monotonicity():
    buf = LogBuffer(0, capacity=1 << 20)
    last = 0
    for i in range(100):
        s, _, _ = ssn_mod.allocate(buf, [], [Cell(i % 7)], 32)
        assert s > last
        last = s


def test_waw_orders_across_buffers():
    """Two writers of the same tuple through different buffers must get
    ordered SSNs (the WAW requirement of recoverability)."""
    la, lb = LogBuffer(0, capacity=1 << 16), LogBuffer(1, capacity=1 << 16)
    x = Cell(0)
    s1, _, _ = ssn_mod.allocate(la, [], [x], 32)
    ssn_mod.writeback(s1, [x])
    s2, _, _ = ssn_mod.allocate(lb, [], [x], 32)
    ssn_mod.writeback(s2, [x])
    assert s1 < s2


def test_buffer_space_backpressure():
    buf = LogBuffer(0, capacity=128)
    s, off, seg = buf.reserve(0, 100)
    with pytest.raises(TimeoutError):
        buf.reserve(0, 100, timeout=0.05)
