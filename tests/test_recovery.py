"""§5 recovery: RSNe computation, last-writer-wins, ww-past-RSNe, torn tails,
checkpoints, parallel == sequential."""

import os

import pytest

from repro.core import (
    CheckpointDaemon,
    EngineConfig,
    PoplarEngine,
    Txn,
    Worker,
    decode_records,
    recover,
)
from repro.core.recovery import compute_rsne
from repro.core.txn import LogRecord


class Cell:
    def __init__(self, ssn=0):
        self.ssn = ssn


def _engine(n=2, tmp=None):
    cfg = EngineConfig(n_buffers=n, device_kind="null", device_dir=str(tmp) if tmp else None)
    return PoplarEngine(cfg)


def test_rsne_is_min_of_device_frontiers():
    recs = [
        [LogRecord(3, 1, False, []), LogRecord(7, 2, False, [])],
        [LogRecord(5, 3, False, [])],
    ]
    assert compute_rsne(recs) == 5


def test_rsne_empty_device_pins_zero():
    recs = [[LogRecord(9, 1, False, [])], []]
    assert compute_rsne(recs) == 0


def test_wr_beyond_rsne_not_replayed():
    """Durable RAW-carrying records beyond RSNe were provably uncommitted —
    replaying them could expose reads of lost writes (scenario c)."""
    e = _engine()
    w0, w1 = Worker(e, 0), Worker(e, 1)
    a, b = Cell(), Cell()
    t0 = Txn(tid=1, write_set=[("a", b"base")])
    w0.run(t0, [], [a])
    e.quiesce([0, 1], timeout=5)
    # t1 (wr) goes to buffer 1 and IS flushed; its predecessor t2 in buffer 0
    # is NOT flushed -> crash
    t2 = Txn(tid=2, write_set=[("a", b"lost")])
    w0.run(t2, [], [a])  # buffer 0, stays in memory
    t1 = Txn(tid=3, read_set=[("a", a.ssn)], write_set=[("b", b"dirty")])
    w1.run(t1, [a], [b])
    e.buffers[1].force_establish()
    e.buffers[1].flush_ready(e.devices[1])
    # crash now: device0 has t0 (+heartbeats <= t0.ssn), device1 has t1
    st = recover(e.devices)
    assert st.get(b"a") == b"base"      # t2 lost (never durable)
    assert st.get(b"b") is None         # t1 durable but > RSNe -> skipped
    assert st.n_skipped_uncommitted >= 1


def test_ww_beyond_rsne_is_replayed():
    """Write-only records commit on their own DSN, so they replay even past
    RSNe (§5)."""
    e = _engine()
    w0, w1 = Worker(e, 0), Worker(e, 1)
    a, b = Cell(), Cell()
    t0 = Txn(tid=1, write_set=[("a", b"1")])
    w0.run(t0, [], [a])
    e.quiesce([0, 1], timeout=5)
    # ww txn in buffer 1 flushed; buffer 0 frontier stays behind
    t1 = Txn(tid=2, write_set=[("b", b"2")])
    w1.run(t1, [], [b])
    # another record in buffer 0 NOT flushed keeps RSNe at t0-era
    t2 = Txn(tid=3, write_set=[("a", b"unflushed")])
    w0.run(t2, [], [a])
    e.buffers[1].force_establish()
    e.buffers[1].flush_ready(e.devices[1])
    assert e.drain(1) == 1 and t1.committed   # ww commit: own DSN only
    st = recover(e.devices)
    assert st.rsne < t1.ssn                   # t1 is beyond RSNe...
    assert st.get(b"b") == b"2"               # ...but still recovered


def test_last_writer_wins_across_devices():
    e = _engine()
    w0, w1 = Worker(e, 0), Worker(e, 1)
    x = Cell()
    vals = []
    for i in range(6):
        w = (w0, w1)[i % 2]
        t = Txn(tid=10 + i, write_set=[("x", f"v{i}".encode())])
        w.run(t, [], [x])
        vals.append(t)
    e.quiesce([0, 1], timeout=5)
    st = recover(e.devices)
    assert st.get(b"x") == b"v5"
    # parallel and sequential recovery agree
    st2 = recover(e.devices, parallel=False)
    assert st.data == st2.data


def test_torn_tail_truncated(tmp_path):
    e = _engine(tmp=tmp_path)
    w0, w1 = Worker(e, 0), Worker(e, 1)
    a = Cell()
    for i in range(4):
        w0.run(Txn(tid=1 + i, write_set=[("a", f"v{i}".encode())]), [], [a])
    e.quiesce([0, 1], timeout=5)
    # corrupt the tail of device 0's log (torn write)
    p = e.devices[0].path
    e.devices[0].close()
    with open(p, "r+b") as f:
        f.seek(-3, os.SEEK_END)
        f.truncate()
    data = open(p, "rb").read()
    recs = decode_records(data)
    assert len(recs) >= 1           # intact prefix survives
    assert recs[-1].writes[0][1] != b"v3"  # torn record dropped


def test_checkpoint_plus_log_recovery(tmp_path):
    e = _engine(tmp=tmp_path)
    w0, w1 = Worker(e, 0), Worker(e, 1)
    cells = {f"k{i}": Cell() for i in range(20)}
    for i in range(20):
        w0.run(Txn(tid=1 + i, write_set=[(f"k{i}", f"a{i}".encode())]), [], [cells[f"k{i}"]])
    e.quiesce([0, 1], timeout=5)

    ck = CheckpointDaemon(str(tmp_path / "ckpt"), n_threads=2, m_files=2,
                          csn_fn=lambda: e.commit.csn)
    parts = [
        [(f"k{i}".encode(), f"a{i}".encode(), cells[f"k{i}"].ssn) for i in range(10)],
        [(f"k{i}".encode(), f"a{i}".encode(), cells[f"k{i}"].ssn) for i in range(10, 20)],
    ]
    ck.run_once(parts)

    # post-checkpoint writes
    for i in range(5):
        w1.run(Txn(tid=100 + i, write_set=[(f"k{i}", f"b{i}".encode())]), [], [cells[f"k{i}"]])
    e.quiesce([0, 1], timeout=5)

    st = recover(e.devices, checkpoint_dir=str(tmp_path / "ckpt"))
    assert st.rsns > 0
    for i in range(5):
        assert st.get(f"k{i}".encode()) == f"b{i}".encode()
    for i in range(5, 20):
        assert st.get(f"k{i}".encode()) == f"a{i}".encode()


def test_checkpoint_elr_validation_times_out():
    ck = CheckpointDaemon("/tmp/_ck_nonexistent_ok", n_threads=1, m_files=1,
                          csn_fn=lambda: 0)
    with pytest.raises(TimeoutError):
        ck.run_once([[(b"k", b"v", 99)]], validate_timeout=0.05)
