"""Recovery equivalence: vectorized ≡ pallas ≡ scalar ≡ threaded replay on
randomized multi-device logs with torn tails and RSNe-skipped records.

Each trial drives a real Poplar engine (stepped mode, file-backed devices)
through a random mix of write-only / RAW-carrying transactions with random
per-buffer flush interleavings, "crashes" with some records never flushed,
optionally tears the tail of one device file, and then recovers through every
replay mode — the full :class:`RecoveredState` (data incl. SSNs, rsns/rsne
watermarks, replayed/skipped counts) must match byte for byte.
"""

import os
import random

import numpy as np
import pytest

from repro.core import (
    EngineConfig,
    PoplarEngine,
    Txn,
    Worker,
    decode_columnar,
    decode_records,
    recover,
    replay_columnar,
)
from repro.core.recovery import RecoveredState, _replay_scalar, compute_rsne

KEYS = [f"k{i}" for i in range(8)] + ["k\x00nul", ""]


class _Cell:
    __slots__ = ("ssn",)

    def __init__(self):
        self.ssn = 0


def _states_equal(a: RecoveredState, b: RecoveredState) -> bool:
    return (
        a.data == b.data
        and a.rsns == b.rsns
        and a.rsne == b.rsne
        and a.n_replayed == b.n_replayed
        and a.n_skipped_uncommitted == b.n_skipped_uncommitted
    )


def _run_trial(seed: int, tmp_path) -> None:
    rng = random.Random(seed)
    n_buffers = rng.choice([1, 2, 3, 4])
    tmp = tmp_path / f"trial{seed}"
    tmp.mkdir()
    engine = PoplarEngine(
        EngineConfig(n_buffers=n_buffers, device_kind="null", device_dir=str(tmp))
    )
    workers = [Worker(engine, i) for i in range(n_buffers * 2)]
    cells = {k: _Cell() for k in KEYS}

    n_txns = rng.randrange(10, 60)
    crash_at = rng.randrange(1, n_txns + 1)
    for i in range(n_txns):
        reads = rng.sample(KEYS, rng.randrange(0, 3))
        writes = rng.sample(KEYS, rng.randrange(0, 3))
        t = Txn(
            tid=1000 + i,
            read_set=[(k, cells[k].ssn) for k in reads],
            write_set=[(k, f"{seed}/{i}/{k!r}".encode()) for k in writes],
        )
        workers[rng.randrange(len(workers))].run(
            t, [cells[k] for k in reads], [cells[k] for k in writes]
        )
        if i < crash_at:
            # random flush interleaving; beyond crash_at nothing is flushed
            for b in range(n_buffers):
                if rng.random() < 0.5:
                    engine.logger_tick(b, force=True)
            engine.commit.advance_csn()

    for d in engine.devices:
        d.close()

    # torn tail: chop a few bytes off one device's log
    if rng.random() < 0.5:
        victim = engine.devices[rng.randrange(n_buffers)]
        size = os.path.getsize(victim.path)
        if size > 4:
            with open(victim.path, "r+b") as f:
                f.seek(-rng.randrange(1, 4), os.SEEK_END)
                f.truncate()

    st_scalar = recover(engine.devices, parallel=False, mode="scalar")
    st_threaded = recover(engine.devices, parallel=True, mode="scalar")
    st_vec = recover(engine.devices, parallel=False, mode="vectorized")
    st_vec_par = recover(engine.devices, parallel=True, mode="vectorized")

    assert _states_equal(st_scalar, st_vec), seed
    assert _states_equal(st_scalar, st_vec_par), seed
    assert st_scalar.data == st_threaded.data, seed

    # pallas scatter-max apply (interpret mode) on the same logs
    st_pallas = recover(engine.devices, parallel=False, mode="pallas")
    assert _states_equal(st_scalar, st_pallas), seed


@pytest.mark.parametrize("seed", range(8))
def test_vectorized_replay_equivalence(seed, tmp_path):
    _run_trial(seed, tmp_path)


def test_ssn_tie_and_nul_key_semantics():
    """Direct-log corner cases: duplicate keys inside one record (equal SSNs
    — first write wins under the strict > guard), keys that differ only by
    trailing NULs, and a checkpoint image that wins its SSN ties."""
    def rec(ssn, writes, has_reads=False):
        t = Txn(tid=ssn, write_set=writes,
                read_set=[("r", 0)] if has_reads else [])
        t.ssn = ssn
        return t.encode()

    log0 = rec(1, [(b"a", b"first"), (b"a", b"second"), (b"a\x00", b"nul")])
    log1 = rec(2, [(b"b", b"x")], has_reads=True) + rec(3, [(b"a", b"new")])
    base = {b"a": (b"ckpt", 3), b"c": (b"keep", 1)}

    recs = [decode_records(log0), decode_records(log1)]
    cols = [decode_columnar(log0), decode_columnar(log1)]
    rsne = compute_rsne(recs)

    st = RecoveredState()
    st.data.update(base)
    _replay_scalar(st, recs, rsne, parallel=False)

    for use_kernel in (False, True):
        data, n_rep, n_skip = replay_columnar(
            cols, rsne, base=dict(base), use_kernel=use_kernel
        )
        assert data == st.data
        assert (n_rep, n_skip) == (st.n_replayed, st.n_skipped_uncommitted)

    # the checkpoint's ssn=3 ties record ssn=3: checkpoint wins (strict >)
    assert st.data[b"a"] == (b"ckpt", 3)
    # intra-record duplicate: first write of the record wins the SSN tie
    assert b"a\x00" in st.data and st.data[b"a\x00"] == (b"nul", 1)


def test_heartbeat_records_end_to_end(tmp_path):
    """`_emit_heartbeat` zero-write records: they must unpin CSN at runtime
    and RSNe at recovery, while both the columnar decode and the scalar
    replay apply no writes for them (regression for the idle-buffer liveness
    path)."""
    engine = PoplarEngine(
        EngineConfig(n_buffers=2, device_kind="null", device_dir=str(tmp_path))
    )
    w = Worker(engine, 0)  # worker 0 -> buffer 0; buffer 1 stays idle
    cells = {"a": _Cell(), "b": _Cell()}

    t1 = Txn(tid=1, write_set=[("a", b"v1")])
    w.run(t1, [], [cells["a"]])
    t2 = Txn(
        tid=2, read_set=[("a", cells["a"].ssn)], write_set=[("b", b"v2")]
    )
    w.run(t2, [cells["a"]], [cells["b"]])

    engine.logger_tick(0, force=True)  # flush buffer 0 only
    w.drain()
    assert t1.committed  # write-only: commits on its own buffer's DSN
    assert not t2.committed  # RAW-carrying: CSN pinned at 0 by idle buffer 1

    engine.logger_tick(1, force=True)  # idle buffer 1 heartbeats to frontier
    assert engine.commit.csn >= t2.ssn  # CSN unpinned
    w.drain()
    assert t2.committed

    for d in engine.devices:
        d.close()

    # the heartbeat is a zero-write tid-0 record in buffer 1's log
    cols = decode_columnar(engine.devices[1].read_all())
    assert cols.n_records >= 1
    assert (cols.n_writes == 0).all() and (cols.tid == 0).all()
    assert len(cols.wr_rec) == 0  # columnar decode carries no writes for it
    assert cols.last_ssn == t2.ssn

    expected = {b"a": (b"v1", t1.ssn), b"b": (b"v2", t2.ssn)}
    for mode in ("scalar", "vectorized", "pallas"):
        st = recover(engine.devices, parallel=False, mode=mode)
        assert st.rsne == t2.ssn, mode  # heartbeat unpins RSNe (else 0)
        assert st.data == expected, mode  # zero-write records add no keys


def test_recover_rejects_unknown_mode(tmp_path):
    engine = PoplarEngine(EngineConfig(n_buffers=1, device_kind="null"))
    with pytest.raises(ValueError):
        recover(engine.devices, mode="bogus")


def test_columnar_roundtrip_matches_rows():
    """decode_columnar(to_records) carries exactly the rows decode_records
    sees, including torn-frame truncation."""
    body = b""
    for i in range(5):
        t = Txn(tid=i, write_set=[(f"k{i}", b"v" * i)],
                read_set=[("x", 0)] if i % 2 else [])
        t.ssn = i + 1
        body += t.encode()
    torn = body[:-3]
    rows = decode_records(torn)
    cols = decode_columnar(torn)
    got = cols.to_records()
    assert [(r.ssn, r.tid, r.has_reads, r.writes) for r in rows] == [
        (r.ssn, r.tid, r.has_reads, r.writes) for r in got
    ]
    assert cols.last_ssn == rows[-1].ssn
    assert np.array_equal(cols.wr_klen, [len(k) for k, _ in sum((r.writes for r in rows), [])])
