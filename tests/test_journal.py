"""Poplar training journal: async save, marker commit semantics, crash
recovery, elastic resharding, torn-lane handling."""

import os
import time

import numpy as np
import pytest

from repro.journal import (
    JournalTails,
    PoplarCheckpointManager,
    flatten_state,
    restore_latest,
    to_pytree,
)
from repro.journal.records import decode_array, encode_array, join_slices, parse_key, split_slices


def _state(step: int):
    return {
        "params": {
            "w": np.full((8, 4), float(step), np.float32),
            "b": np.arange(4, dtype=np.float32) + step,
        },
        "opt": {"mu": np.full((8, 4), 0.1 * step, np.float32)},
        "step": np.asarray(step),
    }


def test_record_roundtrip():
    for arr in [np.asarray(3), np.arange(7, dtype=np.float32),
                np.ones((3, 5), np.float16), np.zeros((2, 2, 2), np.int32)]:
        out = decode_array(encode_array(arr))
        np.testing.assert_array_equal(arr, out)
        assert arr.shape == out.shape and arr.dtype == out.dtype


def test_slices_roundtrip():
    arr = np.arange(24, dtype=np.float32).reshape(6, 4)
    parts = split_slices(arr, 4)
    np.testing.assert_array_equal(join_slices(parts), arr)
    assert parse_key("STEP/0000000000000007") == {"kind": "marker", "step": 7}
    info = parse_key("0000000000000003/['params']['w']#1/4")
    assert info == {"kind": "shard", "step": 3, "path": "['params']['w']",
                    "slice": 1, "n_slices": 4}


def test_save_restore_roundtrip(tmp_path):
    mgr = PoplarCheckpointManager(str(tmp_path), n_lanes=3, device_kind="ssd",
                                  flush_interval=1e-3)
    for step in range(3):
        mgr.save(step, _state(step)).wait()
    mgr.wait_for_commit(2, timeout=30)
    mgr.close()

    step, st, meta = restore_latest(str(tmp_path))
    assert step == 2 and meta["step"] == 2
    tree = to_pytree(st, _state(0))
    np.testing.assert_array_equal(tree["params"]["w"], _state(2)["params"]["w"])
    np.testing.assert_array_equal(tree["step"], np.asarray(2))


def test_crash_falls_back_to_committed_step(tmp_path):
    mgr = PoplarCheckpointManager(str(tmp_path), n_lanes=2, device_kind="ssd",
                                  flush_interval=1e-3)
    mgr.save(0, _state(0)).wait()
    mgr.save(1, _state(1)).wait()
    mgr.wait_for_commit(1, timeout=30)
    # step 2: logged into buffers but loggers are killed before flushing
    h = mgr.save(2, _state(2))
    h.wait()          # logged (in volatile buffers), NOT necessarily durable
    mgr.crash()       # no quiesce, no flush

    out = restore_latest(str(tmp_path))
    assert out is not None
    step, st, meta = out
    assert step <= 2  # step 2 only if its marker made it to disk before crash
    if step < 2:
        tree = to_pytree(st, _state(0))
        np.testing.assert_array_equal(tree["params"]["w"], _state(step)["params"]["w"])


def test_elastic_resharding(tmp_path):
    """Save with 4 slices/lanes; restore merges regardless of topology."""
    mgr = PoplarCheckpointManager(str(tmp_path), n_lanes=4, device_kind="ssd",
                                  flush_interval=1e-3, n_slices=4)
    big = {"w": np.arange(64, dtype=np.float32).reshape(16, 4)}
    mgr.save(0, big).wait()
    mgr.wait_for_commit(0, timeout=30)
    mgr.close()
    step, st, _ = restore_latest(str(tmp_path))
    np.testing.assert_array_equal(st["['w']"], big["w"])
    # parallel and sequential restore agree
    step2, st2, _ = restore_latest(str(tmp_path), parallel=False)
    assert step2 == step
    np.testing.assert_array_equal(st2["['w']"], st["['w']"])


def test_torn_lane_tail(tmp_path):
    mgr = PoplarCheckpointManager(str(tmp_path), n_lanes=2, device_kind="ssd",
                                  flush_interval=1e-3)
    for step in range(3):
        mgr.save(step, _state(step)).wait()
    mgr.wait_for_commit(2, timeout=30)
    mgr.close()
    # tear the tail of lane 0
    lane0 = os.path.join(str(tmp_path), "log_0.bin")
    with open(lane0, "r+b") as f:
        f.seek(-5, os.SEEK_END)
        f.truncate()
    out = restore_latest(str(tmp_path))
    assert out is not None
    step, st, _ = out
    # whatever step is chosen must be complete and consistent
    tree = to_pytree(st, _state(0))
    np.testing.assert_array_equal(tree["params"]["w"], _state(step)["params"]["w"])


def test_marker_blocks_on_lagging_lane(tmp_path):
    """A step marker must not commit while any lane holding its shards is
    unflushed (CSN semantics at the framework level)."""
    mgr = PoplarCheckpointManager(str(tmp_path), n_lanes=2, device_kind="ssd",
                                  flush_interval=3600.0)  # loggers effectively idle
    try:
        h = mgr.save(0, _state(0))
        h.wait()
        assert mgr.last_committed_step() == -1  # nothing flushed yet
        # manually flush only lane 0: marker must still be blocked
        mgr.engine.buffers[0].force_establish()
        mgr.engine.buffers[0].flush_ready(mgr.engine.devices[0])
        mgr.engine.commit.advance_csn()
        assert mgr.last_committed_step() == -1
        # flush lane 1 and heartbeat: marker commits
        for _ in range(3):
            for i in range(2):
                mgr.engine.logger_tick(i, force=True)
        assert mgr.last_committed_step() == 0
    finally:
        mgr.close()


def test_incremental_restore_with_tails(tmp_path):
    """Repeated restores through one :class:`JournalTails` read only the
    bytes appended since the previous probe (no O(n²) lane re-reads) and
    agree with a cold full restore after every step."""
    mgr = PoplarCheckpointManager(str(tmp_path), n_lanes=2, device_kind="ssd",
                                  flush_interval=1e-3)
    tails = JournalTails()
    for step in range(3):
        mgr.save(step, _state(step)).wait()
        mgr.wait_for_commit(step, timeout=30)
        inc = restore_latest(str(tmp_path), tails=tails)
        full = restore_latest(str(tmp_path))
        assert inc is not None and full is not None
        assert inc[0] == full[0] == step and inc[2] == full[2]
        assert inc[1].keys() == full[1].keys()
        for k in inc[1]:
            np.testing.assert_array_equal(inc[1][k], full[1][k])
    mgr.close()
    # every lane was decoded exactly once end-to-end: the tailers' shipped
    # record totals equal the lanes' record counts (nothing re-decoded)
    from repro.core import decode_columnar

    for path, sh in tails._shippers.items():
        with open(path, "rb") as f:
            assert sh.n_shipped == decode_columnar(f.read()).n_records
        assert sh.consumed == os.path.getsize(path)


def test_journal_tails_concurrent_probes(tmp_path):
    """Concurrent lane() calls on one JournalTails must not double-consume:
    the per-lane lock makes poll+splice atomic, so the tailer ends exactly
    at the file frontier having decoded each record once."""
    import threading

    from repro.core import Txn, decode_columnar

    path = os.path.join(str(tmp_path), "log_0.bin")
    tails = JournalTails()

    def writer():
        for i in range(50):
            t = Txn(tid=i, write_set=[(f"k{i}", b"v" * (i % 7))])
            t.ssn = i + 1
            with open(path, "ab") as f:
                f.write(t.encode())

    open(path, "wb").close()
    threads = [threading.Thread(target=writer)] + [
        threading.Thread(target=lambda: [tails.lane(path) for _ in range(40)])
        for _ in range(3)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    final = tails.lane(path)
    with open(path, "rb") as f:
        blob = f.read()
    want = decode_columnar(blob)
    assert final.n_records == want.n_records == 50
    sh = tails._shippers[path]
    assert sh.consumed == len(blob) and sh.n_shipped == 50


def test_columnar_restore_matches_scan_oracle(tmp_path):
    """The columnar lane restore (default) and the original per-record scan
    must agree — including on a torn lane tail and superseded step shards."""
    mgr = PoplarCheckpointManager(str(tmp_path), n_lanes=3, device_kind="ssd",
                                  flush_interval=1e-3, n_slices=2)
    for step in range(4):
        mgr.save(step, _state(step)).wait()
    mgr.wait_for_commit(3, timeout=30)
    mgr.close()
    with open(os.path.join(str(tmp_path), "log_1.bin"), "r+b") as f:
        f.seek(-3, os.SEEK_END)
        f.truncate()

    out_col = restore_latest(str(tmp_path), columnar=True)
    out_scan = restore_latest(str(tmp_path), columnar=False)
    assert (out_col is None) == (out_scan is None)
    step_c, st_c, meta_c = out_col
    step_s, st_s, meta_s = out_scan
    assert step_c == step_s and meta_c == meta_s
    assert st_c.keys() == st_s.keys()
    for k in st_c:
        np.testing.assert_array_equal(st_c[k], st_s[k])
