"""Bucket padding and compiled-hot-path properties.

Three contracts of the compiled (``mode="pallas"``) OLTP path:

* **padding non-interference** — bucket-padded/masked lanes never influence
  results: the fused ``BatchOCC`` pass (forced on by zeroing its engagement
  threshold) stays byte-equivalent to the scalar oracle across the edge
  cases where padding is most load-bearing (empty batch, single record,
  bucket-boundary sizes, lane-blowup fallback, ragged access counts);
* **bounded compilation** — a 100-batch stream of varied sizes compiles at
  most one specialization per bucket-ladder rung per fused op;
* **guarded narrowing** — values outside int32 never silently wrap: the
  cast helpers raise, and the replay path falls back to numpy yet stays
  equivalent at SSNs beyond 2^31 (the regression for the old blind
  ``.astype(np.int32)``).
"""

import random

import numpy as np
import pytest

from repro.core import EngineConfig, PoplarEngine, Txn, recover
from repro.core.storage import DeviceSpec, StorageDevice
from repro.db import ArrayTable, BatchOCC, ScalarBatchOCC, Table, TxnSpec
from repro.db import ycsb
from repro.kernels.bucketing import (I32_MAX, bucket, checked_i32, fits_i32,
                                     ladder, pad_i32, stack_i32)

# --- unit: the padding helpers -------------------------------------------------


def test_bucket_ladder_shapes():
    assert bucket(0) == 8 and bucket(8) == 8 and bucket(9) == 16
    assert bucket(1024) == 1024 and bucket(1025) == 2048
    assert bucket(1, min_size=1) == 1 and bucket(3, min_size=1) == 4
    assert ladder(100) == [8, 16, 32, 64, 128]
    assert ladder(8) == [8]
    # the compile-count contract: sizes 1..max_n land on ladder rungs only
    for n in range(1, 200):
        assert bucket(n) in ladder(200)


def test_checked_i32_guards():
    ok = np.array([0, I32_MAX, -(2**31)], dtype=np.int64)
    assert fits_i32(ok) and checked_i32(ok).dtype == np.int32
    bad = np.array([1, 2**31], dtype=np.int64)
    assert not fits_i32(bad)
    assert not fits_i32(ok, bad)  # any offending array poisons the set
    assert fits_i32(np.empty(0, np.int64))
    with pytest.raises(OverflowError, match="ssn"):
        checked_i32(bad, "ssn")


def test_pad_and_stack_i32():
    a = np.array([5, 6], dtype=np.int64)
    p = pad_i32(a, 8, fill=-1)
    assert p.tolist() == [5, 6, -1, -1, -1, -1, -1, -1]
    s = stack_i32([a, np.array([7, 8])], 4, fills=(0, 9))
    assert s.dtype == np.int32 and s.shape == (2, 4)
    assert s.tolist() == [[5, 6, 0, 0], [7, 8, 9, 9]]
    with pytest.raises(OverflowError):
        stack_i32([np.array([2**31])], 4, fills=(0,))


# --- fused BatchOCC edge cases vs the scalar oracle ----------------------------


def _mk_engine(tmp_path, tag, n_buffers=2):
    d = tmp_path / tag
    d.mkdir()
    return PoplarEngine(
        EngineConfig(n_buffers=n_buffers, device_kind="null",
                     device_dir=str(d), flush_interval=60.0)
    )


def _mk_pair(tmp_path, tag, mode, fused_min_lanes, n_keys=12):
    keys = [ycsb.key_of(i) for i in range(n_keys)]
    tab_s, tab_v = Table(), ArrayTable()
    for k in keys[: n_keys // 2]:
        tab_s.insert(k, b"seed")
        tab_v.insert(k, b"seed")
    oracle = ScalarBatchOCC(tab_s, _mk_engine(tmp_path, tag + "_s"), n_workers=4)
    batched = BatchOCC(tab_v, _mk_engine(tmp_path, tag + "_v"), n_workers=4,
                       mode=mode)
    batched.fused_min_lanes = fused_min_lanes
    return keys, tab_s, tab_v, oracle, batched


def _check_batches(keys, tab_s, tab_v, oracle, batched, batches, max_rounds=2):
    for specs in batches:
        rs = oracle.execute_batch(specs, max_rounds=max_rounds)
        rv = batched.execute_batch(specs, max_rounds=max_rounds)
        assert rs.committed_idx == rv.committed_idx
        assert rs.aborted == rv.aborted
        for ts, tv in zip(rs.committed, rv.committed):
            assert (ts.tid, ts.ssn) == (tv.tid, tv.ssn)
        oracle.drain()
        batched.drain()
    state_s = {k: (tab_s.get(k).value, tab_s.get(k).ssn)
               for k in keys if tab_s.get(k)}
    state_v = {k: tab_v.get(k) for k in keys if tab_v.get(k) is not None}
    assert state_s == state_v


# fused_min_lanes=0 forces the device pass on arbitrarily small batches, so
# these edge shapes exercise real padding lanes, not the numpy fallback
@pytest.mark.parametrize("mode,fused_min_lanes", [
    ("vectorized", 2048), ("pallas", 2048), ("pallas", 0),
])
def test_edge_batches_vs_oracle(tmp_path, mode, fused_min_lanes):
    rng = random.Random(31)
    keys, tab_s, tab_v, oracle, batched = _mk_pair(
        tmp_path, f"edge_{mode}_{fused_min_lanes}", mode, fused_min_lanes)

    def spec(n_writes, n_reads=0):
        ws = [(k, rng.randbytes(rng.randrange(0, 24)))
              for k in rng.sample(keys, n_writes)]
        rd = rng.sample(keys, n_reads)
        return TxnSpec(reads=rd, writes=ws or [(keys[0], b"w")])

    batches = [
        [],                                        # empty batch
        [spec(1)],                                 # single record
        [spec(rng.randrange(1, 3)) for _ in range(7)],   # below bucket edge
        [spec(rng.randrange(1, 3)) for _ in range(8)],   # exactly on it
        [spec(rng.randrange(1, 3)) for _ in range(9)],   # just past it
        # ragged access counts: padding lanes replicate each txn's last
        # access — masked, they must not add phantom conflicts
        [spec(1), spec(3, 2), spec(1, 1), spec(2), spec(3)],
    ]
    _check_batches(keys, tab_s, tab_v, oracle, batched, batches)


def test_lane_blowup_falls_back_correctly(tmp_path):
    """One wide transaction among many narrow ones makes the dense (n_txn, k)
    layout blow past its lane budget: `_fused_round` must decline (return
    None) and the numpy fallback must keep oracle equivalence."""
    rng = random.Random(32)
    keys = [ycsb.key_of(i) for i in range(80)]
    tab_s, tab_v = Table(), ArrayTable()
    oracle = ScalarBatchOCC(tab_s, _mk_engine(tmp_path, "blow_s"), n_workers=4)
    batched = BatchOCC(tab_v, _mk_engine(tmp_path, "blow_v"), n_workers=4,
                       mode="pallas")
    batched.fused_min_lanes = 0

    wide = TxnSpec(reads=[], writes=[(k, b"wide") for k in keys[:64]])
    narrow = [TxnSpec(reads=[], writes=[(rng.choice(keys), b"n%d" % i)])
              for i in range(100)]
    specs = [wide] + narrow
    # k = bucket(64) = 64, n_txn = bucket(101) = 128 -> 8192 lanes, far past
    # max(4 * total, 4096) with total = 164: the fused layout must decline
    total = sum(len(s.writes) + len(s.reads) for s in specs)
    assert bucket(64, min_size=1) * bucket(101) > max(4 * total, 4096)
    rs = oracle.execute_batch(specs, max_rounds=2)
    rv = batched.execute_batch(specs, max_rounds=2)
    assert rs.committed_idx == rv.committed_idx
    oracle.drain()
    batched.drain()
    assert {k: (tab_s.get(k).value, tab_s.get(k).ssn)
            for k in keys if tab_s.get(k)} == \
           {k: tab_v.get(k) for k in keys if tab_v.get(k) is not None}


# --- bounded compilation over a varied-size stream -----------------------------


def test_jit_cache_bounded_over_varied_stream(tmp_path):
    """100 batches of varied sizes/access widths through the forced fused
    path: each fused op may hold at most one specialization per bucket-ladder
    rung actually touched — re-tracing per exact shape would fail this."""
    from repro.kernels.ops import fused_cache_sizes

    rng = random.Random(33)
    n_keys = 64
    keys = [ycsb.key_of(i) for i in range(n_keys)]
    tab = ArrayTable()
    occ = BatchOCC(tab, _mk_engine(tmp_path, "stream"), n_workers=4,
                   mode="pallas")
    occ.fused_min_lanes = 0

    before = fused_cache_sizes()
    max_lanes = 0
    for i in range(100):
        bsz = rng.randrange(1, 40)
        specs = [
            TxnSpec(reads=rng.sample(keys, rng.randrange(0, 2)),
                    writes=[(k, b"v%d" % i)
                            for k in rng.sample(keys, rng.randrange(1, 4))])
            for _ in range(bsz)
        ]
        max_lanes = max(max_lanes, bucket(bsz) * bucket(3, min_size=1))
        occ.execute_batch(specs, max_rounds=2)
        occ.drain()
    after = fused_cache_sizes()
    bound = len(ladder(max_lanes))
    for op in ("fused_validate_sequence",):
        grown = after[op] - before[op]
        assert 0 < grown <= bound, (op, grown, bound, after)
    # nothing else may have specialized per-batch either
    for op, n in after.items():
        assert n - before.get(op, 0) <= bound, (op, n, before)


# --- int32 narrowing regression: SSNs beyond 2^31 ------------------------------


def _synth_devices(ssn_base: int, n_records: int = 60, n_devices: int = 2):
    rng = random.Random(41)
    devs = [StorageDevice(DeviceSpec.null(), clock="virtual")
            for _ in range(n_devices)]
    ssn = ssn_base
    for i in range(n_records):
        ssn += 1
        t = Txn(tid=i, write_set=[(f"k{rng.randrange(12)}", b"v%d" % i)],
                read_set=[("dep", 0)] if rng.random() < 0.3 else [])
        t.ssn = ssn
        devs[i % n_devices].write(t.encode())
    for j, d in enumerate(devs):
        d.seal(ssn - (n_devices - 1 - j))
    return devs


@pytest.mark.parametrize("ssn_base", [0, 2**31 - 30, 2**40])
def test_replay_beyond_i32_matches_scalar(ssn_base):
    """SSNs straddling and beyond 2^31: the kernel paths must detect the
    overflow and fall back (never wrap) — all three modes byte-equal."""
    devs = _synth_devices(ssn_base)
    ref = recover(devs, mode="scalar", parallel=False)
    for mode in ("vectorized", "pallas"):
        st = recover(devs, mode=mode, parallel=False)
        assert st.data == ref.data, (mode, ssn_base)
        assert (st.rsne, st.n_replayed, st.n_skipped_uncommitted) == (
            ref.rsne, ref.n_replayed, ref.n_skipped_uncommitted)


# --- interpret-mode override ---------------------------------------------------


def test_force_interpret_env(monkeypatch):
    import jax

    from repro.kernels import ops

    try:
        ops._default_interpret.cache_clear()
        monkeypatch.setenv("REPRO_FORCE_INTERPRET", "1")
        assert ops._auto_interpret(None) is True
        # the probe is cached: flipping the env without a new process (or
        # cache_clear) must not change the answer
        monkeypatch.setenv("REPRO_FORCE_INTERPRET", "0")
        assert ops._auto_interpret(None) is True
        ops._default_interpret.cache_clear()
        assert ops._auto_interpret(None) == (jax.default_backend() != "tpu")
        # explicit wins over the probe either way
        assert ops._auto_interpret(True) is True
        assert ops._auto_interpret(False) is False
    finally:
        ops._default_interpret.cache_clear()
