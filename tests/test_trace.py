"""Tracer semantics: ring buffer behavior, the disabled-tracer zero-cost
contract, and structural determinism of DAG dumps.

The two load-bearing guarantees pinned here:

* disabled tracing allocates nothing and touches nothing beyond one bool
  load per hook (tracemalloc filtered to ``trace/span.py`` over a tight
  ``execute_batch`` loop);
* the *structural* trace of a deterministic stepped serve run is
  byte-identical across two fresh runs — timestamps differ, the DAG does
  not — which is what makes ``BENCH_trace_dump.json`` diffable and the
  critical-path attribution reproducible.
"""

import json
import tracemalloc

import numpy as np
import pytest

from repro.core import EngineConfig
from repro.db.batch import TxnSpec
from repro.db.ycsb import key_of
from repro.serve import GroupCommitScheduler, ServeConfig, SingleBackend
from repro.trace import (
    ST_ACK,
    ST_CUT,
    ST_DRIVER,
    ST_ENCODE,
    ST_FLUSH,
    ST_PUBLISH,
    ST_SEQUENCE,
    ST_VALIDATE,
    STAGE_NAMES,
    TRACER,
    TraceDump,
    Tracer,
    build_dag,
    critical_path,
    disable,
    enable,
)


@pytest.fixture(autouse=True)
def _disarm():
    """Every test leaves the process tracer disarmed and empty."""
    yield
    TRACER.enabled = False
    TRACER.reset()


# --- ring buffer unit tests ---------------------------------------------------

def test_record_and_dump_roundtrip():
    tr = Tracer(capacity=8)
    tr.record(ST_VALIDATE, shard=1, device=2, batch=3, txn_lo=10, txn_hi=20,
              t0=1.0, t1=2.5, nbytes=100, n_txn=7, aux=9)
    d = tr.dump()
    assert d.n == 1 and d.dropped == 0
    assert d.stage[0] == ST_VALIDATE and d.shard[0] == 1
    assert d.device[0] == 2 and d.batch[0] == 3
    assert (d.txn_lo[0], d.txn_hi[0]) == (10, 20)
    assert d.nbytes[0] == 100 and d.n_txn[0] == 7 and d.aux[0] == 9
    assert d.duration()[0] == pytest.approx(1.5)
    assert d.makespan() == pytest.approx(1.5)


def test_ring_wraparound_keeps_newest_and_counts_dropped():
    tr = Tracer(capacity=4)
    for i in range(10):
        tr.record(ST_DRIVER, batch=i)
    d = tr.dump()
    assert d.n == 4
    assert d.dropped == 6
    assert d.batch.tolist() == [6, 7, 8, 9]  # oldest-first, newest kept


def test_reset_clears_rows_and_batch_sequence():
    tr = Tracer(capacity=4)
    tr.record(ST_DRIVER)
    assert tr.next_batch_id() == 1
    tr.reset()
    assert tr.dump().n == 0
    assert tr.next_batch_id() == 1  # sequences restart: reruns align
    tr.reset(capacity=16)
    assert tr.capacity == 16


def test_dump_save_load_roundtrip(tmp_path):
    tr = Tracer(capacity=8)
    tr.record(ST_PUBLISH, shard=0, device=1, batch=2, txn_lo=5, txn_hi=9,
              t0=0.5, t1=0.7, nbytes=64, n_txn=5)
    p = str(tmp_path / "dump.json")
    d = tr.dump()
    d.save(p)
    d2 = TraceDump.load(p)
    assert d2.structural_dict() == d.structural_dict()
    assert np.allclose(d2.t0, d.t0) and np.allclose(d2.t1, d.t1)


def test_enable_disable_round():
    enable(capacity=32)
    assert TRACER.enabled and TRACER.capacity == 32
    TRACER.record(ST_DRIVER)
    d = disable()
    assert not TRACER.enabled and d.n == 1


def test_stage_names_cover_taxonomy():
    assert len(STAGE_NAMES) == 14
    assert STAGE_NAMES[ST_VALIDATE] == "validate"
    assert STAGE_NAMES[ST_FLUSH] == "flush"
    assert STAGE_NAMES[ST_DRIVER] == "driver"


# --- disabled-tracer cost contract -------------------------------------------

def _stepped_sched(tmp_path, sub="a"):
    cfg = EngineConfig(n_buffers=2, device_kind="null",
                       device_dir=str(tmp_path / sub))
    backend = SingleBackend.make("vectorized", n_workers=2, cfg=cfg)
    return GroupCommitScheduler(
        backend, ServeConfig(max_batch=16, latency_budget_steps=1)
    )


def test_disabled_tracer_allocates_nothing(tmp_path):
    """tracemalloc filtered to span.py: a tight execute_batch loop with the
    tracer disabled must not allocate a single block in the tracer module
    (the hooks reduce to one attribute load + a false branch)."""
    sched = _stepped_sched(tmp_path)
    for i in range(32):
        sched.submit(TxnSpec(writes=[(key_of(i), b"w")]))
    sched.step()  # warm up every code path before measuring

    assert not TRACER.enabled
    flt = tracemalloc.Filter(True, "*trace/span.py")
    tracemalloc.start()
    try:
        for i in range(32, 160):
            sched.submit(TxnSpec(writes=[(key_of(i), b"w")]))
            sched.step()
        snap = tracemalloc.take_snapshot().filter_traces([flt])
    finally:
        tracemalloc.stop()
    assert sum(s.size for s in snap.statistics("filename")) == 0


def test_disabled_tracer_records_nothing(tmp_path):
    sched = _stepped_sched(tmp_path)
    for i in range(8):
        sched.submit(TxnSpec(writes=[(key_of(i), b"w")]))
    sched.run_until_drained()
    assert TRACER.dump().n == 0


# --- structural determinism ---------------------------------------------------

def _traced_serve_run(tmp_path, sub):
    """One deterministic stepped serve run, traced end to end."""
    enable()
    try:
        sched = _stepped_sched(tmp_path, sub)
        for i in range(64):
            sched.submit(TxnSpec(writes=[(key_of(i % 40), bytes([i % 251]))]))
            if i % 4 == 3:
                sched.step()
        sched.run_until_drained()
    finally:
        dump = disable()
    return dump


def test_two_identical_runs_dump_identical_dags(tmp_path):
    d1 = _traced_serve_run(tmp_path, "r1")
    d2 = _traced_serve_run(tmp_path, "r2")
    assert d1.n > 0
    # raw wall-clock columns differ between runs ...
    # ... but the structural dump (and hence the DAG) is byte-identical
    s1 = json.dumps(d1.structural_dict(), sort_keys=True).encode()
    s2 = json.dumps(d2.structural_dict(), sort_keys=True).encode()
    assert s1 == s2
    g1, g2 = build_dag(d1), build_dag(d2)
    assert g1.canonical_bytes() == g2.canonical_bytes()
    assert g1.fingerprint() == g2.fingerprint()


def test_serve_trace_covers_expected_stages(tmp_path):
    d = _traced_serve_run(tmp_path, "r3")
    stages = set(d.stage.tolist())
    for st in (ST_VALIDATE, ST_SEQUENCE, ST_ENCODE, ST_PUBLISH, ST_FLUSH,
               ST_CUT, ST_ACK):
        assert st in stages, f"missing stage {STAGE_NAMES[st]}"


def test_critical_path_attribution_partitions_makespan(tmp_path):
    d = _traced_serve_run(tmp_path, "r4")
    dag = build_dag(d)
    _, attr = critical_path(dag)
    total = sum(attr.values())
    # the walk partitions [start of earliest span, end of last] exactly:
    # stage segments + explicit wait, nothing double counted
    assert total == pytest.approx(d.makespan(), rel=1e-9)
    assert all(v >= 0 for v in attr.values())
