"""Fast tile decode + compiled fused recovery: equivalence and fallbacks.

``decode_fast_tile`` must be byte-equivalent to the scalar-walk columnar
decode on everything it accepts — including torn tails and mid-blob
corruption (same truncation point) — and must *decline* (return ``None``)
on out-of-profile blobs instead of guessing.  The seal-time segment crc
must round-trip through the manifest and let the tile decode skip per-frame
verification only when the whole-blob check passes.  On top of that,
``_fused_tile_winners`` must equal the exact ``_group_winners`` reduction
under adversarial hashes (slot spills, full 64-bit collisions), and
``recover(mode="pallas")`` must stay state-identical to the scalar oracle
whether the fused pipeline engages or falls back.
"""

import json
import random
import zlib

import numpy as np
import pytest

from repro.core import Txn, recover
from repro.core.fastdecode import MAX_FAST_WRITES, decode_fast_tile
from repro.core.recovery import _fused_tile_winners, _group_winners
from repro.core.storage import DeviceSpec, StorageDevice
from repro.core.txn import decode_columnar, decode_columnar_stream


def _mk_txns(rng, n, n_keys=10, wr_frac=0.3, max_writes=3, ssn_base=0):
    txns = []
    for i in range(n):
        t = Txn(
            tid=1000 + i,
            write_set=[(f"key{rng.randrange(n_keys)}",
                        rng.randbytes(rng.randrange(0, 40)))
                       for _ in range(rng.randrange(0, max_writes + 1))],
            read_set=[("dep", 0)] if rng.random() < wr_frac else [],
        )
        t.ssn = ssn_base + i + 1
        txns.append(t)
    return txns


def _blob(txns):
    return b"".join(t.encode() for t in txns)


def _assert_tile_equals_columnar(blob, crc=None):
    tile = decode_fast_tile(blob, crc=crc)
    assert tile is not None
    col, consumed = decode_columnar_stream(blob)
    assert tile.consumed == consumed
    np.testing.assert_array_equal(tile.ssn, col.ssn)
    np.testing.assert_array_equal(tile.has_reads, col.has_reads)
    np.testing.assert_array_equal(tile.wr_rec, col.wr_rec)
    assert tile.keys_fixed.tolist() == col.keys_fixed.tolist()
    all_lanes = np.arange(len(tile.wr_rec))
    assert tile.values_for(all_lanes) == col.values
    return tile


def test_fast_tile_matches_columnar_decode():
    rng = random.Random(1)
    blob = _blob(_mk_txns(rng, 120))
    _assert_tile_equals_columnar(blob)
    # trusted whole-blob crc: same result, per-frame verification skipped
    _assert_tile_equals_columnar(blob, crc=zlib.crc32(blob))
    # empty blob
    t = decode_fast_tile(b"")
    assert t is not None and t.n_records == 0 and t.consumed == 0


def test_fast_tile_torn_tail_truncates_like_scalar():
    rng = random.Random(2)
    blob = _blob(_mk_txns(rng, 40))
    for cut in (len(blob) - 1, len(blob) - 7, len(blob) // 2 + 3):
        _assert_tile_equals_columnar(blob[:cut])


def test_fast_tile_corruption_truncates_like_scalar():
    rng = random.Random(3)
    txns = _mk_txns(rng, 40)
    blob = _blob(txns)
    # flip a byte inside a mid-blob record's payload: the frame crc catches
    # it and both decoders drop that record and everything after it
    mid = sum(len(t.record) for t in txns[:20]) + 12
    bad = blob[:mid] + bytes([blob[mid] ^ 0xFF]) + blob[mid + 1:]
    tile = _assert_tile_equals_columnar(bad)
    assert tile.n_records == 20
    # a stale seal crc (computed over the uncorrupted bytes) must NOT be
    # trusted: the whole-blob check fails and per-frame truncation applies
    tile2 = decode_fast_tile(bad, crc=zlib.crc32(blob))
    assert tile2.n_records == 20 and tile2.consumed == tile.consumed


def test_fast_tile_declines_out_of_profile():
    rng = random.Random(4)
    # XSHARD footer
    txns = _mk_txns(rng, 10)
    txns[5].xdep = [(1, 3)]
    assert decode_fast_tile(_blob(txns)) is None
    # write count beyond the fast-path bound
    wide = Txn(tid=1, write_set=[(f"w{j}", b"x")
                                 for j in range(MAX_FAST_WRITES + 1)])
    wide.ssn = 1
    assert decode_fast_tile(wide.encode()) is None


# --- seal-time segment crc -----------------------------------------------------


def test_seal_crc_memory_device():
    rng = random.Random(5)
    d = StorageDevice(DeviceSpec.null(), clock="virtual")
    parts = [_blob(_mk_txns(rng, 5, ssn_base=i * 5)) for i in range(3)]
    for p in parts[:2]:
        d.write(p)
    seg = d.seal(10)
    assert seg.crc == zlib.crc32(parts[0] + parts[1])
    d.write(parts[2])
    ents = d.read_segment_entries()
    assert ents[0] == (parts[0] + parts[1], seg.crc, 10)
    assert ents[1] == (parts[2], None, None)


def test_seal_crc_manifest_roundtrip(tmp_path):
    rng = random.Random(6)
    path = str(tmp_path / "dev.log")
    d = StorageDevice(DeviceSpec.null(), path=path, clock="virtual")
    b1 = _blob(_mk_txns(rng, 8))
    b2 = _blob(_mk_txns(rng, 8, ssn_base=8))
    d.write(b1)
    seg = d.seal(8)
    d.write(b2)
    d.close()
    assert seg.crc == zlib.crc32(b1)

    # reopen: manifest carries the sealed crc; the tail's running crc is
    # rebuilt from the file so a post-reopen seal stamps the right value
    d2 = StorageDevice(DeviceSpec.null(), path=path, clock="virtual")
    ents = d2.read_segment_entries()
    assert ents[0][1] == seg.crc and ents[0][2] == 8
    seg2 = d2.seal(16)
    assert seg2.crc == zlib.crc32(b2)
    d2.close()


def test_pre_crc_manifest_still_recovers(tmp_path):
    """A manifest written before seal crcs existed (no ``crc`` key) loads as
    ``crc=None`` and the fused pipeline verifies frames individually."""
    rng = random.Random(7)
    path = str(tmp_path / "dev.log")
    d = StorageDevice(DeviceSpec.null(), path=path, clock="virtual")
    d.write(_blob(_mk_txns(rng, 30)))
    d.seal(30)
    d.write(_blob(_mk_txns(rng, 10, ssn_base=30)))
    d.close()
    mpath = path + ".segments.json"
    with open(mpath) as f:
        m = json.load(f)
    for s in m["sealed"]:
        del s["crc"]
    with open(mpath, "w") as f:
        json.dump(m, f)

    d2 = StorageDevice(DeviceSpec.null(), path=path, clock="virtual")
    assert d2.read_segment_entries()[0][1] is None
    ref = recover([d2], mode="scalar", parallel=False)
    st = recover([d2], mode="pallas", parallel=False)
    assert st.data == ref.data and st.rsne == ref.rsne
    d2.close()


# --- fused recovery: equivalence and fallback ----------------------------------


def _seg_devices(rng, n_devices=2, n_records=200, tear=False, xshard=False):
    devs = []
    for di in range(n_devices):
        txns = _mk_txns(rng, n_records, ssn_base=di * n_records)
        if xshard and di == 0:
            txns[n_records // 2].xdep = [(1, 7)]
        d = StorageDevice(DeviceSpec.null(), clock="virtual")
        third = n_records // 3
        d.write(_blob(txns[:third]))
        d.seal(txns[third - 1].ssn)
        d.write(_blob(txns[third: 2 * third]))
        d.seal(txns[2 * third - 1].ssn)
        tail = _blob(txns[2 * third:])
        if tear and di == 0:
            tail = tail[: len(tail) - 9]
        d.write(tail)
        devs.append(d)
    return devs


@pytest.mark.parametrize("tear,xshard", [
    (False, False),   # fused pipeline engages
    (True, False),    # torn tail: truncation inside the fused tail decode
    (False, True),    # XSHARD record: fused declines, columnar path serves
])
def test_fused_recover_equals_scalar(tear, xshard):
    rng = random.Random(11)
    devs = _seg_devices(rng, tear=tear, xshard=xshard)
    ref = recover(devs, mode="scalar", parallel=False)
    for parallel in (False, True):
        st = recover(devs, mode="pallas", parallel=parallel)
        assert st.data == ref.data, (tear, xshard, parallel)
        assert (st.rsne, st.n_replayed, st.n_skipped_uncommitted) == (
            ref.rsne, ref.n_replayed, ref.n_skipped_uncommitted)


def test_fused_tile_winners_equals_group_winners(monkeypatch):
    """Device hash-slot winners == exact reduction, also under adversarial
    hashes: a slot-spill-heavy hash (distinct hashes crowded into 4 slots)
    and a colliding hash (distinct keys, equal 64-bit hash → whole-tile
    exact fallback).  Both monkeypatched hashes remain functions of the key
    words, preserving the 'equal keys hash equal' invariant the repair
    logic relies on."""
    from repro.core import recovery as rec

    rng = random.Random(12)
    # > _FUSED_MIN_LANES committed write lanes, heavy key duplication
    txns = _mk_txns(rng, 1600, n_keys=300, wr_frac=0.2, max_writes=2)
    tile = decode_fast_tile(_blob(txns))
    assert tile is not None and len(tile.wr_rec) > rec._FUSED_MIN_LANES
    rsne = int(tile.ssn[-1])

    def winners_exact():
        ok = tile.committed_mask(rsne)
        lanes = np.flatnonzero(ok[tile.wr_rec])
        w, _, _ = _group_winners(tile.keys_fixed[lanes], tile.wr_ssn[lanes],
                                 np.arange(len(lanes), dtype=np.int64))
        return sorted(lanes[w].tolist())

    ref = winners_exact()
    real_hash = rec._hash_words

    lanes_f, _, _ = _fused_tile_winners(tile, rsne)
    assert sorted(lanes_f.tolist()) == ref

    # spill-heavy: keep high bits (distinct per key) but only 2 slot bits
    monkeypatch.setattr(rec, "_hash_words", lambda w: (
        (real_hash(w).view(np.uint64) & ~np.uint64(0xFFFF))
        | (real_hash(w).view(np.uint64) & np.uint64(3))).view(np.int64))
    lanes_s, _, _ = _fused_tile_winners(tile, rsne)
    assert sorted(lanes_s.tolist()) == ref

    # colliding: 1-bit hash — many distinct keys share a hash value, the
    # word-level check must detect it and fall back to the exact sort
    monkeypatch.setattr(rec, "_hash_words", lambda w: (
        real_hash(w).view(np.uint64) & np.uint64(1)).view(np.int64))
    lanes_c, _, _ = _fused_tile_winners(tile, rsne)
    assert sorted(lanes_c.tolist()) == ref


def test_fused_recover_with_checkpoint_floor(tmp_path):
    """Checkpoint image + sealed segments: the image must win SSN ties
    (strict-> guard) and seed the fused merge exactly like the columnar
    base image."""
    from repro.core.checkpoint import CheckpointDaemon

    rng = random.Random(13)
    devs = _seg_devices(rng, n_devices=2, n_records=120)
    # checkpoint claims a mid-log RSN with a conflicting value for one key:
    # at equal SSN the image wins; above-image log records still apply
    ck_dir = str(tmp_path / "ck")
    ck = CheckpointDaemon(ck_dir, n_threads=1, m_files=1, csn_fn=lambda: 60)
    ck.run_once([[(b"key3", b"from-ckpt", 60)]])
    ref = recover(devs, checkpoint_dir=ck_dir, mode="scalar", parallel=False)
    st = recover(devs, checkpoint_dir=ck_dir, mode="pallas", parallel=False)
    assert st.data == ref.data
    assert (st.rsns, st.rsne) == (ref.rsns, ref.rsne)
