"""Per-architecture smoke tests: reduced same-family configs, real
forward/train step on CPU, asserting shapes + finiteness (assignment spec)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES, ShapeConfig, reduced
from repro.configs.registry import ARCH_NAMES, cell_applicable, get_config, input_specs, make_inputs
from repro.models.api import build_model


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_train_step_reduced(arch):
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_inputs(cfg, ShapeConfig("t", 32, 2, "train"))
    loss, grads = jax.value_and_grad(model.train_loss)(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss))
    gnorm = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32)))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_prefill_decode_reduced(arch):
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_inputs(cfg, ShapeConfig("p", 32, 2, "prefill"))
    logits, cache = model.prefill(params, batch, cache_len=32)
    assert logits.shape[0] == 2 and logits.shape[-1] == cfg.vocab
    assert bool(jnp.isfinite(logits).all())
    tok = jnp.zeros((2, 1), jnp.int32)
    for i in range(3):
        logits, cache = model.decode_step(params, cache, tok, jnp.asarray(32 + i, jnp.int32))
        assert logits.shape == (2, 1, cfg.vocab)
        assert bool(jnp.isfinite(logits).all())
        tok = logits.argmax(-1).astype(jnp.int32)


def test_prefill_matches_decode_continuation():
    """Decoding token-by-token after a prefill must equal a longer prefill's
    logits (cache correctness oracle)."""
    cfg = reduced(get_config("tinyllama-1.1b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (1, 12)), jnp.int32)

    # full prefill over 12 tokens
    full_logits, _ = model.prefill(params, {"tokens": toks}, cache_len=16)
    # prefill over 8, then decode tokens 8..11
    logits, cache = model.prefill(params, {"tokens": toks[:, :8]}, cache_len=16)
    outs = []
    for i in range(8, 12):
        logits, cache = model.decode_step(params, cache, toks[:, i : i + 1], jnp.asarray(i, jnp.int32))
        outs.append(logits)
    np.testing.assert_allclose(
        np.asarray(outs[-1][0, 0], np.float32),
        np.asarray(full_logits[0, -1], np.float32),
        atol=2e-2, rtol=2e-2,
    )


def test_cell_applicability_matrix():
    """40 cells: long_500k runnable only for sub-quadratic archs."""
    runnable = 0
    skipped = []
    for arch in ARCH_NAMES:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            ok, why = cell_applicable(cfg, shape)
            if ok:
                runnable += 1
            else:
                skipped.append((arch, shape.name))
    assert runnable == 33
    assert all(s == "long_500k" for _, s in skipped)
    assert {a for a, _ in skipped} == {
        "grok-1-314b", "qwen2-1.5b", "tinyllama-1.1b", "stablelm-12b",
        "deepseek-7b", "llava-next-mistral-7b", "whisper-medium",
    }


def test_param_counts_match_public_sizes():
    """Analytic parameter counts should land near the published sizes."""
    expect = {
        "tinyllama-1.1b": (1.0e9, 1.2e9),
        "qwen2-1.5b": (1.4e9, 1.9e9),
        "deepseek-7b": (6.5e9, 7.5e9),
        "mixtral-8x22b": (130e9, 148e9),
        "grok-1-314b": (290e9, 330e9),
        "stablelm-12b": (11e9, 13.5e9),
        "rwkv6-7b": (6e9, 8.5e9),
        "llava-next-mistral-7b": (6.8e9, 7.8e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).n_params()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"


def test_serve_llm_engine_reduced():
    """Relocated LLM serving engine (repro.models.serve_llm): one prefill +
    greedy decode on a reduced config, token bounds + shape."""
    from repro.models.serve_llm import ServeEngine

    cfg = reduced(get_config("tinyllama-1.1b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, cache_len=48)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 16)), jnp.int32)}
    res = eng.generate(batch, max_new=4)
    assert res.tokens.shape == (2, 4)
    assert np.all(res.tokens >= 0) and np.all(res.tokens < cfg.vocab)


def test_attn_impl_equivalence_all():
    cfg = reduced(get_config("mixtral-8x22b"))
    base = build_model(dataclasses.replace(cfg, attn_impl="masked_scan"))
    params = base.init(jax.random.PRNGKey(0))
    batch = make_inputs(cfg, ShapeConfig("t", 64, 2, "train"))
    l0 = base.train_loss(params, batch)
    for impl in ("triangular", "flash"):
        m = build_model(dataclasses.replace(cfg, attn_impl=impl))
        l1 = m.train_loss(params, batch)
        np.testing.assert_allclose(l0, l1, rtol=3e-3)
