"""Crash-injection end-to-end: real-clock file-backed devices, a kill
mid-flush that leaves a torn tail record, and recovery that truncates the
tail and restores exactly the committed prefix — single-shard and 2-shard.

The torn tail is physically injected: a prefix of a validly framed record
is appended straight to the device file, which is byte-for-byte what an
interrupted sequential write leaves behind (the frame's length field runs
past EOF / the crc fails, so decode stops there — paper §5's "buffer hole"
semantics at the device level).
"""

import os

from repro.core import EngineConfig, PoplarEngine, Txn, Worker, recover
from repro.db import TxnSpec
from repro.shard import ShardedConfig, ShardedEngine, recover_sharded


def _torn_record(key: str, cut: int = 7) -> bytes:
    t = Txn(tid=777777, write_set=[(key, b"TORN-VALUE-NEVER-COMMITTED")])
    t.ssn = 1 << 40  # would win every last-writer-wins race if replayed
    rec = t.encode()
    assert cut < len(rec)
    return rec[:-cut]


class _Cell:
    __slots__ = ("ssn",)

    def __init__(self):
        self.ssn = 0


def test_single_shard_torn_tail(tmp_path):
    cfg = EngineConfig(n_buffers=2, device_kind="ssd",
                       device_dir=str(tmp_path), device_clock="real",
                       flush_interval=1e-3, logger_poll=1e-4)
    engine = PoplarEngine(cfg)
    engine.start()
    try:
        workers = [Worker(engine, i) for i in range(2)]
        cells = {f"k{i}": _Cell() for i in range(30)}
        txns = []
        for i in range(60):
            t = Txn(tid=1000 + i)
            key = f"k{i % 30}"
            t.write_set = [(key, f"v{i}".encode())]
            workers[i % 2].run(t, [], [cells[key]])
            txns.append(t)
        engine.quiesce(range(2))
        committed = [t for t in txns if t.committed]
        assert len(committed) == 60
    finally:
        engine.stop()   # kill: loggers die, volatile ring contents are lost
    # writes buffered after the kill are never flushed (the crash tail)
    for i in range(5):
        t = Txn(tid=5000 + i)
        key = f"k{i}"
        t.write_set = [(key, f"lost{i}".encode())]
        workers[i % 2].run(t, [], [cells[key]])
    for d in engine.devices:
        d.close()

    # mid-flush kill: a partial frame lands at the end of device 0
    with open(os.path.join(str(tmp_path), "log_0.bin"), "ab") as f:
        f.write(_torn_record("k0"))
        f.flush()
        os.fsync(f.fileno())

    state = recover(engine.devices, parallel=False)
    scalar = recover(engine.devices, parallel=False, mode="scalar")
    assert state.data == scalar.data and state.rsne == scalar.rsne
    # the torn tail is truncated away...
    for v, _ in state.data.values():
        assert v != b"TORN-VALUE-NEVER-COMMITTED"
    # ...and the state equals the committed prefix: last committed writer
    # per key, never one of the unflushed tail writes
    expect = {}
    for t in committed:
        for k, v in t.write_set:
            expect[k.encode()] = (v, t.ssn)
    for kb, (v, s) in expect.items():
        got = state.data[kb]
        assert got[1] >= s
        if got[1] == s:
            assert got == (v, s)
    lost = {f"lost{i}".encode() for i in range(5)}
    assert not lost & {v for v, _ in state.data.values()}


def test_two_shard_torn_tail(tmp_path):
    eng = ShardedEngine(ShardedConfig(
        n_shards=2, n_buffers=1, n_workers=2, device_kind="ssd",
        device_dir=str(tmp_path), device_clock="real",
    ))
    eng.start()
    try:
        keys = [f"user{i:010d}" for i in range(24)]
        by_shard = [[], []]
        for k in keys:
            by_shard[eng.shard_of(k)].append(k)
        for r in range(3):
            specs = [TxnSpec(writes=[(k, f"{k}r{r}".encode())]) for k in keys]
            specs.append(TxnSpec(
                writes=[(by_shard[0][0], f"x0r{r}".encode()),
                        (by_shard[1][0], f"x1r{r}".encode())],
            ))
            res = eng.execute_batch(specs)
            assert not res.aborted
            eng.quiesce()
            assert all(t.committed for t in res.committed)
            assert all(x.committed for x in res.cross)
    finally:
        eng.stop()
    # buffered-but-never-flushed crash tail after the kill
    eng.execute_batch([TxnSpec(writes=[(keys[0], b"lost-tail")])])
    for devs in eng.devices:
        for d in devs:
            d.close()

    # torn frame at the tail of shard 1's only device
    with open(os.path.join(str(tmp_path), "shard1", "log_0.bin"), "ab") as f:
        f.write(_torn_record(by_shard[1][0]))
        f.flush()
        os.fsync(f.fileno())

    st = recover_sharded(eng.devices, parallel=False)
    data = st.data
    for v, _ in data.values():
        assert v != b"TORN-VALUE-NEVER-COMMITTED" and v != b"lost-tail"
    # committed prefix restored exactly: round-2 values everywhere (the two
    # cross keys carry the cross-shard write, sequenced after the solo one)
    for k in keys:
        if k not in (by_shard[0][0], by_shard[1][0]):
            assert data[k.encode()][0] == f"{k}r2".encode()
    assert data[by_shard[0][0].encode()][0] == b"x0r2"
    assert data[by_shard[1][0].encode()][0] == b"x1r2"
    assert st.n_cross_dropped == 0
