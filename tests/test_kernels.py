"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracles.

bf16 tolerances: inputs are cast to bf16 (~3 decimal digits), accumulation
is fp32 in both kernel and oracle, so output atol is dominated by the input
rounding — 2e-2 absolute on O(1) data.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.ops import (flash_attention, occ_seg_reduce, rwkv6,
                               ssm_scan, ssn_scatter_max)
from repro.kernels.ref import (attention_ref, rwkv6_ref, scatter_max_ref,
                               seg_reduce_ref, ssm_scan_ref)
from repro.kernels.scatter_max import NO_POS

RNG = np.random.default_rng(42)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else dict(atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,hq,hkv,s,t,d,causal,window,softcap",
    [
        (1, 2, 2, 128, 128, 128, True, None, None),
        (2, 4, 2, 256, 256, 128, True, None, None),    # GQA
        (1, 2, 1, 128, 256, 128, False, None, None),   # bidir, longer kv
        (2, 2, 2, 256, 256, 128, True, 64, None),      # sliding window
        (1, 2, 2, 128, 128, 128, True, None, 30.0),    # grok softcap
        (1, 8, 2, 384, 384, 128, True, 128, None),     # window + GQA
    ],
)
def test_flash_attention_vs_ref(b, hq, hkv, s, t, d, causal, window, softcap, dtype):
    q = jnp.asarray(RNG.normal(0, 1, (b, hq, s, d)), dtype)
    k = jnp.asarray(RNG.normal(0, 1, (b, hkv, t, d)), dtype)
    v = jnp.asarray(RNG.normal(0, 1, (b, hkv, t, d)), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window, softcap=softcap,
                          interpret=True)
    ref = attention_ref(q, k, v, causal=causal, window=window, softcap=softcap)
    np.testing.assert_allclose(
        out.astype(jnp.float32), ref.astype(jnp.float32), **_tol(dtype)
    )


def test_flash_attention_rejects_misaligned():
    q = jnp.zeros((1, 2, 100, 128))  # 100 not a multiple of block_q
    k = v = jnp.zeros((1, 2, 128, 128))
    with pytest.raises(AssertionError):
        flash_attention_fwd(q, k, v, interpret=True)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,h,s,p,n,chunk",
    [(1, 2, 128, 16, 8, 64), (2, 3, 64, 32, 16, 32), (1, 1, 256, 8, 4, 64)],
)
def test_ssm_scan_vs_ref(b, h, s, p, n, chunk, dtype):
    x = jnp.asarray(RNG.normal(0, 1, (b, h, s, p)), dtype)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, (b, h, s)), jnp.float32)
    decay = jnp.asarray(RNG.uniform(0.7, 0.999, (b, h, s)), jnp.float32)
    bm = jnp.asarray(RNG.normal(0, 1, (b, s, n)), dtype)
    cm = jnp.asarray(RNG.normal(0, 1, (b, s, n)), dtype)
    y, st = ssm_scan(x, dt, decay, bm, cm, chunk=chunk, interpret=True)
    yr, str_ = ssm_scan_ref(x, dt, decay, bm, cm)
    np.testing.assert_allclose(
        y.astype(jnp.float32), yr.astype(jnp.float32), **_tol(dtype)
    )
    np.testing.assert_allclose(st, str_, atol=5e-2 if dtype == jnp.bfloat16 else 2e-4,
                               rtol=5e-2 if dtype == jnp.bfloat16 else 2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,h,s,kd,vd,chunk",
    [(1, 2, 64, 16, 16, 32), (2, 2, 128, 32, 32, 32), (1, 1, 96, 64, 64, 32)],
)
def test_rwkv6_vs_ref(b, h, s, kd, vd, chunk, dtype):
    r = jnp.asarray(RNG.normal(0, 0.5, (b, h, s, kd)), dtype)
    k = jnp.asarray(RNG.normal(0, 0.5, (b, h, s, kd)), dtype)
    v = jnp.asarray(RNG.normal(0, 1, (b, h, s, vd)), dtype)
    w = jnp.asarray(RNG.uniform(0.5, 0.999, (b, h, s, kd)), jnp.float32)
    u = jnp.asarray(RNG.normal(0, 0.5, (h, kd)), jnp.float32)
    y, st = rwkv6(r, k, v, w, u, chunk=chunk, interpret=True)
    yr, str_ = rwkv6_ref(r, k, v, w, u)
    np.testing.assert_allclose(
        y.astype(jnp.float32), yr.astype(jnp.float32), **_tol(dtype)
    )


def test_rwkv6_strong_decay_stability():
    """Strong decay (w -> 0) must not overflow: the kernel uses only
    later-minus-earlier log-cumsum differences (exponents <= 0)."""
    b, h, s, kd, vd = 1, 1, 64, 16, 16
    r = jnp.asarray(RNG.normal(0, 0.5, (b, h, s, kd)), jnp.float32)
    k = jnp.asarray(RNG.normal(0, 0.5, (b, h, s, kd)), jnp.float32)
    v = jnp.asarray(RNG.normal(0, 1, (b, h, s, vd)), jnp.float32)
    w = jnp.full((b, h, s, kd), 0.01, jnp.float32)  # extreme decay
    u = jnp.zeros((h, kd), jnp.float32)
    y, st = rwkv6(r, k, v, w, u, chunk=32, interpret=True)
    yr, _ = rwkv6_ref(r, k, v, w, u)
    assert bool(jnp.isfinite(y).all())
    np.testing.assert_allclose(y, yr, atol=1e-4, rtol=1e-3)


@pytest.mark.parametrize(
    "n_slots,n_writes,block_s,block_w,ckpt_frac",
    [
        (64, 256, 128, 128, 0.0),     # single slot block, padded writes
        (300, 1000, 128, 256, 0.3),   # unaligned sizes, checkpoint image
        (1000, 300, 256, 128, 0.9),   # more slots than writes
        (17, 5, 128, 128, 0.5),       # tiny
    ],
)
def test_ssn_scatter_max_vs_ref(n_slots, n_writes, block_s, block_w, ckpt_frac):
    """SSN-guarded scatter-max vs the sequential numpy oracle, with duplicate
    keys, duplicate SSNs (tie -> smallest position), and checkpoint slots
    that must win their SSN ties (pos -1)."""
    rng = np.random.default_rng(n_slots * 7 + n_writes)
    image_ssn = np.full(n_slots, -1, np.int32)
    image_pos = np.full(n_slots, NO_POS, np.int32)
    ckpt = rng.random(n_slots) < ckpt_frac
    image_ssn[ckpt] = rng.integers(0, 50, ckpt.sum())
    image_pos[ckpt] = -1

    key = rng.integers(0, n_slots, n_writes).astype(np.int32)
    ssn = rng.integers(0, 60, n_writes).astype(np.int32)   # dense: many ties
    pos = np.arange(n_writes, dtype=np.int32)

    out_ssn, out_pos = ssn_scatter_max(
        image_ssn, image_pos, key, ssn, pos,
        block_s=block_s, block_w=block_w, interpret=True,
    )
    ref_ssn, ref_pos = scatter_max_ref(image_ssn, image_pos, key, ssn, pos)
    np.testing.assert_array_equal(np.asarray(out_ssn), ref_ssn)
    np.testing.assert_array_equal(np.asarray(out_pos), ref_pos)


def test_ssn_scatter_max_empty_writes_is_identity():
    image_ssn = np.arange(8, dtype=np.int32)
    image_pos = np.full(8, -1, np.int32)
    out_ssn, out_pos = ssn_scatter_max(
        image_ssn, image_pos,
        np.empty(0, np.int32), np.empty(0, np.int32), np.empty(0, np.int32),
        interpret=True,
    )
    np.testing.assert_array_equal(np.asarray(out_ssn), image_ssn)
    np.testing.assert_array_equal(np.asarray(out_pos), image_pos)


@pytest.mark.parametrize("op", ["max", "min"])
@pytest.mark.parametrize(
    "n_slots,n_items,block_s,block_w",
    [
        (64, 256, 128, 128),    # single slot block, padded items
        (300, 1000, 128, 256),  # unaligned sizes
        (1000, 37, 256, 128),   # sparse: most slots empty
        (5, 3, 128, 128),       # tiny
    ],
)
def test_occ_seg_reduce_vs_ref(op, n_slots, n_items, block_s, block_w):
    """Batched-OCC segmented reduce (base-SSN max / first-writer min) vs the
    sequential oracle, including empty slots (identity sentinels)."""
    rng = np.random.default_rng(n_slots * 13 + n_items + (op == "min"))
    key = rng.integers(0, n_slots, n_items).astype(np.int32)
    val = rng.integers(0, 500, n_items).astype(np.int32)
    out = occ_seg_reduce(key, val, n_slots=n_slots, op=op,
                         block_s=block_s, block_w=block_w, interpret=True)
    ref = seg_reduce_ref(key, val, n_slots, op)
    np.testing.assert_array_equal(np.asarray(out), ref)


def test_occ_seg_reduce_empty_items():
    out = occ_seg_reduce(np.empty(0, np.int32), np.empty(0, np.int32),
                         n_slots=7, op="max", interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.full(7, -1, np.int32))
    out = occ_seg_reduce(np.empty(0, np.int32), np.empty(0, np.int32),
                         n_slots=7, op="min", interpret=True)
    np.testing.assert_array_equal(
        np.asarray(out), np.full(7, np.iinfo(np.int32).max, np.int32)
    )


# --- model-level optimized-impl equivalence (flash vjp, chunked mixers) ------

def test_flash_vjp_matches_masked_scan():
    from repro.models.attention import attend

    b, s, h, kv, d = 2, 64, 4, 2, 16
    q = jnp.asarray(RNG.normal(0, 1, (b, s, h, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(0, 1, (b, s, kv, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(0, 1, (b, s, kv, d)), jnp.float32)

    def loss(impl):
        def f(q, k, v):
            o = attend(q, k, v, causal=True, impl=impl, chunk_k=32)
            return (o.astype(jnp.float32) ** 2).sum()
        return f

    l0, g0 = jax.value_and_grad(loss("masked_scan"), argnums=(0, 1, 2))(q, k, v)
    l1, g1 = jax.value_and_grad(loss("flash"), argnums=(0, 1, 2))(q, k, v)
    np.testing.assert_allclose(l0, l1, rtol=2e-4)
    for a, b_ in zip(g0, g1):
        np.testing.assert_allclose(a, b_, atol=3e-3, rtol=3e-3)


@pytest.mark.parametrize("arch", ["hymba-1.5b", "rwkv6-7b"])
def test_chunked_mixer_matches_scan(arch):
    import dataclasses

    from repro.configs.base import ShapeConfig, reduced
    from repro.configs.registry import get_config, make_inputs
    from repro.models.api import build_model

    r = reduced(get_config(arch), n_layers=2)
    m0 = build_model(dataclasses.replace(r, mixer_impl="scan"))
    m1 = build_model(dataclasses.replace(r, mixer_impl="chunked"))
    params = m0.init(jax.random.PRNGKey(0))
    batch = make_inputs(r, ShapeConfig("t", 64, 2, "train"))
    l0 = m0.train_loss(params, batch)
    l1 = m1.train_loss(params, batch)
    np.testing.assert_allclose(l0, l1, rtol=2e-3)


# --- compiled XLA twins of the OLTP kernels ----------------------------------

def _xla_twin_inputs(n_slots, n_writes, ckpt_frac, seed, pad_lanes=0):
    rng = np.random.default_rng(seed)
    image_ssn = np.full(n_slots, -1, np.int32)
    image_pos = np.full(n_slots, NO_POS, np.int32)
    ckpt = rng.random(n_slots) < ckpt_frac
    image_ssn[ckpt] = rng.integers(0, 50, ckpt.sum())
    image_pos[ckpt] = -1
    key = rng.integers(0, n_slots, n_writes).astype(np.int32)
    ssn = rng.integers(0, 60, n_writes).astype(np.int32)   # dense: many ties
    pos = np.arange(n_writes, dtype=np.int32)
    if pad_lanes:
        # padding lanes target the overflow slot with reduction identities —
        # they must not influence any real slot
        key = np.concatenate([key, np.full(pad_lanes, n_slots, np.int32)])
        ssn = np.concatenate([ssn, np.full(pad_lanes, -1, np.int32)])
        pos = np.concatenate([pos, np.full(pad_lanes, NO_POS, np.int32)])
    return image_ssn, image_pos, key, ssn, pos


@pytest.mark.parametrize("n_slots,n_writes,ckpt_frac,pad_lanes", [
    (64, 256, 0.0, 0),
    (300, 1000, 0.3, 24),    # checkpoint ties + overflow padding lanes
    (1000, 300, 0.9, 1),
    (17, 5, 0.5, 3),
])
def test_scatter_max_xla_equals_pallas_and_ref(n_slots, n_writes, ckpt_frac,
                                               pad_lanes):
    """The compiled XLA twin == the Pallas kernel (interpret) == the
    sequential oracle, including overflow-slot padding lanes the twin must
    drop."""
    from repro.kernels.scatter_max import ssn_scatter_max_xla

    image_ssn, image_pos, key, ssn, pos = _xla_twin_inputs(
        n_slots, n_writes, ckpt_frac, seed=n_slots + n_writes,
        pad_lanes=pad_lanes)
    out_ssn, out_pos = ssn_scatter_max_xla(image_ssn, image_pos,
                                           key, ssn, pos, n_slots)
    ref_ssn, ref_pos = scatter_max_ref(image_ssn, image_pos,
                                       key[: n_writes], ssn[: n_writes],
                                       pos[: n_writes])
    np.testing.assert_array_equal(np.asarray(out_ssn)[:n_slots], ref_ssn)
    np.testing.assert_array_equal(np.asarray(out_pos)[:n_slots], ref_pos)
    k_ssn, k_pos = ssn_scatter_max(image_ssn, image_pos, key[: n_writes],
                                   ssn[: n_writes], pos[: n_writes],
                                   interpret=True)
    np.testing.assert_array_equal(np.asarray(out_ssn)[:n_slots], np.asarray(k_ssn))
    np.testing.assert_array_equal(np.asarray(out_pos)[:n_slots], np.asarray(k_pos))


def test_fused_replay_entry_points_match_ref():
    """The jitted fused entry points (stacked single-transfer layouts used by
    the recovery/replica hot paths) == the sequential oracle."""
    from repro.kernels.ops import fused_replay_apply, fused_replay_scan

    n_slots, n_writes, pad = 256, 900, 124
    image_ssn, image_pos, key, ssn, pos = _xla_twin_inputs(
        n_slots, n_writes, 0.4, seed=99, pad_lanes=pad)
    ref_ssn, ref_pos = scatter_max_ref(image_ssn, image_pos, key[:n_writes],
                                       ssn[:n_writes], pos[:n_writes])
    scan = np.stack([key, ssn, pos])
    image = np.stack([image_ssn, image_pos])
    out_ssn, out_pos = fused_replay_apply(image, scan)
    np.testing.assert_array_equal(np.asarray(out_ssn)[:n_slots], ref_ssn)
    np.testing.assert_array_equal(np.asarray(out_pos)[:n_slots], ref_pos)

    # fused_replay_scan: same reduction against an empty image
    empty_ssn = np.full(n_slots, -1, np.int32)
    empty_pos = np.full(n_slots, NO_POS, np.int32)
    ref2_ssn, ref2_pos = scatter_max_ref(empty_ssn, empty_pos, key[:n_writes],
                                         ssn[:n_writes], pos[:n_writes])
    s_ssn, s_pos = fused_replay_scan(scan, n_slots=n_slots)
    np.testing.assert_array_equal(np.asarray(s_ssn)[:n_slots], ref2_ssn)
    np.testing.assert_array_equal(np.asarray(s_pos)[:n_slots], ref2_pos)


def _validate_brute(acc, a_len, n_txn, k):
    """Per-transaction python walk of the §4.2/§4.4 rules."""
    row, pos, iw, obs, ssn_now, locked = (acc[i].astype(np.int64)
                                          for i in range(6))
    fw = {}
    for t in range(n_txn):
        for j in range(int(a_len[t])):
            lane = t * k + j
            if iw[lane]:
                r = int(row[lane])
                fw[r] = min(fw.get(r, 1 << 31), int(pos[lane]))
    survive = np.zeros(n_txn, bool)
    bases = np.zeros(n_txn, np.int64)
    for t in range(n_txn):
        ok, base = True, 0
        for j in range(int(a_len[t])):
            lane = t * k + j
            base = max(base, int(ssn_now[lane]))
            if fw.get(int(row[lane]), 1 << 31) < int(pos[lane]):
                ok = False        # someone earlier in the batch writes it
            if obs[lane] >= 0 and ssn_now[lane] != obs[lane]:
                ok = False        # driver-observed SSN went stale
            if locked[lane]:
                ok = False
        survive[t] = ok
        bases[t] = base
    return survive, bases


@pytest.mark.parametrize("n_txn,k,cap,lock_frac", [
    (8, 1, 64, 0.0),
    (64, 4, 128, 0.2),      # ragged a_len, locked tuples
    (128, 2, 64, 0.0),      # conflict-heavy: cap << lanes
])
def test_validate_sequence_xla_vs_brute(n_txn, k, cap, lock_frac):
    from repro.kernels.batch_occ import validate_sequence_xla

    rng = np.random.default_rng(n_txn * 31 + k)
    lanes = n_txn * k
    acc = np.empty((6, lanes), np.int32)
    acc[0] = rng.integers(0, cap, lanes)
    acc[1] = rng.permutation(lanes)
    acc[2] = rng.integers(0, 2, lanes)
    ssn = rng.integers(1, 40, lanes).astype(np.int32)
    acc[3] = np.where(rng.random(lanes) < 0.5, ssn, -1)
    acc[4] = ssn
    acc[5] = (rng.random(lanes) < lock_frac).astype(np.int32)
    # stale observations for some read lanes
    stale = rng.random(lanes) < 0.15
    acc[3] = np.where(stale & (acc[3] >= 0), acc[3] + 1, acc[3])
    a_len = rng.integers(1, k + 1, n_txn).astype(np.int32)

    out_sv, out_b = validate_sequence_xla(acc, a_len, n_txn, k, cap)
    ref_sv, ref_b = _validate_brute(acc, a_len, n_txn, k)
    np.testing.assert_array_equal(np.asarray(out_sv), ref_sv)
    np.testing.assert_array_equal(np.asarray(out_b, np.int64), ref_b)
