"""Group-commit transparency properties for the serving tier.

The scheduler's batch cutter is strict-FIFO and conflict-free (a cut is the
longest queue prefix with no intra-prefix key overlap), which makes group
commit *transparent* at the log-byte level.  Two properties pin that down:

P1 (equivalence vs direct batch): for a conflict-free arrival schedule, the
   serve path — arrivals trickling in over steps, arbitrary cut sizes and
   latency budgets — produces **byte-identical device logs** and identical
   final table state to a *single* direct ``execute_batch`` of the same
   transactions on a fresh identical stack.  Checked for the vectorized,
   pallas and scalar executors, and for the sharded engine.

P2 (cut invariance): for an *arbitrary* schedule (duplicate/hot keys — the
   cutter splits at conflicts), any two scheduler configurations (different
   cut sizes, latency budgets, arrival timings) produce byte-identical logs,
   identical final state, and the same per-transaction SSNs and ack order.

Both run as seeded-random trials (always, tier-1) and as hypothesis
properties when hypothesis is installed.

Preconditions the trials honour (and document): one log buffer per engine
(idle-buffer heartbeats are timing-dependent bytes), and worker ids assigned
in admission order (the scheduler's round-robin matches the executor's
default striping).
"""

import random

import pytest

from repro.core import EngineConfig, PoplarEngine
from repro.db.batch import BatchOCC, ScalarBatchOCC, TxnSpec
from repro.db.ycsb import key_of
from repro.serve import (
    ACKED,
    GroupCommitScheduler,
    ServeConfig,
    SingleBackend,
    run_stepped_schedule,
)
from repro.shard import ShardedConfig, ShardedEngine
from repro.serve.backend import ShardedBackend

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # tier-1 containers: seeded trials below still run
    HAVE_HYPOTHESIS = False

READ_POOL = 20  # keys 0..19: preloaded, read-only (never written by specs)


def _mk_backend(mode, base_dir, tag, n_workers):
    d = base_dir / tag
    d.mkdir()
    cfg = EngineConfig(n_buffers=1, device_kind="null", device_dir=str(d))
    be = SingleBackend.make(mode, n_workers=n_workers, cfg=cfg)
    for i in range(READ_POOL):
        be.table.insert(key_of(i), f"seed{i}".encode())
    return be


def _state(table, keys):
    out = {}
    for k in keys:
        got = table.get(k)
        if got is None:
            continue
        out[k] = got if isinstance(got, tuple) else (got.value, got.ssn)
    return out


def _run_serve(be, specs, gaps, max_batch, budget_steps):
    """Drive the serve path over a stepped schedule; return tickets."""
    sched = GroupCommitScheduler(
        be,
        ServeConfig(
            max_batch=max_batch,
            latency_budget_steps=budget_steps,
            queue_capacity=10**6,
        ),
    )
    at, schedule = 0, []
    for spec, gap in zip(specs, gaps):
        at += gap
        schedule.append((at, spec))
    return run_stepped_schedule(sched, schedule)


def _settle_direct(be, res, max_steps=200):
    """Flush + drain a direct execute_batch result until fully committed."""
    for _ in range(max_steps):
        be.tick()
        be.drain()
        if all(t.committed for t in res.committed):
            return
    raise TimeoutError("direct batch did not settle")


def _check_equivalence(mode, base_dir, specs, gaps, max_batch, budget_steps,
                       n_workers):
    """P1: serve path vs one direct execute_batch — bytes, state, SSNs."""
    keys = sorted({k for s in specs for k in list(s.reads) + [w for w, _ in s.writes]}
                  | {key_of(i) for i in range(READ_POOL)})
    be_s = _mk_backend(mode, base_dir, "serve", n_workers)
    be_d = _mk_backend(mode, base_dir, "direct", n_workers)

    tickets = _run_serve(be_s, specs, gaps, max_batch, budget_steps)
    assert all(t.status == ACKED for t in tickets)
    # conflict-free => commit order is admission order, globally
    acks = [t.ack_seq for t in tickets]
    assert acks == sorted(acks)

    res = be_d.occ.execute_batch(specs, max_rounds=1)
    assert not res.aborted and list(res.committed_idx) == list(range(len(specs)))
    _settle_direct(be_d, res)

    # identical per-transaction SSNs (same Algorithm-1 chain)...
    assert [t.ssn for t in tickets] == [t.ssn for t in res.committed]
    # ...identical final table state...
    assert _state(be_s.table, keys) == _state(be_d.table, keys)
    # ...and byte-identical device logs
    for d in be_s.engine.devices + be_d.engine.devices:
        d.close()
    assert [d.read_all() for d in be_s.engine.devices] == [
        d.read_all() for d in be_d.engine.devices
    ]


def _conflict_free_trial(seed, base_dir, mode):
    rng = random.Random(seed)
    n = rng.randrange(1, 22)
    specs = []
    for i in range(n):
        reads = [key_of(j) for j in rng.sample(range(READ_POOL),
                                               rng.randrange(0, 3))]
        # write keys unique per txn and disjoint from the read pool
        specs.append(TxnSpec(
            reads=reads,
            writes=[(key_of(1000 + i), rng.randbytes(rng.randrange(1, 40)))],
        ))
    gaps = [rng.randrange(0, 3) for _ in range(n)]
    _check_equivalence(mode, base_dir, specs, gaps,
                       max_batch=rng.choice([1, 2, 3, 8, 64]),
                       budget_steps=rng.choice([1, 2]),
                       n_workers=rng.choice([1, 2, 3]))


@pytest.mark.parametrize("seed", range(6))
def test_equivalence_vectorized(seed, tmp_path):
    _conflict_free_trial(seed, tmp_path, "vectorized")


@pytest.mark.parametrize("seed", range(3))
def test_equivalence_scalar(seed, tmp_path):
    _conflict_free_trial(seed, tmp_path, "scalar")


@pytest.mark.parametrize("seed", range(2))
def test_equivalence_pallas(seed, tmp_path):
    _conflict_free_trial(seed, tmp_path, "pallas")


# --- P1, sharded --------------------------------------------------------------

def _mk_sharded(base_dir, tag):
    d = base_dir / tag
    d.mkdir()
    eng = ShardedEngine(ShardedConfig(
        n_shards=2, n_buffers=1, n_workers=1,
        device_kind="null", device_dir=str(d),
    ))
    for i in range(READ_POOL):
        eng.insert(key_of(i), f"seed{i}".encode())
    return eng


@pytest.mark.parametrize("seed", range(3))
def test_equivalence_sharded(seed, tmp_path):
    """Serve path over a ShardedBackend vs one direct sharded execute_batch:
    byte-identical per-shard logs (single-shard, write-only, conflict-free
    specs — cross-shard coordination bytes are covered by state-level tests
    in test_serve_scheduler.py)."""
    rng = random.Random(seed)
    n = rng.randrange(1, 20)
    specs = [
        TxnSpec(writes=[(key_of(1000 + i), rng.randbytes(rng.randrange(1, 32)))])
        for i in range(n)
    ]
    gaps = [rng.randrange(0, 3) for _ in range(n)]

    eng_s = _mk_sharded(tmp_path, "serve")
    eng_d = _mk_sharded(tmp_path, "direct")

    tickets = _run_serve(ShardedBackend(eng_s), specs, gaps,
                         max_batch=rng.choice([1, 2, 4, 16]), budget_steps=1)
    assert all(t.status == ACKED for t in tickets)

    res = eng_d.execute_batch(specs)
    assert not res.aborted and not res.cross
    for _ in range(200):
        eng_d.tick(force=True)
        eng_d.drain()
        if all(t.committed for t in res.committed):
            break
    else:
        raise TimeoutError("direct sharded batch did not settle")

    flat_s = [d for devs in eng_s.devices for d in devs]
    flat_d = [d for devs in eng_d.devices for d in devs]
    for d in flat_s + flat_d:
        d.close()
    assert [d.read_all() for d in flat_s] == [d.read_all() for d in flat_d]
    assert eng_s.to_dict() == eng_d.to_dict()


# --- P2: cut invariance on arbitrary (conflicting) schedules ------------------

def _arbitrary_specs(rng, n):
    """Hot-key schedule: writes collide freely (the cutter must split)."""
    specs = []
    for _ in range(n):
        wkeys = rng.sample(range(READ_POOL, READ_POOL + 6),
                           rng.randrange(1, 3))
        specs.append(TxnSpec(
            reads=[key_of(j) for j in rng.sample(range(READ_POOL),
                                                 rng.randrange(0, 2))],
            writes=[(key_of(k), rng.randbytes(rng.randrange(1, 24)))
                    for k in wkeys],
        ))
    return specs


def _check_cut_invariance(mode, base_dir, specs, cfg_a, cfg_b, n_workers):
    keys = sorted({k for s in specs
                   for k in list(s.reads) + [w for w, _ in s.writes]})
    results = []
    for tag, (gaps, max_batch, budget) in (("a", cfg_a), ("b", cfg_b)):
        be = _mk_backend(mode, base_dir, tag, n_workers)
        tickets = _run_serve(be, specs, gaps, max_batch, budget)
        assert all(t.status == ACKED for t in tickets)
        for d in be.engine.devices:
            d.close()
        results.append((
            [d.read_all() for d in be.engine.devices],
            _state(be.table, keys),
            [t.ssn for t in tickets],
            [t.ack_seq for t in tickets],
        ))
    assert results[0] == results[1]


@pytest.mark.parametrize("seed", range(6))
def test_cut_invariance(seed, tmp_path):
    rng = random.Random(100 + seed)
    n = rng.randrange(2, 24)
    specs = _arbitrary_specs(rng, n)
    cfg_a = ([rng.randrange(0, 3) for _ in range(n)],
             rng.choice([1, 2, 4, 64]), rng.choice([1, 2]))
    cfg_b = ([rng.randrange(0, 3) for _ in range(n)],
             rng.choice([1, 3, 8, 64]), rng.choice([1, 3]))
    mode = ("vectorized", "scalar")[seed % 2]
    _check_cut_invariance(mode, tmp_path, specs, cfg_a, cfg_b,
                          n_workers=rng.choice([1, 2]))


# --- hypothesis wrappers (skipped when hypothesis is absent) ------------------

if HAVE_HYPOTHESIS:
    import tempfile
    from pathlib import Path

    schedule_st = st.lists(
        st.tuples(
            st.lists(st.integers(0, READ_POOL - 1), max_size=2, unique=True),
            st.integers(0, 2),     # arrival gap (steps)
            st.integers(1, 24),    # value length
        ),
        min_size=1, max_size=20,
    )

    @settings(max_examples=40, deadline=None)
    @given(sched=schedule_st, max_batch=st.sampled_from([1, 2, 4, 8]),
           budget=st.integers(1, 2), mode=st.sampled_from(["vectorized", "scalar"]),
           n_workers=st.integers(1, 3))
    def test_equivalence_hypothesis(sched, max_batch, budget, mode, n_workers):
        specs = [
            TxnSpec(reads=[key_of(j) for j in reads],
                    writes=[(key_of(1000 + i), bytes([i % 251] * vlen))])
            for i, (reads, _, vlen) in enumerate(sched)
        ]
        gaps = [g for _, g, _ in sched]
        with tempfile.TemporaryDirectory() as d:
            base = Path(d)
            (base / "serve").parent.mkdir(exist_ok=True)
            _check_equivalence(mode, base, specs, gaps, max_batch, budget,
                               n_workers)
else:

    @pytest.mark.skip(reason="hypothesis not installed; the seeded trials "
                             "above exercise the same properties")
    def test_equivalence_hypothesis():
        pass
