"""Streaming-decode torn-record properties: incremental framing over a byte
stream cut at *every* boundary yields exactly the committed prefix, and a
torn/corrupt trailing frame is retried — never decoded, never skipped.

This extends the crash-injection machinery (`test_crash_injection._torn_record`
injects a physically torn frame) to the shipping side: the same byte stream
recovery would truncate is instead tailed incrementally, and the shipper
must converge to the identical record set without ever re-decoding consumed
bytes (the O(n²) re-read pattern the incremental API removes).
"""

import zlib

import numpy as np
import pytest

from repro.core import Txn, decode_columnar, decode_columnar_stream, decode_records
from repro.core.txn import ColumnarLog
from repro.replica import LogShipper


class _GrowingSource:
    """A byte stream revealed prefix-by-prefix (simulated live append)."""

    def __init__(self, blob: bytes):
        self.blob = blob
        self.n = 0

    def grow(self, k: int) -> None:
        self.n = min(len(self.blob), self.n + k)

    def read_from(self, offset: int) -> bytes:
        return self.blob[offset : self.n]

    def size(self) -> int:
        return self.n


def _blob(n_records: int = 24, seed: int = 7) -> bytes:
    rng = np.random.RandomState(seed)
    out = bytearray()
    for i in range(n_records):
        t = Txn(
            tid=100 + i,
            write_set=[
                (f"k{int(rng.randint(6))}", bytes(rng.bytes(int(rng.randint(0, 40)))))
                for _ in range(int(rng.randint(0, 3)))
            ],
            read_set=[("r", 0)] if rng.rand() < 0.4 else [],
        )
        if rng.rand() < 0.3:
            t.xdep = [(0, i + 1), (1, i + 2)]
        t.ssn = i + 1
        out.extend(t.encode())
    return bytes(out)


def test_stream_cut_at_every_boundary():
    """Feed the shipper one byte at a time; after every extension the total
    shipped record set must equal decode of the full revealed prefix — the
    committed prefix, nothing more, nothing less — and consumed bytes must
    never regress or outrun the revealed prefix."""
    blob = _blob()
    src = _GrowingSource(blob)
    sh = LogShipper(src)
    chunks = []
    last_consumed = 0
    for _ in range(len(blob)):
        src.grow(1)
        log = sh.poll()
        if log is not None:
            chunks.append(log)
        assert sh.consumed >= last_consumed
        assert sh.consumed <= src.n
        # invariant at every cut: shipped records == committed prefix
        assert sum(c.n_records for c in chunks) == len(decode_records(blob[: src.n]))
        last_consumed = sh.consumed
    got = ColumnarLog.concat(chunks)
    want = decode_columnar(blob)
    assert got.n_records == want.n_records
    assert np.array_equal(got.ssn, want.ssn)
    assert np.array_equal(got.tid, want.tid)
    assert np.array_equal(got.has_reads, want.has_reads)
    assert np.array_equal(got.wr_rec, want.wr_rec)
    assert got.keys == want.keys and got.values == want.values
    assert np.array_equal(got.x_rec, want.x_rec)
    assert np.array_equal(got.xp_start, want.xp_start)
    assert np.array_equal(got.xp_shard, want.xp_shard)
    assert np.array_equal(got.xp_ssn, want.xp_ssn)
    assert sh.consumed == len(blob)


@pytest.mark.parametrize("seed", range(4))
def test_stream_random_chunks(seed):
    """Random-size increments: same convergence property."""
    rng = np.random.RandomState(seed)
    blob = _blob(seed=seed + 100)
    src = _GrowingSource(blob)
    sh = LogShipper(src)
    total = 0
    while src.n < len(blob):
        src.grow(int(rng.randint(1, 64)))
        log = sh.poll()
        if log is not None:
            total += log.n_records
        assert total == len(decode_records(blob[: src.n]))
    assert total == len(decode_records(blob))


def test_stream_consumed_stops_at_torn_and_corrupt_frames():
    t = Txn(tid=1, write_set=[("a", b"v")])
    t.ssn = 1
    rec = t.encode()
    # torn: a strict prefix of a frame is never consumed
    log, used = decode_columnar_stream(rec[:-3])
    assert log.n_records == 0 and used == 0
    # corrupt crc on a *complete* frame: also not consumed (retried — on a
    # live log these bytes may simply not all have landed yet)
    bad = bytearray(rec)
    bad[-1] ^= 0xFF
    log, used = decode_columnar_stream(bytes(bad))
    assert log.n_records == 0 and used == 0
    assert zlib.crc32(rec[8:]) != zlib.crc32(bytes(bad)[8:])
    # a valid frame before the bad one is consumed exactly
    log, used = decode_columnar_stream(rec + bytes(bad))
    assert log.n_records == 1 and used == len(rec)


def test_shipper_retries_torn_tail_until_complete():
    """A frame revealed in two halves is decoded only once complete, from
    the retained tail — consumed never moves into the partial frame."""
    blob = _blob(n_records=3, seed=1)
    recs = decode_records(blob)
    # find the frame boundaries
    _, b0 = decode_columnar_stream(blob)  # consumes all; recompute manually
    src = _GrowingSource(blob)
    sh = LogShipper(src)
    src.grow(len(blob) - 5)  # everything but the last frame's tail bytes
    first = sh.poll()
    assert first is not None and first.n_records == len(recs) - 1
    held_consumed = sh.consumed
    assert sh.poll() is None  # torn tail: retried, nothing consumed
    assert sh.consumed == held_consumed
    src.grow(5)
    rest = sh.poll()
    assert rest is not None and rest.n_records == 1
    assert sh.consumed == len(blob)
    assert rest.to_records()[0].writes == recs[-1].writes
