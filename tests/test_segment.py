"""Algorithm 2 (segment index / DSN advancement) unit tests."""

import pytest

from repro.core.log_buffer import LogBuffer
from repro.core.segment import CLOSED, OPEN, SegmentIndex
from repro.core.storage import DeviceSpec, StorageDevice


def _dev():
    return StorageDevice(DeviceSpec.null())


def test_segment_closes_at_io_unit():
    buf = LogBuffer(0, capacity=1 << 20, io_unit=128)
    # allocations below the io unit keep the segment open
    buf.reserve(0, 64)
    assert buf.segindex.generating().stat == OPEN
    # crossing the io unit closes it
    buf.reserve(0, 100)
    assert buf.segindex.segments[0].stat == CLOSED
    assert buf.segindex.cur_generate_seg == 1


def test_hole_blocks_flush():
    """A reserved-but-unfilled record (buffer hole) must block the flush of
    its segment — the central correctness property of Figure 4."""
    buf = LogBuffer(0, capacity=1 << 20, io_unit=64)
    dev = _dev()
    s1, off1, seg1 = buf.reserve(0, 40)
    s2, off2, seg2 = buf.reserve(0, 40)  # closes segment (80 >= 64)
    buf.fill(off2, seg2, b"y" * 40)      # second record filled first
    assert buf.flush_ready(dev) == 0     # hole from record 1 blocks
    assert buf.dsn == 0
    buf.fill(off1, seg1, b"x" * 40)
    assert buf.flush_ready(dev) == 1
    assert buf.dsn == s2                 # DSN = largest SSN in the segment


def test_dsn_advances_in_segment_order():
    buf = LogBuffer(0, capacity=1 << 20, io_unit=32)
    dev = _dev()
    ssns = []
    for i in range(6):
        s, off, seg = buf.reserve(0, 40)  # each record closes a segment
        buf.fill(off, seg, bytes(40))
        ssns.append(s)
    n = buf.flush_ready(dev)
    assert n == 6
    assert buf.dsn == ssns[-1]
    assert dev.bytes_written == 240


def test_timer_close_partial_segment():
    buf = LogBuffer(0, capacity=1 << 20, io_unit=1 << 16)
    dev = _dev()
    s, off, seg = buf.reserve(0, 40)
    buf.fill(off, seg, bytes(40))
    assert buf.flush_ready(dev) == 0     # below io unit: still open
    assert buf.force_establish() is True  # group-commit timer path
    assert buf.flush_ready(dev) == 1
    assert buf.dsn == s


def test_ring_wraparound():
    buf = LogBuffer(0, capacity=128, io_unit=32)
    dev = _dev()
    total = 0
    for i in range(10):
        s, off, seg = buf.reserve(0, 40)
        buf.fill(off, seg, bytes([i]) * 40)
        buf.force_establish()
        assert buf.flush_ready(dev) >= 1
        total += 40
    assert dev.bytes_written == total
    assert buf.pending_bytes() == 0


def test_empty_segment_not_closed():
    buf = LogBuffer(0, capacity=1 << 16)
    assert buf.force_establish() is False
