"""whisper-medium [audio] — enc-dec, conv frontend stubbed
(arXiv:2212.04356; unverified).

24 encoder + 24 decoder layers, d_model=1024 16H (kv=16, head_dim 64)
d_ff=4096 vocab=51865, LayerNorm + gelu MLPs.  The conv1d/mel frontend is a
STUB: ``input_specs()`` supplies frame embeddings (B, 1500, d).  Decoder
positions use RoPE in this backbone (original uses learned embeddings —
backbone-equivalent for shape/roofline purposes, noted divergence).
Full attention decoder => long_500k skipped.
"""
from .base import ArchConfig, EncDecCfg

CONFIG = ArchConfig(
    name="whisper-medium",
    family="audio",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab=51865,
    norm="layernorm",
    mlp_style="gelu2",
    enc_dec=EncDecCfg(enc_layers=24, enc_seq=1500),
)
