"""llava-next-mistral-7b [vlm] — anyres tiling backbone
(hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified).

Mistral-7B backbone: 32L d_model=4096 32H (GQA kv=8, head_dim 128)
d_ff=14336 vocab=32000.  The vision frontend is a STUB per the assignment:
``input_specs()`` supplies precomputed patch embeddings (B, 576, d) which
are prepended to the token embeddings; loss is masked to text positions.
Full attention (llava fine-tunes drop mistral's SWA) => long_500k skipped.
"""
from .base import ArchConfig, VLMCfg

CONFIG = ArchConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=32000,
    vlm=VLMCfg(n_patches=576),
)
