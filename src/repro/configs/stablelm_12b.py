"""stablelm-12b [dense] (hf:stabilityai/stablelm-2-12b; hf).

40L d_model=5120 32H (GQA kv=8, head_dim 160) d_ff=13824 vocab=100352.
LayerNorm (stablelm-2 family).  Full attention => long_500k skipped.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=160,
    d_ff=13824,
    vocab=100352,
    norm="layernorm",
)
