"""Architecture registry + per-(arch, shape) input specs.

``input_specs(cfg, shape)`` returns ``jax.ShapeDtypeStruct`` stand-ins for
every model input of the given phase — weak-type-correct, shardable, no
device allocation (the dry-run pattern).  ``make_inputs`` materializes real
arrays from the same specs for smoke tests.
"""

from __future__ import annotations

import importlib
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .base import SHAPES, ArchConfig, ShapeConfig, reduced

_MODULES = {
    "hymba-1.5b": "hymba_1_5b",
    "mixtral-8x22b": "mixtral_8x22b",
    "grok-1-314b": "grok_1_314b",
    "qwen2-1.5b": "qwen2_1_5b",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "stablelm-12b": "stablelm_12b",
    "deepseek-7b": "deepseek_7b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "whisper-medium": "whisper_medium",
    "rwkv6-7b": "rwkv6_7b",
}

ARCH_NAMES = tuple(_MODULES)


def get_config(name: str) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_NAMES}")
    mod = importlib.import_module(f".{_MODULES[name]}", __package__)
    return mod.CONFIG


def cell_applicable(cfg: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Is this (arch x shape) cell runnable? (long_500k needs bounded state)"""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, (
            "long_500k requires sub-quadratic attention state; "
            f"{cfg.name} is pure full-attention (see DESIGN §Arch-applicability)"
        )
    return True, ""


def _text_seq(cfg: ArchConfig, shape: ShapeConfig) -> int:
    """Token count of the text part (vlm reserves patches out of seq_len)."""
    if cfg.vlm is not None and shape.phase in ("train", "prefill"):
        return shape.seq_len - cfg.vlm.n_patches
    return shape.seq_len


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this phase."""
    b = shape.global_batch
    st = _text_seq(cfg, shape)
    i32 = jnp.int32
    bf16 = jnp.bfloat16
    if shape.phase == "train":
        specs: Dict[str, Any] = {
            "tokens": jax.ShapeDtypeStruct((b, st), i32),
            "labels": jax.ShapeDtypeStruct((b, st), i32),
        }
        if cfg.vlm is not None:
            specs["vision_embeds"] = jax.ShapeDtypeStruct((b, cfg.vlm.n_patches, cfg.d_model), bf16)
        if cfg.enc_dec is not None:
            specs["frame_embeds"] = jax.ShapeDtypeStruct((b, cfg.enc_dec.enc_seq, cfg.d_model), bf16)
        return specs
    if shape.phase == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((b, st), i32)}
        if cfg.vlm is not None:
            specs["vision_embeds"] = jax.ShapeDtypeStruct((b, cfg.vlm.n_patches, cfg.d_model), bf16)
        if cfg.enc_dec is not None:
            specs["frame_embeds"] = jax.ShapeDtypeStruct((b, cfg.enc_dec.enc_seq, cfg.d_model), bf16)
        return specs
    # decode: one new token against a cache of shape.seq_len
    return {
        "tokens": jax.ShapeDtypeStruct((b, 1), i32),
        "pos": jax.ShapeDtypeStruct((), i32),
    }


def make_inputs(cfg: ArchConfig, shape: ShapeConfig, seed: int = 0) -> Dict[str, Any]:
    """Real (host) arrays matching input_specs — smoke tests only."""
    rng = np.random.default_rng(seed)
    out: Dict[str, Any] = {}
    for k, s in input_specs(cfg, shape).items():
        if s.dtype == jnp.int32:
            hi = cfg.vocab if k in ("tokens", "labels") else max(1, shape.seq_len)
            if k == "pos":
                out[k] = jnp.asarray(shape.seq_len - 1, jnp.int32)
            else:
                out[k] = jnp.asarray(rng.integers(0, hi, s.shape), jnp.int32)
        else:
            out[k] = jnp.asarray(rng.normal(0, 0.02, s.shape), s.dtype)
    return out
