"""Architecture & run-shape configuration dataclasses."""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, Optional, Sequence, Tuple


@dataclass(frozen=True)
class MoECfg:
    n_experts: int = 8
    top_k: int = 2
    capacity_factor: float = 1.25
    group_size: int = 2048       # token group size for dispatch


@dataclass(frozen=True)
class SSMCfg:
    state_dim: int = 16
    n_heads: int = 25            # mamba heads (hymba: parallel with attn)
    head_dim: int = 64
    dt_rank: int = 0             # 0 => d_model // 16
    conv_width: int = 4


@dataclass(frozen=True)
class RWKVCfg:
    n_heads: int = 64
    head_dim: int = 64
    decay_lora: int = 64         # rank of the data-dependent decay LoRA


@dataclass(frozen=True)
class EncDecCfg:
    enc_layers: int = 24
    enc_seq: int = 1500          # whisper: 30s of audio at 50 fps
    # frontend is a stub: input_specs() supplies frame embeddings directly


@dataclass(frozen=True)
class VLMCfg:
    n_patches: int = 576         # llava-next base tile (24x24)
    # frontend is a stub: input_specs() supplies patch embeddings directly


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 => d_model // n_heads
    qkv_bias: bool = False
    norm: str = "rmsnorm"        # rmsnorm | layernorm
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    # sliding-window attention: None = full; int = window size
    sliding_window: Optional[int] = None
    # layer indices using FULL attention even when sliding_window is set
    full_attn_layers: Tuple[int, ...] = ()
    moe: Optional[MoECfg] = None
    ssm: Optional[SSMCfg] = None          # hybrid (hymba): parallel attn+mamba
    rwkv: Optional[RWKVCfg] = None        # attn-free rwkv6
    enc_dec: Optional[EncDecCfg] = None
    vlm: Optional[VLMCfg] = None
    # numeric policy
    param_dtype: str = "bfloat16"
    opt_moment_dtype: str = "float32"     # grok uses bfloat16 (HBM fit, see DESIGN)
    # attention impl: 'masked_scan' (baseline) | 'triangular' (optimized)
    attn_impl: str = "masked_scan"
    attn_chunk_q: int = 512
    attn_chunk_k: int = 512
    attn_softcap: Optional[float] = None   # grok: 30.0 logit soft-capping
    mlp_style: str = "swiglu"              # 'swiglu' | 'gelu2' (whisper)
    # ssm/rwkv mixer impl: 'scan' (baseline per-step) | 'chunked' (block form)
    mixer_impl: str = "scan"

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run the long_500k decode cell? (bounded state)"""
        if self.rwkv is not None:
            return True
        if self.sliding_window is not None:
            return True  # bounded KV window (+ SSM state for hybrids)
        return False

    def n_params(self) -> int:
        """Analytic parameter count (embeddings included)."""
        d, f, L, V = self.d_model, self.d_ff, self.n_layers, self.vocab
        hd = self.hd
        if self.rwkv is not None:
            H = self.rwkv.n_heads
            per_layer = (
                4 * d * H * self.rwkv.head_dim   # r,k,v,g (time-mix)
                + d * H * self.rwkv.head_dim     # output proj
                + 2 * self.rwkv.decay_lora * d   # decay lora
                + 2 * d * f // 2 + d * f // 2    # channel mix (approx 3 mats)
            )
            body = L * per_layer
        else:
            attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
            if self.moe is not None:
                ffn = self.moe.n_experts * 3 * d * f + d * self.moe.n_experts  # router
            else:
                ffn = 3 * d * f
            per_layer = attn + ffn
            if self.ssm is not None:
                s = self.ssm
                di = s.n_heads * s.head_dim
                per_layer += 2 * d * di + di * d + di * (2 * s.state_dim)  # in/gate/out + B,C proj
            body = L * per_layer
            if self.enc_dec is not None:
                # encoder layers + decoder cross-attention
                enc = self.enc_dec.enc_layers * (attn + 3 * d * f)
                cross = L * (d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d)
                body += enc + cross
        emb = V * d * (1 if self.tie_embeddings else 2)
        return body + emb

    def n_active_params(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if self.moe is None:
            return self.n_params()
        d, f, L = self.d_model, self.d_ff, self.n_layers
        inactive = L * (self.moe.n_experts - self.moe.top_k) * 3 * d * f
        return self.n_params() - inactive


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    phase: str                   # 'train' | 'prefill' | 'decode'

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def reduced(cfg: ArchConfig, **overrides) -> ArchConfig:
    """A tiny same-family config for CPU smoke tests."""
    base = dict(
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=256,
        head_dim=16,
    )
    if cfg.moe is not None:
        base["moe"] = MoECfg(n_experts=2, top_k=2, capacity_factor=1.5, group_size=16)
    if cfg.ssm is not None:
        base["ssm"] = SSMCfg(state_dim=4, n_heads=4, head_dim=16, conv_width=4)
    if cfg.rwkv is not None:
        base["rwkv"] = RWKVCfg(n_heads=4, head_dim=16, decay_lora=8)
        base["n_kv_heads"] = base["n_heads"]
    if cfg.enc_dec is not None:
        base["enc_dec"] = EncDecCfg(enc_layers=2, enc_seq=24)
    if cfg.vlm is not None:
        base["vlm"] = VLMCfg(n_patches=8)
    if cfg.sliding_window is not None:
        base["sliding_window"] = 32
        # keep full-attn layer indices in range
        base["full_attn_layers"] = tuple(i for i in cfg.full_attn_layers if i < 2)
    base.update(overrides)
    return replace(cfg, **base)
