"""mixtral-8x22b [moe] — 8 experts top-2, SWA (arXiv:2401.04088; hf).

56L d_model=6144 48H (GQA kv=8, head_dim 128) d_ff=16384 vocab=32768,
MoE 8e top-2.  Sliding window 4096 per the assignment => bounded decode
cache, long_500k runnable.
"""
from .base import ArchConfig, MoECfg

CONFIG = ArchConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab=32768,
    sliding_window=4096,
    moe=MoECfg(n_experts=8, top_k=2, capacity_factor=1.25, group_size=2048),
)
