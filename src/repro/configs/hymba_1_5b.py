"""hymba-1.5b [hybrid] — parallel attn+mamba heads (arXiv:2411.13676; hf).

32L d_model=1600 25H (GQA kv=5, head_dim 64) d_ff=5504 vocab=32001,
ssm_state=16.  Sliding-window attention everywhere except {first, middle,
last} layers (full attention), per the Hymba recipe; the mamba branch runs
in parallel with attention in every layer (per-branch RMSNorm, mean fuse).
Sub-quadratic: SWA ring caches + constant SSM state => long_500k runnable.
"""
from .base import ArchConfig, SSMCfg

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab=32001,
    sliding_window=1024,
    full_attn_layers=(0, 16, 31),
    ssm=SSMCfg(state_dim=16, n_heads=25, head_dim=64, conv_width=4),
)
