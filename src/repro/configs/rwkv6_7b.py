"""rwkv6-7b "Finch" [ssm] — attn-free, data-dependent decay
(arXiv:2404.05892; hf).

32L d_model=4096 (64 wkv heads x head_dim 64) d_ff=14336 vocab=65536.
Constant-size decode state (token-shift vectors + (H, 64, 64) wkv state)
=> long_500k runnable.
"""
from .base import ArchConfig, RWKVCfg

CONFIG = ArchConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,
    n_kv_heads=64,
    head_dim=64,
    d_ff=14336,
    vocab=65536,
    norm="layernorm",
    rwkv=RWKVCfg(n_heads=64, head_dim=64, decay_lora=64),
)
