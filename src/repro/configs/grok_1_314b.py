"""grok-1-314b [moe] — 8 experts top-2 (hf:xai-org/grok-1; unverified).

64L d_model=6144 48H (GQA kv=8, head_dim 128) d_ff=32768 vocab=131072,
MoE 8e top-2, attention logit soft-capping at 30.  Full attention =>
long_500k skipped (DESIGN §Arch-applicability).  Adam moments in bf16 so
params+opt+grads fit the single-pod HBM budget (DESIGN §5 / EXPERIMENTS
§Dry-run note).
"""
from .base import ArchConfig, MoECfg

CONFIG = ArchConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    vocab=131072,
    attn_softcap=30.0,
    moe=MoECfg(n_experts=8, top_k=2, capacity_factor=1.25, group_size=2048),
    opt_moment_dtype="bfloat16",
)
