"""deepseek-7b [dense] — llama-arch (arXiv:2401.02954; hf).

30L d_model=4096 32H (GQA kv=32 == MHA, head_dim 128) d_ff=11008
vocab=102400.  Full attention => long_500k skipped.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-7b",
    family="dense",
    n_layers=30,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    head_dim=128,
    d_ff=11008,
    vocab=102400,
)
