"""Sharded Poplar: partitioned multi-engine logging (`ROADMAP` north star).

Public surface:

* :class:`~repro.shard.engine.ShardedEngine` / ``ShardedConfig`` — N
  independent Poplar shards behind a hash router; single-shard transactions
  run the existing batched fast path unchanged, cross-shard transactions go
  through the coordinator (shared base SSN, per-participant dependency
  records, commit when durable on every participant).
* :class:`~repro.shard.router.Router` — stable crc32 key partitioning +
  batch splitting.
* :func:`~repro.shard.recovery.recover_sharded` — per-shard vectorized
  replay + the cross-shard consistent cut.
"""

from .coordinator import CrossShardCoordinator, XTxn
from .engine import Shard, ShardBatchResult, ShardedConfig, ShardedEngine
from .recovery import ShardedRecoveredState, recover_sharded, resolve_cut
from .router import Router

__all__ = [
    "CrossShardCoordinator",
    "XTxn",
    "Shard",
    "ShardBatchResult",
    "ShardedConfig",
    "ShardedEngine",
    "ShardedRecoveredState",
    "recover_sharded",
    "resolve_cut",
    "Router",
]
