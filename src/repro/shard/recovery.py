"""Sharded crash recovery (paper §5, applied per shard + per cross edge).

Each shard recovers with the existing vectorized last-writer-wins replay
over its own devices (its SSN space is self-contained), with one addition —
a **consistent cut** over cross-shard transactions:

* every participant of a cross-shard transaction logged a record carrying
  the full ``[(shard, ssn)]`` dependency vector (``FLAG_XSHARD``), so each
  shard's log names the complete participant set;
* a cross-shard transaction is replayed **iff** a record with its gtid is
  durable on *all* participants, and — when it has reads — its per-shard
  SSN clears every participant's RSNe (``ssn_p <= RSNe_p``), the Qwr rule
  evaluated shard-locally on every edge.

Soundness mirrors §3.1/§5 per edge: an *acknowledged* cross-shard commit
required ``ssn_p <= DSN/CSN_p`` on every participant, and per-buffer SSNs
are monotone in flush order, so its records all survive the cut.
Conversely a transaction dropped by the cut was never acknowledged — and
because the forward path defers cross-shard write visibility to global
commit, nothing can have read its writes, so dropping it cascades nowhere.
Replayed RAW edges stay closed: any read predecessor has a tuple SSN below
the shared base, hence below the reader's per-shard SSN, hence durable (and
itself replayed) on its own shard.

Per-shard fuzzy checkpoints plug in unchanged: pass one checkpoint
directory per shard and each shard's image joins its replay reduction.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from ..core.checkpoint import load_latest_checkpoint
from ..core.par import parallel_for
from ..core.recovery import (
    RecoveredState,
    RecoveryReport,
    _replay_scalar,
    compute_rsne,
    device_ssn_floors,
    load_columnar_segmented,
    replay_columnar,
)
from ..core.storage import StorageDevice
from ..core.txn import ColumnarLog, LogRecord, decode_records

# (participant vector, has_reads) of one cross-shard transaction
_XInfo = Tuple[List[Tuple[int, int]], bool]


@dataclass
class ShardedRecoveredState:
    """Per-shard recovered images + the cross-shard cut statistics."""

    shards: List[RecoveredState] = field(default_factory=list)
    n_cross_seen: int = 0        # distinct gtids observed in any log
    n_cross_dropped: int = 0     # gtids dropped by the consistent cut

    def report_dict(self) -> Dict:
        """Aggregate of the per-shard :class:`RecoveryReport`s plus the
        cut statistics (the sharded counterpart of ``state.report``)."""
        return {
            "n_shards": len(self.shards),
            "n_cross_seen": self.n_cross_seen,
            "n_cross_dropped": self.n_cross_dropped,
            "shards": [
                st.report.to_dict() if st.report is not None else None
                for st in self.shards
            ],
        }

    @property
    def data(self) -> Dict[bytes, Tuple[bytes, int]]:
        """Merged image (keys are disjoint across shards by routing)."""
        out: Dict[bytes, Tuple[bytes, int]] = {}
        for st in self.shards:
            out.update(st.data)
        return out

    def get(self, key: bytes) -> Optional[bytes]:
        for st in self.shards:
            v = st.data.get(key)
            if v is not None:
                return v[0]
        return None


def _collect_cut_columnar(
    shard_logs: Sequence[Sequence[ColumnarLog]],
) -> Tuple[Dict[int, Set[int]], Dict[int, _XInfo]]:
    durable: Dict[int, Set[int]] = {}
    info: Dict[int, _XInfo] = {}
    for p, logs in enumerate(shard_logs):
        for log in logs:
            if log.x_rec is None:
                continue
            for i, rec in enumerate(log.x_rec.tolist()):
                g = int(log.tid[rec])
                durable.setdefault(g, set()).add(p)
                if g not in info:
                    lo, hi = int(log.xp_start[i]), int(log.xp_start[i + 1])
                    info[g] = (
                        list(zip(log.xp_shard[lo:hi].tolist(),
                                 log.xp_ssn[lo:hi].tolist())),
                        bool(log.has_reads[rec]),
                    )
    return durable, info


def resolve_cut(
    durable: Dict[int, Set[int]],
    info: Dict[int, _XInfo],
    rsne: Sequence[int],
) -> Dict[int, bool]:
    """Per-gtid replay decision: durable on all participants, and (for
    RAW-carrying transactions) ``ssn_p <= RSNe_p`` on every participant."""
    keep: Dict[int, bool] = {}
    for g, (parts, has_reads) in info.items():
        ok = all(q in durable.get(g, ()) for q, _ in parts)
        if ok and has_reads:
            ok = all(s <= rsne[q] for q, s in parts)
        keep[g] = ok
    return keep


def _cut_masks(
    shard_logs: Sequence[Sequence[ColumnarLog]], keep: Dict[int, bool]
) -> List[List[Optional[np.ndarray]]]:
    """Per-log boolean record masks encoding the cut (None = no x records)."""
    masks: List[List[Optional[np.ndarray]]] = []
    for logs in shard_logs:
        row: List[Optional[np.ndarray]] = []
        for log in logs:
            if log.x_rec is None:
                row.append(None)
                continue
            m = np.ones(log.n_records, dtype=bool)
            for rec in log.x_rec.tolist():
                m[rec] = keep[int(log.tid[rec])]
            row.append(m)
        masks.append(row)
    return masks


def recover_sharded(
    shard_devices: Sequence[Sequence[StorageDevice]],
    checkpoint_dirs: Optional[Sequence[Optional[str]]] = None,
    parallel: bool = True,
    mode: str = "vectorized",
) -> ShardedRecoveredState:
    """Restore every shard from its devices (+ optional per-shard fuzzy
    checkpoints), resolving cross-shard transactions against the cut.

    ``shard_devices[p]`` must be shard ``p``'s device list in the same shard
    order the engine ran with (the xdep shard ids index into it).  ``mode``
    is the per-shard replay engine: ``vectorized`` (default), ``pallas``, or
    ``scalar`` (the per-record oracle).
    """
    if mode not in ("vectorized", "pallas", "scalar"):
        raise ValueError(f"unknown recovery mode {mode!r}")
    n = len(shard_devices)
    if checkpoint_dirs is not None:
        assert len(checkpoint_dirs) == n

    if mode == "scalar":
        return _recover_sharded_scalar(shard_devices, checkpoint_dirs, parallel)

    # stage 1: decode every shard's logs (shards in parallel, like the
    # single-engine path parallelizes over devices; within a shard the
    # decode is per (device, sealed segment) — see load_columnar_segmented)
    shard_logs: List[List[ColumnarLog]] = [None] * n  # type: ignore[list-item]
    seg_rows: List[List[Dict]] = [[] for _ in range(n)]

    import time as _time

    decode_s = [0.0] * n

    def _load(p: int) -> None:
        t0 = _time.perf_counter()
        shard_logs[p] = load_columnar_segmented(
            shard_devices[p], parallel=False, segments=seg_rows[p]
        )
        decode_s[p] = _time.perf_counter() - t0

    parallel_for(n, _load, parallel)

    rsne = [
        compute_rsne(logs, floors=device_ssn_floors(shard_devices[p]))
        for p, logs in enumerate(shard_logs)
    ]

    # stage 2: the consistent cut over cross-shard records
    durable, info = _collect_cut_columnar(shard_logs)
    keep = resolve_cut(durable, info, rsne)
    masks = _cut_masks(shard_logs, keep)

    # stage 3: per-shard vectorized replay under the cut
    out = ShardedRecoveredState(
        n_cross_seen=len(info),
        n_cross_dropped=sum(1 for v in keep.values() if not v),
    )
    for p in range(n):
        st = RecoveredState(rsne=rsne[p])
        n_ckpt_keys = 0
        if checkpoint_dirs is not None and checkpoint_dirs[p] is not None:
            ckpt = load_latest_checkpoint(checkpoint_dirs[p], parallel=parallel)
            if ckpt is not None:
                st.rsns = ckpt.rsn
                st.data.update(ckpt.data)
                n_ckpt_keys = len(ckpt.data)
        t_rep = _time.perf_counter()
        data, n_replayed, n_skipped = replay_columnar(
            shard_logs[p],
            rsne[p],
            base=st.data or None,
            use_kernel=(mode == "pallas"),
            record_mask=masks[p],
        )
        st.data = data
        st.n_replayed = n_replayed
        st.n_skipped_uncommitted = n_skipped
        # the cut's drops land in n_skipped along with the local Qwr rule's;
        # split them back out for the report by re-counting the cut mask
        n_cut_dropped = sum(
            int((~m[log.x_rec]).sum())
            for log, m in zip(shard_logs[p], masks[p])
            if m is not None and log.x_rec is not None
        )
        st.report = RecoveryReport(
            mode=mode,
            n_devices=len(shard_devices[p]),
            rsns=st.rsns,
            rsne=rsne[p],
            n_decoded=sum(lg.n_records for lg in shard_logs[p]),
            n_replayed=n_replayed,
            n_dropped_above_rsne=n_skipped - n_cut_dropped,
            n_dropped_not_durable_all=n_cut_dropped,
            checkpoint_keys=n_ckpt_keys,
            decode_s=decode_s[p],
            replay_s=_time.perf_counter() - t_rep,
            segments=seg_rows[p],
        )
        out.shards.append(st)
    return out


def _recover_sharded_scalar(
    shard_devices: Sequence[Sequence[StorageDevice]],
    checkpoint_dirs: Optional[Sequence[Optional[str]]],
    parallel: bool,
) -> ShardedRecoveredState:
    """Per-record oracle twin of the vectorized path (recovery's
    ``mode="scalar"`` pattern): row-decoded logs, the same cut, guarded
    dict replay."""
    n = len(shard_devices)
    shard_recs: List[List[List[LogRecord]]] = [
        [decode_records(d.read_all()) for d in shard_devices[p]] for p in range(n)
    ]
    rsne = [
        compute_rsne(recs, floors=device_ssn_floors(shard_devices[p]))
        for p, recs in enumerate(shard_recs)
    ]

    durable: Dict[int, Set[int]] = {}
    info: Dict[int, _XInfo] = {}
    for p in range(n):
        for recs in shard_recs[p]:
            for r in recs:
                if r.xdep is None:
                    continue
                durable.setdefault(r.tid, set()).add(p)
                info.setdefault(r.tid, (list(r.xdep), r.has_reads))
    keep = resolve_cut(durable, info, rsne)

    out = ShardedRecoveredState(
        n_cross_seen=len(info),
        n_cross_dropped=sum(1 for v in keep.values() if not v),
    )
    for p in range(n):
        st = RecoveredState(rsne=rsne[p])
        if checkpoint_dirs is not None and checkpoint_dirs[p] is not None:
            ckpt = load_latest_checkpoint(checkpoint_dirs[p], parallel=parallel)
            if ckpt is not None:
                st.rsns = ckpt.rsn
                st.data.update(ckpt.data)
        kept = [
            [r for r in recs if r.xdep is None or keep[r.tid]]
            for recs in shard_recs[p]
        ]
        dropped = sum(len(a) - len(b) for a, b in zip(shard_recs[p], kept))
        _replay_scalar(st, kept, rsne[p], parallel)
        st.n_skipped_uncommitted += dropped
        out.shards.append(st)
    return out
