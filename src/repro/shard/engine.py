"""Partitioned multi-engine logging: N independent Poplar shards + a router.

Each shard owns a full private Poplar stack — :class:`PoplarEngine` (its own
log buffers, devices, logger threads, Qww/Qwr queues),
:class:`~repro.db.array_table.ArrayTable` tuple store, and
:class:`~repro.db.batch.BatchOCC` batched executor — so single-shard
transactions run the existing array-native fast path *unchanged* and the
shards share no latch, no SSN counter and no device head.  A hash
:class:`~repro.shard.router.Router` partitions the key space and splits
incoming :class:`~repro.db.batch.TxnSpec` batches into per-shard
sub-batches; transactions spanning shards go through the
:class:`~repro.shard.coordinator.CrossShardCoordinator` (shared base SSN,
one dependency-stamped record per participant, commit when durable
everywhere).

Worker ids and tid stripes are offset per shard (``worker_id_base``) so the
whole system lives in one collision-free tid universe; the coordinator gets
its own stripe above all shard workers.

Like :class:`PoplarEngine`, the sharded engine runs threaded (``start()``)
or stepped (tests drive :meth:`tick` deterministically).
"""

from __future__ import annotations

import dataclasses
import os
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from ..core.engine import EngineConfig, PoplarEngine
from ..core.txn import Txn
from ..db.array_table import ArrayTable
from ..db.batch import BatchOCC, TxnSpec
from ..db.occ import TID_STRIDE, TidStripe
from .coordinator import CrossShardCoordinator, XTxn
from .router import Router


@dataclass
class ShardedConfig:
    n_shards: int = 2
    n_buffers: int = 1            # log buffers (= devices) per shard
    n_workers: int = 1            # executor worker/tid stripes per shard
    mode: str = "vectorized"      # BatchOCC mode: 'vectorized' | 'pallas'
    device_kind: str = "ssd"
    device_dir: Optional[str] = None   # per-shard subdirs are created inside
    device_clock: str = "real"
    table_capacity: int = 1024
    # full per-shard EngineConfig override (n_buffers etc. come from it);
    # device_dir is still re-pointed at the shard subdirectory
    engine: Optional[EngineConfig] = None
    # adaptive command/value framing: ``shard_id -> AdaptivePolicy`` factory
    # handed to each shard's BatchOCC (None keeps every shard pure-value).
    # Per-shard because eligibility depends on the shard's *own* checkpoint
    # RSN — a dep covered by shard 0's image may be uncovered on shard 1.
    policy_factory: Optional[Callable[[int], object]] = None


class Shard:
    """One partition: a private engine, tuple store, and batch executor."""

    def __init__(self, shard_id: int, cfg: ShardedConfig):
        self.id = shard_id
        ecfg = cfg.engine or EngineConfig(
            n_buffers=cfg.n_buffers,
            device_kind=cfg.device_kind,
            device_clock=cfg.device_clock,
        )
        # always re-point a configured device_dir (from either config
        # source) at a per-shard subdirectory — shards sharing one
        # directory would interleave frames into the same log files
        ddir = cfg.device_dir if cfg.device_dir is not None else ecfg.device_dir
        if ddir is not None:
            ecfg = dataclasses.replace(
                ecfg, device_dir=os.path.join(ddir, f"shard{shard_id}")
            )
        self.engine = PoplarEngine(ecfg)
        self.engine._trace_shard = shard_id
        self.table = ArrayTable(capacity=cfg.table_capacity, name=f"shard{shard_id}")
        self.occ = BatchOCC(
            self.table,
            self.engine,
            n_workers=cfg.n_workers,
            mode=cfg.mode,
            worker_id_base=shard_id * cfg.n_workers,
            policy=(
                cfg.policy_factory(shard_id)
                if cfg.policy_factory is not None else None
            ),
        )


@dataclass
class ShardBatchResult:
    """Outcome of one batch through the sharded engine.

    ``committed`` are the single-shard pre-committed ``Txn``s (durable once
    their shard drains them); ``cross`` the prepared cross-shard ``XTxn``s
    (committed by a later :meth:`ShardedEngine.drain` once durable on every
    participant); ``aborted`` the losing batch indices.
    """

    committed: List[Txn] = field(default_factory=list)
    committed_idx: List[int] = field(default_factory=list)
    cross: List[XTxn] = field(default_factory=list)
    cross_idx: List[int] = field(default_factory=list)
    aborted: List[int] = field(default_factory=list)


class ShardedEngine:
    def __init__(self, cfg: Optional[ShardedConfig] = None, **overrides):
        cfg = cfg or ShardedConfig(**overrides)
        assert (cfg.n_shards + 1) * cfg.n_workers <= TID_STRIDE, (
            "shard x worker grid exceeds the tid stripe space"
        )
        self.cfg = cfg
        self.router = Router(cfg.n_shards)
        self.shards = [Shard(p, cfg) for p in range(cfg.n_shards)]
        self.coordinator = CrossShardCoordinator(
            self.shards, self.router,
            TidStripe(cfg.n_shards * cfg.n_workers),
        )

    # --- tuple-store interop (loader duck-type: insert/get like a table) ----
    def shard_of(self, key: str) -> int:
        return self.router.shard_of(key)

    def insert(self, key: str, value: bytes) -> int:
        return self.shards[self.shard_of(key)].table.insert(key, value)

    def get(self, key: str) -> Optional[Tuple[bytes, int]]:
        return self.shards[self.shard_of(key)].table.get(key)

    def to_dict(self) -> Dict[bytes, Tuple[bytes, int]]:
        out: Dict[bytes, Tuple[bytes, int]] = {}
        for sh in self.shards:
            out.update(sh.table.to_dict())
        return out

    @property
    def devices(self) -> List[List]:
        """Per-shard device lists (the shape sharded recovery takes)."""
        return [sh.engine.devices for sh in self.shards]

    # --- forward path -------------------------------------------------------
    def execute_batch(
        self, specs: Sequence[TxnSpec], max_rounds: int = 1
    ) -> ShardBatchResult:
        """Split one batch by participant set, run the per-shard sub-batches
        through each shard's unchanged fast path, then prepare the
        cross-shard remainder through the coordinator."""
        res = ShardBatchResult()
        if not len(specs):
            return res
        per_shard, cross = self.router.split(specs)
        for p in sorted(per_shard):
            idxs = [i for i, _ in per_shard[p]]
            sub = [s for _, s in per_shard[p]]
            r = self.shards[p].occ.execute_batch(sub, max_rounds=max_rounds)
            res.committed.extend(r.committed)
            res.committed_idx.extend(idxs[j] for j in r.committed_idx)
            res.aborted.extend(idxs[j] for j in r.aborted)
        for i, spec, shard_ids in cross:
            xt = self.coordinator.execute(spec, shard_ids)
            if xt is not None:
                res.cross.append(xt)
                res.cross_idx.append(i)
            else:
                res.aborted.append(i)
        return res

    def drain(self) -> int:
        """Drain every shard's commit queues + sweep the cross-shard
        pending set; returns the number of transactions committed."""
        n = 0
        for sh in self.shards:
            n += sh.occ.drain()
        n += self.coordinator.sweep()
        return n

    # --- lifecycle ----------------------------------------------------------
    def start(self) -> None:
        for sh in self.shards:
            sh.engine.start()

    def stop(self) -> None:
        for sh in self.shards:
            sh.engine.stop()

    def tick(self, force: bool = True) -> None:
        """Stepped mode: one logger tick on every buffer of every shard
        (tests drive flushing deterministically, like ``logger_tick``)."""
        for sh in self.shards:
            for i in range(len(sh.engine.buffers)):
                sh.engine.logger_tick(i, force=force)

    def quiesce(self, timeout: float = 30.0) -> None:
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            self.tick(force=True)
            self.drain()
            pending = self.coordinator.pending_count()
            for sh in self.shards:
                pending += sum(q.pending() for q in sh.engine.queues.values())
                pending += sum(b.pending_bytes() for b in sh.engine.buffers)
            if pending == 0:
                return
            time.sleep(1e-4)
        raise TimeoutError("sharded engine quiesce timed out")

    # --- stats --------------------------------------------------------------
    def stats(self) -> Dict:
        return {
            "engine": "sharded_poplar",
            "n_shards": self.cfg.n_shards,
            "txn_logged": sum(sh.engine.txn_logged for sh in self.shards),
            "txn_committed": sum(sh.engine.txn_committed for sh in self.shards),
            "cross_prepared": self.coordinator.prepared,
            "cross_committed": self.coordinator.committed_total,
            "cross_aborts": self.coordinator.aborts,
            "shards": [sh.engine.stats() for sh in self.shards],
        }
