"""Cross-shard transaction coordinator (Algorithm 1 lifted across shards).

A cross-shard transaction T touching shards P = {p1..pk}:

* **prepare** — under every participant's table mutex (acquired in shard-id
  order; deadlock-free against the single-mutex batch executors): validate
  (no foreign write locks on any accessed row, driver-observed SSNs fresh),
  compute the global base SSN ``base = max tuple SSN over RS ∪ WS across
  all participants`` (:func:`repro.core.ssn.base_ssn_global`), then reserve
  one log record on *every* participant shard — including read-only
  participants, which get a zero-write marker — via
  :meth:`~repro.core.engine.PoplarEngine.reserve_record` from that shared
  base.  Once every per-shard SSN is known, each record is framed with the
  full ``[(shard, ssn)]`` dependency vector (the explicit cross-shard
  WAW/RAW edge; ``FLAG_XSHARD``) and memcpy'd into its ring.  Write rows
  stay *locked and unmodified*: cross-shard writes become visible only at
  commit, so no transaction can ever read cross-shard dirty data — which is
  what keeps the recovery cut free of cross-shard cascades.

* **commit** — T commits when the single-shard watermark rule
  (:meth:`~repro.core.commit.CommitProtocol.committable`) passes on *every*
  participant: ``ssn_p <= DSN(buffer_p)`` per shard for write-only
  transactions (Qww generalized), ``ssn_p <= CSN_p`` per shard when T has
  reads (Qwr generalized — any RAW predecessor on shard p has a tuple SSN
  below the shared base, hence ``< ssn_p <= CSN_p``, hence durable on p).
  Only then are the write values + SSNs applied to the tables and the row
  locks released.

Because reserving from the shared base bumps every participant buffer's SSN
past the base, the per-shard SSN spaces stay loosely synchronized without
any global sequencer — the same observation behind Taurus's vector LSNs and
dependency logging, specialized to Poplar's partially-constrained order.
"""

from __future__ import annotations

import threading
import time
from contextlib import ExitStack
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.ssn import base_ssn_global
from ..core.txn import Txn
from ..db.batch import TxnSpec
from ..db.occ import TidStripe
from ..trace.span import ST_XPREPARE, TRACER
from ..obs.metrics import REGISTRY
from .router import Router


@dataclass
class XPart:
    """One participant shard's slice of a cross-shard transaction."""

    shard: int
    buffer_id: int
    ssn: int
    wr_rows: np.ndarray            # table rows this txn writes on the shard
    wr_vals: np.ndarray            # object array of value payloads
    rd_rows: np.ndarray            # rows read on the shard...
    rd_ssn: np.ndarray             # ...and the tuple SSNs observed at prepare


@dataclass
class XTxn:
    """A prepared cross-shard transaction awaiting its global commit."""

    gtid: int
    has_reads: bool
    parts: List[XPart]
    committed: bool = False
    t_start: float = 0.0
    t_precommit: float = 0.0
    t_commit: float = 0.0

    @property
    def shards(self) -> List[int]:
        return [p.shard for p in self.parts]


class CrossShardCoordinator:
    """Prepares and commits cross-shard transactions over a set of shards.

    ``shards`` is the sharded engine's shard list (each exposing ``engine``
    and ``table``); the coordinator owns its own tid stripe so gtids never
    collide with any shard executor's tids.
    """

    def __init__(self, shards: Sequence, router: Router, tids: TidStripe):
        self.shards = shards
        self.router = router
        self.tids = tids
        self.pending: List[XTxn] = []
        self.lock = threading.Lock()
        self.aborts = 0
        self.prepared = 0
        self.committed_total = 0
        self._seq = 0  # spreads cross-shard records across each shard's buffers

    # --- prepare ------------------------------------------------------------
    def execute(
        self, spec: TxnSpec, shard_ids: Optional[Sequence[int]] = None
    ) -> Optional[XTxn]:
        """Run the prepare phase for one cross-shard spec; returns the
        pending :class:`XTxn` (committed later by :meth:`sweep`) or None on
        a validation abort."""
        router = self.router
        shard_ids = sorted(shard_ids) if shard_ids else router.shards_of(spec)
        t_start = time.perf_counter()

        # group accesses per shard (observed SSNs stay aligned with reads)
        rd_keys: Dict[int, List[str]] = {p: [] for p in shard_ids}
        rd_obs: Dict[int, List[int]] = {p: [] for p in shard_ids}
        wr_keys: Dict[int, List[str]] = {p: [] for p in shard_ids}
        wr_vals: Dict[int, List[bytes]] = {p: [] for p in shard_ids}
        for i, k in enumerate(spec.reads):
            p = router.shard_of(k)
            rd_keys[p].append(k)
            rd_obs[p].append(-1 if spec.observed is None else int(spec.observed[i]))
        for k, v in spec.writes:
            p = router.shard_of(k)
            wr_keys[p].append(k)
            wr_vals[p].append(v)

        # map keys to rows before taking any mutex (rows_for locks internally
        # for inserts; rows are append-only so the arrays stay valid)
        rd_rows = {p: self.shards[p].table.rows_for(rd_keys[p]) for p in shard_ids}
        wr_rows = {p: self.shards[p].table.rows_for(wr_keys[p]) for p in shard_ids}

        has_reads = bool(spec.reads)
        xt: Optional[XTxn] = None
        with ExitStack() as stack:
            for p in shard_ids:  # shard-id order: deadlock-free
                stack.enter_context(self.shards[p].table.mutex)

            # --- validate -----------------------------------------------
            for p in shard_ids:
                table = self.shards[p].table
                rows = np.concatenate([rd_rows[p], wr_rows[p]])
                if table.locked_rows(rows).any():
                    self.aborts += 1
                    if REGISTRY.enabled:
                        REGISTRY.count("shard.xprepare.aborts")
                    return None
                obs = np.asarray(rd_obs[p], dtype=np.int64)
                if len(obs) and (
                    (obs >= 0) & (table.ssn[rd_rows[p]] != obs)
                ).any():
                    self.aborts += 1
                    if REGISTRY.enabled:
                        REGISTRY.count("shard.xprepare.aborts")
                    return None

            # --- sequence: shared base, one record per participant -------
            base = base_ssn_global(
                self.shards[p].table.ssn[rows_p]
                for p in shard_ids
                for rows_p in (rd_rows[p], wr_rows[p])
            )
            gtid = self.tids.next()
            self._seq += 1
            txns: List[Txn] = []
            for p in shard_ids:
                t = Txn(tid=gtid)
                t.write_set = list(zip(wr_keys[p], wr_vals[p]))
                if has_reads:
                    t.read_set = [("", 0)]  # sentinel: flags + Qwr routing
                # placeholder vector: fixes the framed length before the
                # per-shard SSNs are known
                t.xdep = [(q, 0) for q in shard_ids]
                t.t_start = t_start
                self.shards[p].engine.reserve_record(t, base, self._seq)
                txns.append(t)
            xdep = [(p, t.ssn) for p, t in zip(shard_ids, txns)]
            parts: List[XPart] = []
            for p, t in zip(shard_ids, txns):
                t.xdep = list(xdep)
                self.shards[p].engine.fill_record(t)
                vals = np.empty(len(wr_vals[p]), dtype=object)
                vals[:] = wr_vals[p]
                parts.append(
                    XPart(
                        shard=p,
                        buffer_id=t.buffer_id,
                        ssn=t.ssn,
                        wr_rows=wr_rows[p],
                        wr_vals=vals,
                        rd_rows=rd_rows[p],
                        rd_ssn=self.shards[p].table.ssn[rd_rows[p]].copy(),
                    )
                )
                # hold the write locks until global commit: values and tuple
                # SSNs are untouched, so concurrent transactions abort (and
                # retry) rather than observe cross-shard dirty state
                self.shards[p].table.claim_rows(wr_rows[p], gtid)

            xt = XTxn(gtid=gtid, has_reads=has_reads, parts=parts,
                      t_start=t_start, t_precommit=time.perf_counter())
            if TRACER.enabled:
                # one span per participant: the durable-on-all join in the
                # trace DAG needs each (shard, buffer, ssn) leg separately
                for part in parts:
                    TRACER.record(
                        ST_XPREPARE, shard=part.shard, device=part.buffer_id,
                        batch=gtid, txn_lo=part.ssn, txn_hi=part.ssn,
                        t0=t_start, t1=xt.t_precommit, n_txn=1,
                        aux=len(parts),
                    )
        # append outside the table mutexes: sweep() applies under self.lock
        # while taking table mutexes, so the reverse nesting would deadlock
        with self.lock:
            self.pending.append(xt)
        self.prepared += 1
        if REGISTRY.enabled:
            REGISTRY.observe("shard.xprepare_s", xt.t_precommit - t_start)
        return xt

    # --- commit -------------------------------------------------------------
    def _committable(self, xt: XTxn) -> bool:
        for part in xt.parts:
            eng = self.shards[part.shard].engine
            if not eng.commit.committable(part.ssn, xt.has_reads, part.buffer_id):
                return False
        return True

    def _apply(self, xt: XTxn) -> None:
        for part in xt.parts:
            sh = self.shards[part.shard]
            with sh.table.mutex:
                if len(part.wr_rows):
                    sh.table.values[part.wr_rows] = part.wr_vals
                    sh.table.ssn[part.wr_rows] = part.ssn
                sh.table.release_rows(part.wr_rows)
            with sh.engine._count_lock:
                sh.engine.txn_committed += 1
        xt.committed = True
        xt.t_commit = time.perf_counter()

    def sweep(self) -> int:
        """Commit every pending cross-shard transaction whose records are
        durable (per the per-shard watermark rule) on all participants.
        Unlike the per-worker FIFO queues, pending transactions are scanned
        in full — per-shard SSN vectors are only partially ordered, so a
        blocked head says nothing about the rest."""
        n = 0
        with self.lock:
            still: List[XTxn] = []
            for xt in self.pending:
                if self._committable(xt):
                    self._apply(xt)
                    self.committed_total += 1
                    n += 1
                else:
                    still.append(xt)
            self.pending = still
        return n

    def pending_count(self) -> int:
        with self.lock:
            return len(self.pending)
