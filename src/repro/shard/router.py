"""Hash-based key partitioning for the sharded engine.

Every key lives on exactly one shard: ``shard_of(key) = crc32(key) % N``.
CRC32 rather than Python's ``hash`` so the mapping is stable across
processes — recovery (a different process) must route each key to the same
shard that logged it, and benchmarks must be able to pre-bucket keys.

``split`` partitions an incoming batch of :class:`~repro.db.batch.TxnSpec`
into per-shard sub-batches (every access on one shard — these run the
existing single-engine fast path unchanged) and a cross-shard remainder
(these go through the :class:`~repro.shard.coordinator.CrossShardCoordinator`).
"""

from __future__ import annotations

import zlib
from typing import Dict, List, Sequence, Tuple

from ..db.batch import TxnSpec


class Router:
    def __init__(self, n_shards: int):
        assert n_shards >= 1
        self.n_shards = n_shards
        self._cache: Dict[str, int] = {}

    def shard_of(self, key: str) -> int:
        s = self._cache.get(key)
        if s is None:
            s = zlib.crc32(key.encode()) % self.n_shards
            self._cache[key] = s
        return s

    def shards_of(self, spec: TxnSpec) -> List[int]:
        """Sorted participant shard ids of one spec (reads ∪ writes)."""
        shards = {self.shard_of(k) for k in spec.reads}
        shards.update(self.shard_of(k) for k, _ in spec.writes)
        return sorted(shards)

    def split(
        self, specs: Sequence[TxnSpec]
    ) -> Tuple[
        Dict[int, List[Tuple[int, TxnSpec]]],
        List[Tuple[int, TxnSpec, List[int]]],
    ]:
        """Partition a batch by participant set.

        Returns ``(per_shard, cross)``: ``per_shard[p]`` holds the
        ``(batch_index, spec)`` pairs fully contained in shard ``p`` (batch
        order preserved — it fixes the per-shard WAW chain), ``cross`` the
        ``(batch_index, spec, participant_shards)`` triples spanning more
        than one shard.
        """
        per_shard: Dict[int, List[Tuple[int, TxnSpec]]] = {}
        cross: List[Tuple[int, TxnSpec, List[int]]] = []
        for i, spec in enumerate(specs):
            shards = self.shards_of(spec)
            if len(shards) == 1:
                per_shard.setdefault(shards[0], []).append((i, spec))
            else:
                cross.append((i, spec, shards))
        return per_shard, cross
