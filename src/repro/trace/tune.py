"""Configuration autotuner: sweep the replay simulator over a grid of
(batch size, device count) candidates and pick the config with the best
predicted throughput for a traced workload.

The point is the loop the ROADMAP's cost-model items need: fit a
:class:`~repro.trace.sim.CostModel` once from a short calibration trace,
then answer "how should I deploy" without re-running the engine per cell.
``benchmarks/fig_trace.py`` cross-checks the choice against the
measured-best cell (must be within 10% of its throughput).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from .sim import CostModel, SimConfig, SimResult, WorkloadProfile, simulate


@dataclass
class TuneResult:
    batch_size: int
    devices: int
    predicted: SimResult
    table: List[Dict] = field(default_factory=list)

    def to_dict(self) -> Dict:
        return {
            "batch_size": self.batch_size,
            "devices": self.devices,
            "predicted_txn_s": self.predicted.txn_s,
            "predicted_p99_commit_s": self.predicted.p99_commit,
            "table": self.table,
        }


def autotune(
    model: CostModel,
    profile: Optional[WorkloadProfile] = None,
    n_txn: int = 20_000,
    shards: int = 1,
    batch_grid: Sequence[int] = (64, 128, 256, 512, 1024),
    device_grid: Sequence[int] = (1, 2, 4),
    device_bw: Optional[float] = None,
    cross_ratio: float = 0.0,
    p99_budget: Optional[float] = None,
    io_unit: Optional[int] = None,
) -> TuneResult:
    """Pick ``(batch_size, devices)`` maximizing predicted txn/s.

    ``p99_budget`` (seconds), when given, filters out candidates whose
    predicted p99 commit latency blows the budget before ranking — the
    classic group-commit tradeoff (bigger batches amortize CPU but delay
    durability) made explicit.  Falls back to the unconstrained best if
    nothing fits the budget.
    """
    best: Optional[Tuple[float, int, int, SimResult]] = None
    best_any: Optional[Tuple[float, int, int, SimResult]] = None
    table: List[Dict] = []
    for devices in device_grid:
        for batch in batch_grid:
            cfg = SimConfig(
                shards=shards,
                devices=devices,
                batch_size=batch,
                n_txn=n_txn,
                device_bw=device_bw,
                cross_ratio=cross_ratio,
            )
            if io_unit is not None:
                cfg.io_unit = io_unit
            r = simulate(model, cfg, profile)
            table.append({
                "batch_size": batch,
                "devices": devices,
                "txn_s": r.txn_s,
                "p99_commit_s": r.p99_commit,
            })
            key = (r.txn_s, batch, devices, r)
            if best_any is None or key[0] > best_any[0]:
                best_any = key
            if p99_budget is not None and r.p99_commit > p99_budget:
                continue
            if best is None or key[0] > best[0]:
                best = key
    chosen = best or best_any
    assert chosen is not None, "empty tuning grid"
    _, batch, devices, res = chosen
    return TuneResult(
        batch_size=batch, devices=devices, predicted=res, table=table
    )
