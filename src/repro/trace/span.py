"""Near-zero-overhead structured stage tracer.

A process-local :class:`Tracer` records one row per pipeline-stage span —
``(stage, shard, device, batch_id, txn_span, t_start, t_end, bytes,
n_txn, aux)`` — into preallocated numpy ring buffers.  Hook points live in
the seven pipeline stages:

* ``BatchOCC`` validate / sequence / encode   (`repro.db.batch`)
* ``PoplarEngine`` publish + logger flush     (`repro.core.engine`)
* cross-shard prepare                         (`repro.shard.coordinator`)
* ``LogShipper`` ship + ``ReplicaApplier`` apply  (`repro.replica`)
* ``GroupCommitScheduler`` cut / ack          (`repro.serve.scheduler`)
* recovery decode / replay                    (`repro.core.recovery`)

Every hook is guarded by one attribute load on the module singleton::

    _trace = TRACER.enabled
    if _trace:
        _t0 = time.perf_counter()
    ... stage work ...
    if _trace:
        TRACER.record(ST_..., ...)

so the disabled tracer is a no-op: no allocation, no lock, no branch
beyond the bool test (pinned by ``tests/test_trace.py`` via a
``tracemalloc`` filter on this file).  When enabled, :meth:`Tracer.record`
claims a ring slot under a lock and writes ten scalar cells — a few
microseconds per *batch*-granular event, which is what keeps the measured
tracing overhead below the 3% budget (``BENCH_trace.json``).

``txn_span = (txn_lo, txn_hi)`` carries the SSN range a span covers (flush
spans: the DSN interval made durable; publish spans: the batch's SSN
range), which is what lets `repro.trace.dag` reconstruct durability edges
without any timestamps — the structural dump of two identical stepped runs
is byte-identical even though the wall-clock columns differ.
"""

from __future__ import annotations

import json
import threading
import warnings
from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np

# --- stage taxonomy ----------------------------------------------------------
ST_VALIDATE = 0    # BatchOCC: access gather + WW/RW/observed-SSN/lock masks
ST_SEQUENCE = 1    # BatchOCC: base-SSN segmented max + Txn bookkeeping
ST_ENCODE = 2      # BatchOCC: per-buffer reserve_batch + columnar framing
ST_PUBLISH = 3     # PoplarEngine.publish_batch: ring memcpy + queue pushes
ST_FLUSH = 4       # logger_tick: segment flushes to the device (IO)
ST_XPREPARE = 5    # CrossShardCoordinator.execute: one span per participant
ST_SHIP = 6        # LogShipper.poll: tail read + streaming columnar decode
ST_APPLY = 7       # ReplicaApplier.apply: vectorized fold into the table
ST_CUT = 8         # GroupCommitScheduler: batch cut + execute
ST_ACK = 9         # GroupCommitScheduler: durable ack release round
ST_RDECODE = 10    # recovery: per-(device, segment) columnar decode
ST_RREPLAY = 11    # recovery: last-writer-wins replay (or the fused pass)
ST_DRIVER = 12     # free-form driver work (benchmarks wrap workload gen)
ST_WRITEBACK = 13  # BatchOCC phase 2: table scatter under claimed locks

STAGE_NAMES = (
    "validate", "sequence", "encode", "publish", "flush", "xprepare",
    "ship", "apply", "cut", "ack", "rdecode", "rreplay", "driver",
    "writeback",
)

# stages that occupy a (GIL-serialized) CPU; ST_FLUSH occupies its device
CPU_STAGES = frozenset(
    (ST_VALIDATE, ST_SEQUENCE, ST_ENCODE, ST_PUBLISH, ST_XPREPARE,
     ST_SHIP, ST_APPLY, ST_CUT, ST_ACK, ST_RDECODE, ST_RREPLAY, ST_DRIVER,
     ST_WRITEBACK)
)

_COLUMNS = (
    ("stage", np.int16), ("shard", np.int32), ("device", np.int32),
    ("batch", np.int64), ("txn_lo", np.int64), ("txn_hi", np.int64),
    ("t0", np.float64), ("t1", np.float64),
    ("nbytes", np.int64), ("n_txn", np.int64), ("aux", np.int64),
)


class _Ctx(threading.local):
    """Ambient per-thread trace context: the executing batch id and shard,
    set by the batch executor so nested hooks (engine publish) can stamp
    their spans without threading ids through every call signature."""

    batch = -1
    shard = 0


@dataclass
class TraceDump:
    """An immutable snapshot of the tracer's rows, oldest first.

    Columns are plain numpy arrays aligned by row; ``dropped`` counts ring
    overwrites (rows lost to capacity).  ``structural_dict`` /
    ``canonical_bytes`` exclude the wall-clock columns, so two identical
    stepped runs serialize byte-identically (`tests/test_trace.py`).
    """

    stage: np.ndarray
    shard: np.ndarray
    device: np.ndarray
    batch: np.ndarray
    txn_lo: np.ndarray
    txn_hi: np.ndarray
    t0: np.ndarray
    t1: np.ndarray
    nbytes: np.ndarray
    n_txn: np.ndarray
    aux: np.ndarray
    dropped: int = 0

    @property
    def n(self) -> int:
        return len(self.stage)

    def duration(self) -> np.ndarray:
        return self.t1 - self.t0

    def makespan(self) -> float:
        """Wall time covered by the trace (first span start → last end)."""
        if not self.n:
            return 0.0
        return float(self.t1.max() - self.t0.min())

    def structural_dict(self) -> Dict:
        """Timestamp-free row dump (the deterministic part of a trace)."""
        return {
            "n": self.n,
            "dropped": self.dropped,
            "stage": self.stage.tolist(),
            "shard": self.shard.tolist(),
            "device": self.device.tolist(),
            "batch": self.batch.tolist(),
            "txn_lo": self.txn_lo.tolist(),
            "txn_hi": self.txn_hi.tolist(),
            "nbytes": self.nbytes.tolist(),
            "n_txn": self.n_txn.tolist(),
            "aux": self.aux.tolist(),
        }

    def to_dict(self) -> Dict:
        d = self.structural_dict()
        d["t0"] = self.t0.tolist()
        d["t1"] = self.t1.tolist()
        return d

    def save(self, path: str, extra: Optional[Dict] = None) -> None:
        """Write the dump as JSON; ``extra`` merges additional top-level
        keys (e.g. ``run_metadata()`` provenance stamps — ``from_dict``
        ignores keys it does not know, so stamped dumps stay loadable)."""
        d = self.to_dict()
        if extra:
            d.update(extra)
        with open(path, "w") as f:
            json.dump(d, f)
            f.write("\n")

    @classmethod
    def from_dict(cls, d: Dict) -> "TraceDump":
        n = d["n"]
        return cls(
            stage=np.asarray(d["stage"], np.int16),
            shard=np.asarray(d["shard"], np.int32),
            device=np.asarray(d["device"], np.int32),
            batch=np.asarray(d["batch"], np.int64),
            txn_lo=np.asarray(d["txn_lo"], np.int64),
            txn_hi=np.asarray(d["txn_hi"], np.int64),
            t0=np.asarray(d.get("t0", [0.0] * n), np.float64),
            t1=np.asarray(d.get("t1", [0.0] * n), np.float64),
            nbytes=np.asarray(d["nbytes"], np.int64),
            n_txn=np.asarray(d["n_txn"], np.int64),
            aux=np.asarray(d["aux"], np.int64),
            dropped=d.get("dropped", 0),
        )

    @classmethod
    def load(cls, path: str) -> "TraceDump":
        with open(path) as f:
            return cls.from_dict(json.load(f))


class Tracer:
    """Ring-buffer stage tracer.  One process-local instance (:data:`TRACER`)
    is shared by every hook; ``enabled`` is the single gate the hot paths
    test.  ``record`` is thread-safe (logger threads, shard threads and the
    scheduler loop all trace concurrently)."""

    def __init__(self, capacity: int = 1 << 16):
        self.enabled = False
        self._lock = threading.Lock()
        self.ctx = _Ctx()
        self._alloc(capacity)

    def _alloc(self, capacity: int) -> None:
        assert capacity > 0
        self.capacity = capacity
        for name, dt in _COLUMNS:
            setattr(self, f"_{name}", np.zeros(capacity, dt))
        self.n = 0
        self.dropped = 0
        self._batch_seq = 0

    def reset(self, capacity: Optional[int] = None) -> None:
        """Drop all recorded rows (and optionally resize the ring)."""
        with self._lock:
            self._alloc(capacity or self.capacity)

    def next_batch_id(self) -> int:
        """A process-unique batch id for one executor pass (monotone, reset
        with the tracer — stepped reruns see identical id sequences)."""
        with self._lock:
            self._batch_seq += 1
            return self._batch_seq

    def record(
        self,
        stage: int,
        shard: int = 0,
        device: int = -1,
        batch: int = -1,
        txn_lo: int = -1,
        txn_hi: int = -1,
        t0: float = 0.0,
        t1: float = 0.0,
        nbytes: int = 0,
        n_txn: int = 0,
        aux: int = 0,
    ) -> None:
        with self._lock:
            i = self.n % self.capacity
            if self.n >= self.capacity:
                self.dropped += 1
                # drops silently skew any cost model fit on the dump; keep
                # them visible in the online registry too (lazy import: the
                # obs package depends on trace, not vice versa)
                from ..obs.metrics import REGISTRY

                if REGISTRY.enabled:
                    REGISTRY.count("trace.ring_drops")
            self._stage[i] = stage
            self._shard[i] = shard
            self._device[i] = device
            self._batch[i] = batch
            self._txn_lo[i] = txn_lo
            self._txn_hi[i] = txn_hi
            self._t0[i] = t0
            self._t1[i] = t1
            self._nbytes[i] = nbytes
            self._n_txn[i] = n_txn
            self._aux[i] = aux
            self.n += 1

    def dump(self) -> TraceDump:
        """Snapshot the recorded rows oldest-first (ring order unwound).

        Warns when the ring wrapped: a dump with drops under-represents the
        oldest stages, so durations fit from it (``CostModel.fit``) are
        biased — re-trace with a larger ``enable(capacity=...)`` instead.
        """
        if self.dropped:
            warnings.warn(
                f"trace ring dropped {self.dropped} spans (capacity "
                f"{self.capacity}); the dump is a biased sample — re-trace "
                f"with a larger enable(capacity=...) before fitting",
                RuntimeWarning,
                stacklevel=2,
            )
        with self._lock:
            k = min(self.n, self.capacity)
            if self.n <= self.capacity:
                sel = slice(0, k)
                cols = {name: getattr(self, f"_{name}")[sel].copy()
                        for name, _ in _COLUMNS}
            else:
                head = self.n % self.capacity
                cols = {
                    name: np.concatenate(
                        [getattr(self, f"_{name}")[head:],
                         getattr(self, f"_{name}")[:head]]
                    )
                    for name, _ in _COLUMNS
                }
            return TraceDump(
                stage=cols["stage"], shard=cols["shard"],
                device=cols["device"], batch=cols["batch"],
                txn_lo=cols["txn_lo"], txn_hi=cols["txn_hi"],
                t0=cols["t0"], t1=cols["t1"], nbytes=cols["nbytes"],
                n_txn=cols["n_txn"], aux=cols["aux"], dropped=self.dropped,
            )


TRACER = Tracer()


def enable(capacity: int = 1 << 16) -> Tracer:
    """Arm the process tracer with a fresh ring of ``capacity`` rows."""
    TRACER.reset(capacity)
    TRACER.enabled = True
    return TRACER


def disable() -> TraceDump:
    """Disarm the tracer and return the final snapshot."""
    TRACER.enabled = False
    return TRACER.dump()
