"""Dependency-DAG construction over a stage trace, with critical-path
extraction and per-stage/per-resource time attribution.

Nodes are the trace's spans (plus zero-duration virtual *xcommit* join
nodes, one per cross-shard gtid).  Edges encode the pipeline's real
ordering constraints, derived **only** from structural columns — record
order, SSN spans, cumulative byte counts — never from timestamps, so the
DAG of two identical stepped runs is byte-identical
(:meth:`TraceDAG.canonical_bytes`) even though the wall clocks differ:

* **intra-batch chain** — validate → sequence → encode → publish within one
  batch id;
* **exec-lane chain** — CPU-stage spans of one shard are serialized in
  record order (one executor/driver thread per shard; the GIL makes this
  near-exact on the 1-core bench box);
* **device FIFO** — flush spans of one ``(shard, device)`` in record order
  (a device has one head);
* **durability (Qww) edges** — a publish span depends on nothing, but the
  first flush span whose DSN interval covers the publish's SSN range
  depends on it (the record must be buffered before it can flush);
* **ship edges** — a ship span depends on the earliest flush span whose
  cumulative durable bytes reach the ship's cumulative consumed bytes,
  plus ship-FIFO order per device;
* **apply edges** — an apply span depends on every ship span since the
  shard's previous apply, plus the previous apply (the applier folds
  chunks in poll order);
* **durable-on-all (``FLAG_XSHARD``) joins** — per gtid, a virtual xcommit
  node depends on each participant's xprepare span *and* the flush span
  covering that participant's record SSN: the cross-shard commit point;
* **commit (Qwr / CSN) edges** — an ack-release span depends on, for every
  device lane, the first flush whose DSN reaches the acked SSN (the
  CSN = min-DSN join the scheduler's ack rule evaluates).

Critical path: walking back from the last-finishing span, always to the
predecessor that finished latest, partitions the trace's wall window
exactly into per-stage busy time plus ``wait`` (idle/untraced) — the
attribution therefore always sums to the makespan, and the per-stage
shares explain *which* stage bounds throughput (`benchmarks/fig_trace.py`
uses this on the noisy cross-shard cells).
"""

from __future__ import annotations

import hashlib
import json
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .span import (
    CPU_STAGES,
    STAGE_NAMES,
    ST_ACK,
    ST_APPLY,
    ST_CUT,
    ST_DRIVER,
    ST_ENCODE,
    ST_FLUSH,
    ST_PUBLISH,
    ST_RDECODE,
    ST_RREPLAY,
    ST_SEQUENCE,
    ST_SHIP,
    ST_VALIDATE,
    ST_WRITEBACK,
    ST_XPREPARE,
    TraceDump,
)

# stage id of the virtual cross-shard commit join node
ST_XCOMMIT = -2

_PIPELINE = (ST_VALIDATE, ST_SEQUENCE, ST_ENCODE, ST_PUBLISH, ST_WRITEBACK)
_EXEC_LANE = frozenset(
    (ST_DRIVER, ST_VALIDATE, ST_SEQUENCE, ST_ENCODE, ST_PUBLISH,
     ST_XPREPARE, ST_CUT, ST_ACK, ST_RDECODE, ST_RREPLAY, ST_WRITEBACK)
)


def stage_name(s: int) -> str:
    return "xcommit" if s == ST_XCOMMIT else STAGE_NAMES[s]


@dataclass
class TraceDAG:
    """The dependency DAG over one trace dump.

    ``preds[i]`` lists the node indices ``i`` depends on.  Nodes
    ``[0, dump.n)`` are the trace rows; nodes past that are virtual
    xcommit joins whose structural identity lives in ``virtual`` as
    ``(gtid, sorted participant shard list)``.
    """

    dump: TraceDump
    preds: List[List[int]]
    virtual: List[Tuple[int, Tuple[int, ...]]] = field(default_factory=list)

    @property
    def n_nodes(self) -> int:
        return self.dump.n + len(self.virtual)

    def node_stage(self, i: int) -> int:
        return int(self.dump.stage[i]) if i < self.dump.n else ST_XCOMMIT

    def node_duration(self, i: int) -> float:
        if i >= self.dump.n:
            return 0.0
        return float(self.dump.t1[i] - self.dump.t0[i])

    def node_times(self) -> Tuple[np.ndarray, np.ndarray]:
        """(t0, t1) per node; virtual joins inherit max predecessor t1."""
        n = self.dump.n
        t0 = np.zeros(self.n_nodes)
        t1 = np.zeros(self.n_nodes)
        t0[:n] = self.dump.t0
        t1[:n] = self.dump.t1
        for v in range(n, self.n_nodes):
            hi = max((t1[p] for p in self.preds[v]), default=0.0)
            t0[v] = t1[v] = hi
        return t0, t1

    # --- determinism ---------------------------------------------------------
    def structural_dict(self) -> Dict:
        d = self.dump.structural_dict()
        d["edges"] = sorted(
            (p, i) for i, ps in enumerate(self.preds) for p in ps
        )
        d["virtual"] = [[g, list(parts)] for g, parts in self.virtual]
        return d

    def canonical_bytes(self) -> bytes:
        """Timestamp-free canonical serialization: two identical stepped
        runs produce byte-identical output (the determinism contract)."""
        return json.dumps(
            self.structural_dict(), sort_keys=True, separators=(",", ":")
        ).encode()

    def fingerprint(self) -> str:
        return hashlib.sha256(self.canonical_bytes()).hexdigest()

    # --- attribution ---------------------------------------------------------
    def stage_totals(self) -> Dict[str, float]:
        """Total busy seconds per stage (not path-restricted)."""
        out: Dict[str, float] = {}
        dur = self.dump.duration()
        for s in np.unique(self.dump.stage).tolist():
            out[stage_name(int(s))] = float(dur[self.dump.stage == s].sum())
        return out

    def resource_busy(self) -> Dict[str, float]:
        """Busy seconds per resource: one ``cpu`` pool (all CPU stages) and
        one ``dev<shard>.<device>`` per flush lane — the utilization view
        that says which side of the IO roof a run sits on."""
        d = self.dump
        dur = d.duration()
        cpu_mask = np.isin(d.stage, list(CPU_STAGES))
        out = {"cpu": float(dur[cpu_mask].sum())}
        fl = np.flatnonzero(d.stage == ST_FLUSH)
        for i in fl.tolist():
            key = f"dev{d.shard[i]}.{d.device[i]}"
            out[key] = out.get(key, 0.0) + float(dur[i])
        return out


def _chain(preds: List[List[int]], idxs: Sequence[int]) -> None:
    for a, b in zip(idxs, idxs[1:]):
        preds[b].append(a)


def build_dag(dump: TraceDump) -> TraceDAG:
    """Build the dependency DAG from a trace dump (see module docstring for
    the edge semantics)."""
    n = dump.n
    preds: List[List[int]] = [[] for _ in range(n)]
    st = dump.stage

    # intra-batch pipeline chains
    by_batch: Dict[int, List[int]] = {}
    for i in np.flatnonzero(np.isin(st, _PIPELINE)).tolist():
        b = int(dump.batch[i])
        if b >= 0:
            by_batch.setdefault(b, []).append(i)
    for idxs in by_batch.values():
        _chain(preds, idxs)

    # exec-lane serialization per shard (record order)
    lanes: Dict[int, List[int]] = {}
    for i in np.flatnonzero(np.isin(st, list(_EXEC_LANE))).tolist():
        lanes.setdefault(int(dump.shard[i]), []).append(i)
    for idxs in lanes.values():
        _chain(preds, idxs)

    # flush FIFO per (shard, device) + publish -> covering flush
    flush_lanes: Dict[Tuple[int, int], List[int]] = {}
    for i in np.flatnonzero(st == ST_FLUSH).tolist():
        flush_lanes.setdefault(
            (int(dump.shard[i]), int(dump.device[i])), []
        ).append(i)
    for idxs in flush_lanes.values():
        _chain(preds, idxs)

    for i in np.flatnonzero(
        (st == ST_PUBLISH) & (dump.device >= 0) & (dump.nbytes > 0)
    ).tolist():
        lane = flush_lanes.get((int(dump.shard[i]), int(dump.device[i])))
        if not lane:
            continue
        need = int(dump.txn_hi[i])
        for f in lane:
            if f > i and int(dump.txn_hi[f]) >= need:
                preds[f].append(i)
                break

    # flush -> ship (cumulative bytes) + ship FIFO
    ship_lanes: Dict[Tuple[int, int], List[int]] = {}
    for i in np.flatnonzero(st == ST_SHIP).tolist():
        ship_lanes.setdefault(
            (int(dump.shard[i]), int(dump.device[i])), []
        ).append(i)
    for key, idxs in ship_lanes.items():
        _chain(preds, idxs)
        flane = flush_lanes.get(key, [])
        fcum = np.cumsum([int(dump.nbytes[f]) for f in flane])
        scum = 0
        fj = 0
        for i in idxs:
            scum += int(dump.nbytes[i])
            while fj < len(flane) and fcum[fj] < scum:
                fj += 1
            if fj < len(flane):
                preds[i].append(flane[fj])

    # ship* -> apply (per shard, since the previous apply) + apply chain
    apply_by_shard: Dict[int, List[int]] = {}
    for i in np.flatnonzero(st == ST_APPLY).tolist():
        apply_by_shard.setdefault(int(dump.shard[i]), []).append(i)
    for shard, applies in apply_by_shard.items():
        _chain(preds, applies)
        ships = sorted(
            i for (sh, _), idxs in ship_lanes.items() if sh == shard
            for i in idxs
        )
        lo = 0
        for a in applies:
            for s in ships[lo:]:
                if s > a:
                    break
                preds[a].append(s)
                lo += 1

    # ack <- commit (CSN) joins: first flush on every lane reaching the SSN
    for i in np.flatnonzero((st == ST_ACK) & (dump.txn_hi >= 0)).tolist():
        need = int(dump.txn_hi[i])
        for lane in flush_lanes.values():
            for f in lane:
                if int(dump.txn_hi[f]) >= need:
                    if f != i:
                        preds[i].append(f)
                    break

    # durable-on-all joins: one virtual xcommit node per gtid
    virtual: List[Tuple[int, Tuple[int, ...]]] = []
    xprep: Dict[int, List[int]] = {}
    for i in np.flatnonzero(st == ST_XPREPARE).tolist():
        xprep.setdefault(int(dump.batch[i]), []).append(i)
    for gtid in sorted(xprep):
        members = xprep[gtid]
        vp: List[int] = list(members)
        for m in members:
            lane = flush_lanes.get((int(dump.shard[m]), int(dump.device[m])))
            if lane:
                need = int(dump.txn_hi[m])
                for f in lane:
                    if int(dump.txn_hi[f]) >= need:
                        vp.append(f)
                        break
        preds.append(sorted(set(vp)))
        virtual.append(
            (gtid, tuple(sorted(int(dump.shard[m]) for m in members)))
        )

    return TraceDAG(dump=dump, preds=preds, virtual=virtual)


def critical_path(
    dag: TraceDAG, end: Optional[int] = None
) -> Tuple[List[int], Dict[str, float]]:
    """Extract the critical path and its exact time attribution.

    Walks back from ``end`` (default: the last-finishing real span), at each
    node to the predecessor that finished latest.  The wall window
    ``[trace start, end]`` is partitioned exactly: every slice is attributed
    either to a stage on the path or to ``wait`` (idle / untraced time), so
    ``sum(attribution.values()) == t_end - trace_t0`` by construction.

    Returns ``(path node indices, {stage or 'wait': seconds})``.
    """
    d = dag.dump
    if d.n == 0:
        return [], {}
    t0, t1 = dag.node_times()
    if end is None:
        end = int(np.argmax(t1[: d.n]))
    t_min = float(d.t0.min())

    path: List[int] = []
    attr: Dict[str, float] = {}
    cursor = float(t1[end])
    v: Optional[int] = end
    seen = set()
    while v is not None and v not in seen:
        seen.add(v)
        path.append(v)
        seg_lo = float(t0[v])
        seg_hi = min(float(t1[v]), cursor)
        if seg_hi > seg_lo:
            key = stage_name(dag.node_stage(v))
            attr[key] = attr.get(key, 0.0) + (seg_hi - seg_lo)
        cursor = min(cursor, seg_lo)
        ps = dag.preds[v]
        if not ps:
            break
        p = max(ps, key=lambda q: (t1[q], q))
        gap = cursor - float(t1[p])
        if gap > 0:
            attr["wait"] = attr.get("wait", 0.0) + gap
            cursor = float(t1[p])
        v = p
    head = cursor - t_min
    if head > 0:
        attr["wait"] = attr.get("wait", 0.0) + head
    path.reverse()
    return path, attr
