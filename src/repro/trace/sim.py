"""Discrete-event replay simulation over the trace DAG.

Two entry points:

* :func:`simulate_dag` replays a *recorded* DAG — every span keeps its
  measured duration, but execution order is re-derived by a greedy list
  scheduler over explicit resources (a CPU pool for the GIL-serialized
  stages, one server per ``(shard, device)`` flush lane).  On the same
  config it reproduces the measured makespan (the fidelity contract in
  ``tests/test_trace_sim.py``); with a different resource multiplicity or
  scaled durations it answers "what if".

* :func:`simulate` builds a *synthetic* DAG for a hypothetical
  :class:`SimConfig` — shards × devices × batch size × device bandwidth ×
  cross-shard ratio — using per-stage costs from a :class:`CostModel`
  fitted on real traces, and predicts txn/s plus p50/p99 commit latency
  without running the engine.  This is what `repro.trace.tune.autotune`
  sweeps and what ``benchmarks/fig_trace.py`` gates against measurement.

Known non-modeled effects (documented, not bugs): GIL hand-off churn
between logger/shard threads, allocator noise, and lock convoy on the
table mutex — the model treats CPU stages as one FIFO pool, which is why
predictions are gated at 25% drift rather than treated as exact.
"""

from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .dag import TraceDAG, stage_name
from .span import (
    CPU_STAGES,
    ST_DRIVER,
    ST_ENCODE,
    ST_FLUSH,
    ST_PUBLISH,
    ST_SEQUENCE,
    ST_VALIDATE,
    ST_WRITEBACK,
    ST_XPREPARE,
    TraceDump,
)

_MIN_COST = 1e-9


# --- cost model --------------------------------------------------------------
@dataclass
class CostModel:
    """Per-stage linear time models fitted from a trace.

    Stage cost is ``t = a + b * n_txn + c * nbytes`` (all coefficients
    clamped non-negative, intercept re-centred so the mean is preserved);
    the flush stage instead fits the device model ``t = lat + nbytes / bw``
    so simulated configs can swap the bandwidth term out.
    """

    coef: Dict[int, Tuple[float, float, float]] = field(default_factory=dict)
    dev_lat: float = 0.0
    dev_bw: float = 1.2e9
    # untraced per-txn residual (GIL churn, allocator, routing) measured as
    # the gap between a traced run's wall clock and its own replay — see
    # `calibrate_pad`; charged on the driver lane by `simulate`
    pad_per_txn: float = 0.0

    @classmethod
    def fit(cls, dump: TraceDump) -> "CostModel":
        m = cls()
        dur = dump.duration()
        for s in np.unique(dump.stage).tolist():
            s = int(s)
            sel = dump.stage == s
            y = dur[sel]
            n = dump.n_txn[sel].astype(np.float64)
            b = dump.nbytes[sel].astype(np.float64)
            if s == ST_FLUSH:
                lat, inv_bw = _fit_nonneg(np.c_[np.ones_like(b), b], y)
                m.dev_lat = lat
                if inv_bw > 0:
                    m.dev_bw = 1.0 / inv_bw
                continue
            a, bn, cb = _fit_nonneg(np.c_[np.ones_like(n), n, b], y)
            m.coef[s] = (a, bn, cb)
        return m

    def stage_cost(self, stage: int, n_txn: int, nbytes: int) -> float:
        a, bn, cb = self.coef.get(stage, (0.0, 0.0, 0.0))
        return max(_MIN_COST, a + bn * n_txn + cb * nbytes)

    def flush_cost(self, nbytes: int, bw: Optional[float] = None) -> float:
        return max(
            _MIN_COST, self.dev_lat + nbytes / max(bw or self.dev_bw, 1.0)
        )

    def calibrate_pad(
        self,
        measured_txn_s: float,
        cfg: "SimConfig",
        profile: Optional["WorkloadProfile"] = None,
    ) -> float:
        """Fit ``pad_per_txn`` so the simulated per-txn time on the
        calibration config matches the measured one.  The residual is real
        work the hooks don't cover (spec routing, numpy temporaries, GIL
        hand-offs); folding it in per-txn keeps every *other* config an
        honest extrapolation while zeroing out a systematic bias."""
        self.pad_per_txn = 0.0
        if measured_txn_s <= 0:
            return 0.0
        # fixed-point: each step adds the remaining per-txn shortfall; on
        # an IO-bound config extra driver time only partly extends the
        # makespan, so the closed-form one-shot would overshoot downstream
        # — the iteration under-corrects monotonically instead
        for _ in range(12):
            pred = simulate(self, cfg, profile)
            if pred.txn_s <= 0:
                break
            err = 1.0 / measured_txn_s - 1.0 / pred.txn_s
            if err <= 0 and self.pad_per_txn == 0.0:
                break                       # already at/below measurement
            self.pad_per_txn = max(0.0, self.pad_per_txn + err)
            if abs(err) * measured_txn_s < 0.01:
                break
        return self.pad_per_txn

    def merge_stage(self, other: "CostModel", stage: int) -> None:
        """Copy one stage's fitted coefficients from another model (e.g.
        graft the cross-shard prepare cost, which only a sharded trace can
        observe, onto a single-shard calibration fit)."""
        if stage in other.coef:
            self.coef[stage] = other.coef[stage]


def _fit_nonneg(X: np.ndarray, y: np.ndarray) -> Tuple[float, ...]:
    """Least-squares fit with coefficients clamped non-negative and the
    intercept re-centred to preserve the sample mean (robust against the
    tiny, collinear samples short traces produce)."""
    k = X.shape[1]
    if len(y) == 0:
        return tuple([0.0] * k)
    if len(y) < k:
        return (float(np.mean(y)),) + tuple([0.0] * (k - 1))
    beta, *_ = np.linalg.lstsq(X, y, rcond=None)
    beta = np.maximum(beta, 0.0)
    slope_mean = float(X[:, 1:].mean(axis=0) @ beta[1:]) if k > 1 else 0.0
    beta[0] = max(0.0, float(np.mean(y)) - slope_mean)
    return tuple(float(v) for v in beta)


# --- workload profile --------------------------------------------------------
@dataclass
class WorkloadProfile:
    """Workload shape extracted from a trace: what one batch looks like."""

    bytes_per_txn: float = 64.0
    txn_per_batch: float = 256.0
    reads_fraction: float = 0.0

    @classmethod
    def from_dump(cls, dump: TraceDump) -> "WorkloadProfile":
        pub = dump.stage == ST_PUBLISH
        n = float(dump.n_txn[pub].sum())
        b = float(dump.nbytes[pub].sum())
        val = dump.stage == ST_VALIDATE
        counts = dump.n_txn[val]
        return cls(
            bytes_per_txn=(b / n) if n else 64.0,
            txn_per_batch=float(np.median(counts)) if counts.size else 256.0,
            reads_fraction=0.0,
        )


# --- configs / results -------------------------------------------------------
@dataclass
class SimConfig:
    """The hypothetical deployment a simulation answers for."""

    shards: int = 1
    devices: int = 1
    batch_size: int = 256
    n_txn: int = 20_000
    device_bw: Optional[float] = None   # bytes/s; None = fitted value
    cross_ratio: float = 0.0            # fraction of txns cross-shard
    n_cpu: int = 1                      # GIL => 1 on the bench box
    io_unit: int = 1 << 18              # bytes accumulated per flush span


@dataclass
class SimResult:
    makespan: float
    txn_s: float
    p50_commit: float
    p99_commit: float
    stage_busy: Dict[str, float] = field(default_factory=dict)

    def to_dict(self) -> Dict:
        return {
            "makespan_s": self.makespan,
            "txn_s": self.txn_s,
            "p50_commit_s": self.p50_commit,
            "p99_commit_s": self.p99_commit,
            "stage_busy": self.stage_busy,
        }


# --- discrete-event core -----------------------------------------------------
def _list_schedule(
    preds: Sequence[Sequence[int]],
    dur: Sequence[float],
    resource: Sequence[Optional[str]],
    servers: Dict[str, int],
) -> np.ndarray:
    """Greedy list scheduler: nodes start when all predecessors finished
    AND a server of their resource frees up (FIFO by ready time).  A
    ``None`` resource means no contention (virtual joins).  Returns the
    finish time per node."""
    n = len(preds)
    succs: List[List[int]] = [[] for _ in range(n)]
    indeg = [0] * n
    for i, ps in enumerate(preds):
        indeg[i] = len(ps)
        for p in ps:
            succs[p].append(i)
    ready = [0.0] * n
    finish = np.zeros(n)
    pools: Dict[str, List[float]] = {
        k: [0.0] * max(1, c) for k, c in servers.items()
    }
    heap = [(0.0, i) for i in range(n) if indeg[i] == 0]
    heapq.heapify(heap)
    done = 0
    while heap:
        t, i = heapq.heappop(heap)
        r = resource[i]
        if r is None:
            start = t
        else:
            pool = pools.setdefault(r, [0.0])
            j = int(np.argmin(pool))
            start = max(t, pool[j])
            pool[j] = start + dur[i]
        finish[i] = start + dur[i]
        done += 1
        for s in succs[i]:
            if ready[s] < finish[i]:
                ready[s] = finish[i]
            indeg[s] -= 1
            if indeg[s] == 0:
                heapq.heappush(heap, (ready[s], s))
    if done != n:
        raise ValueError(f"trace DAG has a cycle ({n - done} nodes unreached)")
    return finish


def simulate_dag(
    dag: TraceDAG,
    n_cpu: int = 1,
    duration_scale: Optional[Dict[int, float]] = None,
) -> SimResult:
    """Replay a recorded DAG's spans on explicit resources.

    ``duration_scale`` maps stage id → multiplier (e.g. ``{ST_FLUSH: 2.0}``
    asks "what if the device were half as fast").
    """
    d = dag.dump
    nn = dag.n_nodes
    dur = [0.0] * nn
    resource: List[Optional[str]] = [None] * nn
    for i in range(d.n):
        s = int(d.stage[i])
        t = float(d.t1[i] - d.t0[i])
        if duration_scale and s in duration_scale:
            t *= duration_scale[s]
        dur[i] = max(t, 0.0)
        if s == ST_FLUSH:
            resource[i] = f"dev{d.shard[i]}.{d.device[i]}"
        elif s in CPU_STAGES:
            resource[i] = "cpu"
    servers = {"cpu": n_cpu}
    finish = _list_schedule(dag.preds, dur, resource, servers)
    makespan = float(finish.max()) if nn else 0.0
    n_txn = int(d.n_txn[d.stage == ST_PUBLISH].sum())
    busy: Dict[str, float] = {}
    for i in range(d.n):
        k = stage_name(int(d.stage[i]))
        busy[k] = busy.get(k, 0.0) + dur[i]
    # commit latency proxy: publish finish -> covering flush finish
    lat = _dag_commit_latencies(dag, finish, dur)
    return SimResult(
        makespan=makespan,
        txn_s=(n_txn / makespan) if makespan > 0 else 0.0,
        p50_commit=float(np.percentile(lat, 50)) if lat else 0.0,
        p99_commit=float(np.percentile(lat, 99)) if lat else 0.0,
        stage_busy=busy,
    )


def _dag_commit_latencies(
    dag: TraceDAG, finish: np.ndarray, dur: Sequence[float]
) -> List[float]:
    """Per-publish commit latency: publish start -> finish of the flush
    that made its SSN range durable (the Qww rule, per device lane)."""
    d = dag.dump
    pub = np.flatnonzero(
        (d.stage == ST_PUBLISH) & (d.device >= 0) & (d.nbytes > 0)
    )
    # flush successors were wired by build_dag: find them via preds
    cover: Dict[int, float] = {}
    for f in np.flatnonzero(d.stage == ST_FLUSH).tolist():
        for p in dag.preds[f]:
            if p not in cover or finish[f] < cover[p]:
                cover[p] = float(finish[f])
    out = []
    for i in pub.tolist():
        if i in cover:
            start = float(finish[i]) - float(dur[i])
            out.append(max(0.0, cover[i] - start))
    return out


# --- synthetic what-if simulation -------------------------------------------
def simulate(
    model: CostModel,
    cfg: SimConfig,
    profile: Optional[WorkloadProfile] = None,
) -> SimResult:
    """Predict throughput and commit latency for ``cfg`` by generating a
    synthetic batch pipeline DAG and list-scheduling it with fitted costs.

    The generator mirrors ``ShardedEngine.execute_batch``: the driver
    thread submits global batches of ``batch_size``; the router splits
    each into per-shard sub-batches (validate → sequence → encode/publish,
    bytes striped over ``devices``) run *serially* on the driver lane,
    then a ``cross_ratio`` fraction of the batch's transactions pays the
    per-txn coordinator prepare (one xprepare cost each, serialized —
    this, not bandwidth, is why cross-shard cells crater).  Each device
    lane accumulates bytes and emits a flush span per ``io_unit``; commit
    latency of a publish is publish start → covering flush finish.
    """
    profile = profile or WorkloadProfile()
    bpt = profile.bytes_per_txn
    batch = max(1, int(cfg.batch_size))
    n_batches = max(1, -(-cfg.n_txn // batch))
    bw = cfg.device_bw or model.dev_bw
    n_cross = int(round(batch * cfg.cross_ratio)) if cfg.shards > 1 else 0
    n_single = batch - n_cross
    share = n_single // max(1, cfg.shards)

    preds: List[List[int]] = []
    dur: List[float] = []
    resource: List[Optional[str]] = []
    stage_of: List[int] = []

    def add(stage: int, res: Optional[str], t: float,
            ps: Sequence[int]) -> int:
        preds.append(list(ps))
        dur.append(t)
        resource.append(res)
        stage_of.append(stage)
        return len(dur) - 1

    # per-(shard, device) pending bytes and the publishes awaiting a flush
    pend_bytes = {(s, v): 0 for s in range(cfg.shards)
                  for v in range(cfg.devices)}
    pend_pubs: Dict[Tuple[int, int], List[int]] = {
        k: [] for k in pend_bytes
    }
    last_flush: Dict[Tuple[int, int], int] = {}
    covering: Dict[int, int] = {}       # publish node -> flush node

    def emit_flush(key: Tuple[int, int]) -> None:
        nb = pend_bytes[key]
        if nb <= 0:
            return
        ps = list(pend_pubs[key])
        if key in last_flush:
            ps.append(last_flush[key])
        f = add(ST_FLUSH, f"dev{key[0]}.{key[1]}",
                model.flush_cost(nb, bw), ps)
        for p in pend_pubs[key]:
            covering[p] = f
        last_flush[key] = f
        pend_bytes[key] = 0
        pend_pubs[key] = []

    chain: List[int] = []               # the driver thread's serial lane
    for bi in range(n_batches):
        # leading driver half (workload gen) + the untraced per-txn residual
        lead = batch * model.pad_per_txn
        if ST_DRIVER in model.coef:
            lead += model.stage_cost(ST_DRIVER, batch, 0)
        if lead > 0:
            chain = [add(ST_DRIVER, "cpu", lead, chain)]
        for s in range(cfg.shards):
            if share <= 0:
                break
            nb_total = int(share * bpt)
            v = add(ST_VALIDATE, "cpu",
                    model.stage_cost(ST_VALIDATE, share, nb_total), chain)
            q = add(ST_SEQUENCE, "cpu",
                    model.stage_cost(ST_SEQUENCE, share, nb_total), [v])
            tail = q
            d_share = max(1, share // cfg.devices)
            nb_share = max(1, nb_total // cfg.devices)
            for dvi in range(cfg.devices):
                e = add(ST_ENCODE, "cpu",
                        model.stage_cost(ST_ENCODE, d_share, nb_share),
                        [tail])
                p = add(ST_PUBLISH, "cpu",
                        model.stage_cost(ST_PUBLISH, d_share, nb_share), [e])
                tail = p
                key = (s, dvi)
                pend_bytes[key] += nb_share
                pend_pubs[key].append(p)
                if pend_bytes[key] >= cfg.io_unit:
                    emit_flush(key)
            if ST_WRITEBACK in model.coef:
                tail = add(ST_WRITEBACK, "cpu",
                           model.stage_cost(ST_WRITEBACK, share, 0), [tail])
            chain = [tail]
        if n_cross:
            # the coordinator prepares each cross txn one at a time on the
            # driver thread: n_cross serialized per-txn costs, records
            # split across both participants' device lanes
            xp = add(ST_XPREPARE, "cpu",
                     n_cross * model.stage_cost(ST_XPREPARE, 1, int(bpt)),
                     chain)
            xb = int(n_cross * bpt) // cfg.shards
            for s in range(cfg.shards):
                key = (s, bi % cfg.devices)
                pend_bytes[key] += xb
                pend_pubs[key].append(xp)
                if pend_bytes[key] >= cfg.io_unit:
                    emit_flush(key)
            chain = [xp]
        if ST_DRIVER in model.coef:
            # trailing driver half: drain + ack sweep after the batch
            chain = [add(ST_DRIVER, "cpu",
                         model.stage_cost(ST_DRIVER, 0, 0), chain)]
    for key in pend_bytes:
        emit_flush(key)

    finish = _list_schedule(preds, dur, resource, {"cpu": cfg.n_cpu})
    makespan = float(finish.max()) if len(dur) else 0.0

    # commit latency: publish finish -> covering flush finish
    lats: List[float] = []
    for p, f in covering.items():
        if stage_of[p] == ST_PUBLISH:
            lats.append(max(0.0, float(finish[f] - finish[p])) + dur[p])
    busy: Dict[str, float] = {}
    for i, s in enumerate(stage_of):
        k = stage_name(s)
        busy[k] = busy.get(k, 0.0) + dur[i]
    n_done = n_batches * batch
    return SimResult(
        makespan=makespan,
        txn_s=(n_done / makespan) if makespan > 0 else 0.0,
        p50_commit=float(np.percentile(lats, 50)) if lats else 0.0,
        p99_commit=float(np.percentile(lats, 99)) if lats else 0.0,
        stage_busy=busy,
    )
