"""Stage-level tracing, dependency-DAG cost model, and what-if simulator.

The observability subsystem behind ROADMAP item 4: a near-zero-overhead
structured tracer with hook points in all seven pipeline stages
(`repro.trace.span`), a dependency-DAG builder with critical-path
extraction (`repro.trace.dag`), a discrete-event replay simulator that
predicts txn/s and commit latency for a hypothetical configuration without
running the engine (`repro.trace.sim`), and an autotuner sweeping the
simulator to pick batch size and device count per workload
(`repro.trace.tune`).
"""

from .span import (  # noqa: F401
    CPU_STAGES,
    STAGE_NAMES,
    ST_ACK,
    ST_APPLY,
    ST_CUT,
    ST_DRIVER,
    ST_ENCODE,
    ST_FLUSH,
    ST_PUBLISH,
    ST_RDECODE,
    ST_RREPLAY,
    ST_SEQUENCE,
    ST_SHIP,
    ST_VALIDATE,
    ST_WRITEBACK,
    ST_XPREPARE,
    TRACER,
    TraceDump,
    Tracer,
    disable,
    enable,
)
from .dag import TraceDAG, build_dag, critical_path  # noqa: F401
from .sim import (  # noqa: F401
    CostModel,
    SimConfig,
    SimResult,
    WorkloadProfile,
    simulate,
    simulate_dag,
)
from .tune import TuneResult, autotune  # noqa: F401
