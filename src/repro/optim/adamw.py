"""Sharded AdamW with global-norm clipping and cosine schedule.

Moments live in a pytree mirroring the params (same logical axes => same
sharding).  Moment dtype is configurable: fp32 default; bf16 for the
largest archs (grok-1) where fp32 moments alone would exceed per-device
HBM on the single-pod mesh (see DESIGN §5).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models.common import ParamSpec


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    moment_dtype: Any = jnp.float32


def opt_state_specs(param_specs, cfg: AdamWConfig):
    """ParamSpec tree for the optimizer state (dry-run lowering)."""
    def _m(s: ParamSpec) -> ParamSpec:
        return ParamSpec(s.shape, s.logical, cfg.moment_dtype, "zeros")

    is_spec = lambda x: isinstance(x, ParamSpec)
    return {
        "mu": jax.tree.map(_m, param_specs, is_leaf=is_spec),
        "nu": jax.tree.map(_m, param_specs, is_leaf=is_spec),
        "count": ParamSpec((), (), jnp.int32, "zeros"),
    }


def init(params, cfg: AdamWConfig):
    z = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return {
        "mu": jax.tree.map(z, params),
        "nu": jax.tree.map(z, params),
        "count": jnp.zeros((), jnp.int32),
    }


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, step / max(1, cfg.warmup_steps))
    prog = jnp.clip(
        (step - cfg.warmup_steps) / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0
    )
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def update(grads, state, params, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    count = state["count"] + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gn, 1e-9))
    lr = schedule(cfg, count)
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def _upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32, v32 = m.astype(jnp.float32), v.astype(jnp.float32)
        m_new = cfg.b1 * m32 + (1.0 - cfg.b1) * g
        v_new = cfg.b2 * v32 + (1.0 - cfg.b2) * g * g
        mhat = m_new / b1c
        vhat = v_new / b2c
        step = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * step
        return (
            p_new.astype(p.dtype),
            m_new.astype(cfg.moment_dtype),
            v_new.astype(cfg.moment_dtype),
        )

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["mu"])
    flat_v = treedef.flatten_up_to(state["nu"])
    out = [_upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_state = {
        "mu": jax.tree.unflatten(treedef, [o[1] for o in out]),
        "nu": jax.tree.unflatten(treedef, [o[2] for o in out]),
        "count": count,
    }
    return new_params, new_state, {"grad_norm": gn, "lr": lr}
