"""Parallel journal restore + elastic resharding (§5 adapted).

Restore pipeline:
  1. decode every lane's log concurrently (framed records, torn tails cut);
  2. ``RSNe = min over lanes of last durable SSN`` — the crash-time CSN;
  3. restorable steps = markers with ``ssn <= RSNe`` (a marker is a Qwr
     transaction: committed only if its whole read set was durable);
  4. pick the newest restorable step; gather its shard records (write-only
     records are valid regardless of RSNe — exactly the paper's ww rule);
  5. reassemble slices per path (slice count at save time need not match the
     restore-side topology — elastic resharding: the records are logical-
     slice addressed, never device addressed).

Lane count at restore is discovered from the directory, so you can restore
a 4-lane journal on a host configured with 2 lanes (or vice versa).

The default path decodes lanes columnar (:class:`~repro.core.txn.ColumnarLog`
— the same decode the vectorized crash recovery uses) and resolves the
per-slice last-writer-wins with sorted numpy reductions.  Besides skipping
per-record Python objects, this selects the winning slice *before* decoding
any array payload, so superseded shard versions are never deserialized —
the scalar scan (``columnar=False``, kept as the oracle) decodes every
shard record it visits.
"""

from __future__ import annotations

import json
import os
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from ..core.par import parallel_for
from ..core.recovery import compute_rsne
from ..core.txn import ColumnarLog, LogRecord, decode_columnar, decode_records
from . import records


def _lane_files(directory: str) -> List[str]:
    if not os.path.isdir(directory):
        return []
    return sorted(
        os.path.join(directory, f)
        for f in os.listdir(directory)
        if f.startswith("log_") and f.endswith(".bin")
    )


def _load_files(files: List[str], decode, parallel: bool) -> List:
    """Decode every lane file concurrently with ``decode(bytes)``."""
    out: List = [None] * len(files)

    def _load(i: int) -> None:
        with open(files[i], "rb") as f:
            out[i] = decode(f.read())

    parallel_for(len(files), _load, parallel)
    return out


class JournalTails:
    """Incremental lane cache carried across :func:`restore_latest` calls.

    Without it, every restore probe re-reads and re-decodes each full lane
    file — O(n²) read+decode bytes over a training run that probes the
    journal repeatedly (or a test that restores after every step).  With a
    ``JournalTails`` instance passed back in on each call, each lane keeps a
    :class:`~repro.replica.shipper.LogShipper` (the replication tailer over
    a plain :class:`~repro.replica.shipper.FileSource`): a probe reads only
    the new bytes past the consumed offset and decodes only the new
    complete frames (torn tails retried, not decoded).  New chunks are
    spliced onto the accumulated columnar log with
    :meth:`ColumnarLog.concat` — an array copy of the accumulated columns,
    paid only on probes that actually saw new bytes (a no-news probe
    returns the cached log untouched); the per-record decode work is what
    stays strictly incremental.
    """

    def __init__(self):
        self._shippers: Dict[str, "object"] = {}
        self._logs: Dict[str, ColumnarLog] = {}
        self._locks: Dict[str, threading.Lock] = {}
        self._lock = threading.Lock()

    def lane(self, path: str) -> ColumnarLog:
        """Refresh one lane and return its accumulated columnar log.

        Thread-safe per lane: the poll and the splice run under a per-path
        lock (a shipper's consumed offset must advance exactly once per new
        byte range), while distinct lanes still refresh concurrently — the
        parallel restore fan-out touches one path per thread.
        """
        from ..replica.shipper import FileSource as _FS, LogShipper

        with self._lock:
            sh = self._shippers.get(path)
            if sh is None:
                sh = self._shippers[path] = LogShipper(_FS(path))
                self._locks[path] = threading.Lock()
            lane_lock = self._locks[path]
        with lane_lock:
            new = sh.poll()
            if new is not None:
                cur = self._logs.get(path)
                self._logs[path] = (
                    new if cur is None else ColumnarLog.concat([cur, new])
                )
            return self._logs.get(path) or decode_columnar(b"")

    def min_frontier(self) -> int:
        """Min over lanes of the tailed SSN frontier — this tailer's
        consumed-through point for a
        :class:`~repro.core.truncate.FrontierRegistry` (a registered journal
        tailer keeps the truncator from dropping lane records it has not
        decoded yet; an *unregistered* one that falls behind re-probes from
        scratch, which the lifecycle docs call out as the slow path)."""
        with self._lock:
            shippers = list(self._shippers.values())
        if not shippers:
            return 0
        return min(sh.frontier for sh in shippers)


def load_lanes(directory: str, parallel: bool = True) -> List[List[LogRecord]]:
    return _load_files(_lane_files(directory), decode_records, parallel)


def load_lanes_columnar(
    directory: str, parallel: bool = True, tails: Optional[JournalTails] = None
) -> List[ColumnarLog]:
    """Columnar twin of :func:`load_lanes` (same decode as crash recovery).

    ``tails`` (a :class:`JournalTails` the caller carries across calls)
    switches to incremental reads: only bytes appended since the previous
    call are read and decoded.
    """
    files = _lane_files(directory)
    if tails is None:
        return _load_files(files, decode_columnar, parallel)
    out: List[ColumnarLog] = [None] * len(files)  # type: ignore[list-item]

    def _load(i: int) -> None:
        out[i] = tails.lane(files[i])

    parallel_for(len(files), _load, parallel)
    return out


def _restore_latest_columnar(
    directory: str, parallel: bool, tails: Optional[JournalTails] = None
) -> Optional[Tuple[int, Dict[str, np.ndarray], dict]]:
    lanes = load_lanes_columnar(directory, parallel=parallel, tails=tails)
    if not lanes:
        return None
    rsne = compute_rsne(lanes)

    # flatten lane-major (== the scalar scan order, so SSN ties resolve the
    # same way: first-seen wins under the strict > guard)
    keys: List[str] = []
    vals: List[bytes] = []
    ssn_parts: List[np.ndarray] = []
    for lane in lanes:
        keys.extend(k.decode() for k in lane.keys)
        vals.extend(lane.values)
        ssn_parts.append(lane.wr_ssn)
    n = len(keys)
    if n == 0:
        return None
    ssn = np.concatenate(ssn_parts)

    # parse every key once into parallel columns
    is_marker = np.zeros(n, bool)
    valid = np.zeros(n, bool)
    steps = np.zeros(n, np.int64)
    slices = np.zeros(n, np.int64)
    nslices = np.zeros(n, np.int64)
    path_ids = np.zeros(n, np.int64)
    path_of_id: List[str] = []
    pid_lookup: Dict[str, int] = {}
    for i, k in enumerate(keys):
        if not k:
            continue
        info = records.parse_key(k)
        valid[i] = True
        steps[i] = info["step"]
        if info["kind"] == "marker":
            is_marker[i] = True
        else:
            slices[i] = info["slice"]
            nslices[i] = info["n_slices"]
            pid = pid_lookup.setdefault(info["path"], len(path_of_id))
            if pid == len(path_of_id):
                path_of_id.append(info["path"])
            path_ids[i] = pid

    # markers carry RAW deps: only durable-committable ones count
    mmask = valid & is_marker & (ssn <= rsne)
    if not mmask.any():
        return None
    step = int(steps[mmask].max())
    cand = np.flatnonzero(mmask & (steps == step))
    w = int(cand[np.argmax(ssn[cand])])      # max SSN, ties -> first seen
    meta = json.loads(vals[w].decode()) if vals[w] else {}

    # shard writes are write-only txns (durable => committed): per
    # (path, slice) segment keep the max-SSN version, ties -> first seen
    sub = np.flatnonzero(valid & ~is_marker & (steps == step))
    state: Dict[str, np.ndarray] = {}
    if sub.size:
        order = sub[np.lexsort((-sub, ssn[sub], slices[sub], path_ids[sub]))]
        pid_s = path_ids[order]
        sl_s = slices[order]
        boundary = np.empty(order.size, dtype=bool)
        boundary[:-1] = (pid_s[1:] != pid_s[:-1]) | (sl_s[1:] != sl_s[:-1])
        boundary[-1] = True
        winners = order[boundary]            # (pid, slice)-sorted
        for pid in np.unique(path_ids[winners]):
            ws = winners[path_ids[winners] == pid]
            path = path_of_id[int(pid)]
            n_slices = int(nslices[ws[0]])
            if ws.size != n_slices:
                raise RuntimeError(
                    f"step {step} marker committed but shard {path} has "
                    f"{ws.size}/{n_slices} slices — journal corruption"
                )
            # only the winning slices are ever deserialized
            parts = [records.decode_array(vals[int(i)]) for i in ws]
            state[path] = records.join_slices(parts)
    return step, state, meta


def restore_latest(
    directory: str, parallel: bool = True, columnar: bool = True,
    tails: Optional[JournalTails] = None,
) -> Optional[Tuple[int, Dict[str, np.ndarray], dict]]:
    """Returns (step, {path: array}, metadata) or None if nothing restorable.

    ``columnar=True`` (default) uses the vectorized lane decode + sorted
    last-writer-wins; ``columnar=False`` runs the original per-record scan
    (correctness oracle — both produce identical results).  ``tails`` (a
    :class:`JournalTails` carried across calls, columnar only) makes
    repeated restores incremental: each call reads and decodes only the
    bytes appended since the last one.
    """
    if columnar:
        return _restore_latest_columnar(directory, parallel, tails=tails)
    lanes = load_lanes(directory, parallel=parallel)
    if not lanes:
        return None
    rsne = compute_rsne(lanes)

    markers: Dict[int, Tuple[int, dict]] = {}        # step -> (ssn, meta)
    shards: Dict[Tuple[int, str], Dict[int, Tuple[int, np.ndarray, int]]] = {}

    def _scan(recs: List[LogRecord]) -> None:
        for rec in recs:
            for key, val in rec.writes:
                if not key:
                    continue
                info = records.parse_key(key.decode())
                if info["kind"] == "marker":
                    # markers carry RAW deps: only durable-committable ones count
                    if rec.ssn <= rsne:
                        meta = json.loads(val.decode()) if val else {}
                        cur = markers.get(info["step"])
                        if cur is None or rec.ssn > cur[0]:
                            markers[info["step"]] = (rec.ssn, meta)
                else:
                    # shard writes are write-only txns: durable => committed
                    k = (info["step"], info["path"])
                    slot = shards.setdefault(k, {})
                    cur = slot.get(info["slice"])
                    if cur is None or rec.ssn > cur[0]:
                        slot[info["slice"]] = (rec.ssn, records.decode_array(val), info["n_slices"])

    lock = threading.Lock()
    if parallel and len(lanes) > 1:
        def _worker(recs):
            # array decoding dominates; the merge itself is cheap under GIL
            with lock:
                _scan(recs)

        ts = [threading.Thread(target=_worker, args=(recs,)) for recs in lanes]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
    else:
        for recs in lanes:
            _scan(recs)

    if not markers:
        return None
    step = max(markers)
    ssn, meta = markers[step]

    state: Dict[str, np.ndarray] = {}
    for (s, path), slot in shards.items():
        if s != step:
            continue
        n_slices = next(iter(slot.values()))[2]
        if len(slot) != n_slices:
            raise RuntimeError(
                f"step {step} marker committed but shard {path} has "
                f"{len(slot)}/{n_slices} slices — journal corruption"
            )
        parts = [slot[i][1] for i in range(n_slices)]
        state[path] = records.join_slices(parts)
    return step, state, meta


def to_pytree(state: Dict[str, np.ndarray], like) -> Any:
    """Map restored {path: array} back onto a pytree of the same structure
    (the restore-side mesh/topology may differ — elastic resharding happens
    when the caller device_puts these with its own shardings)."""
    flat = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path, leaf in flat[0]:
        key = jax.tree_util.keystr(path)
        if key not in state:
            raise KeyError(f"restored journal is missing {key}")
        arr = state[key]
        want = tuple(leaf.shape) if hasattr(leaf, "shape") else None
        if want is not None and tuple(arr.shape) != want:
            raise ValueError(f"{key}: journal shape {arr.shape} != expected {want}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(flat[1], leaves)
