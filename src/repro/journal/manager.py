"""PoplarCheckpointManager — barrier-free training-state durability.

Architecture (one process; on a pod, one manager per host with its local
lanes — the SSN/CSN algebra is identical since SSNs are decentralized):

  * n **lanes** = Poplar log buffers + logger threads + append-only files
    (one per storage target);
  * ``save(step, state)`` shards the state pytree, round-robins write-only
    shard transactions across lanes (Qww — commit on own-lane durability),
    then logs a step **marker** transaction whose read set covers every
    shard of the step (Qwr — commits at ``ssn <= CSN``);
  * saves run on a background thread (training never blocks on IO);
    ``last_committed_step()`` answers "what would survive a crash right
    now" and is exact, not heuristic;
  * a dead/slow lane freezes the CSN (markers stop committing — correct),
    while other lanes keep absorbing shard writes: the paper's straggler
    behaviour, for checkpoints.

Restore: `repro.journal.restore.restore_latest` — parallel lane decode,
last-writer-wins per (step, shard), newest marker with ssn <= RSNe wins.
"""

from __future__ import annotations

import os
import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from ..core.engine import EngineConfig, PoplarEngine, Worker
from ..core.txn import Txn
from ..core import ssn as ssn_mod
from . import records


class _ShardCell:
    __slots__ = ("ssn",)

    def __init__(self):
        self.ssn = 0


def flatten_state(state) -> List[Tuple[str, np.ndarray]]:
    flat = jax.tree_util.tree_flatten_with_path(state)[0]
    out = []
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        out.append((key, np.asarray(jax.device_get(leaf))))
    return out


@dataclass
class SaveHandle:
    step: int
    marker: Optional[Txn] = None
    done: threading.Event = field(default_factory=threading.Event)
    error: Optional[BaseException] = None

    def wait(self, timeout: float = 120.0) -> None:
        if not self.done.wait(timeout):
            raise TimeoutError(f"save of step {self.step} did not finish logging")
        if self.error is not None:
            raise self.error

    @property
    def committed(self) -> bool:
        return self.marker is not None and self.marker.committed


class PoplarCheckpointManager:
    def __init__(
        self,
        directory: str,
        n_lanes: int = 2,
        device_kind: str = "ssd",
        buffer_capacity: int = 8 * 1024 * 1024,
        io_unit: int = 256 * 1024,
        flush_interval: float = 2e-3,
        n_slices: int = 0,         # 0 => one slice per lane
    ):
        os.makedirs(directory, exist_ok=True)
        self.directory = directory
        self.n_lanes = n_lanes
        self.n_slices = n_slices or n_lanes
        cfg = EngineConfig(
            n_buffers=n_lanes,
            buffer_capacity=buffer_capacity,
            io_unit=io_unit,
            flush_interval=flush_interval,
            device_kind=device_kind,
            device_dir=directory,
        )
        self.engine = PoplarEngine(cfg)
        self.workers = [Worker(self.engine, i) for i in range(n_lanes)]
        self.cells: Dict[str, _ShardCell] = {}
        self._marker_cell = _ShardCell()
        self._queue: "queue.Queue[Optional[Tuple[int, Any, dict, SaveHandle]]]" = queue.Queue()
        self._stop = threading.Event()
        self._last_committed = -1
        self._markers: List[Txn] = []
        self.engine.start()
        self._thread = threading.Thread(target=self._save_loop, daemon=True, name="poplar-ckpt")
        self._thread.start()

    # --- public API -----------------------------------------------------------
    def save(self, step: int, state, metadata: Optional[dict] = None) -> SaveHandle:
        """Asynchronously journal one step's state.  Never blocks on IO."""
        handle = SaveHandle(step=step)
        # device_get on the caller thread (state is consistent at call time —
        # the fuzzy-checkpoint analogue is taking it without a barrier)
        flat = flatten_state(state)
        self._queue.put((step, flat, metadata or {}, handle))
        return handle

    def last_committed_step(self) -> int:
        """Largest step whose marker is durably committed (crash-survivable)."""
        for w in self.workers:
            w.drain()
        for t in self._markers:
            if t.committed:
                meta = getattr(t, "_step", None)
                if meta is not None and meta > self._last_committed:
                    self._last_committed = meta
        return self._last_committed

    def wait_for_commit(self, step: int, timeout: float = 120.0) -> None:
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            if self.last_committed_step() >= step:
                return
            time.sleep(1e-3)
        raise TimeoutError(f"step {step} not committed within {timeout}s")

    def close(self, quiesce: bool = True) -> None:
        self._queue.put(None)
        self._thread.join(timeout=60)
        if quiesce:
            self.engine.quiesce(range(self.n_lanes), timeout=60)
        self.engine.stop()
        for d in self.engine.devices:
            d.close()

    def crash(self) -> None:
        """Abandon everything in memory (tests/demos): stop loggers without
        flushing — whatever already hit the devices is the durable image."""
        self._stop.set()
        self._queue.put(None)
        self.engine.stop()
        for d in self.engine.devices:
            d.close()

    # --- save worker -----------------------------------------------------------
    def _save_loop(self) -> None:
        while not self._stop.is_set():
            item = self._queue.get()
            if item is None:
                return
            step, flat, metadata, handle = item
            try:
                self._log_step(step, flat, metadata, handle)
            except BaseException as e:  # noqa: BLE001 - surfaced via handle
                handle.error = e
            finally:
                handle.done.set()

    def _log_step(self, step: int, flat, metadata: dict, handle: SaveHandle) -> None:
        touched: List[_ShardCell] = []
        lane = 0
        for path, arr in flat:
            for idx, piece in enumerate(records.split_slices(arr, self.n_slices)):
                n = self.n_slices if arr.ndim and arr.shape[0] >= self.n_slices else 1
                key = records.shard_key(step, path, idx, n)
                cell = self.cells.setdefault(f"{path}#{idx}", _ShardCell())
                txn = Txn(tid=hash(key) & 0x7FFFFFFF,
                          write_set=[(key, records.encode_array(piece))])
                self.workers[lane % self.n_lanes].run(txn, [], [cell])
                touched.append(cell)
                lane += 1
        # step marker: RAW-depends on every shard cell of this step
        import json

        meta = dict(metadata)
        meta["step"] = step
        marker = Txn(
            tid=(step << 20) | 0xFFFFF,
            read_set=[("shard", c.ssn) for c in touched],
            write_set=[(records.marker_key(step), json.dumps(meta).encode())],
        )
        self.workers[step % self.n_lanes].run(marker, touched, [self._marker_cell])
        marker._step = step  # type: ignore[attr-defined]
        self._markers.append(marker)
        handle.marker = marker
