"""Journal record encoding for training state.

A training step maps onto Poplar transactions exactly:

* each state **shard** (a pytree leaf, optionally split into slices) is a
  *tuple* with its own SSN;
* writing a shard's bytes for step N is a **write-only transaction** (Qww):
  it is durable/committed as soon as its own lane's DSN covers it — no
  cross-lane coordination (the paper's central point);
* the **step marker** is a read-write transaction (Qwr) whose read set is
  every shard it must see durable: it commits only when ``ssn <= CSN``,
  i.e. when every lane has persisted everything the step depends on.  A
  committed marker == "step N is restorable", with no global barrier ever
  taken on the write path.

Record keys:
  ``{step:016d}/{path}#{slice}/{nslices}`` — shard payload
  ``STEP/{step:016d}``                     — step marker (value: metadata)

Payload: little-endian header (dtype str, ndim, dims) + raw array bytes.
"""

from __future__ import annotations

import json
import struct
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

_HDR = struct.Struct("<16sB")
_U32 = struct.Struct("<I")


def _dtype_name(dt: np.dtype) -> str:
    # ml_dtypes types (bfloat16, float8_*) stringify as void ('|V2') via
    # .str; .name keeps their identity
    return dt.name


def _resolve_dtype(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes

        return np.dtype(getattr(ml_dtypes, name))


def encode_array(arr: np.ndarray) -> bytes:
    # NB: np.ascontiguousarray would promote 0-d arrays to 1-d
    arr = np.asarray(arr, order="C")
    dt = _dtype_name(arr.dtype).encode().ljust(16, b"\0")
    parts = [_HDR.pack(dt, arr.ndim)]
    for d in arr.shape:
        parts.append(_U32.pack(d))
    parts.append(arr.tobytes())
    return b"".join(parts)


def decode_array(buf: bytes) -> np.ndarray:
    dt_raw, ndim = _HDR.unpack_from(buf, 0)
    dtype = _resolve_dtype(dt_raw.rstrip(b"\0").decode())
    pos = _HDR.size
    shape = []
    for _ in range(ndim):
        (d,) = _U32.unpack_from(buf, pos)
        shape.append(d)
        pos += 4
    return np.frombuffer(buf, dtype=dtype, offset=pos).reshape(shape)


def shard_key(step: int, path: str, slice_idx: int, n_slices: int) -> str:
    return f"{step:016d}/{path}#{slice_idx}/{n_slices}"


def marker_key(step: int) -> str:
    return f"STEP/{step:016d}"


def parse_key(key: str) -> Dict[str, Any]:
    if key.startswith("STEP/"):
        return {"kind": "marker", "step": int(key[5:])}
    step_s, rest = key.split("/", 1)
    path, sl = rest.rsplit("#", 1)
    idx, n = sl.split("/")
    return {"kind": "shard", "step": int(step_s), "path": path,
            "slice": int(idx), "n_slices": int(n)}


def split_slices(arr: np.ndarray, n_slices: int) -> List[np.ndarray]:
    """Split along the leading dim (or no-op for scalars / n=1)."""
    if n_slices <= 1 or arr.ndim == 0 or arr.shape[0] < n_slices:
        return [arr]
    return np.array_split(arr, n_slices, axis=0)


def join_slices(parts: Sequence[np.ndarray]) -> np.ndarray:
    if len(parts) == 1:
        return parts[0]
    return np.concatenate(parts, axis=0)
