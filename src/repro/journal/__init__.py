"""Poplar-journaled training-state durability (the paper's technique as a
first-class framework feature). See manager.py for the txn mapping."""

from .manager import PoplarCheckpointManager, SaveHandle, flatten_state
from .restore import JournalTails, restore_latest, to_pytree

__all__ = ["PoplarCheckpointManager", "SaveHandle", "flatten_state",
           "JournalTails", "restore_latest", "to_pytree"]
