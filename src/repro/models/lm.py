"""Decoder-only LM assembly (dense / moe / hybrid / rwkv / vlm families).

Layers are organized into **groups**: contiguous runs of identical blocks,
each group executed as one ``lax.scan`` over stacked parameters (keeps the
512-way SPMD HLO small and compile times bounded).  Hybrid archs (hymba)
with a few full-attention layers between sliding-window runs become multiple
groups; homogeneous archs are a single group.

Three entry points per model (built by :func:`build_lm`):
  * ``train_loss(params, batch)``            — full fwd + xent loss
  * ``prefill(params, batch)``               — fwd returning decode caches
  * ``decode_step(params, cache, tok, pos)`` — one token, cache update

Decode caches are ring buffers of capacity ``cache_len`` (= the shape's
seq_len for full attention, the window size for SWA, constant-size states
for SSM/RWKV).  The current token's k/v is appended logically during the
attention, then written at ``pos % W``.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..parallel.axes import constrain
from . import attention as attn_mod
from . import ffn as ffn_mod
from . import rwkv as rwkv_mod
from . import ssm as ssm_mod
from .common import ParamSpec, apply_norm, apply_rope, dense_spec, norm_spec, stack_specs


@dataclasses.dataclass(frozen=True)
class GroupDef:
    kind: str                 # 'dense' | 'moe' | 'hymba' | 'rwkv'
    n_layers: int
    window: Optional[int]     # sliding window (None = full attention)


def layer_groups(cfg: ArchConfig) -> List[GroupDef]:
    if cfg.rwkv is not None:
        return [GroupDef("rwkv", cfg.n_layers, None)]
    kind = "hymba" if cfg.ssm is not None else ("moe" if cfg.moe is not None else "dense")
    if cfg.sliding_window is None or not cfg.full_attn_layers:
        return [GroupDef(kind, cfg.n_layers, cfg.sliding_window)]
    groups: List[GroupDef] = []
    full = sorted(set(cfg.full_attn_layers))
    prev = 0
    for fi in full:
        if fi > prev:
            groups.append(GroupDef(kind, fi - prev, cfg.sliding_window))
        groups.append(GroupDef(kind, 1, None))
        prev = fi + 1
    if prev < cfg.n_layers:
        groups.append(GroupDef(kind, cfg.n_layers - prev, cfg.sliding_window))
    return groups


# --- per-block specs ----------------------------------------------------------

def attn_spec(cfg: ArchConfig) -> Dict[str, Any]:
    d, hd = cfg.d_model, cfg.hd
    spec = {
        "wq": dense_spec(d, cfg.n_heads * hd, ("embed", "heads")),
        "wk": dense_spec(d, cfg.n_kv_heads * hd, ("embed", "kv_heads")),
        "wv": dense_spec(d, cfg.n_kv_heads * hd, ("embed", "kv_heads")),
        "wo": dense_spec(cfg.n_heads * hd, d, ("heads", "embed")),
    }
    if cfg.qkv_bias:
        spec["bq"] = ParamSpec((cfg.n_heads * hd,), ("heads",), jnp.bfloat16, "zeros")
        spec["bk"] = ParamSpec((cfg.n_kv_heads * hd,), ("kv_heads",), jnp.bfloat16, "zeros")
        spec["bv"] = ParamSpec((cfg.n_kv_heads * hd,), ("kv_heads",), jnp.bfloat16, "zeros")
    return spec


def block_spec(cfg: ArchConfig, kind: str) -> Dict[str, Any]:
    d = cfg.d_model
    if kind == "rwkv":
        r = cfg.rwkv
        s = rwkv_mod.rwkv_spec(d, cfg.d_ff, r.n_heads, r.head_dim, r.decay_lora)
        return {"ln1": norm_spec(cfg, d), "time": s["time"], "ln2": norm_spec(cfg, d), "channel": s["channel"]}
    spec: Dict[str, Any] = {"ln1": norm_spec(cfg, d), "attn": attn_spec(cfg), "ln2": norm_spec(cfg, d)}
    if kind == "moe":
        spec["moe"] = ffn_mod.moe_spec(d, cfg.d_ff, cfg.moe.n_experts)
    else:
        spec["mlp"] = ffn_mod.mlp_spec(d, cfg.d_ff, style=cfg.mlp_style)
    if kind == "hymba":
        s = cfg.ssm
        spec["ssm"] = ssm_mod.ssm_spec(d, s.n_heads, s.head_dim, s.state_dim, s.conv_width)
        spec["attn_branch_norm"] = norm_spec(cfg, d)
        spec["ssm_branch_norm"] = norm_spec(cfg, d)
    return spec


def param_specs(cfg: ArchConfig) -> Dict[str, Any]:
    d = cfg.d_model
    specs: Dict[str, Any] = {
        "embed": ParamSpec((cfg.vocab, d), ("vocab", "embed")),
        "final_norm": norm_spec(cfg, d),
    }
    if not cfg.tie_embeddings:
        specs["unembed"] = dense_spec(d, cfg.vocab, ("embed", "vocab"))
    specs["groups"] = [
        stack_specs(block_spec(cfg, g.kind), g.n_layers) for g in layer_groups(cfg)
    ]
    return specs


# --- attention plumbing ----------------------------------------------------------

def _qkv(cfg: ArchConfig, p: Dict[str, Any], x: jax.Array, positions: jax.Array):
    b, s, d = x.shape
    hd = cfg.hd
    q = jnp.einsum("bsd,de->bse", x, p["wq"])
    k = jnp.einsum("bsd,de->bse", x, p["wk"])
    v = jnp.einsum("bsd,de->bse", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, cfg.n_heads, hd)
    k = k.reshape(b, s, cfg.n_kv_heads, hd)
    v = v.reshape(b, s, cfg.n_kv_heads, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _attn_full(cfg: ArchConfig, p, x, positions, window):
    q, k, v = _qkv(cfg, p, x, positions)
    out = attn_mod.attend(
        q, k, v, causal=True, window=window, impl=cfg.attn_impl,
        chunk_q=cfg.attn_chunk_q, chunk_k=cfg.attn_chunk_k,
        logit_softcap=cfg.attn_softcap,
    )
    b, s, _, _ = q.shape
    return jnp.einsum("bse,ed->bsd", out.reshape(b, s, -1), p["wo"])


def _attn_prefill(cfg: ArchConfig, p, x, positions, window, cache_len):
    q, k, v = _qkv(cfg, p, x, positions)
    out = attn_mod.attend(
        q, k, v, causal=True, window=window, impl=cfg.attn_impl,
        chunk_q=cfg.attn_chunk_q, chunk_k=cfg.attn_chunk_k,
        logit_softcap=cfg.attn_softcap,
    )
    b, s, _, _ = q.shape
    y = jnp.einsum("bse,ed->bsd", out.reshape(b, s, -1), p["wo"])
    # build ring cache of capacity cache_len from the last cache_len tokens
    if s >= cache_len:
        kc, vc = k[:, -cache_len:], v[:, -cache_len:]
    else:
        pad = cache_len - s
        kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return y, {"k": kc, "v": vc}


def _attn_decode(cfg: ArchConfig, p, x, cache, pos, window):
    """x: (B,1,d); cache k/v: (B,W,Kh,hd); pos: scalar absolute position."""
    b = x.shape[0]
    hd = cfg.hd
    w = cache["k"].shape[1]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q, k, v = _qkv(cfg, p, x, positions)
    # attend over cache ∪ current token
    k_all = jnp.concatenate([cache["k"], k], axis=1)
    v_all = jnp.concatenate([cache["v"], v], axis=1)
    # per-slot validity: slot i (if occupied) holds absolute position
    # q_i = pos-1 - ((pos-1-i) mod W); the sliding window additionally drops
    # slots with pos - q_i >= window (e.g. the slot about to be overwritten:
    # a full ring holds W *previous* tokens, but the window allows only W-1
    # previous + self)
    idx = jnp.arange(w)
    occupied = idx < jnp.minimum(pos, w)
    valid = occupied
    if window is not None:
        slot_pos = pos - 1 - jnp.mod(pos - 1 - idx, w)
        valid = occupied & (pos - slot_pos < window)
    valid = jnp.concatenate([valid, jnp.zeros((1,), bool)])  # self via tail_valid
    out = attn_mod.decode_attend(
        q, k_all, v_all, jnp.minimum(pos, w), tail_valid=1,
        valid_mask=valid, logit_softcap=cfg.attn_softcap,
    )
    y = jnp.einsum("bse,ed->bsd", out.reshape(b, 1, -1), p["wo"])
    slot = jnp.mod(pos, w)
    new_k = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
    new_v = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
    return y, {"k": new_k, "v": new_v}


# --- block forward functions ---------------------------------------------------

def make_block_fns(cfg: ArchConfig, g: GroupDef, cache_len: int):
    """Returns (fwd, prefill, decode) closures for one group's block."""
    kind, window = g.kind, g.window

    def _ffn(p, x):
        if kind == "moe":
            m = cfg.moe
            return ffn_mod.moe_fwd(
                p["moe"], x, n_experts=m.n_experts, top_k=m.top_k,
                capacity_factor=m.capacity_factor, group_size=m.group_size,
            )
        return ffn_mod.mlp_fwd(p["mlp"], x, style=cfg.mlp_style)

    def _mixer_full(p, x, positions):
        if kind == "hymba":
            s = cfg.ssm
            a = _attn_full(cfg, p["attn"], x, positions, window)
            m = ssm_mod.ssm_fwd(p["ssm"], x, s.n_heads, s.head_dim, s.state_dim,
                                impl=cfg.mixer_impl)
            a = apply_norm(cfg, p["attn_branch_norm"], a)
            m = apply_norm(cfg, p["ssm_branch_norm"], m)
            return 0.5 * (a + m)
        return _attn_full(cfg, p["attn"], x, positions, window)

    def fwd(p, x, positions):
        if kind == "rwkv":
            r = cfg.rwkv
            st = rwkv_mod.init_state(x.shape[0], cfg.d_model, r.n_heads, r.head_dim, x.dtype)
            y, _, _ = rwkv_mod.time_mix(p["time"], apply_norm(cfg, p["ln1"], x), st,
                                        r.n_heads, r.head_dim, impl=cfg.mixer_impl)
            x = x + y
            y, _ = rwkv_mod.channel_mix(p["channel"], apply_norm(cfg, p["ln2"], x), st["ffn_x"])
            return x + y
        x = x + _mixer_full(p, apply_norm(cfg, p["ln1"], x), positions)
        return x + _ffn(p, apply_norm(cfg, p["ln2"], x))

    def prefill(p, x, positions):
        if kind == "rwkv":
            r = cfg.rwkv
            b = x.shape[0]
            st = rwkv_mod.init_state(b, cfg.d_model, r.n_heads, r.head_dim, x.dtype)
            xn = apply_norm(cfg, p["ln1"], x)
            y, att_x, wkv = rwkv_mod.time_mix(p["time"], xn, st, r.n_heads, r.head_dim,
                                              impl=cfg.mixer_impl)
            x = x + y
            xn2 = apply_norm(cfg, p["ln2"], x)
            y, ffn_x = rwkv_mod.channel_mix(p["channel"], xn2, st["ffn_x"])
            return x + y, {"att_x": xn[:, -1, :], "ffn_x": xn2[:, -1, :], "wkv": wkv}
        cache = {}
        xn = apply_norm(cfg, p["ln1"], x)
        if kind == "hymba":
            s = cfg.ssm
            a, kv = _attn_prefill(cfg, p["attn"], xn, positions, window, cache_len)
            m, ssm_st = ssm_mod.ssm_scan(p["ssm"], xn, None, s.n_heads, s.head_dim,
                                         s.state_dim, impl=cfg.mixer_impl)
            mixed = 0.5 * (
                apply_norm(cfg, p["attn_branch_norm"], a)
                + apply_norm(cfg, p["ssm_branch_norm"], m)
            )
            x = x + mixed
            cache = {**kv, **ssm_st}
        else:
            a, kv = _attn_prefill(cfg, p["attn"], xn, positions, window, cache_len)
            x = x + a
            cache = kv
        x = x + _ffn(p, apply_norm(cfg, p["ln2"], x))
        return x, cache

    def decode(p, x, cache, pos):
        if kind == "rwkv":
            r = cfg.rwkv
            xn = apply_norm(cfg, p["ln1"], x)
            y, att_x, wkv = rwkv_mod.time_mix(
                p["time"], xn, {"att_x": cache["att_x"], "wkv": cache["wkv"]}, r.n_heads, r.head_dim
            )
            x = x + y
            xn2 = apply_norm(cfg, p["ln2"], x)
            y, ffn_x = rwkv_mod.channel_mix(p["channel"], xn2, cache["ffn_x"])
            return x + y, {"att_x": xn[:, -1, :], "ffn_x": xn2[:, -1, :], "wkv": wkv}
        xn = apply_norm(cfg, p["ln1"], x)
        if kind == "hymba":
            s = cfg.ssm
            a, kv = _attn_decode(cfg, p["attn"], xn, {"k": cache["k"], "v": cache["v"]}, pos, window)
            m, ssm_st = ssm_mod.ssm_step(
                p["ssm"], xn, {"conv": cache["conv"], "ssm": cache["ssm"]},
                s.n_heads, s.head_dim, s.state_dim,
            )
            mixed = 0.5 * (
                apply_norm(cfg, p["attn_branch_norm"], a)
                + apply_norm(cfg, p["ssm_branch_norm"], m)
            )
            x = x + mixed
            new_cache = {**kv, **ssm_st}
        else:
            a, kv = _attn_decode(cfg, p["attn"], xn, cache, pos, window)
            x = x + a
            new_cache = kv
        x = x + _ffn(p, apply_norm(cfg, p["ln2"], x))
        return x, new_cache

    return fwd, prefill, decode


# --- cache specs ------------------------------------------------------------------

def group_cache_spec(cfg: ArchConfig, g: GroupDef, batch: int, cache_len: int) -> Dict[str, ParamSpec]:
    """Stacked (over layers) decode-cache ShapeDtypeStructs + logical axes."""
    L = g.n_layers
    if g.kind == "rwkv":
        r = cfg.rwkv
        return {
            "att_x": ParamSpec((L, batch, cfg.d_model), ("layers", "batch", "embed"), jnp.bfloat16, "zeros"),
            "ffn_x": ParamSpec((L, batch, cfg.d_model), ("layers", "batch", "embed"), jnp.bfloat16, "zeros"),
            "wkv": ParamSpec((L, batch, r.n_heads, r.head_dim, r.head_dim),
                             ("layers", "batch", "heads", None, None), jnp.float32, "zeros"),
        }
    w = cache_len if g.window is None else min(g.window, cache_len)
    spec = {
        "k": ParamSpec((L, batch, w, cfg.n_kv_heads, cfg.hd),
                       ("layers", "batch", "kv_seq", "kv_heads", "head_dim"), jnp.bfloat16, "zeros"),
        "v": ParamSpec((L, batch, w, cfg.n_kv_heads, cfg.hd),
                       ("layers", "batch", "kv_seq", "kv_heads", "head_dim"), jnp.bfloat16, "zeros"),
    }
    if g.kind == "hymba":
        s = cfg.ssm
        di = s.n_heads * s.head_dim
        spec["conv"] = ParamSpec((L, batch, s.conv_width - 1, di),
                                 ("layers", "batch", None, "heads"), jnp.bfloat16, "zeros")
        spec["ssm"] = ParamSpec((L, batch, s.n_heads, s.head_dim, s.state_dim),
                                ("layers", "batch", "heads", "head_dim", None), jnp.float32, "zeros")
    return spec


def cache_specs(cfg: ArchConfig, batch: int, cache_len: int) -> List[Dict[str, ParamSpec]]:
    return [group_cache_spec(cfg, g, batch, cache_len) for g in layer_groups(cfg)]


# --- model assembly --------------------------------------------------------------

def _xent(logits: jax.Array, labels: jax.Array, mask: Optional[jax.Array] = None) -> jax.Array:
    """One-hot-einsum cross entropy: every reduction over the (sharded) vocab
    dim lowers to a clean psum; no gather on a sharded dim."""
    lg = logits.astype(jnp.float32)
    lg = constrain(lg, ("batch", None, "vocab"))
    m = jax.lax.stop_gradient(lg.max(axis=-1, keepdims=True))
    lse = m[..., 0] + jnp.log(jnp.sum(jnp.exp(lg - m), axis=-1))
    onehot = jax.nn.one_hot(labels, lg.shape[-1], dtype=lg.dtype)
    lab = jnp.einsum("...v,...v->...", lg, onehot)
    nll = lse - lab
    if mask is not None:
        return (nll * mask).sum(), mask.sum()
    return nll.sum(), jnp.asarray(nll.size, jnp.float32)


def chunked_xent(
    x: jax.Array,
    unembed_w: jax.Array,
    labels: jax.Array,
    mask: Optional[jax.Array] = None,
    chunk: int = 1024,
) -> jax.Array:
    """Sequence-chunked unembed+xent: the (B, S, V) logits tensor is never
    materialized — per-chunk logits are (B, c, V) and rematerialized in the
    backward pass (jax.checkpoint), bounding the loss-path working set."""
    b, s, d = x.shape
    if s % chunk != 0 or s <= chunk:
        logits = constrain(jnp.einsum("bsd,dv->bsv", x, unembed_w), ("batch", None, "vocab"))
        total, count = _xent(logits, labels, mask)
        return total / jnp.maximum(count, 1.0)
    n = s // chunk

    def body(carry, inp):
        total, count = carry
        x_c, lab_c, m_c = inp
        logits = constrain(jnp.einsum("bsd,dv->bsv", x_c, unembed_w), ("batch", None, "vocab"))
        t, c = _xent(logits, lab_c, m_c)
        return (total + t, count + c), None

    xs = x.reshape(b, n, chunk, d).transpose(1, 0, 2, 3)
    labs = labels.reshape(b, n, chunk).transpose(1, 0, 2)
    ms = (
        mask.reshape(b, n, chunk).transpose(1, 0, 2)
        if mask is not None
        else jnp.ones((n, b, chunk), jnp.float32)
    )
    fn = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    (total, count), _ = jax.lax.scan(fn, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)), (xs, labs, ms))
    return total / jnp.maximum(count, 1.0)


class LM:
    """Functional decoder-only LM bound to an ArchConfig."""

    def __init__(self, cfg: ArchConfig, remat_policy: str = "none"):
        self.cfg = cfg
        self.groups = layer_groups(cfg)
        self.remat_policy = remat_policy

    # - specs -
    def param_specs(self) -> Dict[str, Any]:
        return param_specs(self.cfg)

    def cache_specs(self, batch: int, cache_len: int):
        return cache_specs(self.cfg, batch, cache_len)

    # - helpers -
    def _embed(self, params, tokens):
        return constrain(params["embed"][tokens], ("batch", None, None))

    def _unembed_w(self, params):
        return params["embed"].T if self.cfg.tie_embeddings else params["unembed"]

    def _unembed(self, params, x):
        x = apply_norm(self.cfg, params["final_norm"], x)
        return constrain(
            jnp.einsum("bsd,dv->bsv", x, self._unembed_w(params)), ("batch", None, "vocab")
        )

    def _remat(self, fn):
        if self.remat_policy == "none":
            return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
        if self.remat_policy == "dots":
            return jax.checkpoint(
                fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
            )
        if self.remat_policy == "full":  # no rematerialization
            return fn
        raise ValueError(self.remat_policy)

    def _run_groups(self, params, x, positions):
        for g, p_stacked in zip(self.groups, params["groups"]):
            fwd, _, _ = make_block_fns(self.cfg, g, cache_len=0)
            fn = self._remat(
                lambda p, xx: constrain(fwd(p, xx, positions), ("batch", None, None))
            )

            def body(xx, p):
                return fn(p, xx), None

            x, _ = jax.lax.scan(body, x, p_stacked)
        return x

    # - entry points -
    def train_loss(self, params, batch) -> jax.Array:
        cfg = self.cfg
        tokens = batch["tokens"]
        x = self._embed(params, tokens)
        mask = None
        if cfg.vlm is not None:
            ve = batch["vision_embeds"].astype(x.dtype)
            x = jnp.concatenate([ve, x], axis=1)
            mask = jnp.concatenate(
                [jnp.zeros(ve.shape[:2], jnp.float32), jnp.ones(tokens.shape, jnp.float32)],
                axis=1,
            )
        positions = jnp.arange(x.shape[1])[None, :]
        x = self._run_groups(params, x, positions)
        x = apply_norm(cfg, params["final_norm"], x)
        labels = batch["labels"]
        if cfg.vlm is not None:
            pad = jnp.zeros((labels.shape[0], x.shape[1] - labels.shape[1]), labels.dtype)
            labels = jnp.concatenate([pad, labels], axis=1)
        return chunked_xent(x, self._unembed_w(params), labels, mask)

    def prefill(self, params, batch, cache_len: int):
        cfg = self.cfg
        tokens = batch["tokens"]
        x = self._embed(params, tokens)
        if cfg.vlm is not None:
            x = jnp.concatenate([batch["vision_embeds"].astype(x.dtype), x], axis=1)
        positions = jnp.arange(x.shape[1])[None, :]
        caches = []
        for g, p_stacked in zip(self.groups, params["groups"]):
            _, prefill_fn, _ = make_block_fns(self.cfg, g, cache_len)
            fn = self._remat(lambda p, xx: prefill_fn(p, xx, positions))

            def body(xx, p):
                y, c = fn(p, xx)
                return y, c

            x, cache = jax.lax.scan(body, x, p_stacked)
            caches.append(cache)
        logits = self._unembed(params, x[:, -1:, :])
        return logits, caches

    def decode_step(self, params, caches, tokens, pos):
        """tokens: (B, 1); pos: scalar int32 absolute position."""
        x = self._embed(params, tokens)
        new_caches = []
        for g, p_stacked, cache in zip(self.groups, params["groups"], caches):
            _, _, decode_fn = make_block_fns(self.cfg, g, cache_len=0)

            def body(xx, pc):
                p, c = pc
                y, c2 = decode_fn(p, xx, c, pos)
                return y, c2

            x, new_cache = jax.lax.scan(body, x, (p_stacked, cache))
            new_caches.append(new_cache)
        logits = self._unembed(params, x)
        return logits, new_caches
