"""RWKV6 ("Finch") block: token-shift time-mix with data-dependent decay
(wkv6 recurrence) + gated channel-mix.  Attention-free; decode state is
constant-size: two token-shift vectors + one (H, hd, hd) wkv state per layer.

wkv6 per head (hd = head dim, keys and values same width):

    S_t = diag(w_t) S_{t-1} + k_t^T v_t          # (hd, hd) state
    y_t = r_t (S_{t-1} + diag(u) k_t^T v_t)

``w_t = exp(-exp(w0 + lora(x_t)))`` — per-channel, data-dependent decay (the
Finch contribution vs RWKV5's static decay).  This module is the pure-JAX
scan (oracle for `kernels/rwkv6.py`, which implements the chunked form).
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .common import ParamSpec, dense_spec


def rwkv_spec(d: int, f: int, n_heads: int, head_dim: int, lora: int) -> Dict[str, ParamSpec]:
    di = n_heads * head_dim
    return {
        "time": {
            # token-shift interpolation coefficients for r,k,v,g,w
            "mu_r": ParamSpec((d,), (None,), jnp.float32, "ones", 0.5),
            "mu_k": ParamSpec((d,), (None,), jnp.float32, "ones", 0.5),
            "mu_v": ParamSpec((d,), (None,), jnp.float32, "ones", 0.5),
            "mu_g": ParamSpec((d,), (None,), jnp.float32, "ones", 0.5),
            "mu_w": ParamSpec((d,), (None,), jnp.float32, "ones", 0.5),
            "w_r": dense_spec(d, di, ("embed", "heads")),
            "w_k": dense_spec(d, di, ("embed", "heads")),
            "w_v": dense_spec(d, di, ("embed", "heads")),
            "w_g": dense_spec(d, di, ("embed", "heads")),
            "w_o": dense_spec(di, d, ("heads", "embed")),
            # data-dependent decay: w0 + tanh(x A1) A2
            "w0": ParamSpec((di,), (None,), jnp.float32, "decay"),
            "w_lora_a": dense_spec(d, lora, ("embed", None), jnp.float32),
            "w_lora_b": dense_spec(lora, di, (None, "heads"), jnp.float32),
            "u": ParamSpec((n_heads, head_dim), (None, None), jnp.float32, "normal", 1.0),
            "ln_scale": ParamSpec((di,), (None,), jnp.float32, "ones"),
            "ln_bias": ParamSpec((di,), (None,), jnp.float32, "zeros"),
        },
        "channel": {
            "mu_k": ParamSpec((d,), (None,), jnp.float32, "ones", 0.5),
            "mu_r": ParamSpec((d,), (None,), jnp.float32, "ones", 0.5),
            "w_k": dense_spec(d, f, ("embed", "mlp")),
            "w_v": dense_spec(f, d, ("mlp", "embed")),
            "w_r": dense_spec(d, d, ("embed", "embed2")),
        },
    }


def _token_shift(x: jax.Array, prev: jax.Array) -> jax.Array:
    """x: (B, S, d); prev: (B, d) last token of previous chunk.  Returns
    x shifted right by one along S with ``prev`` filling slot 0."""
    return jnp.concatenate([prev[:, None, :], x[:, :-1, :]], axis=1)


def _mix(x: jax.Array, x_prev: jax.Array, mu: jax.Array) -> jax.Array:
    return x + (x_prev - x) * mu.astype(x.dtype)


def _group_norm(y: jax.Array, scale: jax.Array, bias: jax.Array, n_heads: int) -> jax.Array:
    b, s, di = y.shape
    yh = y.reshape(b, s, n_heads, di // n_heads).astype(jnp.float32)
    mu = yh.mean(-1, keepdims=True)
    var = ((yh - mu) ** 2).mean(-1, keepdims=True)
    yh = (yh - mu) * jax.lax.rsqrt(var + 64e-5)
    return (yh.reshape(b, s, di) * scale + bias).astype(y.dtype)


def time_mix(
    p: Dict[str, jax.Array],
    x: jax.Array,
    st: Dict[str, jax.Array],
    n_heads: int,
    head_dim: int,
    impl: str = "scan",
    chunk: int = 16,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (y, new_shift (B,d), new_wkv (B,H,hd,hd)).

    ``impl='chunked'`` uses the block form (kernels/rwkv6.py math in
    differentiable jnp) — per-chunk matmuls instead of a length-S scan."""
    b, s, d = x.shape
    di = n_heads * head_dim
    prev = st["att_x"]
    xs = _token_shift(x, prev)
    r = jnp.einsum("bsd,de->bse", _mix(x, xs, p["mu_r"]), p["w_r"])
    k = jnp.einsum("bsd,de->bse", _mix(x, xs, p["mu_k"]), p["w_k"])
    v = jnp.einsum("bsd,de->bse", _mix(x, xs, p["mu_v"]), p["w_v"])
    g = jnp.einsum("bsd,de->bse", _mix(x, xs, p["mu_g"]), p["w_g"])
    xw = _mix(x, xs, p["mu_w"]).astype(jnp.float32)
    lora = jnp.einsum(
        "bsl,le->bse", jnp.tanh(jnp.einsum("bsd,dl->bsl", xw, p["w_lora_a"])), p["w_lora_b"]
    )
    w = jnp.exp(-jnp.exp(p["w0"][None, None, :] + lora))     # (B,S,di) in (0,1)

    rh = r.reshape(b, s, n_heads, head_dim).astype(jnp.float32)
    kh = k.reshape(b, s, n_heads, head_dim).astype(jnp.float32)
    vh = v.reshape(b, s, n_heads, head_dim).astype(jnp.float32)
    wh = w.reshape(b, s, n_heads, head_dim)
    u = p["u"]                                                # (H, hd)

    if impl == "chunked" and s > 1 and s % chunk == 0:
        y, S_final = _chunked_wkv(rh, kh, vh, wh, u, st["wkv"].astype(jnp.float32), chunk)
        y = y.reshape(b, s, di)
        y = _group_norm(y, p["ln_scale"], p["ln_bias"], n_heads)
        y = y * jax.nn.silu(g.astype(jnp.float32)).astype(y.dtype)
        out = jnp.einsum("bse,ed->bsd", y.astype(x.dtype), p["w_o"])
        return out, x[:, -1, :], S_final

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp                              # (B,H,hd) each
        kv = k_t[..., None] * v_t[..., None, :]               # (B,H,hd,hd)
        y_t = jnp.einsum("bhk,bhkv->bhv", r_t, S + u[None, :, :, None] * kv)
        S = w_t[..., None] * S + kv
        return S, y_t

    S0 = st["wkv"]
    inputs = (
        rh.transpose(1, 0, 2, 3),
        kh.transpose(1, 0, 2, 3),
        vh.transpose(1, 0, 2, 3),
        wh.transpose(1, 0, 2, 3),
    )
    # checkpoint: scan-AD would otherwise save every step's (hd, hd) kv outer
    # product; with checkpoint only the carried wkv state is saved per step
    step = jax.checkpoint(step, policy=jax.checkpoint_policies.nothing_saveable)
    S_final, ys = jax.lax.scan(step, S0, inputs)
    y = ys.transpose(1, 0, 2, 3).reshape(b, s, di)
    y = _group_norm(y, p["ln_scale"], p["ln_bias"], n_heads)
    y = y * jax.nn.silu(g.astype(jnp.float32)).astype(y.dtype)
    out = jnp.einsum("bse,ed->bsd", y.astype(x.dtype), p["w_o"])
    return out, x[:, -1, :], S_final


def _chunked_wkv(rh, kh, vh, wh, u, S0, chunk):
    """Block-form wkv6 (see kernels/rwkv6.py for the math & stability note).
    rh/kh/wh (B,S,H,K) f32, vh (B,S,H,V) f32, u (H,K), S0 (B,H,K,V)."""
    b, s, h, kd = rh.shape
    vd = vh.shape[-1]
    nc = s // chunk
    shape5 = (b, nc, chunk, h, kd)
    rc = rh.reshape(shape5)
    kc = kh.reshape(shape5)
    vc = vh.reshape(b, nc, chunk, h, vd)
    lwc = jnp.log(jnp.maximum(wh, 1e-30)).reshape(shape5)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)

    def chunk_step(S, inp):
        r, k, v, lw_raw = inp                   # (B,C,H,K)... (B,C,H,V)
        lw = jnp.cumsum(lw_raw, axis=1)
        lw_excl = lw - lw_raw
        rd = r * jnp.exp(lw_excl)
        y_state = jnp.einsum("bchk,bhkv->bchv", rd, S)
        rel = lw_excl[:, :, None] - lw[:, None, :, :]          # (B,t,s,H,K)
        decay = jnp.where(tri[None, :, :, None, None], jnp.exp(rel), 0.0)
        a = jnp.einsum("bthk,bshk,btshk->btsh", r, k, decay)
        a_diag = jnp.einsum("bchk,hk,bchk->bch", r, u, k)
        eye = jnp.eye(chunk, dtype=bool)
        a = a + jnp.where(eye[None, :, :, None], a_diag[:, :, None, :], 0.0)
        y_intra = jnp.einsum("btsh,bshv->bthv", a, v)
        lw_last = lw[:, -1:]                                    # (B,1,H,K)
        k_scaled = k * jnp.exp(lw_last - lw)
        S_new = jnp.exp(lw_last[:, 0])[..., None] * S + jnp.einsum(
            "bchk,bchv->bhkv", k_scaled, v
        )
        return S_new, y_state + y_intra

    inputs = tuple(t.transpose(1, 0, 2, 3, 4) for t in (rc, kc, vc, lwc))
    S_final, ys = jax.lax.scan(chunk_step, S0, inputs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, h, vd)
    return y.reshape(b, s, h * vd), S_final


def channel_mix(
    p: Dict[str, jax.Array], x: jax.Array, prev: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    xs = _token_shift(x, prev)
    k = jnp.einsum("bsd,df->bsf", _mix(x, xs, p["mu_k"]), p["w_k"])
    k = jnp.square(jax.nn.relu(k.astype(jnp.float32))).astype(x.dtype)
    r = jax.nn.sigmoid(
        jnp.einsum("bsd,de->bse", _mix(x, xs, p["mu_r"]), p["w_r"]).astype(jnp.float32)
    ).astype(x.dtype)
    return r * jnp.einsum("bsf,fd->bsd", k, p["w_v"]), x[:, -1, :]


def init_state(b: int, d: int, n_heads: int, head_dim: int, dtype):
    return {
        "att_x": jnp.zeros((b, d), dtype),
        "ffn_x": jnp.zeros((b, d), dtype),
        "wkv": jnp.zeros((b, n_heads, head_dim, head_dim), jnp.float32),
    }
