"""Batched LLM serving engine: prefill + greedy decode against ring caches.

Works for every registered arch (full attention, SWA, hybrid, rwkv,
enc-dec).  ``ServeEngine.generate`` processes a batch of prompts in one
prefill and decodes tokens step by step with jitted ``decode_step``.

(Lives next to the model definitions it drives; ``repro.serve`` hosts the
OLTP group-commit serving tier, which is unrelated to token generation.)
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..models.api import Model, build_model


@dataclass
class GenerationResult:
    tokens: np.ndarray          # (B, max_new)
    prefill_s: float
    decode_s: float
    tokens_per_s: float


class ServeEngine:
    def __init__(self, model: Model, params, cache_len: int = 512):
        self.model = model
        self.params = params
        self.cache_len = cache_len
        self._prefill = jax.jit(lambda p, b: model.prefill(p, b, cache_len))
        self._decode = jax.jit(model.decode_step)

    def generate(self, batch: Dict[str, jax.Array], max_new: int = 16) -> GenerationResult:
        tokens = batch["tokens"]
        b, prompt_len = tokens.shape
        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, batch)
        next_tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
        jax.block_until_ready(next_tok)
        t1 = time.perf_counter()

        out = [np.asarray(next_tok)]
        # absolute position accounting includes any vlm prefix
        extra = 0
        if self.model.cfg.vlm is not None and "vision_embeds" in batch:
            extra = batch["vision_embeds"].shape[1]
        pos = prompt_len + extra
        for i in range(max_new - 1):
            logits, cache = self._decode(self.params, cache, next_tok, jnp.asarray(pos + i, jnp.int32))
            next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            out.append(np.asarray(next_tok))
        jax.block_until_ready(next_tok)
        t2 = time.perf_counter()
        toks = np.concatenate(out, axis=1)
        return GenerationResult(
            tokens=toks,
            prefill_s=t1 - t0,
            decode_s=t2 - t1,
            tokens_per_s=b * max_new / max(t2 - t1, 1e-9),
        )
