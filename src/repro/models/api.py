"""Unified model API: ``build_model(cfg)`` -> a Model with

    param_specs()                      -> ParamSpec tree
    init(rng)                          -> real params (smoke/small-scale)
    train_loss(params, batch)          -> scalar
    prefill(params, batch, cache_len)  -> (last_logits, caches)
    decode_step(params, caches, tokens, pos) -> (logits, caches)
    cache_specs(batch, cache_len)      -> ParamSpec tree for decode caches
"""

from __future__ import annotations

from typing import Any

import jax

from ..configs.base import ArchConfig
from .common import init_params
from .encdec import EncDecLM
from .lm import LM


class Model:
    def __init__(self, impl, cfg: ArchConfig):
        self._impl = impl
        self.cfg = cfg

    def param_specs(self):
        return self._impl.param_specs()

    def init(self, rng: jax.Array):
        return init_params(self.param_specs(), rng)

    def init_cache(self, rng: jax.Array, batch: int, cache_len: int):
        return init_params(self.cache_specs(batch, cache_len), rng)

    def train_loss(self, params, batch):
        return self._impl.train_loss(params, batch)

    def prefill(self, params, batch, cache_len: int):
        return self._impl.prefill(params, batch, cache_len)

    def decode_step(self, params, caches, tokens, pos):
        return self._impl.decode_step(params, caches, tokens, pos)

    def cache_specs(self, batch: int, cache_len: int):
        return self._impl.cache_specs(batch, cache_len)


def build_model(cfg: ArchConfig, remat_policy: str = "none") -> Model:
    if cfg.enc_dec is not None:
        return Model(EncDecLM(cfg, remat_policy), cfg)
    return Model(LM(cfg, remat_policy), cfg)
