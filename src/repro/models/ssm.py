"""Mamba-style selective SSM branch (hymba's parallel-head hybrid).

Mamba2-flavoured head-structured selective scan:

    h_t = exp(-exp(A_log) * dt_t) * h_{t-1} + dt_t * (x_t ⊗ B_t)
    y_t = (h_t · C_t) + D * x_t

with per-head scalar decay ``A_log``, data-dependent ``dt_t`` (softplus),
shared B/C projections (single group), causal depthwise conv on the input
path, and a SiLU gate branch — the standard mamba2 block minus the
hardware-specific chunking (the Pallas kernel `kernels/ssm_scan.py` provides
a chunked TPU implementation; this module is the pure-JAX path / oracle).

State for decode: conv tail (B, cw-1, di) + ssm state (B, H, hd, N).
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .common import ParamSpec, dense_spec


def ssm_spec(d: int, n_heads: int, head_dim: int, state: int, conv_width: int) -> Dict[str, ParamSpec]:
    di = n_heads * head_dim
    return {
        "in_proj": dense_spec(d, di, ("embed", "heads")),
        "gate_proj": dense_spec(d, di, ("embed", "heads")),
        "conv_w": ParamSpec((conv_width, di), (None, "heads"), jnp.bfloat16, "normal", 0.5),
        "dt_proj": dense_spec(d, n_heads, ("embed", None)),
        "dt_bias": ParamSpec((n_heads,), (None,), jnp.float32, "zeros"),
        "b_proj": dense_spec(d, state, ("embed", None)),
        "c_proj": dense_spec(d, state, ("embed", None)),
        "a_log": ParamSpec((n_heads,), (None,), jnp.float32, "decay"),
        "d_skip": ParamSpec((n_heads,), (None,), jnp.float32, "ones"),
        "out_proj": dense_spec(di, d, ("heads", "embed")),
    }


def _causal_conv(x: jax.Array, w: jax.Array, tail: jax.Array = None) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv via shifted adds.  x: (B, S, di); w: (cw, di).
    ``tail``: (B, cw-1, di) previous context (decode) — returns new tail."""
    cw = w.shape[0]
    b, s, di = x.shape
    if tail is None:
        tail = jnp.zeros((b, cw - 1, di), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)           # (B, S+cw-1, di)
    y = jnp.zeros_like(x)
    for i in range(cw):
        y = y + xp[:, i : i + s] * w[cw - 1 - i]
    new_tail = xp[:, -(cw - 1):] if cw > 1 else tail
    return y, new_tail


def ssm_fwd(
    p: Dict[str, jax.Array], x: jax.Array, n_heads: int, head_dim: int, state: int,
    impl: str = "scan",
) -> jax.Array:
    """Full-sequence forward (train / prefill). x: (B, S, d) -> (B, S, d)."""
    y, _ = ssm_scan(p, x, None, n_heads, head_dim, state, impl=impl)
    return y


def init_state(b: int, n_heads: int, head_dim: int, state: int, conv_width: int, di: int, dtype):
    return {
        "conv": jnp.zeros((b, conv_width - 1, di), dtype),
        "ssm": jnp.zeros((b, n_heads, head_dim, state), jnp.float32),
    }


def ssm_scan(
    p: Dict[str, jax.Array],
    x: jax.Array,
    st: Dict[str, jax.Array],
    n_heads: int,
    head_dim: int,
    state: int,
    impl: str = "scan",
    chunk: int = 64,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Selective scan over the full input; returns (y, new_state).
    ``st=None`` starts from zeros (training).

    ``impl='chunked'`` uses the SSD block form (the Pallas kernel's math in
    differentiable jnp): per-step HBM round-trips become per-chunk matmuls —
    the optimization recorded in EXPERIMENTS §Perf for the hybrid/ssm cells.
    """
    b, s, d = x.shape
    di = n_heads * head_dim
    xs = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    z = jnp.einsum("bsd,de->bse", x, p["gate_proj"])
    conv_tail = st["conv"] if st is not None else None
    xs, new_tail = _causal_conv(xs, p["conv_w"], conv_tail)
    xs = jax.nn.silu(xs.astype(jnp.float32)).astype(x.dtype)

    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", x.astype(jnp.float32), p["dt_proj"].astype(jnp.float32))
        + p["dt_bias"]
    )                                                   # (B, S, H)
    decay = jnp.exp(-jnp.exp(p["a_log"])[None, None, :] * dt)  # (B, S, H)
    bt = jnp.einsum("bsd,dn->bsn", x, p["b_proj"]).astype(jnp.float32)
    ct = jnp.einsum("bsd,dn->bsn", x, p["c_proj"]).astype(jnp.float32)
    xh = xs.reshape(b, s, n_heads, head_dim).astype(jnp.float32)

    h0 = st["ssm"] if st is not None else jnp.zeros((b, n_heads, head_dim, state), jnp.float32)

    if impl == "chunked" and s > 1 and s % chunk == 0:
        y, h_final = _chunked_selective_scan(xh, dt, decay, bt, ct, h0, chunk)
        y = y + p["d_skip"][None, None, :, None] * xh
        y = y.reshape(b, s, di).astype(x.dtype)
        y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
        out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
        return out, {"conv": new_tail, "ssm": h_final}

    def step(h, inp):
        x_t, dt_t, dec_t, b_t, c_t = inp
        # h: (B, H, hd, N)
        upd = (dt_t[:, :, None] * x_t)[..., None] * b_t[:, None, None, :]
        h = dec_t[:, :, None, None] * h + upd
        y_t = jnp.einsum("bhdn,bn->bhd", h, c_t)
        return h, y_t

    xs_t = (
        xh.transpose(1, 0, 2, 3),       # (S, B, H, hd)
        dt.transpose(1, 0, 2),          # (S, B, H)
        decay.transpose(1, 0, 2),
        bt.transpose(1, 0, 2),
        ct.transpose(1, 0, 2),
    )
    step = jax.checkpoint(step, policy=jax.checkpoint_policies.nothing_saveable)
    h_final, ys = jax.lax.scan(step, h0, xs_t)
    y = ys.transpose(1, 0, 2, 3)                          # (B, S, H, hd)
    y = y + p["d_skip"][None, None, :, None] * xh
    y = y.reshape(b, s, di).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    new_state = {"conv": new_tail, "ssm": h_final}
    return out, new_state


def ssm_step(
    p: Dict[str, jax.Array],
    x1: jax.Array,
    st: Dict[str, jax.Array],
    n_heads: int,
    head_dim: int,
    state: int,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Single-token decode step. x1: (B, 1, d)."""
    return ssm_scan(p, x1, st, n_heads, head_dim, state)


def _chunked_selective_scan(xh, dt, decay, bt, ct, h0, chunk):
    """SSD block form.  xh (B,S,H,P) f32; dt/decay (B,S,H); bt/ct (B,S,N);
    h0 (B,H,P,N).  Exponents are differences of log-cumsums with later-minus-
    earlier ordering, so every exp() argument is <= 0 (stable)."""
    b, s, h, p_dim = xh.shape
    n = bt.shape[-1]
    nc = s // chunk
    u = (dt[..., None] * xh).reshape(b, nc, chunk, h, p_dim)
    la_all = jnp.log(jnp.maximum(decay, 1e-30)).reshape(b, nc, chunk, h)
    btc = bt.reshape(b, nc, chunk, n)
    ctc = ct.reshape(b, nc, chunk, n)

    def chunk_step(h_prev, inp):
        uc, lac, bc, cc = inp          # (B,C,H,P), (B,C,H), (B,C,N), (B,C,N)
        la = jnp.cumsum(lac, axis=1)   # (B,C,H)
        # state contribution
        cs = jnp.einsum("bcn,bhpn->bchp", cc, h_prev)
        y_state = jnp.exp(la)[..., None] * cs
        # intra-chunk
        cb = jnp.einsum("btn,bsn->bts", cc, bc)               # (B,C,C)
        rel = la[:, :, None, :] - la[:, None, :, :]           # (B,t,s,H)
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        m = jnp.where(tri[None, :, :, None], jnp.exp(rel), 0.0) * cb[..., None]
        y_intra = jnp.einsum("btsh,bshp->bthp", m, uc)
        # state update
        la_last = la[:, -1:, :]                               # (B,1,H)
        scaled_u = uc * jnp.exp(la_last - la)[..., None]      # (B,C,H,P)
        h_new = jnp.exp(la_last[:, 0, :])[:, :, None, None] * h_prev + jnp.einsum(
            "bchp,bcn->bhpn", scaled_u, bc
        )
        return h_new, y_state + y_intra

    inputs = (
        u.transpose(1, 0, 2, 3, 4),
        la_all.transpose(1, 0, 2, 3),
        btc.transpose(1, 0, 2, 3),
        ctc.transpose(1, 0, 2, 3),
    )
    h_final, ys = jax.lax.scan(chunk_step, h0.astype(jnp.float32), inputs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, h, p_dim)
    return y, h_final
