"""Attention: GQA with RoPE, causal/sliding-window masks, two chunked
implementations (memory-safe at 32k+ sequure), and decode-time cache reads.

Implementations (selected by ``ArchConfig.attn_impl``):

* ``masked_scan`` — baseline: ``lax.scan`` over KV chunks with an online
  softmax.  HLO is tiny (one inner body) but causal masking wastes ~2x FLOPs
  (every q attends every kv chunk, masked).  This is the paper-faithful-era
  baseline the roofline hillclimb starts from.

* ``triangular`` — optimized: python-unrolled q chunks, each attending only
  its causal prefix (or its sliding-window span, statically sliced), halving
  attention FLOPs at the cost of a larger (still bounded) HLO.

Shapes: q (B, S, H, D); k/v (B, T, Hkv, D).  GQA via reshape to
(B, S, Hkv, G, D) with G = H // Hkv.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _split_gqa(q: jax.Array, n_kv: int) -> jax.Array:
    b, s, h, d = q.shape
    return q.reshape(b, s, n_kv, h // n_kv, d)


def _softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def attend(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    impl: str = "masked_scan",
    chunk_q: int = 512,
    chunk_k: int = 1024,
    logit_softcap: Optional[float] = None,
    q_offset: int = 0,
) -> jax.Array:
    """Full-sequence attention (train / prefill).

    ``q_offset`` — absolute position of q[0] relative to k[0] (used when the
    query block sits at the end of a longer kv sequence, e.g. vlm prefixes).
    """
    b, s, h, d = q.shape
    t = k.shape[1]
    n_kv = k.shape[2]
    scale = 1.0 / math.sqrt(d)
    qg = _split_gqa(q, n_kv) * scale

    if impl == "triangular" and causal:
        return _attend_triangular(qg, k, v, window, chunk_q, logit_softcap, q_offset)
    if impl == "flash":
        out = _flash(qg, k, v, causal, window, chunk_k, logit_softcap, q_offset)
        return out.reshape(b, s, h, d).astype(v.dtype)
    return _attend_masked_scan(qg, k, v, causal, window, chunk_k, logit_softcap, q_offset)


def _attend_masked_scan(qg, k, v, causal, window, chunk_k, logit_softcap, q_offset):
    b, s, n_kv, g, d = qg.shape
    t = k.shape[1]
    ck = min(chunk_k, t)
    n_chunks = (t + ck - 1) // ck
    pad = n_chunks * ck - t
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(b, n_chunks, ck, n_kv, d).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, ck, n_kv, d).transpose(1, 0, 2, 3, 4)

    q_pos = q_offset + jnp.arange(s)

    def body(carry, xs):
        m, l, acc = carry
        kj, vj, j = xs
        kv_pos = j * ck + jnp.arange(ck)
        # scores: (b, s, n_kv, g, ck)
        scores = jnp.einsum(
            "bsngd,bcnd->bsngc", qg.astype(jnp.float32), kj.astype(jnp.float32)
        )
        scores = _softcap(scores, logit_softcap)
        # mask: causal / window / kv padding (pad slots sit at positions >= t)
        mask = (kv_pos < t)[None, :] & jnp.ones((s, 1), dtype=bool)
        if causal:
            mask &= q_pos[:, None] >= kv_pos[None, :]
        if window is not None:
            mask &= q_pos[:, None] - kv_pos[None, :] < window
        scores = jnp.where(mask[None, :, None, None, :], scores, NEG_INF)
        m_new = jnp.maximum(m, scores.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(scores - m_new[..., None])
        l_new = l * alpha + p.sum(axis=-1)
        pv = jnp.einsum("bsngc,bcnd->bsngd", p, vj.astype(jnp.float32))
        acc_new = acc * alpha[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, s, n_kv, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, s, n_kv, g), jnp.float32)
    a0 = jnp.zeros((b, s, n_kv, g, d), jnp.float32)
    js = jnp.arange(n_chunks)
    # checkpoint the chunk body: scan-AD otherwise saves every chunk's
    # (s x ck) probability matrix — the dominant HBM buffer at 32k prefill
    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kc, vc, js))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(b, s, n_kv * g, d).astype(v.dtype)


def _attend_triangular(qg, k, v, window, chunk_q, logit_softcap, q_offset):
    """Python-unrolled q chunks; each chunk sees only its causal span."""
    b, s, n_kv, g, d = qg.shape
    t = k.shape[1]
    cq = min(chunk_q, s)
    outs = []
    for qs in range(0, s, cq):
        qe = min(qs + cq, s)
        q_blk = qg[:, qs:qe]
        abs_start, abs_end = q_offset + qs, q_offset + qe  # absolute kv span
        k_end = min(abs_end, t)
        k_start = 0 if window is None else max(0, abs_start - window + 1)
        k_blk = k[:, k_start:k_end]
        v_blk = v[:, k_start:k_end]
        scores = jnp.einsum(
            "bsngd,bcnd->bsngc", q_blk.astype(jnp.float32), k_blk.astype(jnp.float32)
        )
        scores = _softcap(scores, logit_softcap)
        q_pos = abs_start + jnp.arange(qe - qs)
        kv_pos = k_start + jnp.arange(k_end - k_start)
        mask = q_pos[:, None] >= kv_pos[None, :]
        if window is not None:
            mask &= q_pos[:, None] - kv_pos[None, :] < window
        scores = jnp.where(mask[None, :, None, None, :], scores, NEG_INF)
        out = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bsngc,bcnd->bsngd", out, v_blk.astype(jnp.float32))
        outs.append(out)
    out = jnp.concatenate(outs, axis=1)
    return out.reshape(b, s, n_kv * g, d).astype(v.dtype)


def attend_bidir(
    q: jax.Array, k: jax.Array, v: jax.Array, chunk_k: int = 1024,
) -> jax.Array:
    """Bidirectional attention (encoder / cross-attention)."""
    return attend(q, k, v, causal=False, impl="masked_scan", chunk_k=chunk_k)


# --- flash (custom-vjp online softmax): O(S) memory fwd AND bwd ----------------
#
# The masked_scan baseline lets scan-AD save per-chunk probability matrices
# (or full-q accumulator carries), which is what blows up train-cell HBM
# (EXPERIMENTS §Perf, iteration 1).  The flash path saves only (out, m, l)
# and rebuilds p per kv chunk in the backward — the FlashAttention backward,
# in pure JAX.  The Pallas kernel (kernels/flash_attention.py) is the TPU
# runtime twin of the forward; this path makes the *compiled HLO* exhibit
# the same memory behaviour for the dry-run roofline.

def _chunk_scores(qg, kj, kv_pos, q_pos, causal, window, softcap, t):
    s = jnp.einsum("bsngd,bcnd->bsngc", qg, kj.astype(jnp.float32))
    ds_dsraw = None
    if softcap is not None:
        th = jnp.tanh(s / softcap)
        ds_dsraw = 1.0 - th * th
        s = softcap * th
    mask = (kv_pos < t)[None, :] & jnp.ones((q_pos.shape[0], 1), dtype=bool)
    if causal:
        mask = mask & (q_pos[:, None] >= kv_pos[None, :])
    if window is not None:
        mask = mask & (q_pos[:, None] - kv_pos[None, :] < window)
    s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
    return s, ds_dsraw


def _flash_chunks(k, chunk_k):
    b, t, n_kv, d = k.shape
    ck = min(chunk_k, t)
    n_chunks = (t + ck - 1) // ck
    pad = n_chunks * ck - t
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return k.reshape(b, n_chunks, ck, n_kv, d).transpose(1, 0, 2, 3, 4), ck, n_chunks


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash(qg, k, v, causal, window, chunk_k, softcap, q_offset):
    out, _, _ = _flash_fwd_core(qg, k, v, causal, window, chunk_k, softcap, q_offset)
    return out


def _flash_fwd_core(qg, k, v, causal, window, chunk_k, softcap, q_offset):
    b, s, n_kv, g, d = qg.shape
    t = k.shape[1]
    kc, ck, n_chunks = _flash_chunks(k, chunk_k)
    vc, _, _ = _flash_chunks(v, chunk_k)
    q_pos = q_offset + jnp.arange(s)
    qf = qg.astype(jnp.float32)

    def body(carry, xs):
        m, l, acc = carry
        kj, vj, j = xs
        kv_pos = j * ck + jnp.arange(ck)
        sc, _ = _chunk_scores(qf, kj, kv_pos, q_pos, causal, window, softcap, t)
        m_new = jnp.maximum(m, sc.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(sc - m_new[..., None])
        l_new = l * alpha + p.sum(axis=-1)
        pv = jnp.einsum("bsngc,bcnd->bsngd", p, vj.astype(jnp.float32))
        return (m_new, l_new, acc * alpha[..., None] + pv), None

    m0 = jnp.full((b, s, n_kv, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, s, n_kv, g), jnp.float32)
    a0 = jnp.zeros((b, s, n_kv, g, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kc, vc, jnp.arange(n_chunks)))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out, m, l


def _flash_vjp_fwd(qg, k, v, causal, window, chunk_k, softcap, q_offset):
    out, m, l = _flash_fwd_core(qg, k, v, causal, window, chunk_k, softcap, q_offset)
    return out, (qg, k, v, out, m, l)


def _flash_vjp_bwd(causal, window, chunk_k, softcap, q_offset, res, do):
    qg, k, v, out, m, l = res
    b, s, n_kv, g, d = qg.shape
    t = k.shape[1]
    kc, ck, n_chunks = _flash_chunks(k, chunk_k)
    vc, _, _ = _flash_chunks(v, chunk_k)
    q_pos = q_offset + jnp.arange(s)
    qf = qg.astype(jnp.float32)
    dof = do.astype(jnp.float32)
    lsafe = jnp.maximum(l, 1e-30)
    # D = sum_d do ⊙ out  (per query)
    dsum = jnp.einsum("bsngd,bsngd->bsng", dof, out)

    def body(dq, xs):
        kj, vj, j = xs
        kv_pos = j * ck + jnp.arange(ck)
        sc, dcap = _chunk_scores(qf, kj, kv_pos, q_pos, causal, window, softcap, t)
        p = jnp.exp(sc - m[..., None]) / lsafe[..., None]         # normalized
        dp = jnp.einsum("bsngd,bcnd->bsngc", dof, vj.astype(jnp.float32))
        ds = p * (dp - dsum[..., None])
        if dcap is not None:
            ds = ds * dcap
        dq = dq + jnp.einsum("bsngc,bcnd->bsngd", ds, kj.astype(jnp.float32))
        dkj = jnp.einsum("bsngc,bsngd->bcnd", ds, qf)
        dvj = jnp.einsum("bsngc,bsngd->bcnd", p, dof)
        return dq, (dkj, dvj)

    dq0 = jnp.zeros_like(qf)
    dq, (dks, dvs) = jax.lax.scan(body, dq0, (kc, vc, jnp.arange(n_chunks)))
    dk = dks.transpose(1, 0, 2, 3, 4).reshape(b, n_chunks * ck, n_kv, d)[:, :t]
    dv = dvs.transpose(1, 0, 2, 3, 4).reshape(b, n_chunks * ck, n_kv, d)[:, :t]
    return dq.astype(qg.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_flash.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


def decode_attend(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    cur_len: jax.Array,
    *,
    tail_valid: int = 0,
    valid_mask: Optional[jax.Array] = None,
    logit_softcap: Optional[float] = None,
) -> jax.Array:
    """Single-step decode attention against a cache.

    q: (B, 1, H, D); caches: (B, W, Hkv, D); cur_len: () int32 — number of
    valid cache entries counted from the front (for a ring cache,
    min(pos, W): all slots valid once wrapped).  ``tail_valid``: the last n
    positions are always valid — used when the current token's k/v is
    appended after the cache (it must be attendable even though the cache
    prefix isn't full yet).  ``valid_mask``: precomputed per-slot validity
    (overrides cur_len; used for sliding-window slot-staleness masking).
    """
    b, _, h, d = q.shape
    w = k_cache.shape[1]
    n_kv = k_cache.shape[2]
    scale = 1.0 / math.sqrt(d)
    qg = _split_gqa(q, n_kv)[:, 0] * scale          # (B, n_kv, G, D)
    scores = jnp.einsum(
        "bngd,bcnd->bngc", qg.astype(jnp.float32), k_cache.astype(jnp.float32)
    )
    scores = _softcap(scores, logit_softcap)
    idx = jnp.arange(w)
    if valid_mask is not None:
        valid = valid_mask
    else:
        valid = idx < cur_len
    if tail_valid:
        valid = valid | (idx >= w - tail_valid)
    scores = jnp.where(valid[None, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bngc,bcnd->bngd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, n_kv * (h // n_kv), d).astype(v_cache.dtype)
