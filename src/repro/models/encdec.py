"""Whisper-style encoder-decoder backbone (audio frontend is a stub:
``input_specs()`` supplies precomputed frame embeddings (B, F, d_model);
the conv1d+mel frontend is out of scope per the assignment).

Encoder: bidirectional pre-LN blocks (LayerNorm + gelu MLP), learned-free
sinusoidal positions folded into the stub embeddings.
Decoder: causal self-attention + cross-attention over encoder output + MLP.

Decode caches: per decoder layer — self-attn ring cache + cross-attn K/V
(computed once from the encoder output during ``prefill``).
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from . import attention as attn_mod
from .common import ParamSpec, apply_norm, apply_rope, dense_spec, norm_spec, stack_specs
from .ffn import mlp_fwd, mlp_spec
from .lm import chunked_xent, attn_spec
from ..parallel.axes import constrain


def _enc_block_spec(cfg: ArchConfig) -> Dict[str, Any]:
    return {
        "ln1": norm_spec(cfg, cfg.d_model),
        "attn": attn_spec(cfg),
        "ln2": norm_spec(cfg, cfg.d_model),
        "mlp": mlp_spec(cfg.d_model, cfg.d_ff, style="gelu2"),
    }


def _dec_block_spec(cfg: ArchConfig) -> Dict[str, Any]:
    return {
        "ln1": norm_spec(cfg, cfg.d_model),
        "self_attn": attn_spec(cfg),
        "ln_x": norm_spec(cfg, cfg.d_model),
        "cross_attn": attn_spec(cfg),
        "ln2": norm_spec(cfg, cfg.d_model),
        "mlp": mlp_spec(cfg.d_model, cfg.d_ff, style="gelu2"),
    }


def param_specs(cfg: ArchConfig) -> Dict[str, Any]:
    assert cfg.enc_dec is not None
    return {
        "embed": ParamSpec((cfg.vocab, cfg.d_model), ("vocab", "embed")),
        "enc": stack_specs(_enc_block_spec(cfg), cfg.enc_dec.enc_layers),
        "enc_norm": norm_spec(cfg, cfg.d_model),
        "dec": stack_specs(_dec_block_spec(cfg), cfg.n_layers),
        "final_norm": norm_spec(cfg, cfg.d_model),
        "unembed": dense_spec(cfg.d_model, cfg.vocab, ("embed", "vocab")),
    }


def _proj_qkv(cfg, p, xq, xkv, positions_q=None, positions_kv=None):
    b, s, d = xq.shape
    t = xkv.shape[1]
    hd = cfg.hd
    q = jnp.einsum("bsd,de->bse", xq, p["wq"]).reshape(b, s, cfg.n_heads, hd)
    k = jnp.einsum("bsd,de->bse", xkv, p["wk"]).reshape(b, t, cfg.n_kv_heads, hd)
    v = jnp.einsum("bsd,de->bse", xkv, p["wv"]).reshape(b, t, cfg.n_kv_heads, hd)
    if positions_q is not None:
        q = apply_rope(q, positions_q, cfg.rope_theta)
    if positions_kv is not None:
        k = apply_rope(k, positions_kv, cfg.rope_theta)
    return q, k, v


class EncDecLM:
    def __init__(self, cfg: ArchConfig, remat_policy: str = "none"):
        self.cfg = cfg
        self.remat_policy = remat_policy

    def param_specs(self):
        return param_specs(self.cfg)

    def _remat(self, fn):
        if self.remat_policy == "full":
            return fn
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)

    # --- encoder ------------------------------------------------------------
    def encode(self, params, frame_embeds: jax.Array) -> jax.Array:
        cfg = self.cfg
        x = frame_embeds

        def block(p, xx):
            xn = apply_norm(cfg, p["ln1"], xx)
            q, k, v = _proj_qkv(cfg, p["attn"], xn, xn)
            a = attn_mod.attend_bidir(q, k, v, chunk_k=cfg.attn_chunk_k)
            b_, s_, _, _ = q.shape
            xx = xx + jnp.einsum("bse,ed->bsd", a.reshape(b_, s_, -1), p["attn"]["wo"])
            return xx + mlp_fwd(p["mlp"], apply_norm(cfg, p["ln2"], xx), style="gelu2")

        fn = self._remat(lambda p, xx: constrain(block(p, xx), ("batch", None, None)))

        def body(xx, p):
            return fn(p, xx), None

        x, _ = jax.lax.scan(body, x, params["enc"])
        return apply_norm(cfg, params["enc_norm"], x)

    # --- decoder ----------------------------------------------------------------
    def _dec_block_full(self, p, x, enc_out, positions):
        cfg = self.cfg
        xn = apply_norm(cfg, p["ln1"], x)
        q, k, v = _proj_qkv(cfg, p["self_attn"], xn, xn, positions, positions)
        a = attn_mod.attend(q, k, v, causal=True, impl=cfg.attn_impl,
                            chunk_q=cfg.attn_chunk_q, chunk_k=cfg.attn_chunk_k)
        b_, s_, _, _ = q.shape
        x = x + jnp.einsum("bse,ed->bsd", a.reshape(b_, s_, -1), p["self_attn"]["wo"])
        xn = apply_norm(cfg, p["ln_x"], x)
        q, k, v = _proj_qkv(cfg, p["cross_attn"], xn, enc_out)
        a = attn_mod.attend_bidir(q, k, v, chunk_k=cfg.attn_chunk_k)
        x = x + jnp.einsum("bse,ed->bsd", a.reshape(b_, s_, -1), p["cross_attn"]["wo"])
        return x + mlp_fwd(p["mlp"], apply_norm(cfg, p["ln2"], x), style="gelu2")

    def train_loss(self, params, batch) -> jax.Array:
        enc_out = self.encode(params, batch["frame_embeds"])
        tokens, labels = batch["tokens"], batch["labels"]
        x = params["embed"][tokens]
        positions = jnp.arange(x.shape[1])[None, :]
        fn = self._remat(
            lambda p, xx: constrain(
                self._dec_block_full(p, xx, enc_out, positions), ("batch", None, None)
            )
        )

        def body(xx, p):
            return fn(p, xx), None

        x, _ = jax.lax.scan(body, x, params["dec"])
        x = apply_norm(self.cfg, params["final_norm"], x)
        return chunked_xent(x, params["unembed"], labels)

    # --- serving -----------------------------------------------------------------
    def prefill(self, params, batch, cache_len: int):
        """Encode audio + run decoder prefix; build decode caches."""
        cfg = self.cfg
        enc_out = self.encode(params, batch["frame_embeds"])
        tokens = batch["tokens"]
        x = params["embed"][tokens]
        b, s, d = x.shape
        positions = jnp.arange(s)[None, :]

        def pre_block(p, xx):
            xn = apply_norm(cfg, p["ln1"], xx)
            q, k, v = _proj_qkv(cfg, p["self_attn"], xn, xn, positions, positions)
            a = attn_mod.attend(q, k, v, causal=True, impl=cfg.attn_impl,
                                chunk_q=cfg.attn_chunk_q, chunk_k=cfg.attn_chunk_k)
            xx = xx + jnp.einsum("bse,ed->bsd", a.reshape(b, s, -1), p["self_attn"]["wo"])
            xn = apply_norm(cfg, p["ln_x"], xx)
            qc, kc, vc = _proj_qkv(cfg, p["cross_attn"], xn, enc_out)
            a = attn_mod.attend_bidir(qc, kc, vc, chunk_k=cfg.attn_chunk_k)
            xx = xx + jnp.einsum("bse,ed->bsd", a.reshape(b, s, -1), p["cross_attn"]["wo"])
            xx = xx + mlp_fwd(p["mlp"], apply_norm(cfg, p["ln2"], xx), style="gelu2")
            if s >= cache_len:
                kr, vr = k[:, -cache_len:], v[:, -cache_len:]
            else:
                pad = cache_len - s
                kr = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
                vr = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
            return xx, {"k": kr, "v": vr, "xk": kc, "xv": vc}

        fn = self._remat(pre_block)

        def body(xx, p):
            return fn(p, xx)

        x, cache = jax.lax.scan(body, x, params["dec"])
        x = apply_norm(cfg, params["final_norm"], x[:, -1:, :])
        logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"])
        return logits, cache

    def decode_step(self, params, cache, tokens, pos):
        cfg = self.cfg
        x = params["embed"][tokens]
        b = x.shape[0]

        def block(xx, pc):
            p, c = pc
            xn = apply_norm(cfg, p["ln1"], xx)
            positions = jnp.full((b, 1), pos, jnp.int32)
            q, k, v = _proj_qkv(cfg, p["self_attn"], xn, xn, positions, positions)
            w = c["k"].shape[1]
            k_all = jnp.concatenate([c["k"], k], axis=1)
            v_all = jnp.concatenate([c["v"], v], axis=1)
            a = attn_mod.decode_attend(q, k_all, v_all, jnp.minimum(pos, w), tail_valid=1)
            xx = xx + jnp.einsum("bse,ed->bsd", a.reshape(b, 1, -1), p["self_attn"]["wo"])
            xn = apply_norm(cfg, p["ln_x"], xx)
            qc = jnp.einsum("bsd,de->bse", xn, p["cross_attn"]["wq"]).reshape(
                b, 1, cfg.n_heads, cfg.hd
            )
            a = attn_mod.decode_attend(qc, c["xk"], c["xv"], c["xk"].shape[1])
            xx = xx + jnp.einsum("bse,ed->bsd", a.reshape(b, 1, -1), p["cross_attn"]["wo"])
            xx = xx + mlp_fwd(p["mlp"], apply_norm(cfg, p["ln2"], xx), style="gelu2")
            slot = jnp.mod(pos, w)
            new_k = jax.lax.dynamic_update_slice(c["k"], k, (0, slot, 0, 0))
            new_v = jax.lax.dynamic_update_slice(c["v"], v, (0, slot, 0, 0))
            return xx, {"k": new_k, "v": new_v, "xk": c["xk"], "xv": c["xv"]}

        x, new_cache = jax.lax.scan(block, x, (params["dec"], cache))
        x = apply_norm(cfg, params["final_norm"], x)
        logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"])
        return logits, new_cache

    def cache_specs(self, batch: int, cache_len: int):
        cfg = self.cfg
        L = cfg.n_layers
        F = cfg.enc_dec.enc_seq
        return {
            "k": ParamSpec((L, batch, cache_len, cfg.n_kv_heads, cfg.hd),
                           ("layers", "batch", "kv_seq", "kv_heads", "head_dim"), jnp.bfloat16, "zeros"),
            "v": ParamSpec((L, batch, cache_len, cfg.n_kv_heads, cfg.hd),
                           ("layers", "batch", "kv_seq", "kv_heads", "head_dim"), jnp.bfloat16, "zeros"),
            "xk": ParamSpec((L, batch, F, cfg.n_kv_heads, cfg.hd),
                            ("layers", "batch", None, "kv_heads", "head_dim"), jnp.bfloat16, "zeros"),
            "xv": ParamSpec((L, batch, F, cfg.n_kv_heads, cfg.hd),
                            ("layers", "batch", None, "kv_heads", "head_dim"), jnp.bfloat16, "zeros"),
        }
