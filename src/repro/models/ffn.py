"""Feed-forward blocks: gated MLP (llama-style), gelu MLP (whisper), and
top-k MoE with grouped capacity dispatch (mixtral / grok).

MoE dispatch: tokens are reshaped into groups of ``group_size``; within a
group, top-k routing builds dispatch/combine tensors of shape
``(G, g, E, C)`` with per-group capacity ``C = ceil(g * k * cf / E)``.
Groups are the data-sharded dim, so dispatch memory/FLOPs stay
O(tokens * g) instead of O(tokens^2 / E) — the one-hot overhead is ~5-10%
of expert FLOPs at g=2048 (reported in the roofline's MODEL/HLO ratio).

Expert weights are TP-MoE sharded: ``(E, d, f)`` with f over the model axis
and FSDP over data; 8 experts do not divide the 16-wide model axis, so
expert-parallel-proper is mesh-incompatible here (see DESIGN §5).
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import ParamSpec, dense_spec


# --- dense MLPs -------------------------------------------------------------

def mlp_spec(d: int, f: int, style: str = "swiglu") -> Dict[str, ParamSpec]:
    if style == "gelu2":
        return {
            "w_in": dense_spec(d, f, ("embed", "mlp")),
            "b_in": ParamSpec((f,), ("mlp",), jnp.bfloat16, "zeros"),
            "w_out": dense_spec(f, d, ("mlp", "embed")),
            "b_out": ParamSpec((d,), (None,), jnp.bfloat16, "zeros"),
        }
    return {
        "w_gate": dense_spec(d, f, ("embed", "mlp")),
        "w_up": dense_spec(d, f, ("embed", "mlp")),
        "w_down": dense_spec(f, d, ("mlp", "embed")),
    }


def mlp_fwd(p: Dict[str, jax.Array], x: jax.Array, style: str = "swiglu") -> jax.Array:
    if style == "gelu2":
        h = jnp.einsum("...d,df->...f", x, p["w_in"]) + p["b_in"]
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
        return jnp.einsum("...f,fd->...d", h, p["w_out"]) + p["b_out"]
    g = jnp.einsum("...d,df->...f", x, p["w_gate"])
    u = jnp.einsum("...d,df->...f", x, p["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("...f,fd->...d", h, p["w_down"])


# --- MoE ---------------------------------------------------------------------

def moe_spec(d: int, f: int, n_experts: int) -> Dict[str, ParamSpec]:
    return {
        "router": ParamSpec((d, n_experts), ("embed", None), jnp.float32),
        "w_gate": ParamSpec((n_experts, d, f), ("expert", "embed", "mlp")),
        "w_up": ParamSpec((n_experts, d, f), ("expert", "embed", "mlp")),
        "w_down": ParamSpec((n_experts, f, d), ("expert", "mlp", "embed")),
    }


def moe_fwd(
    p: Dict[str, jax.Array],
    x: jax.Array,
    *,
    n_experts: int,
    top_k: int,
    capacity_factor: float,
    group_size: int,
) -> jax.Array:
    """x: (B, S, d) -> (B, S, d).  Top-k routing with capacity dropping."""
    b, s, d = x.shape
    tokens = b * s
    g = min(group_size, tokens)
    pad = (-tokens) % g
    flat = x.reshape(tokens, d)
    if pad:
        flat = jnp.pad(flat, ((0, pad), (0, 0)))
    valid_tok = (jnp.arange(tokens + pad) < tokens)
    n_groups = (tokens + pad) // g
    xg = flat.reshape(n_groups, g, d)
    valid = valid_tok.reshape(n_groups, g)

    logits = jnp.einsum("Gsd,de->Gse", xg.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)                 # (G, g, E)
    top_p, top_i = jax.lax.top_k(probs, top_k)              # (G, g, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)  # renorm (mixtral)

    capacity = int(math.ceil(g * top_k * capacity_factor / n_experts))
    capacity = max(capacity, top_k)

    # position of each (slot, token) within its expert: slot-0 of all tokens
    # is prioritized over slot-1 (t5x convention)
    oh = jax.nn.one_hot(top_i, n_experts, dtype=jnp.int32)  # (G, g, k, E)
    oh_slotmajor = oh.transpose(0, 2, 1, 3).reshape(n_groups, top_k * g, n_experts)
    pos = jnp.cumsum(oh_slotmajor, axis=1) - oh_slotmajor   # exclusive cumsum
    pos = pos.reshape(n_groups, top_k, g, n_experts).transpose(0, 2, 1, 3)  # (G,g,k,E)
    pos_of_slot = jnp.sum(pos * oh, axis=-1)                # (G, g, k)
    keep = (pos_of_slot < capacity) & valid[..., None]       # capacity drop + pad mask

    # dispatch: (G, g, E, C); combine: same with gate probs folded in
    pos_oh = jax.nn.one_hot(pos_of_slot, capacity, dtype=x.dtype)  # (G,g,k,C)
    disp = jnp.einsum(
        "GskE,GskC->GsEC",
        oh.astype(x.dtype) * keep[..., None].astype(x.dtype),
        pos_oh,
    )
    comb = jnp.einsum(
        "GskE,GskC->GsEC",
        (oh.astype(jnp.float32) * (top_p * keep)[..., None]).astype(x.dtype),
        pos_oh,
    )

    expert_in = jnp.einsum("GsEC,Gsd->GECd", disp, xg)       # gather-as-matmul
    gate = jnp.einsum("GECd,Edf->GECf", expert_in, p["w_gate"])
    up = jnp.einsum("GECd,Edf->GECf", expert_in, p["w_up"])
    h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    expert_out = jnp.einsum("GECf,Efd->GECd", h, p["w_down"])
    out = jnp.einsum("GsEC,GECd->Gsd", comb, expert_out)     # scatter-as-matmul
    out = out.reshape(tokens + pad, d)
    if pad:
        out = out[:tokens]
    return out.reshape(b, s, d)
