"""Shared model building blocks: param specs, norms, RoPE, initializers.

Parameters are described by :class:`ParamSpec` trees (shape, dtype, logical
sharding axes).  The dry-run lowers against ``jax.ShapeDtypeStruct`` leaves;
smoke tests materialize real arrays via :func:`init_params`.

Logical axis names (mapped to mesh axes by ``repro.parallel.sharding``):
  "vocab"   — vocabulary dim (TP)
  "embed"   — d_model dim (FSDP target)
  "heads"   — attention-head dim (TP)
  "kv_heads"— kv-head dim
  "head_dim"— per-head feature dim (TP fallback when heads don't divide)
  "mlp"     — FFN hidden dim (TP)
  "expert"  — MoE expert dim
  "layers"  — stacked-scan layer dim (never sharded)
  None      — replicated
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]
    dtype: Any = jnp.bfloat16
    init: str = "normal"       # 'normal' | 'zeros' | 'ones' | 'decay'
    init_scale: float = 1.0

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)

    def sds(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, self.dtype)


def stack_specs(spec_tree, n: int):
    """Prepend a stacked 'layers' dim of size n to every leaf (scan groups)."""
    return jax.tree.map(
        lambda s: ParamSpec((n,) + s.shape, ("layers",) + s.logical, s.dtype, s.init, s.init_scale),
        spec_tree,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def specs_to_sds(spec_tree):
    return jax.tree.map(
        lambda s: s.sds(), spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec)
    )


def init_params(spec_tree, rng: jax.Array):
    """Materialize real parameters for smoke tests / small-scale training."""
    leaves, treedef = jax.tree.flatten(
        spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec)
    )
    rngs = jax.random.split(rng, len(leaves))
    out = []
    for spec, r in zip(leaves, rngs):
        if spec.init == "zeros":
            arr = jnp.zeros(spec.shape, spec.dtype)
        elif spec.init == "ones":
            arr = jnp.ones(spec.shape, spec.dtype)
        elif spec.init == "decay":
            # rwkv/ssm decay-style init: small negatives
            arr = (-0.5 - jax.random.uniform(r, spec.shape)).astype(spec.dtype)
        else:
            fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
            std = spec.init_scale / math.sqrt(max(1, fan_in))
            arr = (jax.random.normal(r, spec.shape, jnp.float32) * std).astype(spec.dtype)
        out.append(arr)
    return jax.tree.unflatten(treedef, out)


# --- norms -----------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * scale.astype(jnp.float32)).astype(dt)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def norm_spec(cfg, d: int) -> Dict[str, ParamSpec]:
    if cfg.norm == "layernorm":
        return {
            "scale": ParamSpec((d,), (None,), jnp.float32, "ones"),
            "bias": ParamSpec((d,), (None,), jnp.float32, "zeros"),
        }
    return {"scale": ParamSpec((d,), (None,), jnp.float32, "ones")}


def apply_norm(cfg, p: Dict[str, jax.Array], x: jax.Array) -> jax.Array:
    if cfg.norm == "layernorm":
        return layer_norm(x, p["scale"], p["bias"])
    return rms_norm(x, p["scale"])


# --- rotary position embeddings ---------------------------------------------

def rope_freqs(head_dim: int, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    sin = jnp.sin(angles)[..., None, :]                # (..., S, 1, D/2)
    cos = jnp.cos(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


def dense_spec(d_in: int, d_out: int, logical: Tuple[Optional[str], Optional[str]],
               dtype=jnp.bfloat16, init_scale: float = 1.0) -> ParamSpec:
    return ParamSpec((d_in, d_out), logical, dtype, "normal", init_scale)
