"""Open-loop client sessions for the serving tier.

Closed-loop drivers (every benchmark before this tier) submit the next
transaction only after the previous one finishes, so measured latency can
never exceed service time — overload is invisible.  The open-loop driver
models independent clients: arrivals follow a Poisson process at a fixed
*offered* rate regardless of how the system is doing, and latency is
measured from the **scheduled** arrival time, so queueing delay (including
delay caused by the submitter itself falling behind) is charged to the
system, never silently dropped — the standard coordinated-omission fix.

``OpenLoopDriver`` drives a threaded :class:`GroupCommitScheduler`;
``run_stepped_schedule`` replays a deterministic arrival schedule against a
stepped one (the shape every serve test uses).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..db.batch import TxnSpec
from .scheduler import ABORTED, ACKED, REJECTED, GroupCommitScheduler, Ticket


@dataclass
class DriverReport:
    """Outcome of one open-loop run at a fixed offered load."""

    offered_per_s: float
    duration_s: float
    submitted: int
    acked: int
    rejected: int
    aborted: int
    latencies_ms: np.ndarray = field(default_factory=lambda: np.empty(0))

    @property
    def goodput_per_s(self) -> float:
        return self.acked / self.duration_s if self.duration_s else 0.0

    def pct_ms(self, q: float) -> float:
        if not len(self.latencies_ms):
            return float("nan")
        return float(np.percentile(self.latencies_ms, q))


class OpenLoopDriver:
    """Submit pre-generated specs at Poisson arrival times (threaded mode).

    ``specs`` are generated up front (vectorized workload draws) so the
    submission loop does no per-txn generation work; at high offered rates
    the loop catches up in bursts, which is exactly what a lagging load
    generator does — and scheduled-arrival latency accounting keeps the
    numbers honest when it happens.
    """

    def __init__(
        self,
        sched: GroupCommitScheduler,
        specs: Sequence[TxnSpec],
        rate_per_s: float,
        seed: int = 0,
    ):
        self.sched = sched
        self.specs = list(specs)
        self.rate = rate_per_s
        rng = np.random.default_rng(seed)
        gaps = rng.exponential(1.0 / rate_per_s, len(self.specs))
        self.offsets = np.cumsum(gaps)  # scheduled arrival offsets (s)

    def run(self, settle_timeout_s: float = 30.0) -> DriverReport:
        """Blocking: submit every spec at its scheduled time, then wait for
        all tickets to terminate (the scheduler must be started)."""
        t0 = time.perf_counter()
        tickets: List[Ticket] = []
        for i, spec in enumerate(self.specs):
            due = t0 + self.offsets[i]
            now = time.perf_counter()
            if now < due:
                time.sleep(due - now)
            tickets.append(self.sched.submit(spec, client_id=i))
        # settle: every admitted txn must reach ACKED or ABORTED
        deadline = time.perf_counter() + settle_timeout_s
        for t in tickets:
            t.wait(timeout=max(0.0, deadline - time.perf_counter()))
        # goodput denominator: submission window through the last released
        # ack — a straggler that never acks within the settle window must
        # not inflate the divisor for the work that did complete
        t_end = max(
            [t.t_ack for t in tickets if t.status == ACKED],
            default=time.perf_counter(),
        )
        duration = max(t_end, t0 + self.offsets[-1]) - t0
        lat = np.asarray(
            [
                (t.t_ack - (t0 + self.offsets[i])) * 1e3
                for i, t in enumerate(tickets)
                if t.status == ACKED
            ]
        )
        n_acked = sum(1 for t in tickets if t.status == ACKED)
        n_rej = sum(1 for t in tickets if t.status == REJECTED)
        n_ab = sum(1 for t in tickets if t.status == ABORTED)
        return DriverReport(
            offered_per_s=self.rate,
            duration_s=duration,
            submitted=len(tickets),
            acked=n_acked,
            rejected=n_rej,
            aborted=n_ab,
            latencies_ms=lat,
        )


def run_stepped_schedule(
    sched: GroupCommitScheduler,
    schedule: Sequence[Tuple[int, TxnSpec]],
    tick_parts_fn: Optional[Callable[[int], Optional[Sequence[int]]]] = None,
    max_steps: int = 10_000,
) -> List[Ticket]:
    """Replay a deterministic arrival schedule against a stepped scheduler.

    ``schedule`` is a list of ``(arrival_step, spec)`` pairs (any order;
    ties submit in list order).  Before each ``step()``, every spec whose
    arrival step has come is submitted.  ``tick_parts_fn(step)`` chooses
    which device subset flushes that step (None → all) — randomized
    interleaving tests drive DSN/CSN divergence through it.  Runs until all
    tickets are terminal; returns them in submission order.
    """
    by_step: Dict[int, List[Tuple[int, TxnSpec]]] = {}
    for i, (at, spec) in enumerate(schedule):
        by_step.setdefault(int(at), []).append((i, spec))
    tickets: List[Optional[Ticket]] = [None] * len(schedule)
    last_arrival = max(by_step) if by_step else 0
    for _ in range(max_steps):
        step = sched.now_step  # arrivals land before the step they're due
        for i, spec in by_step.pop(step, ()):
            tickets[i] = sched.submit(spec, client_id=i)
        sched.step(tick_parts_fn(step) if tick_parts_fn else None)
        if step >= last_arrival and all(
            t is not None and t.done for t in tickets
        ):
            return tickets  # type: ignore[return-value]
    raise TimeoutError("stepped schedule did not terminate")
