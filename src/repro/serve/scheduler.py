"""Open-loop group-commit scheduler: the OLTP serving front end.

Everything below the serving tier is a closed-loop driver calling
``execute_batch`` directly; this module models the million-client world the
paper's latency claims (§6, fig7) are about.  Async client sessions submit
*single* transactions; the scheduler coalesces arrivals into batches for
the array-native executor under a configurable latency budget (group
commit), applies admission control when the log or a shard saturates, and
retries validation losers with backoff (hot-key skew).  A client's commit
acknowledgment is released **only** once its record is durable *and*
committable under the Qww/Qwr watermark rule — the scheduler never acks a
transaction itself; it observes ``txn.committed``, which only
:meth:`repro.core.commit.CommitProtocol.drain` (or the cross-shard sweep,
which applies the same ``committable()`` predicate per participant) can
set.  Ack = durable ∧ committable, end to end.

Batch cutting is **strict-FIFO and conflict-free**: a cut is the longest
queue prefix in which no two transactions touch a common key, stopped at
the first conflicting transaction (head-of-line) or at ``max_batch``.
Two consequences:

* within a cut every transaction wins validation round 1 (no intra-batch
  first-come-wins losses), so a group-commit round never silently reorders
  admitted work — commit order *is* admission order, per key and globally;
* the device logs are therefore *invariant under cut points*: for a
  conflict-free arrival schedule, any cut sequence produces byte-identical
  logs to one direct ``execute_batch`` of the same transactions, and for
  arbitrary schedules any two cut configurations produce byte-identical
  logs to each other.  The property tests pin both.

Two operating modes, mirroring the engine:

* **stepped** — :meth:`GroupCommitScheduler.step` advances one deterministic
  iteration: retry re-admission → batch cut → execute → flush (``tick``,
  optionally a chosen device subset) → drain → ack release.  No real
  clocks; time is the step counter.  Every scheduler decision is
  unit-testable and interleavings are reproducible.
* **threaded** — :meth:`start` runs the same loop against real clocks (the
  backend's logger threads flush on the group-commit timer; the scheduler
  loop cuts, drains, and releases acks).  Clients block on
  :meth:`Ticket.wait`.

Admission control is lossless-or-explicit: ``submit`` either admits (the
transaction is then *guaranteed* to terminate in ``ACKED`` or ``ABORTED``)
or returns ``REJECTED`` immediately — an explicit retry-later signal.
Saturation can never silently drop an admitted request: validation losers
re-enter the queue *ahead of* new admissions and exempt from the capacity
bound (re-admitting them through the bounded queue would drop them exactly
when the system is overloaded — the failure mode the overflow test pins).
"""

from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional, Sequence, Tuple, Union

from ..db.batch import TxnSpec
from ..obs.metrics import REGISTRY
from ..trace.span import ST_ACK, ST_CUT, TRACER

# ticket lifecycle ----------------------------------------------------------
QUEUED = "queued"          # admitted, waiting for a batch cut
INFLIGHT = "inflight"      # executed (pre-committed), awaiting durable ack
RETRY_WAIT = "retry_wait"  # lost validation, backing off before re-queue
ACKED = "acked"            # durably committed, ack released to the client
ABORTED = "aborted"        # explicit abort after exhausting retries
REJECTED = "rejected"      # admission refused (queue full) — never queued

_TERMINAL = (ACKED, ABORTED, REJECTED)


@dataclass
class ServeConfig:
    """Scheduler knobs.  Step-denominated fields drive stepped mode,
    second-denominated ones threaded mode; both encode the same policy."""

    max_batch: int = 256              # cut size bound
    latency_budget_steps: int = 1     # stepped: cut when head has waited this
    latency_budget_s: float = 2e-3    # threaded: group-commit window
    queue_capacity: int = 4096        # admission bound (retries exempt)
    max_unacked: Optional[int] = None  # backpressure: stall cuts above this
    max_retries: int = 3              # attempts = 1 + max_retries
    backoff_steps: int = 1            # stepped retry backoff base (doubles)
    backoff_s: float = 5e-4           # threaded retry backoff base (doubles)
    max_rounds: int = 1               # rounds inside execute_batch (cuts are
    #                                   conflict-free, so 1 is exact)
    poll_s: float = 1e-4              # threaded loop idle poll


@dataclass
class Ticket:
    """One client transaction's journey through the serving tier."""

    client_id: int
    spec_fn: Callable[[], TxnSpec]   # regenerated per attempt (fresh reads)
    status: str = QUEUED
    spec: Optional[TxnSpec] = None   # the current attempt's materialized spec
    worker_id: int = -1              # assigned at admission, stable across retries
    attempts: int = 0
    txn: object = None               # Txn or XTxn once executed
    ssn: int = -1
    ack_seq: int = -1                # global ack order (release sequence)
    # timestamps: steps in stepped mode, perf_counter seconds in threaded
    t_submit: float = 0.0
    t_ack: float = 0.0
    _backoff_until: float = 0.0
    _event: Optional[threading.Event] = None

    @property
    def done(self) -> bool:
        return self.status in _TERMINAL

    def wait(self, timeout: Optional[float] = None) -> str:
        """Block until the ticket reaches a terminal status (threaded mode)."""
        if self._event is not None and not self.done:
            self._event.wait(timeout)
        return self.status

    def latency(self) -> float:
        """Commit latency: submission → ack release (steps or seconds)."""
        return self.t_ack - self.t_submit


def _keys_of(spec: TxnSpec) -> List[str]:
    return list(spec.reads) + [k for k, _ in spec.writes]


class GroupCommitScheduler:
    """Coalesces single-transaction submissions into group-commit batches.

    ``backend`` is a :class:`~repro.serve.backend.SingleBackend` or
    :class:`~repro.serve.backend.ShardedBackend`.  Construct, then either
    drive :meth:`step` deterministically or :meth:`start` the threaded loop.
    """

    def __init__(self, backend, cfg: Optional[ServeConfig] = None):
        self.backend = backend
        self.cfg = cfg or ServeConfig()
        self._lock = threading.Lock()
        self._queue: Deque[Ticket] = deque()
        self._n_admitted_queue = 0   # admission-counted entries (≤ capacity)
        self._inflight: List[Ticket] = []
        self._waiting: List[Ticket] = []   # backoff room
        self._admit_seq = 0          # round-robin worker assignment
        self._ack_seq = 0
        self.now_step = 0            # stepped-mode clock
        self._threaded = False
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        # counters / instrumentation
        self.n_submitted = 0
        self.n_admitted = 0
        self.n_rejected = 0
        self.n_acked = 0
        self.n_aborted = 0
        self.n_retries = 0
        self.n_exec_errors = 0
        self.n_cuts = 0
        self.n_cut_txns = 0
        self.queue_samples: List[int] = []
        self._max_queue = 0
        self._max_unacked_seen = 0

    # --- client side --------------------------------------------------------
    def _now(self) -> float:
        return time.perf_counter() if self._threaded else float(self.now_step)

    def submit(
        self,
        spec: Optional[TxnSpec] = None,
        client_id: int = 0,
        make_spec: Optional[Callable[[], TxnSpec]] = None,
    ) -> Ticket:
        """Admit one transaction (or reject it, explicitly and immediately).

        Pass a static ``spec``, or ``make_spec`` for transactions whose spec
        must be regenerated per attempt (read-modify-write: observed SSNs
        and derived values go stale when a retry is needed, so each attempt
        re-reads).  The returned ticket terminates in exactly one of
        ``ACKED`` / ``ABORTED`` / ``REJECTED``.
        """
        assert (spec is None) != (make_spec is None), (
            "pass exactly one of spec / make_spec"
        )
        fn = make_spec if make_spec is not None else (lambda: spec)
        t = Ticket(client_id=client_id, spec_fn=fn)
        if self._threaded:
            t._event = threading.Event()
        with self._lock:
            self.n_submitted += 1
            if self._n_admitted_queue >= self.cfg.queue_capacity:
                t.status = REJECTED
                self.n_rejected += 1
                if REGISTRY.enabled:
                    REGISTRY.count("serve.rejected")
                if t._event is not None:
                    t._event.set()
                return t
            t.spec = t.spec_fn()
            t.attempts = 1
            t.worker_id = self._admit_seq % self.backend.n_workers
            self._admit_seq += 1
            t.t_submit = self._now()
            self._queue.append(t)
            self._n_admitted_queue += 1
            self.n_admitted += 1
            self._max_queue = max(self._max_queue, len(self._queue))
        return t

    # --- scheduler internals ------------------------------------------------
    def _requeue_ready_retries(self, now: float) -> None:
        """Move backoff-expired retries to the *front* of the queue, oldest
        first.  Retries are already admitted: they bypass the capacity bound
        and do not increment the admission count (lossless-or-explicit)."""
        if not self._waiting:
            return
        ready = [t for t in self._waiting if t._backoff_until <= now]
        if not ready:
            return
        self._waiting = [t for t in self._waiting if t._backoff_until > now]
        for t in sorted(ready, key=lambda t: t.t_submit, reverse=True):
            t.status = QUEUED
            self._queue.appendleft(t)

    def _cut_due(self, now: float) -> bool:
        if not self._queue:
            return False
        cap = self.cfg.max_unacked
        if cap is not None and len(self._inflight) >= cap:
            return False  # durability lag backpressure: stall the cutter
        if len(self._queue) >= self.cfg.max_batch:
            return True
        budget = (
            self.cfg.latency_budget_steps
            if not self._threaded
            else self.cfg.latency_budget_s
        )
        head = self._queue[0]
        wait_from = max(head.t_submit, head._backoff_until)
        return now - wait_from >= budget

    def _cut(self) -> List[Ticket]:
        """Longest conflict-free FIFO prefix of the queue, ≤ max_batch.
        Stops at the first transaction sharing any key with the cut so far —
        per-key *and* global commit order equal admission order, which makes
        the log bytes independent of where cuts land."""
        _trace = TRACER.enabled
        if _trace:
            _t0 = time.perf_counter()
        cut: List[Ticket] = []
        claimed: set = set()
        while self._queue and len(cut) < self.cfg.max_batch:
            t = self._queue[0]
            keys = _keys_of(t.spec)
            if any(k in claimed for k in keys):
                break
            claimed.update(keys)
            self._queue.popleft()
            self._n_admitted_queue -= 1
            cut.append(t)
        if _trace and cut:
            TRACER.record(
                ST_CUT, t0=_t0, t1=time.perf_counter(),
                n_txn=len(cut), aux=len(self._queue),
            )
        if REGISTRY.enabled:
            REGISTRY.gauge_set("serve.queue_depth", float(len(self._queue)))
            REGISTRY.count("serve.cut_txns", len(cut))
        return cut

    def _execute(self, cut: List[Ticket], now: float) -> None:
        outcome = self.backend.execute(  # slow path: outside the lock
            [t.spec for t in cut],
            worker_ids=[t.worker_id for t in cut],
            max_rounds=self.cfg.max_rounds,
        )
        with self._lock:
            self.n_cuts += 1
            self.n_cut_txns += len(cut)
            for i, txn in outcome.committed:
                t = cut[i]
                t.txn = txn
                t.ssn = self._ssn_of(txn)
                t.status = INFLIGHT
                self._inflight.append(t)
            self._max_unacked_seen = max(
                self._max_unacked_seen, len(self._inflight)
            )
            for i in outcome.aborted:
                t = cut[i]
                if t.attempts > self.cfg.max_retries:
                    t.status = ABORTED
                    self.n_aborted += 1
                    if REGISTRY.enabled:
                        REGISTRY.count("serve.aborted")
                    if t._event is not None:
                        t._event.set()
                    continue
                # retry with exponential backoff; the spec is regenerated at
                # re-queue time so observed SSNs / derived values are fresh
                self.n_retries += 1
                if REGISTRY.enabled:
                    REGISTRY.count("serve.retries")
                backoff = (
                    self.cfg.backoff_steps
                    if not self._threaded
                    else self.cfg.backoff_s
                ) * (1 << (t.attempts - 1))
                t.attempts += 1
                t.status = RETRY_WAIT
                t._backoff_until = now + backoff
                t.spec = t.spec_fn()
                self._waiting.append(t)

    def _abort_cut(self, cut: List[Ticket]) -> None:
        """Backend execution failed outright (engine error, not a validation
        loss): terminate the cut's still-pending tickets explicitly.  An
        admitted transaction must never be stranded in a non-terminal state —
        an explicit ABORTED is the honest outcome when the executor itself
        fails (lossless-or-explicit, applied to infrastructure faults)."""
        with self._lock:
            self.n_exec_errors += 1
            for t in cut:
                if not t.done and t.status != INFLIGHT:
                    t.status = ABORTED
                    self.n_aborted += 1
                    if t._event is not None:
                        t._event.set()

    @staticmethod
    def _ssn_of(txn) -> int:
        ssn = getattr(txn, "ssn", None)
        if ssn is not None:
            return int(ssn)
        # XTxn: order by the highest participant SSN (its commit point —
        # the last record that must become durable)
        return max(p.ssn for p in txn.parts)

    def _release_acks(self, now: float) -> int:
        """Release every in-flight transaction whose backend drain marked it
        durably committed, in SSN order (within one release round a RAW
        dependency always acks before its dependent — SSNs order them)."""
        _trace = TRACER.enabled
        if _trace:
            _t0 = time.perf_counter()
        ready = [t for t in self._inflight if t.txn.committed]
        if not ready:
            return 0
        ready.sort(key=lambda t: t.ssn)
        self._inflight = [t for t in self._inflight if not t.txn.committed]
        for t in ready:
            t.status = ACKED
            t.t_ack = now
            t.ack_seq = self._ack_seq
            self._ack_seq += 1
            self.n_acked += 1
            if t._event is not None:
                t._event.set()
        if _trace:
            TRACER.record(
                ST_ACK, txn_lo=ready[0].ssn, txn_hi=ready[-1].ssn,
                t0=_t0, t1=time.perf_counter(), n_txn=len(ready),
            )
        if REGISTRY.enabled:
            REGISTRY.count("serve.acked", len(ready))
            # units follow the scheduler clock: steps (stepped) or seconds
            REGISTRY.observe_many("serve.ack_latency",
                                  [t.latency() for t in ready])
        return len(ready)

    # --- stepped mode -------------------------------------------------------
    def step(self, tick_parts: Optional[Sequence[int]] = None) -> int:
        """One deterministic scheduler iteration:

        1. re-queue backoff-expired retries (ahead of new admissions);
        2. cut a batch if due (size, latency budget, backpressure);
        3. execute it (validate → sequence → publish, pre-commit);
        4. flush — one forced logger tick per buffer in ``tick_parts``
           (default: all; tests pass subsets to randomize DSN/CSN order);
        5. drain commit queues (the Qww/Qwr watermark rule runs here);
        6. release acks for durably committed transactions, in SSN order.

        Returns the number of acks released.  Wall clocks are never read;
        ``now_step`` is the clock.
        """
        assert not self._threaded, "step() is for stepped mode"
        self.now_step += 1
        now = float(self.now_step)
        with self._lock:
            self._requeue_ready_retries(now)
            if self._cut_due(now):
                cut = self._cut()
            else:
                cut = []
            self.queue_samples.append(len(self._queue))
        if cut:
            self._execute(cut, now)
        self.backend.tick(tick_parts)
        self.backend.drain()
        with self._lock:
            return self._release_acks(now)

    def run_until_drained(
        self, max_steps: int = 10_000, tick_parts: Optional[Sequence[int]] = None
    ) -> None:
        """Step until no admitted work remains in any room (test harness)."""
        for _ in range(max_steps):
            self.step(tick_parts)
            with self._lock:
                if not (self._queue or self._inflight or self._waiting):
                    return
        raise TimeoutError(
            f"scheduler not drained after {max_steps} steps: "
            f"queue={len(self._queue)} inflight={len(self._inflight)} "
            f"waiting={len(self._waiting)}"
        )

    # --- threaded mode ------------------------------------------------------
    def start(self) -> None:
        """Run threaded: backend logger threads + one scheduler loop thread.
        ``submit`` becomes thread-safe for any number of client threads."""
        self._threaded = True
        self._stop.clear()
        self.backend.start()
        self._thread = threading.Thread(
            target=self._loop, daemon=True, name="serve-scheduler"
        )
        self._thread.start()

    def _loop(self) -> None:
        while not self._stop.is_set():
            now = time.perf_counter()
            with self._lock:
                self._requeue_ready_retries(now)
                cut = self._cut() if self._cut_due(now) else []
                self.queue_samples.append(len(self._queue))
            if cut:
                try:
                    self._execute(cut, time.perf_counter())
                except Exception:
                    # the loop must survive an executor fault: strand no
                    # admitted ticket, keep serving the rest of the queue
                    self._abort_cut(cut)
            self.backend.drain()
            with self._lock:
                released = self._release_acks(time.perf_counter())
            if not cut and not released:
                time.sleep(self.cfg.poll_s)

    def stop(self, quiesce: bool = True, timeout: float = 30.0) -> None:
        """Stop the loop.  With ``quiesce`` the backend flushes and commits
        everything outstanding first and remaining acks are released —
        a clean shutdown.  ``quiesce=False`` models a crash: in-flight
        transactions stay un-acked (crash tests kill the engine right
        after)."""
        if quiesce:
            # let the live loop drain the rooms itself first
            deadline = time.monotonic() + timeout
            while time.monotonic() < deadline:
                with self._lock:
                    idle = not (self._queue or self._inflight or self._waiting)
                if idle:
                    break
                time.sleep(self.cfg.poll_s)
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=10)
            self._thread = None
        if quiesce:
            # loop is dead now: final flush + drain + release race-free
            self.backend.quiesce(timeout=timeout)
            self.backend.drain()
            with self._lock:
                self._release_acks(time.perf_counter())
        self.backend.stop()

    # --- stats --------------------------------------------------------------
    def stats(self) -> Dict:
        with self._lock:
            qs = self.queue_samples
            return {
                "submitted": self.n_submitted,
                "admitted": self.n_admitted,
                "rejected": self.n_rejected,
                "acked": self.n_acked,
                "aborted": self.n_aborted,
                "retries": self.n_retries,
                "exec_errors": self.n_exec_errors,
                "cuts": self.n_cuts,
                "mean_cut": self.n_cut_txns / self.n_cuts if self.n_cuts else 0.0,
                "queue_depth": len(self._queue),
                "max_queue_depth": self._max_queue,
                "mean_queue_depth": sum(qs) / len(qs) if qs else 0.0,
                "max_unacked": self._max_unacked_seen,
                "backend_queue_depths": self.backend.queue_depths(),
                "saturated": self.backend.saturated(),
            }
