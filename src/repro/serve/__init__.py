"""OLTP serving tier: open-loop group commit over the Poplar engines.

* :class:`~repro.serve.scheduler.GroupCommitScheduler` — coalesces
  single-transaction client submissions into batched executor calls under a
  latency budget, with admission control, retry-with-backoff, and acks
  gated on the Qww/Qwr committable() rule (ack = durable ∧ committable).
* :class:`~repro.serve.backend.SingleBackend` /
  :class:`~repro.serve.backend.ShardedBackend` — the executor stacks the
  scheduler drives (BatchOCC vectorized/pallas, ScalarBatchOCC, or a
  ShardedEngine).
* :class:`~repro.serve.driver.OpenLoopDriver` — Poisson open-loop client
  sessions with coordinated-omission-safe latency accounting.

(The LLM token-serving engine formerly here lives in
``repro.models.serve_llm``.)
"""

from .backend import ExecOutcome, ShardedBackend, SingleBackend
from .driver import DriverReport, OpenLoopDriver, run_stepped_schedule
from .scheduler import (
    ABORTED,
    ACKED,
    INFLIGHT,
    QUEUED,
    REJECTED,
    RETRY_WAIT,
    GroupCommitScheduler,
    ServeConfig,
    Ticket,
)

__all__ = [
    "GroupCommitScheduler",
    "ServeConfig",
    "Ticket",
    "SingleBackend",
    "ShardedBackend",
    "ExecOutcome",
    "OpenLoopDriver",
    "DriverReport",
    "run_stepped_schedule",
    "QUEUED",
    "INFLIGHT",
    "RETRY_WAIT",
    "ACKED",
    "ABORTED",
    "REJECTED",
]
