"""Execution backends for the OLTP serving tier.

The :class:`~repro.serve.scheduler.GroupCommitScheduler` is engine-agnostic:
it cuts batches and gates client acks, and delegates execution/durability to
a backend wrapping one of the repo's transaction stacks:

* :class:`SingleBackend` — one Poplar engine + one tuple store + one batch
  executor.  The executor can be the array-native
  :class:`~repro.db.batch.BatchOCC` (``mode='vectorized'`` / ``'pallas'``)
  or the per-txn :class:`~repro.db.batch.ScalarBatchOCC` oracle
  (``mode='scalar'``) — the serving tier runs identically over all three,
  which is what the group-commit equivalence property test pins down.
* :class:`ShardedBackend` — a :class:`~repro.shard.engine.ShardedEngine`;
  single-shard sub-batches run each shard's unchanged fast path and
  cross-shard specs go through the coordinator (their acks release only
  when durable on *every* participant, i.e. when the coordinator's sweep
  marks them committed).

The backend contract mirrors the engine's two operating modes: ``tick()``
flushes deterministically (stepped tests pick which devices flush, to
randomize DSN/CSN interleavings), ``start()``/``stop()`` run the real
logger threads (threaded serving, benchmarks).  ``drain()`` is the *only*
place transactions become durably committed — it applies the paper's
Qww/Qwr watermark rule via :meth:`repro.core.commit.CommitProtocol.drain` —
so the scheduler's "ack once ``txn.committed``" gate is exactly
"ack = durable ∧ committable()".
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple, Union

from ..core.engine import EngineConfig, LoggingEngine, PoplarEngine
from ..core.txn import Txn
from ..db.array_table import ArrayTable
from ..db.batch import BatchOCC, BatchResult, ScalarBatchOCC, TxnSpec
from ..db.table import Table
from ..shard.coordinator import XTxn
from ..shard.engine import ShardedConfig, ShardedEngine


class ExecOutcome:
    """Normalized result of one batch execution.

    ``committed`` pairs each winning spec index with its pre-committed
    transaction object (a :class:`~repro.core.txn.Txn`, or an
    :class:`~repro.shard.coordinator.XTxn` for cross-shard specs) whose
    ``.committed`` flag flips once the backend's drain finds it durable and
    committable; ``aborted`` holds the spec indices that lost validation.
    """

    __slots__ = ("committed", "aborted")

    def __init__(
        self,
        committed: List[Tuple[int, Union[Txn, XTxn]]],
        aborted: List[int],
    ):
        self.committed = committed
        self.aborted = aborted


class SingleBackend:
    """One engine + table + batch executor behind the scheduler.

    Build it from parts (tests often pre-build the stack) or via
    :meth:`make`, which wires the standard combination for a mode.
    """

    def __init__(
        self,
        table: Union[ArrayTable, Table],
        engine: LoggingEngine,
        occ: Union[BatchOCC, ScalarBatchOCC],
    ):
        self.table = table
        self.engine = engine
        self.occ = occ

    @classmethod
    def make(
        cls,
        mode: str = "vectorized",
        n_workers: int = 1,
        cfg: Optional[EngineConfig] = None,
        table_capacity: int = 1024,
    ) -> "SingleBackend":
        engine = PoplarEngine(cfg or EngineConfig())
        if mode == "scalar":
            table: Union[ArrayTable, Table] = Table()
            occ: Union[BatchOCC, ScalarBatchOCC] = ScalarBatchOCC(
                table, engine, n_workers=n_workers
            )
        else:
            table = ArrayTable(capacity=table_capacity)
            occ = BatchOCC(table, engine, n_workers=n_workers, mode=mode)
        return cls(table, engine, occ)

    # --- scheduler contract -------------------------------------------------
    @property
    def n_workers(self) -> int:
        return self.occ.n_workers

    def execute(
        self,
        specs: Sequence[TxnSpec],
        worker_ids: Optional[Sequence[int]] = None,
        max_rounds: int = 1,
    ) -> ExecOutcome:
        r: BatchResult = self.occ.execute_batch(
            specs, worker_ids=worker_ids, max_rounds=max_rounds
        )
        return ExecOutcome(
            committed=list(zip(r.committed_idx, r.committed)),
            aborted=list(r.aborted),
        )

    def tick(self, parts: Optional[Sequence[int]] = None) -> None:
        """Stepped flush: force one logger tick on the given buffers (all by
        default).  ``parts`` indexes buffers — partial ticks let tests hold
        one device's DSN back and exercise the CSN gate."""
        idxs = range(len(self.engine.buffers)) if parts is None else parts
        for i in idxs:
            self.engine.logger_tick(i, force=True)

    def drain(self) -> int:
        return self.occ.drain()

    def start(self) -> None:
        self.engine.start()

    def stop(self) -> None:
        self.engine.stop()

    def quiesce(self, timeout: float = 30.0) -> None:
        self.engine.quiesce(
            [self.occ.worker_id_base + w for w in range(self.occ.n_workers)]
            if isinstance(self.occ, BatchOCC)
            else range(self.occ.n_workers),
            timeout=timeout,
        )

    def queue_depths(self) -> List[int]:
        """Pending (logged, not yet durably committed) txns per commit queue
        — the backend-side component of queue depth reporting."""
        return [q.pending() for q in self.engine.queues.values()]

    def saturated(self) -> bool:
        """Log-device saturation signal: any buffer holds more unflushed
        bytes than one io_unit — the flush pipe is behind the offered load."""
        return any(
            b.pending_bytes() > self.engine.cfg.io_unit
            for b in self.engine.buffers
        )


class ShardedBackend:
    """A :class:`ShardedEngine` behind the scheduler.

    ``worker_ids`` are ignored: each shard's executor assigns its own
    (shard-offset) worker stripes to its sub-batch, which keeps the
    single-shard fast path byte-identical to driving the sharded engine
    directly.
    """

    def __init__(self, eng: ShardedEngine):
        self.eng = eng
        self.table = eng  # duck-typed insert/get/to_dict for loaders

    @classmethod
    def make(cls, n_shards: int = 4, **overrides) -> "ShardedBackend":
        return cls(ShardedEngine(ShardedConfig(n_shards=n_shards, **overrides)))

    @property
    def n_workers(self) -> int:
        return self.eng.cfg.n_shards * self.eng.cfg.n_workers

    def execute(
        self,
        specs: Sequence[TxnSpec],
        worker_ids: Optional[Sequence[int]] = None,
        max_rounds: int = 1,
    ) -> ExecOutcome:
        r = self.eng.execute_batch(specs, max_rounds=max_rounds)
        committed: List[Tuple[int, Union[Txn, XTxn]]] = list(
            zip(r.committed_idx, r.committed)
        )
        committed.extend(zip(r.cross_idx, r.cross))
        return ExecOutcome(committed=committed, aborted=list(r.aborted))

    def tick(self, parts: Optional[Sequence[int]] = None) -> None:
        """Stepped flush; ``parts`` indexes shards (every buffer of each)."""
        if parts is None:
            self.eng.tick(force=True)
            return
        for p in parts:
            sh = self.eng.shards[p]
            for i in range(len(sh.engine.buffers)):
                sh.engine.logger_tick(i, force=True)

    def drain(self) -> int:
        return self.eng.drain()

    def start(self) -> None:
        self.eng.start()

    def stop(self) -> None:
        self.eng.stop()

    def quiesce(self, timeout: float = 30.0) -> None:
        self.eng.quiesce(timeout=timeout)

    def queue_depths(self) -> List[int]:
        """Per-shard pending (logged, not durably committed) txn counts.
        Cross-shard transactions awaiting the durable-on-all sweep are global
        — they count against every participant's depth would double-count, so
        they ride on shard 0's entry."""
        out = [
            sum(q.pending() for q in sh.engine.queues.values())
            for sh in self.eng.shards
        ]
        out[0] += self.eng.coordinator.pending_count()
        return out

    def saturated(self) -> bool:
        return any(
            b.pending_bytes() > sh.engine.cfg.io_unit
            for sh in self.eng.shards
            for b in sh.engine.buffers
        )
