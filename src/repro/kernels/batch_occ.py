"""Segmented reduce kernel (Pallas / TPU) — batched OCC conflict detection.

The batched forward path (paper §4.2/§4.4, `repro.db.batch`) has one hot
array step, used twice per validation round:

* **base-SSN max** (Algorithm 1 lines 1–4, batched): per *transaction*, the
  max tuple SSN over its accesses — a segmented max keyed by txn id;
* **first-writer min** (intra-batch WW/RW conflicts): per *tuple*, the
  smallest batch position among the transactions that write it — a
  segmented min keyed by (compacted) tuple row id.  A transaction survives
  the round iff every tuple it touches has ``first_writer_pos >= its own
  position`` (first-come-wins).

Both are the same primitive: ``out[k] = reduce(val[i] for i where
key[i] == k)``.  This kernel evaluates it with a one-hot
compare-and-reduce, scatter_max style:

* the grid is ``(slot_blocks, item_blocks)`` — slot blocks are independent
  ("parallel"); item blocks accumulate sequentially ("arbitrary") into the
  output, so the slot vector stays resident in VMEM while the item stream
  is blocked through;
* within an item block the per-slot reduction is a masked ``jnp.max`` /
  ``jnp.min`` over the ``(BW, BS)`` one-hot membership matrix (VPU-shaped,
  no serial scatter);
* cross-block the merge is the associative max/min join, so any block
  order is correct.

Sentinels: padded items use ``key = -1`` which matches no slot; empty
slots come back as ``SEG_MAX_INIT`` (-1) for ``op="max"`` and ``NO_WRITER``
(int32 max) for ``op="min"`` — exactly the "no writer in batch" value the
validator wants.  Values are int32 (SSNs are dense counters, positions are
batch indices); the caller falls back to its numpy twin when a batch
exceeds the range, same contract as the recovery kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams

SEG_MAX_INIT = np.int32(-1)
NO_WRITER = np.int32(np.iinfo(np.int32).max)

DEFAULT_BLOCK_S = 128
DEFAULT_BLOCK_W = 128


def _kernel(key_ref, val_ref, out_ref, *, block_s: int, is_min: bool):
    sb = pl.program_id(0)
    ib = pl.program_id(1)
    init = NO_WRITER if is_min else SEG_MAX_INIT

    @pl.when(ib == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref[...], init)

    slots = sb * block_s + jax.lax.broadcasted_iota(jnp.int32, (1, block_s), 1)
    key = key_ref[...].reshape(-1, 1)          # (BW, 1)
    val = val_ref[...].reshape(-1, 1)

    m = key == slots                           # (BW, BS) one-hot membership
    if is_min:
        blk = jnp.min(jnp.where(m, val, NO_WRITER), axis=0, keepdims=True)
        out_ref[...] = jnp.minimum(out_ref[...], blk)
    else:
        blk = jnp.max(jnp.where(m, val, SEG_MAX_INIT), axis=0, keepdims=True)
        out_ref[...] = jnp.maximum(out_ref[...], blk)


def _pad_to(a: jax.Array, n: int, fill) -> jax.Array:
    if a.shape[0] == n:
        return a
    return jnp.concatenate([a, jnp.full((n - a.shape[0],), fill, a.dtype)])


def validate_sequence_xla(
    acc: jax.Array,     # (6, n_txn*k) int32: row, pos, iswrite, obs, ssn_now, locked
    a_len: jax.Array,   # (n_txn,) int32 true access count per txn (0 = padding)
    n_txn: int,         # txn bucket (rows of the dense layout)
    k: int,             # access bucket (lanes per txn)
    cap: int,           # row-capacity bucket (first-writer scatter width)
):
    """Fused validate→sequence round for the batched OCC executor
    (`repro.db.batch.BatchOCC`, ``mode="pallas"``), compiled on any backend.

    The batch arrives as ONE stacked int32 transfer in a dense bucket-padded
    ``(n_txn, k)`` layout — every transaction's accesses padded to ``k``
    lanes — so the two segmented reductions of the numpy path (per-txn
    survive-AND and base-SSN max) become plain masked reshape-reduces, and
    the only scatter left is the per-row first-writer min.  Lanes beyond a
    transaction's true access count (``a_len``) are masked: they pass
    validation vacuously, contribute ``0`` to the base-SSN max, and scatter
    the min-identity ``NO_WRITER`` so they can never claim a first-writer
    slot.  Returns ``(survive, bases)``, both ``(n_txn,)``; entries past the
    true transaction count are vacuous (``a_len = 0``).
    """
    row, pos, iswrite, obs, ssn_now, locked = (acc[i] for i in range(6))
    lane = jax.lax.broadcasted_iota(jnp.int32, (n_txn, k), 1)
    valid = (lane < a_len.reshape(n_txn, 1)).reshape(-1)

    w_pos = jnp.where((iswrite != 0) & valid, pos, NO_WRITER)
    fw = jnp.full(cap, NO_WRITER, jnp.int32).at[row].min(
        w_pos, mode="promise_in_bounds"
    )[row]
    ok = (fw >= pos) & ((obs < 0) | (ssn_now == obs)) & (locked == 0)
    survive = (ok | ~valid).reshape(n_txn, k).all(axis=1)
    bases = jnp.where(valid, ssn_now, 0).reshape(n_txn, k).max(axis=1)
    return survive, bases


def seg_reduce(
    key_id: jax.Array,   # (W,) int32 slot id per item (>= 0)
    val: jax.Array,      # (W,) int32 value per item
    n_slots: int,
    *,
    op: str = "max",
    block_s: int = DEFAULT_BLOCK_S,
    block_w: int = DEFAULT_BLOCK_W,
    interpret: bool = False,
) -> jax.Array:
    """Segmented ``max``/``min`` of ``val`` grouped by ``key_id`` into
    ``n_slots`` dense slots.  Slots with no member come back as
    ``SEG_MAX_INIT`` (max) / ``NO_WRITER`` (min)."""
    assert op in ("max", "min"), op
    is_min = op == "min"
    init = NO_WRITER if is_min else SEG_MAX_INIT
    w = key_id.shape[0]
    if n_slots == 0:
        return jnp.empty(0, jnp.int32)
    if w == 0:
        return jnp.full(n_slots, init, jnp.int32)
    sp = -(-n_slots // block_s) * block_s
    wp = -(-w // block_w) * block_w

    key = _pad_to(key_id.astype(jnp.int32), wp, -1).reshape(1, wp)
    val_p = _pad_to(val.astype(jnp.int32), wp, init).reshape(1, wp)

    grid = (sp // block_s, wp // block_w)
    slot_spec = pl.BlockSpec((1, block_s), lambda i, j: (0, i))
    item_spec = pl.BlockSpec((1, block_w), lambda i, j: (0, j))

    out = pl.pallas_call(
        functools.partial(_kernel, block_s=block_s, is_min=is_min),
        grid=grid,
        in_specs=[item_spec, item_spec],
        out_specs=slot_spec,
        out_shape=jax.ShapeDtypeStruct((1, sp), jnp.int32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(key, val_p)
    return out[0, :n_slots]
