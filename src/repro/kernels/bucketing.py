"""Bucket padding for the compiled OLTP hot path.

jit/Pallas specialize on array *shapes*: feeding every batch's exact record
or access count produces one compilation per distinct size and the "compiled"
path spends its time tracing.  All fused OLTP entry points therefore take
their inputs padded up to a power-of-two **bucket**, so a stream of
arbitrary-size batches touches at most ``log2(max_size)`` distinct shapes —
the *bucket ladder* — and every shape after the first few is a cache hit.

Padding is only sound if the padded lanes can never influence a result.
Each fused op routes its pad lanes to a dedicated overflow slot and/or fills
them with the identity of the reduction they feed (``-1`` for a max over
non-negative values, ``NO_POS``/``NO_WRITER`` for a min, "valid=False" for a
segmented all): see ``kernels/scatter_max.py`` / ``kernels/batch_occ.py``
for the per-op conventions, and ``tests/test_bucketing.py`` for the
non-interference property tests.

This module also owns the **guarded int32 downcast**: device arrays are
int32 (the container runs with jax x64 disabled, where int64 inputs would
silently truncate), so every caller must either prove its values fit or
fall back to the numpy path.  ``fits_i32`` is the decision, ``checked_i32``
the enforcing cast — silent ``.astype(np.int32)`` narrowing is a bug.
"""

from __future__ import annotations

from typing import Iterable, List, Sequence

import numpy as np

I32_MAX = np.iinfo(np.int32).max
I32_MIN = np.iinfo(np.int32).min


def bucket(n: int, min_size: int = 8) -> int:
    """Smallest power of two ≥ ``max(n, min_size)``."""
    return 1 << (max(int(n), min_size, 1) - 1).bit_length()


def ladder(max_n: int, min_size: int = 8) -> List[int]:
    """Every bucket a stream of sizes ``1..max_n`` can map to — the upper
    bound on jit cache entries per fused op (the compile-count contract
    asserted in ``tests/test_bucketing.py``)."""
    out = [bucket(min_size, min_size)]
    while out[-1] < bucket(max_n, min_size):
        out.append(out[-1] * 2)
    return out


def fits_i32(*arrays: np.ndarray) -> bool:
    """True iff every value of every array is representable as int32 —
    the precondition for the compiled (device) path.  Empty arrays fit."""
    for a in arrays:
        if a.size and (int(a.max()) > I32_MAX or int(a.min()) < I32_MIN):
            return False
    return True


def checked_i32(a: np.ndarray, what: str = "array") -> np.ndarray:
    """Downcast to int32, raising ``OverflowError`` on any value outside the
    int32 range instead of silently wrapping (callers that can fall back
    should test :func:`fits_i32` first; this is the last line of defence)."""
    if not fits_i32(a):
        raise OverflowError(
            f"{what} exceeds int32 range (max {int(a.max())}); "
            "the compiled kernel path requires a guarded numpy fallback"
        )
    return a.astype(np.int32, copy=False)


def pad_i32(a: np.ndarray, n: int, fill: int, what: str = "array") -> np.ndarray:
    """``a`` checked-downcast to int32 and right-padded to length ``n`` with
    the reduction-identity ``fill``."""
    out = np.full(n, fill, dtype=np.int32)
    out[: len(a)] = checked_i32(np.asarray(a), what)
    return out


def stack_i32(
    cols: Sequence[np.ndarray], n: int, fills: Sequence[int]
) -> np.ndarray:
    """Stack equal-length columns into one ``(len(cols), n)`` int32 matrix,
    padding each with its own identity — the single host→device transfer of
    the fused passes."""
    out = np.empty((len(cols), n), dtype=np.int32)
    for i, (c, f) in enumerate(zip(cols, fills)):
        out[i, : len(c)] = checked_i32(np.asarray(c), f"column {i}")
        out[i, len(c):] = f
    return out


def jit_cache_size(fn) -> int:
    """Number of compiled specializations a ``jax.jit`` function holds
    (0 for plain callables) — the observable the shape-stability tests and
    ``benchmarks/fig_kernels.py`` assert on."""
    getter = getattr(fn, "_cache_size", None)
    return int(getter()) if getter is not None else 0


def total_jit_cache_size(fns: Iterable) -> int:
    return sum(jit_cache_size(f) for f in fns)


def gauge_jit_cache(fns: Iterable, name: str = "kernels.jit_cache_size") -> int:
    """Publish the total compiled-specialization count as a registry gauge
    (and return it).  Sampled, not hooked: compile-cache growth is driven by
    shape churn, so callers gauge it at batch boundaries or register it as a
    snapshot callback:

        REGISTRY.register_callback("kernels.jit_cache_size",
                                   lambda: total_jit_cache_size(fns))
    """
    from ..obs.metrics import REGISTRY

    n = total_jit_cache_size(fns)
    if REGISTRY.enabled:
        REGISTRY.gauge_set(name, float(n))
    return n
