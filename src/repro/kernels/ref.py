"""Pure-jnp/numpy oracles for every Pallas kernel (small-shape exact references)."""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def attention_ref(
    q: jax.Array,      # (B, Hq, S, D)
    k: jax.Array,      # (B, Hkv, T, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
) -> jax.Array:
    b, hq, s, d = q.shape
    _, hkv, t, _ = k.shape
    g = hq // hkv
    kr = jnp.repeat(k, g, axis=1)
    vr = jnp.repeat(v, g, axis=1)
    scores = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32), kr.astype(jnp.float32))
    scores = scores / math.sqrt(d)
    if softcap is not None:
        scores = softcap * jnp.tanh(scores / softcap)
    q_pos = jnp.arange(s)[:, None]
    k_pos = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= q_pos - k_pos < window
    scores = jnp.where(mask[None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", p, vr.astype(jnp.float32)).astype(q.dtype)


def scatter_max_ref(
    image_ssn: np.ndarray,  # (S,) int, -1 = empty slot
    image_pos: np.ndarray,  # (S,) int, -1 = checkpoint value
    key_id: np.ndarray,     # (W,) int
    ssn: np.ndarray,        # (W,) int
    pos: np.ndarray,        # (W,) int
) -> Tuple[np.ndarray, np.ndarray]:
    """Sequential oracle for the SSN-guarded scatter-max: per slot keep the
    max-SSN write, breaking SSN ties toward the smallest replay position
    (the checkpoint image sits at pos -1 and so wins its ties — exactly the
    scalar replay's strict ``ssn > image.ssn`` guard)."""
    out_ssn = np.array(image_ssn, dtype=np.int64)
    out_pos = np.array(image_pos, dtype=np.int64)
    for k, s, p in zip(key_id, ssn, pos):
        if s > out_ssn[k] or (s == out_ssn[k] and p < out_pos[k]):
            out_ssn[k] = s
            out_pos[k] = p
    return out_ssn.astype(image_ssn.dtype), out_pos.astype(image_pos.dtype)


def seg_reduce_ref(
    key_id: np.ndarray,   # (W,) int slot id per item
    val: np.ndarray,      # (W,) int value per item
    n_slots: int,
    op: str = "max",
) -> np.ndarray:
    """Sequential oracle for the batched-OCC segmented reduce: per slot the
    max (or min) value among items with that key; slots with no member stay
    at the identity (-1 for max, int32-max ``NO_WRITER`` for min)."""
    init = np.iinfo(np.int32).max if op == "min" else -1
    out = np.full(n_slots, init, dtype=np.int64)
    for k, v in zip(key_id, val):
        if op == "min":
            if v < out[k]:
                out[k] = v
        elif v > out[k]:
            out[k] = v
    return out.astype(np.int32)


def ssm_scan_ref(
    x: jax.Array,      # (B, H, S, P)   inputs per head
    dt: jax.Array,     # (B, H, S)      softplus'd step sizes
    decay: jax.Array,  # (B, H, S)      exp(-exp(A) dt) in (0, 1)
    bmat: jax.Array,   # (B, S, N)
    cmat: jax.Array,   # (B, S, N)
    h0: Optional[jax.Array] = None,  # (B, H, P, N)
) -> Tuple[jax.Array, jax.Array]:
    """Naive selective-scan: h_t = a_t h + dt_t x_t ⊗ B_t ; y_t = h_t · C_t."""
    b, h, s, p = x.shape
    n = bmat.shape[-1]
    hh = jnp.zeros((b, h, p, n), jnp.float32) if h0 is None else h0.astype(jnp.float32)
    ys = []
    for t in range(s):
        upd = (dt[:, :, t, None] * x[:, :, t].astype(jnp.float32))[..., None] * bmat[:, None, t, None, :].astype(jnp.float32)
        hh = decay[:, :, t, None, None] * hh + upd
        ys.append(jnp.einsum("bhpn,bn->bhp", hh, cmat[:, t].astype(jnp.float32)))
    y = jnp.stack(ys, axis=2)                               # (B, H, S, P)
    return y.astype(x.dtype), hh


def rwkv6_ref(
    r: jax.Array,      # (B, H, S, K)
    k: jax.Array,      # (B, H, S, K)
    v: jax.Array,      # (B, H, S, V)
    w: jax.Array,      # (B, H, S, K)   per-channel decay in (0, 1)
    u: jax.Array,      # (H, K)         bonus
    s0: Optional[jax.Array] = None,  # (B, H, K, V)
) -> Tuple[jax.Array, jax.Array]:
    """Naive wkv6: y_t = r_t (S_{t-1} + u ⊙ k_t^T v_t); S_t = w_t S_{t-1} + k_t^T v_t."""
    b, h, s, kd = r.shape
    vd = v.shape[-1]
    S = jnp.zeros((b, h, kd, vd), jnp.float32) if s0 is None else s0.astype(jnp.float32)
    ys = []
    for t in range(s):
        kv = k[:, :, t].astype(jnp.float32)[..., None] * v[:, :, t].astype(jnp.float32)[..., None, :]
        y = jnp.einsum("bhk,bhkv->bhv", r[:, :, t].astype(jnp.float32),
                       S + u[None, :, :, None] * kv)
        ys.append(y)
        S = w[:, :, t].astype(jnp.float32)[..., None] * S + kv
    return jnp.stack(ys, axis=2).astype(v.dtype), S
