"""Pallas API compatibility shims.

``pltpu.TPUCompilerParams`` was renamed to ``pltpu.CompilerParams`` in newer
JAX releases; resolve whichever this JAX ships so the kernels run on both.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
