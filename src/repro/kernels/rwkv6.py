"""Chunked wkv6 kernel (Pallas / TPU) — RWKV6 "Finch" recurrence.

    y_t = r_t (S_{t-1} + u ⊙ k_t^T v_t);   S_t = diag(w_t) S_{t-1} + k_t^T v_t

TPU adaptation: per-chunk block form with exact in-chunk decay tensors.
With Λ = cumsum(log w) (≤ 0, per k-channel) and Λ̄_t = Λ_t - log w_t
(exclusive cumsum):

    y_state[t]  = (r_t ⊙ exp(Λ̄_t)) · S_prev
    A[t,s]      = Σ_k r_tk k_sk exp(Λ̄_tk - Λ_sk)   (s < t)
    A[t,t]      = Σ_k r_tk u_k k_tk
    y[t]        = y_state[t] + Σ_s A[t,s] v_s
    S_new       = diag(exp(Λ_last)) S_prev + Σ_s (k_s ⊙ exp(Λ_last - Λ_s))^T v_s

All decay exponents are differences of log-cumsums with the *later* index
minus the earlier ⇒ every exponent ≤ 0 ⇒ numerically stable at any chunk
size (no exp overflow — unlike the factored r·exp(Λ) @ (k·exp(-Λ))^T form).
The (C, C, K) in-chunk decay tensor lives in VMEM (chunk 32, K 64 ⇒ 256 KB).

Grid: (B·H, num_chunks), chunk dim sequential with (K, V) state in VMEM.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams

DEFAULT_CHUNK = 32


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, s_out_ref, state_scr,
            *, chunk: int, num_chunks: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    r = r_ref[...].astype(jnp.float32)     # (C, K)
    k = k_ref[...].astype(jnp.float32)     # (C, K)
    v = v_ref[...].astype(jnp.float32)     # (C, V)
    w = w_ref[...].astype(jnp.float32)     # (C, K) in (0, 1)
    u = u_ref[...].astype(jnp.float32)     # (1, K)

    lw = jnp.cumsum(jnp.log(jnp.maximum(w, 1e-30)), axis=0)       # (C, K)
    lw_excl = lw - jnp.log(jnp.maximum(w, 1e-30))                 # (C, K)

    s_prev = state_scr[...]                                        # (K, V)

    # state contribution
    rd = r * jnp.exp(lw_excl)                                      # (C, K)
    y_state = jax.lax.dot_general(rd, s_prev, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)  # (C, V)

    # in-chunk attention matrix A (C, C): strict lower triangle + u diagonal
    rel = lw_excl[:, None, :] - lw[None, :, :]                     # (C, C, K)
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    tri = (t_idx > s_idx)[:, :, None]
    decay = jnp.where(tri, jnp.exp(rel), 0.0)                      # (C, C, K)
    a_lower = jnp.sum(r[:, None, :] * k[None, :, :] * decay, axis=2)
    a_diag = jnp.sum(r * u * k, axis=1)                            # (C,)
    a = a_lower + jnp.where(t_idx == s_idx, a_diag[:, None], 0.0)
    y_intra = jax.lax.dot_general(a, v, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    y_ref[...] = (y_state + y_intra).astype(y_ref.dtype)

    # state update
    lw_last = lw[chunk - 1:chunk, :]                               # (1, K)
    k_scaled = k * jnp.exp(lw_last - lw)                           # (C, K)
    s_new = jnp.exp(lw_last).reshape(-1, 1) * s_prev + jax.lax.dot_general(
        k_scaled, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    state_scr[...] = s_new

    @pl.when(ci == num_chunks - 1)
    def _finish():
        s_out_ref[...] = s_new.astype(s_out_ref.dtype)


def rwkv6_chunked(
    r: jax.Array,      # (B, H, S, K)
    k: jax.Array,      # (B, H, S, K)
    v: jax.Array,      # (B, H, S, V)
    w: jax.Array,      # (B, H, S, K)
    u: jax.Array,      # (H, K)
    *,
    chunk: int = DEFAULT_CHUNK,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (B,H,S,V), final state (B,H,K,V))."""
    b, h, s, kd = r.shape
    vd = v.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    bh = b * h

    rf = r.reshape(bh, s, kd)
    kf = k.reshape(bh, s, kd)
    vf = v.reshape(bh, s, vd)
    wf = w.reshape(bh, s, kd)

    grid = (bh, nc)
    rk_spec = pl.BlockSpec((1, chunk, kd), lambda i, c: (i, c, 0))
    v_spec = pl.BlockSpec((1, chunk, vd), lambda i, c: (i, c, 0))
    u_spec = pl.BlockSpec((1, kd), lambda i, c: (i % h, 0))
    y_spec = pl.BlockSpec((1, chunk, vd), lambda i, c: (i, c, 0))
    st_spec = pl.BlockSpec((1, kd, vd), lambda i, c: (i, 0, 0))

    kernel = functools.partial(_kernel, chunk=chunk, num_chunks=nc)

    def body(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, s_out_ref, state_scr):
        kernel(r_ref.at[0], k_ref.at[0], v_ref.at[0], w_ref.at[0], u_ref,
               y_ref.at[0], s_out_ref.at[0], state_scr)

    y, s_fin = pl.pallas_call(
        body,
        grid=grid,
        in_specs=[rk_spec, rk_spec, v_spec, rk_spec, u_spec],
        out_specs=[y_spec, st_spec],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, vd), v.dtype),
            jax.ShapeDtypeStruct((bh, kd, vd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((kd, vd), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(rf, kf, vf, wf, u)
    return y.reshape(b, h, s, vd), s_fin.reshape(b, h, kd, vd)
