"""Chunked selective-scan kernel (Pallas / TPU) — mamba-style SSM.

TPU adaptation: the recurrence  h_t = a_t h_{t-1} + dt_t·(x_t ⊗ B_t),
y_t = C_t·h_t  is reorganized into the SSD block form so each chunk becomes
MXU matmuls instead of a length-S serial scan:

  within a chunk (all decays a ∈ (0,1), log-cumsums stay ≤ 0 ⇒ stable):
    y_state[t] = exp(Λ_t) · (C_t · S_prev)            Λ = cumsum(log a)
    y_intra[t] = Σ_{s≤t} exp(Λ_t - Λ_s) (C_t·B_s) u_s     u = dt ⊙ x
    S_new      = exp(Λ_last) S_prev + Σ_s exp(Λ_last - Λ_s) u_s ⊗ B_s

Grid: (B·H, num_chunks); the chunk dimension is sequential ("arbitrary")
with the (P, N) state in VMEM scratch.  This removes the O(S) HBM
round-trips of the naive per-step scan (the hymba/rwkv baseline pathology
in EXPERIMENTS §Perf).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams

DEFAULT_CHUNK = 64


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, s_out_ref, state_scr,
            *, chunk: int, num_chunks: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[...].astype(jnp.float32)        # (C, P)
    dt = dt_ref[...].astype(jnp.float32)      # (C, 1)
    a = a_ref[...].astype(jnp.float32)        # (C, 1)
    bm = b_ref[...].astype(jnp.float32)       # (C, N)
    cm = c_ref[...].astype(jnp.float32)       # (C, N)

    la = jnp.cumsum(jnp.log(jnp.maximum(a, 1e-30)), axis=0)   # (C, 1), <= 0
    u = dt * x                                                  # (C, P)

    s_prev = state_scr[...]                                     # (P, N)

    # state contribution: exp(la_t) * (C_t . S_prev)
    cs = jax.lax.dot_general(cm, s_prev, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (C, P)
    y_state = jnp.exp(la) * cs

    # intra-chunk: M[t,s] = exp(la_t - la_s) (C_t . B_s), lower-triangular
    cb = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (C, C)
    rel = la - la.reshape(1, chunk)                               # (C, C) via broadcast
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    m = jnp.where(t_idx >= s_idx, jnp.exp(rel) * cb, 0.0)
    y_intra = jax.lax.dot_general(m, u, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)  # (C, P)

    y_ref[...] = (y_state + y_intra).astype(y_ref.dtype)

    # state update: S_new = exp(la_last) S_prev + sum_s exp(la_last - la_s) u_s ⊗ B_s
    la_last = la[chunk - 1:chunk, :]                              # (1, 1)
    scaled_u = u * jnp.exp(la_last - la)                          # (C, P)
    s_new = jnp.exp(la_last) * s_prev + jax.lax.dot_general(
        scaled_u, bm, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                                             # (P, N)
    state_scr[...] = s_new

    @pl.when(ci == num_chunks - 1)
    def _finish():
        s_out_ref[...] = s_new.astype(s_out_ref.dtype)


def ssm_scan_chunked(
    x: jax.Array,       # (B, H, S, P)
    dt: jax.Array,      # (B, H, S)
    decay: jax.Array,   # (B, H, S)   a_t = exp(-exp(A) dt_t) in (0,1)
    bmat: jax.Array,    # (B, S, N)
    cmat: jax.Array,    # (B, S, N)
    *,
    chunk: int = DEFAULT_CHUNK,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (B,H,S,P), final state (B,H,P,N))."""
    b, h, s, p = x.shape
    n = bmat.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    bh = b * h

    xf = x.reshape(bh, s, p)
    dtf = dt.reshape(bh, s, 1)
    af = decay.reshape(bh, s, 1)

    grid = (bh, nc)
    x_spec = pl.BlockSpec((1, chunk, p), lambda i, c: (i, c, 0))
    s1_spec = pl.BlockSpec((1, chunk, 1), lambda i, c: (i, c, 0))
    bc_spec = pl.BlockSpec((1, chunk, n), lambda i, c: (i // h, c, 0))
    y_spec = pl.BlockSpec((1, chunk, p), lambda i, c: (i, c, 0))
    st_spec = pl.BlockSpec((1, p, n), lambda i, c: (i, 0, 0))

    kernel = functools.partial(_kernel, chunk=chunk, num_chunks=nc)

    def body(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, s_out_ref, state_scr):
        kernel(x_ref.at[0], dt_ref.at[0], a_ref.at[0], b_ref.at[0], c_ref.at[0],
               y_ref.at[0], s_out_ref.at[0], state_scr)

    y, s_fin = pl.pallas_call(
        body,
        grid=grid,
        in_specs=[x_spec, s1_spec, s1_spec, bc_spec, bc_spec],
        out_specs=[y_spec, st_spec],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, p), x.dtype),
            jax.ShapeDtypeStruct((bh, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(xf, dtf, af, bmat, cmat)
    return y.reshape(b, h, s, p), s_fin.reshape(b, h, p, n)
