"""Flash attention forward kernel (Pallas / TPU).

TPU adaptation of the FlashAttention insight (online softmax, O(S) memory):
instead of CUDA shared-memory staging, tiling is expressed as BlockSpecs —
each grid step pipelines one (block_q x d) query tile and one (block_k x d)
KV tile HBM→VMEM; softmax statistics (m, l) and the output accumulator live
in VMEM scratch across the sequential kv grid dimension.  Block shapes are
MXU-aligned (multiples of 128 on the contraction/lane dims).

Grid: (batch, q_heads, num_q_blocks, num_kv_blocks) with the kv dimension
innermost & sequential ("arbitrary"), accumulating into scratch; the output
tile is written on the last kv step.  GQA is handled in the k/v index_maps
(kv_head = q_head * n_kv // n_q).  Causal/sliding-window masking is applied
in-kernel; fully-masked kv blocks are skipped with ``pl.when`` (the compute
saving the `triangular` jnp path gets by construction).

Numerics: fp32 accumulation regardless of input dtype (MXU native).
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: Optional[int],
            block_q: int, block_k: int, num_kv_blocks: int,
            softcap: Optional[float]):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k

    # block-level skip: entire kv block after the causal frontier, or entirely
    # before the sliding window
    run = jnp.asarray(True)
    if causal:
        run = jnp.logical_and(run, k_start <= q_start + block_q - 1)
    if window is not None:
        run = jnp.logical_and(run, k_start + block_k - 1 >= q_start - window + 1)

    @pl.when(run)
    def _body():
        q = q_ref[...].astype(jnp.float32) * scale          # (bq, d)
        k = k_ref[...].astype(jnp.float32)                  # (bk, d)
        v = v_ref[...].astype(jnp.float32)                  # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )                                                   # (bq, bk)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, q_pos >= k_pos)
        if window is not None:
            mask = jnp.logical_and(mask, q_pos - k_pos < window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                                 # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                              # (bq, bk)
        l_new = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        acc_scr[...] = acc_scr[...] * alpha + pv
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ki == num_kv_blocks - 1)
    def _finish():
        l = l_scr[...]
        o_ref[...] = (acc_scr[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def flash_attention_fwd(
    q: jax.Array,      # (B, Hq, S, D)
    k: jax.Array,      # (B, Hkv, T, D)
    v: jax.Array,      # (B, Hkv, T, D)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> jax.Array:
    b, hq, s, d = q.shape
    _, hkv, t, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    assert s % block_q == 0 and t % block_k == 0, (s, t, block_q, block_k)
    nq, nk = s // block_q, t // block_k
    scale = 1.0 / math.sqrt(d)

    grid = (b, hq, nq, nk)

    q_spec = pl.BlockSpec((1, 1, block_q, d), lambda bi, hi, qi, ki: (bi, hi, qi, 0))
    kv_spec = pl.BlockSpec(
        (1, 1, block_k, d), lambda bi, hi, qi, ki: (bi, hi * hkv // hq, ki, 0)
    )
    o_spec = pl.BlockSpec((1, 1, block_q, d), lambda bi, hi, qi, ki: (bi, hi, qi, 0))

    kernel = functools.partial(
        _kernel,
        scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, num_kv_blocks=nk, softcap=softcap,
    )

    # wrap refs to drop the leading singleton block dims inside the kernel
    def body(q_ref, k_ref, v_ref, o_ref, m, l, acc):
        kernel(q_ref.at[0, 0], k_ref.at[0, 0], v_ref.at[0, 0], o_ref.at[0, 0], m, l, acc)

    return pl.pallas_call(
        body,
        grid=grid,
        in_specs=[q_spec, kv_spec, kv_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((b, hq, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),    # m: running max
            pltpu.VMEM((block_q, 1), jnp.float32),    # l: running denominator
            pltpu.VMEM((block_q, d), jnp.float32),    # acc: output accumulator
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
