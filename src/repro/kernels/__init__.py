# Pallas kernels for the compute hot-spots, each with an exact oracle in
# ref.py and a jit'd public wrapper in ops.py (interpret mode off-TPU):
#   flash_attention.py  — attention (models layer)
#   ssm_scan.py         — chunked selective scan (models layer)
#   rwkv6.py            — chunked wkv6 (models layer)
#   scatter_max.py      — SSN-guarded scatter-max (recovery §5 batch replay)
#   batch_occ.py        — segmented max/min reduce (batched OCC §4.2/§4.4)
