"""SSN-guarded scatter-max kernel (Pallas / TPU) — batched log replay.

Recovery's inner loop (paper §5) is, per log write ``(key, value, ssn)``::

    if ssn > image[key].ssn: image[key] = (value, ssn)

i.e. a scatter-max over SSNs with the *argmax payload* (which write won)
carried along — the Thomas write rule that makes Poplar's replay order-free.
This kernel applies a whole batch of writes against the recovered image in
one pass:

* slots are the dense key ids of the recovered image (built host-side from
  the checkpoint ∪ log key vocabulary);
* the grid is ``(slot_blocks, write_blocks)`` — slot blocks are independent
  ("parallel"); write blocks accumulate sequentially ("arbitrary") into the
  output, flash-attention style, so the image stays resident in VMEM while
  the write stream is blocked through;
* within a write block the winner per slot is found with a one-hot
  compare-and-reduce (VPU-shaped, no serial scatter): ``blk_ssn`` is the
  block's max SSN per slot and ``blk_pos`` the *earliest* log position among
  that max — ties between equal SSNs resolve to the first write in replay
  order, matching the scalar oracle's strict ``>`` guard;
* cross-block (and vs. the checkpoint image) the merge is the associative
  ``(max ssn, then min pos)`` lattice join, so any block order is correct.

Sentinels: a slot with no value has ``ssn = -1`` and ``pos = NO_POS``; a
checkpoint-provided slot has ``pos = -1`` (smaller than every log position,
so the checkpoint wins SSN ties exactly like the scalar guard). Padded
writes use ``key = -1`` which matches no slot.

``ssn`` / ``pos`` are int32: the engine's SSNs are dense counters (one per
logged record), so 2^31 records per recovery batch is far beyond any log
this replays; the caller (``recovery.replay_columnar``) checks the range and
falls back to its equivalent numpy reduction when a batch exceeds it.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams

NO_POS = np.int32(np.iinfo(np.int32).max)

DEFAULT_BLOCK_S = 128
DEFAULT_BLOCK_W = 128


def _kernel(img_ssn_ref, img_pos_ref, key_ref, ssn_ref, pos_ref,
            out_ssn_ref, out_pos_ref, *, block_s: int):
    sb = pl.program_id(0)
    wb = pl.program_id(1)

    @pl.when(wb == 0)
    def _init():
        out_ssn_ref[...] = img_ssn_ref[...]
        out_pos_ref[...] = img_pos_ref[...]

    slots = sb * block_s + jax.lax.broadcasted_iota(jnp.int32, (1, block_s), 1)
    key = key_ref[...].reshape(-1, 1)          # (BW, 1)
    ssn = ssn_ref[...].reshape(-1, 1)
    pos = pos_ref[...].reshape(-1, 1)

    m = key == slots                           # (BW, BS) one-hot membership
    blk_ssn = jnp.max(jnp.where(m, ssn, -1), axis=0, keepdims=True)   # (1, BS)
    blk_pos = jnp.min(
        jnp.where(m & (ssn == blk_ssn), pos, NO_POS), axis=0, keepdims=True
    )

    run_ssn = out_ssn_ref[...]
    run_pos = out_pos_ref[...]
    better = blk_ssn > run_ssn
    tie = blk_ssn == run_ssn
    out_ssn_ref[...] = jnp.where(better, blk_ssn, run_ssn)
    out_pos_ref[...] = jnp.where(
        better, blk_pos, jnp.where(tie, jnp.minimum(run_pos, blk_pos), run_pos)
    )


def _pad_to(a: jax.Array, n: int, fill) -> jax.Array:
    if a.shape[0] == n:
        return a
    return jnp.concatenate([a, jnp.full((n - a.shape[0],), fill, a.dtype)])


def ssn_scatter_max_xla(
    image_ssn: jax.Array,   # (S,) int32, -1 = empty slot
    image_pos: jax.Array,   # (S,) int32, -1 = checkpoint value, NO_POS = empty
    key_id: jax.Array,      # (W,) int32 slot id per write; id == S is ignored
    ssn: jax.Array,         # (W,) int32 SSN per write (-1 for padded lanes)
    pos: jax.Array,         # (W,) int32 replay position (NO_POS for padding)
    n_slots: int,
):
    """Compiled twin of :func:`ssn_scatter_max` for backends without a
    Pallas lowering (CPU/GPU): the same ``(max ssn, then min pos)`` merge
    lattice expressed as two native XLA scatters instead of the one-hot
    grid, so ``mode="pallas"`` compiles everywhere.

    Scatters accept ids in ``[0, n_slots]`` — the extra slot ``n_slots`` is
    the overflow lane bucket padding routes to (its result is dropped), so
    padded lanes need no branch.  Padded ``ssn = -1`` loses every max
    against real SSNs (≥ 0) and the image init, and padded ``pos = NO_POS``
    loses every min, so padding cannot win a slot (property-tested in
    ``tests/test_bucketing.py``).
    """
    ext_ssn = jnp.concatenate([image_ssn, jnp.full((1,), -1, jnp.int32)])
    ext_pos = jnp.concatenate([image_pos, jnp.full((1,), NO_POS, jnp.int32)])
    out_ssn = ext_ssn.at[key_id].max(ssn, mode="promise_in_bounds")
    cand = ssn == out_ssn[key_id]
    cpos = jnp.where(cand, pos, NO_POS)
    keep = image_ssn == out_ssn[:n_slots]       # image still (co-)maximal?
    base = jnp.concatenate(
        [jnp.where(keep, image_pos, NO_POS), jnp.full((1,), NO_POS, jnp.int32)]
    )
    out_pos = base.at[key_id].min(cpos, mode="promise_in_bounds")
    return out_ssn[:n_slots], out_pos[:n_slots]


def ssn_scatter_max(
    image_ssn: jax.Array,   # (S,) int32, -1 = empty slot
    image_pos: jax.Array,   # (S,) int32, -1 = checkpoint value, NO_POS = empty
    key_id: jax.Array,      # (W,) int32 dense key id per write
    ssn: jax.Array,         # (W,) int32 SSN per write (>= 0)
    pos: jax.Array,         # (W,) int32 replay position per write (>= 0)
    *,
    block_s: int = DEFAULT_BLOCK_S,
    block_w: int = DEFAULT_BLOCK_W,
    interpret: bool = False,
):
    """Apply a batch of SSN-guarded writes; returns ``(new_ssn, new_pos)``,
    both (S,): the winning SSN per slot and the position of the winning
    write (-1 if the checkpoint value stands, NO_POS if the slot is empty).
    """
    s = image_ssn.shape[0]
    w = key_id.shape[0]
    if s == 0 or w == 0:
        return image_ssn, image_pos
    sp = -(-s // block_s) * block_s
    wp = -(-w // block_w) * block_w

    img_ssn = _pad_to(image_ssn.astype(jnp.int32), sp, -1).reshape(1, sp)
    img_pos = _pad_to(image_pos.astype(jnp.int32), sp, NO_POS).reshape(1, sp)
    key = _pad_to(key_id.astype(jnp.int32), wp, -1).reshape(1, wp)
    ssn_p = _pad_to(ssn.astype(jnp.int32), wp, -1).reshape(1, wp)
    pos_p = _pad_to(pos.astype(jnp.int32), wp, NO_POS).reshape(1, wp)

    grid = (sp // block_s, wp // block_w)
    slot_spec = pl.BlockSpec((1, block_s), lambda i, j: (0, i))
    write_spec = pl.BlockSpec((1, block_w), lambda i, j: (0, j))

    out_ssn, out_pos = pl.pallas_call(
        functools.partial(_kernel, block_s=block_s),
        grid=grid,
        in_specs=[slot_spec, slot_spec, write_spec, write_spec, write_spec],
        out_specs=[slot_spec, slot_spec],
        out_shape=[
            jax.ShapeDtypeStruct((1, sp), jnp.int32),
            jax.ShapeDtypeStruct((1, sp), jnp.int32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(img_ssn, img_pos, key, ssn_p, pos_p)
    return out_ssn[0, :s], out_pos[0, :s]
