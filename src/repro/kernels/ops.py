"""jit'd public wrappers for the Pallas kernels.

On TPU the kernels run compiled (interpret=False); on CPU (this container)
they execute in interpret mode — same kernel body, Python-evaluated — so
correctness is CI-testable without hardware.  ``interpret=None`` selects
automatically from the default backend.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .batch_occ import seg_reduce as _seg_reduce_raw
from .flash_attention import flash_attention_fwd
from .rwkv6 import rwkv6_chunked
from .scatter_max import ssn_scatter_max as _ssn_scatter_max_raw
from .ssm_scan import ssm_scan_chunked


def _auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: Optional[int] = None,
                    softcap: Optional[float] = None, block_q: int = 128,
                    block_k: int = 128, interpret: Optional[bool] = None):
    """q (B,Hq,S,D); k/v (B,Hkv,T,D) -> (B,Hq,S,D)."""
    return flash_attention_fwd(
        q, k, v, causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_k=block_k, interpret=_auto_interpret(interpret),
    )


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssm_scan(x, dt, decay, bmat, cmat, *, chunk: int = 64,
             interpret: Optional[bool] = None):
    """Chunked selective scan: returns (y, final_state)."""
    return ssm_scan_chunked(
        x, dt, decay, bmat, cmat, chunk=chunk, interpret=_auto_interpret(interpret)
    )


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6(r, k, v, w, u, *, chunk: int = 32, interpret: Optional[bool] = None):
    """Chunked wkv6: returns (y, final_state)."""
    return rwkv6_chunked(r, k, v, w, u, chunk=chunk, interpret=_auto_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("block_s", "block_w", "interpret"))
def ssn_scatter_max(image_ssn, image_pos, key_id, ssn, pos, *,
                    block_s: int = 128, block_w: int = 128,
                    interpret: Optional[bool] = None):
    """SSN-guarded scatter-max batch replay (recovery §5):
    returns (winning ssn per slot, winning write position per slot)."""
    return _ssn_scatter_max_raw(
        image_ssn, image_pos, key_id, ssn, pos,
        block_s=block_s, block_w=block_w, interpret=_auto_interpret(interpret),
    )


@functools.partial(jax.jit, static_argnames=("n_slots", "op", "block_s",
                                             "block_w", "interpret"))
def occ_seg_reduce(key_id, val, *, n_slots: int, op: str = "max",
                   block_s: int = 128, block_w: int = 128,
                   interpret: Optional[bool] = None):
    """Segmented max/min for the batched OCC validator (§4.2/§4.4): per-txn
    base-SSN max (``op="max"`` keyed by txn id) and per-tuple first-writer
    position (``op="min"`` keyed by compacted row id)."""
    return _seg_reduce_raw(
        key_id, val, n_slots, op=op,
        block_s=block_s, block_w=block_w, interpret=_auto_interpret(interpret),
    )
