"""jit'd public wrappers for the Pallas kernels.

On TPU the Pallas kernels run compiled (interpret=False); on CPU (this
container) they execute in interpret mode — same kernel body,
Python-evaluated — so correctness is CI-testable without hardware.
``interpret=None`` selects automatically from the default backend; the
probe result is cached once per process and ``REPRO_FORCE_INTERPRET=1``
overrides it so CI can exercise the interpret path deterministically.

The OLTP hot paths don't stop at interpret mode on CPU: the fused entry
points below (:func:`fused_replay_scan`, :func:`fused_validate_sequence`)
route to *compiled* XLA twins of the kernel bodies
(``scatter_max.ssn_scatter_max_xla`` / ``batch_occ.validate_sequence_xla``)
wherever the Pallas lowering is unavailable, so ``mode="pallas"`` means
"compiled device path" on every backend.  Their callers pad inputs to the
power-of-two bucket ladder (``kernels/bucketing.py``), keeping the jit
cache bounded; :func:`fused_cache_sizes` exposes the per-op compile counts
that the shape-stability tests and ``benchmarks/fig_kernels.py`` assert on.
"""

from __future__ import annotations

import functools
import os
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .batch_occ import seg_reduce as _seg_reduce_raw
from .batch_occ import validate_sequence_xla as _validate_sequence_xla
from .bucketing import jit_cache_size
from .flash_attention import flash_attention_fwd
from .rwkv6 import rwkv6_chunked
from .scatter_max import ssn_scatter_max as _ssn_scatter_max_raw
from .scatter_max import ssn_scatter_max_xla as _ssn_scatter_max_xla
from .ssm_scan import ssm_scan_chunked


@functools.lru_cache(maxsize=1)
def _default_interpret() -> bool:
    """One-time backend probe: interpret unless a TPU can compile the Pallas
    lowering.  ``REPRO_FORCE_INTERPRET=1`` pins interpret mode regardless
    (read once, at first kernel use — like the probe itself)."""
    if os.environ.get("REPRO_FORCE_INTERPRET", "") not in ("", "0"):
        return True
    return jax.default_backend() != "tpu"


def _auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is not None:
        return interpret
    return _default_interpret()


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: Optional[int] = None,
                    softcap: Optional[float] = None, block_q: int = 128,
                    block_k: int = 128, interpret: Optional[bool] = None):
    """q (B,Hq,S,D); k/v (B,Hkv,T,D) -> (B,Hq,S,D)."""
    return flash_attention_fwd(
        q, k, v, causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_k=block_k, interpret=_auto_interpret(interpret),
    )


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssm_scan(x, dt, decay, bmat, cmat, *, chunk: int = 64,
             interpret: Optional[bool] = None):
    """Chunked selective scan: returns (y, final_state)."""
    return ssm_scan_chunked(
        x, dt, decay, bmat, cmat, chunk=chunk, interpret=_auto_interpret(interpret)
    )


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6(r, k, v, w, u, *, chunk: int = 32, interpret: Optional[bool] = None):
    """Chunked wkv6: returns (y, final_state)."""
    return rwkv6_chunked(r, k, v, w, u, chunk=chunk, interpret=_auto_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("block_s", "block_w", "interpret"))
def ssn_scatter_max(image_ssn, image_pos, key_id, ssn, pos, *,
                    block_s: int = 128, block_w: int = 128,
                    interpret: Optional[bool] = None):
    """SSN-guarded scatter-max batch replay (recovery §5):
    returns (winning ssn per slot, winning write position per slot)."""
    return _ssn_scatter_max_raw(
        image_ssn, image_pos, key_id, ssn, pos,
        block_s=block_s, block_w=block_w, interpret=_auto_interpret(interpret),
    )


@functools.partial(jax.jit, static_argnames=("n_slots", "op", "block_s",
                                             "block_w", "interpret"))
def occ_seg_reduce(key_id, val, *, n_slots: int, op: str = "max",
                   block_s: int = 128, block_w: int = 128,
                   interpret: Optional[bool] = None):
    """Segmented max/min for the batched OCC validator (§4.2/§4.4): per-txn
    base-SSN max (``op="max"`` keyed by txn id) and per-tuple first-writer
    position (``op="min"`` keyed by compacted row id)."""
    return _seg_reduce_raw(
        key_id, val, n_slots, op=op,
        block_s=block_s, block_w=block_w, interpret=_auto_interpret(interpret),
    )


# --- fused OLTP entry points (compiled on every backend) ----------------------

@functools.partial(jax.jit, static_argnames=("n_slots", "use_pallas"))
def fused_replay_scan(scan, *, n_slots: int, use_pallas: bool = False):
    """Fused hash-slot last-writer-wins scan — the device half of the
    compiled replay path (`repro.core.recovery`).

    ``scan`` is one stacked ``(3, N)`` int32 transfer: slot id, SSN, replay
    position per write lane, bucket-padded to ``N`` with the identity lanes
    ``(n_slots, -1, NO_POS)`` (the overflow slot).  Returns the winning
    ``(ssn, pos)`` per slot under the ``(max ssn, then min pos)`` lattice —
    the host resolves slot hash spills exactly afterwards.

    ``use_pallas`` routes through the Pallas one-hot kernel (TPU); the
    default is the XLA scatter twin, which compiles on CPU/GPU.
    """
    slot, ssn, pos = scan[0], scan[1], scan[2]
    image_ssn = jnp.full(n_slots, -1, jnp.int32)
    image_pos = jnp.full(n_slots, jnp.int32(2**31 - 1), jnp.int32)
    if use_pallas:
        return _ssn_scatter_max_raw(
            image_ssn, image_pos, slot, ssn, pos, interpret=False
        )
    return _ssn_scatter_max_xla(image_ssn, image_pos, slot, ssn, pos, n_slots)


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def fused_replay_apply(image, scan, *, use_pallas: bool = False):
    """Like :func:`fused_replay_scan` but against a *preloaded* image — the
    compiled guarded apply of ``replay_columnar``/the replica applier, where
    the checkpoint (or the carried table watermark) seeds the per-slot
    ``(ssn, pos)`` state.  ``image`` is one stacked ``(2, S)`` int32 transfer
    (ssn row, pos row — empty slots ``(-1, NO_POS)``); ``scan`` is the
    ``(3, N)`` lane transfer with padding lanes pointing at the overflow
    slot ``S``.  Both dims arrive bucket-padded, so the jit cache is bounded
    by ladder pairs."""
    if use_pallas:
        return _ssn_scatter_max_raw(
            image[0], image[1], scan[0], scan[1], scan[2], interpret=False
        )
    return _ssn_scatter_max_xla(
        image[0], image[1], scan[0], scan[1], scan[2], image.shape[1]
    )


@functools.partial(jax.jit, static_argnames=("n_txn", "k", "cap"))
def fused_validate_sequence(acc, a_len, *, n_txn: int, k: int, cap: int):
    """Fused validate→sequence pass for ``BatchOCC`` rounds: one stacked
    ``(6, n_txn*k)`` int32 transfer in, ``(survive, bases)`` out — see
    ``batch_occ.validate_sequence_xla`` for the layout and masking rules."""
    return _validate_sequence_xla(acc, a_len, n_txn, k, cap)


def fused_cache_sizes() -> Dict[str, int]:
    """Compiled-specialization counts of the fused OLTP entry points — with
    bucket padding these stay ≤ the bucket-ladder size no matter how many
    distinct batch shapes stream through (asserted in
    ``tests/test_bucketing.py``)."""
    return {
        "fused_replay_scan": jit_cache_size(fused_replay_scan),
        "fused_replay_apply": jit_cache_size(fused_replay_apply),
        "fused_validate_sequence": jit_cache_size(fused_validate_sequence),
        "ssn_scatter_max": jit_cache_size(ssn_scatter_max),
        "occ_seg_reduce": jit_cache_size(occ_seg_reduce),
    }
