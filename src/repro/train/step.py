"""Training step builder: loss + grads + AdamW, with optional microbatch
gradient accumulation (``accum_steps``) and optional int8 error-feedback
gradient compression on the DP all-reduce path.

``make_train_step`` returns a pure function
``step(params, opt_state, batch) -> (params, opt_state, metrics)``
suitable for ``jax.jit`` with in/out shardings (the dry-run path) or direct
execution (smoke tests / quickstart).
"""

from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models.api import Model
from ..optim import adamw
from ..parallel import compression


def make_train_step(
    model: Model,
    opt_cfg: adamw.AdamWConfig,
    accum_steps: int = 1,
    compress_grads: bool = False,
):
    loss_fn = model.train_loss

    def _grads(params, batch):
        return jax.value_and_grad(loss_fn)(params, batch)

    def step(params, opt_state, batch):
        if accum_steps == 1:
            loss, grads = _grads(params, batch)
        else:
            # split every leading-batch leaf into accum_steps microbatches
            def _split(x):
                b = x.shape[0]
                assert b % accum_steps == 0, (b, accum_steps)
                return x.reshape(accum_steps, b // accum_steps, *x.shape[1:])

            micro = jax.tree.map(_split, batch)
            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def body(carry, mb):
                acc, loss_acc = carry
                loss, grads = _grads(params, mb)
                acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), acc, grads)
                return (acc, loss_acc + loss), None

            (gsum, loss_sum), _ = jax.lax.scan(body, (zeros, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda g: (g / accum_steps).astype(jnp.bfloat16), gsum)
            loss = loss_sum / accum_steps

        if compress_grads:
            grads = compression.fake_quantize_tree(grads)

        params, opt_state, metrics = adamw.update(grads, opt_state, params, opt_cfg)
        metrics = {**metrics, "loss": loss}
        return params, opt_state, metrics

    return step
