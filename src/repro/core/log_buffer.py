"""Log buffers with decentralized SSN state (paper §4.1–§4.3).

Each LogBuffer owns:
  * ``ssn``    — SSN of the most recently cached record (Algorithm 1 state);
  * ``offset`` — logical, monotonically increasing allocation cursor;
  * ``dsn``    — durable SSN: largest SSN whose record is persistent;
  * a ring byte array of ``capacity`` bytes;
  * a :class:`~repro.core.segment.SegmentIndex` tracking buffer holes.

``reserve()`` implements the latched portion of Algorithm 1 (lines 6–12)
plus the worker half of Algorithm 2 (segment allocation/establishment).
``fill()`` is the memcpy done outside the latch; it completes the hole.

Workers block in ``reserve()`` when the ring is full (flushed space is
reclaimed by the logger) — this reproduces the paper's observation that
worker threads wait for buffer space once IO saturates (Fig. 8 "Log work").
"""

from __future__ import annotations

import threading
from typing import Optional, Tuple

import numpy as np

from .segment import SegmentIndex, CLOSED
from .storage import StorageDevice


class LogBuffer:
    def __init__(
        self,
        buffer_id: int,
        capacity: int = 30 * 1024 * 1024,
        io_unit: int = 16 * 1024,
        segment_ring: int = 256,
    ):
        self.id = buffer_id
        self.capacity = capacity
        self.io_unit = io_unit
        self.data = bytearray(capacity)

        # Algorithm 1 state
        self.ssn = 0
        self.offset = 0            # logical cursor (never wraps)
        self.dsn = 0

        self.flushed_offset = 0    # logical offset below which space is free
        self.latch = threading.Lock()        # the CAS latch of Algorithm 1
        self.space = threading.Condition(threading.Lock())
        # one logger owns a buffer in the paper; the flush lock makes manual
        # ticks (quiesce, tests) safe against the live logger thread
        self.flush_lock = threading.Lock()
        self.segindex = SegmentIndex(segment_ring)

        # perf counters
        self.reserve_waits = 0     # times a worker waited for space
        self.n_records = 0

    # ------------------------------------------------------------------ ---
    def reserve(
        self,
        base_ssn: int,
        length: int,
        timeout: float = 30.0,
        fixed_ssn: Optional[int] = None,
    ) -> Tuple[int, int, int]:
        """Allocate an SSN and a slot for a record of ``length`` bytes.

        Implements Algorithm 1 lines 6–12 under the buffer latch:
        ``T.ssn = max(base, L.ssn) + 1``;  ``L.ssn = T.ssn``;
        ``FETCH_ADD(L.offset, len)``, plus segment accounting.

        ``fixed_ssn`` (epoch-based engines): use the given sequence number
        verbatim — ``L.ssn = max(fixed_ssn, L.ssn)`` without the +1 — so the
        buffer SSN tracks epochs exactly.

        Returns ``(ssn, logical_offset, segment_index)``.
        """
        if length > self.capacity:
            raise ValueError(f"record of {length}B exceeds buffer capacity")
        while True:
            self._wait_space(length, timeout)
            with self.latch:
                if self.offset + length - self.flushed_offset > self.capacity:
                    continue  # lost the race; re-wait
                if fixed_ssn is not None:
                    ssn = max(fixed_ssn, self.ssn)
                    self.ssn = ssn
                else:
                    ssn = max(base_ssn, self.ssn) + 1
                    self.ssn = ssn
                offset = self.offset
                self.offset += length
                seg_idx = self.segindex.allocate(length)
                self.segindex.try_establish(self.ssn, self.offset, self.io_unit)
                self.n_records += 1
                return ssn, offset, seg_idx

    def _wait_space(self, nbytes: int, timeout: float) -> None:
        """Block until ``nbytes`` could fit (checked outside the latch to
        avoid holding it while blocked; the caller re-checks under the
        latch and re-waits if it lost the race)."""
        with self.space:
            waited = False
            while self.offset + nbytes - self.flushed_offset > self.capacity:
                waited = True
                if not self.space.wait(timeout):
                    raise TimeoutError("log buffer space wait timed out")
            if waited:
                self.reserve_waits += 1

    def reserve_batch(
        self,
        bases: np.ndarray,
        lengths: np.ndarray,
        timeout: float = 30.0,
    ) -> Tuple[np.ndarray, np.ndarray, int]:
        """Batched Algorithm 1: allocate SSNs and slots for a whole batch of
        records under a *single* latch acquisition.

        ``bases`` are the per-record base SSNs (in batch order — the order
        fixes the WAW chain), ``lengths`` the framed record lengths.  The SSN
        recurrence ``s_i = max(base_i, s_{i-1}) + 1`` is evaluated in closed
        form (:func:`repro.core.ssn.chain_ssns`) and the offsets are one
        prefix sum — replacing N ``reserve()`` lock round-trips with one.

        The whole batch is accounted to the generating segment (one bulk
        ``SegmentIndex.allocate``), so the reserved region is contiguous and
        a single :meth:`fill` of the concatenated records completes it.

        Returns ``(ssns, offsets, segment_index)``.
        """
        from .ssn import chain_ssns  # function-level: ssn.py imports this module

        n = len(bases)
        assert n > 0, "empty batch reservation"
        lengths = np.asarray(lengths, dtype=np.int64)
        total = int(lengths.sum())
        if total > self.capacity:
            raise ValueError(
                f"batch of {total}B exceeds buffer capacity {self.capacity}B; "
                "split the batch"
            )
        while True:
            self._wait_space(total, timeout)
            with self.latch:
                if self.offset + total - self.flushed_offset > self.capacity:
                    continue  # lost the race; re-wait
                ssns = chain_ssns(self.ssn, bases)
                offsets = self.offset + np.concatenate(
                    ([0], np.cumsum(lengths[:-1], dtype=np.int64))
                )
                self.ssn = int(ssns[-1])
                self.offset += total
                seg_idx = self.segindex.allocate(total)
                self.segindex.try_establish(self.ssn, self.offset, self.io_unit)
                self.n_records += n
                return ssns, offsets, seg_idx

    def fill(self, offset: int, seg_idx: int, record: bytes) -> None:
        """Copy the encoded record into the ring (outside the latch) and mark
        its bytes buffered, closing the hole."""
        pos = offset % self.capacity
        n = len(record)
        end = pos + n
        if end <= self.capacity:
            self.data[pos:end] = record
        else:
            first = self.capacity - pos
            self.data[pos:] = record[:first]
            self.data[: n - first] = record[first:]
        self.segindex.add_buffered(seg_idx, n)

    # --- logger side -------------------------------------------------------
    def force_establish(self) -> bool:
        """Timer-close the generating segment (logger as segment thread)."""
        with self.latch:
            return self.segindex.force_establish(self.ssn, self.offset)

    def flush_ready(self, device: StorageDevice) -> int:
        """Algorithm 2, AdvancingDSN: flush every ready segment in order,
        advancing the DSN.  Returns the number of segments flushed."""
        flushed = 0
        with self.flush_lock:
            while True:
                seg = self.segindex.flushable()
                if seg is None:
                    break
                start = seg.start_offset % self.capacity
                n = seg.allocated_bytes
                end = start + n
                if end <= self.capacity:
                    chunk = bytes(self.data[start:end])
                else:
                    chunk = bytes(self.data[start:]) + bytes(self.data[: end - self.capacity])
                device.write(chunk)
                self.dsn = seg.ssn
                with self.space:
                    self.flushed_offset += n
                    self.space.notify_all()
                self.segindex.pop_flushed()
                flushed += 1
        return flushed

    def pending_bytes(self) -> int:
        return self.offset - self.flushed_offset
