"""Poplar - recoverable, partially constrained transaction logging (paper core).

Public surface:

* :class:`~repro.core.engine.PoplarEngine` - the paper's contribution (section 4).
* :class:`~repro.core.engine.EngineConfig`, :class:`~repro.core.engine.Worker`
* Baselines (sections 3.3/6.1): :class:`~repro.core.variants.CentrEngine`,
  :class:`~repro.core.variants.SiloEngine`, :class:`~repro.core.variants.NvmDEngine`
* :func:`~repro.core.recovery.recover` - section 5 parallel recovery.
* :class:`~repro.core.checkpoint.CheckpointDaemon` - section 5 fuzzy checkpoints.
* :mod:`~repro.core.levels` - section 3.1 constraint-level checkers.
"""

from .engine import EngineConfig, LoggingEngine, PoplarEngine, Worker
from .variants import CentrEngine, NvmDEngine, SiloEngine
from .recovery import RecoveredState, recover, replay_columnar
from .checkpoint import (
    CheckpointDaemon,
    load_latest_checkpoint,
    load_latest_checkpoint_meta,
)
from .storage import DeviceSpec, StorageDevice, TruncatedLogError, make_devices
from .truncate import FrontierRegistry, LogTruncator, ShardedLogTruncator
from .txn import (
    Txn,
    LogRecord,
    ColumnarLog,
    decode_records,
    decode_columnar,
    decode_columnar_stream,
    encode_batch,
)

__all__ = [
    "EngineConfig",
    "LoggingEngine",
    "PoplarEngine",
    "Worker",
    "CentrEngine",
    "SiloEngine",
    "NvmDEngine",
    "recover",
    "replay_columnar",
    "RecoveredState",
    "CheckpointDaemon",
    "load_latest_checkpoint",
    "load_latest_checkpoint_meta",
    "DeviceSpec",
    "StorageDevice",
    "TruncatedLogError",
    "make_devices",
    "FrontierRegistry",
    "LogTruncator",
    "ShardedLogTruncator",
    "Txn",
    "LogRecord",
    "ColumnarLog",
    "decode_records",
    "decode_columnar",
    "decode_columnar_stream",
    "encode_batch",
]
