"""Deterministic command registry for adaptive (command-framed) logging.

A command-framed log record (``FLAG_COMMAND`` in :mod:`repro.core.txn`)
replaces each write's value payload with an op *parameter*; recovery
re-derives the value by re-executing the registered operator against the
write's pre-image::

    new_value = op.fn(old_value, param)

Determinism is the whole contract: the same ``(old_value, param)`` pair must
produce the same bytes on the forward path (where the executor computed the
value it applied to the table) and on every replay (single-shard recovery,
sharded recovery, replica promote), otherwise command framing breaks the
byte-identity the crash-equivalence tests pin.  Operators therefore must be
pure functions of their two arguments — no clocks, no randomness, no global
state.

``old_value`` is ``None`` when the key has no pre-image (blind insert); ops
that require a pre-image treat ``None`` as their documented identity value
(e.g. zero for the arithmetic ops) so replay of a command whose pre-image
was never durable still terminates deterministically.

The registry is intentionally tiny and append-only: op ids are stable wire
constants (they are serialized into log records), so renumbering or reusing
an id silently corrupts old logs.  The adaptive policy value-frames any
record whose op id is not registered *in the decoding process* — an old log
replayed by a binary missing an op is caught by recovery, which refuses the
record rather than guessing (see ``repro.core.recovery``).
"""

from __future__ import annotations

import struct
from dataclasses import dataclass
from typing import Callable, Dict, Iterator, Optional

_U64LE = struct.Struct("<Q")
_F64LE = struct.Struct("<d")

#: op signature: (pre-image bytes or None, param bytes) -> new value bytes
OpFn = Callable[[Optional[bytes], bytes], bytes]


@dataclass(frozen=True)
class CommandOp:
    """One registered operator: a stable wire id, a debug name, and the
    deterministic apply function."""

    op_id: int
    name: str
    fn: OpFn

    def apply(self, old: Optional[bytes], param: bytes) -> bytes:
        return self.fn(old, param)


class CommandRegistry:
    """Id -> operator table consulted by the adaptive policy (encode side)
    and by every replay path (decode side)."""

    def __init__(self) -> None:
        self._ops: Dict[int, CommandOp] = {}

    def register(self, op_id: int, name: str, fn: OpFn) -> CommandOp:
        if op_id in self._ops:
            raise ValueError(
                f"op id {op_id} already registered as "
                f"{self._ops[op_id].name!r} — ids are stable wire constants"
            )
        op = CommandOp(op_id, name, fn)
        self._ops[op_id] = op
        return op

    def get(self, op_id: int) -> CommandOp:
        return self._ops[op_id]

    def __contains__(self, op_id: int) -> bool:
        return op_id in self._ops

    def __iter__(self) -> Iterator[CommandOp]:
        return iter(self._ops.values())

    def __len__(self) -> int:
        return len(self._ops)


def _op_put(old: Optional[bytes], param: bytes) -> bytes:
    """Blind put: the param *is* the new value (no pre-image dependency).
    Never smaller than value framing — exists for tests and as the identity
    op of the wire format."""
    return param


def _op_add_u64(old: Optional[bytes], param: bytes) -> bytes:
    """u64 little-endian add modulo 2^64 (counter bump; missing or short
    pre-image reads as 0)."""
    base = _U64LE.unpack_from(old)[0] if old and len(old) >= 8 else 0
    (delta,) = _U64LE.unpack_from(param)
    return _U64LE.pack((base + delta) & 0xFFFFFFFFFFFFFFFF) + (
        old[8:] if old else b""
    )


def _op_add_f64(old: Optional[bytes], param: bytes) -> bytes:
    """float64 little-endian add (TPC-C YTD / balance deltas; missing or
    short pre-image reads as 0.0).  Bytes beyond the leading float ride
    along unchanged (the district tuple packs a counter after the float)."""
    base = _F64LE.unpack_from(old)[0] if old and len(old) >= 8 else 0.0
    (delta,) = _F64LE.unpack_from(param)
    return _F64LE.pack(base + delta) + (old[8:] if old else b"")


def _op_patch_prefix(old: Optional[bytes], param: bytes) -> bytes:
    """Overwrite the tuple's leading ``len(param)`` bytes, preserving the
    tail — the field-update shape of YCSB-style RMW over wide tuples, where
    the delta is one column of a 1 KB row.  A missing pre-image degenerates
    to a blind put of the param."""
    if not old:
        return param
    return param + old[len(param):]


#: process-wide registry with the builtin ops.  Ids are wire constants.
COMMANDS = CommandRegistry()
OP_PUT = COMMANDS.register(1, "put", _op_put).op_id
OP_ADD_U64 = COMMANDS.register(2, "add_u64", _op_add_u64).op_id
OP_ADD_F64 = COMMANDS.register(3, "add_f64", _op_add_f64).op_id
OP_PATCH_PREFIX = COMMANDS.register(4, "patch_prefix", _op_patch_prefix).op_id
