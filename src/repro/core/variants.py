"""Baseline logging engines the paper compares against (§3.3, §6.1, Table 1).

* :class:`CentrEngine`  — ARIES-style centralized logging ("CENTR"): one log
  buffer, one device, total LSN order (``fetch_add``), sequential commit.
  Level: sequentiality.
* :class:`SiloEngine`   — epoch-based parallel logging ("SILO"): multiple
  buffers/devices, coarse-grained epochs (default 50 ms), epoch group commit.
  Level: epoch-based sequentiality.
* :class:`NvmDEngine`   — distributed NVM logging ("NVM-D", Wang & Johnson):
  GSN tracks RAW+WAW+WAR (readers update tuple SSNs too), worker threads
  persist records *synchronously* to their mapped device (no logger threads,
  no batching), rigorous commit in GSN order.  Level: rigorousness.

All variants expose the :class:`~repro.core.engine.LoggingEngine` interface so
the OCC layer and the benchmarks are engine-agnostic.
"""

from __future__ import annotations

import threading
import time
from typing import Dict, Iterable, List, Optional, Sequence

from . import ssn as ssn_mod
from .commit import CommitQueues
from .engine import EngineConfig, LoggingEngine, PoplarEngine, _framed_len
from .log_buffer import LogBuffer
from .storage import StorageDevice, make_devices
from .txn import Txn


class CentrEngine(PoplarEngine):
    """Centralized ARIES-style logging.

    Reuses the Poplar machinery with n_buffers=1 but allocates the LSN with a
    pure fetch-add (ignores tuple SSNs → total order) and commits *both*
    queues against the single buffer's DSN, which with one sequential device
    is exactly LSN-order commit.
    """

    name = "centr"
    level = "sequentiality"

    def __init__(self, cfg: EngineConfig = EngineConfig(), devices: Optional[List[StorageDevice]] = None):
        cfg = EngineConfig(**{**cfg.__dict__, "n_buffers": 1})
        super().__init__(cfg, devices)

    def allocate(self, txn: Txn, read_items: Iterable, write_items: Sequence) -> int:
        worker_id = getattr(txn, "worker_id", txn.tid)
        buf = self.buffers[0]
        length = _framed_len(txn)
        if txn.write_set:
            # base=buf.ssn ⇒ ssn = buf.ssn + 1: a centralized fetch-add LSN.
            s, off, seg = buf.reserve(0, length)
            txn.ssn, txn.buffer_id, txn.offset = s, 0, off
            txn._seg_idx = seg  # type: ignore[attr-defined]
        else:
            # read-only txns still serialize behind the current LSN
            txn.ssn = buf.ssn
        txn.t_precommit = time.perf_counter()
        return txn.ssn

    def drain(self, worker_id: int) -> int:
        # Total-order commit: everything (incl. read-only) waits on the
        # single buffer's DSN.
        q = self.queues[worker_id]
        n = 0
        with q.lock:
            dsn = self.buffers[0].dsn
            for queue in (q.qww, q.qwr):
                while queue:
                    txn = queue[0]
                    if txn.ssn <= dsn:
                        queue.popleft()
                        txn.committed = True
                        txn.t_commit = time.perf_counter()
                        n += 1
                    else:
                        break
        if n:
            with self._count_lock:
                self.txn_committed += n
        return n


class SiloEngine(LoggingEngine):
    """Epoch-based parallel logging (Silo/SiloR).

    A global epoch advances every ``epoch_interval``.  A transaction's
    sequence number is its epoch; it commits once every buffer has durably
    persisted all records of epochs ≤ its own (epoch group commit).  The log
    insert path reuses the segment machinery for hole-free flushing.
    """

    name = "silo"
    level = "epoch-sequentiality"

    def __init__(
        self,
        cfg: EngineConfig = EngineConfig(),
        devices: Optional[List[StorageDevice]] = None,
        epoch_interval: float = 50e-3,  # paper §6.1: epoch increments every 50ms
    ):
        self.cfg = cfg
        self.epoch_interval = epoch_interval
        self.devices = devices or make_devices(
            cfg.n_buffers, cfg.device_kind, cfg.device_dir, cfg.device_clock
        )
        self.buffers = [
            LogBuffer(i, cfg.buffer_capacity, cfg.io_unit, cfg.segment_ring)
            for i in range(cfg.n_buffers)
        ]
        self.queues: Dict[int, CommitQueues] = {}
        self.epoch = 1
        self._epoch_lock = threading.Lock()
        # durable epoch per buffer: all records with epoch <= value are durable
        self.durable_epoch = [0] * cfg.n_buffers
        self._last_force = [time.perf_counter()] * cfg.n_buffers
        self._threads: List[threading.Thread] = []
        self._stop = threading.Event()
        self.txn_logged = 0
        self.txn_committed = 0
        self._count_lock = threading.Lock()

    # --- epochs ------------------------------------------------------------
    def advance_epoch(self) -> int:
        with self._epoch_lock:
            self.epoch += 1
            return self.epoch

    def persistent_epoch(self) -> int:
        return min(self.durable_epoch)

    # --- worker side ---------------------------------------------------------
    def register_worker(self, worker_id: int) -> None:
        self.queues.setdefault(worker_id, CommitQueues(worker_id))

    def buffer_for(self, worker_id: int) -> LogBuffer:
        return self.buffers[worker_id % self.cfg.n_buffers]

    def allocate(self, txn: Txn, read_items: Iterable, write_items: Sequence) -> int:
        worker_id = getattr(txn, "worker_id", txn.tid)
        buf = self.buffer_for(worker_id)
        txn.ssn = self.epoch  # epoch is the sequence number
        if txn.write_set:
            length = _framed_len(txn)
            # Silo logs carry the epoch, not a fine-grained LSN; records
            # within an epoch are unordered. The buffer SSN tracks the epoch
            # exactly (monotone), so seg.ssn/DSN are epochs.
            s, off, seg = buf.reserve(0, length, fixed_ssn=txn.ssn)
            txn.buffer_id, txn.offset = buf.id, off
            txn._seg_idx = seg  # type: ignore[attr-defined]
            txn.ssn = s
        txn.t_precommit = time.perf_counter()
        return txn.ssn

    def publish(self, txn: Txn) -> None:
        q = self.queues[getattr(txn, "worker_id", txn.tid)]
        if txn.write_set:
            record = txn.encode()
            buf = self.buffers[txn.buffer_id]
            buf.fill(txn.offset, txn._seg_idx, record)  # type: ignore[attr-defined]
        with self._count_lock:
            self.txn_logged += 1
        q.push(txn)

    def drain(self, worker_id: int) -> int:
        q = self.queues[worker_id]
        buf = self.buffer_for(worker_id)
        if self.devices[buf.id].spec.latency_s < 5e-6:
            self.logger_tick(buf.id)  # NVM inline flush (see PoplarEngine.drain)
        pe = self.persistent_epoch()
        n = 0
        with q.lock:
            for queue in (q.qww, q.qwr):
                while queue:
                    txn = queue[0]
                    if txn.ssn <= pe:
                        queue.popleft()
                        txn.committed = True
                        txn.t_commit = time.perf_counter()
                        n += 1
                    else:
                        break
        if n:
            with self._count_lock:
                self.txn_committed += n
        return n

    # --- logger side -------------------------------------------------------------
    def logger_tick(self, i: int, now: Optional[float] = None, force: bool = False) -> int:
        now = time.perf_counter() if now is None else now
        buf = self.buffers[i]
        epoch_at_start = self.epoch
        if force or now - self._last_force[i] >= self.cfg.flush_interval:
            buf.force_establish()
            self._last_force[i] = now
        n = buf.flush_ready(self.devices[i])
        if n:
            self._last_force[i] = time.perf_counter()
        if buf.pending_bytes() == 0:
            # everything allocated before this tick is durable
            self.durable_epoch[i] = max(self.durable_epoch[i], epoch_at_start - 1)
        else:
            self.durable_epoch[i] = max(self.durable_epoch[i], buf.dsn - 1)
        return n

    def _logger_loop(self, i: int) -> None:
        while not self._stop.is_set():
            if self.logger_tick(i):
                for wid in list(self.queues.keys()):
                    self.drain(wid)  # committer assist (see PoplarEngine)
            else:
                time.sleep(self.cfg.logger_poll)

    def _epoch_loop(self) -> None:
        while not self._stop.is_set():
            time.sleep(self.epoch_interval)
            self.advance_epoch()

    def start(self) -> None:
        self._stop.clear()
        self._threads = [
            threading.Thread(target=self._logger_loop, args=(i,), daemon=True, name=f"silo-logger-{i}")
            for i in range(self.cfg.n_buffers)
        ]
        self._threads.append(threading.Thread(target=self._epoch_loop, daemon=True, name="silo-epoch"))
        for t in self._threads:
            t.start()

    def stop(self) -> None:
        self._stop.set()
        for t in self._threads:
            t.join(timeout=10)
        self._threads = []

    def quiesce(self, worker_ids: Sequence[int], timeout: float = 30.0) -> None:
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            self.advance_epoch()
            for i in range(self.cfg.n_buffers):
                self.buffers[i].force_establish()
                self.buffers[i].flush_ready(self.devices[i])
                self.logger_tick(i)
            pending = 0
            for w in worker_ids:
                self.drain(w)
                pending += self.queues[w].pending()
            if pending == 0 and all(b.pending_bytes() == 0 for b in self.buffers):
                return
            time.sleep(1e-4)
        raise TimeoutError("silo quiesce timed out")

    def stats(self) -> Dict:
        return {
            "engine": self.name,
            "epoch": self.epoch,
            "persistent_epoch": self.persistent_epoch(),
            "txn_logged": self.txn_logged,
            "txn_committed": self.txn_committed,
            "devices": [d.stats() for d in self.devices],
        }


class NvmDEngine(LoggingEngine):
    """Distributed GSN logging (NVM-D): rigorous, synchronous persistence.

    * GSN allocation updates the SSN of **every** accessed tuple (RS and WS):
      WAR is tracked, so allocation cost grows with the read-set size
      (reproduces Fig. 10's linear degradation with scan length).
    * ``publish`` writes the record synchronously to the worker's mapped
    device (the paper's port of NVM-D to SSDs: no batching, no loggers).
    * Commit is rigorous: a txn commits when its GSN ≤ the global durable
      watermark = min over devices of (all-smaller-GSNs-durable point).
    """

    name = "nvmd"
    level = "rigorousness"

    def __init__(
        self,
        n_workers: int,
        n_devices: int = 2,
        device_kind: str = "nvm",
        device_dir: Optional[str] = None,
        device_clock: str = "real",
        devices: Optional[List[StorageDevice]] = None,
    ):
        self.n_devices = n_devices
        self.devices = devices or make_devices(n_devices, device_kind, device_dir, device_clock)
        self.queues: Dict[int, CommitQueues] = {}
        # per-device GSN bookkeeping
        self._dev_lock = [threading.Lock() for _ in range(n_devices)]
        self._inflight: List[Dict[int, int]] = [dict() for _ in range(n_devices)]  # gsn -> count
        self._dev_max_gsn = [0] * n_devices  # max gsn ever allocated to device
        self._dev_durable = [0] * n_devices
        self.gsn_floor = 0
        # per-buffer(device) gsn state for allocation
        self._gsn = [0] * n_devices
        self._gsn_lock = [threading.Lock() for _ in range(n_devices)]
        self.txn_logged = 0
        self.txn_committed = 0
        self._count_lock = threading.Lock()

    def register_worker(self, worker_id: int) -> None:
        self.queues.setdefault(worker_id, CommitQueues(worker_id))

    def device_for(self, worker_id: int) -> int:
        return worker_id % self.n_devices

    def allocate(self, txn: Txn, read_items: Iterable, write_items: Sequence) -> int:
        worker_id = getattr(txn, "worker_id", txn.tid)
        d = self.device_for(worker_id)
        read_items = list(read_items)
        write_items = list(write_items)
        base = 0
        for e in read_items:
            base = max(base, e.ssn)
        for e in write_items:
            base = max(base, e.ssn)
        with self._gsn_lock[d]:
            gsn = max(base, self._gsn[d]) + 1
            self._gsn[d] = gsn
        # WAR tracking: *every* accessed tuple gets the new GSN (the cost the
        # paper's Fig. 10 measures). Writes get it via the caller's writeback;
        # reads are updated here.
        for e in read_items:
            if gsn > e.ssn:
                e.ssn = gsn
        txn.ssn = gsn
        txn.buffer_id = d
        with self._dev_lock[d]:
            self._inflight[d][gsn] = self._inflight[d].get(gsn, 0) + 1
            self._dev_max_gsn[d] = max(self._dev_max_gsn[d], gsn)
        txn.t_precommit = time.perf_counter()
        return gsn

    def publish(self, txn: Txn) -> None:
        d = txn.buffer_id
        if txn.write_set:
            record = txn.encode()
            # synchronous direct persistence (mfence / direct IO semantics)
            self.devices[d].write(record)
        with self._dev_lock[d]:
            cnt = self._inflight[d].get(txn.ssn, 0) - 1
            if cnt <= 0:
                self._inflight[d].pop(txn.ssn, None)
            else:
                self._inflight[d][txn.ssn] = cnt
        with self._count_lock:
            self.txn_logged += 1
        self.queues[getattr(txn, "worker_id", txn.tid)].push(txn)

    def _durable_watermark(self) -> int:
        # A device's durable point: every GSN below min(inflight) is safely on
        # the device (or was never routed there). With no inflight records the
        # device is caught up to the global max allocated GSN.
        global_max = max(self._dev_max_gsn) if self._dev_max_gsn else 0
        wm = None
        for d in range(self.n_devices):
            with self._dev_lock[d]:
                if self._inflight[d]:
                    dev_wm = min(self._inflight[d]) - 1
                else:
                    dev_wm = global_max
            wm = dev_wm if wm is None else min(wm, dev_wm)
        return wm or 0

    def drain(self, worker_id: int) -> int:
        q = self.queues[worker_id]
        wm = self._durable_watermark()
        n = 0
        with q.lock:
            for queue in (q.qww, q.qwr):
                while queue:
                    txn = queue[0]
                    if txn.ssn <= wm:
                        queue.popleft()
                        txn.committed = True
                        txn.t_commit = time.perf_counter()
                        n += 1
                    else:
                        break
        if n:
            with self._count_lock:
                self.txn_committed += n
        return n

    def quiesce(self, worker_ids: Sequence[int], timeout: float = 30.0) -> None:
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            pending = 0
            for w in worker_ids:
                self.drain(w)
                pending += self.queues[w].pending()
            if pending == 0:
                return
            time.sleep(1e-4)
        raise TimeoutError("nvmd quiesce timed out")

    def stats(self) -> Dict:
        return {
            "engine": self.name,
            "watermark": self._durable_watermark(),
            "txn_logged": self.txn_logged,
            "txn_committed": self.txn_committed,
            "devices": [d.stats() for d in self.devices],
        }
