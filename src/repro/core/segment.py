"""Segment index (paper Figure 4 / Algorithm 2).

Concurrent SSN allocation + record memcpy create *holes* in a log buffer:
slots that are reserved but not yet filled.  The segment index logically
divides the buffer into variable-size segments; a segment becomes flushable
only when it is ``CLOSED`` *and* every reserved byte has been buffered
(``allocated_bytes == buffered_bytes``).  Each flushed segment advances the
buffer's DSN to the segment's largest SSN.

Segments close in one of two ways (two "segment threads", §4.3):
  * a worker closes the generating segment when its cumulative allocation
    reaches the IO unit size;
  * the logger closes it when the group-commit flush timer expires.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import List, Optional

OPEN = 0
CLOSED = 1


@dataclass
class Segment:
    ssn: int = 0                # largest SSN of records in this segment
    allocated_bytes: int = 0
    buffered_bytes: int = 0
    start_offset: int = 0       # logical offset of the segment start
    stat: int = OPEN

    def reset(self) -> None:
        self.ssn = 0
        self.allocated_bytes = 0
        self.buffered_bytes = 0
        self.start_offset = 0
        self.stat = OPEN


class SegmentIndex:
    """Ring of segments for one log buffer.

    All mutation of ``allocated_bytes`` / ``stat`` / ``cur_generate_seg``
    happens under the owning buffer's latch (the paper uses atomics; under
    CPython we piggyback on the buffer latch which subsumes them).
    ``buffered_bytes`` is incremented after memcpy *outside* the latch and is
    protected by a dedicated fine-grained lock, mirroring FETCH_ADD.
    """

    def __init__(self, size: int = 64):
        self.size = size
        self.segments: List[Segment] = [Segment() for _ in range(size)]
        self.cur_generate_seg = 0
        self.cur_flush_seg = 0
        self._buffered_lock = threading.Lock()

    # --- producer (worker) side: called under buffer latch ----------------
    def generating(self) -> Segment:
        return self.segments[self.cur_generate_seg % self.size]

    def gen_index(self) -> int:
        return self.cur_generate_seg

    def allocate(self, nbytes: int) -> int:
        """Account ``nbytes`` of a freshly reserved record to the generating
        segment.  Returns the absolute segment index the record belongs to."""
        idx = self.cur_generate_seg
        self.segments[idx % self.size].allocated_bytes += nbytes
        return idx

    def try_establish(self, buffer_ssn: int, buffer_offset: int, io_unit: int) -> bool:
        """Algorithm 2, EstablishingSegment — close the generating segment if
        it has reached the IO unit size.  Called under the buffer latch."""
        seg = self.generating()
        if seg.allocated_bytes >= io_unit and seg.stat != CLOSED:
            self._establish(seg, buffer_ssn, buffer_offset)
            return True
        return False

    def force_establish(self, buffer_ssn: int, buffer_offset: int) -> bool:
        """Timer-triggered close by the logger thread (group commit).
        Called under the buffer latch.  Empty segments are not closed."""
        seg = self.generating()
        if seg.allocated_bytes > 0 and seg.stat != CLOSED:
            self._establish(seg, buffer_ssn, buffer_offset)
            return True
        return False

    def _establish(self, seg: Segment, buffer_ssn: int, buffer_offset: int) -> None:
        nxt = self.segments[(self.cur_generate_seg + 1) % self.size]
        if nxt.stat == CLOSED:
            # Ring full: the logger has fallen behind by `size` segments.
            # Workers will observe allocation back-pressure via buffer space
            # accounting; we simply refuse to close (flush timer will retry).
            return
        seg.ssn = buffer_ssn
        seg.stat = CLOSED
        nxt.start_offset = buffer_offset
        self.cur_generate_seg += 1

    # --- memcpy completion (outside latch) --------------------------------
    def add_buffered(self, seg_index: int, nbytes: int) -> None:
        with self._buffered_lock:
            self.segments[seg_index % self.size].buffered_bytes += nbytes

    # --- consumer (logger) side --------------------------------------------
    def flushable(self) -> Optional[Segment]:
        """Return the next segment ready to flush, else None."""
        seg = self.segments[self.cur_flush_seg % self.size]
        with self._buffered_lock:
            ready = seg.stat == CLOSED and seg.allocated_bytes == seg.buffered_bytes
        return seg if ready else None

    def pop_flushed(self) -> None:
        """Reset the just-flushed segment and advance cur_flush_seg."""
        seg = self.segments[self.cur_flush_seg % self.size]
        seg.reset()
        self.cur_flush_seg += 1
