"""Scalable Sequence Number allocation (paper §4.2, Algorithm 1).

The SSN of a transaction T with read set RS and write set WS, logging into
buffer L, is the smallest number that is

  (i)  larger than the SSN of every tuple in RS ∪ WS, and
  (ii) larger than the SSN of the log buffer L,

i.e. ``ssn(T) = max(max_{e∈RS∪WS} e.ssn, L.ssn) + 1``.  The new SSN is then
written back into L and into every tuple of WS (WAR is deliberately *not*
tracked: read-only tuples keep their SSN, so pure readers never delay
writers — this is the key difference from NVM-D's GSN).

Read-only transactions take no latch and consume no buffer slot:
``ssn(T) = base`` (Algorithm 1 lines 16–17).

The tuple side is duck-typed: anything with a mutable ``ssn`` attribute
works (DB tuple cells in `repro.db`, state shards in `repro.journal`).
"""

from __future__ import annotations

from typing import Iterable, Optional, Tuple

import numpy as np

from .log_buffer import LogBuffer


def base_ssn(read_items: Iterable, write_items: Iterable) -> int:
    """max tuple-SSN over RS ∪ WS (Algorithm 1 lines 1–4)."""
    base = 0
    for e in read_items:
        if e.ssn > base:
            base = e.ssn
    for e in write_items:
        if e.ssn > base:
            base = e.ssn
    return base


def allocate(
    buffer: Optional[LogBuffer],
    read_items: Iterable,
    write_items: Iterable,
    record_len: int,
) -> Tuple[int, int, int]:
    """Run Algorithm 1 end-to-end for a transaction.

    Returns ``(ssn, offset, segment_index)``; for read-only transactions
    (empty write set) returns ``(base, -1, -1)`` without touching the buffer.

    NOTE: writing the SSN back into the write-set tuples (lines 13–15) is the
    caller's job, because under OCC (§4.4) it must happen in the write phase
    while the write locks are still held.
    """
    write_items = list(write_items)
    base = base_ssn(read_items, write_items)
    if not write_items:
        return base, -1, -1
    assert buffer is not None, "write transactions need a log buffer"
    ssn, offset, seg_idx = buffer.reserve(base, record_len)
    return ssn, offset, seg_idx


def writeback(ssn: int, write_items: Iterable) -> None:
    """Algorithm 1 lines 13–15: store the transaction's SSN into every
    written tuple."""
    for e in write_items:
        e.ssn = ssn


def base_ssn_global(ssn_arrays: Iterable[np.ndarray]) -> int:
    """Algorithm 1 lines 1–4 lifted across shards (`repro.shard`): the base
    of a cross-shard transaction is the max tuple SSN over its read and
    write sets on *every* participating shard.  Per-shard SSN spaces are
    independent, so this mixes spaces — deliberately: reserving from the
    mixed base on each participant pushes every participant's buffer SSN
    past every observed tuple SSN, which is exactly what makes the
    per-shard ``ssn <= CSN`` commit rule imply global RAW durability."""
    base = 0
    for arr in ssn_arrays:
        if len(arr):
            m = int(arr.max())
            if m > base:
                base = m
    return base


# --- batched Algorithm 1 (array-native forward path) -------------------------

def base_ssn_batch(acc_ssn: np.ndarray, acc_start: np.ndarray) -> np.ndarray:
    """Batched Algorithm 1 lines 1–4: per-transaction base SSN.

    ``acc_ssn`` holds the tuple SSNs of every access (RS ∪ WS), flattened
    transaction-major; ``acc_start`` is the ``(B+1,)`` prefix of per-txn
    access counts.  Returns the ``(B,)`` segment max (0 for a transaction
    with no accesses), i.e. ``base_i = max_{e ∈ RS_i ∪ WS_i} e.ssn``.
    """
    b = len(acc_start) - 1
    out = np.zeros(b, dtype=np.int64)
    nonempty = acc_start[:-1] < acc_start[1:]
    if acc_ssn.size and nonempty.any():
        # reduceat over only the nonempty segment starts: an empty segment
        # contributes no elements between two consecutive nonempty starts,
        # so the filtered boundaries still delimit the right slices
        out[nonempty] = np.maximum.reduceat(
            np.asarray(acc_ssn, dtype=np.int64), acc_start[:-1][nonempty]
        )
    return out


def chain_ssns(buffer_ssn: int, bases: np.ndarray) -> np.ndarray:
    """Vectorized Algorithm 1 lines 6–9 for a whole batch on one buffer.

    The scalar recurrence is ``s_i = max(base_i, s_{i-1}) + 1`` seeded with
    the buffer SSN; expanding it gives the closed form

        ``s_i = i + 1 + max(L.ssn, max_{j<=i} (base_j - j))``

    which is one subtraction, one running max, and one add — no serial loop.
    The caller stores ``s[-1]`` back into the buffer (done by
    :meth:`~repro.core.log_buffer.LogBuffer.reserve_batch` under its latch).
    """
    bases = np.asarray(bases, dtype=np.int64)
    idx = np.arange(len(bases), dtype=np.int64)
    return idx + 1 + np.maximum(int(buffer_ssn), np.maximum.accumulate(bases - idx))


def allocate_batch(
    buffer: LogBuffer, bases: np.ndarray, lengths: np.ndarray
) -> Tuple[np.ndarray, np.ndarray, int]:
    """Batched Algorithm 1 for write transactions mapped to one buffer.

    One latch acquisition reserves SSNs and slots for the whole batch
    (replacing N :func:`allocate` round-trips); returns ``(ssns, offsets,
    segment_index)``.  Read-only transactions never reach here — their SSN
    is just :func:`base_ssn_batch`'s output (Algorithm 1 lines 16–17).
    """
    return buffer.reserve_batch(bases, lengths)
