"""Scalable Sequence Number allocation (paper §4.2, Algorithm 1).

The SSN of a transaction T with read set RS and write set WS, logging into
buffer L, is the smallest number that is

  (i)  larger than the SSN of every tuple in RS ∪ WS, and
  (ii) larger than the SSN of the log buffer L,

i.e. ``ssn(T) = max(max_{e∈RS∪WS} e.ssn, L.ssn) + 1``.  The new SSN is then
written back into L and into every tuple of WS (WAR is deliberately *not*
tracked: read-only tuples keep their SSN, so pure readers never delay
writers — this is the key difference from NVM-D's GSN).

Read-only transactions take no latch and consume no buffer slot:
``ssn(T) = base`` (Algorithm 1 lines 16–17).

The tuple side is duck-typed: anything with a mutable ``ssn`` attribute
works (DB tuple cells in `repro.db`, state shards in `repro.journal`).
"""

from __future__ import annotations

from typing import Iterable, Optional, Tuple

from .log_buffer import LogBuffer


def base_ssn(read_items: Iterable, write_items: Iterable) -> int:
    """max tuple-SSN over RS ∪ WS (Algorithm 1 lines 1–4)."""
    base = 0
    for e in read_items:
        if e.ssn > base:
            base = e.ssn
    for e in write_items:
        if e.ssn > base:
            base = e.ssn
    return base


def allocate(
    buffer: Optional[LogBuffer],
    read_items: Iterable,
    write_items: Iterable,
    record_len: int,
) -> Tuple[int, int, int]:
    """Run Algorithm 1 end-to-end for a transaction.

    Returns ``(ssn, offset, segment_index)``; for read-only transactions
    (empty write set) returns ``(base, -1, -1)`` without touching the buffer.

    NOTE: writing the SSN back into the write-set tuples (lines 13–15) is the
    caller's job, because under OCC (§4.4) it must happen in the write phase
    while the write locks are still held.
    """
    write_items = list(write_items)
    base = base_ssn(read_items, write_items)
    if not write_items:
        return base, -1, -1
    assert buffer is not None, "write transactions need a log buffer"
    ssn, offset, seg_idx = buffer.reserve(base, record_len)
    return ssn, offset, seg_idx


def writeback(ssn: int, write_items: Iterable) -> None:
    """Algorithm 1 lines 13–15: store the transaction's SSN into every
    written tuple."""
    for e in write_items:
        e.ssn = ssn
