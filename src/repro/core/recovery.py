"""Crash recovery (paper §5).

Two stages:

1. **Checkpoint recovery** — load the newest *valid* checkpoint; its metadata
   carries ``RSNs`` (the CSN at checkpoint start), the starting point for log
   replay.

2. **Log recovery** — decode every device's log in parallel; compute
   ``RSNe = min over devices of (SSN of the most recently durable record)``
   — i.e. the crash-time CSN, since per-buffer SSNs are monotone in flush
   order.  Replay with **last-writer-wins** (Thomas write rule, per-tuple
   SSN guard):

   * records with RAW potential (``has_reads``) are applied only if
     ``ssn <= RSNe`` (their commit required CSN ≥ ssn);
   * write-only (WAW-only) records are applied whenever durable, regardless
     of RSNe (§5: they committed on their own buffer's DSN alone).

   A device with *no* durable record pins RSNe to 0: its DSN never advanced,
   so no RAW-dependent transaction can have committed.

Replay across devices is order-free thanks to the per-tuple SSN guard, so it
vectorizes: the default path decodes each log into columnar arrays
(:class:`~repro.core.txn.ColumnarLog`), concatenates all durable-committed
writes with the checkpoint image, and resolves last-writer-wins in one
segment-sorted SSN reduction (sort by key, take the max-SSN entry per key
segment) instead of a per-record guarded dict walk.  Three replay modes:

* ``mode="vectorized"`` (default) — the batched numpy reduction;
* ``mode="pallas"``     — same batching, but the guarded apply against the
  recovered image runs through the Pallas SSN scatter-max kernel
  (:func:`repro.kernels.ops.ssn_scatter_max`) — interpret mode on CPU,
  compiled on TPU;
* ``mode="scalar"``     — the original per-record replay, kept as the
  correctness oracle (tested equivalent on randomized logs).

All modes produce identical :class:`RecoveredState` contents, including the
``rsns``/``rsne`` watermarks and skipped-uncommitted counts.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from .checkpoint import CheckpointData, load_latest_checkpoint
from .par import parallel_for
from .storage import StorageDevice
from .txn import (
    ColumnarLog,
    LogRecord,
    decode_columnar,
    decode_columnar_stream,
    decode_records,
)


@dataclass
class RecoveredState:
    """Recovered database image: key -> (value, ssn)."""

    data: Dict[bytes, Tuple[bytes, int]] = field(default_factory=dict)
    rsns: int = 0
    rsne: int = 0
    n_replayed: int = 0
    n_skipped_uncommitted: int = 0

    def get(self, key: bytes) -> Optional[bytes]:
        v = self.data.get(key)
        return v[0] if v is not None else None

    def ssn_of(self, key: bytes) -> int:
        v = self.data.get(key)
        return v[1] if v is not None else 0


def compute_rsne(
    device_records: Sequence[Union[Sequence[LogRecord], ColumnarLog]],
    floors: Optional[Sequence[int]] = None,
) -> int:
    """min over devices of the most recently durable record's SSN.

    Accepts either row-decoded logs (``List[LogRecord]``) or columnar logs.

    ``floors`` (aligned with ``device_records``) carries each device's
    truncation floor (:attr:`~repro.core.storage.StorageDevice.truncated_ssn`):
    a device whose retained suffix is empty because *everything* durable was
    truncated away did advance its DSN to the newest dropped segment's last
    SSN — without the floor it would pin RSNe to 0 and recovery would skip
    every committed Qwr record on the other devices.  (A truncated device
    with a non-empty suffix needs no correction: its newest record is still
    its true frontier.)
    """
    rsne = None
    for i, recs in enumerate(device_records):
        if isinstance(recs, ColumnarLog):
            last = recs.last_ssn
        else:
            last = recs[-1].ssn if recs else 0
        if floors is not None:
            last = max(last, floors[i])
        rsne = last if rsne is None else min(rsne, last)
    return rsne or 0


def device_ssn_floors(devices: Sequence[StorageDevice]) -> List[int]:
    """Per-device truncation floors for :func:`compute_rsne` (0 for devices
    that were never truncated, or device-likes without the attribute)."""
    return [int(getattr(d, "truncated_ssn", 0)) for d in devices]


# --- scalar replay (correctness oracle) --------------------------------------

def _apply(state: RecoveredState, rec: LogRecord, lock: Optional[threading.Lock]) -> None:
    for key, val in rec.writes:
        if lock:
            with lock:
                cur = state.data.get(key)
                if cur is None or rec.ssn > cur[1]:
                    state.data[key] = (val, rec.ssn)
        else:
            cur = state.data.get(key)
            if cur is None or rec.ssn > cur[1]:
                state.data[key] = (val, rec.ssn)


def _replay_scalar(
    state: RecoveredState,
    device_records: Sequence[List[LogRecord]],
    rsne: int,
    parallel: bool,
) -> None:
    """Per-record guarded replay — one thread per device when ``parallel``."""
    lock = threading.Lock() if parallel else None

    def _replay(recs: List[LogRecord]) -> Tuple[int, int]:
        applied = skipped = 0
        for rec in recs:
            if rec.ssn <= state.rsns and not rec.write_only:
                # already reflected by the checkpoint (and guard makes replay
                # idempotent anyway) — skip as an optimization
                pass
            if rec.write_only or rec.ssn <= rsne:
                _apply(state, rec, lock)
                applied += 1
            else:
                skipped += 1  # durable but provably uncommitted RAW-dependent
        return applied, skipped

    results: List[Tuple[int, int]] = [(0, 0)] * len(device_records)

    def _worker(i: int) -> None:
        results[i] = _replay(device_records[i])

    parallel_for(len(device_records), _worker, parallel)

    state.n_replayed = sum(r[0] for r in results)
    state.n_skipped_uncommitted = sum(r[1] for r in results)


# --- vectorized replay (batched last-writer-wins) ----------------------------

def committed_mask(log: ColumnarLog, rsne: int) -> np.ndarray:
    """Per-record §5 commit guard: write-only (Qww) records replay whenever
    durable; HAS_READS (Qwr) records only with ``ssn <= RSNe``."""
    return ~log.has_reads | (log.ssn <= rsne)


def _key_words(key_mat: np.ndarray) -> np.ndarray:
    """Reinterpret a fixed-width 'S' key array as (n, width/8) int64 words
    (zero-copy when the width is already a multiple of 8, as the columnar
    decode guarantees; pads otherwise)."""
    n = len(key_mat)
    width = max(key_mat.dtype.itemsize, 1)
    if width % 8 == 0:
        return key_mat.view("<i8").reshape(n, width // 8)
    wpad = -(-width // 8) * 8
    u8 = np.zeros((n, wpad), np.uint8)
    u8[:, : key_mat.dtype.itemsize] = key_mat.view(np.uint8).reshape(n, -1)
    return u8.view("<i8")


def _hash_words(words: np.ndarray) -> np.ndarray:
    """Vectorized 64-bit mixing hash over key words.

    Equal keys always hash equal; the (astronomically rare) converse failure
    — two distinct keys colliding — is *detected* by the caller's word-level
    group check and falls back to the exact sort, so the hash only ever
    affects speed, never results.
    """
    mult = np.uint64(0x9E3779B97F4A7C15)        # golden-ratio odd constant
    acc = np.uint64(0x632BE59BD9B4E019)
    uw = words.view(np.uint64)
    with np.errstate(over="ignore"):
        h = np.full(len(words), np.uint64(0x9AFB33C1), dtype=np.uint64)
        for j in range(words.shape[1]):
            acc = acc * mult + np.uint64(1)
            h += uw[:, j] * (acc | np.uint64(1))
            h ^= h >> np.uint64(29)
    return h.view(np.int64)


def _group_winners(
    key_mat: np.ndarray, ssn_arr: np.ndarray, pos_arr: np.ndarray,
    want_inv: bool = False,
) -> Tuple[np.ndarray, Optional[np.ndarray], int]:
    """Segment-sorted last-writer-wins reduction.

    Entries are grouped by exact key identity (the sentinel-terminated
    fixed-width encoding of :meth:`ColumnarLog.encode_keys_fixed`), and each
    key segment reduces to the entry with the max SSN — SSN ties going to
    the *smallest* position, i.e. first in replay order, which reproduces
    the scalar guard's strict ``>`` (the checkpoint image sits at position
    -1 and therefore wins its ties).

    Fast path: segments come from a single int64 argsort of a 64-bit key
    hash, and the (ssn, -pos) argmax per segment from one
    ``np.maximum.reduceat`` over a packed ``ssn << shift | ~pos`` composite.
    If the packing ranges don't fit, or the word-level check finds more
    distinct keys than hash groups (a hash collision), it falls back to one
    exact multi-column lexsort — identical semantics either way.

    Returns ``(winners, inv, n_groups)``: the winning entry index per group
    (in group order), each entry's dense group id (``None`` unless
    ``want_inv`` — only the kernel apply needs it), and the group count.
    """
    n = len(key_mat)

    avail = 62 - max(int(ssn_arr.max()), 1).bit_length() if n else 0
    if n and avail > 1 and int(pos_arr.max()) + 2 < 1 << avail:
        # composite: bigger SSN sorts higher, then smaller position
        v = (ssn_arr << avail) + ((1 << avail) - 2 - pos_arr)
        words = _key_words(key_mat)
        h = _hash_words(words)
        order = np.argsort(h)
        h_s = h[order]
        gb = np.empty(n, dtype=bool)
        gb[0] = True
        np.not_equal(h_s[1:], h_s[:-1], out=gb[1:])
        # exact word boundaries: a superset of the hash boundaries, strictly
        # larger only under a hash collision
        w_s = words[order]
        exact = np.empty(n, dtype=bool)
        exact[0] = True
        np.not_equal(w_s[1:, 0], w_s[:-1, 0], out=exact[1:])
        for j in range(1, words.shape[1]):
            exact[1:] |= w_s[1:, j] != w_s[:-1, j]
        if int(gb.sum()) == int(exact.sum()):
            gid = np.cumsum(gb) - 1
            v_s = v[order]
            seg_max = np.maximum.reduceat(v_s, np.flatnonzero(gb))
            winners = order[v_s == seg_max[gid]]   # v is unique: one per group
            inv = None
            if want_inv:
                inv = np.empty(n, dtype=np.int64)
                inv[order] = gid
            return winners, inv, int(gid[-1]) + 1
        # hash collision: fall through to the exact sort

    order = np.lexsort((-pos_arr, ssn_arr, key_mat))
    k_s = key_mat[order]
    gb = np.empty(n, dtype=bool)
    gb[0] = True
    gb[1:] = k_s[1:] != k_s[:-1]
    gid = np.cumsum(gb) - 1
    boundary = np.empty(n, dtype=bool)
    boundary[:-1] = gb[1:]
    boundary[-1] = True
    inv = None
    if want_inv:
        inv = np.empty(n, dtype=np.int64)
        inv[order] = gid
    return order[boundary], inv, int(gid[-1]) + 1


def replay_columnar(
    logs: Sequence[ColumnarLog],
    rsne: int,
    base: Optional[Dict[bytes, Tuple[bytes, int]]] = None,
    use_kernel: bool = False,
    record_mask: Optional[Sequence[Optional[np.ndarray]]] = None,
) -> Tuple[Dict[bytes, Tuple[bytes, int]], int, int]:
    """Batched last-writer-wins replay over columnar device logs.

    ``base`` is the checkpoint image (key -> (value, ssn)); its entries join
    the reduction at position -1 so they win SSN ties against log writes,
    exactly like the scalar path's strict ``ssn > image.ssn`` guard.

    With ``use_kernel=True`` the guarded apply against the image runs through
    the Pallas SSN scatter-max kernel instead of the numpy reduction.

    ``record_mask`` (aligned with ``logs``; entries may be None) injects an
    extra per-record commit decision ANDed with the local §5 guard — the
    extension point sharded recovery uses to drop cross-shard records that
    are not durable on every participant (`repro.shard.recovery`).

    Returns ``(data, n_replayed, n_skipped_uncommitted)``.
    """
    base = base or {}
    n_replayed = 0
    n_skipped = 0
    n_base = len(base)

    # surviving writes, columnar across sources: exact key identity (the
    # sentinel-terminated fixed-width encoding), SSN, value payload (object
    # array — only the winners' payloads are ever touched again)
    base_keys = list(base.keys())
    key_mats: List[np.ndarray] = [
        ColumnarLog.encode_keys_fixed(base_keys, [len(k) for k in base_keys])
    ]
    ssn_parts: List[np.ndarray] = [
        np.fromiter((s for _, s in base.values()), dtype=np.int64, count=n_base)
    ]
    val_parts: List[np.ndarray] = [
        np.fromiter((v for v, _ in base.values()), dtype=object, count=n_base)
    ]

    for li, log in enumerate(logs):
        ok = committed_mask(log, rsne)
        if record_mask is not None and record_mask[li] is not None:
            ok = ok & record_mask[li]
        n_ok = int(np.count_nonzero(ok))
        n_replayed += n_ok
        n_skipped += log.n_records - n_ok
        if not len(log.wr_rec):
            continue
        vals = log.values_obj
        wmask = ok[log.wr_rec]
        if wmask.all():
            key_mats.append(log.keys_fixed)
            ssn_parts.append(log.wr_ssn)
            val_parts.append(vals)
        else:
            key_mats.append(log.keys_fixed[wmask])
            ssn_parts.append(log.wr_ssn[wmask])
            val_parts.append(vals[wmask])

    n_total = sum(len(p) for p in ssn_parts)
    if n_total == 0:
        return {}, n_replayed, n_skipped

    # common width, kept a multiple of 8 so the int64 word view is zero-copy
    width = -(-max(1, max(m.dtype.itemsize for m in key_mats)) // 8) * 8
    key_mat = np.concatenate([m.astype(f"S{width}", copy=False) for m in key_mats])
    ssn_arr = np.concatenate(ssn_parts)
    val_arr = np.concatenate(val_parts)
    pos_arr = np.empty(n_total, dtype=np.int64)
    pos_arr[:n_base] = -1                       # checkpoint wins SSN ties
    pos_arr[n_base:] = np.arange(n_total - n_base)

    winners, inv, n_slots = _group_winners(
        key_mat, ssn_arr, pos_arr, want_inv=use_kernel
    )

    # 'S' items come back NUL-stripped: dropping the final byte (the \x01
    # terminator) recovers the exact original key
    win_keys = key_mat[winners].tolist()

    if use_kernel and n_total > n_base and (
        int(ssn_arr.max()) >= 2**31 or n_total - n_base >= 2**31
    ):
        # outside the kernel's int32 range (checkpoint or log SSNs, or the
        # write count): the numpy reduction below is equivalent — fall back
        use_kernel = False

    if not use_kernel or n_total == n_base:
        data = {}
        for k, v, s in zip(
            win_keys, val_arr[winners].tolist(), ssn_arr[winners].tolist()
        ):
            data[k[:-1]] = (v, s)
        return data, n_replayed, n_skipped

    # --- Pallas path: dense key ids + SSN-guarded scatter-max apply ----------
    from ..kernels.ops import ssn_scatter_max
    from ..kernels.scatter_max import NO_POS

    image_ssn = np.full(n_slots, -1, np.int32)
    image_pos = np.full(n_slots, NO_POS, np.int32)
    base_slots = inv[:n_base]
    image_ssn[base_slots] = ssn_arr[:n_base]
    image_pos[base_slots] = -1
    base_idx_of_slot = np.full(n_slots, -1, np.int64)
    base_idx_of_slot[base_slots] = np.arange(n_base)

    out_ssn, out_pos = ssn_scatter_max(
        image_ssn,
        image_pos,
        inv[n_base:].astype(np.int32),
        ssn_arr[n_base:].astype(np.int32),
        pos_arr[n_base:].astype(np.int32),
    )
    out_ssn = np.asarray(out_ssn)
    out_pos = np.asarray(out_pos)

    # winners[g] is a member of group g: use it for the exact key bytes
    data = {}
    for g, (p, s) in enumerate(zip(out_pos.tolist(), out_ssn.tolist())):
        if p == NO_POS:
            continue
        idx = int(base_idx_of_slot[g]) if p < 0 else n_base + p
        data[win_keys[g][:-1]] = (val_arr[idx], s)
    return data, n_replayed, n_skipped


# --- top-level recovery -------------------------------------------------------

def _load_per_device(devices: Sequence[StorageDevice], decode, parallel: bool) -> List:
    out: List = [None] * len(devices)

    def _load(i: int) -> None:
        out[i] = decode(devices[i].read_all())

    parallel_for(len(devices), _load, parallel)
    return out


def load_columnar_segmented(
    devices: Sequence[StorageDevice], parallel: bool
) -> List[ColumnarLog]:
    """Segment-parallel columnar decode: every (device, segment) pair decodes
    on its own thread and the chunks splice back per device in chain order.

    Sealed segments end at record boundaries, so each blob is an independent
    framed stream; only the tail blob can carry a torn frame, and it is the
    last chunk, so per-segment truncation semantics equal whole-log decode.
    Devices without a segment chain (journal lanes, test doubles) fall back
    to one blob via ``read_all``.
    """
    blobs: List[List[bytes]] = [
        d.read_segment_blobs() if hasattr(d, "read_segment_blobs")
        else [d.read_all()]
        for d in devices
    ]
    flat = [(di, si) for di, bs in enumerate(blobs) for si in range(len(bs))]
    decoded: List[Optional[Tuple[ColumnarLog, int]]] = [None] * len(flat)

    def _decode(j: int) -> None:
        di, si = flat[j]
        decoded[j] = decode_columnar_stream(blobs[di][si])

    parallel_for(len(flat), _decode, parallel)

    out: List[ColumnarLog] = []
    j = 0
    for bs in blobs:
        chunk = decoded[j : j + len(bs)]
        j += len(bs)
        # a blob that did not fully decode ends this device's stream: a
        # whole-log decode would stop at that frame too (only the final,
        # tail blob can legitimately end torn)
        keep: List[ColumnarLog] = []
        for (log, consumed), blob in zip(chunk, bs):
            keep.append(log)
            if consumed < len(blob):
                break
        out.append(keep[0] if len(keep) == 1 else ColumnarLog.concat(keep))
    return out


def recover(
    devices: Sequence[StorageDevice],
    checkpoint_dir: Optional[str] = None,
    parallel: bool = True,
    mode: str = "vectorized",
) -> RecoveredState:
    """Restore a consistent state from checkpoint files + device logs.

    ``mode`` selects the replay engine: ``"vectorized"`` (default, batched
    numpy last-writer-wins), ``"pallas"`` (batched + Pallas scatter-max
    apply), or ``"scalar"`` (the per-record oracle).  All modes are
    equivalent; ``parallel`` controls decode threading — the vectorized
    paths decode per (device, sealed segment) pair, so a long-lived
    segmented log fans decode wider than one thread per device — and, for
    the scalar mode, per-device replay threading.

    Truncated logs (see `repro.core.truncate.LogTruncator`) recover from
    ``(checkpoint image, retained log suffix)``: pass the ``checkpoint_dir``
    the truncator was anchored to — its image covers everything the dropped
    segments held, and fully-truncated devices contribute their persisted
    ``truncated_ssn`` floor to RSNe instead of pinning it to 0.
    """
    if mode not in ("vectorized", "pallas", "scalar"):
        raise ValueError(f"unknown recovery mode {mode!r}")
    state = RecoveredState()

    # --- stage 1: checkpoint recovery -------------------------------------
    ckpt: Optional[CheckpointData] = None
    if checkpoint_dir is not None:
        ckpt = load_latest_checkpoint(checkpoint_dir, parallel=parallel)
    if ckpt is not None:
        state.rsns = ckpt.rsn
        state.data.update(ckpt.data)

    # --- stage 2: log recovery --------------------------------------------
    floors = device_ssn_floors(devices)
    if mode == "scalar":
        device_records = _load_per_device(devices, decode_records, parallel)
        state.rsne = compute_rsne(device_records, floors=floors)
        _replay_scalar(state, device_records, state.rsne, parallel)
        return state

    logs: List[ColumnarLog] = load_columnar_segmented(devices, parallel)
    state.rsne = compute_rsne(logs, floors=floors)
    data, n_replayed, n_skipped = replay_columnar(
        logs, state.rsne, base=state.data or None, use_kernel=(mode == "pallas")
    )
    state.data = data
    state.n_replayed = n_replayed
    state.n_skipped_uncommitted = n_skipped
    return state
