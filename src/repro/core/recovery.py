"""Crash recovery (paper §5).

Two stages:

1. **Checkpoint recovery** — load the newest *valid* checkpoint; its metadata
   carries ``RSNs`` (the CSN at checkpoint start), the starting point for log
   replay.

2. **Log recovery** — decode every device's log in parallel; compute
   ``RSNe = min over devices of (SSN of the most recently durable record)``
   — i.e. the crash-time CSN, since per-buffer SSNs are monotone in flush
   order.  Replay with **last-writer-wins** (Thomas write rule, per-tuple
   SSN guard):

   * records with RAW potential (``has_reads``) are applied only if
     ``ssn <= RSNe`` (their commit required CSN ≥ ssn);
   * write-only (WAW-only) records are applied whenever durable, regardless
     of RSNe (§5: they committed on their own buffer's DSN alone).

   A device with *no* durable record pins RSNe to 0: its DSN never advanced,
   so no RAW-dependent transaction can have committed.

Replay across devices is order-free thanks to the per-tuple SSN guard, so
recovery threads can process log files concurrently (tested threaded and
sequentially — results must be identical).
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from .checkpoint import CheckpointData, load_latest_checkpoint
from .storage import StorageDevice
from .txn import LogRecord, decode_records


@dataclass
class RecoveredState:
    """Recovered database image: key -> (value, ssn)."""

    data: Dict[bytes, Tuple[bytes, int]] = field(default_factory=dict)
    rsns: int = 0
    rsne: int = 0
    n_replayed: int = 0
    n_skipped_uncommitted: int = 0

    def get(self, key: bytes) -> Optional[bytes]:
        v = self.data.get(key)
        return v[0] if v is not None else None

    def ssn_of(self, key: bytes) -> int:
        v = self.data.get(key)
        return v[1] if v is not None else 0


def compute_rsne(device_records: Sequence[Sequence[LogRecord]]) -> int:
    """min over devices of the most recently durable record's SSN."""
    rsne = None
    for recs in device_records:
        last = recs[-1].ssn if recs else 0
        rsne = last if rsne is None else min(rsne, last)
    return rsne or 0


def _apply(state: RecoveredState, rec: LogRecord, lock: Optional[threading.Lock]) -> None:
    for key, val in rec.writes:
        if lock:
            with lock:
                cur = state.data.get(key)
                if cur is None or rec.ssn > cur[1]:
                    state.data[key] = (val, rec.ssn)
        else:
            cur = state.data.get(key)
            if cur is None or rec.ssn > cur[1]:
                state.data[key] = (val, rec.ssn)


def recover(
    devices: Sequence[StorageDevice],
    checkpoint_dir: Optional[str] = None,
    parallel: bool = True,
) -> RecoveredState:
    """Restore a consistent state from checkpoint files + device logs."""
    state = RecoveredState()

    # --- stage 1: checkpoint recovery -------------------------------------
    ckpt: Optional[CheckpointData] = None
    if checkpoint_dir is not None:
        ckpt = load_latest_checkpoint(checkpoint_dir, parallel=parallel)
    if ckpt is not None:
        state.rsns = ckpt.rsn
        state.data.update(ckpt.data)

    # --- stage 2: log recovery --------------------------------------------
    device_records: List[List[LogRecord]] = [[] for _ in devices]

    def _load(i: int) -> None:
        device_records[i] = decode_records(devices[i].read_all())

    if parallel and len(devices) > 1:
        threads = [threading.Thread(target=_load, args=(i,)) for i in range(len(devices))]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    else:
        for i in range(len(devices)):
            _load(i)

    rsne = compute_rsne(device_records)
    state.rsne = rsne

    lock = threading.Lock() if parallel else None

    def _replay(recs: List[LogRecord]) -> Tuple[int, int]:
        applied = skipped = 0
        for rec in recs:
            if rec.ssn <= state.rsns and not rec.write_only:
                # already reflected by the checkpoint (and guard makes replay
                # idempotent anyway) — skip as an optimization
                pass
            if rec.write_only or rec.ssn <= rsne:
                _apply(state, rec, lock)
                applied += 1
            else:
                skipped += 1  # durable but provably uncommitted RAW-dependent
        return applied, skipped

    results: List[Tuple[int, int]] = [(0, 0)] * len(devices)
    if parallel and len(devices) > 1:
        def _worker(i: int) -> None:
            results[i] = _replay(device_records[i])

        threads = [threading.Thread(target=_worker, args=(i,)) for i in range(len(devices))]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    else:
        for i, recs in enumerate(device_records):
            results[i] = _replay(recs)

    state.n_replayed = sum(r[0] for r in results)
    state.n_skipped_uncommitted = sum(r[1] for r in results)
    return state
