"""Crash recovery (paper §5).

Two stages:

1. **Checkpoint recovery** — load the newest *valid* checkpoint; its metadata
   carries ``RSNs`` (the CSN at checkpoint start), the starting point for log
   replay.

2. **Log recovery** — decode every device's log in parallel; compute
   ``RSNe = min over devices of (SSN of the most recently durable record)``
   — i.e. the crash-time CSN, since per-buffer SSNs are monotone in flush
   order.  Replay with **last-writer-wins** (Thomas write rule, per-tuple
   SSN guard):

   * records with RAW potential (``has_reads``) are applied only if
     ``ssn <= RSNe`` (their commit required CSN ≥ ssn);
   * write-only (WAW-only) records are applied whenever durable, regardless
     of RSNe (§5: they committed on their own buffer's DSN alone).

   A device with *no* durable record pins RSNe to 0: its DSN never advanced,
   so no RAW-dependent transaction can have committed.

Replay across devices is order-free thanks to the per-tuple SSN guard, so it
vectorizes: the default path decodes each log into columnar arrays
(:class:`~repro.core.txn.ColumnarLog`), concatenates all durable-committed
writes with the checkpoint image, and resolves last-writer-wins in one
segment-sorted SSN reduction (sort by key, take the max-SSN entry per key
segment) instead of a per-record guarded dict walk.  Three replay modes:

* ``mode="vectorized"`` (default) — the batched numpy reduction;
* ``mode="pallas"``     — the *compiled* pipeline: vectorized tile decode
  (`repro.core.fastdecode`, seal-crc verified) feeding the fused hash-slot
  scatter-max scan (:func:`repro.kernels.ops.fused_replay_scan` — compiled
  XLA on CPU/GPU, the Pallas kernel on TPU), sealed tiles prefetch-decoded
  while the previous tile replays; anything out of profile falls back to
  the batched path with the scatter-max kernel apply;
* ``mode="scalar"``     — the original per-record replay, kept as the
  correctness oracle (tested equivalent on randomized logs).

All modes produce identical :class:`RecoveredState` contents, including the
``rsns``/``rsne`` watermarks and skipped-uncommitted counts.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from .checkpoint import CheckpointData, load_latest_checkpoint
from .fastdecode import FastTile, decode_fast_tile
from .par import parallel_for
from .storage import StorageDevice
from .txn import (
    ColumnarLog,
    LogRecord,
    decode_columnar,
    decode_columnar_stream,
    decode_records,
)
from ..kernels.bucketing import bucket, checked_i32, fits_i32, stack_i32
from ..obs.metrics import REGISTRY
from ..trace.span import ST_RDECODE, ST_RREPLAY, TRACER

#: forensics verdict string for a command record whose pre-image is neither
#: in the retained log nor covered by the checkpoint image (see
#: ``repro.obs.forensics``)
REASON_CMD_DEP = "command-dep-unreplayable"
REASON_CMD_OP = "command-op-unknown"


class CommandReplayError(RuntimeError):
    """A command-framed record cannot be re-executed: its operator is not
    registered in this process, or its observed pre-image SSN points at
    state that was truncated away without checkpoint coverage.  A sound
    pipeline never raises this — the adaptive policy only command-frames
    records whose dependencies are covered, and the truncators refuse safe
    points that would strand a retained command's pre-image — so recovery
    fails loudly instead of guessing a value."""

    def __init__(self, msg: str, reason: str = REASON_CMD_DEP) -> None:
        super().__init__(msg)
        self.reason = reason


@dataclass
class RecoveryReport:
    """Structured account of one recovery pass — what was decoded, what
    replayed, and what each §5 rule dropped — consumed by
    ``repro.obs.forensics`` and logged by ``benchmarks/table23_recovery.py``.

    ``segments`` holds one row per decoded (device, segment) blob:
    ``{"device", "segment", "bytes", "records", "seconds"}`` (empty for the
    scalar and fused modes, which do not decode per-segment).
    """

    mode: str = "vectorized"
    fused: bool = False               # the pallas tiled pipeline engaged
    n_devices: int = 0
    rsns: int = 0
    rsne: int = 0
    n_decoded: int = 0                # records decoded from retained logs
    n_replayed: int = 0
    n_dropped_above_rsne: int = 0     # HAS_READS records with ssn > RSNe
    n_dropped_not_durable_all: int = 0  # cross-shard cut drops (sharded only)
    checkpoint_keys: int = 0
    decode_s: float = 0.0
    replay_s: float = 0.0
    segments: List[Dict] = field(default_factory=list)

    def to_dict(self) -> Dict:
        return {
            "mode": self.mode,
            "fused": self.fused,
            "n_devices": self.n_devices,
            "rsns": self.rsns,
            "rsne": self.rsne,
            "n_decoded": self.n_decoded,
            "n_replayed": self.n_replayed,
            "n_dropped_above_rsne": self.n_dropped_above_rsne,
            "n_dropped_not_durable_all": self.n_dropped_not_durable_all,
            "checkpoint_keys": self.checkpoint_keys,
            "decode_s": self.decode_s,
            "replay_s": self.replay_s,
            "segments": list(self.segments),
        }


@dataclass
class RecoveredState:
    """Recovered database image: key -> (value, ssn)."""

    data: Dict[bytes, Tuple[bytes, int]] = field(default_factory=dict)
    rsns: int = 0
    rsne: int = 0
    n_replayed: int = 0
    n_skipped_uncommitted: int = 0
    report: Optional[RecoveryReport] = None

    def get(self, key: bytes) -> Optional[bytes]:
        v = self.data.get(key)
        return v[0] if v is not None else None

    def ssn_of(self, key: bytes) -> int:
        v = self.data.get(key)
        return v[1] if v is not None else 0


def compute_rsne(
    device_records: Sequence[Union[Sequence[LogRecord], ColumnarLog]],
    floors: Optional[Sequence[int]] = None,
) -> int:
    """min over devices of the most recently durable record's SSN.

    Accepts either row-decoded logs (``List[LogRecord]``) or columnar logs.

    ``floors`` (aligned with ``device_records``) carries each device's
    truncation floor (:attr:`~repro.core.storage.StorageDevice.truncated_ssn`):
    a device whose retained suffix is empty because *everything* durable was
    truncated away did advance its DSN to the newest dropped segment's last
    SSN — without the floor it would pin RSNe to 0 and recovery would skip
    every committed Qwr record on the other devices.  (A truncated device
    with a non-empty suffix needs no correction: its newest record is still
    its true frontier.)
    """
    rsne = None
    for i, recs in enumerate(device_records):
        if isinstance(recs, ColumnarLog):
            last = recs.last_ssn
        else:
            last = recs[-1].ssn if recs else 0
        if floors is not None:
            last = max(last, floors[i])
        rsne = last if rsne is None else min(rsne, last)
    return rsne or 0


def device_ssn_floors(devices: Sequence[StorageDevice]) -> List[int]:
    """Per-device truncation floors for :func:`compute_rsne` (0 for devices
    that were never truncated, or device-likes without the attribute)."""
    return [int(getattr(d, "truncated_ssn", 0)) for d in devices]


# --- scalar replay (correctness oracle) --------------------------------------

def _apply(state: RecoveredState, rec: LogRecord, lock: Optional[threading.Lock]) -> None:
    for key, val in rec.writes:
        if lock:
            with lock:
                cur = state.data.get(key)
                if cur is None or rec.ssn > cur[1]:
                    state.data[key] = (val, rec.ssn)
        else:
            cur = state.data.get(key)
            if cur is None or rec.ssn > cur[1]:
                state.data[key] = (val, rec.ssn)


def _replay_scalar(
    state: RecoveredState,
    device_records: Sequence[List[LogRecord]],
    rsne: int,
    parallel: bool,
) -> None:
    """Per-record guarded replay — one thread per device when ``parallel``."""
    lock = threading.Lock() if parallel else None

    def _replay(recs: List[LogRecord]) -> Tuple[int, int]:
        applied = skipped = 0
        for rec in recs:
            if rec.ssn <= state.rsns and not rec.write_only:
                # already reflected by the checkpoint (and guard makes replay
                # idempotent anyway) — skip as an optimization
                pass
            if rec.write_only or rec.ssn <= rsne:
                # command records need their pre-image, so they cannot join
                # the order-free guarded walk: counted here, re-executed in
                # SSN order after every value record has landed
                if not rec.is_command:
                    _apply(state, rec, lock)
                applied += 1
            else:
                skipped += 1  # durable but provably uncommitted RAW-dependent
        return applied, skipped

    results: List[Tuple[int, int]] = [(0, 0)] * len(device_records)

    def _worker(i: int) -> None:
        results[i] = _replay(device_records[i])

    parallel_for(len(device_records), _worker, parallel)

    state.n_replayed = sum(r[0] for r in results)
    state.n_skipped_uncommitted = sum(r[1] for r in results)

    cmds = [
        rec
        for recs in device_records
        for rec in recs
        if rec.is_command and (rec.write_only or rec.ssn <= rsne)
    ]
    if cmds:
        cmds.sort(key=lambda r: r.ssn)
        depth, applied = _apply_command_records(state.data, cmds)
        if REGISTRY.enabled:
            REGISTRY.gauge_max("adaptive.replay.cmd_depth", depth)
            REGISTRY.count("adaptive.replay.commands", applied)


# --- command re-execution (adaptive logging) ---------------------------------
#
# Command-framed records (FLAG_COMMAND) carry op parameters, not values, so
# they cannot join the order-free last-writer-wins reduction: each one needs
# its key's pre-image.  OCC validation gives the ordering theorem that keeps
# this cheap: a committed command at SSN ``s`` observed its pre-image at SSN
# ``d`` and *no committed writer of that key exists in (d, s)*.  So after the
# value pass produces each key's value base (checkpoint image or last value
# winner at SSN ``V``), the surviving commands on a key are exactly a suffix
# chain above ``V``: commands with ``s <= V`` are superseded (Thomas rule),
# and the rest apply in per-key SSN order, each one's pre-image being the
# running entry.  Execution is batched per dependency level — level ``l`` is
# the ``l``-th command above its key's base — so independent keys re-execute
# together and only true chains serialize.

def _exec_command_write(
    data: Dict[bytes, Tuple[bytes, int]],
    key: bytes,
    ssn: int,
    op_id: int,
    dep: int,
    param: bytes,
    registry,
    dep_lookup=None,
) -> bool:
    """Apply one command write against the running image under the §5 guard.
    Returns False when the command is superseded by a newer entry; raises
    :class:`CommandReplayError` when the pre-image is missing (``dep``
    points below the current entry and nothing covers it)."""
    cur = data.get(key)
    if dep_lookup is not None and (cur is None or cur[1] < dep):
        # the round's reduction may hold an *older* entry than the external
        # store (a late chunk shipping a superseded write after the dep was
        # already folded) — take whichever is newer
        ext = dep_lookup(key)
        if ext is not None and (cur is None or ext[1] > cur[1]):
            cur = ext
    if cur is not None and ssn <= cur[1]:
        return False                   # superseded by a later (value) winner
    if op_id not in registry:
        raise CommandReplayError(
            f"command record ssn={ssn} key={key!r} uses unregistered op "
            f"{op_id}", reason=REASON_CMD_OP,
        )
    if cur is None or cur[1] < dep:
        have = "nothing" if cur is None else f"ssn {cur[1]}"
        raise CommandReplayError(
            f"command record ssn={ssn} key={key!r} depends on pre-image "
            f"ssn {dep} but recovery holds {have} — dependency truncated "
            f"away without checkpoint coverage", reason=REASON_CMD_DEP,
        )
    data[key] = (registry.get(op_id).fn(cur[0], param), ssn)
    return True


def _apply_command_records(
    data: Dict[bytes, Tuple[bytes, int]],
    recs: Sequence[LogRecord],
    dep_lookup=None,
) -> Tuple[int, int]:
    """Scalar-oracle command pass: re-execute committed command records in
    global SSN order (which embeds every per-key chain order).  ``recs``
    must already be filtered by the §5 guard and sorted by SSN.

    Returns ``(max chain depth, writes applied)``.
    """
    from .command import COMMANDS

    chain: Dict[bytes, int] = {}
    depth = applied = 0
    for rec in recs:
        deps = rec.cmd_deps or []
        if len(deps) != len(rec.writes):
            raise CommandReplayError(
                f"command record ssn={rec.ssn} carries {len(deps)} deps for "
                f"{len(rec.writes)} writes — footer does not mirror the "
                f"write chain", reason=REASON_CMD_DEP,
            )
        for (key, param), (_dkey, dssn) in zip(rec.writes, deps):
            lvl = chain.get(key, 0) + 1
            chain[key] = lvl
            depth = max(depth, lvl)
            if _exec_command_write(
                data, key, rec.ssn, rec.cmd_op, dssn, param, COMMANDS,
                dep_lookup,
            ):
                applied += 1
    return depth, applied


def _command_dep_per_write(log: ColumnarLog) -> np.ndarray:
    """Scatter a columnar log's command dep SSNs onto per-write lanes
    (``-1`` for value-record lanes).  The encoder invariant — dep footers
    mirror the write chain one-to-one — is validated here because replay is
    the first consumer that needs the positional alignment."""
    nw = log.n_writes.astype(np.int64)
    cd = np.diff(log.cmd_dep_start)
    if not np.array_equal(cd, nw[log.cmd_rec]):
        raise CommandReplayError(
            "command dep footers do not mirror their write chains",
            reason=REASON_CMD_DEP,
        )
    dep = np.full(len(log.wr_rec), -1, np.int64)
    total = int(cd.sum())
    if total:
        wr_off = np.zeros(log.n_records + 1, np.int64)
        np.cumsum(nw, out=wr_off[1:])
        cum = np.zeros(len(cd) + 1, np.int64)
        np.cumsum(cd, out=cum[1:])
        lane = (
            np.repeat(wr_off[log.cmd_rec], cd)
            + np.arange(total, dtype=np.int64)
            - np.repeat(cum[:-1], cd)
        )
        dep[lane] = log.cmd_dep_ssn
    return dep


def _apply_commands_vectorized(
    data: Dict[bytes, Tuple[bytes, int]],
    keys: List[bytes],
    ssn: np.ndarray,
    op: np.ndarray,
    dep: np.ndarray,
    params: np.ndarray,
    dep_lookup=None,
) -> Tuple[int, int]:
    """Dependency-level-batched command re-execution over flattened command
    write lanes (the vectorized twin of :func:`_apply_command_records`).

    Lanes lexsort by (key, SSN); each lane's *level* is its rank within its
    key segment.  Level ``l`` lanes touch distinct keys, so they re-execute
    as one batch; the loop over levels serializes only true per-key chains.
    Returns ``(max chain depth, writes applied)``.
    """
    from .command import COMMANDS

    n = len(keys)
    kf = ColumnarLog.encode_keys_fixed(keys, [len(k) for k in keys])
    order = np.lexsort((ssn, kf))
    k_s = kf[order]
    gb = np.empty(n, dtype=bool)
    gb[0] = True
    gb[1:] = k_s[1:] != k_s[:-1]
    starts = np.flatnonzero(gb)
    seg_len = np.diff(np.append(starts, n))
    level = np.arange(n, dtype=np.int64) - np.repeat(starts, seg_len)
    depth = int(seg_len.max())
    ssn_l = ssn.tolist()
    op_l = op.tolist()
    dep_l = dep.tolist()
    applied = 0
    for lvl in range(depth):
        for j in order[np.flatnonzero(level == lvl)].tolist():
            if _exec_command_write(
                data, keys[j], ssn_l[j], op_l[j], dep_l[j], params[j],
                COMMANDS, dep_lookup,
            ):
                applied += 1
    return depth, applied


# --- vectorized replay (batched last-writer-wins) ----------------------------

def committed_mask(log: ColumnarLog, rsne: int) -> np.ndarray:
    """Per-record §5 commit guard: write-only (Qww) records replay whenever
    durable; HAS_READS (Qwr) records only with ``ssn <= RSNe``."""
    return ~log.has_reads | (log.ssn <= rsne)


def _key_words(key_mat: np.ndarray) -> np.ndarray:
    """Reinterpret a fixed-width 'S' key array as (n, width/8) int64 words
    (zero-copy when the width is already a multiple of 8, as the columnar
    decode guarantees; pads otherwise)."""
    n = len(key_mat)
    width = max(key_mat.dtype.itemsize, 1)
    if width % 8 == 0:
        return key_mat.view("<i8").reshape(n, width // 8)
    wpad = -(-width // 8) * 8
    u8 = np.zeros((n, wpad), np.uint8)
    u8[:, : key_mat.dtype.itemsize] = key_mat.view(np.uint8).reshape(n, -1)
    return u8.view("<i8")


def _hash_words(words: np.ndarray) -> np.ndarray:
    """Vectorized 64-bit mixing hash over key words.

    Equal keys always hash equal; the (astronomically rare) converse failure
    — two distinct keys colliding — is *detected* by the caller's word-level
    group check and falls back to the exact sort, so the hash only ever
    affects speed, never results.
    """
    mult = np.uint64(0x9E3779B97F4A7C15)        # golden-ratio odd constant
    acc = np.uint64(0x632BE59BD9B4E019)
    uw = words.view(np.uint64)
    with np.errstate(over="ignore"):
        h = np.full(len(words), np.uint64(0x9AFB33C1), dtype=np.uint64)
        for j in range(words.shape[1]):
            acc = acc * mult + np.uint64(1)
            h += uw[:, j] * (acc | np.uint64(1))
            h ^= h >> np.uint64(29)
    return h.view(np.int64)


def _group_winners(
    key_mat: np.ndarray, ssn_arr: np.ndarray, pos_arr: np.ndarray,
    want_inv: bool = False,
) -> Tuple[np.ndarray, Optional[np.ndarray], int]:
    """Segment-sorted last-writer-wins reduction.

    Entries are grouped by exact key identity (the sentinel-terminated
    fixed-width encoding of :meth:`ColumnarLog.encode_keys_fixed`), and each
    key segment reduces to the entry with the max SSN — SSN ties going to
    the *smallest* position, i.e. first in replay order, which reproduces
    the scalar guard's strict ``>`` (the checkpoint image sits at position
    -1 and therefore wins its ties).

    Fast path: segments come from a single int64 argsort of a 64-bit key
    hash, and the (ssn, -pos) argmax per segment from one
    ``np.maximum.reduceat`` over a packed ``ssn << shift | ~pos`` composite.
    If the packing ranges don't fit, or the word-level check finds more
    distinct keys than hash groups (a hash collision), it falls back to one
    exact multi-column lexsort — identical semantics either way.

    Returns ``(winners, inv, n_groups)``: the winning entry index per group
    (in group order), each entry's dense group id (``None`` unless
    ``want_inv`` — only the kernel apply needs it), and the group count.
    """
    n = len(key_mat)

    avail = 62 - max(int(ssn_arr.max()), 1).bit_length() if n else 0
    if n and avail > 1 and int(pos_arr.max()) + 2 < 1 << avail:
        # composite: bigger SSN sorts higher, then smaller position
        v = (ssn_arr << avail) + ((1 << avail) - 2 - pos_arr)
        words = _key_words(key_mat)
        h = _hash_words(words)
        order = np.argsort(h)
        h_s = h[order]
        gb = np.empty(n, dtype=bool)
        gb[0] = True
        np.not_equal(h_s[1:], h_s[:-1], out=gb[1:])
        # exact word boundaries: a superset of the hash boundaries, strictly
        # larger only under a hash collision
        w_s = words[order]
        exact = np.empty(n, dtype=bool)
        exact[0] = True
        np.not_equal(w_s[1:, 0], w_s[:-1, 0], out=exact[1:])
        for j in range(1, words.shape[1]):
            exact[1:] |= w_s[1:, j] != w_s[:-1, j]
        if int(gb.sum()) == int(exact.sum()):
            gid = np.cumsum(gb) - 1
            v_s = v[order]
            seg_max = np.maximum.reduceat(v_s, np.flatnonzero(gb))
            winners = order[v_s == seg_max[gid]]   # v is unique: one per group
            inv = None
            if want_inv:
                inv = np.empty(n, dtype=np.int64)
                inv[order] = gid
            return winners, inv, int(gid[-1]) + 1
        # hash collision: fall through to the exact sort

    order = np.lexsort((-pos_arr, ssn_arr, key_mat))
    k_s = key_mat[order]
    gb = np.empty(n, dtype=bool)
    gb[0] = True
    gb[1:] = k_s[1:] != k_s[:-1]
    gid = np.cumsum(gb) - 1
    boundary = np.empty(n, dtype=bool)
    boundary[:-1] = gb[1:]
    boundary[-1] = True
    inv = None
    if want_inv:
        inv = np.empty(n, dtype=np.int64)
        inv[order] = gid
    return order[boundary], inv, int(gid[-1]) + 1


def replay_columnar(
    logs: Sequence[ColumnarLog],
    rsne: int,
    base: Optional[Dict[bytes, Tuple[bytes, int]]] = None,
    use_kernel: bool = False,
    record_mask: Optional[Sequence[Optional[np.ndarray]]] = None,
    dep_lookup=None,
) -> Tuple[Dict[bytes, Tuple[bytes, int]], int, int]:
    """Batched last-writer-wins replay over columnar device logs.

    ``base`` is the checkpoint image (key -> (value, ssn)); its entries join
    the reduction at position -1 so they win SSN ties against log writes,
    exactly like the scalar path's strict ``ssn > image.ssn`` guard.

    With ``use_kernel=True`` the guarded apply against the image runs through
    the Pallas SSN scatter-max kernel instead of the numpy reduction.

    ``record_mask`` (aligned with ``logs``; entries may be None) injects an
    extra per-record commit decision ANDed with the local §5 guard — the
    extension point sharded recovery uses to drop cross-shard records that
    are not durable on every participant (`repro.shard.recovery`).

    Command-framed records (adaptive logging) are masked out of the value
    reduction and re-executed afterwards in dependency-level batches against
    the reduced image — see the command re-execution section above.
    ``dep_lookup`` resolves a command pre-image that is in none of ``logs``
    or ``base`` (``key -> (value, ssn) | None``) — the replica applier
    passes its live table here, because chunks already applied in earlier
    polls hold the pre-images of later command records.

    Returns ``(data, n_replayed, n_skipped_uncommitted)``.
    """
    base = base or {}
    n_replayed = 0
    n_skipped = 0
    n_base = len(base)

    # command write lanes, deferred past the value reduction
    cmd_keys: List[bytes] = []
    cmd_parts: List[Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]] = []

    def _finish(
        data: Dict[bytes, Tuple[bytes, int]]
    ) -> Tuple[Dict[bytes, Tuple[bytes, int]], int, int]:
        if cmd_keys:
            depth, applied = _apply_commands_vectorized(
                data,
                cmd_keys,
                np.concatenate([p[0] for p in cmd_parts]),
                np.concatenate([p[1] for p in cmd_parts]),
                np.concatenate([p[2] for p in cmd_parts]),
                np.concatenate([p[3] for p in cmd_parts]),
                dep_lookup,
            )
            if REGISTRY.enabled:
                REGISTRY.gauge_max("adaptive.replay.cmd_depth", depth)
                REGISTRY.count("adaptive.replay.commands", applied)
        return data, n_replayed, n_skipped

    # surviving writes, columnar across sources: exact key identity (the
    # sentinel-terminated fixed-width encoding), SSN, value payload (object
    # array — only the winners' payloads are ever touched again)
    base_keys = list(base.keys())
    key_mats: List[np.ndarray] = [
        ColumnarLog.encode_keys_fixed(base_keys, [len(k) for k in base_keys])
    ]
    ssn_parts: List[np.ndarray] = [
        np.fromiter((s for _, s in base.values()), dtype=np.int64, count=n_base)
    ]
    val_parts: List[np.ndarray] = [
        np.fromiter((v for v, _ in base.values()), dtype=object, count=n_base)
    ]

    for li, log in enumerate(logs):
        ok = committed_mask(log, rsne)
        if record_mask is not None and record_mask[li] is not None:
            ok = ok & record_mask[li]
        n_ok = int(np.count_nonzero(ok))
        n_replayed += n_ok
        n_skipped += log.n_records - n_ok
        if not len(log.wr_rec):
            continue
        vals = log.values_obj
        wmask = ok[log.wr_rec]
        if log.n_command:
            wcmd = log.cmd_mask[log.wr_rec]
            sel = np.flatnonzero(wmask & wcmd)
            if len(sel):
                dep_w = _command_dep_per_write(log)
                cmd_keys.extend(k[:-1] for k in log.keys_fixed[sel].tolist())
                cmd_parts.append((
                    log.wr_ssn[sel],
                    log.cmd_op_col[log.wr_rec[sel]],
                    dep_w[sel],
                    vals[sel],       # the op param rides the value slot
                ))
            wmask = wmask & ~wcmd
        if wmask.all():
            key_mats.append(log.keys_fixed)
            ssn_parts.append(log.wr_ssn)
            val_parts.append(vals)
        else:
            key_mats.append(log.keys_fixed[wmask])
            ssn_parts.append(log.wr_ssn[wmask])
            val_parts.append(vals[wmask])

    n_total = sum(len(p) for p in ssn_parts)
    if n_total == 0:
        return _finish({})

    # common width, kept a multiple of 8 so the int64 word view is zero-copy
    width = -(-max(1, max(m.dtype.itemsize for m in key_mats)) // 8) * 8
    key_mat = np.concatenate([m.astype(f"S{width}", copy=False) for m in key_mats])
    ssn_arr = np.concatenate(ssn_parts)
    val_arr = np.concatenate(val_parts)
    pos_arr = np.empty(n_total, dtype=np.int64)
    pos_arr[:n_base] = -1                       # checkpoint wins SSN ties
    pos_arr[n_base:] = np.arange(n_total - n_base)

    winners, inv, n_slots = _group_winners(
        key_mat, ssn_arr, pos_arr, want_inv=use_kernel
    )

    # 'S' items come back NUL-stripped: dropping the final byte (the \x01
    # terminator) recovers the exact original key
    win_keys = key_mat[winners].tolist()

    if use_kernel and n_total > n_base and not (
        fits_i32(ssn_arr) and n_total - n_base < 2**31 and n_slots < 2**31
    ):
        # outside the kernel's int32 range (checkpoint or log SSNs, the
        # write count, or the slot count): the numpy reduction below is
        # equivalent — fall back
        use_kernel = False

    if not use_kernel or n_total == n_base:
        data = {}
        for k, v, s in zip(
            win_keys, val_arr[winners].tolist(), ssn_arr[winners].tolist()
        ):
            data[k[:-1]] = (v, s)
        return _finish(data)

    # --- compiled path: dense key ids + SSN-guarded scatter-max apply --------
    # both dims bucket-padded (slots to S with empty-slot identities, lanes
    # to N with overflow-slot lanes) so streaming callers — the replica
    # applier polls this with a different chunk size every round — reuse a
    # bounded set of compiled specializations
    from ..kernels.ops import fused_replay_apply
    from ..kernels.scatter_max import NO_POS

    s_pad = bucket(n_slots)
    image = np.empty((2, s_pad), np.int32)
    image[0] = -1
    image[1] = NO_POS
    base_slots = inv[:n_base]
    image[0, base_slots] = checked_i32(ssn_arr[:n_base], "checkpoint SSNs")
    image[1, base_slots] = -1
    base_idx_of_slot = np.full(n_slots, -1, np.int64)
    base_idx_of_slot[base_slots] = np.arange(n_base)

    scan = stack_i32(
        [inv[n_base:], ssn_arr[n_base:], pos_arr[n_base:]],
        bucket(n_total - n_base), fills=(s_pad, -1, int(NO_POS)),
    )
    out_ssn, out_pos = fused_replay_apply(image, scan)
    out_ssn = np.asarray(out_ssn)[:n_slots]
    out_pos = np.asarray(out_pos)[:n_slots]

    # winners[g] is a member of group g: use it for the exact key bytes
    data = {}
    for g, (p, s) in enumerate(zip(out_pos.tolist(), out_ssn.tolist())):
        if p == NO_POS:
            continue
        idx = int(base_idx_of_slot[g]) if p < 0 else n_base + p
        data[win_keys[g][:-1]] = (val_arr[idx], s)
    return _finish(data)


# --- compiled fused replay (tile decode -> hash-slot scan -> merge) -----------

# below this lane count the device round-trip (dispatch + transfer) costs more
# than the numpy reduction it replaces; tiles this small reduce on the host
_FUSED_MIN_LANES = 1024


def _fused_tile_winners(tile: FastTile, rsne: int) -> Tuple[np.ndarray, int, int]:
    """Per-key last-writer-wins winners among one tile's committed write
    lanes, via the compiled hash-slot scan (:func:`repro.kernels.ops.
    fused_replay_scan`).

    Device side: every lane scatters ``(hash-slot, ssn, pos)`` into a
    power-of-two slot table under the ``(max ssn, then min pos)`` lattice —
    one bucket-padded int32 transfer, one compiled scatter.  Host side: the
    winning lane of each slot is recovered by value-matching, then the two
    ways hashing can mislead are repaired *exactly*:

    * **slot spill** — distinct keys sharing a slot (expected at ~1/2 load
      factor): every lane whose 64-bit key hash differs from its slot
      winner's was suppressed by a different key; those lanes re-reduce
      through the exact :func:`_group_winners` (a key's lanes are either all
      owner-hash or all spilled, so each side sees complete key groups);
    * **hash collision** — distinct keys with equal 64-bit hashes
      (astronomically rare): detected by word-comparing same-hash lanes
      against their slot winner, and the whole tile falls back to the exact
      reduction.

    Returns ``(winner lane indices, n_replayed, n_skipped)`` — lane indices
    into the tile's write-lane arrays, records counted per the §5 guard.
    """
    ok = tile.committed_mask(rsne)
    n_rep = int(np.count_nonzero(ok))
    n_skip = tile.n_records - n_rep
    n_lanes = len(tile.wr_rec)
    if n_lanes == 0:
        return np.empty(0, np.int64), n_rep, n_skip
    if n_rep == tile.n_records:
        lanes = np.arange(n_lanes, dtype=np.int64)
        keys, ssn = tile.keys_fixed, tile.wr_ssn
    else:
        lanes = np.flatnonzero(ok[tile.wr_rec])
        keys, ssn = tile.keys_fixed[lanes], tile.wr_ssn[lanes]
    n = len(lanes)
    if n == 0:
        return lanes, n_rep, n_skip
    pos = np.arange(n, dtype=np.int64)
    if n < _FUSED_MIN_LANES or not fits_i32(ssn):
        w, _, _ = _group_winners(keys, ssn, pos)
        return lanes[w], n_rep, n_skip

    from ..kernels.ops import fused_replay_scan
    from ..kernels.scatter_max import NO_POS

    words = _key_words(keys)
    h = _hash_words(words)
    n_slots = 2 * bucket(n)            # ~1/2 load factor keeps spills rare
    slot = (h.view(np.uint64) & np.uint64(n_slots - 1)).view(np.int64)
    scan = stack_i32([slot, ssn, pos], bucket(n),
                     fills=(n_slots, -1, int(NO_POS)))
    out_ssn, out_pos = fused_replay_scan(scan, n_slots=n_slots)
    out_ssn = np.asarray(out_ssn).astype(np.int64)
    out_pos = np.asarray(out_pos).astype(np.int64)

    win_idx = np.flatnonzero((ssn == out_ssn[slot]) & (pos == out_pos[slot]))
    owner_of_slot = np.empty(n_slots, np.int64)
    owner_of_slot[slot[win_idx]] = win_idx
    owner = owner_of_slot[slot]        # each lane's slot-winning lane
    same_h = h == h[owner]
    if bool((same_h & ~(words == words[owner]).all(axis=1)).any()):
        # true 64-bit hash collision: two distinct keys merged into one
        # hash group — resolve the whole tile exactly
        w, _, _ = _group_winners(keys, ssn, pos)
        return lanes[w], n_rep, n_skip
    spill = np.flatnonzero(~same_h)
    if len(spill):
        w_sp, _, _ = _group_winners(keys[spill], ssn[spill], pos[spill])
        win_idx = np.concatenate([win_idx, spill[w_sp]])
    return lanes[win_idx], n_rep, n_skip


def _apply_tile_winners(
    data: Dict[bytes, Tuple[bytes, int]], tile: FastTile, lanes: np.ndarray
) -> None:
    """Merge one tile's per-key winners into the running image under the
    strict-`>` SSN guard (the scalar rule: the image — which starts as the
    checkpoint — wins ties; cross-tile same-key ties cannot happen because
    per-key SSNs strictly increase).  Values materialize lazily here, only
    for lanes that won their tile."""
    if not len(lanes):
        return
    keys = tile.keys_fixed[lanes].tolist()
    ssns = tile.wr_ssn[lanes].tolist()
    for k, s, v in zip(keys, ssns, tile.values_for(lanes)):
        key = k[:-1]                  # drop the \x01 terminator
        cur = data.get(key)
        if cur is None or s > cur[1]:
            data[key] = (v, s)


def _recover_fused(
    state: RecoveredState,
    devices: Sequence[StorageDevice],
    floors: Sequence[int],
    parallel: bool,
) -> bool:
    """The compiled recovery pipeline (``mode="pallas"``).

    Stage order is dictated by the §5 guard: the **tails** decode first —
    each device's durable SSN frontier pins RSNe, and an empty tail reads
    its frontier off the newest seal stamp in the manifest — then the sealed
    tiles stream through decode→scan→merge, prefetch-decoded on worker
    threads (seal-crc verified, per-frame crc skipped) while the main thread
    runs the previous tile's fused scan and merge.  Sealed segments end at
    record boundaries, so tiles are independent and the merge is order-free.

    Returns False — leaving ``state.data`` untouched — when anything is out
    of profile (a device without a segment chain, XSHARD records, a sealed
    blob that decodes short): the caller redoes recovery on the generic
    columnar path, which handles all of those, with identical semantics.
    """
    if not all(hasattr(d, "read_segment_entries") for d in devices):
        return False
    per_dev = [d.read_segment_entries() for d in devices]

    tail_tiles: List[FastTile] = []
    for ents in per_dev:
        t = decode_fast_tile(ents[-1][0])
        if t is None:
            return False
        tail_tiles.append(t)
    rsne = None
    for ents, tt, floor in zip(per_dev, tail_tiles, floors):
        if tt.n_records:
            last = tt.last_ssn
        elif len(ents) > 1:
            last = int(ents[-2][2])   # newest sealed segment's seal stamp
        else:
            last = 0
        last = max(last, floor)
        rsne = last if rsne is None else min(rsne, last)
    state.rsne = rsne or 0

    sealed = [ents[i][:2] for ents in per_dev for i in range(len(ents) - 1)]
    data: Dict[bytes, Tuple[bytes, int]] = dict(state.data)
    n_rep = n_skip = 0

    def _decode(ent: Tuple[bytes, Optional[int]]):
        return decode_fast_tile(ent[0], crc=ent[1]), len(ent[0])

    ex = None
    if parallel and len(sealed) > 1:
        from concurrent.futures import ThreadPoolExecutor
        ex = ThreadPoolExecutor(max_workers=2)
        tiles_iter = ex.map(_decode, sealed)
    else:
        tiles_iter = map(_decode, sealed)
    try:
        for tile, blob_len in tiles_iter:
            if tile is None or tile.consumed < blob_len:
                return False          # out of profile / short sealed blob
            lanes, r, s = _fused_tile_winners(tile, state.rsne)
            _apply_tile_winners(data, tile, lanes)
            n_rep += r
            n_skip += s
    finally:
        if ex is not None:
            ex.shutdown(wait=False)
    for tt in tail_tiles:
        lanes, r, s = _fused_tile_winners(tt, state.rsne)
        _apply_tile_winners(data, tt, lanes)
        n_rep += r
        n_skip += s
    state.data = data
    state.n_replayed = n_rep
    state.n_skipped_uncommitted = n_skip
    return True


# --- top-level recovery -------------------------------------------------------

def _load_per_device(devices: Sequence[StorageDevice], decode, parallel: bool) -> List:
    out: List = [None] * len(devices)

    def _load(i: int) -> None:
        out[i] = decode(devices[i].read_all())

    parallel_for(len(devices), _load, parallel)
    return out


def load_columnar_segmented(
    devices: Sequence[StorageDevice], parallel: bool,
    segments: Optional[List[Dict]] = None,
) -> List[ColumnarLog]:
    """Segment-parallel columnar decode: every (device, segment) pair decodes
    on its own thread and the chunks splice back per device in chain order.

    Sealed segments end at record boundaries, so each blob is an independent
    framed stream; only the tail blob can carry a torn frame, and it is the
    last chunk, so per-segment truncation semantics equal whole-log decode.
    Devices without a segment chain (journal lanes, test doubles) fall back
    to one blob via ``read_all``.

    ``segments``, when given, is extended with one per-(device, segment)
    timing row (the :class:`RecoveryReport` decode breakdown).
    """
    blobs: List[List[bytes]] = [
        d.read_segment_blobs() if hasattr(d, "read_segment_blobs")
        else [d.read_all()]
        for d in devices
    ]
    flat = [(di, si) for di, bs in enumerate(blobs) for si in range(len(bs))]
    decoded: List[Optional[Tuple[ColumnarLog, int]]] = [None] * len(flat)
    seg_s = [0.0] * len(flat)

    def _decode(j: int) -> None:
        di, si = flat[j]
        t0 = time.perf_counter()
        decoded[j] = decode_columnar_stream(blobs[di][si])
        seg_s[j] = time.perf_counter() - t0

    parallel_for(len(flat), _decode, parallel)

    if segments is not None:
        for j, (di, si) in enumerate(flat):
            segments.append({
                "device": di, "segment": si,
                "bytes": len(blobs[di][si]),
                "records": decoded[j][0].n_records,
                "seconds": seg_s[j],
            })

    out: List[ColumnarLog] = []
    j = 0
    for bs in blobs:
        chunk = decoded[j : j + len(bs)]
        j += len(bs)
        # a blob that did not fully decode ends this device's stream: a
        # whole-log decode would stop at that frame too (only the final,
        # tail blob can legitimately end torn)
        keep: List[ColumnarLog] = []
        for (log, consumed), blob in zip(chunk, bs):
            keep.append(log)
            if consumed < len(blob):
                break
        out.append(keep[0] if len(keep) == 1 else ColumnarLog.concat(keep))
    return out


def recover(
    devices: Sequence[StorageDevice],
    checkpoint_dir: Optional[str] = None,
    parallel: bool = True,
    mode: str = "vectorized",
) -> RecoveredState:
    """Restore a consistent state from checkpoint files + device logs.

    ``mode`` selects the replay engine: ``"vectorized"`` (default, batched
    numpy last-writer-wins), ``"pallas"`` (batched + Pallas scatter-max
    apply), or ``"scalar"`` (the per-record oracle).  All modes are
    equivalent; ``parallel`` controls decode threading — the vectorized
    paths decode per (device, sealed segment) pair, so a long-lived
    segmented log fans decode wider than one thread per device — and, for
    the scalar mode, per-device replay threading.

    Truncated logs (see `repro.core.truncate.LogTruncator`) recover from
    ``(checkpoint image, retained log suffix)``: pass the ``checkpoint_dir``
    the truncator was anchored to — its image covers everything the dropped
    segments held, and fully-truncated devices contribute their persisted
    ``truncated_ssn`` floor to RSNe instead of pinning it to 0.
    """
    if mode not in ("vectorized", "pallas", "scalar"):
        raise ValueError(f"unknown recovery mode {mode!r}")
    state = RecoveredState()
    report = state.report = RecoveryReport(mode=mode, n_devices=len(devices))

    # --- stage 1: checkpoint recovery -------------------------------------
    ckpt: Optional[CheckpointData] = None
    if checkpoint_dir is not None:
        ckpt = load_latest_checkpoint(checkpoint_dir, parallel=parallel)
    if ckpt is not None:
        state.rsns = ckpt.rsn
        state.data.update(ckpt.data)
        report.rsns = ckpt.rsn
        report.checkpoint_keys = len(ckpt.data)

    def _finalize() -> RecoveredState:
        report.rsne = state.rsne
        report.n_replayed = state.n_replayed
        report.n_dropped_above_rsne = state.n_skipped_uncommitted
        return state

    # --- stage 2: log recovery --------------------------------------------
    floors = device_ssn_floors(devices)
    _trace = TRACER.enabled
    if mode == "scalar":
        _t0 = time.perf_counter()
        device_records = _load_per_device(devices, decode_records, parallel)
        state.rsne = compute_rsne(device_records, floors=floors)
        _t1 = time.perf_counter()
        report.decode_s = _t1 - _t0
        report.n_decoded = sum(len(r) for r in device_records)
        if _trace:
            TRACER.record(
                ST_RDECODE, device=len(devices), t0=_t0, t1=_t1,
                n_txn=report.n_decoded,
            )
        _replay_scalar(state, device_records, state.rsne, parallel)
        report.replay_s = time.perf_counter() - _t1
        if _trace:
            TRACER.record(
                ST_RREPLAY, txn_hi=state.rsne, t0=_t1,
                t1=_t1 + report.replay_s, n_txn=state.n_replayed,
            )
        return _finalize()

    if mode == "pallas":
        _t0 = time.perf_counter()
        if _recover_fused(state, devices, floors, parallel):
            report.fused = True
            # one tiled decode→scan→merge sweep: decode and replay are
            # pipelined, so the wall time is attributed to replay
            report.replay_s = time.perf_counter() - _t0
            report.n_decoded = state.n_replayed + state.n_skipped_uncommitted
            if _trace:
                # (aux=1 marks the fused engine)
                TRACER.record(
                    ST_RREPLAY, txn_hi=state.rsne, t0=_t0,
                    t1=_t0 + report.replay_s, n_txn=state.n_replayed, aux=1,
                )
            return _finalize()

    _t0 = time.perf_counter()
    logs: List[ColumnarLog] = load_columnar_segmented(
        devices, parallel, segments=report.segments
    )
    state.rsne = compute_rsne(logs, floors=floors)
    _t1 = time.perf_counter()
    report.decode_s = _t1 - _t0
    report.n_decoded = sum(lg.n_records for lg in logs)
    if _trace:
        TRACER.record(
            ST_RDECODE, device=len(devices), t0=_t0, t1=_t1,
            nbytes=sum(d.durable_bytes() for d in devices
                       if hasattr(d, "durable_bytes")),
            n_txn=report.n_decoded,
        )
    data, n_replayed, n_skipped = replay_columnar(
        logs, state.rsne, base=state.data or None, use_kernel=(mode == "pallas")
    )
    state.data = data
    state.n_replayed = n_replayed
    state.n_skipped_uncommitted = n_skipped
    report.replay_s = time.perf_counter() - _t1
    if _trace:
        TRACER.record(
            ST_RREPLAY, txn_hi=state.rsne, t0=_t1,
            t1=_t1 + report.replay_s, n_txn=n_replayed, aux=n_skipped,
        )
    return _finalize()
