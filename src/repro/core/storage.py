"""Emulated storage devices for the Poplar engine.

The container has no PCIe SSDs or NVDIMMs, so devices are emulated with the
paper's own constants (§6.1):

* ``SSD``  — 1.2 GB/s peak sequential write, 21.5 µs latency per sequential
  16 KB block write.
* ``NVM``  — ~2x DRAM latency; modelled as a fixed per-persist latency of
  ~0.2 µs (the paper emulates it with a busy-wait loop calibrated from PMEP).

Every device supports two clock modes:

* ``real``    — writes go to a backing file (durable, used by recovery tests
  and the examples) and the emulated device time is *slept*, releasing the
  GIL so that multi-device IO concurrency is physically real even on 1 core.
* ``virtual`` — no sleeping; the device accumulates busy-time in a local
  virtual clock.  Benchmarks use this to derive device-bandwidth numbers
  (fig 6) deterministically.

Write calls are serialized per device (a device has one head); this models
the single logger-thread-per-device binding of the paper.

Log lifecycle (§5 applied to the devices): a device is a chain of immutable
**sealed segments** plus one active **tail**, all addressed by *logical*
offsets that never move — ``read_from``/``size`` and the whole SSN machinery
are oblivious to where a byte physically lives.  :meth:`StorageDevice.seal`
freezes the tail into a sealed segment (stamped with the SSN of its last
record, which the caller — the logger, who owns the DSN — supplies);
:meth:`StorageDevice.truncate_to_ssn` atomically drops the prefix of sealed
segments whose records all fall at or below a safe SSN (the checkpoint-
anchored point `repro.core.truncate.LogTruncator` computes).  A reader
asking for truncated bytes gets :class:`TruncatedLogError` — a hole is an
error, never silently empty — and recovers via checkpoint catch-up
(`repro.replica.replica.Replica`).  Path-backed devices persist the chain in
a ``<path>.segments.json`` manifest (written atomically) so a reopened
device knows its base offset and RSNe floor across a real crash.
"""

from __future__ import annotations

import bisect
import json
import os
import threading
import time
import zlib
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple


class TruncatedLogError(Exception):
    """A read asked for log bytes below the device's truncation point.

    Raised instead of returning a hole: the caller (a lagging log shipper, a
    stale journal tailer) must re-base from a checkpoint — the dropped bytes
    are, by the truncator's safe-point rule, fully covered by it.
    """

    def __init__(self, offset: int, base: int):
        super().__init__(
            f"log offset {offset} predates the truncation point {base}"
        )
        self.offset = offset
        self.base = base


@dataclass
class DeviceSpec:
    name: str
    bandwidth_bytes_per_s: float
    latency_s: float           # fixed per-write latency
    sync_granularity: int = 1  # min bytes accounted per write

    @staticmethod
    def ssd() -> "DeviceSpec":
        # §6.1: 1.2 GB/s sequential write, 21.5us for a 16KB block.
        # REPRO_SSD_BW rescales bandwidth: benchmarks on this 1-core container
        # shrink it (default 30 MB/s there) so the IO-bound regime the paper
        # measures is reached below the GIL-bound txn rate — variant *ratios*
        # are the reproduction target (DESIGN §9).
        bw = float(os.environ.get("REPRO_SSD_BW", 1.2e9))
        return DeviceSpec("ssd", bw, 21.5e-6)

    @staticmethod
    def nvm() -> "DeviceSpec":
        # §6.1: 2x DRAM latency; ~0.2us per persist barrier (mfence+clwb scale)
        return DeviceSpec("nvm", 20e9, 0.2e-6)

    @staticmethod
    def null() -> "DeviceSpec":
        return DeviceSpec("null", float("inf"), 0.0)

    def write_time(self, nbytes: int) -> float:
        if self.bandwidth_bytes_per_s == float("inf"):
            return self.latency_s
        return self.latency_s + nbytes / self.bandwidth_bytes_per_s


@dataclass
class LogSegment:
    """One immutable sealed segment of a device log.

    ``start``/``end`` are logical byte offsets (``end`` exclusive);
    ``last_ssn`` is the SSN of the newest record the segment holds — because
    per-device SSNs are monotone in flush order, ``last_ssn <= safe`` means
    *every* record in the segment is at or below ``safe``, which is the whole
    truncation decision.  Sealing happens at flushed record boundaries only,
    so a sealed segment always holds complete frames.
    """

    start: int
    end: int
    last_ssn: int
    path: Optional[str] = None            # backing file (path-backed devices)
    chunks: List[bytes] = field(default_factory=list)  # in-memory devices
    # crc32 of the segment's bytes, computed incrementally as the tail is
    # written and frozen at seal time.  Recovery verifies it with one
    # C-speed pass over the blob and can then skip per-frame crc checks in
    # the vectorized tile decode (`repro.core.fastdecode`); ``None`` (a
    # pre-crc manifest) falls back to per-frame verification.
    crc: Optional[int] = None

    @property
    def nbytes(self) -> int:
        return self.end - self.start

    def read(self) -> bytes:
        if self.path is not None:
            with open(self.path, "rb") as f:
                return f.read()
        return b"".join(self.chunks)


class StorageDevice:
    """An append-only log device with emulated timing.

    ``write(data)`` appends and *persists* ``data``; on return the data is
    durable (fsync semantics).  Timing is emulated per the DeviceSpec.
    """

    def __init__(
        self,
        spec: DeviceSpec,
        path: Optional[str] = None,
        clock: str = "real",
    ):
        self.spec = spec
        self.path = path
        self.clock = clock
        self._lock = threading.Lock()
        self.bytes_written = 0
        self.n_writes = 0
        self.busy_time = 0.0       # virtual busy time (seconds)
        # --- segment chain state ------------------------------------------
        self._sealed: List[LogSegment] = []
        self._tail_start = 0       # logical offset of the tail's first byte
        self._tail_bytes = 0       # bytes in the active tail
        # lifecycle watermarks, persisted in the manifest: the last SSN and
        # byte count ever dropped by truncation.  ``truncated_ssn`` is this
        # device's RSNe floor — with the whole log truncated away, the last
        # durable record's SSN is exactly the newest dropped segment's.
        self.truncated_ssn = 0
        self.truncated_bytes = 0
        self.n_seals = 0
        self.n_truncations = 0
        self._buf: List[bytes] = []  # in-memory tail chunks when no path
        self._buf_starts: List[int] = []  # logical start offset of each chunk
        self._tail_crc = 0         # running crc32 of the active tail's bytes
        if path is not None:
            self._load_manifest()
            self._fh = open(path, "ab")
            self._tail_bytes = os.path.getsize(path)
            if self._tail_bytes:
                # reopened with a pre-existing tail: rebuild the running crc
                # so a later seal() stamps the correct whole-segment value
                with open(path, "rb") as f:
                    self._tail_crc = zlib.crc32(f.read())
        else:
            self._fh = None

    # --- manifest (path-backed persistence of the segment chain) ----------
    def _manifest_path(self) -> str:
        return self.path + ".segments.json"

    def _load_manifest(self) -> None:
        try:
            with open(self._manifest_path()) as f:
                m = json.load(f)
        except (FileNotFoundError, json.JSONDecodeError):
            return
        self._tail_start = m["tail_start"]
        self.truncated_ssn = m.get("truncated_ssn", 0)
        self.truncated_bytes = m.get("truncated_bytes", 0)
        self._sealed = [
            LogSegment(s["start"], s["end"], s["last_ssn"], path=s["path"],
                       crc=s.get("crc"))
            for s in m["sealed"]
        ]

    def _write_manifest(self) -> None:
        """Atomically publish the chain (sealed list + tail base).  Called
        under the device lock, on every seal/truncate.  Crash ordering: the
        manifest is renamed into place *before* sealed files are unlinked, so
        a crash can orphan a data file (harmless, rediscovery is manifest-
        driven) but never reference a missing one."""
        m = {
            "tail_start": self._tail_start,
            "truncated_ssn": self.truncated_ssn,
            "truncated_bytes": self.truncated_bytes,
            "sealed": [
                {"start": s.start, "end": s.end, "last_ssn": s.last_ssn,
                 "path": s.path, "crc": s.crc}
                for s in self._sealed
            ],
        }
        tmp = self._manifest_path() + ".tmp"
        with open(tmp, "w") as f:
            json.dump(m, f)
            f.flush()
            os.fsync(f.fileno())
        os.rename(tmp, self._manifest_path())

    # --- write path --------------------------------------------------------
    def write(self, data: bytes) -> None:
        """Durably append ``data``. Blocks for the emulated device time."""
        t = self.spec.write_time(len(data))
        with self._lock:
            if self._fh is not None:
                self._fh.write(data)
                self._fh.flush()
                os.fsync(self._fh.fileno())
            else:
                self._buf.append(data)
                self._buf_starts.append(self._tail_start + self._tail_bytes)
            self._tail_crc = zlib.crc32(data, self._tail_crc)
            self._tail_bytes += len(data)
            self.bytes_written += len(data)
            self.n_writes += 1
            self.busy_time += t
        if self.clock == "real" and t > 0:
            time.sleep(t)

    # --- lifecycle: sealing and truncation ---------------------------------
    def seal(self, last_ssn: int) -> Optional[LogSegment]:
        """Freeze the active tail into an immutable sealed segment.

        ``last_ssn`` must be the SSN of the newest record the tail holds —
        the caller is whoever owns the flush path (the logger's DSN, held
        consistent under the buffer's flush lock), because the device is
        byte-oriented and cannot know.  Must only be called at a record
        boundary (everything flushed so far is complete frames; the engine
        guarantees this by sealing right after ``flush_ready``).

        Logical offsets are untouched: the new tail starts where the sealed
        segment ends.  Returns the new segment, or None for an empty tail.
        """
        with self._lock:
            if self._tail_bytes == 0:
                return None
            start, end = self._tail_start, self._tail_start + self._tail_bytes
            crc = self._tail_crc
            if self.path is not None:
                seg_path = f"{self.path}.seg-{start:020d}"
                self._fh.close()
                os.rename(self.path, seg_path)
                seg = LogSegment(start, end, last_ssn, path=seg_path, crc=crc)
                self._sealed.append(seg)
                self._tail_start, self._tail_bytes = end, 0
                self._tail_crc = 0
                self._fh = open(self.path, "ab")
                self._write_manifest()
            else:
                seg = LogSegment(start, end, last_ssn, chunks=self._buf,
                                 crc=crc)
                self._sealed.append(seg)
                self._buf, self._buf_starts = [], []
                self._tail_start, self._tail_bytes = end, 0
                self._tail_crc = 0
            self.n_seals += 1
            return seg

    def truncate_to_ssn(
        self, safe_ssn: int, keep_from: Optional[int] = None
    ) -> Tuple[int, int]:
        """Atomically drop the prefix of sealed segments whose records are
        all at or below ``safe_ssn`` (monotone SSNs make that exactly
        ``last_ssn <= safe_ssn``).  ``keep_from`` optionally stops earlier —
        the sharded truncator uses it to pin a segment whose cross-shard
        records are not yet checkpoint-covered on every participant.

        Only whole sealed segments are ever dropped, never the tail, and
        only as a prefix — the retained log is always a contiguous,
        hole-free suffix.  Returns ``(segments_dropped, bytes_dropped)``.
        """
        with self._lock:
            n_drop = 0
            for i, seg in enumerate(self._sealed):
                if seg.last_ssn > safe_ssn:
                    break
                if keep_from is not None and i >= keep_from:
                    break
                n_drop = i + 1
            if n_drop == 0:
                return 0, 0
            dropped, self._sealed = self._sealed[:n_drop], self._sealed[n_drop:]
            nbytes = sum(s.nbytes for s in dropped)
            self.truncated_ssn = dropped[-1].last_ssn
            self.truncated_bytes += nbytes
            self.n_truncations += 1
            if self.path is not None:
                # manifest first: a crash mid-unlink leaves orphan files the
                # manifest no longer references, never dangling references
                self._write_manifest()
                for s in dropped:
                    try:
                        os.remove(s.path)
                    except OSError:
                        pass
            return n_drop, nbytes

    def base_offset(self) -> int:
        """Logical offset of the oldest retained byte (the truncation point)."""
        with self._lock:
            return self._base_locked()

    def _base_locked(self) -> int:
        return self._sealed[0].start if self._sealed else self._tail_start

    def segments(self) -> List[Tuple[int, int, int]]:
        """``(start, end, last_ssn)`` of every sealed segment (tail excluded)."""
        with self._lock:
            return [(s.start, s.end, s.last_ssn) for s in self._sealed]

    def read_sealed_blob(self, index: int) -> Optional[bytes]:
        """Bytes of the ``index``-th sealed segment, or None if the chain
        shrank (a concurrent truncation) — the lazy single-segment read the
        sharded truncator uses to inspect only droppable candidates."""
        with self._lock:
            if index >= len(self._sealed):
                return None
            return self._sealed[index].read()

    def tail_bytes(self) -> int:
        """Bytes in the active (unsealed) tail."""
        with self._lock:
            return self._tail_bytes

    def disk_bytes(self) -> int:
        """Bytes the device currently retains (sealed chain + tail) — the
        on-disk footprint truncation bounds."""
        with self._lock:
            return sum(s.nbytes for s in self._sealed) + self._tail_bytes

    # --- read path ---------------------------------------------------------
    def size(self) -> int:
        """Durable byte count (the log's logical append frontier).

        Computed entirely under the device lock from the internal offset
        accounting — stat-ing the backing file after releasing the lock
        raced a concurrent :meth:`write` and could report a frontier that
        includes a torn in-flight append.
        """
        with self._lock:
            if self._fh is not None:
                self._fh.flush()
            return self._tail_start + self._tail_bytes

    def read_from(self, offset: int) -> bytes:
        """Durable bytes from ``offset`` to the current frontier.

        The incremental read primitive of log shipping
        (:class:`repro.replica.LogShipper`): a tailer calls this with its
        consumed offset and gets only the delta, so repeatedly polling a
        growing log is O(new bytes), not O(log) per poll (``read_all`` in a
        loop re-reads the whole image every time).

        Raises :class:`TruncatedLogError` when ``offset`` predates the
        truncation point — the caller's bytes are gone and it must re-base
        from a checkpoint.
        """
        # everything — sealed reads *and* the tail read — happens under the
        # device lock: a concurrent seal() renames the tail file and a
        # concurrent truncate_to_ssn() unlinks sealed files, so reading
        # after releasing the lock could splice the *new* (re-opened) tail's
        # bytes at the old logical offset or hit a vanished path.  Writes
        # already do their IO under this lock; readers are no different.
        with self._lock:
            base = self._base_locked()
            if offset < base:
                raise TruncatedLogError(offset, base)
            if self._fh is not None:
                self._fh.flush()
            parts: List[bytes] = []
            for seg in self._sealed:
                if seg.end <= offset:
                    continue
                data = seg.read()
                parts.append(data[max(0, offset - seg.start):])
            if self.path is None:
                if offset > self._tail_start:
                    i = bisect.bisect_right(self._buf_starts, offset) - 1
                    if i >= 0:
                        out = b"".join(self._buf[i:])
                        parts.append(out[offset - self._buf_starts[i]:])
                else:
                    parts.extend(self._buf)
            else:
                with open(self.path, "rb") as f:
                    f.seek(max(0, offset - self._tail_start))
                    parts.append(f.read())
            return b"".join(parts)

    def read_all(self) -> bytes:
        """Return the full retained durable image, i.e. everything from the
        truncation point on (recovery path)."""
        return self.read_from(self.base_offset())

    def read_segment_blobs(self) -> List[bytes]:
        """The retained log as per-segment byte blobs (sealed chain, then
        tail) — the unit of segment-parallel recovery decode.  Sealed
        segments hold complete frames, so each blob decodes independently
        and the decoded chunks concatenate in chain order."""
        with self._lock:           # see read_from for why IO stays inside
            if self._fh is not None:
                self._fh.flush()
            blobs = [s.read() for s in self._sealed]
            if self.path is None:
                blobs.append(b"".join(self._buf))
            else:
                with open(self.path, "rb") as f:
                    blobs.append(f.read())
            return blobs

    def read_segment_entries(
        self,
    ) -> List[Tuple[bytes, Optional[int], Optional[int]]]:
        """Like :meth:`read_segment_blobs` but pairing each blob with its
        seal-time crc32 and ``last_ssn`` (both ``None`` for the tail, which
        can be torn and has no seal stamp; crc also ``None`` for segments
        from pre-crc manifests).  The compiled recovery pipeline verifies a
        sealed blob with one ``zlib.crc32`` call — skipping the per-frame
        crc loop of the tile decode — and reads the device's durable SSN
        frontier off the seal stamps when the tail is empty."""
        with self._lock:           # see read_from for why IO stays inside
            if self._fh is not None:
                self._fh.flush()
            entries: List[Tuple[bytes, Optional[int], Optional[int]]] = [
                (s.read(), s.crc, s.last_ssn) for s in self._sealed
            ]
            if self.path is None:
                entries.append((b"".join(self._buf), None, None))
            else:
                with open(self.path, "rb") as f:
                    entries.append((f.read(), None, None))
            return entries

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    # --- stats -----------------------------------------------------------
    def stats(self) -> Dict[str, float]:
        return {
            "bytes_written": self.bytes_written,
            "n_writes": self.n_writes,
            "busy_time_s": self.busy_time,
            "avg_write_bytes": self.bytes_written / max(1, self.n_writes),
            "n_sealed_segments": len(self._sealed),
            "truncated_bytes": self.truncated_bytes,
        }


def make_devices(
    n: int,
    kind: str = "ssd",
    directory: Optional[str] = None,
    clock: str = "real",
    prefix: str = "log",
) -> List[StorageDevice]:
    """Create ``n`` devices of ``kind`` ('ssd' | 'nvm' | 'null')."""
    spec = {"ssd": DeviceSpec.ssd, "nvm": DeviceSpec.nvm, "null": DeviceSpec.null}[kind]()
    if directory is not None:
        os.makedirs(directory, exist_ok=True)
    devs = []
    for i in range(n):
        path = os.path.join(directory, f"{prefix}_{i}.bin") if directory else None
        devs.append(StorageDevice(spec, path=path, clock=clock))
    return devs
