"""Emulated storage devices for the Poplar engine.

The container has no PCIe SSDs or NVDIMMs, so devices are emulated with the
paper's own constants (§6.1):

* ``SSD``  — 1.2 GB/s peak sequential write, 21.5 µs latency per sequential
  16 KB block write.
* ``NVM``  — ~2x DRAM latency; modelled as a fixed per-persist latency of
  ~0.2 µs (the paper emulates it with a busy-wait loop calibrated from PMEP).

Every device supports two clock modes:

* ``real``    — writes go to a backing file (durable, used by recovery tests
  and the examples) and the emulated device time is *slept*, releasing the
  GIL so that multi-device IO concurrency is physically real even on 1 core.
* ``virtual`` — no sleeping; the device accumulates busy-time in a local
  virtual clock.  Benchmarks use this to derive device-bandwidth numbers
  (fig 6) deterministically.

Write calls are serialized per device (a device has one head); this models
the single logger-thread-per-device binding of the paper.
"""

from __future__ import annotations

import bisect
import os
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional


@dataclass
class DeviceSpec:
    name: str
    bandwidth_bytes_per_s: float
    latency_s: float           # fixed per-write latency
    sync_granularity: int = 1  # min bytes accounted per write

    @staticmethod
    def ssd() -> "DeviceSpec":
        # §6.1: 1.2 GB/s sequential write, 21.5us for a 16KB block.
        # REPRO_SSD_BW rescales bandwidth: benchmarks on this 1-core container
        # shrink it (default 30 MB/s there) so the IO-bound regime the paper
        # measures is reached below the GIL-bound txn rate — variant *ratios*
        # are the reproduction target (DESIGN §9).
        bw = float(os.environ.get("REPRO_SSD_BW", 1.2e9))
        return DeviceSpec("ssd", bw, 21.5e-6)

    @staticmethod
    def nvm() -> "DeviceSpec":
        # §6.1: 2x DRAM latency; ~0.2us per persist barrier (mfence+clwb scale)
        return DeviceSpec("nvm", 20e9, 0.2e-6)

    @staticmethod
    def null() -> "DeviceSpec":
        return DeviceSpec("null", float("inf"), 0.0)

    def write_time(self, nbytes: int) -> float:
        if self.bandwidth_bytes_per_s == float("inf"):
            return self.latency_s
        return self.latency_s + nbytes / self.bandwidth_bytes_per_s


class StorageDevice:
    """An append-only log device with emulated timing.

    ``write(data)`` appends and *persists* ``data``; on return the data is
    durable (fsync semantics).  Timing is emulated per the DeviceSpec.
    """

    def __init__(
        self,
        spec: DeviceSpec,
        path: Optional[str] = None,
        clock: str = "real",
    ):
        self.spec = spec
        self.path = path
        self.clock = clock
        self._lock = threading.Lock()
        self.bytes_written = 0
        self.n_writes = 0
        self.busy_time = 0.0       # virtual busy time (seconds)
        self._buf: List[bytes] = []  # in-memory durable image when no path
        self._buf_starts: List[int] = []  # logical start offset of each chunk
        self._buf_len = 0
        self._fh = open(path, "ab") if path else None

    def write(self, data: bytes) -> None:
        """Durably append ``data``. Blocks for the emulated device time."""
        t = self.spec.write_time(len(data))
        with self._lock:
            if self._fh is not None:
                self._fh.write(data)
                self._fh.flush()
                os.fsync(self._fh.fileno())
            else:
                self._buf.append(data)
                self._buf_starts.append(self._buf_len)
                self._buf_len += len(data)
            self.bytes_written += len(data)
            self.n_writes += 1
            self.busy_time += t
        if self.clock == "real" and t > 0:
            time.sleep(t)

    def size(self) -> int:
        """Durable byte count (the log's append frontier)."""
        with self._lock:
            if self._fh is not None:
                self._fh.flush()
            if self.path is None:
                return self._buf_len
        return os.path.getsize(self.path)

    def read_from(self, offset: int) -> bytes:
        """Durable bytes from ``offset`` to the current frontier.

        The incremental read primitive of log shipping
        (:class:`repro.replica.LogShipper`): a tailer calls this with its
        consumed offset and gets only the delta, so repeatedly polling a
        growing log is O(new bytes), not O(log) per poll (``read_all`` in a
        loop re-reads the whole image every time).
        """
        with self._lock:
            if self._fh is not None:
                self._fh.flush()
            if self.path is None:
                if offset >= self._buf_len:
                    return b""
                # first chunk whose range covers `offset`
                i = bisect.bisect_right(self._buf_starts, offset) - 1
                out = b"".join(self._buf[i:])
                return out[offset - self._buf_starts[i]:]
        with open(self.path, "rb") as f:
            f.seek(offset)
            return f.read()

    def read_all(self) -> bytes:
        """Return the full durable image (recovery path)."""
        return self.read_from(0)

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    # --- stats -----------------------------------------------------------
    def stats(self) -> Dict[str, float]:
        return {
            "bytes_written": self.bytes_written,
            "n_writes": self.n_writes,
            "busy_time_s": self.busy_time,
            "avg_write_bytes": self.bytes_written / max(1, self.n_writes),
        }


def make_devices(
    n: int,
    kind: str = "ssd",
    directory: Optional[str] = None,
    clock: str = "real",
    prefix: str = "log",
) -> List[StorageDevice]:
    """Create ``n`` devices of ``kind`` ('ssd' | 'nvm' | 'null')."""
    spec = {"ssd": DeviceSpec.ssd, "nvm": DeviceSpec.nvm, "null": DeviceSpec.null}[kind]()
    if directory is not None:
        os.makedirs(directory, exist_ok=True)
    devs = []
    for i in range(n):
        path = os.path.join(directory, f"{prefix}_{i}.bin") if directory else None
        devs.append(StorageDevice(spec, path=path, clock=clock))
    return devs
