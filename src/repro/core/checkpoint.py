"""Fuzzy checkpointing (paper §5).

* n checkpoint threads × m files each (n*m total checkpoint files; the paper
  sizes n*m to the CPU core count for recovery parallelism).
* Tuples are evenly partitioned; each thread walks its partition in key order
  writing ``(key, value, ssn)`` entries.
* Transactions keep running — the snapshot is *fuzzy*; with early lock
  release a thread may even observe dirty (pre-committed) data.  Validity
  rule: each thread records the max SSN it observed; the checkpoint is valid
  only once the CSN exceeds every thread's max (then everything observed was
  truly committed — or will be superseded during replay by the per-tuple SSN
  guard).
* The daemon records the CSN at checkpoint start as ``RSN`` (the log-replay
  starting point) and writes metadata only after completion, so a crash mid-
  checkpoint simply falls back to the previous checkpoint.

Checkpoint entry framing: ``[u32 klen][key][u32 vlen][value][u64 ssn]`` with
a trailing ``[u32 crc]`` per file.
"""

from __future__ import annotations

import json
import os
import re
import struct
import threading
import time
import zlib
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

from .par import parallel_for

_META_RE = re.compile(r"^ckpt_(\d+)\.meta\.json$")

_U32 = struct.Struct("<I")
_U64 = struct.Struct("<Q")


@dataclass
class CheckpointData:
    rsn: int
    data: Dict[bytes, Tuple[bytes, int]] = field(default_factory=dict)
    files: List[str] = field(default_factory=list)


def _encode_entries(entries: Iterable[Tuple[bytes, bytes, int]]) -> bytes:
    parts: List[bytes] = []
    for key, val, ssn in entries:
        parts.append(_U32.pack(len(key)))
        parts.append(key)
        parts.append(_U32.pack(len(val)))
        parts.append(val)
        parts.append(_U64.pack(ssn))
    body = b"".join(parts)
    return body + _U32.pack(zlib.crc32(body))


def _decode_entries(buf: bytes) -> List[Tuple[bytes, bytes, int]]:
    if len(buf) < 4:
        return []
    body, crc = buf[:-4], _U32.unpack(buf[-4:])[0]
    if zlib.crc32(body) != crc:
        return []  # incomplete/corrupt checkpoint file → invalid
    out = []
    pos = 0
    n = len(body)
    while pos < n:
        (klen,) = _U32.unpack_from(body, pos)
        pos += 4
        key = body[pos : pos + klen]
        pos += klen
        (vlen,) = _U32.unpack_from(body, pos)
        pos += 4
        val = body[pos : pos + vlen]
        pos += vlen
        (ssn,) = _U64.unpack_from(body, pos)
        pos += 8
        out.append((key, val, ssn))
    return out


class CheckpointDaemon:
    """Produces fuzzy checkpoints of a live tuple store.

    ``snapshot_iter`` must yield ``(key: bytes, value: bytes, ssn: int)`` for
    a key partition — it is called concurrently from n threads with disjoint
    partitions and must tolerate concurrent writers (per-tuple atomicity is
    the store's job).
    """

    def __init__(
        self,
        directory: str,
        n_threads: int = 2,
        m_files: int = 2,
        csn_fn: Optional[Callable[[], int]] = None,
    ):
        self.directory = directory
        self.n_threads = n_threads
        self.m_files = m_files
        self.csn_fn = csn_fn or (lambda: 0)
        os.makedirs(directory, exist_ok=True)

    def run_once(
        self,
        partitions: Sequence[Iterable[Tuple[bytes, bytes, int]]],
        validate_timeout: float = 30.0,
        epoch: Optional[int] = None,
    ) -> str:
        """Write one checkpoint; returns the metadata path.

        ``partitions`` — one iterable per checkpoint thread (len == n_threads).
        """
        assert len(partitions) == self.n_threads
        epoch = int(time.time() * 1000) if epoch is None else epoch
        rsn = self.csn_fn()
        max_observed = [0] * self.n_threads
        files: List[List[str]] = [[] for _ in range(self.n_threads)]

        def _worker(i: int) -> None:
            entries = list(partitions[i])
            for _, _, ssn in entries:
                if ssn > max_observed[i]:
                    max_observed[i] = ssn
            # split this thread's partition across m files
            chunks = [entries[j :: self.m_files] for j in range(self.m_files)]
            for j, chunk in enumerate(chunks):
                path = os.path.join(self.directory, f"ckpt_{epoch}_{i}_{j}.bin")
                with open(path, "wb") as f:
                    f.write(_encode_entries(chunk))
                    f.flush()
                    os.fsync(f.fileno())
                files[i].append(path)

        # parallel_for propagates worker exceptions after joining everyone:
        # a dead writer must abort the whole checkpoint *before* the metadata
        # publish below, or a partial file set gets blessed as valid
        try:
            parallel_for(self.n_threads, _worker, parallel=True)
        except BaseException:
            # best-effort cleanup of the partial epoch; never publish meta
            for fs in files:
                for p in fs:
                    try:
                        os.remove(p)
                    except OSError:
                        pass
            raise

        # ELR validity: wait until CSN passes every observed SSN
        needed = max(max_observed) if max_observed else 0
        deadline = time.monotonic() + validate_timeout
        while self.csn_fn() < needed:
            if time.monotonic() > deadline:
                raise TimeoutError(
                    f"checkpoint validation timed out: csn={self.csn_fn()} < observed={needed}"
                )
            time.sleep(1e-4)

        meta = {
            "epoch": epoch,
            "rsn": rsn,
            "max_observed": needed,
            "files": [p for fs in files for p in fs],
        }
        meta_path = os.path.join(self.directory, f"ckpt_{epoch}.meta.json")
        tmp = meta_path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(meta, f)
            f.flush()
            os.fsync(f.fileno())
        os.rename(tmp, meta_path)  # atomic publish
        return meta_path


def load_latest_checkpoint_meta(directory: str) -> Optional[dict]:
    """Metadata of the newest complete checkpoint, or None.

    "Newest" means the largest *numeric* epoch: the filenames are
    ``ckpt_{epoch}.meta.json`` and a lexicographic sort would rank epoch
    ``999`` above ``1000`` (shorter string, bigger leading digit), making
    recovery replay from a stale RSN once epochs cross a digit boundary.

    This is also the cheap probe the log truncator polls (it needs the
    ``rsn``/``epoch`` watermarks, never the tuple image).
    """
    if not os.path.isdir(directory):
        return None
    epochs = []
    for p in os.listdir(directory):
        m = _META_RE.match(p)
        if m:
            epochs.append((int(m.group(1)), p))
    if not epochs:
        return None
    _, newest = max(epochs)
    with open(os.path.join(directory, newest)) as f:
        return json.load(f)


def load_latest_checkpoint(directory: str, parallel: bool = True) -> Optional[CheckpointData]:
    """Load the newest complete checkpoint (recovery stage 1)."""
    meta = load_latest_checkpoint_meta(directory)
    if meta is None:
        return None
    data: Dict[bytes, Tuple[bytes, int]] = {}
    lock = threading.Lock()

    def _load(path: str) -> None:
        try:
            with open(path, "rb") as f:
                entries = _decode_entries(f.read())
        except FileNotFoundError:
            entries = []
        with lock:
            for key, val, ssn in entries:
                cur = data.get(key)
                if cur is None or ssn > cur[1]:
                    data[key] = (val, ssn)

    files = meta["files"]
    parallel_for(len(files), lambda i: _load(files[i]), parallel)
    return CheckpointData(rsn=meta["rsn"], data=data, files=files)
