"""Checkpoint-anchored log truncation (§5's purpose made operational).

A fuzzy checkpoint exists to *bound* recovery, yet an append-only-forever
log grows the recovery replay, the disk footprint, and replica cold
catch-up without bound.  The truncator closes that loop: once a checkpoint
is durable, every log record the checkpoint image provably covers is dead
weight and its sealed segments can be dropped.

Safe-point rule (per engine):

* the checkpoint contributes its **RSN** — the CSN at checkpoint start.
  Every record with ``ssn <= RSN`` was durable *and applied to the tuple
  store* before the fuzzy scan began (commit required ``CSN >= ssn``, and
  CSN already equalled RSN at start), so the scan observed its write or a
  newer one for every key it touched: the image supersedes the record under
  the per-key SSN guard (checkpoint wins ties).  Note this is deliberately
  *not* ``max_observed``: a record with ``RSN < ssn <= max_observed`` may
  have written a key *after* the scanner passed it, so only the log carries
  its newest value — truncating it would lose a committed write.
* every **live consumer** caps it from below: a registered replica shipper,
  journal tailer, or cross-shard cut contributes the SSN frontier it has
  consumed through (:class:`FrontierRegistry`); records above any
  consumer's frontier stay.  A consumer that instead falls behind a
  truncation (registered late, offline) hits
  :class:`~repro.core.storage.TruncatedLogError` and re-bases from the
  checkpoint — the safe-point rule is exactly what makes that fallback
  lossless.

The truncator seals each device's flushed tail under the owning buffer's
flush lock (so the segment's ``last_ssn`` stamp — the buffer DSN — is
consistent with its bytes), then drops whole sealed segments whose
``last_ssn`` is at or below the safe point.  Per-device SSN monotonicity
makes the per-segment decision exact, and only prefixes are ever dropped,
so the retained log is always a contiguous suffix.

:class:`ShardedLogTruncator` adds the cross-shard refinement: a segment
holding ``FLAG_XSHARD`` records is droppable only if every participant
record of every such transaction is itself checkpoint-covered on its own
shard (``ssn_q <= safe_q`` for all participants q).  Otherwise dropping
this shard's copy would break recovery's durable-on-all-participants cut
and discard the surviving participants' records of a *committed*
transaction that only their logs still carry.  Candidate segments are
decoded once (cold data, about to be deleted) to find their x-records.

**Command-dep pin (adaptive logging).**  A retained ``FLAG_COMMAND``
record re-executes at recovery against its observed pre-image SSN; if the
pre-image is neither in the retained log nor covered by the checkpoint
image, recovery refuses the record (``command-dep-unreplayable``).  Both
truncators therefore refuse to drop any segment that may still hold the
pre-image of a retained command record: the pass scans the segments it is
*keeping* for command deps above the checkpoint RSN (deps at or below the
RSN are image-covered) and pins the droppable prefix below the smallest
such dep.  Under the adaptive policy's own framing rule this floor can
never bite — a dep above the RSN lives above the safe point and is
retained by the plain rule already — so it is a belt-and-suspenders
invariant against foreign or hand-built logs and stale safe points, at the
cost of decoding the retained suffix once per pass.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from .checkpoint import load_latest_checkpoint_meta
from .txn import decode_columnar
from ..obs.metrics import REGISTRY


class FrontierRegistry:
    """Live log consumers, by name, each reporting an SSN frontier.

    A consumer's frontier F means "every record with ``ssn <= F`` has been
    consumed" (shipped, applied, tailed).  The truncator never drops a
    segment above ``min`` over registered frontiers, so a *registered*
    consumer never observes a hole; unregistered/lagging consumers rely on
    checkpoint re-basing instead.
    """

    def __init__(self):
        self._fns: Dict[str, Callable[[], int]] = {}
        self._lock = threading.Lock()

    def register(self, name: str, frontier_fn: Callable[[], int]) -> None:
        with self._lock:
            self._fns[name] = frontier_fn

    def unregister(self, name: str) -> None:
        with self._lock:
            self._fns.pop(name, None)

    def register_replica(self, name: str, replica) -> None:
        """A :class:`~repro.replica.replica.Replica`: consumed through the
        min over its per-device shipped frontiers."""
        self.register(
            name,
            lambda: min(f) if (f := replica.shipped_frontiers()) else 0,
        )

    def register_journal(self, name: str, tails) -> None:
        """A :class:`~repro.journal.restore.JournalTails` incremental tailer."""
        self.register(name, tails.min_frontier)

    def frontiers(self) -> Dict[str, int]:
        with self._lock:
            fns = dict(self._fns)
        return {name: fn() for name, fn in fns.items()}

    def min_frontier(self) -> Optional[int]:
        """min over registered consumers' frontiers; None when none are
        registered (no consumer cap)."""
        f = self.frontiers()
        return min(f.values()) if f else None


def retained_command_dep_floor(
    devices, safe: Optional[int], ckpt_rsn: int
) -> Optional[int]:
    """Smallest command-record dep SSN above ``ckpt_rsn`` among the records
    a pass at ``safe`` would *retain* (sealed segments above the safe point
    plus the unsealed tail), or None when no retained command depends on
    log-covered state.  Dropping any segment that may hold a record at or
    above this SSN could strand a retained command's pre-image — see the
    command-dep pin in the module docstring."""
    floor: Optional[int] = None
    for dev in devices:
        if not hasattr(dev, "read_segment_blobs"):
            continue
        segs = dev.segments() if hasattr(dev, "segments") else []
        for i, blob in enumerate(dev.read_segment_blobs()):
            # blobs beyond the sealed metadata (the tail, or a chain that
            # grew mid-pass) are always retained — scan them
            if i < len(segs) and safe is not None and segs[i][2] <= safe:
                continue                     # droppable: goes with its deps
            if not blob:
                continue
            log = decode_columnar(blob)
            if log.cmd_dep_ssn is None or not len(log.cmd_dep_ssn):
                continue
            deps = log.cmd_dep_ssn[log.cmd_dep_ssn > ckpt_rsn]
            if len(deps):
                m = int(deps.min())
                floor = m if floor is None else min(floor, m)
    return floor


def _keep_from_floor(dev, floor: Optional[int]) -> Optional[int]:
    """First sealed-segment index of ``dev`` that may contain a record at
    ``floor`` or above (per-device SSN monotonicity: a segment whose
    ``last_ssn`` is below the floor cannot hold the dep)."""
    if floor is None:
        return None
    for i, (_, _, last_ssn) in enumerate(dev.segments()):
        if last_ssn >= floor:
            return i
    return None


@dataclass
class TruncationStats:
    """Outcome of one truncation pass."""

    epoch: Optional[int] = None       # checkpoint epoch anchoring the pass
    safe_ssn: int = 0                 # the computed safe point (0 = no-op)
    segments_sealed: int = 0
    segments_dropped: int = 0
    bytes_dropped: int = 0
    per_device: List[Dict[str, int]] = field(default_factory=list)


class LogTruncator:
    """Checkpoint-anchored truncation daemon for one Poplar engine.

    Stepped (:meth:`run_once` after each checkpoint) or threaded
    (:meth:`start` polls the checkpoint directory and runs a pass whenever a
    new epoch publishes), like the engines.
    """

    def __init__(
        self,
        engine,
        checkpoint_dir: str,
        registry: Optional[FrontierRegistry] = None,
        min_seal_bytes: int = 1,
    ):
        self.engine = engine
        self.checkpoint_dir = checkpoint_dir
        self.registry = registry or FrontierRegistry()
        self.min_seal_bytes = max(1, min_seal_bytes)
        self.last_epoch: Optional[int] = None
        self.total_bytes_dropped = 0
        self._last_safe = -1       # safe point of the last pass (threaded mode)
        self._safe_advance_t = time.monotonic()  # last time the safe point rose
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # --- safe point --------------------------------------------------------
    def _anchor(self) -> Optional[Tuple[int, int, int]]:
        """``(checkpoint epoch, safe SSN, checkpoint RSN)`` — the one place
        the safe-point rule lives: the newest checkpoint's RSN, capped by
        the registered consumers' min frontier.  None without a checkpoint.
        ``safe < RSN`` means a consumer frontier is pinning the safe point
        below what the checkpoint alone would allow (a truncation stall)."""
        meta = load_latest_checkpoint_meta(self.checkpoint_dir)
        if meta is None:
            return None
        rsn = int(meta["rsn"])
        safe = rsn
        cap = self.registry.min_frontier()
        if cap is not None:
            safe = min(safe, cap)
        return int(meta["epoch"]), safe, rsn

    def safe_ssn(self) -> Optional[int]:
        """The current safe truncation SSN, or None without a checkpoint."""
        a = self._anchor()
        return None if a is None else a[1]

    def stall_ssn(self) -> int:
        """How far a consumer frontier pins the safe point below the
        checkpoint RSN (0 = no stall / no checkpoint).  The health monitor's
        truncation-stall signal."""
        a = self._anchor()
        return 0 if a is None else a[2] - a[1]

    # --- one pass ----------------------------------------------------------
    def _seal_all(self, stats: TruncationStats) -> None:
        """Seal every device's flushed tail at a consistent (bytes, DSN)
        point: the buffer flush lock keeps ``flush_ready`` from landing new
        records between reading the DSN and renaming the tail."""
        for buf, dev in zip(self.engine.buffers, self.engine.devices):
            with buf.flush_lock:
                if dev.tail_bytes() < self.min_seal_bytes:
                    continue
                if dev.seal(buf.dsn) is not None:
                    stats.segments_sealed += 1

    def run_once(self) -> TruncationStats:
        stats = TruncationStats()
        anchor = self._anchor()
        if anchor is None:
            return stats
        stats.epoch, stats.safe_ssn, ckpt_rsn = anchor
        safe = stats.safe_ssn
        self._seal_all(stats)
        floor = retained_command_dep_floor(self.engine.devices, safe, ckpt_rsn)
        if floor is not None and REGISTRY.enabled:
            REGISTRY.count("truncate.cmd_dep_pins")
        for dev in self.engine.devices:
            n, b = dev.truncate_to_ssn(
                safe, keep_from=_keep_from_floor(dev, floor)
            )
            stats.segments_dropped += n
            stats.bytes_dropped += b
            stats.per_device.append({"segments": n, "bytes": b})
        self.last_epoch = stats.epoch
        if stats.safe_ssn > self._last_safe:
            self._safe_advance_t = time.monotonic()
        self._last_safe = stats.safe_ssn
        self.total_bytes_dropped += stats.bytes_dropped
        if REGISTRY.enabled:
            REGISTRY.count("truncate.bytes_reclaimed", stats.bytes_dropped)
            REGISTRY.count("truncate.segments_dropped", stats.segments_dropped)
            REGISTRY.gauge_set("truncate.safe_ssn", float(safe))
            REGISTRY.gauge_set("truncate.pin_ssn", float(ckpt_rsn - safe))
            REGISTRY.gauge_set("truncate.safe_point_age_s",
                               time.monotonic() - self._safe_advance_t)
            if ckpt_rsn > safe:
                REGISTRY.count("truncate.stalled_passes")
        return stats

    # --- continuous operation ----------------------------------------------
    def start(self, poll_interval: float = 50e-3) -> None:
        """Run a pass whenever a new checkpoint epoch publishes — or, with
        registered consumers, whenever the consumer-capped safe point has
        risen past the last pass (a lagging consumer caps a pass below the
        checkpoint RSN; the retained segments become droppable as soon as
        it catches up, without any new checkpoint)."""
        self._stop.clear()

        def _loop() -> None:
            while not self._stop.is_set():
                a = self._anchor()
                if a is not None and (
                    a[0] != self.last_epoch or a[1] > self._last_safe
                ):
                    self.run_once()
                time.sleep(poll_interval)

        self._thread = threading.Thread(target=_loop, daemon=True,
                                        name="log-truncator")
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=10)
            self._thread = None


class ShardedLogTruncator:
    """Per-shard truncation with the cross-shard coverage check.

    ``checkpoint_dirs`` aligns with the engine's shard order;  a shard
    without a checkpoint directory (or without a published checkpoint) is
    never truncated, and cross-shard records depending on it pin their
    segments everywhere.  ``registries`` optionally caps each shard's safe
    point with its live consumers (e.g. a ``ShardedReplica``'s per-shard
    shippers).
    """

    def __init__(
        self,
        engine,
        checkpoint_dirs: Sequence[Optional[str]],
        registries: Optional[Sequence[Optional[FrontierRegistry]]] = None,
    ):
        self.engine = engine
        self.checkpoint_dirs = list(checkpoint_dirs)
        assert len(self.checkpoint_dirs) == len(engine.shards)
        self.registries = list(registries) if registries is not None else [
            None
        ] * len(engine.shards)
        self.total_bytes_dropped = 0

    def _safe_points(self) -> List[Optional[int]]:
        out: List[Optional[int]] = []
        for d, reg in zip(self.checkpoint_dirs, self.registries):
            meta = load_latest_checkpoint_meta(d) if d is not None else None
            if meta is None:
                out.append(None)
                continue
            safe = int(meta["rsn"])
            cap = reg.min_frontier() if reg is not None else None
            if cap is not None:
                safe = min(safe, cap)
            out.append(safe)
        return out

    def _droppable_prefix(self, dev, safe: List[Optional[int]],
                          p: int) -> int:
        """Index of the first sealed segment of shard ``p``'s device ``dev``
        that must be kept because of an uncovered cross-shard record.

        Only candidate segments — the droppable prefix at or below the safe
        point — are read and decoded (lazily, one at a time): a pass never
        touches the retained remainder or the tail, so its IO is bounded by
        what it is about to delete.
        """
        segs = dev.segments()
        for i, (_, _, last_ssn) in enumerate(segs):
            if safe[p] is None or last_ssn > safe[p]:
                return i                          # plain rule stops here anyway
            blob = dev.read_sealed_blob(i)
            if blob is None:
                return i
            log = decode_columnar(blob)
            if log.x_rec is None:
                continue
            for j in range(len(log.x_rec)):
                lo, hi = int(log.xp_start[j]), int(log.xp_start[j + 1])
                for q, sq in zip(log.xp_shard[lo:hi].tolist(),
                                 log.xp_ssn[lo:hi].tolist()):
                    if safe[q] is None or sq > safe[q]:
                        return i
        return len(segs)

    def run_once(self) -> List[TruncationStats]:
        safe = self._safe_points()
        out: List[TruncationStats] = []
        for p, sh in enumerate(self.engine.shards):
            stats = TruncationStats(safe_ssn=safe[p] or 0)
            if safe[p] is not None:
                meta = load_latest_checkpoint_meta(self.checkpoint_dirs[p])
                stats.epoch = int(meta["epoch"]) if meta else None
                rsn_p = int(meta["rsn"]) if meta else 0
                for buf, dev in zip(sh.engine.buffers, sh.engine.devices):
                    with buf.flush_lock:
                        if dev.seal(buf.dsn) is not None:
                            stats.segments_sealed += 1
                # command deps are shard-local (the policy value-frames
                # cross-shard records), so the pin floor is per shard
                floor = retained_command_dep_floor(
                    sh.engine.devices, safe[p], rsn_p
                )
                for dev in sh.engine.devices:
                    keep_from = self._droppable_prefix(dev, safe, p)
                    kf_cmd = _keep_from_floor(dev, floor)
                    if kf_cmd is not None:
                        keep_from = min(keep_from, kf_cmd)
                    n, b = dev.truncate_to_ssn(safe[p], keep_from=keep_from)
                    stats.segments_dropped += n
                    stats.bytes_dropped += b
                    stats.per_device.append({"segments": n, "bytes": b})
                self.total_bytes_dropped += stats.bytes_dropped
            out.append(stats)
        return out
