"""Commit protocol (paper §4.3).

Each worker owns two private commit queues:

* ``Qww`` — transactions with *only* write operations.  Committable as soon
  as their own record is durable: ``ssn <= DSN(buffer)``.
* ``Qwr`` — transactions with a read set (potential RAW dependencies, incl.
  read-only transactions).  Committable when ``ssn <= CSN`` where
  ``CSN = min over buffers of DSN`` — every RAW predecessor has a smaller
  SSN, hence is durable in *whichever* buffer holds it.

Queues are FIFO per worker and SSNs are monotone per buffer, so draining
from the head is exact (a blocked head implies a blocked tail for the same
watermark).
"""

from __future__ import annotations

import threading
import time
from collections import deque
from typing import Callable, Deque, List, Optional, Sequence

from .log_buffer import LogBuffer
from .txn import Txn


class CommitQueues:
    """Per-worker Qww / Qwr pair."""

    def __init__(self, worker_id: int):
        self.worker_id = worker_id
        self.qww: Deque[Txn] = deque()
        self.qwr: Deque[Txn] = deque()
        # Queues are worker-private in the paper; a lock keeps them safe if a
        # separate committer thread drains them (engine option).
        self.lock = threading.Lock()

    def push(self, txn: Txn) -> None:
        with self.lock:
            if txn.write_only:
                self.qww.append(txn)
            else:
                self.qwr.append(txn)

    def push_batch(self, txns: Sequence[Txn]) -> None:
        """Enqueue a batch under one lock acquisition (batched forward path).
        ``txns`` must be in SSN order per queue, which holds for any slice of
        a batch allocated through ``reserve_batch`` (SSNs are monotone in
        batch order per buffer)."""
        with self.lock:
            for txn in txns:
                if txn.write_only:
                    self.qww.append(txn)
                else:
                    self.qwr.append(txn)

    def pending(self) -> int:
        with self.lock:
            return len(self.qww) + len(self.qwr)


class CommitProtocol:
    """Drains commit queues against the DSN/CSN watermarks."""

    def __init__(self, buffers: List[LogBuffer], on_commit: Optional[Callable[[Txn], None]] = None):
        self.buffers = buffers
        self.on_commit = on_commit
        self._csn = 0
        self._csn_lock = threading.Lock()

    # --- Algorithm 2, AdvancingCSN ----------------------------------------
    def advance_csn(self) -> int:
        csn = min(b.dsn for b in self.buffers) if self.buffers else 0
        with self._csn_lock:
            if csn > self._csn:
                self._csn = csn
            return self._csn

    @property
    def csn(self) -> int:
        return self._csn

    # --- commit stage -------------------------------------------------------
    def committable(self, ssn: int, has_reads: bool, buffer_id: int = -1) -> bool:
        """The watermark rule, factored out of :meth:`drain` so external
        coordinators (the sharded engine's cross-shard commit, which applies
        this same test *per participant shard*) share one definition:

        * write-only  — own-buffer durability: ``ssn <= DSN(buffer_id)``;
        * with reads  — global committability: ``ssn <= CSN`` (every RAW
          predecessor has a smaller SSN, hence is durable in whichever
          buffer holds it; read-only txns pass ``buffer_id=-1``).
        """
        if has_reads:
            return ssn <= self.advance_csn()
        return ssn <= self.buffers[buffer_id].dsn

    def _commit(self, txn: Txn) -> None:
        txn.committed = True
        txn.t_commit = time.perf_counter()
        if self.on_commit is not None:
            self.on_commit(txn)

    def drain(self, queues: CommitQueues) -> int:
        """Commit every currently-committable transaction for one worker
        (the :meth:`committable` rule, with the CSN hoisted out of the Qwr
        loop — it only grows during a drain).  Returns the number
        committed."""
        n = 0
        with queues.lock:
            # Qww: own-buffer durability only
            while queues.qww:
                txn = queues.qww[0]
                if txn.ssn <= self.buffers[txn.buffer_id].dsn:
                    queues.qww.popleft()
                    self._commit(txn)
                    n += 1
                else:
                    break
            # Qwr: global committability (CSN)
            csn = self.advance_csn()
            while queues.qwr:
                txn = queues.qwr[0]
                # read-only txns have buffer_id == -1 and commit purely on CSN
                if txn.ssn <= csn:
                    queues.qwr.popleft()
                    self._commit(txn)
                    n += 1
                else:
                    break
        return n
