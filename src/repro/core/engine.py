"""Poplar logging engine (paper §4) and the three-stage logging pipeline.

Stages (Fig. 2):
  * prepare    — worker allocates an SSN (Algorithm 1), reserves a slot in its
                 mapped log buffer, memcpys the record, pushes the txn into
                 its private Qww/Qwr;
  * persistence — logger threads (1:1 with buffers/devices) close segments on
                 the group-commit timer, flush ready segments, advance DSNs;
  * commit     — workers drain their queues against DSN (Qww) / CSN (Qwr).

The engine is usable in two modes:
  * threaded — ``start()`` spawns real logger threads (benchmarks, examples);
  * stepped  — tests call ``logger_tick(i)`` deterministically.

Worker → buffer mapping is many-to-one (``worker_id % n_buffers``).
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterable, List, Optional, Sequence

from . import ssn as ssn_mod
from .commit import CommitProtocol, CommitQueues
from .log_buffer import LogBuffer
from .storage import StorageDevice, make_devices
from .txn import Txn
from ..trace.span import ST_FLUSH, ST_PUBLISH, TRACER
from ..obs.metrics import REGISTRY


@dataclass
class EngineConfig:
    n_buffers: int = 2
    buffer_capacity: int = 30 * 1024 * 1024   # 30 MB (paper §6.1)
    io_unit: int = 16 * 1024                  # 16 KB segment close threshold
    flush_interval: float = 5e-3              # 5 ms group commit (paper §6.1)
    segment_ring: int = 256
    device_kind: str = "ssd"                  # 'ssd' | 'nvm' | 'null'
    device_dir: Optional[str] = None          # None => in-memory durable image
    device_clock: str = "real"                # 'real' | 'virtual'
    logger_poll: float = 2e-4                 # logger idle poll
    # roll the device's active tail into an immutable sealed segment once it
    # exceeds this many bytes (the unit `core.truncate.LogTruncator` drops
    # and recovery decodes in parallel); None = seal only on truncator passes
    segment_bytes: Optional[int] = None

    @staticmethod
    def nvm(n_buffers: int = 2, device_dir: Optional[str] = None) -> "EngineConfig":
        # §6.1: NVM runs use 1 MB buffers, flush every 5ms or 1/10 full.
        return EngineConfig(
            n_buffers=n_buffers,
            buffer_capacity=1024 * 1024,
            io_unit=1024 * 1024 // 10,
            flush_interval=5e-3,
            device_kind="nvm",
            device_dir=device_dir,
        )


class LoggingEngine:
    """Interface shared by Poplar and the baseline variants."""

    name = "base"
    level = "?"

    def register_worker(self, worker_id: int) -> None:
        raise NotImplementedError

    def allocate(self, txn: Txn, read_items: Iterable, write_items: Sequence) -> int:
        """Prepare-stage entry: assign a sequence number + buffer slot."""
        raise NotImplementedError

    def publish(self, txn: Txn) -> None:
        """Finish the prepare stage: persist-or-buffer the encoded record and
        enqueue the txn for commit."""
        raise NotImplementedError

    def drain(self, worker_id: int) -> int:
        """Commit-stage: commit every committable txn of this worker."""
        raise NotImplementedError

    def start(self) -> None:  # pragma: no cover - trivial
        pass

    def stop(self) -> None:  # pragma: no cover - trivial
        pass

    def quiesce(self, worker_ids: Sequence[int], timeout: float = 30.0) -> None:
        """Flush + commit everything outstanding (shutdown / test barrier)."""
        raise NotImplementedError


class PoplarEngine(LoggingEngine):
    name = "poplar"
    level = "recoverability"

    def __init__(self, cfg: EngineConfig = EngineConfig(), devices: Optional[List[StorageDevice]] = None):
        self.cfg = cfg
        self.devices = devices or make_devices(
            cfg.n_buffers, cfg.device_kind, cfg.device_dir, cfg.device_clock
        )
        assert len(self.devices) == cfg.n_buffers
        self.buffers = [
            LogBuffer(i, cfg.buffer_capacity, cfg.io_unit, cfg.segment_ring)
            for i in range(cfg.n_buffers)
        ]
        self.commit = CommitProtocol(self.buffers)
        self.queues: Dict[int, CommitQueues] = {}
        self._last_force: List[float] = [time.perf_counter()] * cfg.n_buffers
        self._threads: List[threading.Thread] = []
        self._stop = threading.Event()
        # perf counters
        self.txn_logged = 0
        self.txn_committed = 0
        self._count_lock = threading.Lock()
        # shard id stamped on this engine's trace spans (`repro.shard.engine`
        # overwrites it on each shard's private engine)
        self._trace_shard = 0
        # metric names are interned per device so the armed flush hook does
        # no string formatting on the hot path
        self._obs_names = [
            (f"engine.flush_bytes.d{i}", f"engine.flush_txns.d{i}",
             f"engine.buffer_occupancy.d{i}")
            for i in range(cfg.n_buffers)
        ]

    # --- worker side --------------------------------------------------------
    def register_worker(self, worker_id: int) -> None:
        self.queues.setdefault(worker_id, CommitQueues(worker_id))

    def buffer_for(self, worker_id: int) -> LogBuffer:
        return self.buffers[worker_id % self.cfg.n_buffers]

    def allocate(self, txn: Txn, read_items: Iterable, write_items: Sequence) -> int:
        """Algorithm 1.  For write txns, reserves a slot; the caller must then
        write the SSN back into the write set (under its OCC locks) and call
        :meth:`publish`.

        ``txn.worker_id`` must be set (use :class:`Worker`, or set it
        directly); it determines the mapped log buffer.
        """
        worker_id = getattr(txn, "worker_id", txn.tid)
        buf = self.buffer_for(worker_id)
        txn.record = b""
        # estimate framed length analytically to reserve before encoding
        length = _framed_len(txn)
        s, off, seg = ssn_mod.allocate(buf if txn.write_set else None,
                                       read_items, write_items, length)
        txn.ssn = s
        if txn.write_set:
            txn.buffer_id = buf.id
            txn.offset = off
            txn._seg_idx = seg  # type: ignore[attr-defined]
        txn.t_precommit = time.perf_counter()
        return s

    def publish(self, txn: Txn) -> None:
        q = self.queues[getattr(txn, "worker_id", txn.tid)]
        if txn.write_set:
            record = txn.encode()
            assert len(record) == _framed_len(txn), (
                f"framed length drift: {len(record)} != {_framed_len(txn)}"
            )
            buf = self.buffers[txn.buffer_id]
            buf.fill(txn.offset, txn._seg_idx, record)  # type: ignore[attr-defined]
        with self._count_lock:
            self.txn_logged += 1
        q.push(txn)

    def publish_batch(
        self,
        txns: Sequence[Txn],
        blob: bytes = b"",
        buffer_id: int = -1,
        offset: int = 0,
        seg_idx: int = -1,
    ) -> None:
        """Batch twin of :meth:`publish` for the array-native forward path.

        ``txns`` is one batch slice whose write records were reserved
        contiguously on buffer ``buffer_id`` via
        :meth:`~repro.core.log_buffer.LogBuffer.reserve_batch` and
        pre-encoded (``core.txn.encode_batch``) into ``blob``; the region is
        completed with a single ring memcpy.  Read-only transactions (no
        blob) ride along and are only enqueued.  Commit-queue pushes are
        grouped per worker (one lock acquisition each).
        """
        _trace = TRACER.enabled
        if _trace:
            _t0 = time.perf_counter()
        if blob:
            self.buffers[buffer_id].fill(offset, seg_idx, blob)
        now = time.perf_counter()
        by_worker: Dict[int, List[Txn]] = {}
        for t in txns:
            t.t_precommit = now
            w = getattr(t, "worker_id", None)
            # no tid fallback here (unlike publish()): striped tids are
            # never registered worker ids, so failing fast beats a KeyError
            # deep inside the commit queues
            assert w is not None, "publish_batch requires txn.worker_id"
            by_worker.setdefault(w, []).append(t)
        for w, group in by_worker.items():
            self.queues[w].push_batch(group)
        with self._count_lock:
            self.txn_logged += len(txns)
        if _trace and txns:
            ssns = [t.ssn for t in txns]
            TRACER.record(
                ST_PUBLISH, shard=self._trace_shard, device=buffer_id,
                batch=TRACER.ctx.batch, txn_lo=min(ssns), txn_hi=max(ssns),
                t0=_t0, t1=time.perf_counter(), nbytes=len(blob),
                n_txn=len(txns),
            )

    # --- external-coordinator extension points -----------------------------
    # The sharded engine (`repro.shard`) logs cross-shard records through the
    # same buffers but tracks commit itself (its watermark rule spans several
    # engines), so the reserve and fill halves are exposed separately: the
    # coordinator must learn every participant's SSN before it can frame any
    # record (the xdep footer carries the full SSN vector).

    def reserve_record(self, txn: Txn, base_ssn: int, worker_id: int) -> int:
        """Latched half of Algorithm 1 for an externally-committed record:
        reserve an SSN + slot on ``worker_id``'s mapped buffer from ``base``
        (which may come from tuple state outside this engine).  The caller
        must finish with :meth:`fill_record` once ``txn`` is fully framed.
        Unlike :meth:`allocate`, a slot is reserved even for zero-write
        records (cross-shard read-participant markers must be durable)."""
        buf = self.buffer_for(worker_id)
        length = _framed_len(txn)
        s, off, seg = buf.reserve(base_ssn, length)
        txn.ssn = s
        txn.buffer_id = buf.id
        txn.offset = off
        txn._seg_idx = seg  # type: ignore[attr-defined]
        return s

    def fill_record(self, txn: Txn) -> None:
        """Memcpy half for :meth:`reserve_record` (no commit-queue push —
        the external coordinator owns the commit decision)."""
        record = txn.encode()
        assert len(record) == _framed_len(txn), (
            f"framed length drift: {len(record)} != {_framed_len(txn)}"
        )
        self.buffers[txn.buffer_id].fill(
            txn.offset, txn._seg_idx, record  # type: ignore[attr-defined]
        )
        txn.t_precommit = time.perf_counter()
        with self._count_lock:
            self.txn_logged += 1

    def drain(self, worker_id: int) -> int:
        # On NVM-class devices (sub-5us persist) a worker flushes its own
        # buffer inline before draining: the IO is cheaper than waiting for
        # the logger's scheduler slot (cf. NVM-D's worker-issued mfence; for
        # SSDs the logger thread keeps exclusive IO duty).  flush_lock makes
        # the concurrent tick safe.
        buf = self.buffer_for(worker_id)
        dev = self.devices[buf.id]
        if dev.spec.latency_s < 5e-6:
            self.logger_tick(buf.id)
        n = self.commit.drain(self.queues[worker_id])
        if n:
            with self._count_lock:
                self.txn_committed += n
        return n

    # --- logger side ----------------------------------------------------------
    def _emit_heartbeat(self, i: int, target_ssn: int) -> None:
        """Advance an idle buffer's durable frontier to the global SSN
        frontier by logging an empty (0-write) record carrying that SSN.

        The paper's CSN = min(DSN) assumes every buffer sees continuous
        traffic; an idle buffer would otherwise pin the CSN forever (liveness)
        *and* pin RSNe at recovery (its device's last durable SSN lags).  An
        empty record is sound: the buffer is fully flushed, so raising L.ssn
        monotonically and persisting it cannot order any real record
        incorrectly — subsequent allocations just start above the frontier.
        """
        buf = self.buffers[i]
        hb = Txn(tid=0)
        length = _framed_len(hb)
        s, off, seg = buf.reserve(0, length, fixed_ssn=target_ssn)
        hb.ssn = s
        buf.fill(off, seg, hb.encode())
        buf.force_establish()

    def logger_tick(self, i: int, now: Optional[float] = None, force: bool = False) -> int:
        """One iteration of logger thread ``i`` (Algorithm 2)."""
        now = time.perf_counter() if now is None else now
        buf = self.buffers[i]
        if force or now - self._last_force[i] >= self.cfg.flush_interval:
            # heartbeat an idle, fully-flushed buffer that lags the frontier
            if len(self.buffers) > 1 and buf.pending_bytes() == 0:
                frontier = max(b.ssn for b in self.buffers)
                if buf.dsn < frontier:
                    self._emit_heartbeat(i, frontier)
            buf.force_establish()
            self._last_force[i] = now
        _trace = TRACER.enabled
        _obs = REGISTRY.enabled
        if _trace or _obs:
            _dsn0 = buf.dsn
            _off0 = buf.flushed_offset
            _t0 = time.perf_counter()
        n = buf.flush_ready(self.devices[i])
        if _trace and n:
            TRACER.record(
                ST_FLUSH, shard=self._trace_shard, device=i,
                txn_lo=_dsn0, txn_hi=buf.dsn, t0=_t0,
                t1=time.perf_counter(), nbytes=buf.flushed_offset - _off0,
                n_txn=n, aux=n,
            )
        if _obs:
            names = self._obs_names[i]
            if n:
                REGISTRY.count(names[0], buf.flushed_offset - _off0)
                REGISTRY.count(names[1], n)
            REGISTRY.gauge_set(names[2], buf.pending_bytes() / buf.capacity)
        if n:
            self._last_force[i] = time.perf_counter()
            if self.cfg.segment_bytes:
                dev = self.devices[i]
                if dev.tail_bytes() >= self.cfg.segment_bytes:
                    # flush_lock keeps further flushes out between reading
                    # the DSN and renaming the tail, so the sealed segment's
                    # last_ssn stamp matches its bytes exactly
                    with buf.flush_lock:
                        dev.seal(buf.dsn)
        self.commit.advance_csn()
        return n

    def _logger_loop(self, i: int) -> None:
        while not self._stop.is_set():
            flushed = self.logger_tick(i)
            if flushed:
                # committer assist: a group-commit daemon acks transactions
                # as soon as the watermarks pass them (queues are locked, so
                # helping from the logger is safe); workers still drain too.
                for wid in list(self.queues.keys()):
                    self.drain(wid)
            else:
                time.sleep(self.cfg.logger_poll)

    def start(self) -> None:
        self._stop.clear()
        self._threads = [
            threading.Thread(target=self._logger_loop, args=(i,), daemon=True, name=f"logger-{i}")
            for i in range(self.cfg.n_buffers)
        ]
        for t in self._threads:
            t.start()

    def stop(self) -> None:
        self._stop.set()
        for t in self._threads:
            t.join(timeout=10)
        self._threads = []

    def quiesce(self, worker_ids: Sequence[int], timeout: float = 30.0) -> None:
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            for i in range(self.cfg.n_buffers):
                self.logger_tick(i, force=True)
            pending = 0
            for w in worker_ids:
                self.drain(w)
                pending += self.queues[w].pending()
            if pending == 0 and all(b.pending_bytes() == 0 for b in self.buffers):
                return
            time.sleep(1e-4)
        raise TimeoutError("engine quiesce timed out")

    # --- stats -----------------------------------------------------------------
    def stats(self) -> Dict:
        return {
            "engine": self.name,
            "csn": self.commit.csn,
            "dsn": [b.dsn for b in self.buffers],
            "txn_logged": self.txn_logged,
            "txn_committed": self.txn_committed,
            "reserve_waits": sum(b.reserve_waits for b in self.buffers),
            "devices": [d.stats() for d in self.devices],
        }


def _framed_len(txn: Txn) -> int:
    # header (u32 len + u32 crc) + fixed payload (u64 ssn + u64 tid + u8 flags
    # + u32 n_writes) + per-write (u32 klen + key + u32 vlen + val)
    n = 8 + 21
    for key, val in txn.write_set:
        kb = key.encode() if isinstance(key, str) else bytes(key)
        n += 8 + len(kb) + len(val)
    if txn.cmd_op is not None:
        # command footer: u32 op + u32 n_deps + per dep (u32 klen + key + u64)
        n += 8
        for key, _ in txn.cmd_deps or []:
            kb = key.encode() if isinstance(key, str) else bytes(key)
            n += 12 + len(kb)
    if txn.xdep is not None:
        # cross-shard footer: u32 n_parts + per part (u32 shard + u64 ssn)
        n += 4 + 12 * len(txn.xdep)
    return n


class AdaptivePolicy:
    """Per-record command-vs-value framing choice (adaptive logging).

    A winner transaction may be *command-framed* — logging ``(op id, param)``
    per write plus the observed pre-image SSNs instead of full value
    payloads — iff every clause holds:

    * its spec names a registered op (``cmd_op in registry``);
    * it is shard-local (``xdep is None`` — a cross-shard record's deps live
      on other shards where this shard's recovery cannot re-execute them, so
      ``FLAG_XSHARD`` always ships values);
    * every written key carries an observed pre-image SSN (the spec read it:
      deps mirror the write chain one-to-one), so each dep is SSN-covered:
      deps at or below the latest checkpoint RSN are covered by the fuzzy
      checkpoint image (image version of any key ≥ any version < RSN), and
      deps above it live in log segments no sound safe point may drop (safe
      ≤ checkpoint RSN, see ``repro.core.truncate``);
    * a dep SSN of **0** — a key loaded into the table before any logged
      write touched it — is only covered when a checkpoint image exists
      (initial loads are in no log), so without one those records stay
      value-framed.

    ``force_value`` pins everything to value framing (the pure-value oracle
    of the crash-equivalence tests and the bench's value arm);
    ``force_command`` inverts the escape hatch for the bench's pure-command
    arm (records that *can't* be command-framed still fall back to value —
    the hatch is about eligibility, not a third wire format).

    ``refresh()`` re-probes the checkpoint directory for the latest RSN —
    the policy input that classifies each dep as image-covered vs
    log-covered (surfaced as metrics; the soundness argument above is why
    both classes stay replayable).
    """

    def __init__(
        self,
        checkpoint_dir: Optional[str] = None,
        registry=None,
        force_value: bool = False,
        force_command: bool = False,
    ):
        if registry is None:
            from .command import COMMANDS
            registry = COMMANDS
        self.registry = registry
        self.checkpoint_dir = checkpoint_dir
        self.force_value = force_value
        self.force_command = force_command
        self.checkpoint_rsn = 0
        # a full-image checkpoint exists — required cover for dep SSN 0
        # (keys loaded before any logged write; they are in no log segment)
        self.has_checkpoint = False

    def refresh(self) -> int:
        """Re-read the latest checkpoint RSN (0 when none exists)."""
        if self.checkpoint_dir is not None:
            from .checkpoint import load_latest_checkpoint_meta
            meta = load_latest_checkpoint_meta(self.checkpoint_dir)
            self.checkpoint_rsn = int(meta["rsn"]) if meta else 0
            self.has_checkpoint = meta is not None
        return self.checkpoint_rsn

    def eligible(self, cmd_op: Optional[int], deps: Sequence[int],
                 xshard: bool = False) -> bool:
        """May this record be command-framed?  ``deps`` is the per-written-key
        observed pre-image SSN (``-1`` for a key the spec did not read)."""
        if self.force_value:
            return False
        if cmd_op is None or cmd_op not in self.registry:
            return False  # forced-value hatch: unregistered op
        if xshard:
            return False  # forced-value hatch: FLAG_XSHARD ships values
        if not len(deps):
            return False  # nothing to re-execute
        for d in deps:
            if d < 0:
                return False  # blind write: no dep SSN — not covered
            if d == 0 and not self.has_checkpoint:
                return False  # initial load, in no log, no image covers it
        return True


class Worker:
    """Thin convenience handle binding a worker id to an engine.

    Drives the full per-transaction pipeline for callers that don't go
    through the OCC layer (e.g. direct logging benchmarks):

        w = Worker(engine, 3)
        w.run(txn, read_items, write_items)   # allocate + writeback + publish
        w.drain()
    """

    def __init__(self, engine: LoggingEngine, worker_id: int):
        self.engine = engine
        self.worker_id = worker_id
        engine.register_worker(worker_id)

    def run(self, txn: Txn, read_items: Sequence, write_items: Sequence) -> int:
        txn.worker_id = self.worker_id  # type: ignore[attr-defined]
        txn.t_start = txn.t_start or time.perf_counter()
        s = self.engine.allocate(txn, read_items, write_items)
        ssn_mod.writeback(s, write_items) if txn.write_set else None
        self.engine.publish(txn)
        return s

    def drain(self) -> int:
        return self.engine.drain(self.worker_id)
