"""One shared thread fan-out for the per-device / per-lane / per-shard
parallel loops (decode, restore, shipping).

Every consumer used to hand-roll the same spawn/start/join block; keeping
one copy means the joining and fall-back-to-sequential behaviour is fixed
in exactly one place.  Workers run under the GIL — these loops parallelize
IO and zlib/numpy releases, not Python bytecode.
"""

from __future__ import annotations

import threading
from typing import Callable


def parallel_for(n: int, fn: Callable[[int], None], parallel: bool = True) -> None:
    """Run ``fn(i)`` for ``i in range(n)`` — on one thread per index when
    ``parallel`` and ``n > 1``, else sequentially.  Joins all threads before
    returning."""
    if parallel and n > 1:
        threads = [threading.Thread(target=fn, args=(i,)) for i in range(n)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    else:
        for i in range(n):
            fn(i)
