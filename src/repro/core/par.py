"""One shared thread fan-out for the per-device / per-lane / per-shard
parallel loops (decode, restore, shipping).

Every consumer used to hand-roll the same spawn/start/join block; keeping
one copy means the joining and fall-back-to-sequential behaviour is fixed
in exactly one place.  Workers run under the GIL — these loops parallelize
IO and zlib/numpy releases, not Python bytecode.

Worker exceptions propagate: a bare ``threading.Thread`` swallows them,
which let a failed checkpoint writer look like a successful one (the
metadata was published over a partial file set).  ``parallel_for`` joins
every worker first, then re-raises the lowest-index failure — so a caller
can never observe "done" when any worker died.
"""

from __future__ import annotations

import threading
from typing import Callable, List, Optional


def parallel_for(n: int, fn: Callable[[int], None], parallel: bool = True) -> None:
    """Run ``fn(i)`` for ``i in range(n)`` — on one thread per index when
    ``parallel`` and ``n > 1``, else sequentially.  Joins all threads before
    returning; if any worker raised, re-raises the lowest-index exception
    (after every worker has finished, so no thread is left running)."""
    if parallel and n > 1:
        errs: List[Optional[BaseException]] = [None] * n

        def _run(i: int) -> None:
            try:
                fn(i)
            except BaseException as e:  # noqa: BLE001 - re-raised below
                errs[i] = e

        threads = [threading.Thread(target=_run, args=(i,)) for i in range(n)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        for e in errs:
            if e is not None:
                raise e
    else:
        for i in range(n):
            fn(i)
