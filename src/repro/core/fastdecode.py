"""Vectorized sealed-segment tile decode — the feed of the compiled replay.

``decode_columnar_stream`` walks frames one ``struct.unpack`` at a time and
dominates recovery wall time (the replay reduction is an order of magnitude
cheaper than the decode that feeds it).  This module decodes a segment blob
almost entirely with array ops:

* frame boundaries + crc truncation come from :func:`repro.core.txn.frame_scan`
  (run-speculative strided scan, one C-speed crc per frame);
* fixed payload fields (ssn/tid/flags/n_writes) are unaligned byte-plane
  gathers;
* per-write (klen, key, vlen, val) chains resolve in ``max(n_writes)``
  vectorized rounds — one round per write ordinal, each advancing every
  record's write cursor at once — with the same bounds checks (and the same
  tolerance quirks) as the scalar walk, so truncation at a malformed frame
  is byte-identical;
* key identities build straight into the fixed-width ``keys_fixed`` matrix
  (one 2-D byte gather), and **values stay lazy**: a :class:`FastTile`
  records ``(offset, length)`` per write and materializes bytes only for
  the lanes replay actually wins — the value-gather half of the fused
  replay kernel.

Tiles with exotic shapes (XSHARD footers, pathological write counts) return
``None`` and the caller falls back to the scalar-equivalent columnar decode;
the fast path is an optimization, never a semantics fork.
"""

from __future__ import annotations

import zlib
from dataclasses import dataclass
from typing import List, Optional

import numpy as np

from .txn import (
    _HDR,
    _PAYLOAD_FIXED,
    FLAG_COMMAND,
    FLAG_HAS_READS,
    FLAG_XSHARD,
    frame_scan,
    gather_u32,
    gather_u64,
)

# a frame advertising more writes than this falls back to the scalar walk
# (the engine never frames anywhere near it; this bounds the round loop)
MAX_FAST_WRITES = 64


@dataclass
class FastTile:
    """One decoded segment blob in replay-ready form.

    Same per-record/per-write columns replay consumes from a
    :class:`~repro.core.txn.ColumnarLog`, minus materialized key/value
    bytes: ``keys_fixed`` carries exact key identity, and values resolve on
    demand from ``(val_off, val_len)`` into the source blob.
    """

    buf: bytes
    ssn: np.ndarray          # (n_records,) int64
    has_reads: np.ndarray    # (n_records,) bool
    wr_rec: np.ndarray       # (n_writes,) int64 owning record index
    keys_fixed: np.ndarray   # (n_writes,) 'S' fixed-width key identity
    val_off: np.ndarray      # (n_writes,) int64 byte offset into buf
    val_len: np.ndarray      # (n_writes,) int64
    consumed: int            # first undecodable byte offset (torn/corrupt)

    @property
    def n_records(self) -> int:
        return len(self.ssn)

    @property
    def last_ssn(self) -> int:
        return int(self.ssn[-1]) if len(self.ssn) else 0

    @property
    def wr_ssn(self) -> np.ndarray:
        return self.ssn[self.wr_rec]

    def committed_mask(self, rsne: int) -> np.ndarray:
        """Per-record §5 commit guard (Qww always, Qwr iff ssn ≤ RSNe)."""
        return ~self.has_reads | (self.ssn <= rsne)

    def values_for(self, idx: np.ndarray) -> List[bytes]:
        """Materialize the value payloads of the given write lanes."""
        buf = self.buf
        return [
            buf[o : o + ln]
            for o, ln in zip(self.val_off[idx].tolist(), self.val_len[idx].tolist())
        ]


def _keys_fixed_from_buf(
    u8: np.ndarray, koff: np.ndarray, klen: np.ndarray
) -> np.ndarray:
    """Build the sentinel-terminated fixed-width key matrix straight from
    the blob bytes (matches ``ColumnarLog.encode_keys_fixed``: key +
    ``\\x01`` terminator, NUL-padded to a multiple of 8)."""
    w = len(koff)
    if w == 0:
        return np.empty(0, dtype="S8")
    width = -(-(int(klen.max()) + 1) // 8) * 8
    # one (W, width) gather, clipped to stay in-bounds; lanes past each key's
    # true length are zeroed, then the terminator lands per lane
    idx = koff[:, None] + np.arange(width, dtype=np.int64)[None, :]
    mat = u8[np.minimum(idx, len(u8) - 1)]
    mat[np.arange(width)[None, :] >= klen[:, None]] = 0
    mat[np.arange(w), klen] = 1
    return np.ascontiguousarray(mat).view(f"S{width}").reshape(w)


def decode_fast_tile(buf: bytes, crc: Optional[int] = None) -> Optional[FastTile]:
    """Vectorized twin of :func:`~repro.core.txn.decode_columnar_stream` for
    the replay pipeline; ``None`` when the blob needs the scalar-equivalent
    walk (XSHARD footers / out-of-profile write counts).

    ``crc`` is the blob's seal-time segment crc32 when the caller has one
    (sealed segments via ``StorageDevice.read_segment_entries``): a single
    whole-blob ``zlib.crc32`` match covers every frame crc inside, so the
    per-frame verification loop is skipped; a mismatch — or no crc, e.g.
    the torn-able tail — keeps the frame-by-frame truncation semantics.
    """
    trusted = crc is not None and zlib.crc32(buf) == crc
    rec_off, plen, consumed = frame_scan(buf, skip_crc=trusted)
    n = len(rec_off)
    u8 = np.frombuffer(buf, dtype=np.uint8)
    if n == 0:
        return FastTile(
            buf=buf,
            ssn=np.empty(0, np.int64),
            has_reads=np.empty(0, bool),
            wr_rec=np.empty(0, np.int64),
            keys_fixed=np.empty(0, dtype="S8"),
            val_off=np.empty(0, np.int64),
            val_len=np.empty(0, np.int64),
            consumed=consumed,
        )

    pay = rec_off + _HDR.size
    ssn = gather_u64(u8, pay)
    flags = u8[pay + 16].astype(np.int64)      # after u64 ssn + u64 tid
    nw = gather_u32(u8, pay + 17)
    if (
        (flags & FLAG_XSHARD).any()
        or (flags & FLAG_COMMAND).any()
        or (nw > MAX_FAST_WRITES).any()
    ):
        return None

    end = pay + plen                 # payload end per record
    total = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(nw, out=total[1:])
    n_writes = int(total[-1])
    wr_rec = np.empty(n_writes, np.int64)
    koff = np.empty(n_writes, np.int64)
    klen = np.empty(n_writes, np.int64)
    voff = np.empty(n_writes, np.int64)
    vlen = np.empty(n_writes, np.int64)

    # resolve the variable-length write chains: round j advances the cursor
    # of every record that still owes a j-th write.  Bounds checks mirror the
    # scalar walk exactly (checked before each u32 length read; key/value
    # slices clip at the payload end), so the first malformed record — and
    # everything after it, like the scalar truncation — is dropped.
    cursor = pay + _PAYLOAD_FIXED.size
    good = n
    safe = len(u8) - 4 if len(u8) >= 4 else 0
    for j in range(int(nw.max()) if n else 0):
        act = np.flatnonzero(nw > j)
        if not len(act):
            break
        cur = cursor[act]
        rec_end = end[act]
        ok = cur + 4 <= rec_end
        kl = gather_u32(u8, np.minimum(cur, safe))
        ko = cur + 4
        cur2 = ko + kl
        ok &= cur2 + 4 <= rec_end
        vl = gather_u32(u8, np.minimum(cur2, safe))
        vo = cur2 + 4
        bad = np.flatnonzero(~ok)
        if len(bad):
            good = min(good, int(act[bad[0]]))
        slot = total[act] + j
        wr_rec[slot] = act
        koff[slot] = ko
        klen[slot] = np.minimum(kl, np.maximum(rec_end - ko, 0))
        voff[slot] = vo
        vlen[slot] = np.minimum(vl, np.maximum(rec_end - vo, 0))
        cursor[act] = vo + vl

    if good < n:
        consumed = int(rec_off[good])
        n = good
        ssn = ssn[:n]
        flags = flags[:n]
        w_keep = int(total[n])
        wr_rec = wr_rec[:w_keep]
        koff, klen = koff[:w_keep], klen[:w_keep]
        voff, vlen = voff[:w_keep], vlen[:w_keep]

    return FastTile(
        buf=buf,
        ssn=ssn,
        has_reads=(flags & FLAG_HAS_READS) != 0,
        wr_rec=wr_rec,
        keys_fixed=_keys_fixed_from_buf(u8, koff, klen),
        val_off=voff,
        val_len=vlen,
        consumed=consumed,
    )
