"""Transaction objects and log-record framing for the Poplar engine.

The paper (§2) assumes each transaction produces a single log record holding
all of its writes.  A record here is framed as::

    [u32 length][u32 crc32-of-payload][payload]
    payload := [u64 ssn][u64 tid][u8 flags][u32 n_writes]
               n_writes * ([u32 key_len][key bytes][u32 val_len][val bytes])

``flags`` bit 0: HAS_READS — the transaction had a read set, i.e. it was
committed through the Qwr / CSN path and carries potential RAW dependencies.
Write-only (Qww) records may be replayed past RSNe during recovery (§5);
records with HAS_READS may not.

``flags`` bit 1: XSHARD — the record belongs to a cross-shard transaction
(`repro.shard`).  The payload then carries a dependency footer after the
writes::

    footer := [u32 n_parts] n_parts * ([u32 shard_id][u64 ssn])

listing every participating shard and the SSN the transaction holds there —
the explicit cross-shard WAW/RAW dependency edge.  The transaction's global
id (gtid) is the record's ``tid``, identical on every participant, so
sharded recovery can resolve a consistent cut: a cross-shard transaction is
replayed iff a record with its gtid is durable on *all* participants (see
``repro.shard.recovery``).

The length+crc framing makes torn tail writes detectable: recovery truncates
the log at the first bad frame, which is exactly the paper's "buffer hole"
semantics at the device level.
"""

from __future__ import annotations

import struct
import zlib
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

FLAG_HAS_READS = 0x01
FLAG_XSHARD = 0x02

_HDR = struct.Struct("<II")           # length, crc32
_PAYLOAD_FIXED = struct.Struct("<QQBI")  # ssn, tid, flags, n_writes
_U32 = struct.Struct("<I")
_XPART = struct.Struct("<IQ")         # shard_id, ssn (xdep footer entry)


@dataclass
class Txn:
    """A transaction as seen by the logging subsystem."""

    tid: int
    # read set: list of (key, ssn observed at read time)
    read_set: List[Tuple[Any, int]] = field(default_factory=list)
    # write set: list of (key, new value bytes)
    write_set: List[Tuple[Any, bytes]] = field(default_factory=list)

    # Filled in by the engine:
    ssn: int = -1
    buffer_id: int = -1
    offset: int = -1          # logical offset of the record in its log buffer
    record: bytes = b""

    # cross-shard dependency edge (repro.shard): every participant shard and
    # the SSN this transaction holds there; None for single-shard records
    xdep: Optional[List[Tuple[int, int]]] = None

    # lifecycle timestamps (perf accounting)
    t_start: float = 0.0
    t_precommit: float = 0.0  # SSN allocated + record buffered ("pre-committed")
    t_commit: float = 0.0     # durably committed
    committed: bool = False
    aborted: bool = False

    @property
    def has_reads(self) -> bool:
        return bool(self.read_set)

    @property
    def write_only(self) -> bool:
        return not self.read_set

    def encode(self) -> bytes:
        """Serialize this transaction into a single framed log record."""
        flags = FLAG_HAS_READS if self.has_reads else 0
        if self.xdep is not None:
            flags |= FLAG_XSHARD
        parts = [
            _PAYLOAD_FIXED.pack(self.ssn, self.tid, flags, len(self.write_set))
        ]
        for key, val in self.write_set:
            kb = key.encode() if isinstance(key, str) else bytes(key)
            parts.append(_U32.pack(len(kb)))
            parts.append(kb)
            parts.append(_U32.pack(len(val)))
            parts.append(val)
        if self.xdep is not None:
            parts.append(_U32.pack(len(self.xdep)))
            for shard_id, ssn in self.xdep:
                parts.append(_XPART.pack(shard_id, ssn))
        payload = b"".join(parts)
        self.record = _HDR.pack(len(payload), zlib.crc32(payload)) + payload
        return self.record


# the frame prefix of a record as an unaligned structured dtype: exactly
# _HDR ("<II") followed by _PAYLOAD_FIXED ("<QQBI"), 29 bytes
_FRAME_DTYPE = np.dtype(
    {
        "names": ["len", "crc", "ssn", "tid", "flags", "nw"],
        "formats": ["<u4", "<u4", "<u8", "<u8", "u1", "<u4"],
        "offsets": [0, 4, 8, 16, 24, 25],
        "itemsize": _HDR.size + _PAYLOAD_FIXED.size,
    }
)


def _scatter_ranges(starts: np.ndarray, width: int) -> np.ndarray:
    """Flat indices of ``n`` byte ranges ``[starts[i], starts[i]+width)``."""
    return (starts[:, None] + np.arange(width, dtype=np.int64)).ravel()


def encode_batch(txns: Sequence["Txn"]) -> Tuple[bytes, np.ndarray]:
    """Encode a batch of transactions into one contiguous framed blob —
    byte-identical to ``b"".join(t.encode() for t in txns)``, i.e. exactly
    the stream :func:`decode_columnar` reads back during recovery.

    The encode is columnar: every fixed-width field (frame headers, payload
    fixed parts, per-write key/value length prefixes) is computed as a numpy
    column and scattered into the output buffer in one fancy-index per
    column; the only per-item Python left is one memcpy per key/value blob
    and one ``zlib.crc32`` per record.  This is the encode half of the
    batched forward path: the caller reserves a contiguous region via
    :meth:`~repro.core.log_buffer.LogBuffer.reserve_batch` and fills it with
    the returned blob in one ring memcpy.

    Returns ``(blob, framed_lengths)``; ``framed_lengths[i]`` matches what
    ``Txn.encode`` would report for ``txns[i]``.
    """
    n = len(txns)
    if n == 0:
        return b"", np.empty(0, dtype=np.int64)

    kbs: List[bytes] = []
    vals: List[bytes] = []
    nw_l: List[int] = []
    ssn_l: List[int] = []
    tid_l: List[int] = []
    flag_l: List[int] = []
    for t in txns:
        nw_l.append(len(t.write_set))
        ssn_l.append(t.ssn)
        tid_l.append(t.tid)
        flag_l.append(FLAG_HAS_READS if t.read_set else 0)
        for key, val in t.write_set:
            kbs.append(key.encode() if isinstance(key, str) else bytes(key))
            vals.append(val)
    return encode_batch_columns(
        np.asarray(ssn_l, dtype=np.int64),
        np.asarray(tid_l, dtype=np.int64),
        np.asarray(flag_l, dtype=np.uint8),
        np.asarray(nw_l, dtype=np.int64),
        kbs,
        vals,
    )


def encode_batch_columns(
    ssn: np.ndarray,                 # (n,) per-record SSN
    tid: np.ndarray,                 # (n,) per-record tid
    flags: np.ndarray,               # (n,) uint8 flags (FLAG_HAS_READS)
    nw: np.ndarray,                  # (n,) writes per record
    kbs: Sequence[bytes],            # flattened key bytes, record-major
    vals: Sequence[bytes],           # flattened value bytes, record-major
    klen: Optional[np.ndarray] = None,
    vlen: Optional[np.ndarray] = None,
) -> Tuple[bytes, np.ndarray]:
    """Columnar core of :func:`encode_batch`: frame a batch straight from
    arrays — the fully array-native entry used by the indexed batch pipeline
    (`repro.db.batch.BatchOCC.execute_indexed`), where keys/lengths come
    from the table's columns instead of per-``Txn`` objects."""
    n = len(ssn)
    if n == 0:
        return b"", np.empty(0, dtype=np.int64)
    frame = _FRAME_DTYPE.itemsize
    if klen is None:
        klen = np.fromiter(map(len, kbs), np.int64, len(kbs))
    if vlen is None:
        vlen = np.fromiter(map(len, vals), np.int64, len(vals))
    wlen = 8 + klen + vlen                       # framed bytes per write

    wstart = np.zeros(n + 1, dtype=np.int64)     # per-txn write-slice prefix
    np.cumsum(nw, out=wstart[1:])
    wcs = np.zeros(len(kbs) + 1, dtype=np.int64)
    np.cumsum(wlen, out=wcs[1:])
    plen = _PAYLOAD_FIXED.size + wcs[wstart[1:]] - wcs[wstart[:-1]]
    lengths = _HDR.size + plen
    rec_off = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(lengths, out=rec_off[1:])
    out = np.zeros(int(rec_off[-1]), dtype=np.uint8)

    # frame prefixes (len/ssn/tid/flags/nw; crc patched after the blobs land)
    hdr = np.zeros(n, dtype=_FRAME_DTYPE)
    hdr["len"] = plen
    hdr["ssn"] = np.asarray(ssn, dtype=np.int64).view(np.uint64)
    hdr["tid"] = np.asarray(tid, dtype=np.int64).view(np.uint64)
    hdr["flags"] = flags
    hdr["nw"] = nw
    out[_scatter_ranges(rec_off[:-1], frame)] = hdr.view(np.uint8)

    if len(kbs):
        # absolute offset of each write's framed region
        intra = wcs[:-1] - np.repeat(wcs[wstart[:-1]], nw)
        woff = np.repeat(rec_off[:-1] + frame, nw) + intra
        out[_scatter_ranges(woff, 4)] = (
            klen.astype("<u4").view(np.uint8).reshape(-1, 4).ravel()
        )
        voff = woff + 4 + klen
        out[_scatter_ranges(voff, 4)] = (
            vlen.astype("<u4").view(np.uint8).reshape(-1, 4).ravel()
        )
        mv = memoryview(out)
        for o, ln, kb in zip((woff + 4).tolist(), klen.tolist(), kbs):
            mv[o : o + ln] = kb
        for o, ln, vb in zip((voff + 4).tolist(), vlen.tolist(), vals):
            mv[o : o + ln] = vb

    # per-record CRC over the payload bytes, patched into the header column
    mv = memoryview(out)
    crc32 = zlib.crc32
    crcs = np.fromiter(
        (
            crc32(mv[p : p + ln])
            for p, ln in zip((rec_off[:-1] + _HDR.size).tolist(), plen.tolist())
        ),
        np.uint32,
        n,
    )
    out[_scatter_ranges(rec_off[:-1] + 4, 4)] = (
        crcs.astype("<u4").view(np.uint8).reshape(-1, 4).ravel()
    )
    return out.tobytes(), lengths


@dataclass
class LogRecord:
    """A decoded log record (recovery side)."""

    ssn: int
    tid: int
    has_reads: bool
    writes: List[Tuple[bytes, bytes]]
    # cross-shard dependency edge: [(shard_id, ssn), ...] over every
    # participant; None for single-shard records.  The gtid is ``tid``.
    xdep: Optional[List[Tuple[int, int]]] = None

    @property
    def write_only(self) -> bool:
        return not self.has_reads


def decode_records(buf: bytes) -> List[LogRecord]:
    """Decode a byte stream of framed records, truncating at the first torn
    or corrupt frame (paper §5: only fully durable records participate)."""
    out: List[LogRecord] = []
    off = 0
    n = len(buf)
    while off + _HDR.size <= n:
        length, crc = _HDR.unpack_from(buf, off)
        start = off + _HDR.size
        end = start + length
        if end > n:
            break  # torn tail write
        payload = buf[start:end]
        if zlib.crc32(payload) != crc:
            break  # corrupt frame: stop (holes never precede valid frames on
            # a device because segments flush sequentially)
        ssn, tid, flags, n_writes = _PAYLOAD_FIXED.unpack_from(payload, 0)
        pos = _PAYLOAD_FIXED.size
        writes: List[Tuple[bytes, bytes]] = []
        ok = True
        for _ in range(n_writes):
            if pos + 4 > length:
                ok = False
                break
            (klen,) = _U32.unpack_from(payload, pos)
            pos += 4
            key = payload[pos : pos + klen]
            pos += klen
            if pos + 4 > length:
                ok = False
                break
            (vlen,) = _U32.unpack_from(payload, pos)
            pos += 4
            val = payload[pos : pos + vlen]
            pos += vlen
            writes.append((key, val))
        xdep: Optional[List[Tuple[int, int]]] = None
        if ok and flags & FLAG_XSHARD:
            xdep, pos = _decode_xdep(payload, pos, length)
            ok = xdep is not None
        if not ok:
            break
        out.append(
            LogRecord(
                ssn=ssn,
                tid=tid,
                has_reads=bool(flags & FLAG_HAS_READS),
                writes=writes,
                xdep=xdep,
            )
        )
        off = end
    return out


def _decode_xdep(
    payload: bytes, pos: int, length: int
) -> Tuple[Optional[List[Tuple[int, int]]], int]:
    """Parse the XSHARD dependency footer; ``(None, pos)`` on a bounds error
    (torn frame — the caller stops decoding, like any other malformed frame)."""
    if pos + 4 > length:
        return None, pos
    (n_parts,) = _U32.unpack_from(payload, pos)
    pos += 4
    if pos + n_parts * _XPART.size > length:
        return None, pos
    parts: List[Tuple[int, int]] = []
    for _ in range(n_parts):
        shard_id, ssn = _XPART.unpack_from(payload, pos)
        pos += _XPART.size
        parts.append((shard_id, ssn))
    return parts, pos


@dataclass
class ColumnarLog:
    """A decoded device log in columnar (struct-of-arrays) form.

    Per-record columns (length ``n_records``):

    * ``ssn``       — int64, monotone within one device log (flush order);
    * ``tid``       — int64;
    * ``has_reads`` — bool; write-only (Qww) records have ``has_reads=False``
      and may be replayed past RSNe, HAS_READS (Qwr) records may not;
    * ``n_writes``  — int32 writes carried by each record.

    Per-write columns (length ``n_writes.sum()``), flattened record-major so
    write ``j`` belongs to record ``wr_rec[j]``:

    * ``wr_rec``  — int64 owning-record index;
    * ``wr_klen`` — int64 true key length in bytes;
    * ``keys_fixed`` — the keys in a fixed-width numpy ``'S'`` array holding
      ``key + b"\\x01"`` NUL-padded to a multiple of 8 (so replay can
      reinterpret it as int64 words without copying).  The ``\\x01``
      terminator makes the padded cell an *exact*, self-delimiting key
      identity — raw NUL padding alone would make ``b"a"`` and ``b"a\\0"``
      compare equal under 'S' semantics.  Recover the original bytes by
      stripping trailing NULs and dropping the final byte (decode it with
      :meth:`fixed_to_key`);
    * ``keys`` / ``values`` — the raw bytes (variable length, Python lists;
      replay touches these only to materialize the winning entries).

    This is the decode format of the batched replay path: recovery never
    materializes per-record Python objects, it reduces these arrays directly
    (see :func:`repro.core.recovery.replay_columnar`).
    """

    ssn: np.ndarray
    tid: np.ndarray
    has_reads: np.ndarray
    n_writes: np.ndarray
    wr_rec: np.ndarray
    wr_klen: np.ndarray
    keys_fixed: np.ndarray
    keys: List[bytes]
    values: List[bytes]
    _values_obj: Optional[np.ndarray] = None
    # cross-shard dependency columns (``None`` when the log carries no
    # XSHARD records — the common case, and the shape every pre-shard
    # constructor produces).  ``x_rec[i]`` is the owning record index of the
    # i-th cross-shard record, ``xp_start`` the ``(len(x_rec)+1,)`` prefix
    # delimiting its participant slice of ``xp_shard``/``xp_ssn``.  The gtid
    # of ``x_rec[i]`` is ``tid[x_rec[i]]``.
    x_rec: Optional[np.ndarray] = None
    xp_start: Optional[np.ndarray] = None
    xp_shard: Optional[np.ndarray] = None
    xp_ssn: Optional[np.ndarray] = None

    @property
    def n_records(self) -> int:
        return len(self.ssn)

    @staticmethod
    def encode_keys_fixed(keys: Sequence[bytes], klens: Sequence[int]) -> np.ndarray:
        """Build the sentinel-terminated fixed-width key array (see class
        docstring) for ``keys`` with known lengths ``klens``."""
        if not len(keys):
            return np.empty(0, dtype="S8")
        width = -(-(max(klens) + 1) // 8) * 8
        arr = np.asarray(keys, dtype=f"S{width}")
        u8 = arr.view(np.uint8).reshape(len(arr), width)
        u8[np.arange(len(arr)), np.asarray(klens)] = 1
        return arr

    @staticmethod
    def fixed_to_key(cell: bytes) -> bytes:
        """Invert the ``keys_fixed`` encoding for one (NUL-stripped) cell."""
        return cell[:-1]

    @property
    def values_obj(self) -> np.ndarray:
        """The values as an object ndarray (cached) — lets replay gather the
        winning payloads with one fancy-index instead of per-item list ops."""
        if self._values_obj is None:
            self._values_obj = np.fromiter(self.values, dtype=object, count=len(self.values))
        return self._values_obj

    @property
    def last_ssn(self) -> int:
        """SSN of the most recently durable record (device DSN frontier)."""
        return int(self.ssn[-1]) if len(self.ssn) else 0

    @property
    def wr_ssn(self) -> np.ndarray:
        """Per-write SSN (gathered from the owning record)."""
        return self.ssn[self.wr_rec]

    @property
    def wr_has_reads(self) -> np.ndarray:
        return self.has_reads[self.wr_rec]

    @property
    def n_xshard(self) -> int:
        return 0 if self.x_rec is None else len(self.x_rec)

    @staticmethod
    def concat(parts: Sequence["ColumnarLog"]) -> "ColumnarLog":
        """Concatenate decoded chunks of one log stream in arrival order —
        equivalent to decoding the concatenated bytes (incremental tailers
        decode only new frames and splice the chunks with this)."""
        parts = [p for p in parts if p.n_records]
        if not parts:
            return decode_columnar(b"")
        if len(parts) == 1:
            return parts[0]
        rec_off = np.cumsum([0] + [p.n_records for p in parts])
        keys: List[bytes] = []
        values: List[bytes] = []
        klens: List[int] = []
        x_rec: List[np.ndarray] = []
        xp_shard: List[np.ndarray] = []
        xp_ssn: List[np.ndarray] = []
        xp_start_parts: List[np.ndarray] = []
        xp_off = 0
        for i, p in enumerate(parts):
            keys.extend(p.keys)
            values.extend(p.values)
            klens.extend(p.wr_klen.tolist())
            if p.x_rec is not None:
                x_rec.append(p.x_rec + rec_off[i])
                xp_shard.append(p.xp_shard)
                xp_ssn.append(p.xp_ssn)
                xp_start_parts.append(p.xp_start[1:] + xp_off)
                xp_off += int(p.xp_start[-1])
        has_x = bool(x_rec)
        return ColumnarLog(
            ssn=np.concatenate([p.ssn for p in parts]),
            tid=np.concatenate([p.tid for p in parts]),
            has_reads=np.concatenate([p.has_reads for p in parts]),
            n_writes=np.concatenate([p.n_writes for p in parts]),
            wr_rec=np.concatenate(
                [p.wr_rec + rec_off[i] for i, p in enumerate(parts)]
            ),
            wr_klen=np.asarray(klens, dtype=np.int64),
            keys_fixed=ColumnarLog.encode_keys_fixed(keys, klens),
            keys=keys,
            values=values,
            x_rec=np.concatenate(x_rec) if has_x else None,
            xp_start=np.concatenate([np.zeros(1, np.int64)] + xp_start_parts)
            if has_x else None,
            xp_shard=np.concatenate(xp_shard) if has_x else None,
            xp_ssn=np.concatenate(xp_ssn) if has_x else None,
        )

    def to_records(self) -> List[LogRecord]:
        """Round-trip back to row objects (tests / scalar-oracle interop)."""
        xdeps: Dict[int, List[Tuple[int, int]]] = {}
        if self.x_rec is not None:
            for i, rec in enumerate(self.x_rec.tolist()):
                lo, hi = int(self.xp_start[i]), int(self.xp_start[i + 1])
                xdeps[rec] = list(
                    zip(self.xp_shard[lo:hi].tolist(), self.xp_ssn[lo:hi].tolist())
                )
        out: List[LogRecord] = []
        w = 0
        for i in range(self.n_records):
            nw = int(self.n_writes[i])
            out.append(
                LogRecord(
                    ssn=int(self.ssn[i]),
                    tid=int(self.tid[i]),
                    has_reads=bool(self.has_reads[i]),
                    writes=list(zip(self.keys[w : w + nw], self.values[w : w + nw])),
                    xdep=xdeps.get(i),
                )
            )
            w += nw
        return out


def decode_columnar(buf: bytes) -> ColumnarLog:
    """Columnar twin of :func:`decode_records`: one pass over the framed
    stream, truncating at the first torn or corrupt frame, emitting arrays
    instead of ``LogRecord`` objects.

    Same validation as the scalar decoder (length + crc32 per frame, bounds
    checks on every write) so torn-tail semantics are byte-identical.
    """
    return decode_columnar_stream(buf)[0]


def decode_columnar_stream(buf: bytes) -> Tuple[ColumnarLog, int]:
    """Incremental-framing variant of :func:`decode_columnar`: returns
    ``(log, consumed)`` where ``consumed`` is the byte offset of the first
    frame that did not decode — torn (runs past the end of ``buf``), corrupt
    (crc mismatch), or truncated mid-payload.

    This is the streaming contract of log shipping
    (`repro.replica.LogShipper`): on a *live* log a bad trailing frame just
    means the writer's append has not fully landed yet, so the tailer keeps
    the bytes from ``consumed`` on and retries once more bytes arrive — it
    never decodes a partial record.  A crash-recovery caller discards the
    remainder instead; both behaviours share this one decoder, so shipped
    and recovered torn-tail semantics are byte-identical.
    """
    ssns: List[int] = []
    tids: List[int] = []
    flags_l: List[bool] = []
    nw_l: List[int] = []
    wr_rec: List[int] = []
    klens: List[int] = []
    keys: List[bytes] = []
    values: List[bytes] = []
    x_rec: List[int] = []
    xp_shard: List[int] = []
    xp_ssn: List[int] = []
    xp_start: List[int] = [0]

    off = 0
    n = len(buf)
    rec_i = 0
    while off + _HDR.size <= n:
        length, crc = _HDR.unpack_from(buf, off)
        start = off + _HDR.size
        end = start + length
        if end > n:
            break  # torn tail write
        payload = buf[start:end]
        if zlib.crc32(payload) != crc:
            break
        ssn, tid, flags, n_writes = _PAYLOAD_FIXED.unpack_from(payload, 0)
        pos = _PAYLOAD_FIXED.size
        ok = True
        wrote = 0
        for _ in range(n_writes):
            if pos + 4 > length:
                ok = False
                break
            (klen,) = _U32.unpack_from(payload, pos)
            pos += 4
            key = payload[pos : pos + klen]
            pos += klen
            if pos + 4 > length:
                ok = False
                break
            (vlen,) = _U32.unpack_from(payload, pos)
            pos += 4
            val = payload[pos : pos + vlen]
            pos += vlen
            keys.append(key)
            values.append(val)
            wr_rec.append(rec_i)
            klens.append(klen)
            wrote += 1
        if ok and flags & FLAG_XSHARD:
            parts, pos = _decode_xdep(payload, pos, length)
            if parts is None:
                ok = False
            else:
                x_rec.append(rec_i)
                for shard_id, pssn in parts:
                    xp_shard.append(shard_id)
                    xp_ssn.append(pssn)
                xp_start.append(len(xp_shard))
        if not ok:
            # drop the partial record's writes and stop at the bad frame
            del keys[len(keys) - wrote :]
            del values[len(values) - wrote :]
            del wr_rec[len(wr_rec) - wrote :]
            del klens[len(klens) - wrote :]
            break
        ssns.append(ssn)
        tids.append(tid)
        flags_l.append(bool(flags & FLAG_HAS_READS))
        nw_l.append(n_writes)
        rec_i += 1
        off = end

    return _columnar_from_lists(
        ssns, tids, flags_l, nw_l, wr_rec, klens, keys, values,
        x_rec, xp_start, xp_shard, xp_ssn,
    ), off


def _columnar_from_lists(
    ssns, tids, flags_l, nw_l, wr_rec, klens, keys, values,
    x_rec, xp_start, xp_shard, xp_ssn,
) -> ColumnarLog:
    return ColumnarLog(
        ssn=np.asarray(ssns, dtype=np.int64),
        tid=np.asarray(tids, dtype=np.int64),
        has_reads=np.asarray(flags_l, dtype=bool),
        n_writes=np.asarray(nw_l, dtype=np.int32),
        wr_rec=np.asarray(wr_rec, dtype=np.int64),
        wr_klen=np.asarray(klens, dtype=np.int64),
        keys_fixed=ColumnarLog.encode_keys_fixed(keys, klens),
        keys=keys,
        values=values,
        x_rec=np.asarray(x_rec, dtype=np.int64) if x_rec else None,
        xp_start=np.asarray(xp_start, dtype=np.int64) if x_rec else None,
        xp_shard=np.asarray(xp_shard, dtype=np.int64) if x_rec else None,
        xp_ssn=np.asarray(xp_ssn, dtype=np.int64) if x_rec else None,
    )


def gather_u32(u8: np.ndarray, off: np.ndarray) -> np.ndarray:
    """Little-endian u32 values at arbitrary byte offsets of a uint8 view —
    the unaligned-field gather of the vectorized frame scan (int64 out)."""
    o = off.astype(np.int64, copy=False)
    return (
        u8[o].astype(np.int64)
        | u8[o + 1].astype(np.int64) << 8
        | u8[o + 2].astype(np.int64) << 16
        | u8[o + 3].astype(np.int64) << 24
    )


def gather_u64(u8: np.ndarray, off: np.ndarray) -> np.ndarray:
    """Little-endian u64 gather (int64 out — engine SSNs/tids are < 2^63)."""
    o = off.astype(np.int64, copy=False)
    acc = u8[o].astype(np.int64)
    for j in range(1, 8):
        acc |= u8[o + j].astype(np.int64) << (8 * j)
    return acc


def frame_scan(
    buf: bytes, skip_crc: bool = False
) -> Tuple[np.ndarray, np.ndarray, int]:
    """Vectorized framing scan: offsets and payload lengths of every intact
    frame of ``buf``, truncated at the first torn or crc-corrupt frame —
    byte-identical boundaries to the scalar walk in
    :func:`decode_columnar_stream`, without per-record struct unpacking.

    The offset chase is run-speculative: consecutive records of one log
    buffer overwhelmingly share a framed length (fixed-size workloads
    produce exactly one run), so the scan guesses that frame ``i+1`` repeats
    frame ``i``'s length, verifies the whole run with one strided gather,
    and only falls back to stepping on a length change.  CRC validation is
    one C-speed ``zlib.crc32`` per frame over a zero-copy memoryview;
    ``skip_crc`` elides it entirely when the caller has already verified the
    blob wholesale against its seal-time segment crc (the manifest field a
    sealed segment carries — a whole-blob match implies every frame crc
    matches, since the frame crcs are part of the covered bytes).

    Returns ``(rec_off, plen, consumed)``: frame start offsets, payload
    lengths, and the byte offset of the first frame that did not decode.
    """
    u8 = np.frombuffer(buf, dtype=np.uint8)
    n = len(buf)
    hdr = _HDR.size
    parts: List[np.ndarray] = []
    off = 0
    while off + hdr <= n:
        (length,) = _U32.unpack_from(buf, off)
        stride = hdr + length
        if off + stride > n:
            break  # torn tail write
        max_run = (n - off) // stride
        if max_run <= 2:
            parts.append(np.asarray([off], dtype=np.int64))
            off += stride
            continue
        cand = off + np.arange(max_run, dtype=np.int64) * stride
        neq = gather_u32(u8, cand) != length
        run = int(np.argmax(neq)) if neq.any() else max_run
        parts.append(cand[:run])
        off += run * stride
    if not parts:
        return np.empty(0, np.int64), np.empty(0, np.int64), off
    rec_off = np.concatenate(parts)
    plen = gather_u32(u8, rec_off)
    if skip_crc:
        return rec_off, plen, off
    stored_crc = gather_u32(u8, rec_off + 4)
    mv = memoryview(buf)
    crc32 = zlib.crc32
    calc = np.fromiter(
        (
            crc32(mv[p : p + ln])
            for p, ln in zip((rec_off + hdr).tolist(), plen.tolist())
        ),
        np.int64,
        len(rec_off),
    )
    bad = np.flatnonzero(calc != stored_crc)
    if len(bad):
        good = int(bad[0])
        return rec_off[:good], plen[:good], int(rec_off[good])
    return rec_off, plen, off


def record_size(n_writes: int, key_bytes: int, val_bytes: int) -> int:
    """Size of a framed record for napkin math in benchmarks."""
    return _HDR.size + _PAYLOAD_FIXED.size + n_writes * (8 + key_bytes + val_bytes)
